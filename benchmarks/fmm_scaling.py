"""Paper §7 reproduction: strong scaling, efficiency, and load balance.

The paper's experiment: Lamb-Oseen lattice, N = 765,625, tree level 10,
root (cut) level 4, p = 17, P in {1, 4, 8, 16, 32, 64}; reported >90%
parallel efficiency at 32 procs, >85% at 64, LB within 5% / 7% (Figs 6-9).

This container has one CPU, so per-processor *times* are modeled: the §5
cost model supplies per-partition work and cut communication, calibrated
against a real measured serial FMM run (so the absolute scale is honest).
Speedup S = T1 / max_p(T_p + comm_p); LB = min_p T_p / max_p T_p — exactly
the paper's Eqs (18)-(20) evaluated on the modeled schedule.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import cost_model as cm                      # noqa: E402
from repro.core.partition import (build_subtree_graph, partition,  # noqa: E402
                                  load_balance_metric)
from repro.core.vortex import lamb_oseen_particles           # noqa: E402


def paper_counts(level: int = 10, m_side: int = 875) -> np.ndarray:
    """Leaf-box occupancy for the paper's lattice initialization."""
    pos, gamma, sigma = lamb_oseen_particles(m_side)
    n = 1 << level
    ij = np.clip((pos * n).astype(int), 0, n - 1)
    counts = np.zeros((n, n), dtype=np.int64)
    np.add.at(counts, (ij[:, 1], ij[:, 0]), 1)
    return counts


def calibrate_t_flop(level: int = 5, n_particles: int = 20_000, p: int = 12) -> float:
    """Seconds per modeled work unit, from a real serial FMM run."""
    import jax
    from repro.core.fmm import fmm_velocity
    from repro.core.quadtree import build_tree

    rng = np.random.default_rng(0)
    pos = rng.uniform(0.01, 0.99, (n_particles, 2))
    tree, _ = build_tree(pos, rng.normal(size=n_particles), level, 0.02)
    fmm_velocity(tree, p).block_until_ready()          # compile
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        fmm_velocity(tree, p).block_until_ready()
    wall = (time.perf_counter() - t0) / reps

    n = 1 << level
    ij = np.clip((pos * n).astype(int), 0, n - 1)
    counts = np.zeros((n, n), dtype=np.int64)
    np.add.at(counts, (ij[:, 1], ij[:, 0]), 1)
    params = cm.ModelParams(level=level, cut=2, p=p, slots=int(counts.max()))
    work = cm.work_subtree(counts, params).sum()
    return wall / work


def scaling_table(procs=(1, 4, 8, 16, 32, 64), level: int = 10, cut: int = 4,
                  p: int = 17, t_byte: float = 1e-9, t_flop: float | None = None,
                  counts: np.ndarray | None = None) -> list[dict]:
    counts = paper_counts(level) if counts is None else counts
    t_flop = t_flop if t_flop is not None else calibrate_t_flop()
    rows = []
    for P in procs:
        # keep >= 64 subtrees per processor (paper §4: 'more subtrees than
        # processes'; their recursive-cutting remark for larger P — fine
        # granularity is what lets hot subtrees spread across processors)
        k = cut
        while 4 ** k < 64 * P and k < level - 1:
            k += 1
        params = cm.ModelParams(level=level, cut=k, p=p,
                                slots=max(int(counts.max()), 1))
        g = build_subtree_graph(counts, params)
        t1 = g.vertex_weight.sum() * t_flop
        out = {"P": P}
        for method in ("model", "uniform-sfc"):
            assign = partition(g, P, method=method)
            loads = g.part_loads(assign, P) * t_flop
            # per-proc communication = cut edges incident to that proc
            comm = np.zeros(P)
            for u, nbrs in enumerate(g.adjacency):
                for v, w in nbrs:
                    if v > u and assign[u] != assign[v]:
                        comm[assign[u]] += w * t_byte
                        comm[assign[v]] += w * t_byte
            t_par = (loads + comm).max()
            key = "model" if method == "model" else "uniform"
            out[f"T_{key}"] = t_par
            out[f"S_{key}"] = t1 / t_par
            out[f"E_{key}"] = t1 / t_par / P
            out[f"LB_{key}"] = float(loads.min() / loads.max()) if loads.max() else 1.0
        rows.append(out)
    return rows


def cluster_counts(level: int = 8, total: int = 765_625, seed: int = 0,
                   sigma: float = 0.08) -> np.ndarray:
    """Asymmetric two-scale distribution (the case the paper's model exists
    for: uniform-count partitions break down, cf. their DPMTA discussion).

    Note the regime: per-box particle work (n_nd N_i^2, paper Eq 14) must
    dominate the per-box M2L work (p^2 n_IL) for occupancy imbalance to
    matter — hence a shallower tree (higher occupancy) than the lattice run.
    """
    rng = np.random.default_rng(seed)
    n = 1 << level
    n_cl = int(total * 0.7)
    pos = np.concatenate([
        rng.normal((0.3, 0.62), sigma, (n_cl, 2)),
        rng.uniform(0, 1, (total - n_cl, 2)),
    ]).clip(0.001, 0.999)
    ij = (pos * n).astype(int)
    counts = np.zeros((n, n), dtype=np.int64)
    np.add.at(counts, (ij[:, 1], ij[:, 0]), 1)
    return counts


def main():
    t_flop = calibrate_t_flop()
    for label, level, counts in (("lattice(paper §7)", 10, None),
                                 ("clustered(non-uniform)", 8, cluster_counts())):
        rows = scaling_table(t_flop=t_flop, level=level, counts=counts)
        print(f"# {label}")
        print("P,S_model,E_model,LB_model,S_uniform,E_uniform,LB_uniform")
        for r in rows:
            print(f"{r['P']},{r['S_model']:.2f},{r['E_model']:.3f},{r['LB_model']:.3f},"
                  f"{r['S_uniform']:.2f},{r['E_uniform']:.3f},{r['LB_uniform']:.3f}")
    return rows


if __name__ == "__main__":
    main()
