"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), in seconds-per-step on TPU v5e:

    compute    = HLO_FLOPs_per_device / 197e12        (bf16 peak per chip)
    memory     = HLO_bytes_per_device / 819e9         (HBM bandwidth)
    collective = collective_bytes_per_device / 50e9   (ICI per link)

FLOPs/bytes come from our while-trip-corrected HLO walk
(repro.launch.hlo_analysis) over the post-SPMD module, so they are
per-device local quantities already.  MODEL_FLOPS = 6 * N(_active) * tokens.
"""
from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12       # TPU v5e bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs.registry import get_config
    from repro.models.config import SHAPES
    cfg = get_config(arch)
    s = SHAPES[shape_name]
    tokens = s.global_batch * (s.seq_len if s.kind in ("train", "prefill") else 1)
    n = cfg.active_param_count
    flops = 6.0 * n * tokens
    if s.kind == "prefill":
        flops /= 3.0        # forward only (no backward)
    if s.kind == "decode":
        flops /= 3.0
    return flops


def load_cells(dirpath: str) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(cell: dict) -> dict | None:
    if "skipped" in cell or "error" in cell or "hlo_analysis" not in cell:
        return None
    chips = cell["num_chips"]
    fl = cell["hlo_analysis"]["flops"]
    by = cell["hlo_analysis"]["bytes"]
    co = cell["collectives"]["total_bytes"]
    t_c = fl / PEAK_FLOPS
    t_m = by / HBM_BW
    t_n = co / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))[1]
    row = {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "chips": chips,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "bound": dom,
        "step_s": max(t_c, t_m, t_n),
    }
    if cell["arch"] != "petfmm-vortex":
        mf = model_flops(cell["arch"], cell["shape"])
        row["model_flops"] = mf
        row["useful_ratio"] = mf / max(fl * chips, 1.0)
        # roofline fraction: useful FLOP/s achieved at the modeled step time
        row["mfu_bound"] = mf / (row["step_s"] * chips * PEAK_FLOPS)
    return row


def advice(row: dict) -> str:
    a = {
        "compute": "cut recompute (remat policy) / capacity factor; pad less",
        "memory": "fuse + bf16 intermediates; larger blocks to raise arithmetic intensity",
        "collective": "reshard to cut FSDP regathers; overlap collectives with compute",
    }
    return a[row["bound"]]


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bound | 6ND/HLO | MFU bound |\n|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['bound']} "
            f"| {r.get('useful_ratio', float('nan')):.3f} "
            f"| {r.get('mfu_bound', float('nan')):.3f} |\n")
    return "".join(out)


def main(dirpath: str = "experiments/dryrun", out_csv: str | None = None):
    rows = [r for r in (roofline_row(c) for c in load_cells(dirpath)) if r]
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    print("arch,shape,mesh,chips,compute_s,memory_s,collective_s,bound,"
          "useful_ratio,mfu_bound")
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r['chips']},"
              f"{r['compute_s']:.4f},{r['memory_s']:.4f},{r['collective_s']:.4f},"
              f"{r['bound']},{r.get('useful_ratio', float('nan')):.4f},"
              f"{r.get('mfu_bound', float('nan')):.4f}")
    if out_csv:
        with open(out_csv, "w") as f:
            f.write(markdown_table(rows))
    return rows


if __name__ == "__main__":
    main(*sys.argv[1:])
