"""Benchmark harness: one entry per paper table/figure + kernel benches.

Prints ``name,us_per_call,derived`` CSV rows (the scaffold contract).
``derived`` carries the figure-specific metric (efficiency, LB, GB/s, ...).
Run: PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]

``--json PATH`` additionally writes the rows as a JSON list of
``{"name", "us_per_call", "derived"}`` objects — the machine-readable
baseline the perf acceptance criteria diff against (BENCH_fmm.json).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _time(fn, reps=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_fig6_stage_timings(rows, quick=False):
    """Paper Fig 6: per-stage FMM timings (measured, serial, CPU)."""
    import jax
    from repro.core import expansions as ex
    from repro.core.fmm import fmm_velocity, near_field, upward_sweep
    from repro.core.quadtree import build_tree

    n_particles, level, p = (20_000, 5, 12) if quick else (100_000, 6, 17)
    rng = np.random.default_rng(0)
    pos = rng.uniform(0.01, 0.99, (n_particles, 2))
    tree, _ = build_tree(pos, rng.normal(size=n_particles), level, 0.02)

    total = _time(lambda: jax.block_until_ready(fmm_velocity(tree, p)))
    rows.append(("fig6_total_fmm", total, f"N={n_particles}_L={level}_p={p}"))

    up = jax.jit(lambda t: upward_sweep(t, p)[0], static_argnames=())
    rows.append(("fig6_upward_sweep", _time(lambda: jax.block_until_ready(up(tree))),
                 "P2M+M2M"))
    me = upward_sweep(tree, p)
    m2l = jax.jit(lambda g: ex.m2l_reference(g, level, p))
    m2l_t = _time(lambda: jax.block_until_ready(m2l(me[level])))
    rows.append(("fig6_m2l_leaf_level", m2l_t, "M2L_parity_folded"))
    # same-op comparison: the pre-folding 40-offset masked formulation
    m2l40 = jax.jit(lambda g: ex.m2l_masked40(g, level, p))
    m2l40_t = _time(lambda: jax.block_until_ready(m2l40(me[level])))
    rows.append(("fig6_m2l_leaf_level_masked40", m2l40_t,
                 f"folded_speedup={m2l40_t / max(m2l_t, 1e-9):.2f}x"))
    nearf = jax.jit(near_field)
    rows.append(("fig6_p2p_near_field",
                 _time(lambda: jax.block_until_ready(nearf(tree))), "P2P"))


def bench_fig7_9_scaling(rows, quick=False):
    """Paper Figs 7-9: speedup / efficiency / load balance (modeled)."""
    from benchmarks.fmm_scaling import scaling_table
    level = 8 if quick else 10
    t = scaling_table(level=level, cut=4)
    for r in t:
        rows.append((f"fig7_speedup_P{r['P']}", 0.0, f"{r['S_model']:.2f}"))
        rows.append((f"fig8_efficiency_P{r['P']}", 0.0, f"{r['E_model']:.3f}"))
        rows.append((f"fig9_loadbalance_P{r['P']}", 0.0,
                     f"model={r['LB_model']:.3f}_uniform={r['LB_uniform']:.3f}"))


def bench_table12_memory(rows, quick=False):
    """Paper §5.3 Tables 1-2 + the 64M-particle headline (<1.01 GB/proc)."""
    from repro.core import cost_model as cm
    params = cm.ModelParams(level=10, cut=4, p=17, slots=1)
    mem = cm.memory_serial(params, 765_625)
    rows.append(("table1_serial_total_MB", 0.0, f"{sum(mem.values())/1e6:.1f}"))
    par = cm.memory_parallel(params, 64, 256, 64)
    rows.append(("table2_parallel_total_MB", 0.0, f"{sum(par.values())/1e6:.1f}"))
    # 64M particles / 64 procs headline (paper: 115.8 s, < 1.01 GB/proc)
    params64 = cm.ModelParams(level=12, cut=5, p=17, slots=4)
    per_proc = (sum(cm.memory_serial(params64, 64_000_000).values()) / 64 +
                sum(cm.memory_parallel(params64, 64, 1024, 128).values()))
    rows.append(("headline_64M_per_proc_paperTable_GB", 0.0, f"{per_proc/1e9:.2f}"))
    # our dense implementation stores NO interaction lists/values (generated
    # from the 40 static offsets — the paper's own 'future improvement'):
    L, p, s = 12, 17, 4
    nleaf = 4 ** L
    lam = cm.total_boxes(L)
    ours = (nleaf * s * (8 + 8 + 1 + 8)      # z, q, mask, W
            + lam * p * 8 * 2) / 64          # ME + LE grids (complex64)
    rows.append(("headline_64M_per_proc_ours_GB", 0.0, f"{ours/1e9:.2f}"))


def bench_kernels(rows, quick=False):
    """Pallas kernels vs jnp reference, same op on both sides (CPU: the
    kernels run in the Pallas interpreter, so their wall time is a
    validation-mode number; 'derived' reports the oracle error)."""
    import jax
    import jax.numpy as jnp
    from repro.core import expansions as ex
    from repro.kernels import ref
    from repro.kernels.m2l import m2l_pallas
    from repro.kernels.p2p import p2p_pallas
    from repro.kernels.flash_attn import flash_attention

    rng = np.random.default_rng(0)
    ny = nx = 8 if quick else 16
    s = 8
    z = jnp.asarray(rng.uniform(size=(ny, nx, s)) + 1j * rng.uniform(size=(ny, nx, s)),
                    jnp.complex64)
    q = jnp.asarray(rng.normal(size=(ny, nx, s)) + 0j, jnp.complex64)
    mask = jnp.ones((ny, nx, s), bool)
    expect = np.asarray(ref.p2p_ref(z, q, mask, 0.05))
    p2p_jit = jax.jit(lambda a, b, c: ref.p2p_ref(a, b, c, 0.05))
    p2p_ref_t = _time(lambda: jax.block_until_ready(p2p_jit(z, q, mask)))
    err = float(np.linalg.norm(np.asarray(p2p_pallas(z, q, mask, 0.05)) - expect) /
                np.linalg.norm(expect))
    rows.append(("kernel_p2p_ref_jnp", p2p_ref_t, f"pallas_relerr={err:.1e}"))
    p2p_k_t = _time(lambda: jax.block_until_ready(p2p_pallas(z, q, mask, 0.05)))
    rows.append(("kernel_p2p_pallas_interpret", p2p_k_t,
                 f"same_op_ref_us={p2p_ref_t:.1f}"))

    p = 17
    level = 4
    me = jnp.asarray(rng.normal(size=(ny, nx, p)) + 1j * rng.normal(size=(ny, nx, p)),
                     jnp.complex64)
    expect = np.asarray(ref.m2l_ref(me, level, p))          # masked-40 oracle
    m2l_fold = jax.jit(lambda g: ex.m2l_reference(g, level, p))
    m2l_t = _time(lambda: jax.block_until_ready(m2l_fold(me)))
    err = float(np.linalg.norm(np.asarray(m2l_pallas(me, level, p)) - expect) /
                np.linalg.norm(expect))
    rows.append(("kernel_m2l_ref_jnp", m2l_t, f"pallas_relerr={err:.1e}"))
    m2l_k_t = _time(lambda: jax.block_until_ready(m2l_pallas(me, level, p)))
    rows.append(("kernel_m2l_pallas_interpret", m2l_k_t,
                 f"same_op_ref_us={m2l_t:.1f}"))

    qq = jnp.asarray(rng.normal(size=(1, 4, 128, 64)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    expect = np.asarray(ref.attention_ref(qq, kk, kk))
    fa_t = _time(lambda: jax.block_until_ready(ref.attention_ref(qq, kk, kk)))
    err = float(np.linalg.norm(
        np.asarray(flash_attention(qq, kk, kk, block_q=64, block_k=64)) - expect) /
        np.linalg.norm(expect))
    rows.append(("kernel_flash_attn_ref_jnp", fa_t, f"pallas_relerr={err:.1e}"))


def bench_m2l_staging_bytes(rows, quick=False):
    """hlo_analysis check that parity folding dropped the M2L HBM traffic.

    Walks the optimized HLO of the folded reference, the pre-folding
    masked-40 formulation, and the Pallas kernel wrapper.  The folded paths
    must move fewer bytes AND contain no ``40p``-wide staging buffer (the
    old wrapper's (nb, 40p) gather tensor)."""
    import jax
    import jax.numpy as jnp
    from repro.core import expansions as ex
    from repro.kernels import ops as kops
    from repro.launch.hlo_analysis import analyze_hlo, shape_dim_pattern

    rng = np.random.default_rng(0)
    level, p = (3, 12) if quick else (4, 17)
    n = 1 << level
    me = jnp.asarray(rng.normal(size=(n, n, p)) + 1j * rng.normal(size=(n, n, p)),
                     jnp.complex64)

    def hlo(fn):
        return jax.jit(fn).lower(me).compile().as_text()

    b_old = analyze_hlo(hlo(lambda g: ex.m2l_masked40(g, level, p)))["bytes"]
    b_new = analyze_hlo(hlo(lambda g: ex.m2l_reference(g, level, p)))["bytes"]
    t_kern = hlo(lambda g: kops.m2l_apply(g, level, p))
    b_kern = analyze_hlo(t_kern)["bytes"]
    n40 = len(shape_dim_pattern(40 * p).findall(t_kern))
    rows.append(("m2l_hbm_bytes_masked40", 0.0, f"{b_old:.3e}"))
    rows.append(("m2l_hbm_bytes_folded", 0.0,
                 f"{b_new:.3e}_drop={b_old / max(b_new, 1.0):.2f}x"))
    rows.append(("m2l_kernel_wrapper_staging", 0.0,
                 f"bytes={b_kern:.3e}_40p_buffers={n40}"))


def bench_parallel_multidevice(rows, quick=False):
    """Sharded FMM wall time on forced host devices (subprocess: jax locks
    the device count at first init, and the parent runs single-device)."""
    ndev = 2 if quick else 4
    level, p = (4, 8) if quick else (5, 12)
    body = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
        import time
        import numpy as np
        import jax
        from jax.sharding import Mesh
        from repro.core.parallel_fmm import parallel_fmm_velocity
        from repro.core.quadtree import build_tree

        rng = np.random.default_rng(0)
        n_particles = {4000 if quick else 20000}
        pos = rng.uniform(0.02, 0.98, size=(n_particles, 2))
        tree, _ = build_tree(pos, rng.normal(size=n_particles), {level}, 0.02)
        mesh = Mesh(np.array(jax.devices()[:{ndev}]), ("data",))
        fn = lambda: jax.block_until_ready(parallel_fmm_velocity(tree, {p}, mesh))
        fn()
        t0 = time.perf_counter()
        for _ in range(3):
            fn()
        print("US", (time.perf_counter() - t0) / 3 * 1e6)
    """)
    env = dict(os.environ)
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    old_pp = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src_dir + (os.pathsep + old_pp if old_pp else "")
    try:
        proc = subprocess.run([sys.executable, "-c", body], capture_output=True,
                              text=True, env=env, timeout=600)
        us = [float(l.split()[1]) for l in proc.stdout.splitlines()
              if l.startswith("US")]
        if proc.returncode != 0 or not us:
            raise RuntimeError(proc.stderr[-300:])
        rows.append((f"parallel_fmm_P{ndev}", us[0], f"L={level}_p={p}"))
    except Exception as e:  # report, never abort the whole harness
        detail = " ".join(str(e).split())[-160:].replace(",", ";")
        rows.append((f"parallel_fmm_P{ndev}", 0.0,
                     f"failed:{type(e).__name__}:{detail}"))


def bench_plan_execution(rows, quick=False):
    """Partition-driven execution plans on the Lamb-Oseen lattice (paper
    Eq 20 next to measured step time): uniform strawman vs a-priori model
    plan vs dynamic re-planning vs a 2-D block grid vs the per-axis grid
    autotuner, on forced host devices (subprocess: jax locks the device
    count at first init).

    Timing protocol: after the compile-warm step, the loop keeps stepping
    (bounded) until a step adopts no new plan/level — that step doubles as
    the warm step for whatever plan is current, so re-level/re-plan
    recompiles never land inside the timed window.  The reported time is
    the MINIMUM steady-state step (robust to host-device scheduling noise);
    any adoption that still happens while timing is counted and emitted in
    the derived field (releveled/replanned), keeping the trajectory
    comparable across PRs.  Modes run in small subprocess GROUPS: sharing
    one long-lived process let allocator/jit-cache state accumulate across
    all modes and skewed later ones (plan_dynamic read ~6% slower than
    plan_model at identical plans and programs), while full isolation
    exposes the parity comparison to minute-scale machine drift between
    subprocesses.  So model+dynamic — the pair whose parity is pinned —
    run TOGETHER with their timed steps interleaved (drift hits both
    equally; the stepper's on-device occupancy check keeps the dynamic
    replan check off the step path), and every other mode gets its own
    process.  The dynamic row reports ``vs_model`` and becomes a failed
    row (CI-fatal) outside a generous noise band.
    """
    ndev = 4
    m_side, p, steps = (120, 8, 3) if quick else (160, 12, 4)
    groups = (("uniform",), ("model", "dynamic"), ("block",), ("auto",))
    env = dict(os.environ)
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    old_pp = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src_dir + (os.pathsep + old_pp if old_pp else "")
    for group in groups:
        body = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
            import time
            import numpy as np
            import jax
            from jax.sharding import Mesh
            from repro.core.stepper import VortexStepper
            from repro.core.vortex import lamb_oseen_particles

            pos, gamma, sigma = lamb_oseen_particles({m_side})
            mesh = Mesh(np.array(jax.devices()[:{ndev}]), ("data",))
            sts, counts = {{}}, {{}}
            for mode in {group!r}:
                grid = {{"block": (2, 2), "auto": "auto"}}.get(mode)
                st = VortexStepper(pos, gamma, sigma, p={p}, dt=0.004,
                                   mesh=mesh,
                                   plan_method="uniform" if mode == "uniform" else "model",
                                   dynamic=(mode in ("dynamic", "block", "auto")),
                                   plan_grid=grid, replan_every=2)
                st.step()                  # compile + warm
                for _ in range(4):         # settle: warm again after adoption
                    rec = st.step()
                    if not (rec.replanned or rec.releveled):
                        break
                sts[mode] = st
                counts[mode] = [0, 0, []]  # releveled, replanned, timed
            for _ in range({steps}):       # interleaved: drift is paired
                for mode in {group!r}:
                    rec = sts[mode].step()
                    counts[mode][0] += rec.releveled
                    counts[mode][1] += rec.replanned
                    counts[mode][2].append(rec.seconds)
            for mode in {group!r}:
                st = sts[mode]
                releveled, replanned, timed = counts[mode]
                us = min(timed) * 1e6
                s = st.stats()
                geom = "/".join(map(str, st.plan.rows))
                if len(getattr(st.plan, "cols", ())) > 1:
                    geom += "x" + "/".join(map(str, st.plan.cols))
                print(f"ROW plan_{{mode}} {{us:.1f}} "
                      f"LB={{s['load_balance']:.3f}}_min={{s['min_load']:.3g}}"
                      f"_max={{s['max_load']:.3g}}_rows={{geom}}"
                      f"_releveled={{releveled}}_replanned={{replanned}}")
        """)
        try:
            proc = subprocess.run([sys.executable, "-c", body],
                                  capture_output=True, text=True, env=env,
                                  timeout=1800)
            got = [l.split(maxsplit=3) for l in proc.stdout.splitlines()
                   if l.startswith("ROW")]
            if proc.returncode != 0 or len(got) != len(group):
                raise RuntimeError(proc.stderr[-300:])
            by_mode = {name: (float(us), derived)
                       for _, name, us, derived in got}
            for name, (us, derived) in by_mode.items():
                if name == "plan_dynamic" and "plan_model" in by_mode:
                    ratio = us / by_mode["plan_model"][0]
                    derived += f"_vs_model={ratio:.2f}x"
                    # the pin: paired steady-state dynamic stepping must
                    # stay within noise of the static model plan
                    if not 0.75 <= ratio <= 1.33:
                        derived = "failed:parity_" + derived
                rows.append((name, us, derived))
        except Exception as e:  # report, never abort the whole harness
            detail = " ".join(str(e).split())[-160:].replace(",", ";")
            for mode in group:
                rows.append((f"plan_{mode}", 0.0,
                             f"failed:{type(e).__name__}:{detail}"))


def bench_overlap(rows, quick=False):
    """Interior/rim overlapped execution vs the monolithic ordering
    (DESIGN.md §9), plus the fused packed P2P exchange, on 4 forced host
    devices (subprocess: jax locks the device count at first init).

    ``overlap_on`` / ``overlap_off`` time the full sharded FMM with the
    halo collectives hidden behind tile-interior compute vs the serial
    exchange-then-compute ordering (interleaved reps, min per mode — the
    two modes share one process so the comparison is paired).
    ``p2p_exchange_fused`` times the ONE packed (z, q, mask) ``_tile_halo``
    round against the three separate exchanges it replaced and counts the
    ``collective-permute`` ops in the lowered HLO of each (3x reduction,
    12 -> 4 on a 2x2 grid).

    Runs at the full problem size even under ``--quick``: overlap pays off
    when the tile interiors are big enough to hide the exchange (the
    production regime); at toy tile sizes the extra rim launches dominate
    and the row would misrepresent the trade.
    """
    ndev = 4
    m_side, level, p = (160, 6, 12)
    body = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
        import time
        import numpy as np
        import jax
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core import parallel_fmm as pf
        from repro.core.cost_model import ModelParams
        from repro.core.plan import plan_from_counts
        from repro.core.quadtree import build_tree
        from repro.core.vortex import lamb_oseen_particles

        mesh = Mesh(np.array(jax.devices()[:{ndev}]), ("data",))
        pos, gamma, sigma = lamb_oseen_particles({m_side})
        tree, index = build_tree(pos, gamma, level={level}, sigma=sigma)
        params = ModelParams(level={level}, cut=4, p={p}, slots=tree.slots)
        plan = plan_from_counts(index.counts, params, {ndev}, method="model")

        fns = {{}}
        for ov in (True, False):
            fn = (lambda ov=ov: jax.block_until_ready(
                pf.parallel_fmm_velocity(tree, {p}, mesh, plan=plan,
                                         overlap=ov)))
            fn()                               # compile + warm
            fns[ov] = fn
        t = {{True: [], False: []}}
        for _ in range(6):                     # interleaved, paired reps
            for ov in (False, True):
                t0 = time.perf_counter()
                fns[ov]()
                t[ov].append(time.perf_counter() - t0)
        on, off = min(t[True]) * 1e6, min(t[False]) * 1e6
        # the pin: overlapped execution must not lose to the serial
        # ordering (10% jitter allowance for shared CI runners); a
        # violation marks the row failed, which the CI guard treats as
        # fatal
        tag = "" if on <= 1.10 * off else "failed:overlap_slower_"
        print(f"ROW overlap_on {{on:.1f}} {{tag}}"
              f"hidden_vs_serial={{off / on:.2f}}x_rows="
              + "/".join(map(str, plan.rows)))
        print(f"ROW overlap_off {{off:.1f}} serial_comm_baseline")

        # fused packed P2P exchange vs the three separate rounds it replaced
        # (2x2 grid: the full two-axis exchange, 4 ppermutes per round)
        grid = (2, 2)
        rmax = cmax = (1 << {level}) // 2
        rv = cv = rmax
        def fused(z, q, m):
            buf = pf._tile_halo(pf._pack_particles(z, q, m), 1, rv, cv,
                                "data", grid)
            return pf._unpack_particles(buf, z.dtype)
        def unfused(z, q, m):
            return (pf._tile_halo(z, 1, rv, cv, "data", grid),
                    pf._tile_halo(q, 1, rv, cv, "data", grid),
                    pf._tile_halo(m, 1, rv, cv, "data", grid))
        spec = P("data", None, None)
        kw = {{pf._CHECK_KW: False}} if pf._CHECK_KW else {{}}
        rng = np.random.default_rng(0)
        s = tree.slots
        shape = ({ndev} * rmax, cmax, s)
        z = jnp.asarray(rng.normal(size=shape) + 1j * rng.normal(size=shape),
                        jnp.complex64)
        q = z * 0.5
        m = jnp.asarray(rng.uniform(size=shape) > 0.3)
        from repro.analysis import contracts as C
        cc = C.collective_count("collective-permute", 4)
        stats = {{}}
        lows = {{}}
        for name, fn in (("fused", fused), ("unfused", unfused)):
            jfn = jax.jit(pf._shard_map(fn, mesh=mesh, in_specs=(spec,) * 3,
                                        out_specs=(spec,) * 3, **kw))
            lows[name] = C.Lowered(jfn, z, q, m, label=name)
            nperm = cc.measure(lows[name].text(cc.ir))
            jax.block_until_ready(jfn(z, q, m))
            t0 = time.perf_counter()
            for _ in range(20):
                jax.block_until_ready(jfn(z, q, m))
            stats[name] = ((time.perf_counter() - t0) / 20 * 1e6, nperm)
        (fus, nf), (unf, nu) = stats["fused"], stats["unfused"]
        # the pin, now through the contract registry: the packed exchange
        # compiles to exactly 4 collective-permutes (TRUE instance counts
        # in the optimized HLO — the old regex counted textual mentions),
        # a 3x reduction vs the three rounds it replaced
        (res,) = C.evaluate(lows["fused"], [cc])
        tag = "" if res.ok and nu == 3 * nf else "failed:collective_count_"
        print(f"ROW p2p_exchange_fused {{fus:.1f}} {{tag}}"
              f"collectives={{nf}}_was={{nu}}_unfused_us={{unf:.1f}}")
    """)
    env = dict(os.environ)
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    old_pp = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src_dir + (os.pathsep + old_pp if old_pp else "")
    names = ("overlap_on", "overlap_off", "p2p_exchange_fused")
    try:
        proc = subprocess.run([sys.executable, "-c", body], capture_output=True,
                              text=True, env=env, timeout=1800)
        got = [l.split(maxsplit=3) for l in proc.stdout.splitlines()
               if l.startswith("ROW")]
        if proc.returncode != 0 or len(got) != len(names):
            raise RuntimeError(proc.stderr[-300:])
        for _, name, us, derived in got:
            rows.append((name, float(us), derived))
    except Exception as e:  # report, never abort the whole harness
        detail = " ".join(str(e).split())[-160:].replace(",", ";")
        for name in names:
            rows.append((name, 0.0, f"failed:{type(e).__name__}:{detail}"))


def bench_pipeline(rows, quick=False):
    """Substep-pipelined asynchrony vs the serial issue order (DESIGN.md
    §12) on 4 forced host devices (subprocess: jax locks the device count
    at first init).

    ``pipeline_on`` / ``pipeline_off`` time the full RK2 step with the
    cross-substep P2P prefetch + gather/root-tree overlap vs the
    pre-pipeline ordering (interleaved reps, min per mode; paired in one
    process).  Host CPU collectives cannot actually overlap compute, so
    the pin is the one that transfers to real backends: pipelining must
    not LOSE (<= 1.10x, jitter allowance), while the issue-order win is
    pinned structurally in ``gather_overlap``.

    ``gather_overlap`` parses both lowered StableHLO modules (trace order
    is preserved) and reports the cut-level all_gather's *issue depth* —
    dot_generals between issue and first consumption.  Pins, evaluated
    through the trace-contract registry (repro/analysis/contracts):
    ``issue_depth_grows`` — depth must GROW under pipelining (that window
    is what the GPU latency-hiding scheduler fills) with EQUAL
    collective_permute counts across modes (the prefetch replaces the
    exchange, never duplicates it) — and ``min_issue_depth`` as an
    absolute floor.  Violations mark the row failed:, CI-fatal.
    """
    ndev = 4
    m_side, level, p = (80, 5, 8) if quick else (160, 6, 12)
    depth_floor = 8 if quick else 32
    body = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
        import time
        import numpy as np
        import jax
        from jax.sharding import Mesh
        from repro.core import parallel_fmm as pf
        from repro.core.cost_model import ModelParams
        from repro.core.plan import plan_from_counts
        from repro.core.quadtree import build_tree
        from repro.core.stepper import rk2_step
        from repro.core.vortex import lamb_oseen_particles
        from repro.launch.hlo_analysis import collective_issue_depths

        mesh = Mesh(np.array(jax.devices()[:{ndev}]), ("data",))
        pos, gamma, sigma = lamb_oseen_particles({m_side})
        tree, index = build_tree(pos, gamma, level={level}, sigma=sigma)
        params = ModelParams(level={level}, cut=4, p={p}, slots=tree.slots)
        plan = plan_from_counts(index.counts, params, {ndev}, method="model")

        fns = {{}}
        for pl in (True, False):
            fn = (lambda pl=pl: jax.block_until_ready(rk2_step(
                tree, 1e-4, p={p}, mesh=mesh, plan=plan,
                pipeline=pl)[0].z))
            fn()                               # compile + warm
            fns[pl] = fn
        t = {{True: [], False: []}}
        for _ in range(10):                    # interleaved, paired reps
            for pl in (False, True):
                t0 = time.perf_counter()
                fns[pl]()
                t[pl].append(time.perf_counter() - t0)
        on, off = min(t[True]) * 1e6, min(t[False]) * 1e6
        tag = "" if on <= 1.10 * off else "failed:pipeline_slower_"
        print(f"ROW pipeline_on {{on:.1f}} {{tag}}"
              f"vs_serial_order={{off / on:.2f}}x_rows="
              + "/".join(map(str, plan.rows)))
        print(f"ROW pipeline_off {{off:.1f}} serial_issue_order_baseline")

        # structural pin, through the contract registry: the cut-level
        # all_gather's issue depth must GROW under pipelining (and clear
        # an absolute floor) while permute counts stay equal (prefetch
        # replaces, never duplicates)
        from repro.analysis import contracts as C
        entry = pf.TRACE_ENTRY_POINTS["parallel_fmm_evaluate"]
        lows = {{pl: C.Lowered(entry, tree, {p}, mesh, plan=plan,
                               pipeline=pl, label="pipeline=" + str(pl))
                for pl in (True, False)}}
        res = C.evaluate(lows[True],
                         [C.issue_depth_grows("all_gather"),
                          C.min_issue_depth("all_gather", {depth_floor})],
                         pair_with=lows[False])
        depths = {{pl: collective_issue_depths(lows[pl].stablehlo)
                  for pl in (True, False)}}
        ag_on = max(depths[True]["all_gather"], default=0)
        ag_off = max(depths[False]["all_gather"], default=0)
        np_on = len(depths[True]["collective_permute"])
        np_off = len(depths[False]["collective_permute"])
        tag = "" if not C.violations(res) else "failed:issue_order_"
        print(f"ROW gather_overlap {{float(ag_on):.1f}} {{tag}}"
              f"gather_issue_depth={{ag_on}}_was={{ag_off}}"
              f"_permutes={{np_on}}_was={{np_off}}")
    """)
    env = dict(os.environ)
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
    old_pp = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src_dir + (os.pathsep + old_pp if old_pp else "")
    names = ("pipeline_on", "pipeline_off", "gather_overlap")
    try:
        proc = subprocess.run([sys.executable, "-c", body],
                              capture_output=True, text=True, env=env,
                              timeout=1800)
        got = [l.split(maxsplit=3) for l in proc.stdout.splitlines()
               if l.startswith("ROW")]
        if proc.returncode != 0 or len(got) != len(names):
            raise RuntimeError(proc.stderr[-300:])
        for _, name, us, derived in got:
            rows.append((name, float(us), derived))
    except Exception as e:  # report, never abort the whole harness
        detail = " ".join(str(e).split())[-160:].replace(",", ";")
        for name in names:
            rows.append((name, 0.0, f"failed:{type(e).__name__}:{detail}"))


def bench_guarded_step(rows, quick=False):
    """Guarded vs unguarded RK2 step on 4 forced host devices.

    The health word (DESIGN.md §11) is computed inside the step's own
    device program — a handful of finiteness reductions riding the
    existing outputs, no extra host sync — so guarded throughput must
    stay within 3% of unguarded.  Interleaved paired reps, min per mode;
    a violation marks the row ``failed:``, which the CI guard treats as
    fatal."""
    ndev = 4
    m_side, level, p = (80, 5, 8) if quick else (160, 6, 12)
    body = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
        import time
        import numpy as np
        import jax
        from jax.sharding import Mesh
        from repro.core.cost_model import ModelParams
        from repro.core.plan import plan_from_counts
        from repro.core.quadtree import build_tree
        from repro.core.stepper import rk2_step
        from repro.core.vortex import lamb_oseen_particles

        mesh = Mesh(np.array(jax.devices()[:{ndev}]), ("data",))
        pos, gamma, sigma = lamb_oseen_particles({m_side})
        tree, index = build_tree(pos, gamma, level={level}, sigma=sigma)
        params = ModelParams(level={level}, cut=4, p={p}, slots=tree.slots)
        plan = plan_from_counts(index.counts, params, {ndev}, method="model")

        fns = {{}}
        for g in (True, False):
            fn = (lambda g=g: jax.block_until_ready(rk2_step(
                tree, 1e-4, p={p}, mesh=mesh, plan=plan, guard=g)[0].z))
            fn()                               # compile + warm
            fns[g] = fn
        t = {{True: [], False: []}}
        for _ in range(10):                    # interleaved, paired reps
            for g in (False, True):
                t0 = time.perf_counter()
                fns[g]()
                t[g].append(time.perf_counter() - t0)
        gu, un = min(t[True]) * 1e6, min(t[False]) * 1e6
        ratio = gu / un
        tag = "" if ratio <= 1.03 else "failed:guard_overhead_"
        print(f"ROW guarded_step_overhead {{gu:.1f}} {{tag}}"
              f"ratio={{ratio:.3f}}_unguarded_us={{un:.1f}}")
    """)
    env = dict(os.environ)
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
    old_pp = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src_dir + (os.pathsep + old_pp if old_pp else "")
    try:
        proc = subprocess.run([sys.executable, "-c", body],
                              capture_output=True, text=True, env=env,
                              timeout=1800)
        got = [l.split(maxsplit=3) for l in proc.stdout.splitlines()
               if l.startswith("ROW")]
        if proc.returncode != 0 or len(got) != 1:
            raise RuntimeError(proc.stderr[-300:])
        for _, name, us, derived in got:
            rows.append((name, float(us), derived))
    except Exception as e:  # report, never abort the whole harness
        detail = " ".join(str(e).split())[-160:].replace(",", ";")
        rows.append(("guarded_step_overhead", 0.0,
                     f"failed:{type(e).__name__}:{detail}"))


def bench_plan_halo(rows, quick=False):
    """1-D band vs 2-D block halo volume on the Lamb-Oseen lattice (the
    BlockPlan's reason to exist — ROADMAP "2-D execution plans").

    ``halo_model_P*`` prices the valid-extent (modeled) ppermute bytes per
    FMM evaluation; ``halo_exec_P*`` prices what the driver literally
    transfers (padded extents + corner-carrying strips).  Host-side only —
    no devices needed."""
    from repro.core.cost_model import ModelParams
    from repro.core.plan import halo_volume, plan_from_counts
    from repro.core.quadtree import build_tree
    from repro.core.vortex import lamb_oseen_particles

    level = 5 if quick else 6
    pos, gamma, sigma = lamb_oseen_particles(120 if quick else 160)
    tree, index = build_tree(pos, gamma, level, sigma)
    params = ModelParams(level=level, cut=4, p=12, slots=tree.slots)
    grids = {4: (2, 2), 8: (4, 2), 16: (4, 4)}
    for P in (4, 8) if quick else (4, 8, 16):
        slab = plan_from_counts(index.counts, params, P, method="model")
        block = plan_from_counts(index.counts, params, P, method="model",
                                 grid=grids[P])
        for tag, executed in (("model", False), ("exec", True)):
            hs = halo_volume(slab, params, executed=executed)["total"]
            hb = halo_volume(block, params, executed=executed)["total"]
            rows.append((f"halo_{tag}_P{P}", 0.0,
                         f"slab={hs:.3e}_block={hb:.3e}"
                         f"_ratio={hs / hb:.2f}x"))


def bench_equations(rows, quick=False):
    """The pluggable equation subsystem (DESIGN.md §10): wall time of the
    two new workloads next to the vortex baseline, same tree, same slab
    path.

    ``eq_laplace_step`` times one full Laplace evaluation (potential +
    field from ONE downward sweep — the 2-channel analogue of a vortex
    velocity step); ``eq_tracer_eval`` times the passive probe-grid
    evaluation (sources' expansions + near field at a separate target
    batch).  Derived fields carry the f64 direct-sum relative error of a
    subsample, so the rows double as numerics smoke."""
    import jax
    from repro.core import equations as eqs
    from repro.core.fmm import fmm_evaluate, fmm_velocity
    from repro.core.quadtree import build_tree, gather_particle_values

    n_particles, level, p = (20_000, 5, 12) if quick else (100_000, 6, 17)
    rng = np.random.default_rng(0)
    pos = rng.uniform(0.01, 0.99, (n_particles, 2))
    strength = rng.normal(size=n_particles)
    # sigma well under the leaf box size so the mollifier is ~1 at
    # interaction-list distance and the relerr fields measure the
    # implementation, not Type-I kernel-substitution error (paper §3)
    sigma = 0.25 / 2 ** level

    vtree, _ = build_tree(pos, strength, level, sigma)
    vortex_t = _time(lambda: jax.block_until_ready(fmm_velocity(vtree, p)))

    ltree, lindex = build_tree(pos, strength, level, sigma,
                               charge_scale=eqs.LAPLACE.charge_scale)
    lap = lambda: jax.block_until_ready(
        fmm_evaluate(ltree, p, eq=eqs.LAPLACE))
    lap_t = _time(lap)
    out = np.asarray(fmm_evaluate(ltree, p, eq=eqs.LAPLACE))
    sel = rng.choice(n_particles, size=400, replace=False)
    z = pos[:, 0] + 1j * pos[:, 1]
    exact = eqs.direct_sum(eqs.LAPLACE, z[sel], z, strength, sigma=sigma)
    pot = gather_particle_values(out[..., 0], lindex)[sel].real
    err = float(np.linalg.norm(pot - exact[:, 0].real) /
                np.linalg.norm(exact[:, 0].real))
    rows.append(("eq_laplace_step", lap_t,
                 f"C=2_vs_vortex={lap_t / max(vortex_t, 1e-9):.2f}x"
                 f"_relerr={err:.1e}"))

    m = int(np.sqrt(n_particles // 4))
    xs = np.linspace(0.05, 0.95, m)
    PX, PY = np.meshgrid(xs, xs, indexing="xy")
    probes = np.stack([PX.ravel(), PY.ravel()], axis=1)
    targets, tindex = build_tree(probes, np.zeros(len(probes)), level, sigma)
    trc = lambda: jax.block_until_ready(
        fmm_evaluate(vtree, p, eq=eqs.TRACER, targets=targets))
    trc_t = _time(trc)
    got = gather_particle_values(
        np.asarray(fmm_evaluate(vtree, p, eq=eqs.TRACER, targets=targets)),
        tindex)
    tsel = rng.choice(len(probes), size=400, replace=False)
    tz = probes[tsel, 0] + 1j * probes[tsel, 1]
    texact = eqs.direct_sum(eqs.TRACER, tz, z, strength, sigma=sigma)
    terr = float(np.linalg.norm(got[tsel] - texact) /
                 np.linalg.norm(texact))
    rows.append(("eq_tracer_eval", trc_t,
                 f"targets={len(probes)}_relerr={terr:.1e}"))


def bench_moe_placement(rows, quick=False):
    """The paper's technique transplanted: expert-placement load balance."""
    from repro.models.moe import expert_placement
    rng = np.random.default_rng(0)
    E, ranks = 64, 8
    counts = (rng.zipf(1.5, E) * 100).clip(0, 50_000).astype(np.float64)
    coact = np.zeros((E, E))
    assign = expert_placement(counts, coact, ranks)
    loads = np.bincount(assign, weights=counts, minlength=ranks)
    naive = counts.reshape(ranks, -1).sum(1)
    rows.append(("moe_placement_lb", 0.0,
                 f"model={loads.min()/max(loads.max(),1):.3f}_"
                 f"contiguous={naive.min()/max(naive.max(),1):.3f}"))


def bench_trace_contracts(rows, quick=False):
    """The static-analysis layer as a benchmark row: run the serial
    trace-contract catalog (M2L no-staging + fewer-bytes, guard-free and
    callback-free traces, no donation on ``rk2_step``, no f64 upcasts)
    plus the repo lint pass in-process, and report checked/violations.
    Any violation marks the row ``failed:``, which the CI guard treats as
    fatal.  The multidevice contracts (fused-exchange counts, pipelined
    issue depth, SPMD schedule consistency, retrace session) run in the
    dedicated static-analysis CI job via ``python -m
    repro.analysis.check``."""
    try:
        import pathlib

        import jax
        import jax.numpy as jnp

        from repro.analysis import contracts as C
        from repro.analysis import lint as L
        from repro.core import expansions as ex
        from repro.core.fmm import fmm_velocity
        from repro.core.quadtree import build_tree
        from repro.core.stepper import TRACE_ENTRY_POINTS
        from repro.kernels import ops as kops

        level, p = (3, 12) if quick else (4, 17)
        n = 1 << level
        rng = np.random.default_rng(0)
        me = jnp.asarray(rng.normal(size=(n, n, p)) +
                         1j * rng.normal(size=(n, n, p)), jnp.complex64)
        kern = C.Lowered(jax.jit(lambda g: kops.m2l_apply(g, level, p)), me,
                         label="m2l_apply")
        fold = C.Lowered(jax.jit(lambda g: ex.m2l_reference(g, level, p)),
                         me, label="m2l_reference")
        m40 = C.Lowered(jax.jit(lambda g: ex.m2l_masked40(g, level, p)), me,
                        label="m2l_masked40")
        pos = rng.uniform(0.05, 0.95, size=(600, 2))
        tree, _ = build_tree(pos, rng.normal(size=600), 3, sigma=0.02)
        drv = C.Lowered(jax.jit(lambda t: fmm_velocity(t, p=6)), tree,
                        label="fmm_velocity")
        rk2 = C.Lowered(TRACE_ENTRY_POINTS["rk2_step"], tree, 1e-4, p=6,
                        label="rk2_step")

        staging = [C.no_staging_dim(40 * p), C.no_f64_upcast()]
        results = C.evaluate(kern, staging) + C.evaluate(fold, staging)
        results += C.evaluate(fold, [C.fewer_bytes("folded", "masked40")],
                              pair_with=m40)
        results += C.evaluate(drv, [C.sentinel_free(), C.no_host_callback(),
                                    C.no_f64_upcast()])
        results += C.evaluate(rk2, [C.sentinel_free(), C.not_donated("rk2"),
                                    C.no_host_callback()])

        src_root = pathlib.Path(__file__).resolve().parents[1] / "src" / \
            "repro"
        findings = L.run_lint(src_root)
        checked = len(results) + len(L.DEFAULT_RULES)
        nviol = len(C.violations(results)) + len(findings)
        tag = "" if nviol == 0 else "failed:"
        rows.append(("trace_contracts", 0.0,
                     f"{tag}checked={checked}_violations={nviol}"))
    except Exception as e:  # report, never abort the whole harness
        detail = " ".join(str(e).split())[-160:].replace(",", ";")
        rows.append(("trace_contracts", 0.0,
                     f"failed:{type(e).__name__}:{detail}"))


def bench_serve(rows, quick=False):
    """FMM-as-a-service throughput/latency (DESIGN.md §15), subprocess.

    ``serve_batched`` / ``serve_sequential``: the SAME wave of tiny
    same-bucket one-shot jobs served through the vmap bin-packing engine
    vs an engine capped at ``batch_capacities=(1,)`` (one device program
    per job).  Paired-interleaved reps, min per mode; trees are pulled
    from each engine's warm artifact cache, so the pair isolates
    dispatch + execution.  Pins: batched throughput >= 1.5x sequential
    (failed: below 1.35x, the pipeline_on-style 10% jitter band) at
    EQUAL results (1e-5), and zero steady-state retraces
    (``batched_cache_entries`` flat across reps, failed: otherwise —
    CI-fatal via the no-silently-failed-rows guard).

    ``serve_throughput`` reports requests/s of the batched engine;
    ``serve_latency`` reports p50/p99 per job class (batched one-shots +
    RK2 session steps) from the engine's own latency counters.
    """
    n_jobs, reps, steps = (8, 3, 1) if quick else (12, 6, 2)
    body = textwrap.dedent(f"""
        import time
        import numpy as np
        from repro.serve import fmm_service as svc

        n_jobs, n = {n_jobs}, 60
        rng = np.random.default_rng(0)
        pos = rng.uniform(0.1, 0.9, size=(n, 2))
        qs = [rng.normal(size=n) for _ in range(n_jobs)]
        waves = {{m: qs for m in ("batched", "sequential")}}  # same jobs
        engines = {{
            "batched": svc.FmmServiceEngine(),
            "sequential": svc.FmmServiceEngine(batch_capacities=(1,)),
        }}

        def wave(mode):
            eng = engines[mode]
            jids = [eng.submit(svc.FmmJob(positions=pos, strength=q, p=4,
                                          sigma=0.02, tenant=mode))
                    for q in waves[mode]]
            eng.drain()
            return [np.asarray(eng.result(j).out) for j in jids]

        out = {{m: wave(m) for m in engines}}      # compile + warm caches
        entries_warm = svc.batched_cache_entries()
        for eng in engines.values():
            eng._latencies.clear()                 # drop compile-wave tails
        t = {{m: [] for m in engines}}
        for _ in range({reps}):                    # interleaved, paired
            for m in ("sequential", "batched"):
                t0 = time.perf_counter()
                out[m] = wave(m)
                t[m].append(time.perf_counter() - t0)
        retraces = svc.batched_cache_entries() - entries_warm

        err = max(np.abs(a - b).max() / max(np.abs(b).max(), 1e-30)
                  for a, b in zip(out["batched"], out["sequential"]))
        bat = min(t["batched"]) * 1e6 / n_jobs     # us per job
        seq = min(t["sequential"]) * 1e6 / n_jobs
        tag = ""
        if err > 1e-5:
            tag = "failed:batched_results_diverge_"
        elif seq < 1.35 * bat:
            tag = "failed:batched_speedup_below_band_"
        print(f"ROW serve_batched {{bat:.1f}} {{tag}}"
              f"vs_sequential={{seq / bat:.2f}}x_err={{err:.1e}}"
              f"_jobs={{n_jobs}}")
        print(f"ROW serve_sequential {{seq:.1f}} one_program_per_job")

        tag = "" if retraces == 0 else "failed:steady_state_retraced_"
        print(f"ROW serve_throughput {{bat:.1f}} {{tag}}req_s="
              f"{{1e6 / bat:.0f}}_retraces={{retraces}}"
              f"_entries={{entries_warm}}")

        eng = engines["batched"]
        sid = eng.submit(svc.FmmJob(positions=pos,
                                    strength=0.1 * rng.normal(size=n),
                                    steps={steps}, p=4, dt=1e-3, sigma=0.02))
        for _ in range({steps}):
            eng.step_session(sid)
        lat = eng.stats()["latency"]
        b, s = lat["batched"], lat["session"]
        print(f"ROW serve_latency {{b['p50_ms'] * 1e3:.1f}} "
              f"batched_p50={{b['p50_ms']:.1f}}ms_p99={{b['p99_ms']:.1f}}ms"
              f"_session_p50={{s['p50_ms']:.0f}}ms_p99="
              f"{{s['p99_ms']:.0f}}ms")
    """)
    env = dict(os.environ)
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
    old_pp = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src_dir + (os.pathsep + old_pp if old_pp else "")
    names = ("serve_batched", "serve_sequential", "serve_throughput",
             "serve_latency")
    try:
        proc = subprocess.run([sys.executable, "-c", body],
                              capture_output=True, text=True, env=env,
                              timeout=1800)
        got = [l.split(maxsplit=3) for l in proc.stdout.splitlines()
               if l.startswith("ROW")]
        if proc.returncode != 0 or len(got) != len(names):
            raise RuntimeError(proc.stderr[-300:])
        for _, name, us, derived in got:
            rows.append((name, float(us), derived))
    except Exception as e:  # report, never abort the whole harness
        detail = " ".join(str(e).split())[-160:].replace(",", ";")
        for name in names:
            rows.append((name, 0.0, f"failed:{type(e).__name__}:{detail}"))


def bench_proc_fault_recovery(rows, quick=False):
    """MTTR of the cross-process fault-tolerance path (DESIGN.md §14): a
    2-rank kill drill through ``launch/supervisor.py`` — SIGKILL rank 1
    mid-step, survivors agree, shrink to 1, ``from_checkpoint``-restore,
    finish.  us_per_call is the mean time to recovery (detection +
    teardown/agreement/restore + first post-restore step); the pieces ride
    in ``derived``.  Any failure (including an unfinished drill) marks the
    row ``failed:``, which the CI guard treats as fatal."""
    import tempfile

    from repro.core.faults import FaultInjector, FaultSpec
    from repro.launch.supervisor import Supervisor, SupervisorConfig
    from repro.parallel import resilience as rz

    try:
        with tempfile.TemporaryDirectory(prefix="fmm-drill-") as d:
            cfg = SupervisorConfig(
                world=2, target_step=4, coord_dir=d, n_side=16, p=4,
                dt=0.004, checkpoint_every=1, checkpoint_keep=8,
                watchdog=rz.WatchdogPolicy(compile_grace=900.0,
                                           teardown_grace=30.0),
                restart=rz.RestartPolicy(min_world=1, backoff_base=0.05),
                max_wall=1500.0)
            sup = Supervisor(cfg, faults=FaultInjector(
                FaultSpec(site="proc_kill", step=2, device=1)))
            result = sup.run()
            if not result.success or len(result.faults) != 1:
                raise RuntimeError(f"drill did not recover: "
                                   f"{len(result.faults)} faults")
            rep = result.faults[0]
            parts = [rep.detect_seconds, rep.restore_seconds,
                     rep.first_step_seconds]
            if any(p is None for p in parts):
                raise RuntimeError(f"MTTR piece missing: {parts}")
            mttr = sum(parts)
            rows.append(("proc_fault_recovery", mttr * 1e6,
                         f"detect={rep.detect_seconds:.2f}s_restore="
                         f"{rep.restore_seconds:.2f}s_first_step="
                         f"{rep.first_step_seconds:.2f}s_world="
                         f"{rep.world_before}to{rep.world_after}"))
    except Exception as e:  # report, never abort the whole harness
        detail = " ".join(str(e).split())[-160:].replace(",", ";")
        rows.append(("proc_fault_recovery", 0.0,
                     f"failed:{type(e).__name__}:{detail}"))


def main() -> None:
    quick = "--quick" in sys.argv
    json_path = None
    if "--json" in sys.argv:
        i = sys.argv.index("--json")
        if i + 1 >= len(sys.argv) or sys.argv[i + 1].startswith("--"):
            sys.exit("usage: python -m benchmarks.run [--quick] [--json PATH]")
        json_path = sys.argv[i + 1]
    rows: list[tuple[str, float, str]] = []
    for bench in (bench_fig6_stage_timings, bench_fig7_9_scaling,
                  bench_table12_memory, bench_kernels, bench_m2l_staging_bytes,
                  bench_parallel_multidevice, bench_plan_execution,
                  bench_overlap, bench_pipeline, bench_guarded_step,
                  bench_plan_halo,
                  bench_equations,
                  bench_serve,
                  bench_trace_contracts,
                  bench_proc_fault_recovery,
                  bench_moe_placement):
        bench(rows, quick=quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump([{"name": n, "us_per_call": round(u, 1), "derived": d}
                       for n, u, d in rows], f, indent=2)
        print(f"# wrote {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
