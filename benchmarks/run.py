"""Benchmark harness: one entry per paper table/figure + kernel benches.

Prints ``name,us_per_call,derived`` CSV rows (the scaffold contract).
``derived`` carries the figure-specific metric (efficiency, LB, GB/s, ...).
Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _time(fn, reps=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_fig6_stage_timings(rows, quick=False):
    """Paper Fig 6: per-stage FMM timings (measured, serial, CPU)."""
    import jax
    from repro.core import expansions as ex
    from repro.core.fmm import fmm_velocity, near_field, upward_sweep
    from repro.core.quadtree import build_tree

    n_particles, level, p = (20_000, 5, 12) if quick else (100_000, 6, 17)
    rng = np.random.default_rng(0)
    pos = rng.uniform(0.01, 0.99, (n_particles, 2))
    tree, _ = build_tree(pos, rng.normal(size=n_particles), level, 0.02)

    total = _time(lambda: jax.block_until_ready(fmm_velocity(tree, p)))
    rows.append(("fig6_total_fmm", total, f"N={n_particles}_L={level}_p={p}"))

    up = jax.jit(lambda t: upward_sweep(t, p)[0], static_argnames=())
    rows.append(("fig6_upward_sweep", _time(lambda: jax.block_until_ready(up(tree))),
                 "P2M+M2M"))
    me = upward_sweep(tree, p)
    m2l = jax.jit(lambda g: ex.m2l_reference(g, level, p))
    rows.append(("fig6_m2l_leaf_level",
                 _time(lambda: jax.block_until_ready(m2l(me[level]))), "M2L"))
    nearf = jax.jit(near_field)
    rows.append(("fig6_p2p_near_field",
                 _time(lambda: jax.block_until_ready(nearf(tree))), "P2P"))


def bench_fig7_9_scaling(rows, quick=False):
    """Paper Figs 7-9: speedup / efficiency / load balance (modeled)."""
    from benchmarks.fmm_scaling import scaling_table
    level = 8 if quick else 10
    t = scaling_table(level=level, cut=4)
    for r in t:
        rows.append((f"fig7_speedup_P{r['P']}", 0.0, f"{r['S_model']:.2f}"))
        rows.append((f"fig8_efficiency_P{r['P']}", 0.0, f"{r['E_model']:.3f}"))
        rows.append((f"fig9_loadbalance_P{r['P']}", 0.0,
                     f"model={r['LB_model']:.3f}_uniform={r['LB_uniform']:.3f}"))


def bench_table12_memory(rows, quick=False):
    """Paper §5.3 Tables 1-2 + the 64M-particle headline (<1.01 GB/proc)."""
    from repro.core import cost_model as cm
    params = cm.ModelParams(level=10, cut=4, p=17, slots=1)
    mem = cm.memory_serial(params, 765_625)
    rows.append(("table1_serial_total_MB", 0.0, f"{sum(mem.values())/1e6:.1f}"))
    par = cm.memory_parallel(params, 64, 256, 64)
    rows.append(("table2_parallel_total_MB", 0.0, f"{sum(par.values())/1e6:.1f}"))
    # 64M particles / 64 procs headline (paper: 115.8 s, < 1.01 GB/proc)
    params64 = cm.ModelParams(level=12, cut=5, p=17, slots=4)
    per_proc = (sum(cm.memory_serial(params64, 64_000_000).values()) / 64 +
                sum(cm.memory_parallel(params64, 64, 1024, 128).values()))
    rows.append(("headline_64M_per_proc_paperTable_GB", 0.0, f"{per_proc/1e9:.2f}"))
    # our dense implementation stores NO interaction lists/values (generated
    # from the 40 static offsets — the paper's own 'future improvement'):
    L, p, s = 12, 17, 4
    nleaf = 4 ** L
    lam = cm.total_boxes(L)
    ours = (nleaf * s * (8 + 8 + 1 + 8)      # z, q, mask, W
            + lam * p * 8 * 2) / 64          # ME + LE grids (complex64)
    rows.append(("headline_64M_per_proc_ours_GB", 0.0, f"{ours/1e9:.2f}"))


def bench_kernels(rows, quick=False):
    """Pallas kernels vs jnp reference (CPU: ref timed; kernels run in the
    interpreter for correctness, so 'derived' reports the validation error)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.m2l import m2l_pallas
    from repro.kernels.p2p import p2p_pallas
    from repro.kernels.flash_attn import flash_attention

    rng = np.random.default_rng(0)
    ny = nx = 8 if quick else 16
    s = 8
    z = jnp.asarray(rng.uniform(size=(ny, nx, s)) + 1j * rng.uniform(size=(ny, nx, s)),
                    jnp.complex64)
    q = jnp.asarray(rng.normal(size=(ny, nx, s)) + 0j, jnp.complex64)
    mask = jnp.ones((ny, nx, s), bool)
    expect = np.asarray(ref.p2p_ref(z, q, mask, 0.05))
    p2p_ref_t = _time(lambda: jax.block_until_ready(ref.p2p_ref(z, q, mask, 0.05)))
    err = float(np.linalg.norm(np.asarray(p2p_pallas(z, q, mask, 0.05)) - expect) /
                np.linalg.norm(expect))
    rows.append(("kernel_p2p_ref_jnp", p2p_ref_t, f"pallas_relerr={err:.1e}"))

    p = 17
    me = jnp.asarray(rng.normal(size=(ny, nx, p)) + 1j * rng.normal(size=(ny, nx, p)),
                     jnp.complex64)
    expect = np.asarray(ref.m2l_ref(me, 4, p))
    m2l_t = _time(lambda: jax.block_until_ready(ref.m2l_ref(me, 4, p)))
    err = float(np.linalg.norm(np.asarray(m2l_pallas(me, 4, p)) - expect) /
                np.linalg.norm(expect))
    rows.append(("kernel_m2l_ref_jnp", m2l_t, f"pallas_relerr={err:.1e}"))

    qq = jnp.asarray(rng.normal(size=(1, 4, 128, 64)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    expect = np.asarray(ref.attention_ref(qq, kk, kk))
    fa_t = _time(lambda: jax.block_until_ready(ref.attention_ref(qq, kk, kk)))
    err = float(np.linalg.norm(
        np.asarray(flash_attention(qq, kk, kk, block_q=64, block_k=64)) - expect) /
        np.linalg.norm(expect))
    rows.append(("kernel_flash_attn_ref_jnp", fa_t, f"pallas_relerr={err:.1e}"))


def bench_moe_placement(rows, quick=False):
    """The paper's technique transplanted: expert-placement load balance."""
    from repro.models.moe import expert_placement
    rng = np.random.default_rng(0)
    E, ranks = 64, 8
    counts = (rng.zipf(1.5, E) * 100).clip(0, 50_000).astype(np.float64)
    coact = np.zeros((E, E))
    assign = expert_placement(counts, coact, ranks)
    loads = np.bincount(assign, weights=counts, minlength=ranks)
    naive = counts.reshape(ranks, -1).sum(1)
    rows.append(("moe_placement_lb", 0.0,
                 f"model={loads.min()/max(loads.max(),1):.3f}_"
                 f"contiguous={naive.min()/max(naive.max(),1):.3f}"))


def main() -> None:
    quick = "--quick" in sys.argv
    rows: list[tuple[str, float, str]] = []
    for bench in (bench_fig6_stage_timings, bench_fig7_9_scaling,
                  bench_table12_memory, bench_kernels, bench_moe_placement):
        bench(rows, quick=quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
