"""Benchmark harness: one entry per paper table/figure + kernel benches.

Prints ``name,us_per_call,derived`` CSV rows (the scaffold contract).
``derived`` carries the figure-specific metric (efficiency, LB, GB/s, ...).
Run: PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]

``--json PATH`` additionally writes the rows as a JSON list of
``{"name", "us_per_call", "derived"}`` objects — the machine-readable
baseline the perf acceptance criteria diff against (BENCH_fmm.json).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _time(fn, reps=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_fig6_stage_timings(rows, quick=False):
    """Paper Fig 6: per-stage FMM timings (measured, serial, CPU)."""
    import jax
    from repro.core import expansions as ex
    from repro.core.fmm import fmm_velocity, near_field, upward_sweep
    from repro.core.quadtree import build_tree

    n_particles, level, p = (20_000, 5, 12) if quick else (100_000, 6, 17)
    rng = np.random.default_rng(0)
    pos = rng.uniform(0.01, 0.99, (n_particles, 2))
    tree, _ = build_tree(pos, rng.normal(size=n_particles), level, 0.02)

    total = _time(lambda: jax.block_until_ready(fmm_velocity(tree, p)))
    rows.append(("fig6_total_fmm", total, f"N={n_particles}_L={level}_p={p}"))

    up = jax.jit(lambda t: upward_sweep(t, p)[0], static_argnames=())
    rows.append(("fig6_upward_sweep", _time(lambda: jax.block_until_ready(up(tree))),
                 "P2M+M2M"))
    me = upward_sweep(tree, p)
    m2l = jax.jit(lambda g: ex.m2l_reference(g, level, p))
    m2l_t = _time(lambda: jax.block_until_ready(m2l(me[level])))
    rows.append(("fig6_m2l_leaf_level", m2l_t, "M2L_parity_folded"))
    # same-op comparison: the pre-folding 40-offset masked formulation
    m2l40 = jax.jit(lambda g: ex.m2l_masked40(g, level, p))
    m2l40_t = _time(lambda: jax.block_until_ready(m2l40(me[level])))
    rows.append(("fig6_m2l_leaf_level_masked40", m2l40_t,
                 f"folded_speedup={m2l40_t / max(m2l_t, 1e-9):.2f}x"))
    nearf = jax.jit(near_field)
    rows.append(("fig6_p2p_near_field",
                 _time(lambda: jax.block_until_ready(nearf(tree))), "P2P"))


def bench_fig7_9_scaling(rows, quick=False):
    """Paper Figs 7-9: speedup / efficiency / load balance (modeled)."""
    from benchmarks.fmm_scaling import scaling_table
    level = 8 if quick else 10
    t = scaling_table(level=level, cut=4)
    for r in t:
        rows.append((f"fig7_speedup_P{r['P']}", 0.0, f"{r['S_model']:.2f}"))
        rows.append((f"fig8_efficiency_P{r['P']}", 0.0, f"{r['E_model']:.3f}"))
        rows.append((f"fig9_loadbalance_P{r['P']}", 0.0,
                     f"model={r['LB_model']:.3f}_uniform={r['LB_uniform']:.3f}"))


def bench_table12_memory(rows, quick=False):
    """Paper §5.3 Tables 1-2 + the 64M-particle headline (<1.01 GB/proc)."""
    from repro.core import cost_model as cm
    params = cm.ModelParams(level=10, cut=4, p=17, slots=1)
    mem = cm.memory_serial(params, 765_625)
    rows.append(("table1_serial_total_MB", 0.0, f"{sum(mem.values())/1e6:.1f}"))
    par = cm.memory_parallel(params, 64, 256, 64)
    rows.append(("table2_parallel_total_MB", 0.0, f"{sum(par.values())/1e6:.1f}"))
    # 64M particles / 64 procs headline (paper: 115.8 s, < 1.01 GB/proc)
    params64 = cm.ModelParams(level=12, cut=5, p=17, slots=4)
    per_proc = (sum(cm.memory_serial(params64, 64_000_000).values()) / 64 +
                sum(cm.memory_parallel(params64, 64, 1024, 128).values()))
    rows.append(("headline_64M_per_proc_paperTable_GB", 0.0, f"{per_proc/1e9:.2f}"))
    # our dense implementation stores NO interaction lists/values (generated
    # from the 40 static offsets — the paper's own 'future improvement'):
    L, p, s = 12, 17, 4
    nleaf = 4 ** L
    lam = cm.total_boxes(L)
    ours = (nleaf * s * (8 + 8 + 1 + 8)      # z, q, mask, W
            + lam * p * 8 * 2) / 64          # ME + LE grids (complex64)
    rows.append(("headline_64M_per_proc_ours_GB", 0.0, f"{ours/1e9:.2f}"))


def bench_kernels(rows, quick=False):
    """Pallas kernels vs jnp reference, same op on both sides (CPU: the
    kernels run in the Pallas interpreter, so their wall time is a
    validation-mode number; 'derived' reports the oracle error)."""
    import jax
    import jax.numpy as jnp
    from repro.core import expansions as ex
    from repro.kernels import ref
    from repro.kernels.m2l import m2l_pallas
    from repro.kernels.p2p import p2p_pallas
    from repro.kernels.flash_attn import flash_attention

    rng = np.random.default_rng(0)
    ny = nx = 8 if quick else 16
    s = 8
    z = jnp.asarray(rng.uniform(size=(ny, nx, s)) + 1j * rng.uniform(size=(ny, nx, s)),
                    jnp.complex64)
    q = jnp.asarray(rng.normal(size=(ny, nx, s)) + 0j, jnp.complex64)
    mask = jnp.ones((ny, nx, s), bool)
    expect = np.asarray(ref.p2p_ref(z, q, mask, 0.05))
    p2p_jit = jax.jit(lambda a, b, c: ref.p2p_ref(a, b, c, 0.05))
    p2p_ref_t = _time(lambda: jax.block_until_ready(p2p_jit(z, q, mask)))
    err = float(np.linalg.norm(np.asarray(p2p_pallas(z, q, mask, 0.05)) - expect) /
                np.linalg.norm(expect))
    rows.append(("kernel_p2p_ref_jnp", p2p_ref_t, f"pallas_relerr={err:.1e}"))
    p2p_k_t = _time(lambda: jax.block_until_ready(p2p_pallas(z, q, mask, 0.05)))
    rows.append(("kernel_p2p_pallas_interpret", p2p_k_t,
                 f"same_op_ref_us={p2p_ref_t:.1f}"))

    p = 17
    level = 4
    me = jnp.asarray(rng.normal(size=(ny, nx, p)) + 1j * rng.normal(size=(ny, nx, p)),
                     jnp.complex64)
    expect = np.asarray(ref.m2l_ref(me, level, p))          # masked-40 oracle
    m2l_fold = jax.jit(lambda g: ex.m2l_reference(g, level, p))
    m2l_t = _time(lambda: jax.block_until_ready(m2l_fold(me)))
    err = float(np.linalg.norm(np.asarray(m2l_pallas(me, level, p)) - expect) /
                np.linalg.norm(expect))
    rows.append(("kernel_m2l_ref_jnp", m2l_t, f"pallas_relerr={err:.1e}"))
    m2l_k_t = _time(lambda: jax.block_until_ready(m2l_pallas(me, level, p)))
    rows.append(("kernel_m2l_pallas_interpret", m2l_k_t,
                 f"same_op_ref_us={m2l_t:.1f}"))

    qq = jnp.asarray(rng.normal(size=(1, 4, 128, 64)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    expect = np.asarray(ref.attention_ref(qq, kk, kk))
    fa_t = _time(lambda: jax.block_until_ready(ref.attention_ref(qq, kk, kk)))
    err = float(np.linalg.norm(
        np.asarray(flash_attention(qq, kk, kk, block_q=64, block_k=64)) - expect) /
        np.linalg.norm(expect))
    rows.append(("kernel_flash_attn_ref_jnp", fa_t, f"pallas_relerr={err:.1e}"))


def bench_m2l_staging_bytes(rows, quick=False):
    """hlo_analysis check that parity folding dropped the M2L HBM traffic.

    Walks the optimized HLO of the folded reference, the pre-folding
    masked-40 formulation, and the Pallas kernel wrapper.  The folded paths
    must move fewer bytes AND contain no ``40p``-wide staging buffer (the
    old wrapper's (nb, 40p) gather tensor)."""
    import jax
    import jax.numpy as jnp
    from repro.core import expansions as ex
    from repro.kernels import ops as kops
    from repro.launch.hlo_analysis import analyze_hlo, shape_dim_pattern

    rng = np.random.default_rng(0)
    level, p = (3, 12) if quick else (4, 17)
    n = 1 << level
    me = jnp.asarray(rng.normal(size=(n, n, p)) + 1j * rng.normal(size=(n, n, p)),
                     jnp.complex64)

    def hlo(fn):
        return jax.jit(fn).lower(me).compile().as_text()

    b_old = analyze_hlo(hlo(lambda g: ex.m2l_masked40(g, level, p)))["bytes"]
    b_new = analyze_hlo(hlo(lambda g: ex.m2l_reference(g, level, p)))["bytes"]
    t_kern = hlo(lambda g: kops.m2l_apply(g, level, p))
    b_kern = analyze_hlo(t_kern)["bytes"]
    n40 = len(shape_dim_pattern(40 * p).findall(t_kern))
    rows.append(("m2l_hbm_bytes_masked40", 0.0, f"{b_old:.3e}"))
    rows.append(("m2l_hbm_bytes_folded", 0.0,
                 f"{b_new:.3e}_drop={b_old / max(b_new, 1.0):.2f}x"))
    rows.append(("m2l_kernel_wrapper_staging", 0.0,
                 f"bytes={b_kern:.3e}_40p_buffers={n40}"))


def bench_parallel_multidevice(rows, quick=False):
    """Sharded FMM wall time on forced host devices (subprocess: jax locks
    the device count at first init, and the parent runs single-device)."""
    ndev = 2 if quick else 4
    level, p = (4, 8) if quick else (5, 12)
    body = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
        import time
        import numpy as np
        import jax
        from jax.sharding import Mesh
        from repro.core.parallel_fmm import parallel_fmm_velocity
        from repro.core.quadtree import build_tree

        rng = np.random.default_rng(0)
        n_particles = {4000 if quick else 20000}
        pos = rng.uniform(0.02, 0.98, size=(n_particles, 2))
        tree, _ = build_tree(pos, rng.normal(size=n_particles), {level}, 0.02)
        mesh = Mesh(np.array(jax.devices()[:{ndev}]), ("data",))
        fn = lambda: jax.block_until_ready(parallel_fmm_velocity(tree, {p}, mesh))
        fn()
        t0 = time.perf_counter()
        for _ in range(3):
            fn()
        print("US", (time.perf_counter() - t0) / 3 * 1e6)
    """)
    env = dict(os.environ)
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    old_pp = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src_dir + (os.pathsep + old_pp if old_pp else "")
    try:
        proc = subprocess.run([sys.executable, "-c", body], capture_output=True,
                              text=True, env=env, timeout=600)
        us = [float(l.split()[1]) for l in proc.stdout.splitlines()
              if l.startswith("US")]
        if proc.returncode != 0 or not us:
            raise RuntimeError(proc.stderr[-300:])
        rows.append((f"parallel_fmm_P{ndev}", us[0], f"L={level}_p={p}"))
    except Exception as e:  # report, never abort the whole harness
        detail = " ".join(str(e).split())[-160:].replace(",", ";")
        rows.append((f"parallel_fmm_P{ndev}", 0.0,
                     f"failed:{type(e).__name__}:{detail}"))


def bench_plan_execution(rows, quick=False):
    """Partition-driven execution plans on the Lamb-Oseen lattice (paper
    Eq 20 next to measured step time): uniform strawman vs a-priori model
    plan vs dynamic re-planning vs a 2-D block grid, on forced host devices
    (subprocess: jax locks the device count at first init).

    Timing protocol: after the compile-warm step, the loop keeps stepping
    (bounded) until a step adopts no new plan/level — that step doubles as
    the warm step for whatever plan is current, so re-level/re-plan
    recompiles never land inside the timed window.  The reported time is
    the MINIMUM steady-state step (robust to host-device scheduling noise);
    any adoption that still happens while timing is counted and emitted in
    the derived field (releveled/replanned), keeping the trajectory
    comparable across PRs.
    """
    ndev = 4
    m_side, p, steps = (120, 8, 3) if quick else (160, 12, 4)
    modes = ("uniform", "model", "dynamic", "block")
    body = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
        import time
        import numpy as np
        import jax
        from jax.sharding import Mesh
        from repro.core.stepper import VortexStepper
        from repro.core.vortex import lamb_oseen_particles

        pos, gamma, sigma = lamb_oseen_particles({m_side})
        mesh = Mesh(np.array(jax.devices()[:{ndev}]), ("data",))
        for mode in {modes!r}:
            st = VortexStepper(pos, gamma, sigma, p={p}, dt=0.004, mesh=mesh,
                               plan_method="uniform" if mode == "uniform" else "model",
                               dynamic=(mode in ("dynamic", "block")),
                               plan_grid=(2, 2) if mode == "block" else None,
                               replan_every=2)
            st.step()                      # compile + warm
            for _ in range(4):             # settle: warm again after adoption
                rec = st.step()
                if not (rec.replanned or rec.releveled):
                    break
            releveled = replanned = 0
            timed = []
            for _ in range({steps}):
                rec = st.step()
                releveled += rec.releveled
                replanned += rec.replanned
                timed.append(rec.seconds)
            us = min(timed) * 1e6
            s = st.stats()
            geom = "/".join(map(str, st.plan.rows))
            if mode == "block":
                geom += "x" + "/".join(map(str, st.plan.cols))
            print(f"ROW plan_{{mode}} {{us:.1f}} "
                  f"LB={{s['load_balance']:.3f}}_min={{s['min_load']:.3g}}"
                  f"_max={{s['max_load']:.3g}}_rows={{geom}}"
                  f"_releveled={{releveled}}_replanned={{replanned}}")
    """)
    env = dict(os.environ)
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    old_pp = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src_dir + (os.pathsep + old_pp if old_pp else "")
    try:
        proc = subprocess.run([sys.executable, "-c", body], capture_output=True,
                              text=True, env=env, timeout=1800)
        got = [l.split(maxsplit=3) for l in proc.stdout.splitlines()
               if l.startswith("ROW")]
        if proc.returncode != 0 or len(got) != len(modes):
            raise RuntimeError(proc.stderr[-300:])
        for _, name, us, derived in got:
            rows.append((name, float(us), derived))
    except Exception as e:  # report, never abort the whole harness
        detail = " ".join(str(e).split())[-160:].replace(",", ";")
        for mode in modes:
            rows.append((f"plan_{mode}", 0.0,
                         f"failed:{type(e).__name__}:{detail}"))


def bench_plan_halo(rows, quick=False):
    """1-D band vs 2-D block halo volume on the Lamb-Oseen lattice (the
    BlockPlan's reason to exist — ROADMAP "2-D execution plans").

    ``halo_model_P*`` prices the valid-extent (modeled) ppermute bytes per
    FMM evaluation; ``halo_exec_P*`` prices what the driver literally
    transfers (padded extents + corner-carrying strips).  Host-side only —
    no devices needed."""
    from repro.core.cost_model import ModelParams
    from repro.core.plan import halo_volume, plan_from_counts
    from repro.core.quadtree import build_tree
    from repro.core.vortex import lamb_oseen_particles

    level = 5 if quick else 6
    pos, gamma, sigma = lamb_oseen_particles(120 if quick else 160)
    tree, index = build_tree(pos, gamma, level, sigma)
    params = ModelParams(level=level, cut=4, p=12, slots=tree.slots)
    grids = {4: (2, 2), 8: (4, 2), 16: (4, 4)}
    for P in (4, 8) if quick else (4, 8, 16):
        slab = plan_from_counts(index.counts, params, P, method="model")
        block = plan_from_counts(index.counts, params, P, method="model",
                                 grid=grids[P])
        for tag, executed in (("model", False), ("exec", True)):
            hs = halo_volume(slab, params, executed=executed)["total"]
            hb = halo_volume(block, params, executed=executed)["total"]
            rows.append((f"halo_{tag}_P{P}", 0.0,
                         f"slab={hs:.3e}_block={hb:.3e}"
                         f"_ratio={hs / hb:.2f}x"))


def bench_moe_placement(rows, quick=False):
    """The paper's technique transplanted: expert-placement load balance."""
    from repro.models.moe import expert_placement
    rng = np.random.default_rng(0)
    E, ranks = 64, 8
    counts = (rng.zipf(1.5, E) * 100).clip(0, 50_000).astype(np.float64)
    coact = np.zeros((E, E))
    assign = expert_placement(counts, coact, ranks)
    loads = np.bincount(assign, weights=counts, minlength=ranks)
    naive = counts.reshape(ranks, -1).sum(1)
    rows.append(("moe_placement_lb", 0.0,
                 f"model={loads.min()/max(loads.max(),1):.3f}_"
                 f"contiguous={naive.min()/max(naive.max(),1):.3f}"))


def main() -> None:
    quick = "--quick" in sys.argv
    json_path = None
    if "--json" in sys.argv:
        i = sys.argv.index("--json")
        if i + 1 >= len(sys.argv) or sys.argv[i + 1].startswith("--"):
            sys.exit("usage: python -m benchmarks.run [--quick] [--json PATH]")
        json_path = sys.argv[i + 1]
    rows: list[tuple[str, float, str]] = []
    for bench in (bench_fig6_stage_timings, bench_fig7_9_scaling,
                  bench_table12_memory, bench_kernels, bench_m2l_staging_bytes,
                  bench_parallel_multidevice, bench_plan_execution,
                  bench_plan_halo, bench_moe_placement):
        bench(rows, quick=quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump([{"name": n, "us_per_call": round(u, 1), "derived": d}
                       for n, u, d in rows], f, indent=2)
        print(f"# wrote {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
