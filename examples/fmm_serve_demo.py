"""Multi-tenant serving drill: the FMM-as-a-service acceptance scenario.

Spins up :class:`repro.serve.fmm_service.FmmServiceEngine` on N forced
host devices and drives a mixed workload from four tenants at once:

* two vortex RK2 trajectory sessions (streamed, prefetched),
* a wave of laplace probe-grid one-shots,
* a wave of tracer (passive velocity probe) one-shots,
* an oversized job that must be REJECTED with its cost-model price.

Every result is asserted against its single-tenant reference: sessions
against a serial ``VortexStepper`` run of the same system, one-shots
against the f64 ``direct_sum`` oracle — so multi-tenancy, batching, and
sharding change nothing but throughput.  Steady-state serving is pinned
retrace-free: the second wave of one-shots must not grow any batched jit
cache.

Run:  PYTHONPATH=src python examples/fmm_serve_demo.py [--devices 4]
          [--n 600] [--steps 3] [--p 8]
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--n", type=int, default=600,
                    help="particles per session tenant")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--p", type=int, default=8)
    ap.add_argument("--sigma", type=float, default=0.02)
    ap.add_argument("--dt", type=float, default=1e-3)
    args = ap.parse_args()

    if args.devices > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}")

    sys.path.insert(0, "src")
    import numpy as np
    import jax
    from jax.sharding import Mesh

    from repro.core import equations as eqs
    from repro.core.stepper import VortexStepper
    from repro.serve import fmm_service as svc

    ndev = min(args.devices, len(jax.devices()))
    mesh = Mesh(np.array(jax.devices()[:ndev]), ("data",)) if ndev > 1 \
        else None
    print(f"== fmm_serve_demo: {ndev} device(s), "
          f"{args.steps}-step sessions, n={args.n}")

    engine = svc.FmmServiceEngine(mesh=mesh)
    rng = np.random.default_rng(11)

    # -- tenants 1+2: vortex RK2 trajectory sessions -------------------------
    session_inputs = []
    for t in range(2):
        pos = rng.uniform(0.25, 0.75, size=(args.n, 2))
        gam = 0.1 * rng.normal(size=args.n)     # gentle dynamics: the drill
        session_inputs.append((pos, gam))       # compares trajectories
    sids = [engine.submit(svc.FmmJob(
        positions=pos, strength=gam, steps=args.steps, p=args.p,
        dt=args.dt, sigma=args.sigma, tenant=f"vortex-{t}"))
        for t, (pos, gam) in enumerate(session_inputs)]

    # -- tenants 3+4: laplace probe one-shots + tracer jobs ------------------
    oneshot_jobs = []
    for w in range(3):
        n_src = 180 + 8 * w            # nearby sizes share one bucket
        src = rng.uniform(0.1, 0.9, size=(n_src, 2))
        q = rng.normal(size=n_src)
        tgt = rng.uniform(0.1, 0.9, size=(72, 2))
        for eq_name in ("laplace", "tracer"):
            jid = engine.submit(svc.FmmJob(
                positions=src, strength=q, equation=eq_name, targets=tgt,
                p=12, sigma=args.sigma, tenant=eq_name))
            oneshot_jobs.append((jid, eq_name, src, q, tgt))

    # -- oversized job: typed rejection with its Eq 13-15 price --------------
    big = rng.uniform(0.0, 1.0, size=(200_000, 2))
    try:
        engine.submit(svc.FmmJob(positions=big, strength=np.ones(len(big)),
                                 level=9, p=24, sigma=args.sigma,
                                 tenant="whale"))
        raise AssertionError("oversized job was not rejected")
    except svc.JobRejected as e:
        assert e.price.total_flops > engine.budget.max_job_flops
        print(f"   oversized job rejected as priced: "
              f"{e.price.total_flops:.3g} modeled flops "
              f"(budget {engine.budget.max_job_flops:.3g})")

    # -- serve everything concurrently ---------------------------------------
    # Pull the first step of each session stream to start both prefetch
    # workers, then drain the one-shot queue while the sessions' next steps
    # compute in their worker threads — all four tenants in flight at once.
    import itertools

    streams = [engine.session(sid).stream(args.steps) for sid in sids]
    first = [next(s) for s in streams]
    engine.drain()
    finals = [None, None]
    for t, stream in enumerate(streams):
        for i, pos_t, rec in itertools.chain([first[t]], stream):
            print(f"   session {t}: step {i} "
                  f"({rec.seconds * 1e3:.1f} ms, lb={rec.load_balance:.3f})")
        finals[t] = engine.session(sids[t]).particles()[0]

    # -- references -----------------------------------------------------------
    def canon(a):
        # particles() returns (box, slot) order, which depends on the tree
        # level — canonicalize to a position-sorted point set to compare a
        # sharded session against a serial reference binned differently
        return a[np.lexsort((a[:, 1], a[:, 0]))]

    for t, (pos, gam) in enumerate(session_inputs):
        ref = VortexStepper(pos, gam, args.sigma, p=args.p, dt=args.dt)
        for _ in range(args.steps):
            ref.step()
        ref_pos = ref.particles()[0]
        err = np.abs(canon(finals[t]) - canon(ref_pos)).max()
        print(f"   session {t} vs serial reference: max |dx| = {err:.2e}")
        assert err < 5e-4, f"session {t} diverged from reference: {err}"

    for jid, eq_name, src, q, tgt in oneshot_jobs:
        out = engine.result(jid).out
        ref = eqs.direct_sum(eq_name, tgt[:, 0] + 1j * tgt[:, 1],
                             src[:, 0] + 1j * src[:, 1], q, args.sigma)
        if eq_name == "laplace":
            # Re of the potential channel is branch-cut exact; the field
            # channel compares as a full complex value
            err = max(np.abs(out[:, 0].real - ref[:, 0].real).max()
                      / np.abs(ref[:, 0].real).max(),
                      np.abs(out[:, 1] - ref[:, 1]).max()
                      / np.abs(ref[:, 1]).max())
        else:
            err = np.abs(out - ref).max() / np.abs(ref).max()
        assert err < 2e-3, f"{eq_name} job {jid}: rel err {err:.2e}"
        print(f"   {eq_name} job {jid} vs f64 direct sum: "
              f"rel err = {err:.2e}")

    # -- steady state must not retrace ---------------------------------------
    # second wave: same layouts (-> same buckets), FRESH charge strengths —
    # new tenant data must ride the compiled programs, not recompile them
    entries_warm = svc.batched_cache_entries()
    for jid, eq_name, src, q, tgt in oneshot_jobs:
        engine.submit(svc.FmmJob(positions=src,
                                 strength=rng.normal(size=len(src)),
                                 equation=eq_name, targets=tgt, p=12,
                                 sigma=args.sigma, tenant=eq_name))
    engine.drain()
    entries_steady = svc.batched_cache_entries()
    assert entries_steady == entries_warm, \
        f"steady-state serving retraced: {entries_warm} -> {entries_steady}"
    print(f"   steady-state retraces: 0 "
          f"(batched jit entries pinned at {entries_steady})")

    stats = engine.stats()
    print(f"   cache: {stats['cache']}  "
          f"batch_utilization={stats['batch_utilization']:.2f}")
    for lane, l in stats["latency"].items():
        print(f"   latency[{lane}]: p50={l['p50_ms']:.1f} ms "
              f"p99={l['p99_ms']:.1f} ms (n={l['n']})")
    print("== fmm_serve_demo: OK")


if __name__ == "__main__":
    main()
