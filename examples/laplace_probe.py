"""Laplace charges + a probe-grid evaluation through the sharded driver.

The equation-registry client (DESIGN.md §10): point charges induce the 2-D
Laplace potential ``q log|z - z_j|`` and field ``-q/(z - z_j)``; both come
out of ONE downward sweep of the ``laplace`` equation, and a passive probe
grid — binned into the same tree as a targets batch — is evaluated against
the sources' local expansions and near field, sharded by the same
partition-driven execution plan the vortex client uses.  Nothing here is
vortex-specific: the drivers consume only the equation spec.

Run:  PYTHONPATH=src python examples/laplace_probe.py [--devices 4]
          [--n-charges 4000] [--probe-side 48] [--plan model]
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-charges", type=int, default=4000)
    ap.add_argument("--probe-side", type=int, default=48,
                    help="probe grid resolution (probe-side^2 targets)")
    ap.add_argument("--p", type=int, default=12)
    ap.add_argument("--level", type=int, default=5)
    ap.add_argument("--sigma", type=float, default=0.01)
    ap.add_argument("--plan", choices=("uniform", "model"), default="model")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard over N devices (forces host devices on CPU)")
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--check", type=int, default=400,
                    help="probe subsample size verified against the f64 "
                         "direct sum")
    args = ap.parse_args()

    if args.devices > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}")

    sys.path.insert(0, "src")
    import numpy as np
    import jax
    from jax.sharding import Mesh

    from repro.core import equations as eqs
    from repro.core.cost_model import ModelParams
    from repro.core.fmm import fmm_evaluate
    from repro.core.parallel_fmm import parallel_fmm_evaluate
    from repro.core.plan import plan_from_counts, plan_stats
    from repro.core.quadtree import build_tree, gather_particle_values

    eq = eqs.LAPLACE
    rng = np.random.default_rng(0)

    # a +/- charge dipole pair of Gaussian clusters over a weak background
    n_half = args.n_charges // 2
    pos = np.concatenate([
        rng.normal((0.35, 0.5), 0.08, size=(n_half, 2)),
        rng.normal((0.65, 0.5), 0.08, size=(args.n_charges - n_half, 2)),
    ]).clip(0.01, 0.99)
    charge = np.concatenate([np.ones(n_half),
                             -np.ones(args.n_charges - n_half)])
    charge *= 1.0 + 0.1 * rng.normal(size=args.n_charges)

    # probe grid: passive targets binned into the SAME tree level
    xs = np.linspace(0.06, 0.94, args.probe_side)
    PX, PY = np.meshgrid(xs, xs, indexing="xy")
    probes = np.stack([PX.ravel(), PY.ravel()], axis=1)

    tree, index = build_tree(pos, charge, args.level, sigma=args.sigma,
                             charge_scale=eq.charge_scale)
    targets, tindex = build_tree(probes, np.zeros(len(probes)), args.level,
                                 sigma=args.sigma)

    mesh = None
    if args.devices > 1:
        if len(jax.devices()) < args.devices:
            sys.exit(f"need {args.devices} devices, have {len(jax.devices())}")
        mesh = Mesh(np.array(jax.devices()[:args.devices]), ("data",))

    plan = None
    if mesh is not None:
        params = ModelParams(level=args.level, cut=min(args.level - 1, 4),
                             p=args.p, slots=tree.slots, nout=eq.nout)
        plan = plan_from_counts(index.counts, params, args.devices,
                                method=args.plan)
        lb = plan_stats(plan, index.counts, params)["load_balance"]
        print(f"plan={args.plan} devices={args.devices} "
              f"bands={plan.describe()} LB(min/max)={lb:.3f}")

    if mesh is None:
        out = fmm_evaluate(tree, args.p, eq=eq, targets=targets,
                           use_kernels=args.use_kernels)
    else:
        out = parallel_fmm_evaluate(tree, args.p, mesh, plan=plan, eq=eq,
                                    targets=targets,
                                    use_kernels=args.use_kernels)
    out = np.asarray(jax.block_until_ready(out))
    pot = gather_particle_values(out[..., 0], tindex).real
    fld = gather_particle_values(out[..., 1], tindex)
    print(f"probes={len(probes)} potential range "
          f"[{pot.min():+.3f}, {pot.max():+.3f}]  max|E|={np.abs(fld).max():.3f}")

    # verify a probe subsample against the f64 direct sum
    sel = rng.choice(len(probes), size=min(args.check, len(probes)),
                     replace=False)
    z_src = pos[:, 0] + 1j * pos[:, 1]
    z_prb = probes[sel, 0] + 1j * probes[sel, 1]
    exact = eqs.direct_sum(eq, z_prb, z_src, charge, sigma=args.sigma)
    err_pot = np.linalg.norm(pot[sel] - exact[:, 0].real) \
        / np.linalg.norm(exact[:, 0].real)
    err_fld = np.linalg.norm(fld[sel] - exact[:, 1]) \
        / np.linalg.norm(exact[:, 1])
    print(f"vs direct sum: potential rel err {err_pot:.2e}, "
          f"field rel err {err_fld:.2e}")
    assert err_pot < 1e-4 and err_fld < 1e-4, (err_pot, err_fld)
    print("OK")


if __name__ == "__main__":
    main()
