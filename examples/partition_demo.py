"""Paper Fig 5 reproduction: automatic load-balanced partition of the FMM
tree, visualized as an ASCII map of subtree -> processor assignments.

Run:  PYTHONPATH=src python examples/partition_demo.py [--nparts 16]
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core.cost_model import ModelParams
from repro.core.partition import (build_subtree_graph, partition,
                                  partition_stats)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nparts", type=int, default=16)
    ap.add_argument("--level", type=int, default=8)
    ap.add_argument("--cut", type=int, default=4)
    ap.add_argument("--distribution", default="uniform",
                    choices=["uniform", "gaussian", "two-cluster"])
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    n = 1 << args.level
    N = 200_000
    if args.distribution == "uniform":
        pos = rng.uniform(0, 1, (N, 2))
    elif args.distribution == "gaussian":
        pos = rng.normal(0.5, 0.15, (N, 2)).clip(0.001, 0.999)
    else:
        a = rng.normal((0.3, 0.3), 0.08, (N // 2, 2))
        b = rng.normal((0.75, 0.7), 0.12, (N // 2, 2))
        pos = np.concatenate([a, b]).clip(0.001, 0.999)
    ij = (pos * n).astype(int)
    counts = np.zeros((n, n), dtype=np.int64)
    np.add.at(counts, (ij[:, 1], ij[:, 0]), 1)

    params = ModelParams(level=args.level, cut=args.cut, p=17,
                         slots=max(int(counts.max()), 1))
    g = build_subtree_graph(counts, params)
    nsub = 1 << args.cut

    for method in ("uniform-sfc", "model"):
        assign = partition(g, args.nparts, method=method)
        stats = partition_stats(g, assign, args.nparts)
        print(f"\n== {method}: LB={stats['load_balance']:.3f} "
              f"cut={stats['edge_cut']:.2e} imbalance={stats['imbalance']:.3f}")
        grid = assign.reshape(nsub, nsub)
        sym = "0123456789abcdefghijklmnopqrstuvwxyz"
        for row in grid:
            print("  " + " ".join(sym[v % len(sym)] for v in row))
    print("\n(paper Fig 5: 256 subtrees distributed among 16 partitions)")


if __name__ == "__main__":
    main()
