"""Quickstart: evaluate the velocity of N vortex particles with the FMM.

Builds a Lamb-Oseen vortex (the paper's §7 test case), runs the full FMM
(upward sweep, M2L, L2L, evaluation) and compares against the O(N^2)
direct Biot-Savart sum and the analytical solution.

Run:  PYTHONPATH=src python examples/quickstart.py [--n-side 150] [--p 17]
"""
import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core.fmm import fmm_velocity
from repro.core.quadtree import build_tree, choose_level, gather_particle_values
from repro.core.vortex import direct_sum, lamb_oseen_particles, lamb_oseen_velocity


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-side", type=int, default=120)
    ap.add_argument("--p", type=int, default=17)
    ap.add_argument("--use-kernels", action="store_true",
                    help="route M2L/P2P through the Pallas kernels (interpret)")
    args = ap.parse_args()

    pos, gamma, sigma = lamb_oseen_particles(args.n_side)
    n = len(pos)
    level = choose_level(n, target_per_box=8)
    print(f"N = {n} particles, tree level {level}, p = {args.p}, sigma = {sigma:.4f}")

    tree, index = build_tree(pos, gamma, level, sigma)
    t0 = time.perf_counter()
    w = np.asarray(fmm_velocity(tree, args.p, use_kernels=args.use_kernels))
    t_fmm = time.perf_counter() - t0
    w_at = gather_particle_values(w, index)

    t0 = time.perf_counter()
    exact = direct_sum(pos[:, 0] + 1j * pos[:, 1], gamma, sigma)
    t_dir = time.perf_counter() - t0

    err = np.linalg.norm(w_at - exact) / np.linalg.norm(exact)
    print(f"FMM time    : {t_fmm:.3f} s  (includes jit compile on first call)")
    print(f"direct time : {t_dir:.3f} s")
    print(f"relative L2 error vs direct sum: {err:.3e}")

    # against the analytical Lamb-Oseen field (nu*t from the initializer)
    u_a, v_a = lamb_oseen_velocity(pos[:, 0], pos[:, 1], 1.0, 5e-4, 4.0)
    u_f, v_f = np.real(w_at), -np.imag(w_at)
    mask = np.abs(u_a) + np.abs(v_a) > 1e-3
    err_a = (np.linalg.norm((u_f - u_a)[mask]) + np.linalg.norm((v_f - v_a)[mask])) / \
            (np.linalg.norm(u_a[mask]) + np.linalg.norm(v_a[mask]))
    print(f"relative error vs analytical Lamb-Oseen: {err_a:.3e} "
          f"(discretization-limited)")
    assert err < 1e-3
    print("OK")


if __name__ == "__main__":
    main()
