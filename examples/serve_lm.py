"""Serving example: batched prefill + greedy decode with a persistent cache.

Exercises the production decode path (ring-buffer / SSM states included if
you pick a hybrid/ssm arch).

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch yi-6b] [--new 16]
"""
import argparse
import sys
import time

import numpy as np
import jax

sys.path.insert(0, "src")

from repro.configs.registry import get_smoke_config
from repro.models.transformer import init_params
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, batch_slots=args.batch,
                         max_len=args.prompt_len + args.new + 8)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    out = engine.step_all(prompts, args.new)
    wall = time.perf_counter() - t0
    assert out.shape == (args.batch, args.new)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new}")
    print(f"generated (first seq): {out[0].tolist()}")
    print(f"wall {wall:.2f}s -> {args.batch * args.new / wall:.1f} tok/s "
          f"(CPU, includes compile)")
    print("OK")


if __name__ == "__main__":
    main()
