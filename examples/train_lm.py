"""End-to-end training driver: train a small LM for a few hundred steps.

Presets:
  tiny  (~6M params,  default) — runs a full 300-step training on CPU in
         minutes, with checkpointing every 100 steps and restart support.
  100m  (~100M params)         — the 'real' small-model config; same code
         path, sized for a single accelerator.
  Any --arch from the registry can be trained at its smoke-reduced size.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
      PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 200
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import AdamWConfig
from repro.train.loop import Trainer, TrainerConfig

PRESETS = {
    "tiny": ModelConfig(name="tiny-lm", family="dense", num_layers=4,
                        d_model=256, num_heads=4, num_kv_heads=2, d_ff=640,
                        vocab=2048, head_dim=64),
    "100m": ModelConfig(name="lm-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4, d_ff=2048,
                        vocab=32_000, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--arch", default=None, help="registry arch (smoke size)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.arch:
        from repro.configs.registry import get_smoke_config
        cfg = get_smoke_config(args.arch)
    else:
        cfg = PRESETS[args.preset]
    print(f"model: {cfg.name}  params ~ {cfg.param_count/1e6:.1f}M")

    shape = ShapeConfig("example", "train", args.seq_len, args.batch)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=100,
                         ckpt_dir=args.ckpt_dir, log_every=20)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 1))
    tr = Trainer(cfg, shape, opt, tcfg)
    if args.resume and tr.try_restore():
        print(f"resumed from step {int(tr.opt_state['step'])}")

    log = tr.run()
    for m in log:
        if m["step"] % 20 == 0 or m["step"] == args.steps - 1:
            print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['grad_norm']:.3f}  lr {m['lr']:.2e}  "
                  f"{m['time_s']*1e3:.0f} ms")
    print(f"tokens/s (steady state): "
          f"{args.batch * args.seq_len / min(tr.step_times[2:]):,.0f}")
    print("OK")


if __name__ == "__main__":
    main()
