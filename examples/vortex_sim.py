"""Vortex-method simulation driver (the paper's client application, §3).

Advects Lamb-Oseen vortex particles with their FMM-computed Biot-Savart
velocity (inviscid step, RK2) through :class:`repro.core.stepper.VortexStepper`:
each step is ONE jitted device program (FMM -> half-kick -> device rebin ->
FMM -> full kick -> rebin; no host tree rebuild), executed under the
partition-driven :class:`SlabPlan` of choice:

  --plan uniform   equal-count row bands (the DPMTA-style strawman)
  --plan model     a-priori cost-model bands (paper §4-§5, static)
  --plan dynamic   model bands re-planned from the drifted particle
                   distribution every --replan-every steps (paper's title)

``--plan-grid PrxPc`` (e.g. ``2x3``) schedules a 2-D BlockPlan tile grid
with two-axis halos instead of 1-D row bands; it implies
``--devices Pr*Pc``.  ``--plan-grid auto`` lets the per-axis grid
autotuner choose slab vs block and the (Pr, Pc) factorization from the
cost model (Eq-20 balance + overlap-aware comm residue) at build time and
every replan.  ``--no-overlap`` disables the sharded driver's interior/rim
communication-computation overlap (DESIGN.md §9).

The vorticity field is a steady Euler solution up to core diffusion, so
particles should orbit the vortex center on (nearly) circular paths — the
initial radius is carried through every rebinning as a step payload and
the max radius drift is the correctness invariant.

Run:  PYTHONPATH=src python examples/vortex_sim.py [--steps 10] [--n-side 80]
          [--plan dynamic] [--devices 4]
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--dt", type=float, default=0.005)
    ap.add_argument("--n-side", type=int, default=80)
    ap.add_argument("--p", type=int, default=12)
    ap.add_argument("--plan", choices=("uniform", "model", "dynamic"),
                    default="model")
    ap.add_argument("--plan-grid", default=None, metavar="PrxPc|auto",
                    help="2-D BlockPlan device grid, e.g. 2x3 (implies "
                         "--devices Pr*Pc), or 'auto' to let the per-axis "
                         "grid autotuner pick slab vs block and (Pr, Pc) "
                         "from the cost model at every replan")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard over N devices (forces host devices on CPU)")
    ap.add_argument("--replan-every", type=int, default=4)
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable interior/rim comm-compute overlap")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="disable substep pipelining (cross-substep P2P "
                         "prefetch + gather/root-tree overlap); the serial "
                         "issue order of the pre-pipeline driver")
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--debug-nans", action="store_true",
                    help="jax_debug_nans: crash on the first NaN any jitted "
                         "computation produces (guarded recovery is "
                         "disabled so the fault is not masked)")
    ap.add_argument("--no-guard", action="store_true",
                    help="disable the health word + recovery ladder")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="snapshot (tree, payload) here every "
                         "--checkpoint-every steps")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore from the latest checkpoint in "
                         "--checkpoint-dir instead of starting fresh")
    args = ap.parse_args()

    plan_grid = None
    if args.plan_grid is not None and args.plan_grid.lower() == "auto":
        plan_grid = "auto"
    elif args.plan_grid is not None:
        try:
            plan_grid = tuple(int(x) for x in args.plan_grid.lower().split("x"))
            assert len(plan_grid) == 2 and min(plan_grid) >= 1
        except (ValueError, AssertionError):
            sys.exit(f"--plan-grid must look like 2x3 or auto, "
                     f"got {args.plan_grid!r}")
        ndev = plan_grid[0] * plan_grid[1]
        if args.devices not in (1, ndev):
            sys.exit(f"--plan-grid {args.plan_grid} needs {ndev} devices, "
                     f"--devices says {args.devices}")
        args.devices = ndev

    if args.devices > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}")

    sys.path.insert(0, "src")
    from repro.configs import backend
    if args.debug_nans:
        # debug-NaN wants the raw failure, not a recovered one
        backend.set_debug_nan(True)
        args.no_guard = True
    import numpy as np
    import jax
    from jax.sharding import Mesh

    from repro.core.stepper import VortexStepper
    from repro.core.vortex import lamb_oseen_particles

    pos, gamma, sigma = lamb_oseen_particles(args.n_side)
    r0 = np.hypot(pos[:, 0] - 0.5, pos[:, 1] - 0.5)

    mesh = None
    if args.devices > 1:
        if len(jax.devices()) < args.devices:
            sys.exit(f"need {args.devices} devices, have {len(jax.devices())}")
        mesh = Mesh(np.array(jax.devices()[:args.devices]), ("data",))

    common = dict(
        mesh=mesh, use_kernels=args.use_kernels,
        plan_method="uniform" if args.plan == "uniform" else "model",
        dynamic=(args.plan == "dynamic"), plan_grid=plan_grid,
        overlap=not args.no_overlap, pipeline=not args.no_pipeline,
        replan_every=args.replan_every,
        guard=not args.no_guard,
        checkpoint_every=args.checkpoint_every)
    if args.resume:
        if not args.checkpoint_dir:
            sys.exit("--resume needs --checkpoint-dir")
        stepper = VortexStepper.from_checkpoint(args.checkpoint_dir, **common)
        print(f"resumed from step {stepper.step_count} in "
              f"{args.checkpoint_dir}")
    else:
        stepper = VortexStepper(
            pos, gamma, sigma, p=args.p, dt=args.dt,
            checkpoint_dir=args.checkpoint_dir,
            payload={"r0": r0 + 0j}, **common)
    s0 = stepper.stats()
    print(f"plan={args.plan} devices={stepper.nparts} "
          f"level={stepper.params.level} bands={stepper.plan.describe()} "
          f"LB(min/max)={s0['load_balance']:.3f}")

    drift = 0.0
    for step in range(args.steps):
        rec = stepper.step()
        if step % 2 == 1 or step == args.steps - 1:
            m = np.asarray(stepper.tree.mask).reshape(-1)
            z = np.asarray(stepper.tree.z).reshape(-1)[m]
            rr0 = np.asarray(stepper.payload["r0"]).reshape(-1)[m].real
            r = np.hypot(z.real - 0.5, z.imag - 0.5)
            sel = rr0 > 0.02
            drift = np.abs(r[sel] - rr0[sel]).max()
            flags = ("R" if rec.replanned else "") + ("L" if rec.releveled else "")
            print(f"step {rec.step:3d}: max |r - r0| = {drift:.2e}  "
                  f"LB={rec.load_balance:.3f}  {rec.seconds * 1e3:7.1f} ms {flags}")
    assert drift < 5e-3, drift
    print("OK")


if __name__ == "__main__":
    main()
