"""Vortex-method simulation driver (the paper's client application, §3).

Advects Lamb-Oseen vortex particles with their FMM-computed Biot-Savart
velocity (inviscid step, RK2).  The vorticity field is a steady solution of
the Euler equations up to core diffusion, so particles should rotate about
the vortex center on (nearly) circular orbits — we check radius drift.

Run:  PYTHONPATH=src python examples/vortex_sim.py [--steps 10] [--n-side 80]
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core.fmm import fmm_velocity
from repro.core.quadtree import build_tree, choose_level, gather_particle_values
from repro.core.vortex import lamb_oseen_particles


def velocity(pos, gamma, sigma, level, p):
    tree, index = build_tree(pos, gamma, level, sigma)
    w = np.asarray(fmm_velocity(tree, p))
    w_at = gather_particle_values(w, index)
    return np.stack([np.real(w_at), -np.imag(w_at)], axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--dt", type=float, default=0.005)
    ap.add_argument("--n-side", type=int, default=80)
    ap.add_argument("--p", type=int, default=12)
    args = ap.parse_args()

    pos, gamma, sigma = lamb_oseen_particles(args.n_side)
    level = choose_level(len(pos), target_per_box=8)
    r0 = np.hypot(pos[:, 0] - 0.5, pos[:, 1] - 0.5)

    for step in range(args.steps):
        # RK2 (midpoint) advection — the standard vortex-method time step
        u1 = velocity(pos, gamma, sigma, level, args.p)
        mid = pos + 0.5 * args.dt * u1
        u2 = velocity(mid, gamma, sigma, level, args.p)
        pos = pos + args.dt * u2
        if step % 2 == 1 or step == args.steps - 1:
            r = np.hypot(pos[:, 0] - 0.5, pos[:, 1] - 0.5)
            sel = r0 > 0.02
            drift = np.abs(r[sel] - r0[sel]).max()
            print(f"step {step + 1:3d}: max |r - r0| = {drift:.2e} "
                  f"(circular-orbit invariant)")
    assert drift < 5e-3, drift
    print("OK")


if __name__ == "__main__":
    main()
