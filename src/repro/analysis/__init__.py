"""Trace contracts: static analysis over lowered programs and the source tree.

Four layers (DESIGN.md §13):

* :mod:`repro.analysis.contracts` — declarative :class:`TraceContract`
  objects evaluated against a jitted entry point's StableHLO / optimized
  HLO text (the `launch/hlo_analysis` walker does the measuring).  Every
  structural pin the perf/robustness PRs introduced — M2L no-staging,
  fused-exchange collective counts, pipelined issue depth, guard-free
  traces, no-donation on the recovery path — lives here as a named
  contract instead of an inline regex.
* :mod:`repro.analysis.schedule` — the SPMD collective-schedule verifier:
  simulates the lowered module for every device id and statically checks
  that all devices issue the SAME collective sequence (a mismatch is the
  distributed-hang analog of a data race).
* :mod:`repro.analysis.retrace` — jit cache-miss accounting across a
  scripted session; an unexpected retrace is named down to the offending
  argument.
* :mod:`repro.analysis.lint` — AST rules over the source tree replacing
  the grep-guards (spec-generic drivers, no host syncs in jitted code,
  rebuild_tree ok-flag consumption, ...).

``python -m repro.analysis.check`` runs all four; CI has a dedicated
``static-analysis`` job on it.
"""
from repro.analysis.contracts import (  # noqa: F401
    ContractResult, Lowered, TraceContract, collective_count, evaluate,
    fewer_bytes, format_results, issue_depth_grows, min_issue_depth,
    no_f64_upcast, no_host_callback, no_staging_dim, not_donated,
    sentinel_free, violations)
