"""``python -m repro.analysis.check`` — run every static-analysis layer.

Sections (each independently skippable):

* ``lint``      — AST rules over ``src/repro`` (:mod:`.lint`)
* ``contracts`` — the trace-contract catalog over the named entry points
  (:mod:`.contracts`): M2L no-staging + fewer-bytes, fused-exchange
  collective counts (2x2 and both degenerate grids), pipelined issue
  depth, guard-free traces, no-donation on ``rk2_step``, no f64 upcasts,
  no host callbacks
* ``schedule``  — the SPMD collective-schedule verifier across every
  device id, both plan kinds, degenerate single-rank axes included
  (:mod:`.schedule`)
* ``retrace``   — the scripted jit-cache session (:mod:`.retrace`)

Exit status is nonzero on any violation; CI runs this as the dedicated
``static-analysis`` job.  ``--json PATH`` writes machine-readable
section summaries.  The process forces 6 host devices BEFORE importing
jax (jax locks the device count at first init) so the 4- and 6-device
meshes both exist; ``--devices N`` lowers the forced count.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

SECTIONS = ("lint", "contracts", "schedule", "retrace")


def _force_devices(n: int) -> None:
    if "jax" in sys.modules:
        return                      # too late; use whatever is configured
    flag = f"--xla_force_host_platform_device_count={n}"
    prev = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in prev:
        os.environ["XLA_FLAGS"] = (prev + " " + flag).strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


def _fmm_fixture(level, p, n=2000, charge_scale=None):
    import numpy as np
    from repro.core.quadtree import build_tree

    rng = np.random.default_rng(0)
    pos = rng.uniform(0.02, 0.98, size=(n, 2))
    return build_tree(pos, rng.normal(size=n), level, sigma=0.02,
                      charge_scale=charge_scale)


def _mesh(ndev):
    import numpy as np
    import jax
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:ndev]), ("data",))


def _plans(tree, index, level, p, ndev, grid):
    from repro.core.cost_model import ModelParams
    from repro.core.plan import block_plan_from_counts, plan_from_counts

    params = ModelParams(level=level, cut=min(4, level - 1), p=p,
                         slots=tree.slots)
    slab = plan_from_counts(index.counts, params, ndev, method="model")
    block = block_plan_from_counts(index.counts, params, grid,
                                   method="model")
    return slab, block


def _fused_exchange(grid, ndev):
    """The packed P2P ``_tile_halo`` round as its own jitted entry — the
    PR-4 fusion pin's exact subject.  Tile extents don't affect the
    collective count, only strip widths, so a small fixed tile is fine."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import parallel_fmm as pf

    rmax = cmax = 4
    def fused(z, q, m):
        buf = pf._tile_halo(pf._pack_particles(z, q, m), 1, rmax, cmax,
                            "data", grid)
        return pf._unpack_particles(buf, z.dtype)

    spec = P("data", None, None)
    kw = {pf._CHECK_KW: False} if pf._CHECK_KW else {}
    jfn = jax.jit(pf._shard_map(fused, mesh=_mesh(ndev),
                                in_specs=(spec,) * 3,
                                out_specs=(spec,) * 3, **kw))
    shape = (ndev * rmax, cmax, 2)
    z = jnp.ones(shape, jnp.complex64)
    return jfn, (z, z, jnp.ones(shape, bool))


# ---------------------------------------------------------------------------
# sections
# ---------------------------------------------------------------------------


def run_lint_section(args):
    from repro.analysis import lint

    root = os.path.join(os.path.dirname(__file__), "..")
    findings = lint.run_lint(os.path.abspath(root))
    print(lint.format_findings(findings))
    return {"checked": len(lint.DEFAULT_RULES), "violations": len(findings),
            "detail": [str(f) for f in findings]}


def run_contracts_section(args):
    import jax
    from repro.analysis import contracts as C
    from repro.core import expansions as ex
    from repro.core import parallel_fmm as pf
    from repro.core import stepper as stp
    from repro.core.fmm import fmm_velocity
    from repro.kernels import ops as kops

    quick = args.quick
    results = []

    # -- M2L staging/bytes (serial, compiled HLO) ---------------------------
    import numpy as np
    import jax.numpy as jnp
    level, p = (3, 12) if quick else (4, 17)
    n = 1 << level
    rng = np.random.default_rng(0)
    me = jnp.asarray(rng.normal(size=(n, n, p)) +
                     1j * rng.normal(size=(n, n, p)), jnp.complex64)
    lw = lambda f, label: C.Lowered(jax.jit(f), me, label=label)
    kern = lw(lambda g: kops.m2l_apply(g, level, p), "m2l_apply")
    fold = lw(lambda g: ex.m2l_reference(g, level, p), "m2l_reference")
    m40 = lw(lambda g: ex.m2l_masked40(g, level, p), "m2l_masked40")
    staging = [C.no_staging_dim(40 * p), C.no_f64_upcast()]
    results += C.evaluate(kern, staging)
    results += C.evaluate(fold, staging)
    results += C.evaluate(fold, [C.fewer_bytes("folded", "masked40")],
                          pair_with=m40)

    # -- unguarded serial driver + rk2_step (sentinels, donation) -----------
    tree, index = _fmm_fixture(3 if quick else 4, 6)
    drv = C.Lowered(jax.jit(lambda t: fmm_velocity(t, p=6)), tree,
                    label="fmm_velocity")
    results += C.evaluate(drv, [C.sentinel_free(), C.no_host_callback(),
                                C.no_f64_upcast()])
    rk2 = stp.TRACE_ENTRY_POINTS["rk2_step"]
    rk2_low = C.Lowered(rk2, tree, 1e-4, p=6, label="rk2_step[guard=False]")
    results += C.evaluate(rk2_low, [C.sentinel_free(),
                                    C.not_donated("rk2"),
                                    C.no_host_callback()])

    # -- batched serving entries (serve/fmm_service, PR 10) -----------------
    from repro.core import equations as eqs
    from repro.serve import fmm_service as svc
    strees = [_fmm_fixture(3, 6, n=300)[0] for _ in range(2)]
    bz, bq, bm = svc.stack_trees(strees, 2)
    for ep_name, xargs in (("batched_fmm_eval", (bz, bq, bm)),
                           ("batched_fmm_eval_targets",
                            (bz, bq, bm, bz, bm))):
        low = C.Lowered(svc.TRACE_ENTRY_POINTS[ep_name], *xargs,
                        level=3, sigma=0.02, p=6, eq=eqs.VORTEX,
                        label=f"{ep_name}[B2]")
        results += C.evaluate(low, [C.sentinel_free(), C.no_host_callback(),
                                    C.no_f64_upcast()])

    # -- fused packed exchange: 4 ppermutes on 2x2, 2 on degenerate axes ----
    ndev = min(4, args.devices)
    if ndev >= 4:
        for grid, want in (((2, 2), 4), ((4, 1), 2), ((1, 4), 2)):
            jfn, xargs = _fused_exchange(grid, 4)
            low = C.Lowered(jfn, *xargs,
                            label=f"p2p_exchange{grid[0]}x{grid[1]}")
            results += C.evaluate(
                low, [C.collective_count("collective-permute", want)])

        # -- pipelined issue order on the sharded evaluation ----------------
        level, p = (5, 8) if quick else (6, 12)
        tree, index = _fmm_fixture(level, p, n=4000 if quick else 20000)
        slab, _ = _plans(tree, index, level, p, 4, (2, 2))
        mesh = _mesh(4)
        evaluate_ep = pf.TRACE_ENTRY_POINTS["parallel_fmm_evaluate"]
        on = C.Lowered(evaluate_ep, tree, p, mesh, plan=slab,
                       pipeline=True, label="fmm[pipeline=on]")
        off = C.Lowered(evaluate_ep, tree, p, mesh, plan=slab,
                        pipeline=False, label="fmm[pipeline=off]")
        results += C.evaluate(on, [C.issue_depth_grows("all_gather"),
                                   C.min_issue_depth("all_gather",
                                                     8 if quick else 32)],
                              pair_with=off)

    print(C.format_results(results))
    bad = C.violations(results)
    return {"checked": len(results), "violations": len(bad),
            "detail": [str(r) for r in bad]}


def run_schedule_section(args):
    from repro.analysis import schedule as S
    from repro.core import parallel_fmm as pf
    from repro.core import stepper as stp

    reports = []
    level, p = (4, 6) if args.quick else (5, 8)
    tree, index = _fmm_fixture(level, p)
    evaluate_ep = pf.TRACE_ENTRY_POINTS["parallel_fmm_evaluate"]

    cases = []
    if args.devices >= 4:
        slab, block = _plans(tree, index, level, p, 4, (2, 2))
        cases += [("slab_P4", 4, slab), ("block_2x2", 4, block)]
        # degenerate single-rank axes — PR 7's exchange-skip edge
        _, b41 = _plans(tree, index, level, p, 4, (4, 1))
        _, b14 = _plans(tree, index, level, p, 4, (1, 4))
        cases += [("block_4x1", 4, b41), ("block_1x4", 4, b14)]
    if args.devices >= 6:
        _, b23 = _plans(tree, index, level, p, 6, (2, 3))
        cases += [("block_2x3", 6, b23)]
    if args.devices >= 3:
        # shrunken-world mesh (DESIGN.md §14): after a coordinated 4->3
        # shrink the survivors re-lower every module at the odd world
        # size — verify the post-shrink schedule is hang-free too, not
        # just the power-of-two launch configurations
        slab3, _ = _plans(tree, index, level, p, 3, (3, 1))
        cases += [("slab_P3_shrunk", 3, slab3)]

    for label, ndev, plan in cases:
        rep = S.verify_entry(evaluate_ep, tree, p, _mesh(ndev), plan=plan,
                             ndev=ndev, label=f"parallel_fmm[{label}]")
        reports.append(rep)
    if args.devices >= 4:
        slab, _ = _plans(tree, index, level, p, 4, (2, 2))
        rep = S.verify_entry(stp.TRACE_ENTRY_POINTS["rk2_step"], tree, 1e-4,
                             p=p, mesh=_mesh(4), plan=slab, ndev=4,
                             label="rk2_step[slab_P4]")
        reports.append(rep)
        # targets mode — the serving engine's sharded probe-grid lane
        # (serve/fmm_service._run_sharded) runs this exact configuration
        tgt_tree, _ = _fmm_fixture(level, p, n=500)
        rep = S.verify_entry(evaluate_ep, tree, p, _mesh(4), plan=slab,
                             targets=tgt_tree, ndev=4,
                             label="parallel_fmm[slab_P4_targets]")
        reports.append(rep)
    if args.devices >= 3:
        slab3, _ = _plans(tree, index, level, p, 3, (3, 1))
        rep = S.verify_entry(stp.TRACE_ENTRY_POINTS["rk2_step"], tree, 1e-4,
                             p=p, mesh=_mesh(3), plan=slab3, ndev=3,
                             label="rk2_step[slab_P3_shrunk]")
        reports.append(rep)

    bad = [r for r in reports if not r.ok]
    for r in reports:
        print(r.diff_text() if not r.ok else
              f"schedule [{r.label}]: consistent, "
              f"{len(r.schedules[0])} collectives x {r.ndev} devices")
    return {"checked": len(reports), "violations": len(bad),
            "detail": [r.diff_text() for r in bad]}


def run_retrace_section(args):
    from repro.analysis import retrace as R

    events = R.run_session(level=3, p=4)
    events += R.run_serve_session(level=2, p=4)
    bad = [e for e in events if not e.ok]
    for e in events:
        print(f"retrace {e}")
    return {"checked": len(events), "violations": len(bad),
            "detail": [str(e) for e in bad]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="trace contracts + lint + schedule verify + retrace")
    ap.add_argument("--quick", action="store_true",
                    help="smaller fixtures (CI quick tier)")
    ap.add_argument("--devices", type=int, default=6,
                    help="host devices to force (default 6: covers the "
                         "4-dev and 2x3 meshes)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write section summaries as JSON")
    ap.add_argument("--skip", action="append", default=[],
                    choices=SECTIONS, help="skip a section (repeatable)")
    args = ap.parse_args(argv)

    _force_devices(args.devices)

    runners = {"lint": run_lint_section,
               "contracts": run_contracts_section,
               "schedule": run_schedule_section,
               "retrace": run_retrace_section}
    summary, failed = {}, 0
    for name in SECTIONS:
        if name in args.skip:
            summary[name] = {"skipped": True}
            continue
        print(f"==== {name} ====")
        res = runners[name](args)
        summary[name] = res
        failed += res["violations"]
        print(f"---- {name}: {res['checked']} checked, "
              f"{res['violations']} violation(s)\n")

    total_checked = sum(s.get("checked", 0) for s in summary.values())
    print(f"==== total: {total_checked} checks, {failed} violation(s) ====")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
