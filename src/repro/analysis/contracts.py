"""Declarative trace contracts over lowered/compiled program text.

A :class:`TraceContract` states one structural invariant of a jitted entry
point — "no staging buffer with a 680-wide dimension", "exactly 4
collective-permutes", "the all_gather is issued >= 32 dots before first
use" — and checks it against the program TEXT (StableHLO from
``jit(f).lower(...)`` or optimized HLO from ``...compile().as_text()``).
The measuring is done by the :mod:`repro.launch.hlo_analysis` walker; the
contract owns the expectation and the failure message.

Why text, not numerics: these invariants are about the *program*, not its
outputs.  A regression that re-introduces the (nb, 40p) M2L gather buffer
or un-fuses the packed P2P exchange produces bit-identical results and a
silent slowdown; the contract turns it into a red check with a name.

Each contract declares which IR it wants via ``ir``:

* ``"stablehlo"`` — the lowered (pre-XLA) module.  Trace order is
  preserved, so issue-depth and sentinel contracts read this one.
* ``"hlo"`` — the optimized post-SPMD module.  Shapes are per-device and
  fusion has happened, so byte/collective-count contracts read this one.

:class:`Lowered` lazily materializes both texts from one jitted call
signature so a catalog of contracts costs one ``lower()`` and at most one
``compile()``.  Pair contracts (:func:`fewer_bytes`,
:func:`issue_depth_grows`) compare two entry points — the "folded beats
masked-40" and "pipelining grows the overlap window" pins.

Declaring a new contract (DESIGN.md §13): subclass :class:`TraceContract`,
implement ``measure(text) -> value`` and ``check(text) -> ContractResult``,
give it a stable ``name`` — then add it to the entry-point catalog in
:mod:`repro.analysis.check` and a planted-violation negative test in
``tests/test_analysis.py``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional

from repro.launch.hlo_analysis import (analyze_hlo, collective_issue_depths,
                                       shape_dim_pattern)

__all__ = [
    "ContractResult", "Lowered", "TraceContract", "PairContract",
    "collective_count", "evaluate", "fewer_bytes", "format_results",
    "issue_depth_grows", "min_issue_depth", "no_f64_upcast",
    "no_host_callback", "no_staging_dim", "not_donated", "sentinel_free",
    "violations",
]


@dataclasses.dataclass(frozen=True)
class ContractResult:
    contract: str          # contract name, e.g. "no_staging_dim(680)"
    ok: bool
    detail: str            # measured value / first offending line
    target: str = ""       # entry-point label, filled in by evaluate()

    def __str__(self):
        state = "OK  " if self.ok else "FAIL"
        tgt = f" @ {self.target}" if self.target else ""
        return f"[{state}] {self.contract}{tgt}: {self.detail}"


def _snippet(text: str, match: "re.Match") -> str:
    """The line containing ``match``, trimmed — failure messages should
    show the offending instruction, not an offset."""
    start = text.rfind("\n", 0, match.start()) + 1
    end = text.find("\n", match.end())
    line = text[start:end if end != -1 else len(text)].strip()
    return line[:160]


class TraceContract:
    """One structural invariant over a single lowered/compiled module."""

    ir = "hlo"             # which text check() wants: "hlo" | "stablehlo"
    name = "trace-contract"

    def measure(self, text: str):
        """The quantity the contract constrains (for diagnostics/benches)."""
        raise NotImplementedError

    def check(self, text: str) -> ContractResult:
        raise NotImplementedError

    def _result(self, ok: bool, detail: str) -> ContractResult:
        return ContractResult(self.name, bool(ok), detail)


class PairContract:
    """A comparative invariant between two modules (a, b)."""

    ir = "hlo"
    name = "pair-contract"

    def check_pair(self, text_a: str, text_b: str) -> ContractResult:
        raise NotImplementedError

    def _result(self, ok: bool, detail: str) -> ContractResult:
        return ContractResult(self.name, bool(ok), detail)


# ---------------------------------------------------------------------------
# the catalog of contract classes
# ---------------------------------------------------------------------------


class _NoStagingDim(TraceContract):
    """No tensor in the module has a ``dim``-sized dimension — the M2L
    no-HBM-staging pin: the pre-folding wrapper materialized a (nb, 40p)
    gather buffer, so any 40p-wide shape is the regression signature."""

    ir = "hlo"

    def __init__(self, dim: int):
        self.dim = int(dim)
        self.name = f"no_staging_dim({dim})"
        self._pat = shape_dim_pattern(self.dim)

    def measure(self, text: str) -> int:
        return len(self._pat.findall(text))

    def check(self, text: str) -> ContractResult:
        m = self._pat.search(text)
        if m is None:
            return self._result(True, f"no {self.dim}-wide buffer")
        return self._result(False, f"staging buffer found: {_snippet(text, m)}")


def no_staging_dim(dim: int) -> TraceContract:
    return _NoStagingDim(dim)


class _CollectiveCount(TraceContract):
    """Instance count of one collective kind in the optimized module
    (while-loop bodies multiplied by their trip counts — the
    ``ModuleStats.add`` fix this PR regression-pins).  ``count`` pins
    equality; ``max_count``/``min_count`` pin a band."""

    ir = "hlo"

    def __init__(self, kind: str, count: Optional[int] = None,
                 min_count: Optional[int] = None,
                 max_count: Optional[int] = None):
        if count is None and min_count is None and max_count is None:
            raise ValueError("pin at least one of count/min_count/max_count")
        self.kind, self.count = kind, count
        self.min_count, self.max_count = min_count, max_count
        want = (f"=={count}" if count is not None else
                "/".join(filter(None, [
                    f">={min_count}" if min_count is not None else None,
                    f"<={max_count}" if max_count is not None else None])))
        self.name = f"collective_count({kind}, {want})"

    def measure(self, text: str) -> int:
        return int(analyze_hlo(text)["count_per_kind"].get(self.kind, 0))

    def check(self, text: str) -> ContractResult:
        got = self.measure(text)
        ok = ((self.count is None or got == self.count)
              and (self.min_count is None or got >= self.min_count)
              and (self.max_count is None or got <= self.max_count))
        return self._result(ok, f"{self.kind} x{got}")


def collective_count(kind: str, count: Optional[int] = None, *,
                     min_count: Optional[int] = None,
                     max_count: Optional[int] = None) -> TraceContract:
    return _CollectiveCount(kind, count, min_count, max_count)


class _MinIssueDepth(TraceContract):
    """The deepest instance of ``kind`` must be issued at least
    ``min_depth`` compute ops before its first use — the substep-pipeline
    pin (DESIGN.md §12): that window is what a latency-hiding scheduler
    fills with overlap."""

    ir = "stablehlo"

    def __init__(self, kind: str, min_depth: int):
        self.kind, self.min_depth = kind, int(min_depth)
        self.name = f"min_issue_depth({kind}, {min_depth})"

    def measure(self, text: str) -> int:
        return max(collective_issue_depths(text, collectives=(self.kind,))
                   [self.kind], default=0)

    def check(self, text: str) -> ContractResult:
        got = self.measure(text)
        return self._result(got >= self.min_depth,
                            f"max {self.kind} issue depth {got}")


def min_issue_depth(kind: str, min_depth: int) -> TraceContract:
    return _MinIssueDepth(kind, min_depth)


class _NoPattern(TraceContract):
    """Shared body of the absence contracts: the module text must not
    match ``pattern`` at all."""

    def __init__(self, name: str, pattern: str, ir: str, why: str):
        self.name, self.ir, self.why = name, ir, why
        self._pat = re.compile(pattern)

    def measure(self, text: str) -> int:
        return len(self._pat.findall(text))

    def check(self, text: str) -> ContractResult:
        m = self._pat.search(text)
        if m is None:
            return self._result(True, self.why)
        return self._result(False, f"{self.why} violated: "
                                   f"{_snippet(text, m)}")


def no_f64_upcast() -> TraceContract:
    """No f64/c128 tensor anywhere: the production path is f32/complex64
    end to end (f64 lives only in the host-side oracles), so a double
    tensor in a lowered module is an accidental upcast eating 2x HBM."""
    return _NoPattern("no_f64_upcast", r"\b(?:f64|c128)\[", "stablehlo",
                      "no f64/c128 tensor")


def sentinel_free() -> TraceContract:
    """``guard=False`` traces the exact unguarded program: no finiteness
    sentinel ops at all (the PR-6 zero-cost guarantee — the guard's cost
    is opt-in, never ambient)."""
    return _NoPattern("sentinel_free", r"is_finite", "stablehlo",
                      "no finiteness sentinels")


def no_host_callback() -> TraceContract:
    """No host callback custom-calls in the lowered module: a
    ``pure_callback``/``io_callback``/debug print smuggled into the step
    serializes every device program on a host round trip."""
    return _NoPattern("no_host_callback",
                      r"callback|CustomCall.*host", "stablehlo",
                      "no host callbacks")


def not_donated(argname: str = "buffers") -> TraceContract:
    """No input buffer is donated (``tf.aliasing_output``): the guarded
    stepper's recovery ladder retries the SAME step from the intact
    pre-step tree, so ``rk2_step`` must never alias its inputs — donation
    would hand the retry a poisoned operand."""
    return _NoPattern(f"not_donated({argname})", r"tf\.aliasing_output",
                      "stablehlo", "no donated input buffers")


class _FewerBytes(PairContract):
    """Module a must move strictly fewer fusion-aware HBM bytes than
    module b (the parity-folded M2L vs the masked-40 formulation)."""

    ir = "hlo"

    def __init__(self, label_a: str = "a", label_b: str = "b"):
        self.label_a, self.label_b = label_a, label_b
        self.name = f"fewer_bytes({label_a} < {label_b})"

    def check_pair(self, text_a: str, text_b: str) -> ContractResult:
        ba = analyze_hlo(text_a)["bytes"]
        bb = analyze_hlo(text_b)["bytes"]
        return self._result(ba < bb,
                            f"{self.label_a}={ba:.3e} {self.label_b}={bb:.3e}"
                            f" ratio={bb / max(ba, 1.0):.2f}x")


def fewer_bytes(label_a: str = "a", label_b: str = "b") -> PairContract:
    return _FewerBytes(label_a, label_b)


class _IssueDepthGrows(PairContract):
    """Module a (pipelined) must issue ``kind`` strictly deeper than
    module b (serial order), while the ``guard_kind`` instance count stays
    EQUAL — the prefetch replaces the exchange, never duplicates it."""

    ir = "stablehlo"

    def __init__(self, kind: str = "all_gather",
                 guard_kind: str = "collective_permute"):
        self.kind, self.guard_kind = kind, guard_kind
        self.name = f"issue_depth_grows({kind})"

    def check_pair(self, text_a: str, text_b: str) -> ContractResult:
        kinds = (self.kind, self.guard_kind)
        da = collective_issue_depths(text_a, collectives=kinds)
        db = collective_issue_depths(text_b, collectives=kinds)
        deep_a = max(da[self.kind], default=0)
        deep_b = max(db[self.kind], default=0)
        n_a, n_b = len(da[self.guard_kind]), len(db[self.guard_kind])
        ok = deep_a > deep_b and n_a == n_b
        return self._result(ok, f"{self.kind} depth {deep_a} vs {deep_b}, "
                                f"{self.guard_kind} x{n_a} vs x{n_b}")


def issue_depth_grows(kind: str = "all_gather",
                      guard_kind: str = "collective_permute") -> PairContract:
    return _IssueDepthGrows(kind, guard_kind)


# ---------------------------------------------------------------------------
# evaluation engine
# ---------------------------------------------------------------------------


class Lowered:
    """Lazy (stablehlo, hlo) text pair for one jitted call signature.

    One catalog evaluation costs one ``lower()`` and — only if some
    contract wants the optimized IR — one ``compile()``.  ``from_text``
    builds one from raw text (tests plant violations that way).
    """

    def __init__(self, fn: Callable, *args, label: str = "", **kwargs):
        self._lower = lambda: fn.lower(*args, **kwargs)
        self.label = label or getattr(fn, "__name__", "entry")
        self._lowered = None
        self._texts: dict = {}

    @classmethod
    def from_text(cls, text: str, ir: str = "stablehlo", label: str = "text"):
        self = cls.__new__(cls)
        self._lower = None
        self.label = label
        self._lowered = None
        # planted text stands in for both IRs unless the caller splits them
        self._texts = {"stablehlo": text, "hlo": text, ir: text}
        return self

    def text(self, ir: str) -> str:
        if ir not in self._texts:
            if self._lowered is None:
                self._lowered = self._lower()
            if ir == "stablehlo":
                self._texts[ir] = self._lowered.as_text()
            elif ir == "hlo":
                self._texts[ir] = self._lowered.compile().as_text()
            else:
                raise ValueError(f"unknown ir {ir!r}")
        return self._texts[ir]

    @property
    def stablehlo(self) -> str:
        return self.text("stablehlo")

    @property
    def hlo(self) -> str:
        return self.text("hlo")


def evaluate(lowered: Lowered, contracts,
             pair_with: Optional[Lowered] = None) -> list:
    """Check every contract against ``lowered`` (pair contracts against
    ``(lowered, pair_with)``); results carry the entry-point label."""
    out = []
    for c in contracts:
        if isinstance(c, PairContract):
            if pair_with is None:
                raise ValueError(f"{c.name} needs pair_with=")
            r = c.check_pair(lowered.text(c.ir), pair_with.text(c.ir))
            label = f"{lowered.label} vs {pair_with.label}"
        else:
            r = c.check(lowered.text(c.ir))
            label = lowered.label
        out.append(dataclasses.replace(r, target=label))
    return out


def violations(results) -> list:
    return [r for r in results if not r.ok]


def format_results(results) -> str:
    return "\n".join(str(r) for r in results)
