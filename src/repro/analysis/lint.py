"""AST lint rules over the source tree — the grep-guards, promoted.

The repo accumulated source-level invariants enforced by regex greps
scattered through the test suite (spec-generic drivers in
``test_equations.py``, rebuild_tree ok-flag consumption in
``test_health.py``).  Those regexes are brittle (a line break defeats
them) and each invents its own failure format.  This module restates
them — plus new rules for host syncs and nondeterminism inside
jit-traced code — as AST rules with one registry and one finding format,
shared by the tests, the ``python -m repro.analysis.check`` CLI, and CI.

Jit-reachability: a function is *jit-traced* if it is decorated with
``@jax.jit`` / ``@functools.partial(jax.jit, ...)`` or is referenced
(called, or passed to ``functools.partial``) from a jit-traced function
in the SAME module, transitively.  Same-module resolution keeps the
analysis local and false-positive free: host-side drivers
(``VortexStepper``, benchmarks) legitimately call ``float()``/``bool()``
on device scalars, and they are not reachable from any jit root.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Iterable, Optional

__all__ = ["LintFinding", "LintRule", "DEFAULT_RULES", "run_lint",
           "lint_source", "format_findings",
    "EquationBranchRule", "HostSyncInJitRule", "StaticArgsRule",
    "NondeterminismInJitRule", "RebuildTreeOkRule"]


@dataclasses.dataclass(frozen=True)
class LintFinding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class LintRule:
    name = "lint-rule"
    # None = every file; else only paths whose tail matches one entry
    applies_to: Optional[tuple] = None

    def check(self, tree: ast.AST, src: str, path: str) -> list:
        raise NotImplementedError

    def _find(self, path: str, node: ast.AST, message: str) -> LintFinding:
        return LintFinding(self.name, path, getattr(node, "lineno", 0),
                           message)

    def applies(self, path: str) -> bool:
        if self.applies_to is None:
            return True
        norm = path.replace("\\", "/")
        return any(norm.endswith(tail) for tail in self.applies_to)


# ---------------------------------------------------------------------------
# jit reachability (shared by the in-jit rules)
# ---------------------------------------------------------------------------


def _is_jit_decorator(dec: ast.AST) -> bool:
    """@jax.jit, @jit, @functools.partial(jax.jit, ...), @partial(jit,...)"""
    def names(node):
        if isinstance(node, ast.Name):
            return node.id
        if isinstance(node, ast.Attribute):
            return node.attr
        return ""

    if names(dec) == "jit":
        return True
    if isinstance(dec, ast.Call):
        if names(dec.func) == "jit":
            return True
        if names(dec.func) == "partial" and dec.args:
            return names(dec.args[0]) == "jit"
    return False


def jit_reachable_functions(tree: ast.AST) -> dict:
    """{name: FunctionDef} of module-level functions reachable from a jit
    root in the same module (roots included)."""
    funcs = {n.name: n for n in tree.body
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    roots = [name for name, fn in funcs.items()
             if any(_is_jit_decorator(d) for d in fn.decorator_list)]
    reachable, frontier = set(), list(roots)
    while frontier:
        name = frontier.pop()
        if name in reachable:
            continue
        reachable.add(name)
        # any Name reference counts as an edge: direct calls, and functions
        # handed to functools.partial / shard_map / jax.lax.cond
        for node in ast.walk(funcs[name]):
            if isinstance(node, ast.Name) and node.id in funcs \
                    and node.id != name:
                frontier.append(node.id)
    return {name: funcs[name] for name in reachable}


def _attr_tail(node: ast.AST) -> str:
    return node.attr if isinstance(node, ast.Attribute) else (
        node.id if isinstance(node, ast.Name) else "")


def _expr_touches_device_values(node: ast.AST) -> bool:
    """Heuristic: the expression contains a jnp./lax./jax. call — i.e. it
    produces a traced array, so wrapping it in float()/np.asarray() would
    force a host sync (vs. static host data like plan rows, which is
    fine)."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute):
            root = sub
            while isinstance(root, ast.Attribute):
                root = root.value
            if isinstance(root, ast.Name) and root.id in ("jnp", "lax",
                                                          "jax"):
                return True
    return False


# ---------------------------------------------------------------------------
# the rules
# ---------------------------------------------------------------------------


class EquationBranchRule(LintRule):
    """Drivers and kernels consume ONLY the EquationSpec: no comparisons
    against equation names and no isinstance checks on concrete equation
    classes in the slab-path files (DESIGN.md §10 acceptance guard —
    formerly a regex grep in tests/test_equations.py)."""

    name = "no-equation-branches"
    applies_to = ("core/fmm.py", "core/parallel_fmm.py", "kernels/ops.py",
                  "kernels/m2l.py", "kernels/p2p.py")
    _names = frozenset({"vortex", "laplace", "tracer"})

    def check(self, tree, src, path):
        out = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Compare):
                sides = [node.left] + list(node.comparators)
                for s in sides:
                    if isinstance(s, ast.Constant) and s.value in self._names:
                        out.append(self._find(
                            path, node, f"comparison against equation name "
                            f"{s.value!r}; dispatch through the spec"))
                        break
                else:
                    if any(_attr_tail(s) == "name" and
                           isinstance(s, ast.Attribute) and
                           _attr_tail(s.value) == "eq" for s in sides):
                        out.append(self._find(
                            path, node, "branch on eq.name; use the spec's "
                            "hooks instead"))
            if isinstance(node, ast.Call) and \
                    _attr_tail(node.func) == "isinstance" and \
                    len(node.args) == 2:
                tail = _attr_tail(node.args[1])
                if tail.endswith("Equation"):
                    out.append(self._find(
                        path, node, f"isinstance({tail}) in a driver; "
                        "the slab path must be spec-generic"))
        return out


class HostSyncInJitRule(LintRule):
    """No host syncs inside jit-traced functions: ``.item()``,
    ``.tolist()``, ``jax.device_get``, or ``float()/int()/bool()/
    np.asarray()`` wrapping a traced expression block the device stream
    on a host round trip — inside a traced function they either fail at
    trace time (ConcretizationError) or, worse, silently force the value
    at a re-trace boundary."""

    name = "no-host-sync-in-jit"
    _casts = frozenset({"float", "int", "bool", "complex"})

    def check(self, tree, src, path):
        out = []
        for fname, fn in jit_reachable_functions(tree).items():
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                tail = _attr_tail(node.func)
                if tail in ("item", "tolist") and \
                        isinstance(node.func, ast.Attribute):
                    out.append(self._find(
                        path, node, f".{tail}() inside jit-traced "
                        f"{fname}(): forces a host sync"))
                elif tail == "device_get":
                    out.append(self._find(
                        path, node, f"jax.device_get inside jit-traced "
                        f"{fname}()"))
                elif (tail in self._casts or tail == "asarray") and \
                        node.args and \
                        _expr_touches_device_values(node.args[0]):
                    what = tail + "()" if tail in self._casts \
                        else "np.asarray()"
                    # np.asarray on *static* host data (plan rows) is fine;
                    # only traced expressions are findings
                    if tail == "asarray" and \
                            _attr_tail(node.func.value
                                       if isinstance(node.func,
                                                     ast.Attribute)
                                       else node.func) in ("jnp", "jax"):
                        continue        # jnp.asarray stays on device
                    out.append(self._find(
                        path, node, f"{what} around a traced expression "
                        f"inside jit-traced {fname}(): host sync"))
        return out


class StaticArgsRule(LintRule):
    """Every name in ``static_argnames`` must be a real parameter of the
    decorated function (jax only errors when the arg is passed, so a
    renamed parameter silently stops being static), and no parameter
    carries a mutable (unhashable) default."""

    name = "static-args-sound"

    def _static_argnames(self, fn: ast.FunctionDef):
        for dec in fn.decorator_list:
            if not (isinstance(dec, ast.Call) and _is_jit_decorator(dec)):
                continue
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    names = []
                    for node in ast.walk(kw.value):
                        if isinstance(node, ast.Constant) and \
                                isinstance(node.value, str):
                            names.append(node.value)
                    return names
        return None

    def check(self, tree, src, path):
        out = []
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            statics = self._static_argnames(fn)
            if statics is None:
                continue
            params = {a.arg for a in (fn.args.posonlyargs + fn.args.args +
                                      fn.args.kwonlyargs)}
            for name in statics:
                if name not in params:
                    out.append(self._find(
                        path, fn, f"static_argnames entry {name!r} is not "
                        f"a parameter of {fn.name}()"))
            for arg, default in list(zip(reversed(fn.args.args),
                                         reversed(fn.args.defaults))) + \
                    list(zip(fn.args.kwonlyargs, fn.args.kw_defaults)):
                if default is not None and arg.arg in statics and \
                        isinstance(default, (ast.List, ast.Dict, ast.Set)):
                    out.append(self._find(
                        path, default, f"static arg {arg.arg!r} of "
                        f"{fn.name}() has an unhashable "
                        f"{type(default).__name__.lower()} default"))
        return out


class NondeterminismInJitRule(LintRule):
    """No ambient nondeterminism in jit-traced functions: wall-clock
    reads (``time.time``, ``datetime.now``, ``perf_counter``) and the
    legacy global numpy RNG (``np.random.*``) are evaluated ONCE at trace
    time and then baked into the cached program — silently frozen, and
    different per retrace."""

    name = "no-nondeterminism-in-jit"
    _calls = frozenset({"now", "time", "perf_counter", "monotonic",
                        "time_ns", "utcnow"})

    def check(self, tree, src, path):
        out = []
        for fname, fn in jit_reachable_functions(tree).items():
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                tail = _attr_tail(node.func)
                if tail in self._calls:
                    out.append(self._find(
                        path, node, f"{tail}() inside jit-traced "
                        f"{fname}(): traced once, frozen into the cache"))
                elif isinstance(node.func, ast.Attribute) and \
                        _attr_tail(node.func.value) == "random" and \
                        isinstance(node.func.value, ast.Attribute) and \
                        _attr_tail(node.func.value.value) == "np":
                    out.append(self._find(
                        path, node, f"np.random.{tail}() inside jit-traced "
                        f"{fname}(): use a jax PRNG key"))
        return out


class RebuildTreeOkRule(LintRule):
    """``rebuild_tree`` silently drops particles on leaf overflow and
    reports it only through its third output: every call site must bind
    all three results and give the ok flag a real name (formerly a regex
    in tests/test_health.py — the AST form also catches multi-line
    calls)."""

    name = "rebuild-tree-ok-consumed"

    def check(self, tree, src, path):
        out = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Assign):
                continue
            call = node.value
            if not (isinstance(call, ast.Call) and
                    _attr_tail(call.func) == "rebuild_tree"):
                continue
            tgt = node.targets[0]
            names = [e.id for e in tgt.elts
                     if isinstance(e, ast.Name)] \
                if isinstance(tgt, ast.Tuple) else []
            if not isinstance(tgt, ast.Tuple) or len(tgt.elts) != 3:
                out.append(self._find(
                    path, node, "rebuild_tree call must unpack "
                    "(tree, aux, ok)"))
            elif not names or names[-1] in ("_", "__"):
                out.append(self._find(
                    path, node, "rebuild_tree's ok flag is discarded; "
                    "overflow drops would be silent"))
        return out


DEFAULT_RULES = (EquationBranchRule(), HostSyncInJitRule(),
                 StaticArgsRule(), NondeterminismInJitRule(),
                 RebuildTreeOkRule())


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------


def lint_source(src: str, path: str = "<string>",
                rules: Iterable[LintRule] = DEFAULT_RULES) -> list:
    """Lint one source string (tests plant violations this way)."""
    tree = ast.parse(src)
    out = []
    for rule in rules:
        if rule.applies(path):
            out.extend(rule.check(tree, src, path))
    return out


def run_lint(root, rules: Iterable[LintRule] = DEFAULT_RULES) -> list:
    """Lint every ``*.py`` under ``root`` (a directory or a single file).
    Findings are sorted by (path, line) for stable output."""
    root = pathlib.Path(root)
    paths = [root] if root.is_file() else sorted(root.rglob("*.py"))
    findings = []
    for p in paths:
        try:
            src = p.read_text()
        except (OSError, UnicodeDecodeError):
            continue
        findings.extend(lint_source(src, str(p), rules))
    return sorted(findings, key=lambda f: (f.path, f.line))


def format_findings(findings) -> str:
    if not findings:
        return "lint: clean"
    return "\n".join([f"lint: {len(findings)} finding(s)"] +
                     [f"  {f}" for f in findings])
