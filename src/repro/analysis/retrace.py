"""Retrace detector: jit cache-miss accounting with argument blame.

A retrace of ``rk2_step``/``parallel_fmm_evaluate`` costs seconds of
compile time; an *unexpected* one usually means a static argument stopped
hashing stably (an EquationSpec losing its name/class identity, a plan
object rebuilt with a fresh non-equal instance, a shape wobble from a
re-level that should have been a cache hit).  PR 5 pinned "spec hash
keeps jit caches honest" and PR 7's ``clean_wall_samples`` assumes
steady-state steps do NOT recompile — this module makes both checkable.

:class:`RetraceMonitor` wraps one jitted callable and watches its
``_cache_size()`` across calls.  ``expect_hit``/``expect_miss`` assert
the caching outcome; on an unexpected miss the monitor diffs the call's
*signature* — static-argument reprs plus array (shape, dtype) leaves —
against the previous call's and names exactly the arguments that
changed.  ``run_session`` scripts the canonical lifecycle (cold compile,
steady step, replan onto an equal plan, re-level, checkpoint restore,
equation switch) and returns a report the CLI and CI consume.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

__all__ = ["RetraceMonitor", "RetraceViolation", "SessionEvent",
           "signature_of", "diff_signatures"]


class RetraceViolation(AssertionError):
    """An unexpected jit cache outcome, with the blamed arguments."""


def signature_of(args, kwargs) -> dict:
    """Flatten a call into {path: descriptor}: arrays become
    (shape, dtype) — a shape/dtype change legitimately retraces — and
    everything else (the static args) becomes its repr, the same
    identity-by-value jit hashes on."""
    import jax

    import numpy as np

    leaves = jax.tree_util.tree_flatten_with_path((args, kwargs))[0]
    sig = {}
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            # Host-resident numpy leaves key a SEPARATE jit cache entry
            # from device arrays with identical avals — tag them so a
            # restore-from-host retrace blames the right arguments.
            kind = ":host" if isinstance(leaf, np.ndarray) else ""
            sig[key] = f"array{tuple(leaf.shape)}:{leaf.dtype}{kind}"
        else:
            sig[key] = repr(leaf)
    return sig


def diff_signatures(old: Optional[dict], new: dict) -> list:
    """Human-readable per-argument differences, ['path: old -> new', ...]."""
    if old is None:
        return ["<first call>"]
    out = []
    for key in sorted(set(old) | set(new)):
        a, b = old.get(key, "<absent>"), new.get(key, "<absent>")
        if a != b:
            out.append(f"{key}: {a} -> {b}")
    return out or ["<signatures identical — likely a non-hashable or "
                   "identity-hashed static argument>"]


@dataclasses.dataclass
class SessionEvent:
    step: str                  # script step label, e.g. "replan-equal"
    expected: str              # "hit" | "miss"
    got: str
    blame: list                # argument diffs when got == "miss"

    @property
    def ok(self) -> bool:
        return self.expected == self.got

    def __str__(self):
        state = "OK  " if self.ok else "FAIL"
        extra = f" blame: {'; '.join(self.blame)}" if (
            self.blame and not self.ok) else ""
        return f"[{state}] {self.step}: expected {self.expected}, " \
               f"got {self.got}{extra}"


class RetraceMonitor:
    """Watch one jitted callable's compile cache across a session."""

    def __init__(self, jitted: Callable, name: str = ""):
        if not hasattr(jitted, "_cache_size"):
            raise TypeError(f"{name or jitted!r} is not a jitted function "
                            "(no _cache_size); wrap with jax.jit first")
        self.fn = jitted
        self.name = name or getattr(jitted, "__name__", "jitted")
        self.events: list = []
        self._last_sig: Optional[dict] = None

    @property
    def cache_size(self) -> int:
        return self.fn._cache_size()

    def call(self, *args, expect: Optional[str] = None, step: str = "call",
             strict: bool = True, **kwargs):
        """Call through, recording whether the cache grew.  ``expect`` is
        "hit"/"miss"/None; a violated expectation raises
        :class:`RetraceViolation` (``strict=False`` records it only)."""
        before = self.cache_size
        out = self.fn(*args, **kwargs)
        got = "miss" if self.cache_size > before else "hit"
        sig = signature_of(args, kwargs)
        blame = diff_signatures(self._last_sig, sig) if got == "miss" else []
        self._last_sig = sig
        ev = SessionEvent(step=step, expected=expect or got, got=got,
                          blame=blame)
        self.events.append(ev)
        if strict and expect is not None and not ev.ok:
            raise RetraceViolation(
                f"{self.name}: unexpected {got} at step {step!r} "
                f"(cache {before} -> {self.cache_size}); "
                f"offending arguments: {'; '.join(blame) or 'none changed'}")
        return out

    def expect_hit(self, *args, step: str = "hit", **kwargs):
        return self.call(*args, expect="hit", step=step, **kwargs)

    def expect_miss(self, *args, step: str = "miss", **kwargs):
        return self.call(*args, expect="miss", step=step, **kwargs)

    @property
    def ok(self) -> bool:
        return all(e.ok for e in self.events)

    def report(self) -> str:
        head = f"retrace monitor [{self.name}]: " + \
               ("OK" if self.ok else "VIOLATIONS")
        return "\n".join([head] + [f"  {e}" for e in self.events])


def run_session(level: int = 3, p: int = 4, n: int = 400) -> list:
    """The scripted lifecycle, serial mesh (the CLI's retrace section).

    Steps and their expectations:

    * cold ``rk2_step``                         -> miss (first compile)
    * steady second step                        -> hit
    * replan onto an EQUAL plan (fresh object)  -> hit  (plans hash by value)
    * re-level (tree shape changes)             -> miss (legitimate)
    * checkpoint restore (same shapes)          -> hit
    * ``parallel_fmm_evaluate`` equation switch -> miss, then hit both ways
      (specs hash by name+class — PR 5's "spec hash keeps jit caches
      honest")

    Returns the combined event list; any ``not ev.ok`` entry is a finding.
    """
    import numpy as np

    from repro.core import equations as eqs
    from repro.core import parallel_fmm as pf
    from repro.core import stepper as stp
    from repro.core.cost_model import ModelParams
    from repro.core.plan import plan_from_counts
    from repro.core.quadtree import build_tree

    rng = np.random.default_rng(0)
    pos = rng.uniform(0.05, 0.95, size=(n, 2))
    gamma = rng.normal(size=n)
    tree, index = build_tree(pos, gamma, level, sigma=0.02)
    params = ModelParams(level=level, cut=2, p=p, slots=tree.slots)
    plan = plan_from_counts(index.counts, params, 1, method="model")

    mon = RetraceMonitor(stp.rk2_step, "rk2_step")
    mon.call(tree, 1e-4, p=p, plan=plan, expect="miss", step="cold-compile",
             strict=False)
    mon.call(tree, 1e-4, p=p, plan=plan, expect="hit", step="steady-step",
             strict=False)
    # replan: a fresh plan object with identical content must be a HIT —
    # plans are value-hashed jit keys, not identity-hashed
    plan2 = plan_from_counts(index.counts, params, 1, method="model")
    mon.call(tree, 1e-4, p=p, plan=plan2, expect="hit", step="replan-equal",
             strict=False)
    # re-level: the tree's static shape changes — a legitimate retrace
    tree_up, index_up = build_tree(pos, gamma, level + 1, sigma=0.02)
    params_up = ModelParams(level=level + 1, cut=2, p=p, slots=tree_up.slots)
    plan_up = plan_from_counts(index_up.counts, params_up, 1, method="model")
    mon.call(tree_up, 1e-4, p=p, plan=plan_up, expect="miss", step="re-level",
             strict=False)
    # checkpoint restore: same shapes, same statics — must be a hit.
    # The host round-trip (np.asarray = "read from disk") must be
    # followed by a device put: raw numpy leaves key a SEPARATE jit
    # cache entry from device arrays of identical aval, so restoring
    # straight from host buffers silently recompiles every entry point.
    import jax.numpy as jnp
    host = {k: np.asarray(getattr(tree, k)) for k in ("z", "q", "mask")}
    restored = tree.__class__(z=jnp.asarray(host["z"]),
                              q=jnp.asarray(host["q"]),
                              mask=jnp.asarray(host["mask"]),
                              level=tree.level, sigma=tree.sigma)
    mon.call(restored, 1e-4, p=p, plan=plan, expect="hit",
             step="checkpoint-restore", strict=False)

    # equation switch on the evaluation entry point
    ltree, _ = build_tree(pos, gamma, level, sigma=0.02,
                          charge_scale=eqs.LAPLACE.charge_scale)
    mon2 = RetraceMonitor(pf.parallel_fmm_evaluate, "parallel_fmm_evaluate")
    mon2.call(tree, p, expect="miss", step="vortex-cold", strict=False)
    mon2.call(ltree, p, eq=eqs.LAPLACE, expect="miss", step="switch-laplace",
              strict=False)
    mon2.call(tree, p, expect="hit", step="switch-back-vortex", strict=False)
    # a re-built spec INSTANCE equal to the registered one must also hit
    mon2.call(ltree, p, eq=eqs.LaplaceEquation(), expect="hit",
              step="fresh-spec-instance", strict=False)
    return mon.events + mon2.events


def run_serve_session(level: int = 2, p: int = 6, n: int = 90) -> list:
    """The scripted *serving* lifecycle over the batched entry points.

    The FMM service (``serve/fmm_service.py``) bin-packs one-shot jobs
    into shape buckets whose :class:`~repro.serve.fmm_service.BucketKey`
    IS the jit cache key of ``batched_fmm_eval``.  Steady-state serving
    therefore compiles once per bucket and never again — this session
    makes that checkable the same way :func:`run_session` pins the
    stepper lifecycle:

    * cold bucket compile (first batch of a shape)     -> miss
    * steady wave: same bucket, FRESH charge values    -> hit
    * second bucket (bigger slot capacity)             -> miss (legitimate)
    * switch back to the first bucket                  -> hit
    * probe-grid entry: cold, then steady              -> miss, hit
    * entry-count pin: the batched caches grew by EXACTLY the number of
      distinct buckets scripted (3) — any extra entry is a silent
      per-request recompile
    * host-leaf foot-gun: raw numpy batch leaves key a SEPARATE entry
      (the PR 8 restore hazard ``stack_trees`` guards against) -> miss,
      with ``:host`` blamed

    Returns the combined event list; any ``not ev.ok`` entry is a finding.
    """
    import numpy as np

    from repro.core import equations as eqs
    from repro.core.quadtree import build_tree
    from repro.serve import fmm_service as svc

    rng = np.random.default_rng(7)
    sigma = 0.02

    def batch(n_jobs, slots, charge_scale=None):
        trees = []
        for _ in range(n_jobs):
            pos = rng.uniform(0.05, 0.95, size=(n, 2))
            t, _ = build_tree(pos, rng.normal(size=n), level, sigma=sigma,
                              slots=slots, charge_scale=charge_scale)
            trees.append(t)
        return svc.stack_trees(trees, n_jobs)

    base = svc.batched_cache_entries()
    kw = dict(level=level, sigma=sigma, p=p, eq=eqs.VORTEX)

    mon = RetraceMonitor(svc.batched_fmm_eval, "batched_fmm_eval")
    z, q, m = batch(2, slots=16)
    mon.call(z, q, m, expect="miss", step="cold-bucket-compile",
             strict=False, **kw)
    # steady wave: new tenants' data, identical bucket — the serving path
    # must ride the compiled program
    z2, q2, m2 = batch(2, slots=16)
    mon.call(z2, q2, m2, expect="hit", step="steady-wave-fresh-values",
             strict=False, **kw)
    zb, qb, mb = batch(2, slots=32)
    mon.call(zb, qb, mb, expect="miss", step="second-bucket", strict=False,
             **kw)
    mon.call(z, q, m, expect="hit", step="switch-back-bucket", strict=False,
             **kw)

    # probe-grid lane: passive targets ride their own entry point
    mon2 = RetraceMonitor(svc.batched_fmm_eval_targets,
                          "batched_fmm_eval_targets")
    tz, _, tm = batch(2, slots=16)
    mon2.call(z, q, m, tz, tm, expect="miss", step="targets-cold",
              strict=False, **kw)
    mon2.call(z2, q2, m2, tz, tm, expect="hit", step="targets-steady",
              strict=False, **kw)

    # pin the steady-state entry count: 3 buckets scripted -> 3 entries
    delta = svc.batched_cache_entries() - base
    mon.events.append(SessionEvent(
        step="entry-count-pin", expected="3 entries", got=f"{delta} entries",
        blame=[] if delta == 3 else
        ["batched jit caches grew past the scripted bucket count — "
         "a bucket key is not hashing stably"]))

    # the foot-gun stack_trees exists to prevent: host numpy leaves key
    # a separate cache entry from device arrays of identical aval
    mon.call(np.asarray(z), np.asarray(q), np.asarray(m), expect="miss",
             step="host-leaf-footgun", strict=False, **kw)
    return mon.events + mon2.events
