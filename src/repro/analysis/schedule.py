"""SPMD collective-schedule verifier: one program, every device id.

Under ``shard_map`` all devices run ONE lowered module; per-device
divergence can only enter through values derived from
``stablehlo.partition_id`` / ``replica_id`` (that is how ``lax.cond`` on
``axis_index`` lowers: a scalar chain ``partition_id -> divide ->
remainder -> convert -> compare -> convert`` selecting a
``stablehlo.case`` region).  A branch that makes one device skip a
collective the others issue is the distributed-hang analog of a data
race: every other device blocks in the collective forever, and nothing
at trace time says so.

This module makes that property checkable statically:

1. parse the StableHLO module text into a region tree (functions,
   ``case``/``if`` regions, ``while`` cond/body, ``func.call`` edges);
2. for each device id, walk the tree with a tiny scalar evaluator —
   constants, partition/replica id, integer arithmetic, compares,
   converts — resolving every device-dependent branch;
3. record the sequence of collective *events* (kind, result shape,
   source-target pairs, replica groups, channel id) each device issues;
4. verify the per-device sequences are mutually identical, and that each
   event is internally sane (permute pairs have unique sources/targets
   in range, replica groups are disjoint).

``while`` bodies execute a data-dependent number of times, but the trip
computation itself is shared by all devices, so body events are emitted
once with ``in_loop=True`` — consistent bodies imply consistent
execution.  A ``case`` whose selector the evaluator cannot resolve is
accepted only if all its regions issue identical sequences; otherwise it
is reported as an unresolvable divergence (conservative: no silent pass).

Scope: this is a TRACE-level verifier on the pre-XLA module.  XLA will
not introduce cross-partition divergence on its own (SPMD compilation is
one program), so lowered-level consistency is the property that matters.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

__all__ = ["CollectiveEvent", "ScheduleReport", "parse_module",
           "extract_schedule", "verify_schedule", "verify_entry",
           "COLLECTIVE_OPS"]

COLLECTIVE_OPS = {
    "stablehlo.collective_permute": "collective_permute",
    "stablehlo.all_gather": "all_gather",
    "stablehlo.all_reduce": "all_reduce",
    "stablehlo.reduce_scatter": "reduce_scatter",
    "stablehlo.all_to_all": "all_to_all",
    "stablehlo.collective_broadcast": "collective_broadcast",
}

_FUNC_RE = re.compile(r"^\s*func\.func\s+(?:public\s+|private\s+)?"
                      r"@([\w.\-$]+)\s*\((.*?)\)")
_STMT_RE = re.compile(r'^\s*(?:(%[\w#:,.\s]+?)\s*=\s*)?'
                      r'"?([\w.]+)"?\s*(.*)$')
_ARG_RE = re.compile(r"(%[\w.\-]+)\s*:")
_OPERAND_RE = re.compile(r"%[\w.\-]+(?:#\d+)?")
_PAIRS_RE = re.compile(r"source_target_pairs\s*=\s*dense<(.*?)>")
_GROUPS_RE = re.compile(r"replica_groups\s*=\s*dense<(.*?)>")
_CHANNEL_RE = re.compile(r"channel_handle<handle\s*=\s*(\d+)")
_RESULT_TY_RE = re.compile(r"->\s*(.+?)\s*$")
_DENSE_SCALAR_RE = re.compile(r"dense<(-?\d+)>")
_COMPARE_RE = re.compile(r"compare\s+(\w+)\s*,")
_NPART_RE = re.compile(r"mhlo\.num_partitions\s*=\s*(\d+)")
_NREPL_RE = re.compile(r"mhlo\.num_replicas\s*=\s*(\d+)")


@dataclasses.dataclass(frozen=True)
class CollectiveEvent:
    """One collective issued by one device, in issue order."""
    kind: str                              # e.g. "collective_permute"
    shape: str                             # result type text
    pairs: Optional[tuple] = None          # ((src, tgt), ...) for permutes
    groups: Optional[tuple] = None         # replica groups, as tuples
    channel: Optional[int] = None
    in_loop: bool = False                  # emitted from a while body

    def brief(self) -> str:
        bits = [self.kind]
        if self.channel is not None:
            bits.append(f"ch={self.channel}")
        if self.pairs is not None:
            bits.append(f"pairs={list(map(list, self.pairs))}")
        if self.groups is not None:
            bits.append(f"groups={list(map(list, self.groups))}")
        if self.in_loop:
            bits.append("in_loop")
        return " ".join(bits) + f" {self.shape}"


@dataclasses.dataclass
class Stmt:
    results: Optional[str]      # lhs text ("%0" / "%0:2") or None
    op: str                     # "stablehlo.add", "func.call", ...
    line: str                   # full stripped text of the first line
    regions: list               # list of blocks (lists of Stmt)


# ---------------------------------------------------------------------------
# parsing
# ---------------------------------------------------------------------------


def parse_module(text: str) -> dict:
    """StableHLO text -> {function name: block}, block = [Stmt, ...]."""
    lines = text.splitlines()
    funcs: dict = {}
    i = 0
    while i < len(lines):
        m = _FUNC_RE.match(lines[i])
        if m:
            name = m.group(1)
            args = _ARG_RE.findall(m.group(2))
            block, i = _parse_block(lines, i + 1)
            funcs[name] = {"args": args, "block": block}
            # _parse_block leaves i at the closing "}" of the function
            i += 1
            continue
        i += 1
    return funcs


def _parse_block(lines, i):
    """Parse statements until a line starting with '}' (not consumed)."""
    block = []
    while i < len(lines):
        s = lines[i].strip()
        if not s or s.startswith("^bb"):    # region arg header: skip
            i += 1
            continue
        if s.startswith("}"):
            return block, i
        stmt, i = _parse_stmt(lines, i)
        if stmt is not None:
            block.append(stmt)
    return block, i


def _parse_stmt(lines, i):
    s = lines[i].strip()
    m = _STMT_RE.match(s)
    if not m:
        return None, i + 1
    results, op, _rest = m.group(1), m.group(2), m.group(3)
    stmt = Stmt(results=results, op=op, line=s, regions=[])
    i += 1
    if op == "stablehlo.while":
        # form:  %r = stablehlo.while(...) : types \n cond { ... } do { ... }
        if i < len(lines) and lines[i].strip().startswith("cond"):
            cond, i = _parse_block(lines, i + 1)
            stmt.regions.append(cond)
            # at "} do {"
            if i < len(lines) and "do" in lines[i]:
                body, i = _parse_block(lines, i + 1)
                stmt.regions.append(body)
                i += 1                       # consume final "}"
        return stmt, i
    if s.endswith("({"):
        # region list:  "op"(...) ({ ... }, { ... }) : type
        while True:
            region, i = _parse_block(lines, i)
            stmt.regions.append(region)
            close = lines[i].strip() if i < len(lines) else "})"
            i += 1
            if close.startswith("}, {") or close == "}, {":
                continue
            break                            # "}) : ..." closes the op
        # the result type rides the closing line; keep it reachable
        if i - 1 < len(lines):
            stmt.line += " " + lines[i - 1].strip()
        return stmt, i
    if s.endswith("{"):
        # generic single-region op (reduce with block, sort, scatter, ...)
        region, i = _parse_block(lines, i)
        stmt.regions.append(region)
        i += 1                               # consume "}" / "}) : ..."
        return stmt, i
    return stmt, i


# ---------------------------------------------------------------------------
# per-device scalar evaluation + event extraction
# ---------------------------------------------------------------------------


def _parse_dense_nested(text: str):
    """'[[0, 1], [1, 2]]' or '0' -> tuple of tuples (rows)."""
    text = text.strip()
    try:
        val = json.loads(text)
    except ValueError:
        return None
    if isinstance(val, (int, float)):
        return ((int(val),),)
    if val and not isinstance(val[0], list):
        return (tuple(int(x) for x in val),)
    return tuple(tuple(int(x) for x in row) for row in val)


_ARITH = {
    "stablehlo.add": lambda a, b: a + b,
    "stablehlo.subtract": lambda a, b: a - b,
    "stablehlo.multiply": lambda a, b: a * b,
    "stablehlo.divide": lambda a, b: a // b if b else None,
    "stablehlo.remainder": lambda a, b: a % b if b else None,
    "stablehlo.and": lambda a, b: a & b,
    "stablehlo.or": lambda a, b: a | b,
    "stablehlo.xor": lambda a, b: a ^ b,
    "stablehlo.maximum": max,
    "stablehlo.minimum": min,
}

_CMP = {
    "EQ": lambda a, b: a == b, "NE": lambda a, b: a != b,
    "LT": lambda a, b: a < b, "LE": lambda a, b: a <= b,
    "GT": lambda a, b: a > b, "GE": lambda a, b: a >= b,
}


class _Evaluator:
    def __init__(self, funcs: dict, device: int, npartitions: int,
                 nreplicas: int):
        self.funcs = funcs
        self.device = device
        self.npartitions = npartitions
        self.nreplicas = nreplicas
        self.events: list = []
        self.problems: list = []

    # -- helpers ------------------------------------------------------------

    def _operands(self, stmt: Stmt):
        """SSA operand ids on the statement's rhs, in order."""
        rhs = stmt.line
        if stmt.results:
            rhs = rhs.split("=", 1)[1]
        # drop the trailing type annotation; operands precede it
        rhs = rhs.split(" : ")[0]
        return _OPERAND_RE.findall(rhs)

    def _bind_results(self, env, stmt: Stmt, values):
        if not stmt.results:
            return
        base = stmt.results.strip()
        if ":" in base:                       # tuple result "%0:2"
            rid, n = base.split(":")
            n = int(n)
            for k in range(n):
                env[f"{rid}#{k}"] = values[k] if values and k < len(values) \
                    else None
            env[rid] = None
        else:
            env[base] = values[0] if values else None

    def _event_from(self, stmt: Stmt, in_loop: bool) -> CollectiveEvent:
        line = stmt.line
        pairs = groups = None
        pm = _PAIRS_RE.search(line)
        if pm:
            pairs = _parse_dense_nested(pm.group(1))
            pairs = tuple(tuple(p) for p in pairs) if pairs else None
        gm = _GROUPS_RE.search(line)
        if gm:
            groups = _parse_dense_nested(gm.group(1))
        cm = _CHANNEL_RE.search(line)
        tm = _RESULT_TY_RE.search(line)
        return CollectiveEvent(
            kind=COLLECTIVE_OPS[stmt.op],
            shape=tm.group(1) if tm else "?",
            pairs=pairs, groups=groups,
            channel=int(cm.group(1)) if cm else None,
            in_loop=in_loop)

    # -- execution ----------------------------------------------------------

    def run(self, entry: str = "main"):
        if entry not in self.funcs:
            # single-function modules (planted fixtures) may name it anything
            entry = next(iter(self.funcs))
        f = self.funcs[entry]
        self._run_block(f["block"], {a: None for a in f["args"]},
                        in_loop=False)
        return self.events

    def _run_block(self, block, env, in_loop):
        returned = None
        for stmt in block:
            returned = self._run_stmt(stmt, env, in_loop)
        return returned

    def _run_stmt(self, stmt: Stmt, env, in_loop):
        op = stmt.op
        if op in COLLECTIVE_OPS:
            self.events.append(self._event_from(stmt, in_loop))
            self._bind_results(env, stmt, [None])
            return None
        if op in ("return", "stablehlo.return", "func.return"):
            return [env.get(o) for o in self._operands(stmt)]
        if op == "stablehlo.constant":
            sm = _DENSE_SCALAR_RE.search(stmt.line)
            self._bind_results(env, stmt,
                               [int(sm.group(1))] if sm else [None])
            return None
        if op == "stablehlo.partition_id":
            self._bind_results(
                env, stmt, [self.device if self.npartitions > 1 else 0])
            return None
        if op == "stablehlo.replica_id":
            self._bind_results(
                env, stmt, [self.device if self.nreplicas > 1 else 0])
            return None
        if op in ("stablehlo.convert", "stablehlo.bitcast_convert",
                  "stablehlo.reshape", "stablehlo.not"):
            ops_ = self._operands(stmt)
            v = env.get(ops_[0]) if ops_ else None
            if op == "stablehlo.not" and v is not None:
                v = 0 if v else 1
            self._bind_results(env, stmt, [v])
            return None
        if op in _ARITH:
            ops_ = self._operands(stmt)
            a = env.get(ops_[0]) if len(ops_) > 0 else None
            b = env.get(ops_[1]) if len(ops_) > 1 else None
            v = _ARITH[op](a, b) if a is not None and b is not None else None
            self._bind_results(env, stmt, [v])
            return None
        if op == "stablehlo.compare":
            dm = _COMPARE_RE.search(stmt.line)
            ops_ = self._operands(stmt)
            v = None
            if dm and len(ops_) >= 2:
                a, b = env.get(ops_[0]), env.get(ops_[1])
                if a is not None and b is not None:
                    v = int(_CMP[dm.group(1)](a, b))
            self._bind_results(env, stmt, [v])
            return None
        if op == "stablehlo.select":
            ops_ = self._operands(stmt)
            v = None
            if len(ops_) == 3:
                p = env.get(ops_[0])
                if p is not None:
                    v = env.get(ops_[1] if p else ops_[2])
            self._bind_results(env, stmt, [v])
            return None
        if op in ("stablehlo.case", "stablehlo.if"):
            self._run_branch(stmt, env, in_loop)
            return None
        if op == "stablehlo.while":
            # regions: [cond, body]; trip is data-dependent but shared by
            # all devices -> one symbolic pass, events tagged in_loop
            for region in stmt.regions:
                self._run_block(region, dict(env), in_loop=True)
            self._bind_results(env, stmt, None)
            return None
        if op in ("call", "func.call"):
            cm = re.search(r"@([\w.\-$]+)", stmt.line)
            callee = self.funcs.get(cm.group(1)) if cm else None
            if callee is not None:
                args = self._operands(stmt)
                cenv = {a: env.get(v) for a, v in zip(callee["args"], args)}
                for a in callee["args"]:
                    cenv.setdefault(a, None)
                ret = self._run_block(callee["block"], cenv, in_loop)
                self._bind_results(env, stmt, ret)
            else:
                self._bind_results(env, stmt, None)
            return None
        # any other op: run regions (reduce/sort bodies may not contain
        # collectives, but be conservative), result unknown
        for region in stmt.regions:
            self._run_block(region, dict(env), in_loop)
        self._bind_results(env, stmt, None)
        return None

    def _run_branch(self, stmt: Stmt, env, in_loop):
        ops_ = self._operands(stmt)
        sel = env.get(ops_[0]) if ops_ else None
        nreg = len(stmt.regions)
        if not nreg:
            return
        if op_is_if := (stmt.op == "stablehlo.if"):
            # region 0 = true branch
            idx = None if sel is None else (0 if sel else 1)
        else:
            # case: out-of-range index executes the last region
            idx = None if sel is None else min(max(sel, 0), nreg - 1)
        if idx is not None:
            self._run_block(stmt.regions[idx], dict(env), in_loop)
            return
        # selector unresolved: all regions must issue identical sequences
        seqs = []
        for region in stmt.regions:
            sub = _Evaluator(self.funcs, self.device, self.npartitions,
                             self.nreplicas)
            sub._run_block(region, dict(env), in_loop)
            seqs.append(sub.events)
            self.problems.extend(sub.problems)
        if any(s != seqs[0] for s in seqs[1:]):
            self.problems.append(
                f"unresolvable divergent {'if' if op_is_if else 'case'}: "
                f"selector {ops_[0] if ops_ else '?'} is not statically "
                f"known and its regions issue different collective "
                f"sequences ({[len(s) for s in seqs]} events per region)")
        self.events.extend(seqs[0])


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def extract_schedule(text: str, device: int,
                     npartitions: Optional[int] = None) -> tuple:
    """The collective sequence device ``device`` issues, plus problems
    local to that device's evaluation."""
    funcs = parse_module(text)
    npart = npartitions
    if npart is None:
        m = _NPART_RE.search(text)
        npart = int(m.group(1)) if m else 1
    rm = _NREPL_RE.search(text)
    nrepl = int(rm.group(1)) if rm else 1
    ev = _Evaluator(funcs, device, npart, nrepl)
    ev.run()
    return ev.events, ev.problems


@dataclasses.dataclass
class ScheduleReport:
    ok: bool
    ndev: int
    schedules: list            # per-device [CollectiveEvent, ...]
    problems: list             # human-readable findings
    label: str = ""

    def diff_text(self) -> str:
        head = f"schedule report [{self.label}] ndev={self.ndev}: " + \
               ("CONSISTENT" if self.ok else "DIVERGENT")
        lines = [head]
        lines.extend(f"  problem: {p}" for p in self.problems)
        counts = {len(s) for s in self.schedules}
        if not self.ok or len(counts) > 1:
            for d, seq in enumerate(self.schedules):
                lines.append(f"  device {d}: {len(seq)} collectives")
                for k, e in enumerate(seq):
                    lines.append(f"    [{k}] {e.brief()}")
        elif self.schedules:
            seq = self.schedules[0]
            lines.append(f"  all devices: {len(seq)} collectives")
            for k, e in enumerate(seq):
                lines.append(f"    [{k}] {e.brief()}")
        return "\n".join(lines)


def _check_event_sanity(e: CollectiveEvent, ndev: int, where: str) -> list:
    problems = []
    if e.pairs is not None:
        srcs = [p[0] for p in e.pairs]
        tgts = [p[1] for p in e.pairs]
        if len(set(srcs)) != len(srcs):
            problems.append(f"{where}: duplicate sources in permute pairs "
                            f"{list(map(list, e.pairs))}")
        if len(set(tgts)) != len(tgts):
            problems.append(f"{where}: duplicate targets in permute pairs "
                            f"{list(map(list, e.pairs))}")
        bad = [d for d in srcs + tgts if not 0 <= d < ndev]
        if bad:
            problems.append(f"{where}: device ids {sorted(set(bad))} out of "
                            f"range [0, {ndev})")
    if e.groups is not None:
        seen: set = set()
        for g in e.groups:
            dup = seen.intersection(g)
            if dup:
                problems.append(f"{where}: replica groups overlap on "
                                f"{sorted(dup)}")
            seen.update(g)
        bad = [d for d in seen if d >= 0 and not d < ndev]
        if bad:
            problems.append(f"{where}: replica-group ids {sorted(bad)} out "
                            f"of range [0, {ndev})")
    return problems


def verify_schedule(text: str, ndev: Optional[int] = None,
                    label: str = "") -> ScheduleReport:
    """Statically verify the per-device collective schedules of one
    lowered module are mutually consistent and internally sane."""
    if ndev is None:
        m = _NPART_RE.search(text)
        rm = _NREPL_RE.search(text)
        ndev = max(int(m.group(1)) if m else 1,
                   int(rm.group(1)) if rm else 1)
    schedules, problems = [], []
    for d in range(ndev):
        seq, probs = extract_schedule(text, d, npartitions=ndev)
        schedules.append(seq)
        problems.extend(f"device {d}: {p}" for p in probs)
    # cross-device consistency: every device must issue the same sequence
    ref = schedules[0]
    for d, seq in enumerate(schedules[1:], start=1):
        if seq == ref:
            continue
        n = min(len(ref), len(seq))
        k = next((i for i in range(n) if ref[i] != seq[i]), n)
        if k < n:
            problems.append(
                f"device {d} diverges from device 0 at event {k}: "
                f"[{ref[k].brief()}] vs [{seq[k].brief()}]")
        else:
            longer, who = (ref, 0) if len(ref) > len(seq) else (seq, d)
            problems.append(
                f"device {d} issues {len(seq)} collectives, device 0 "
                f"issues {len(ref)}; first unmatched: "
                f"[{longer[k].brief()}] only on device {who} — the other "
                f"devices would block in this collective forever")
    # intra-event sanity (sequence-consistent events are identical across
    # devices, so checking device 0's is enough)
    for k, e in enumerate(ref):
        problems.extend(_check_event_sanity(e, ndev, f"event {k}"))
    return ScheduleReport(ok=not problems, ndev=ndev, schedules=schedules,
                          problems=problems, label=label)


def verify_entry(fn, *args, ndev: Optional[int] = None, label: str = "",
                 **kwargs) -> ScheduleReport:
    """Lower a jitted entry point and verify its collective schedules."""
    text = fn.lower(*args, **kwargs).as_text()
    return verify_schedule(text, ndev=ndev,
                           label=label or getattr(fn, "__name__", "entry"))
