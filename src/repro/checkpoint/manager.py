"""Fault-tolerant checkpointing: atomic writes, manifest, async save,
keep-last-k, and elastic restore onto a different mesh.

Format: one .npz per pytree ("params", "opt", "meta") under
``<dir>/step_<n>.tmp`` renamed atomically to ``step_<n>`` once complete,
plus a LATEST pointer file written last.  A crash mid-save never corrupts
the previous checkpoint; restore reads LATEST, falling back to the newest
complete step directory when LATEST is missing, corrupt, or dangling
(points at a directory that was GC'd or lost).

Durability: every payload file, meta.json, and LATEST are fsync'd before
their rename, and the checkpoint directory is fsync'd after, so the commit
point survives power loss, not just process death.  Errors raised inside
the async ``_write`` thread are captured and re-raised on the next
``save()`` / ``wait()`` — a failed snapshot is never silent (the
cross-process shrink path of DESIGN.md §14 restores from ``latest_step()``
and must be able to trust it).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np
import jax


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    # directory fsync makes the rename itself durable (POSIX: metadata
    # lives in the parent directory's log)
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    except OSError:
        pass            # some filesystems refuse fsync on directories
    finally:
        os.close(fd)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(template, data: dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"checkpoint shape mismatch at {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def _raise_pending(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                "previous async checkpoint save failed") from err

    def save(self, step: int, trees: dict[str, Any], meta: Optional[dict] = None):
        """trees: name -> pytree.  Blocks only to snapshot to host memory.

        An exception from a previous async save surfaces HERE (or in
        :meth:`wait`) rather than dying silently in the writer thread."""
        host = {name: _flatten(jax.device_get(t)) for name, t in trees.items()}
        meta = dict(meta or {})
        meta["step"] = step
        if self._thread is not None:
            self._thread.join()     # one in-flight save at a time
            self._thread = None
        self._raise_pending()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host, meta),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _write_guarded(self, step: int, host: dict, meta: dict):
        try:
            self._write(step, host, meta)
        except BaseException as e:      # surfaces on next save()/wait()
            self._error = e

    def _write(self, step: int, host: dict, meta: dict):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        for name, data in host.items():
            path = os.path.join(tmp, f"{name}.npz")
            np.savez(path, **data)
            _fsync_file(path)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        _fsync_dir(self.dir)    # make the rename durable before LATEST
        # LATEST pointer written last -> atomic commit point
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.replace(os.path.join(self.dir, "LATEST.tmp"),
                   os.path.join(self.dir, "LATEST"))
        _fsync_dir(self.dir)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        """Newest RESTORABLE step: LATEST's referent when it exists on
        disk, else the newest complete step directory (LATEST can dangle
        after a crash between GC and pointer update, or point at a step a
        concurrent ``keep`` policy collected)."""
        path = os.path.join(self.dir, "LATEST")
        step = None
        if os.path.exists(path):
            try:
                with open(path) as f:
                    step = int(f.read().strip())
            except (ValueError, OSError):
                step = None
        if step is not None and os.path.isdir(
                os.path.join(self.dir, f"step_{step}")):
            return step
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load_meta(self, step: Optional[int] = None) -> Optional[dict]:
        """Read a checkpoint's meta.json without restoring any arrays —
        callers use it to build restore templates (shapes/dtypes) first."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        with open(os.path.join(self.dir, f"step_{step}", "meta.json")) as f:
            return json.load(f)

    def restore(self, templates: dict[str, Any], step: Optional[int] = None,
                shardings: Optional[dict[str, Any]] = None):
        """Restore pytrees; ``shardings`` (same structure) enables elastic
        restore onto any mesh via device_put with the new sharding."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        base = os.path.join(self.dir, f"step_{step}")
        out = {}
        for name, template in templates.items():
            with np.load(os.path.join(base, f"{name}.npz")) as z:
                data = {k: z[k] for k in z.files}
            tree = _unflatten_into(template, data)
            if shardings and name in shardings:
                tree = jax.tree.map(jax.device_put, tree, shardings[name])
            out[name] = tree
        with open(os.path.join(base, "meta.json")) as f:
            meta = json.load(f)
        return out, meta
