"""Fault-tolerant checkpointing: atomic writes, manifest, async save,
keep-last-k, and elastic restore onto a different mesh.

Format: one .npz per pytree ("params", "opt", "meta") under
``<dir>/step_<n>.tmp`` renamed atomically to ``step_<n>`` once complete,
plus a LATEST pointer file written last.  A crash mid-save never corrupts
the previous checkpoint; restore always reads LATEST.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np
import jax


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(template, data: dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"checkpoint shape mismatch at {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, trees: dict[str, Any], meta: Optional[dict] = None):
        """trees: name -> pytree.  Blocks only to snapshot to host memory."""
        host = {name: _flatten(jax.device_get(t)) for name, t in trees.items()}
        meta = dict(meta or {})
        meta["step"] = step
        if self._thread is not None:
            self._thread.join()     # one in-flight save at a time
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict, meta: dict):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        for name, data in host.items():
            np.savez(os.path.join(tmp, f"{name}.npz"), **data)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        # LATEST pointer written last -> atomic commit point
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.dir, "LATEST.tmp"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return int(f.read().strip())

    def load_meta(self, step: Optional[int] = None) -> Optional[dict]:
        """Read a checkpoint's meta.json without restoring any arrays —
        callers use it to build restore templates (shapes/dtypes) first."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        with open(os.path.join(self.dir, f"step_{step}", "meta.json")) as f:
            return json.load(f)

    def restore(self, templates: dict[str, Any], step: Optional[int] = None,
                shardings: Optional[dict[str, Any]] = None):
        """Restore pytrees; ``shardings`` (same structure) enables elastic
        restore onto any mesh via device_put with the new sharding."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        base = os.path.join(self.dir, f"step_{step}")
        out = {}
        for name, template in templates.items():
            with np.load(os.path.join(base, f"{name}.npz")) as z:
                data = {k: z[k] for k in z.files}
            tree = _unflatten_into(template, data)
            if shardings and name in shardings:
                tree = jax.tree.map(jax.device_put, tree, shardings[name])
            out[name] = tree
        with open(os.path.join(base, "meta.json")) as f:
            meta = json.load(f)
        return out, meta
