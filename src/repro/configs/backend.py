"""JAX backend knobs for drivers and CI lanes (guarded execution §11).

Every function here only takes effect at the BEGINNING of a program —
before the first jax array is created — so drivers call them right after
parsing flags and before importing anything that touches jax arrays.
``set_cpu_cores`` must run before ``import jax`` entirely (XLA reads the
flag once at backend init); the others are safe any time pre-trace.
"""
from __future__ import annotations

import os
import warnings
from multiprocessing import cpu_count


def jax_enable_x64(use_x64: bool) -> None:
    """Switch the default array precision to 64-bit (or back to 32)."""
    import jax
    jax.config.update("jax_enable_x64", bool(use_x64))


# GPU flags appended (idempotently) by set_platform.  The async-collective
# pair makes the substep pipeline's issue-before-consume ordering
# (DESIGN.md §12) an actual overlap on GPU: collectives run on their own
# high-priority stream while the latency-hiding scheduler slots the
# independent compute between issue and first use — without them the
# reordered HLO still executes serially on one stream.
_GPU_FLAGS = (
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def set_platform(platform: str = "cpu") -> None:
    """Pin the backend to 'cpu', 'gpu', or 'tpu'."""
    import jax
    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        # https://jax.readthedocs.io/en/latest/gpu_performance_tips.html
        cur = os.environ.get("XLA_FLAGS", "")
        add = [f for f in _GPU_FLAGS if f not in cur.split()]
        os.environ["XLA_FLAGS"] = " ".join([cur] + add).strip()


def set_cpu_cores(n: int) -> None:
    """Expose ``n`` host CPU devices (XLA host-platform device count).

    Call BEFORE importing jax anywhere in the process — the flag is read
    once when the CPU backend initializes."""
    n = int(n)
    total = cpu_count()
    if n > total:
        warnings.warn(f"only {total} CPUs available, will use {total - 1}",
                      Warning)
        n = total - 1
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n}").strip()


def set_debug_nan(flag: bool) -> None:
    """Raise on the first NaN any jitted computation produces.

    The brute-force debugging lane: complements the packed health word
    (which classifies and recovers instead of crashing) when a fault needs
    to be pinned to the exact primitive that produced it.
    https://jax.readthedocs.io/en/latest/debugging/flags.html
    """
    import jax
    jax.config.update("jax_debug_nans", bool(flag))
