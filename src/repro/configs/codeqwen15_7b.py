"""codeqwen1.5-7b [dense]: 32L d4096 32H (MHA kv=32) ff13440 vocab 92416,
qwen1.5 arch (QKV bias).  [hf:Qwen/CodeQwen1.5-7B]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13_440, vocab=92_416, head_dim=128, qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=3, d_model=128, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=224, vocab=512,
)
