"""command-r-35b [dense]: 40L d8192 64H (GQA kv=8) ff22528 vocab 256000,
GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22_528, vocab=256_000, head_dim=128, rope_theta=8_000_000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=3, d_model=128, num_heads=8, num_kv_heads=2,
    head_dim=16, d_ff=352, vocab=512,
)
