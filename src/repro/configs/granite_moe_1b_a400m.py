"""granite-moe-1b-a400m [moe]: 24L d1024 16H (GQA kv=8) expert-ff 512,
vocab 49155, MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
import dataclasses
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab=49_155, head_dim=64,
    moe=MoEConfig(num_experts=32, top_k=8, expert_ff=512),
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=3, d_model=96, num_heads=4, num_kv_heads=2,
    head_dim=24, vocab=384, moe=MoEConfig(num_experts=4, top_k=2, expert_ff=64),
)
