"""internvl2-26b [vlm]: 48L d6144 48H (GQA kv=8) ff16384 vocab 92553,
InternViT frontend (STUB: input_specs provides precomputed patch
embeddings) + InternLM2-20B backbone.  [arXiv:2404.16821]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16_384, vocab=92_553, head_dim=128,
    num_patches=1024, patch_dim=3200,   # InternViT-6B output width
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=3, d_model=128, num_heads=8, num_kv_heads=2,
    head_dim=16, d_ff=256, vocab=512, num_patches=16, patch_dim=64,
)
