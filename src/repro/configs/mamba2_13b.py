"""mamba2-1.3b [ssm]: 48L d2048 attn-free, vocab 50280, ssm_state=128,
SSD (state-space duality).  [arXiv:2405.21060; unverified]"""
import dataclasses
from repro.models.config import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab=50_280, head_dim=64,
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, vocab=384,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32),
)
