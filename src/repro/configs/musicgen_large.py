"""musicgen-large [audio]: 48L d2048 32H (MHA kv=32) ff8192 vocab 2048,
decoder-only over EnCodec tokens (frontend = stub: token ids are the
precomputed frame codes).  [arXiv:2306.05284]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab=2048, head_dim=64,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=3, d_model=96, num_heads=4, num_kv_heads=4,
    head_dim=24, d_ff=192, vocab=256,
)
