"""The paper's own client application: vortex-method FMM configuration.

Matches the strong-scaling experiment of PetFMM §7: N = 765,625 particles
(875^2 lattice), tree level 10, cut (root) level 4, p = 17 expansion terms.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class FMMConfig:
    name: str = "petfmm-vortex"
    num_particles: int = 765_625
    level: int = 10
    cut_level: int = 4
    p: int = 17
    sigma: float = 0.02
    spacing_ratio: float = 0.8


CONFIG = FMMConfig()
SMOKE_CONFIG = dataclasses.replace(CONFIG, num_particles=2_500, level=4,
                                   cut_level=2, p=8)
