"""qwen1.5-32b [dense]: 64L d5120 40H (MHA kv=40) ff27392 vocab 152064,
QKV bias.  [hf:Qwen/Qwen1.5 family]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27_392, vocab=152_064, head_dim=128, qkv_bias=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=3, d_model=120, num_heads=6, num_kv_heads=6,
    head_dim=20, d_ff=256, vocab=512,
)
