"""qwen3-moe-235b-a22b [moe]: 94L d4096 64H (GQA kv=4) expert-ff 1536,
vocab 151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B family; hf]"""
import dataclasses
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=1536, vocab=151_936, head_dim=64, rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, expert_ff=1536),
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=96, vocab=512,
    moe=MoEConfig(num_experts=8, top_k=2, expert_ff=96),
)
