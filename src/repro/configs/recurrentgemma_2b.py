"""recurrentgemma-2b [hybrid]: 26L d2560 10H (MQA kv=1) ff7680 vocab 256000,
RG-LRU + local attention, pattern 2 recurrent : 1 attn.  [arXiv:2402.19427]"""
import dataclasses
from repro.models.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    d_ff=7680, vocab=256_000, head_dim=256,
    rglru=RGLRUConfig(lru_width=2560, window=2048),
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=5, d_model=64, num_heads=2, num_kv_heads=1,
    head_dim=32, d_ff=128, vocab=384,
    rglru=RGLRUConfig(lru_width=64, window=32),
)
