"""Architecture registry: --arch <id> -> ModelConfig (full and smoke-reduced)."""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen3_moe_235b_a22b",
    "granite_moe_1b_a400m",
    "command_r_35b",
    "codeqwen15_7b",
    "yi_6b",
    "qwen15_32b",
    "recurrentgemma_2b",
    "musicgen_large",
    "internvl2_26b",
    "mamba2_13b",
    "petfmm_vortex",            # the paper's own client application
]

_ALIASES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "command-r-35b": "command_r_35b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "yi-6b": "yi_6b",
    "qwen1.5-32b": "qwen15_32b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "musicgen-large": "musicgen_large",
    "internvl2-26b": "internvl2_26b",
    "mamba2-1.3b": "mamba2_13b",
    "petfmm-vortex": "petfmm_vortex",
}


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch.replace("-", "_").replace(".", ""))


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.SMOKE_CONFIG


def lm_archs() -> list[str]:
    return [a for a in ARCHS if a != "petfmm_vortex"]
