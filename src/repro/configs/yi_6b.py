"""yi-6b [dense]: 32L d4096 32H (GQA kv=4) ff11008 vocab 64000,
llama-arch GQA.  [arXiv:2403.04652]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
    d_ff=11_008, vocab=64_000, head_dim=128, rope_theta=5_000_000.0,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=3, d_model=128, num_heads=8, num_kv_heads=2,
    head_dim=16, d_ff=256, vocab=512,
)
