"""Work, communication, and memory estimates (paper §5, Eqs 11-15, Tables 1-2).

This module is the quantitative heart of the paper: an a-priori model of
tree-based N-body computation that feeds the load-balancing partitioner.
All functions are host-side NumPy (they run in the launcher / partitioner,
never on device).

Conventions: d = 2 (quadtree), L = tree depth, k = cut level, p = expansion
terms, s = max particles per box, N_i = per-box particle count.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

D = 2                 # space dimension (quadtree; the model generalizes via d)
N_CHILD = 4           # n_c
N_IL = 27             # interaction-list size (2D upper bound, paper §5.2)
N_ND = 9              # near-domain boxes (3x3 stencil incl. self)
PARTICLE_BYTES = 28   # B, paper §5.3
ARROW_BYTES = 108     # A, overlap arrow size, paper §5.3

# Halo widths of the dense slab implementation (rows of ghost data exchanged
# per sharded level).  Parity folding (DESIGN.md §4) works at parent
# granularity, so M2L needs ±1 parent row = 2 child rows — down from the ±3
# child rows a box-granularity interaction list implies.  P2P needs ±1 leaf
# row.  tests/test_cost_model.py pins these against expansions.M2L_HALO and
# kernels.p2p.P2P_HALO.
M2L_HALO_ROWS = 2
P2P_HALO_ROWS = 1


@dataclasses.dataclass(frozen=True)
class ModelParams:
    level: int                   # L: leaf level of the global tree
    cut: int                     # k: tree cut level -> 4^k subtrees
    p: int                       # expansion order
    slots: int                   # s: max particles per box
    coeff_bytes: int = 16        # bytes per complex coefficient (complex128)
    # calibration constants (seconds per unit); fit from measurements
    t_flop: float = 1.0
    t_byte: float = 1.0
    # per-equation work constant (core/equations.py): output channels per
    # target — P2P and L2P scale with it, the coefficient sweeps do not
    nout: int = 1


# ---------------------------------------------------------------------------
# Work estimates (paper Eqs 13-15)
# ---------------------------------------------------------------------------


def work_nonleaf(p: int, n_c: int = N_CHILD, n_il: int = N_IL) -> float:
    """Eq (13): O(p^2 (2 n_c + n_IL)) — M2M + L2L + M2L for one box."""
    return float(p * p * (2 * n_c + n_il))


def work_leaf(n_i: np.ndarray, p: int, n_il: int = N_IL, n_nd: int = N_ND,
              neighbor_counts: np.ndarray | None = None,
              nout: int = 1) -> np.ndarray:
    """Eq (14): O(2 N_i p + p^2 n_IL + n_nd N_i^2) per leaf box.

    If ``neighbor_counts`` (sum of particle counts over the 3x3 stencil) is
    given, the P2P term uses the *exact* N_i * sum_nd N_j product instead of
    the paper's uniform n_nd * N_i^2 surrogate.  ``nout`` is the equation's
    output arity (ModelParams.nout): the P2P pair sum and the L2P half of
    the ``2 N_i p`` term scale with the channel count, the P2M half and the
    shared coefficient sweep do not.
    """
    n_i = np.asarray(n_i, dtype=np.float64)
    p2p = n_i * neighbor_counts if neighbor_counts is not None else n_nd * n_i * n_i
    return (1.0 + nout) * n_i * p + float(p * p * n_il) + p2p * nout


def neighbor_count_sum(counts: np.ndarray) -> np.ndarray:
    """Sum of per-box particle counts over each box's 3x3 near domain."""
    padded = np.pad(counts, 1)
    out = np.zeros_like(counts, dtype=np.float64)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            n = counts.shape[0]
            out += padded[1 + dy:1 + dy + n, 1 + dx:1 + dx + n]
    return out


def work_subtree(counts: np.ndarray, params: ModelParams) -> np.ndarray:
    """Eq (15) evaluated exactly per subtree from leaf occupancy ``counts``.

    counts: (2^L, 2^L) particles per leaf box (row-major grid).
    Returns (4^k,) modeled work per subtree, ordered by subtree grid id
    (row-major over the cut-level grid; use morton reorder for z-order).
    """
    L, k, p = params.level, params.cut, params.p
    nsub = 1 << k
    sub_leaf = 1 << (L - k)            # leaf boxes per subtree side
    # Non-leaf boxes inside one subtree: levels k..L-1 of the global tree
    # (the subtree root sits at cut level k).  Eq 15's first sum.
    nonleaf_boxes = sum(4 ** (l - k) for l in range(k, L))
    w_nonleaf = nonleaf_boxes * work_nonleaf(p)

    nb = neighbor_count_sum(counts)
    w_leaf = work_leaf(counts, p, neighbor_counts=nb,
                       nout=params.nout)                    # (2^L, 2^L)
    w_leaf_sub = w_leaf.reshape(nsub, sub_leaf, nsub, sub_leaf).sum(axis=(1, 3))
    return (w_leaf_sub + w_nonleaf).reshape(-1)


def work_active_total(counts: np.ndarray, params: ModelParams) -> float:
    """Total useful work (for padding-waste metrics on SPMD hardware)."""
    return float(work_subtree(counts, params).sum())


def work_padded_total(counts: np.ndarray, params: ModelParams) -> float:
    """Work actually paid by the dense padded execution (all slots active)."""
    full = np.full_like(counts, params.slots)
    return float(work_subtree(full, params).sum())


def batch_padding_stats(per_job_work: float, n_jobs: int,
                        capacity: int) -> dict[str, float]:
    """Batch-axis pricing for the serving engine's padded vmap lane.

    A bucket executed at ``capacity`` pays the dense per-job work for
    every batch row, occupied or padding — the batch-axis analogue of
    :func:`work_padded_total`'s slot padding.  Returns the paid/useful
    split and the utilization the admission policy can steer on.
    """
    paid = float(per_job_work) * int(capacity)
    useful = float(per_job_work) * int(n_jobs)
    return {"paid": paid, "useful": useful,
            "padding_waste": paid - useful,
            "utilization": (useful / paid) if paid else 1.0}


def array_digest(*arrays) -> str:
    """Stable content digest of host arrays — the value part of artifact
    cache keys (trees keyed by particle data, plans by leaf counts)."""
    h = hashlib.sha1()
    for a in arrays:
        a = np.ascontiguousarray(np.asarray(a))
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Communication estimates (paper Eqs 11-12)
# ---------------------------------------------------------------------------


def alpha_comm(p: int, coeff_bytes: int = 16) -> float:
    """Bytes per expansion exchanged: p coefficients of ``coeff_bytes``."""
    return float(p * coeff_bytes)


def comm_lateral(params: ModelParams) -> float:
    """Eq (11): sum_{n=k+1}^{L} alpha * 2^(n-k) * 4.

    Boundary boxes of a subtree facing a lateral neighbor at global level n
    form a line of 2^(n-k) boxes; the factor 4 covers the M2L ghost exchange
    in both directions for both expansion rings (paper §5.1).
    """
    L, k = params.level, params.cut
    a = alpha_comm(params.p, params.coeff_bytes)
    return float(sum(a * (2 ** (n - k)) * 4 for n in range(k + 1, L + 1)))


def comm_diagonal(params: ModelParams) -> float:
    """Eq (12): alpha * (L - k - 1) * 4 — only corner boxes at each level.

    The paper prints ``alpha ((k - L) - 1) * 4``; the cut level k is always
    < L so we read it as the magnitude |L - k| - 1 (one corner box per level
    below the cut, excluding the subtree root).
    """
    L, k = params.level, params.cut
    a = alpha_comm(params.p, params.coeff_bytes)
    return float(a * max(L - k - 1, 0) * 4)


def comm_particles_boundary(params: ModelParams, counts_edge: float) -> float:
    """Ghost-particle traffic for P2P across a subtree face (model extension).

    The paper folds this into 'communication of particles in the local
    domain'; we expose it so the graph can weight particle-heavy boundaries.
    counts_edge: total particles in the boundary boxes of the shared face.
    """
    return PARTICLE_BYTES * counts_edge


def comm_halo_dense(params: ModelParams, slots: int | None = None) -> dict[str, float]:
    """Per-device halo-exchange bytes of the dense slab implementation.

    Implementation-level counterpart of Eqs (11)-(12): a row slab exchanges
    ``M2L_HALO_ROWS`` full rows of ME coefficients per sharded level (both
    directions) and ``P2P_HALO_ROWS`` rows of particle slots at the leaves.
    Parity folding cuts the M2L term by ``1 - M2L_HALO_ROWS/3`` relative to
    the box-granularity ±3 halo.
    """
    L, k, p = params.level, params.cut, params.p
    s = params.slots if slots is None else slots
    m2l = sum(2 * M2L_HALO_ROWS * (2 ** n) * p * params.coeff_bytes
              for n in range(k + 1, L + 1))
    p2p = 2 * P2P_HALO_ROWS * (2 ** L) * s * PARTICLE_BYTES
    return {"m2l": float(m2l), "p2p": float(p2p), "total": float(m2l + p2p)}


def comm_root_tree(params: ModelParams) -> float:
    """M2M/L2L traffic between a subtree and the root tree (per subtree)."""
    return alpha_comm(params.p, params.coeff_bytes) * 2.0


def comm_overlap_effective(comm_bytes, hide_work, params: ModelParams,
                           overlap: bool = True, extra_hide=0.0):
    """Serial-residue cost of an overlapped halo exchange (DESIGN.md §9).

    The paper's running-time model (Eqs 16-20) prices communication as a
    serial term added to compute; the interior/rim driver instead hides the
    exchange behind the tile-interior work, so only the residue
    ``max(0, t_byte * bytes - t_flop * hide_work)`` is paid serially.
    ``hide_work`` is the modeled interior work available to hide behind
    (same units as ``work_leaf`` / ``work_subtree``); without overlap the
    full serial price is returned.  Accepts scalars or per-device arrays.

    ``extra_hide`` is the substep pipeline's ENLARGED hiding budget
    (DESIGN.md §12): additional flops traced between a collective's issue
    and its first consumption — the replicated root-tree sweep the
    pipelined driver defers past the sharded M2L work
    (:func:`work_root_tree`) and the cross-substep window the prefetched
    P2P exchange flies through (:func:`work_upward`).  It simply joins
    ``hide_work`` under the same max(0, ...) residue, so more hiding can
    never price WORSE than less.  Ignored when ``overlap`` is False (the
    serial ordering has nothing in flight).
    """
    t_comm = params.t_byte * np.asarray(comm_bytes, dtype=np.float64)
    if not overlap:
        return t_comm
    hide = (np.asarray(hide_work, dtype=np.float64)
            + np.asarray(extra_hide, dtype=np.float64))
    return np.maximum(0.0, t_comm - params.t_flop * hide)


def work_root_tree(params: ModelParams) -> float:
    """Flops of the replicated root-tree sweep (levels 2..k M2L/L2L plus
    the below-cut M2M chain), paid identically on every device.

    Under the pipelined driver (DESIGN.md §12) this compute runs only at
    the cut-level all_gather's first consumption point — i.e. AFTER all
    sharded-level M2L work — so it is hiding budget for the per-level halo
    exchanges still in flight, on top of the interior extents.
    """
    k, p = params.cut, params.p
    boxes = sum(4 ** l for l in range(2, k + 1))
    return float(boxes * work_nonleaf(p))


def work_upward(params: ModelParams, leaf_boxes) -> np.ndarray:
    """P2M + subtree M2M flops for ``leaf_boxes`` local leaf boxes — the
    substep k+1 compute available to hide a CROSS-substep prefetched P2P
    exchange (DESIGN.md §12): the stepper issues the next substep's packed
    exchange right after rebinning, and the upward sweep of the next
    evaluation runs before the exchanged rim is first read.  Dense layout
    pays every slot, so the P2M term scales with ``params.slots``.
    """
    lb = np.asarray(leaf_boxes, dtype=np.float64)
    p2m = lb * params.slots * 2.0 * params.p
    # subtree M2M boxes above the leaves: sum_{j>=1} 4^-j ~ 1/3 of leaves
    m2m = (lb / 3.0) * params.p * params.p
    return p2m + m2m


# ---------------------------------------------------------------------------
# Memory estimates (paper §5.3, Tables 1 and 2)
# ---------------------------------------------------------------------------


def total_boxes(level: int) -> int:
    """Lambda = sum_l 4^l = (4^(L+1) - 1) / 3."""
    return (4 ** (level + 1) - 1) // 3


def memory_serial(params: ModelParams, n_particles: int) -> dict[str, float]:
    """Table 1 (bytes).  d=2, B=28, Lambda = total boxes, s = slots."""
    L, p, s = params.level, params.p, params.slots
    lam = total_boxes(L)
    d, B = D, PARTICLE_BYTES
    return {
        "box_centers": 8 * d * lam,
        "interaction_boxes": (2 * 4) * lam + (27 * 4) * lam,
        "interaction_values": (2 * 4) * lam + 27 * (8 * d + 16 * p) * lam,
        "multipole_coefficients": 16 * p * lam,
        "temporary_coefficients": 16 * p * lam,
        "local_coefficients": 16 * p * lam,
        "local_particles": (2 * 4) * lam + B * n_particles,
        "neighbor_particles": (2 * 4) * lam + 8 * B * s * (2 ** (d * L)),
    }


def memory_parallel(params: ModelParams, n_procs: int, n_local_trees: int,
                    n_boundary_boxes: int) -> dict[str, float]:
    """Table 2 (bytes): explicitly parallel structures per process."""
    s, A = params.slots, ARROW_BYTES
    return {
        "partition": (2 * 4) * n_procs + 4 * n_local_trees,
        "inverse_partition": 4 * n_local_trees,
        "neighbor_send_overlap": n_boundary_boxes * s * A,
        "neighbor_recv_overlap": n_boundary_boxes * s * A,
        "interaction_send_overlap": 27 * n_boundary_boxes * A,
        "interaction_recv_overlap": 27 * n_boundary_boxes * A,
    }


# ---------------------------------------------------------------------------
# Greengard-Gropp running-time model (paper Eq 10) — kept as the baseline
# model our extension is compared against in benchmarks/fmm_scaling.py.
# ---------------------------------------------------------------------------


def greengard_gropp_time(n: int, n_procs: int, boxes_finest: int,
                         a: float = 1.0, b: float = 1.0, c: float = 1.0,
                         d: float = 1.0) -> float:
    """T = a N/P + b log4(P) + c N/(B P) + d N B / P   (lower-order e dropped)."""
    import math

    P, B = n_procs, boxes_finest
    return (a * n / P + b * math.log(P, 4.0) + c * n / (B * P) + d * n * B / P)
