"""Pluggable equation registry: everything kernel-specific in ONE object.

PetFMM's headline claim is extensibility — one FMM core serving many
science codes (paper §1/§6; Holm et al., arXiv:1311.1006 serve potential-,
field-, and vortex-type evaluations behind the same kernel abstraction).
Until this module, the entire stack from ``core/expansions.py`` down to the
Pallas kernels hardcoded the complex velocity kernel ``q / (z - z_j)``.

An :class:`EquationSpec` captures the full kernel contract the drivers
consume — they never branch on an equation name (grep-guarded in
tests/test_equations.py):

* ``charge_scale``    — input strength -> stored pseudo-charge ``q``;
* ``p2m_coeff``       — per-order charge map ``ahat_k = c_k sum q zhat^k``;
* ``m2m_operator``    — the (4, p, p) upward translation tensor;
* ``m2l_folded``      — the parity-folded (8, 4p, 4p) block operator
  (DESIGN.md §4), per level when the physics demands it;
* ``m2l_scale``       — the M2L dimension scalar (``1/r`` for the velocity
  kernel; ``1`` for the Laplace potential, whose ``a_0 log r`` shift rides
  inside the level-dependent operator instead);
* ``l2p_modes``       — which LE evaluations to emit (value, -derivative);
* ``p2p_terms``       — the near-field pair interaction in explicit
  real/imag arithmetic (the ONE formula behind the jnp slab reference, the
  Pallas P2P kernel, and :func:`EquationSpec.pairwise`);
* ``nout``            — output channels per target slot;
* ``q_is_real``       — packed P2P halo payload width (4 planes vs 5);
* ``needs_targets``   — passive source != target evaluation mode.

Registered equations:

``vortex``   the existing complex-velocity Biot-Savart client (default;
             bit-compatible with the pre-registry code paths);
``laplace``  2-D Laplace potential ``Re[q log(z - z_j)]`` plus field
             ``-q/(z - z_j)`` from ONE downward sweep — the classic
             Greengard-Rokhlin log expansion.  ``Re`` of channel 0 is the
             potential (exact for real charges, where the branch-cut
             ambiguity of the complex log is purely imaginary); channel 1
             is the field ``-dPhi/dz``;
``tracer``   passive evaluation of the velocity kernel at a separate batch
             of target points (probe grids, tracer particles) binned into
             the same tree and sharded by the same execution plan.

Everything a new equation inherits for free — plans, two-axis halos,
interior/rim overlap, kernel block autotuning — is documented in
DESIGN.md §10.
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp

from . import expansions as ex
from .quadtree import box_size


class EquationSpec:
    """Base spec: the complex velocity kernel (vortex) contract.

    Instances are lightweight singletons; hashing/equality go through
    ``name`` so a spec can be a jit static argument and an ``lru_cache``
    key.  Subclasses override the kernel-specific pieces; the geometric
    machinery (parity folding, halos, plans) is equation-independent.
    """

    name: str = "vortex"
    nout: int = 1                    # complex output channels per target
    q_is_real: bool = False          # packed P2P payload: 4 planes vs 5
    needs_targets: bool = False      # passive source != target evaluation
    l2p_modes: tuple[str, ...] = ("value",)
    charge_scale: complex = 1.0 / (2j * np.pi)   # gamma -> pseudo-charge q
    default_p: int = 12              # expansion order for p="auto" jobs

    def __hash__(self):
        # class identity participates: two specs with the same name but
        # different overrides must NOT collide in jit caches keyed on the
        # spec (they would silently serve each other's compiled programs)
        return hash(("EquationSpec", type(self).__qualname__, self.name))

    def __eq__(self, other):
        return type(other) is type(self) and other.name == self.name

    def __repr__(self):
        return f"EquationSpec({self.name!r})"

    # -- expansion-side contract (numpy operator builders, host-side) -------

    def p2m_coeff(self, p: int):
        """(p,) per-order weights ``c_k`` in ``ahat_k = c_k sum q zhat^k``,
        or None for the identity map (the velocity-kernel ME)."""
        return None

    def m2m_operator(self, p: int) -> np.ndarray:
        return ex.m2m_operator(p)

    def m2l_folded(self, p: int, level: int) -> np.ndarray:
        """Parity-folded (8, 4p, 4p) block operator for ``level``.  The
        velocity kernel is scale-normalized to level independence."""
        return ex.m2l_folded_operator(p)

    def m2l_scale(self, level: int) -> float:
        """Scalar applied to the folded M2L output (the kernel dimension:
        the velocity kernel carries 1/length)."""
        return float(2.0 ** level)           # == 1 / box_size(level), exact

    # -- near-field contract (traced jnp math; ONE formula, three users) ----

    def p2p_terms(self, ddx, ddy, r2, valid, qr, qi, moll):
        """Per-pair contributions in explicit real/imag arithmetic.

        All operands broadcast to ``(..., T, S)``: target-source deltas
        ``ddx/ddy``, squared distance ``r2``, the validity mask (source
        occupancy AND ``r2 > 0`` self-exclusion), source charge components
        ``qr/qi``, and the Gaussian mollifier ``moll`` (None selects the
        singular kernel).  Returns ``nout`` pairs ``(re, im)`` to be summed
        over the source axis.  This one method is consumed by the jnp slab
        reference, the Pallas P2P kernel body, and :meth:`pairwise`.
        """
        inv = jnp.where(valid, 1.0, 0.0) / jnp.where(r2 > 0.0, r2, 1.0)
        if moll is not None:
            inv = inv * moll
        return [((qr * ddx + qi * ddy) * inv, (qi * ddx - qr * ddy) * inv)]

    def pairwise(self, z_tgt, z_src, q_src, mask_src, sigma,
                 exclude_self: bool = True):
        """Direct pair sum built on :meth:`p2p_terms`.

        Shapes: z_tgt (..., T); z_src/q_src/mask_src (..., S).  Returns
        (..., T) complex for single-channel equations, (..., T, nout)
        otherwise.
        """
        ddx = z_tgt.real[..., :, None] - z_src.real[..., None, :]
        ddy = z_tgt.imag[..., :, None] - z_src.imag[..., None, :]
        r2 = ddx * ddx + ddy * ddy
        valid = mask_src[..., None, :] & \
            (r2 > 0 if exclude_self else jnp.bool_(True))
        moll = None
        if sigma is not None:
            moll = 1.0 - jnp.exp(-r2 / (2.0 * sigma * sigma))
        qr = q_src.real[..., None, :]
        qi = q_src.imag[..., None, :]
        outs = [(re + 1j * im).sum(axis=-1).astype(z_tgt.dtype)
                for re, im in self.p2p_terms(ddx, ddy, r2, valid, qr, qi,
                                             moll)]
        return outs[0] if self.nout == 1 else jnp.stack(outs, axis=-1)

    # -- f64 numpy oracle (independent of the jnp path; used by tests/CLIs) -

    def direct_channels(self, dz: np.ndarray, r2: np.ndarray, q: np.ndarray,
                        moll) -> list[np.ndarray]:
        """Numpy complex128 per-pair channels (guarded at r2 == 0)."""
        with np.errstate(divide="ignore", invalid="ignore"):
            inv = np.where(r2 > 0, 1.0, 0.0) / np.where(r2 > 0, dz, 1.0)
        if moll is not None:
            inv = inv * moll
        return [inv * q]


class VortexEquation(EquationSpec):
    """The Biot-Savart velocity client — the registry default.

    Identical math to the base contract; the jnp-route pair sum is routed
    through ``vortex.pairwise_w`` (the complex-division einsum form the
    pre-registry driver used) rather than the generic real-arithmetic
    expansion of ``p2p_terms`` — the two agree to f32 roundoff, but the
    einsum form keeps the serial near field's exact legacy numerics and
    its XLA fusion profile (the Pallas kernel route consumes ``p2p_terms``
    directly, unchanged either way).
    """

    def pairwise(self, z_tgt, z_src, q_src, mask_src, sigma,
                 exclude_self: bool = True):
        from .vortex import pairwise_w
        return pairwise_w(z_tgt, z_src, q_src, mask_src, sigma,
                          exclude_self=exclude_self)


class LaplaceEquation(EquationSpec):
    """2-D Laplace potential + field from one downward sweep.

    Multipole data is the Greengard-Rokhlin log expansion
    ``Phi(z) = a_0 log(z - c) + sum_k a_k / (z - c)^k`` with
    ``a_0 = sum q`` and ``a_k = -(1/k) sum q (z_j - c)^k``; the local side
    is the plain polynomial ``sum_l b_l (z - c)^l`` whose value is the
    (complex) potential and whose negated derivative is the field.  All
    coefficients are scale-normalized exactly as the velocity kernel's
    (``ahat_k = a_k r^-k``, ``bhat_l = b_l r^l``): M2M and L2L stay level
    independent and the only level dependence is the ``a_0 log r`` shift,
    folded into the M2L operator's ``[l=0, k=0]`` entries (DESIGN.md §10).
    Charges are real; ``Re`` of the potential channel is branch-cut exact.
    """

    name = "laplace"
    nout = 2
    q_is_real = True
    l2p_modes = ("value", "ngrad")
    charge_scale = 1.0 + 0.0j
    default_p = 16                   # the log expansion converges slower

    def p2m_coeff(self, p: int):
        c = np.zeros(p, dtype=np.complex128)
        c[0] = 1.0
        c[1:] = -1.0 / np.arange(1, p)
        return c

    def m2m_operator(self, p: int) -> np.ndarray:
        return _laplace_m2m_operator(p)

    def m2l_folded(self, p: int, level: int) -> np.ndarray:
        return _laplace_m2l_folded(p, level)

    def m2l_scale(self, level: int) -> float:
        return 1.0

    def p2p_terms(self, ddx, ddy, r2, valid, qr, qi, moll):
        w = jnp.where(valid, 1.0, 0.0)
        if moll is not None:
            w = w * moll
        # potential: q * log|dz| (real log; Re[] is branch-exact for the
        # real charges this equation is defined over)
        pot = 0.5 * jnp.log(jnp.where(r2 > 0.0, r2, 1.0)) * w
        inv = w / jnp.where(r2 > 0.0, r2, 1.0)
        return [(qr * pot, qi * pot),
                (-(qr * ddx + qi * ddy) * inv, -(qi * ddx - qr * ddy) * inv)]

    def direct_channels(self, dz, r2, q, moll):
        w = np.where(r2 > 0, 1.0, 0.0)
        if moll is not None:
            w = w * moll
        pot = 0.5 * np.log(np.where(r2 > 0, r2, 1.0)) * w
        with np.errstate(divide="ignore", invalid="ignore"):
            inv = w / np.where(r2 > 0, dz, 1.0)
        return [q * pot, -q * inv]


class TracerEquation(VortexEquation):
    """Passive velocity evaluation at a separate target batch.

    Identical expansion/P2P math to ``vortex``; the targets carry no
    charges and are binned into the same leaf layout (probe grids, tracer
    particles), evaluated against the sources' local expansions and
    near field, sharded by the same execution plan.
    """

    name = "tracer"
    needs_targets = True


VORTEX = VortexEquation()
LAPLACE = LaplaceEquation()
TRACER = TracerEquation()

EQUATIONS: dict[str, EquationSpec] = {e.name: e
                                      for e in (VORTEX, LAPLACE, TRACER)}


def get_equation(eq) -> EquationSpec:
    """Resolve a spec, a registered name, or None (-> vortex default)."""
    if eq is None:
        return VORTEX
    if isinstance(eq, EquationSpec):
        return eq
    try:
        return EQUATIONS[eq]
    except KeyError:
        raise ValueError(f"unknown equation {eq!r}; registered: "
                         f"{sorted(EQUATIONS)}") from None


def resolve_job_spec(eq, *, have_targets: bool = False,
                     steps: int = 0) -> EquationSpec:
    """Per-job spec resolution for the serving path (serve/fmm_service.py).

    Resolves like :func:`get_equation` and then validates the job shape
    against the spec's contract, so malformed requests fail typed at
    ADMISSION instead of deep inside a traced driver:

    * a ``needs_targets`` equation (tracer) without a probe/target set is
      meaningless — the sources carry no charges to evaluate at;
    * trajectory sessions (``steps > 0``) integrate the vortex system
      (:class:`~repro.core.stepper.VortexStepper`); evaluation-only
      equations cannot be advected.
    """
    spec = get_equation(eq)
    if spec.needs_targets and not have_targets:
        raise ValueError(f"equation {spec.name!r} requires a probe/target "
                         f"set (job.targets is None)")
    if steps and spec.name != "vortex":
        raise ValueError(f"trajectory sessions (steps={steps}) integrate "
                         f"the vortex system; equation {spec.name!r} is "
                         f"evaluation-only")
    return spec


def register(spec: EquationSpec) -> EquationSpec:
    """Add a spec to the registry (application codes extend here).

    Re-registering the same spec is a no-op; replacing an existing name
    with a DIFFERENT spec raises — drivers jit-cache compiled programs
    keyed on the spec, so silently swapping the physics behind a name
    would serve stale programs.  Pick a new name for variants.
    """
    if spec.name in EQUATIONS and EQUATIONS[spec.name] != spec:
        raise ValueError(
            f"equation {spec.name!r} is already registered with a "
            f"different spec; register variants under a new name")
    EQUATIONS[spec.name] = spec
    return spec


# ---------------------------------------------------------------------------
# Laplace operator builders (Carrier-Greengard-Rokhlin lemmas 2.3 / 2.4,
# scale-normalized like expansions.py)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _laplace_m2m_operator(p: int) -> np.ndarray:
    """(4, p, p) log-expansion M2M: ``bhat_l = sum_k Op[c, l, k] ahat_k``.

    With dhat = (c_child - c_parent) / r_parent (CGR Lemma 2.3, normalized):
    ``bhat_0 = ahat_0``; for l >= 1,
    ``bhat_l = -ahat_0 dhat^l / l + sum_{k=1}^{l} ahat_k 2^-k dhat^(l-k)
    C(l-1, k-1)``.
    """
    C = ex._binom_table(max(p, 2))
    op = np.zeros((4, p, p), dtype=np.complex128)
    for ci, (cy, cx) in enumerate(ex.CHILD_OFFSETS):
        dhat = ((cx - 0.5) / 2.0) + 1j * ((cy - 0.5) / 2.0)
        op[ci, 0, 0] = 1.0
        for l in range(1, p):
            op[ci, l, 0] = -(dhat ** l) / l
            for k in range(1, l + 1):
                op[ci, l, k] = C[l - 1, k - 1] * dhat ** (l - k) * 2.0 ** (-k)
    return op


@functools.lru_cache(maxsize=None)
def _laplace_m2l_base(p: int, level: int) -> np.ndarray:
    """(40, p, p) log-expansion M2L: ``bhat_l = sum_k Op[o, l, k] ahat_k``.

    For a source at dimensionless offset d (CGR Lemma 2.4, normalized with
    z0 = d * r): the tail entries are level independent, and the whole
    ``a_0 log(z0) = a_0 (log(-d) + log r)`` shift sits in ``Op[o, 0, 0]``
    — the ONLY level-dependent entry (the "log r shift").  ``Re`` of the
    resulting potential is branch-cut exact for real charges.
    """
    C = ex._binom_table(2 * p + 2)
    logr = np.log(box_size(level))
    op = np.zeros((len(ex.M2L_OFFSETS), p, p), dtype=np.complex128)
    for oi, (dx, dy) in enumerate(ex.M2L_OFFSETS):
        d = float(dx) + 1j * float(dy)
        op[oi, 0, 0] = np.log(-d) + logr
        for k in range(1, p):
            op[oi, 0, k] = (-1.0) ** k * d ** (-k)
        for l in range(1, p):
            op[oi, l, 0] = -1.0 / (l * d ** l)
            for k in range(1, p):
                op[oi, l, k] = (-1.0) ** k * C[l + k - 1, k - 1] \
                    * d ** (-(k + l))
    return op


@functools.lru_cache(maxsize=None)
def _laplace_m2l_folded(p: int, level: int) -> np.ndarray:
    return ex.fold_operator(_laplace_m2l_base(p, level), p)


# ---------------------------------------------------------------------------
# O(N^2) oracle, per equation (host-side numpy, f64)
# ---------------------------------------------------------------------------


def direct_sum(eq, z_tgt: np.ndarray, z_src: np.ndarray, strength: np.ndarray,
               sigma: float | None, chunk: int = 2048) -> np.ndarray:
    """f64 direct sum of ``eq``'s pair interaction at arbitrary targets.

    ``strength`` is the raw input strength (circulation for vortex/tracer,
    charge for laplace); the spec's ``charge_scale`` maps it to the stored
    pseudo-charge exactly as ``quadtree.build_tree`` does.  Returns (T,)
    complex128 for single-channel equations, (T, nout) otherwise.
    Self/coincident pairs are excluded via the r2 > 0 guard.
    """
    eq = get_equation(eq)
    z_tgt = np.asarray(z_tgt, dtype=np.complex128)
    z_src = np.asarray(z_src, dtype=np.complex128)
    q = np.asarray(strength, dtype=np.float64) * eq.charge_scale
    out = np.zeros((len(z_tgt), eq.nout), dtype=np.complex128)
    for start in range(0, len(z_tgt), chunk):
        zt = z_tgt[start:start + chunk]
        dz = zt[:, None] - z_src[None, :]
        r2 = np.abs(dz) ** 2
        moll = None
        if sigma is not None:
            moll = 1.0 - np.exp(-r2 / (2.0 * sigma * sigma))
        for c, ch in enumerate(eq.direct_channels(dz, r2, q[None, :], moll)):
            out[start:start + chunk, c] = ch.sum(axis=1)
    return out[:, 0] if eq.nout == 1 else out
