"""2D complex multipole/local expansions and translation operators.

The far-field kernel is the singular complex velocity kernel

    W(z) = sum_j q_j / (z - z_j),        q_j = gamma_j / (2*pi*i),

which is the paper's ``1/|x|^2``-type substitution kernel (PetFMM §3): the
Gaussian-regularized Biot-Savart kernel equals this singular kernel times a
mollifier that is ~1 at interaction-list distances.

Multipole expansion (ME) about a box center c with radius (side) r:

    W(z) = sum_{k=0}^{p-1} a_k / (z - c)^{k+1}

Local expansion (LE):

    W(z) = sum_{l=0}^{p-1} b_l (z - c)^l

**Scale normalization (beyond-paper, see DESIGN.md §3):** we store
``ahat_k = a_k r^-k`` and ``bhat_l = b_l r^l``.  All translation operators
then become *level independent*; M2L carries a single ``1/r`` scalar (the
kernel has dimension 1/length).  One (4,p,p) M2M tensor, one (40,p,p) M2L
tensor and one (4,p,p) L2L tensor serve the whole tree and stay resident in
VMEM inside the Pallas kernels.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .quadtree import M2L_OFFSETS, M2L_VALIDITY

# Child offsets within a parent, (cy, cx) in {0,1}^2; delta_hat = (c_child -
# c_parent) / r_parent = ((cx - .5)/2, (cy - .5)/2).
CHILD_OFFSETS = [(cy, cx) for cy in range(2) for cx in range(2)]


def _binom_table(n: int) -> np.ndarray:
    c = np.zeros((n, n), dtype=np.float64)
    c[:, 0] = 1.0
    for i in range(1, n):
        for j in range(1, i + 1):
            c[i, j] = c[i - 1, j - 1] + c[i - 1, j]
    return c


@functools.lru_cache(maxsize=None)
def m2m_operator(p: int) -> np.ndarray:
    """(4, p, p) tensor: ahat_parent[m] = sum_k Op[c, m, k] ahat_child[k].

    Op[c, m, k] = C(m, k) * dhat_c^(m-k) * 2^-k   (k <= m), with
    dhat_c = (child center - parent center) / r_parent.
    """
    C = _binom_table(p)
    op = np.zeros((4, p, p), dtype=np.complex128)
    for ci, (cy, cx) in enumerate(CHILD_OFFSETS):
        dhat = ((cx - 0.5) / 2.0) + 1j * ((cy - 0.5) / 2.0)
        for m in range(p):
            for k in range(m + 1):
                op[ci, m, k] = C[m, k] * dhat ** (m - k) * 2.0 ** (-k)
    return op


@functools.lru_cache(maxsize=None)
def l2l_operator(p: int) -> np.ndarray:
    """(4, p, p) tensor: bhat_child[m] = sum_l Op[c, m, l] bhat_parent[l].

    Op[c, m, l] = 2^-m * C(l, m) * dhat_c^(l-m)   (l >= m).
    """
    C = _binom_table(p)
    op = np.zeros((4, p, p), dtype=np.complex128)
    for ci, (cy, cx) in enumerate(CHILD_OFFSETS):
        dhat = ((cx - 0.5) / 2.0) + 1j * ((cy - 0.5) / 2.0)
        for m in range(p):
            for l in range(m, p):
                op[ci, m, l] = 2.0 ** (-m) * C[l, m] * dhat ** (l - m)
    return op


@functools.lru_cache(maxsize=None)
def m2l_operator(p: int) -> np.ndarray:
    """(40, p, p) tensor: bhat_tgt[l] = (1/r) sum_k Op[o, l, k] ahat_src[k].

    For source at integer offset d = (dx, dy) from the target (in units of
    the level box size), dhat = c_src - c_tgt (normalized) = dx + 1j*dy and

        Op[o, l, k] = (-1)^(k+1) * C(k+l, l) * dhat^-(k+l+1).
    """
    C = _binom_table(2 * p)
    op = np.zeros((len(M2L_OFFSETS), p, p), dtype=np.complex128)
    for oi, (dx, dy) in enumerate(M2L_OFFSETS):
        dhat = float(dx) + 1j * float(dy)
        for l in range(p):
            for k in range(p):
                op[oi, l, k] = (-1.0) ** (k + 1) * C[k + l, l] * dhat ** (-(k + l + 1))
    return op


# ---------------------------------------------------------------------------
# Stage implementations (pure jnp; dense level grids).
# Grids: me / le at level l have shape (n, n, p), n = 2**l, row-major (iy,ix).
# ---------------------------------------------------------------------------


def _powers(zhat: jnp.ndarray, p: int) -> jnp.ndarray:
    """Stack [zhat^0, ..., zhat^(p-1)] along a new last axis."""
    ones = jnp.ones_like(zhat)
    steps = [ones]
    for _ in range(p - 1):
        steps.append(steps[-1] * zhat)
    return jnp.stack(steps, axis=-1)


def p2m(z: jnp.ndarray, q: jnp.ndarray, mask: jnp.ndarray, centers: jnp.ndarray,
        r: float, p: int) -> jnp.ndarray:
    """Particles -> normalized MEs at the leaf level.  -> (n, n, p)."""
    zhat = (z - centers[..., None]) / r            # (n, n, s)
    pw = _powers(zhat, p)                          # (n, n, s, p)
    qm = jnp.where(mask, q, 0.0)
    return jnp.einsum("yxs,yxsk->yxk", qm, pw)


def m2m(me_child: jnp.ndarray, p: int) -> jnp.ndarray:
    """Child level grid (2ny, 2nx, p) -> parent grid (ny, nx, p).

    Rectangular grids supported (row slabs under the parallel decomposition).
    """
    op = jnp.asarray(m2m_operator(p), dtype=me_child.dtype)
    ny, nx = me_child.shape[0] // 2, me_child.shape[1] // 2
    c = me_child.reshape(ny, 2, nx, 2, p)          # [py, cy, px, cx, k]
    # CHILD_OFFSETS order is (cy, cx) row-major -> index c = cy*2+cx
    c = c.transpose(0, 2, 1, 3, 4).reshape(ny, nx, 4, p)
    return jnp.einsum("yxck,cmk->yxm", c, op)


def parity_mask(n: int, validity_o: np.ndarray) -> np.ndarray:
    """(n, n) bool mask from a (2, 2) [py, px] parity-validity table."""
    return parity_mask_rect(n, n, validity_o)


def parity_mask_rect(rows: int, cols: int, validity_o: np.ndarray,
                     row0: int = 0) -> np.ndarray:
    """(rows, cols) parity mask; ``row0`` is the global index of row 0."""
    iy = (np.arange(rows) + row0) % 2
    ix = np.arange(cols) % 2
    return validity_o[np.ix_(iy, ix)]


def m2l_reference(me: jnp.ndarray, level: int, p: int) -> jnp.ndarray:
    """Dense M2L at one level via 40 static-slice shifted matmuls.

    This is the pure-jnp path (and the oracle for the Pallas kernel).
    """
    n = me.shape[0]
    r = 2.0 ** (-level)
    ops = m2l_operator(p)
    pad = jnp.pad(me, ((3, 3), (3, 3), (0, 0)))
    le = jnp.zeros_like(me)
    for oi, (dx, dy) in enumerate(M2L_OFFSETS):
        src = pad[3 + dy:3 + dy + n, 3 + dx:3 + dx + n, :]
        op = jnp.asarray(ops[oi], dtype=me.dtype)
        contrib = jnp.einsum("yxk,lk->yxl", src, op)
        m = jnp.asarray(parity_mask(n, M2L_VALIDITY[oi]), dtype=me.dtype)
        le = le + contrib * m[..., None]
    return le / r


def l2l(le_parent: jnp.ndarray, p: int) -> jnp.ndarray:
    """Parent grid (ny, nx, p) -> child grid (2ny, 2nx, p)."""
    op = jnp.asarray(l2l_operator(p), dtype=le_parent.dtype)
    ny, nx = le_parent.shape[0], le_parent.shape[1]
    c = jnp.einsum("yxl,cml->yxcm", le_parent, op)  # (ny, nx, 4, m)
    c = c.reshape(ny, nx, 2, 2, p).transpose(0, 2, 1, 3, 4)
    return c.reshape(2 * ny, 2 * nx, p)


def l2p(le: jnp.ndarray, z: jnp.ndarray, centers: jnp.ndarray, r: float,
        p: int) -> jnp.ndarray:
    """Evaluate leaf LEs at particle positions -> complex W, (n, n, s)."""
    zhat = (z - centers[..., None]) / r
    pw = _powers(zhat, p)                          # (n, n, s, p)
    return jnp.einsum("yxl,yxsl->yxs", le, pw)


# -- Expansion evaluation helpers (unit tests / debugging) ------------------


def eval_me(ahat: np.ndarray, center: complex, r: float, z: np.ndarray) -> np.ndarray:
    """Evaluate a normalized ME at points z (far from the box)."""
    zh = (np.asarray(z) - center) / r
    out = np.zeros_like(zh, dtype=np.complex128)
    for k in range(len(ahat) - 1, -1, -1):
        out = (out + ahat[k]) / zh
    return out / r


def eval_le(bhat: np.ndarray, center: complex, r: float, z: np.ndarray) -> np.ndarray:
    """Evaluate a normalized LE at points z (inside the box)."""
    zh = (np.asarray(z) - center) / r
    out = np.zeros_like(zh, dtype=np.complex128)
    for l in range(len(bhat) - 1, -1, -1):
        out = out * zh + bhat[l]
    return out
