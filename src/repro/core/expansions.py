"""2D complex multipole/local expansions and translation operators.

The far-field kernel is the singular complex velocity kernel

    W(z) = sum_j q_j / (z - z_j),        q_j = gamma_j / (2*pi*i),

which is the paper's ``1/|x|^2``-type substitution kernel (PetFMM §3): the
Gaussian-regularized Biot-Savart kernel equals this singular kernel times a
mollifier that is ~1 at interaction-list distances.

Multipole expansion (ME) about a box center c with radius (side) r:

    W(z) = sum_{k=0}^{p-1} a_k / (z - c)^{k+1}

Local expansion (LE):

    W(z) = sum_{l=0}^{p-1} b_l (z - c)^l

**Scale normalization (beyond-paper, see DESIGN.md §3):** we store
``ahat_k = a_k r^-k`` and ``bhat_l = b_l r^l``.  All translation operators
then become *level independent*; M2L carries a single ``1/r`` scalar (the
kernel has dimension 1/length).  One (4,p,p) M2M tensor, one parity-folded
(8,4p,4p) M2L block operator and one (4,p,p) L2L tensor serve the whole
tree and stay resident in VMEM inside the Pallas kernels.

**Parity folding (DESIGN.md §4):** M2L works at parent granularity.  The
leaf/level grid is relayouted into four child-parity planes stacked along
the coefficient axis — a ``(ny/2, nx/2, 4p)`` "parent-plane" grid — and the
whole 40-offset masked reduction collapses to 8 shifted matmuls against the
parent-neighbor block operator, whose zero blocks *are* the parity masks.
Every box receives exactly its 27 valid interactions; nothing is computed
and thrown away, and the halo needed from neighbors shrinks from ±3 child
rows to ±1 parent row (= 2 child rows).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .quadtree import (M2L_OFFSETS, M2L_VALIDITY, PARENT_NEIGH8, box_size)

# Child offsets within a parent, (cy, cx) in {0,1}^2; delta_hat = (c_child -
# c_parent) / r_parent = ((cx - .5)/2, (cy - .5)/2).
CHILD_OFFSETS = [(cy, cx) for cy in range(2) for cx in range(2)]


def _binom_table(n: int) -> np.ndarray:
    c = np.zeros((n, n), dtype=np.float64)
    c[:, 0] = 1.0
    for i in range(1, n):
        for j in range(1, i + 1):
            c[i, j] = c[i - 1, j - 1] + c[i - 1, j]
    return c


@functools.lru_cache(maxsize=None)
def m2m_operator(p: int) -> np.ndarray:
    """(4, p, p) tensor: ahat_parent[m] = sum_k Op[c, m, k] ahat_child[k].

    Op[c, m, k] = C(m, k) * dhat_c^(m-k) * 2^-k   (k <= m), with
    dhat_c = (child center - parent center) / r_parent.
    """
    C = _binom_table(p)
    op = np.zeros((4, p, p), dtype=np.complex128)
    for ci, (cy, cx) in enumerate(CHILD_OFFSETS):
        dhat = ((cx - 0.5) / 2.0) + 1j * ((cy - 0.5) / 2.0)
        for m in range(p):
            for k in range(m + 1):
                op[ci, m, k] = C[m, k] * dhat ** (m - k) * 2.0 ** (-k)
    return op


@functools.lru_cache(maxsize=None)
def l2l_operator(p: int) -> np.ndarray:
    """(4, p, p) tensor: bhat_child[m] = sum_l Op[c, m, l] bhat_parent[l].

    Op[c, m, l] = 2^-m * C(l, m) * dhat_c^(l-m)   (l >= m).
    """
    C = _binom_table(p)
    op = np.zeros((4, p, p), dtype=np.complex128)
    for ci, (cy, cx) in enumerate(CHILD_OFFSETS):
        dhat = ((cx - 0.5) / 2.0) + 1j * ((cy - 0.5) / 2.0)
        for m in range(p):
            for l in range(m, p):
                op[ci, m, l] = 2.0 ** (-m) * C[l, m] * dhat ** (l - m)
    return op


@functools.lru_cache(maxsize=None)
def m2l_operator(p: int) -> np.ndarray:
    """(40, p, p) tensor: bhat_tgt[l] = (1/r) sum_k Op[o, l, k] ahat_src[k].

    For source at integer offset d = (dx, dy) from the target (in units of
    the level box size), dhat = c_src - c_tgt (normalized) = dx + 1j*dy and

        Op[o, l, k] = (-1)^(k+1) * C(k+l, l) * dhat^-(k+l+1).
    """
    C = _binom_table(2 * p)
    op = np.zeros((len(M2L_OFFSETS), p, p), dtype=np.complex128)
    for oi, (dx, dy) in enumerate(M2L_OFFSETS):
        dhat = float(dx) + 1j * float(dy)
        for l in range(p):
            for k in range(p):
                op[oi, l, k] = (-1.0) ** (k + 1) * C[k + l, l] * dhat ** (-(k + l + 1))
    return op


# ---------------------------------------------------------------------------
# Stage implementations (pure jnp; dense level grids).
# Grids: me / le at level l have shape (n, n, p), n = 2**l, row-major (iy,ix).
# ---------------------------------------------------------------------------


def _powers(zhat: jnp.ndarray, p: int) -> jnp.ndarray:
    """Stack [zhat^0, ..., zhat^(p-1)] along a new last axis."""
    ones = jnp.ones_like(zhat)
    steps = [ones]
    for _ in range(p - 1):
        steps.append(steps[-1] * zhat)
    return jnp.stack(steps, axis=-1)


def p2m(z: jnp.ndarray, q: jnp.ndarray, mask: jnp.ndarray, centers: jnp.ndarray,
        r: float, p: int, coeff: np.ndarray | None = None) -> jnp.ndarray:
    """Particles -> normalized MEs at the leaf level.  -> (n, n, p).

    ``coeff`` is an optional (p,) per-order charge map ``c_k`` (the
    equation spec's ``p2m_coeff``): ``ahat_k = c_k sum q zhat^k``.  None
    is the identity map of the velocity kernel.
    """
    zhat = (z - centers[..., None]) / r            # (n, n, s)
    pw = _powers(zhat, p)                          # (n, n, s, p)
    qm = jnp.where(mask, q, 0.0)
    me = jnp.einsum("yxs,yxsk->yxk", qm, pw)
    if coeff is not None:
        me = me * jnp.asarray(coeff, dtype=me.dtype)
    return me


def m2m(me_child: jnp.ndarray, p: int, op: np.ndarray | None = None
        ) -> jnp.ndarray:
    """Child level grid (2ny, 2nx, p) -> parent grid (ny, nx, p).

    Rectangular grids supported (row slabs under the parallel
    decomposition).  ``op`` overrides the (4, p, p) translation tensor
    (equation specs supply theirs; None is the velocity kernel's).
    """
    op = jnp.asarray(m2m_operator(p) if op is None else op,
                     dtype=me_child.dtype)
    ny, nx = me_child.shape[0] // 2, me_child.shape[1] // 2
    c = me_child.reshape(ny, 2, nx, 2, p)          # [py, cy, px, cx, k]
    # CHILD_OFFSETS order is (cy, cx) row-major -> index c = cy*2+cx
    c = c.transpose(0, 2, 1, 3, 4).reshape(ny, nx, 4, p)
    return jnp.einsum("yxck,cmk->yxm", c, op)


def parity_mask(n: int, validity_o: np.ndarray) -> np.ndarray:
    """(n, n) bool mask from a (2, 2) [py, px] parity-validity table."""
    return parity_mask_rect(n, n, validity_o)


def parity_mask_rect(rows: int, cols: int, validity_o: np.ndarray,
                     row0: int = 0) -> np.ndarray:
    """(rows, cols) parity mask; ``row0`` is the global index of row 0."""
    iy = (np.arange(rows) + row0) % 2
    ix = np.arange(cols) % 2
    return validity_o[np.ix_(iy, ix)]


def m2l_masked40(me: jnp.ndarray, level: int, p: int) -> jnp.ndarray:
    """Dense M2L via 40 masked shifted matmuls (the pre-folding formulation).

    Kept as the independent oracle for the parity-folded path: every box
    computes all 40 candidate offsets and the parity masks discard ~1/3 of
    the work afterwards.  Do not use on the hot path.
    """
    n = me.shape[0]
    r = 2.0 ** (-level)
    ops = m2l_operator(p)
    pad = jnp.pad(me, ((3, 3), (3, 3), (0, 0)))
    le = jnp.zeros_like(me)
    for oi, (dx, dy) in enumerate(M2L_OFFSETS):
        src = pad[3 + dy:3 + dy + n, 3 + dx:3 + dx + n, :]
        op = jnp.asarray(ops[oi], dtype=me.dtype)
        contrib = jnp.einsum("yxk,lk->yxl", src, op)
        m = jnp.asarray(parity_mask(n, M2L_VALIDITY[oi]), dtype=me.dtype)
        le = le + contrib * m[..., None]
    return le / r


# ---------------------------------------------------------------------------
# Parity-folded M2L (parent granularity) — the hot path.
# ---------------------------------------------------------------------------

M2L_HALO = 2   # child rows/cols of ghost data needed by an even-aligned slab


def fold_operator(base: np.ndarray, p: int) -> np.ndarray:
    """Fold a (40, p, p) child-offset M2L operator ``[o, l, k]`` into the
    (8, 4p, 4p) parent-neighbor block operator.

    ``W[d, s*p + k, c*p + l]`` maps coefficient ``k`` of source child ``s``
    of parent-neighbor ``PARENT_NEIGH8[d]`` to coefficient ``l`` of target
    child ``c`` (children in CHILD_OFFSETS order).  Blocks for near-neighbor
    (child-distance < 2) pairs are structurally zero — these zeros are the
    parity masks, folded in.  Exactly 27 blocks per target child are
    nonzero, so the contraction performs exactly the valid interactions.
    The folding is purely geometric, so any equation's base operator
    (core/equations.py) folds the same way.
    """
    idx = {off: i for i, off in enumerate(M2L_OFFSETS)}
    W = np.zeros((8, 4 * p, 4 * p), dtype=np.complex128)
    for di, (Dx, Dy) in enumerate(PARENT_NEIGH8):
        for si, (sy, sx) in enumerate(CHILD_OFFSETS):
            for ci, (py, px) in enumerate(CHILD_OFFSETS):
                d = (2 * Dx + sx - px, 2 * Dy + sy - py)
                if max(abs(d[0]), abs(d[1])) >= 2:
                    # bhat_tgt[l] = sum_k Op[o, l, k] ahat_src[k]
                    W[di, si * p:(si + 1) * p, ci * p:(ci + 1) * p] = base[idx[d]].T
    return W


@functools.lru_cache(maxsize=None)
def m2l_folded_operator(p: int) -> np.ndarray:
    """The velocity kernel's folded block operator (see ``fold_operator``)."""
    return fold_operator(m2l_operator(p), p)


def to_parent_planes(grid: jnp.ndarray, p: int) -> jnp.ndarray:
    """(2R, 2C, p) even-aligned child grid -> (R, C, 4p) parent planes.

    Plane ``c = cy*2 + cx`` (CHILD_OFFSETS order) holds the child with local
    parity (cy, cx); row 0 of ``grid`` must have even global parity.
    """
    R, C = grid.shape[0] // 2, grid.shape[1] // 2
    g = grid.reshape(R, 2, C, 2, p).transpose(0, 2, 1, 3, 4)
    return g.reshape(R, C, 4 * p)


def from_parent_planes(stack: jnp.ndarray, p: int) -> jnp.ndarray:
    """(R, C, 4p) parent planes -> (2R, 2C, p) child grid (inverse layout)."""
    R, C = stack.shape[0], stack.shape[1]
    g = stack.reshape(R, C, 2, 2, p).transpose(0, 2, 1, 3, 4)
    return g.reshape(2 * R, 2 * C, p)


def m2l_slab_geometry(rows: int, row0: int, halo: int) -> tuple[int, int, int]:
    """Index algebra shared by the jnp and Pallas folded M2L paths.

    Returns ``(lo, PR, shift)``: ``lo`` is the local index (into the halo'd
    slab) of the first source child row, ``PR`` the number of parent rows
    covering the interior, ``shift`` the interior's offset within its first
    parent cell.  Raises if ``halo`` ghost rows cannot cover the ±1 parent
    source neighborhood (even-aligned even-length slabs need 2; odd
    alignment or odd length needs 3).
    """
    g0, g1 = row0, row0 + rows - 1
    Ps, Pe = g0 // 2, g1 // 2
    PR = Pe - Ps + 1
    shift = g0 - 2 * Ps
    lo = (2 * Ps - 2) - g0 + halo            # first needed source child row
    hi = (2 * Pe + 3) - g0 + halo            # last needed source child row
    if lo < 0 or hi > rows + 2 * halo - 1:
        raise ValueError(
            f"halo={halo} too small for rows={rows}, row0={row0}: the ±1 "
            f"parent source window needs rows [{lo}, {hi}] of the slab")
    return lo, PR, shift


def m2l_slab_stack(me_halo: jnp.ndarray, p: int, row0: int, halo: int,
                   col0: int = 0, col_halo: int = 0
                   ) -> tuple[jnp.ndarray, tuple[int, int], tuple[int, int]]:
    """Stage a halo'd slab (or 2-D tile) into the parent-plane layout.

    Shared, parity-critical front end of both the jnp and Pallas folded
    M2L paths: slices the ±1-parent source window out of the slab and
    relayouts to parent planes.  With ``col_halo=0`` the columns span the
    full (even) grid width and the ±1-parent column window is zero-padded
    here (row-slab and serial callers); with ``col_halo>0`` the slab
    carries exchanged column ghosts too (2-D tiles under ``shard_map``)
    and the same geometry algebra runs on the column axis, anchored at
    ``col0``.  Returns ``(stack, (PR, rshift), (PC, cshift))`` with
    ``stack`` of shape (PR+2, PC+2, 4p).
    """
    rows = me_halo.shape[0] - 2 * halo
    lo, PR, rshift = m2l_slab_geometry(rows, row0, halo)
    sub = jax.lax.slice_in_dim(me_halo, lo, lo + 2 * (PR + 2), axis=0)
    if col_halo == 0:
        cols = me_halo.shape[1]
        if cols % 2:
            raise ValueError("M2L slab columns must span the full (even) width")
        sub = jnp.pad(sub, ((0, 0), (2, 2), (0, 0)))
        PC, cshift = cols // 2, 0
    else:
        cols = me_halo.shape[1] - 2 * col_halo
        clo, PC, cshift = m2l_slab_geometry(cols, col0, col_halo)
        sub = jax.lax.slice_in_dim(sub, clo, clo + 2 * (PC + 2), axis=1)
    return to_parent_planes(sub, p), (PR, rshift), (PC, cshift)


def m2l_folded(me_halo: jnp.ndarray, level: int, p: int, row0: int = 0,
               halo: int = M2L_HALO, col0: int = 0,
               col_halo: int = 0, op: np.ndarray | None = None,
               scale: float | None = None) -> jnp.ndarray:
    """Parity-folded M2L over a slab/tile with ghost data attached.

    ``me_halo``: (rows + 2*halo, cols + 2*col_halo, p) — the interior plus
    ``halo`` ghost rows above and below and ``col_halo`` ghost columns left
    and right (zeros at domain edges, exchanged halos under ``shard_map``).
    With ``col_halo=0`` columns span the full grid width (even) and the
    column window is zero-padded internally.  ``row0``/``col0`` are the
    global indices of the first interior row/column and anchor the parity
    pattern; any alignment is supported given enough halo.  Returns the
    (rows, cols, p) LE slab.

    This is the single M2L implementation behind the serial driver, the
    sharded driver (1-D bands and 2-D tiles), and the jnp reference; the
    Pallas kernel (kernels/m2l.py) computes the same contraction tile by
    tile.  ``op``/``scale`` override the folded block operator and the
    dimension scalar (equation specs supply theirs — core/equations.py);
    the defaults are the velocity kernel's.
    """
    rows = me_halo.shape[0] - 2 * halo
    cols = me_halo.shape[1] - 2 * col_halo
    stack, (PR, rshift), (PC, cshift) = m2l_slab_stack(me_halo, p, row0, halo,
                                                       col0, col_halo)
    W = m2l_folded_operator(p) if op is None else op
    if scale is None:
        scale = float(2.0 ** level)          # 1 / box_size(level), exact
    acc = jnp.zeros((PR, PC, 4 * p), dtype=me_halo.dtype)
    for d, (Dx, Dy) in enumerate(PARENT_NEIGH8):
        src = stack[1 + Dy:1 + Dy + PR, 1 + Dx:1 + Dx + PC, :]
        acc = acc + jnp.einsum("yxa,ab->yxb", src,
                               jnp.asarray(W[d], dtype=me_halo.dtype))
    le = from_parent_planes(acc, p)                        # (2PR, 2PC, p)
    le = jax.lax.slice_in_dim(le, rshift, rshift + rows, axis=0)
    le = jax.lax.slice_in_dim(le, cshift, cshift + cols, axis=1)
    return le * scale


def m2l_reference(me: jnp.ndarray, level: int, p: int) -> jnp.ndarray:
    """Dense M2L over a full (n, n, p) grid — parity-folded jnp path."""
    me_halo = jnp.pad(me, ((M2L_HALO, M2L_HALO), (0, 0), (0, 0)))
    return m2l_folded(me_halo, level, p, row0=0, halo=M2L_HALO)


def l2l(le_parent: jnp.ndarray, p: int) -> jnp.ndarray:
    """Parent grid (ny, nx, p) -> child grid (2ny, 2nx, p)."""
    op = jnp.asarray(l2l_operator(p), dtype=le_parent.dtype)
    ny, nx = le_parent.shape[0], le_parent.shape[1]
    c = jnp.einsum("yxl,cml->yxcm", le_parent, op)  # (ny, nx, 4, m)
    c = c.reshape(ny, nx, 2, 2, p).transpose(0, 2, 1, 3, 4)
    return c.reshape(2 * ny, 2 * nx, p)


def l2p(le: jnp.ndarray, z: jnp.ndarray, centers: jnp.ndarray, r: float,
        p: int) -> jnp.ndarray:
    """Evaluate leaf LEs at particle positions -> complex W, (n, n, s)."""
    zhat = (z - centers[..., None]) / r
    pw = _powers(zhat, p)                          # (n, n, s, p)
    return jnp.einsum("yxl,yxsl->yxs", le, pw)


def l2p_eval(le: jnp.ndarray, z: jnp.ndarray, centers: jnp.ndarray, r: float,
             p: int, modes: tuple[str, ...] = ("value",)) -> jnp.ndarray:
    """Evaluate leaf LEs at (source or target) positions, per channel.

    ``modes`` is the equation spec's ``l2p_modes``; each entry emits one
    complex channel: ``"value"`` is the LE polynomial itself (the velocity
    for the vortex kernel, the complex potential for Laplace) and
    ``"ngrad"`` its negated z-derivative ``-(1/r) sum_l l bhat_l
    zhat^(l-1)`` (the Laplace field).  Returns (n, n, s) for one mode,
    (n, n, s, len(modes)) otherwise; single-mode output is bit-identical
    to :func:`l2p`.
    """
    zhat = (z - centers[..., None]) / r
    pw = _powers(zhat, p)                          # (n, n, s, p)
    outs = []
    for mode in modes:
        if mode == "value":
            outs.append(jnp.einsum("yxl,yxsl->yxs", le, pw))
        elif mode == "ngrad":
            lw = jnp.arange(1, p, dtype=le.real.dtype)
            outs.append(-jnp.einsum("yxl,yxsl->yxs", le[..., 1:] * lw,
                                    pw[..., :p - 1]) / r)
        else:
            raise ValueError(f"unknown l2p mode {mode!r}")
    return outs[0] if len(outs) == 1 else jnp.stack(outs, axis=-1)


# -- Expansion evaluation helpers (unit tests / debugging) ------------------


def eval_me(ahat: np.ndarray, center: complex, r: float, z: np.ndarray) -> np.ndarray:
    """Evaluate a normalized ME at points z (far from the box)."""
    zh = (np.asarray(z) - center) / r
    out = np.zeros_like(zh, dtype=np.complex128)
    for k in range(len(ahat) - 1, -1, -1):
        out = (out + ahat[k]) / zh
    return out / r


def eval_le(bhat: np.ndarray, center: complex, r: float, z: np.ndarray) -> np.ndarray:
    """Evaluate a normalized LE at points z (inside the box)."""
    zh = (np.asarray(z) - center) / r
    out = np.zeros_like(zh, dtype=np.complex128)
    for l in range(len(bhat) - 1, -1, -1):
        out = out * zh + bhat[l]
    return out
