"""Deterministic fault injection for guarded execution (DESIGN.md §11).

A :class:`FaultSpec` names one fault SITE, keyed by the 1-based step index
at which it fires; a :class:`FaultInjector` holds a set of specs and is the
only object drivers ever see.  Specs are frozen/hashable, so the active
specs for a step ride into ``rk2_step`` / ``parallel_fmm_evaluate`` as a
STATIC jit argument: a step with no active fault passes the empty tuple and
traces the exact program an injector-free run traces — injection is
zero-cost when disabled (pinned by an HLO-equality test) and each injected
step compiles its own program once.

Sites (where each one lands):

  halo_nan      NaN written into the received ghost strip of the packed P2P
                halo exchange on one device (sharded driver only; the jnp
                reference route has no exchange).  ``only_grid`` restricts
                the site to a specific plan grid, so a plan-fallback rung
                can escape it.
  tile_corrupt  one device's output tile multiplied into non-finite after
                the masked evaluation (sharded driver only).
  teleport      the slot-0 live particle of every occupied leaf box shifted
                by ``magnitude`` (PHYSICAL units — the stepper rescales by
                its domain size, so root-box expansion can cure a sticky
                teleport whose magnitude fits the grown domain) after the
                first half-kick (both drivers).
  overflow      every live particle clumped into one leaf box after the
                first half-kick, overflowing its slot capacity (both
                drivers).
  time_inflate  one step's measured wall-clock sample multiplied by
                ``magnitude`` (host side; exercises the outlier filter on
                the measured-feedback loop, never the device program).
  proc_kill     SIGKILL rank ``device`` once its heartbeat reaches step
                ``step`` (supervisor level — the spec never enters a jit;
                the kill-drill supervisor of ``launch/supervisor.py`` is
                the executor).  Drills the dead-process shrink path.
  proc_hang     SIGSTOP the same way: the process stays alive but its
                heartbeat goes stale, drilling the hung-not-dead
                watchdog path (DESIGN.md §14).

Non-sticky specs fire only on attempt 0 of their step — the model of a
transient fault, recovered by the ladder's plain retry.  ``sticky=True``
fires on every attempt, forcing escalation down the ladder (and, when no
rung can dodge the site, the typed ``StepperFaultError``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

DEVICE_SITES = ("halo_nan", "tile_corrupt")
STEP_SITES = ("teleport", "overflow")
HOST_SITES = ("time_inflate",)
PROC_SITES = ("proc_kill", "proc_hang")
SITES = DEVICE_SITES + STEP_SITES + HOST_SITES + PROC_SITES


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    site: str
    step: int                 # 1-based step index at which to fire
    device: int = 0           # target device (device sites)
    sticky: bool = False      # fire on every attempt, not just the first
    magnitude: float = 2.0    # teleport offset / time inflation factor
    only_grid: Optional[tuple[int, int]] = None  # restrict halo_nan to a grid

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"one of {SITES}")

    @property
    def rank(self) -> int:
        """Target rank of a process-granularity site (alias of ``device``:
        one spec vocabulary covers both granularities)."""
        return self.device


class FaultInjector:
    """Holds the configured faults; drivers query the active subset."""

    def __init__(self, *specs: FaultSpec):
        self.specs = tuple(specs)

    def active(self, step: int, attempt: int = 0) -> tuple[FaultSpec, ...]:
        """Device-program faults firing at (step, attempt) — the static
        tuple threaded into the jitted step.  Host- and process-level
        sites never enter a jit."""
        return tuple(f for f in self.specs
                     if f.step == step and f.site in DEVICE_SITES + STEP_SITES
                     and (f.sticky or attempt == 0))

    def proc_faults(self) -> tuple[FaultSpec, ...]:
        """Process-granularity specs, executed by the kill-drill
        supervisor (never by the drivers)."""
        return tuple(f for f in self.specs if f.site in PROC_SITES)

    def time_factor(self, step: int) -> float:
        """Host-side measured-time inflation factor for this step."""
        factor = 1.0
        for f in self.specs:
            if f.step == step and f.site == "time_inflate":
                factor *= f.magnitude
        return factor


# -- device-side application (called from inside the jitted drivers) --------


def corrupt_halo(buf: jnp.ndarray, faults: tuple[FaultSpec, ...],
                 device_index, grid: tuple[int, int]) -> jnp.ndarray:
    """Apply active ``halo_nan`` specs to an exchanged halo buffer.

    ``device_index`` is the traced ``lax.axis_index``; the first ghost row
    of the buffer is multiplied by NaN on the target device (NaN * x = NaN,
    including the zero domain-edge padding)."""
    for f in faults:
        if f.site != "halo_nan":
            continue
        if f.only_grid is not None and tuple(f.only_grid) != tuple(grid):
            continue
        scale = jnp.where(device_index == f.device, jnp.nan, 1.0)
        buf = buf.at[0].mul(scale.astype(buf.dtype))
    return buf


def corrupt_tile(out: jnp.ndarray, faults: tuple[FaultSpec, ...],
                 device_index) -> jnp.ndarray:
    """Apply active ``tile_corrupt`` specs to one device's output tile."""
    for f in faults:
        if f.site == "tile_corrupt":
            bad = jnp.where(device_index == f.device, jnp.inf, 0.0)
            out = out + bad.astype(out.real.dtype)
    return out


def corrupt_positions(z: jnp.ndarray, mask: jnp.ndarray,
                      faults: tuple[FaultSpec, ...]) -> jnp.ndarray:
    """Apply active ``teleport`` / ``overflow`` specs to mid-step positions
    (acts on the global (n, n, s) position grid inside ``rk2_step``)."""
    for f in faults:
        if f.site == "teleport":
            shift = jnp.asarray(f.magnitude * (1.0 + 1.0j), z.dtype)
            # slot 0 of every occupied box: nonempty wherever particles are
            sel = jnp.zeros_like(mask).at[..., 0].set(mask[..., 0])
            z = jnp.where(sel, z + shift, z)
        elif f.site == "overflow":
            z = jnp.where(mask, jnp.asarray(0.5 + 0.5j, z.dtype), z)
    return z
