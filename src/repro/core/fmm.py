"""Serial dense FMM driver (pure JAX, jit-able end to end).

Mirrors the paper's bird's-eye view (Fig 2): upward sweep (P2M, M2M),
downward sweep (M2L, L2L), evaluation (L2P + near-field P2P).  All stages
operate on dense level grids; see DESIGN.md §3 for the TPU-native layout.

The M2L and P2P hot paths go through ONE slab-oriented implementation each
(``m2l_slab_fn`` / ``p2p_slab_fn``): the serial driver attaches zero ghost
rows, the ``shard_map`` driver (core/parallel_fmm.py) attaches exchanged
halos — same math, same kernels, same parity-folded operators either way
(DESIGN.md §4-§5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import expansions as ex
from .quadtree import P2P_OFFSETS, Tree, box_centers, box_size


# ---------------------------------------------------------------------------
# Unified slab dispatchers — the one M2L / P2P path for both drivers.
# ---------------------------------------------------------------------------


def m2l_slab_fn(p: int, use_kernels: bool = False):
    """Returns ``fn(me_halo, level, row0=0, halo=M2L_HALO, col0=0,
    col_halo=0) -> le_slab``.

    ``me_halo`` carries ``halo`` ghost rows top and bottom — and, when
    ``col_halo > 0``, ghost columns left and right (2-D tiles) — zeros at
    domain edges, exchanged halos under ``shard_map``; ``row0``/``col0``
    anchor the global parity.  Both the jnp path and the Pallas kernel path
    implement the same parity-folded contraction (exactly 27 interactions
    per box).
    """
    if use_kernels:
        from ..kernels import ops as kops

        def fn(me_halo, level, row0=0, halo=ex.M2L_HALO, col0=0, col_halo=0):
            return kops.m2l_apply_slab(me_halo, level, p, row0=row0,
                                       halo=halo, col0=col0,
                                       col_halo=col_halo)
        return fn

    def fn(me_halo, level, row0=0, halo=ex.M2L_HALO, col0=0, col_halo=0):
        return ex.m2l_folded(me_halo, level, p, row0=row0, halo=halo,
                             col0=col0, col_halo=col_halo)
    return fn


def m2l_grid_fn(p: int, use_kernels: bool = False):
    """Grid form of ``m2l_slab_fn``: ``fn(grid, level)`` over a full
    (ny, nx, p) level grid, zero ghost rows attached here.  Used by the
    serial driver and for the replicated root-tree levels of the sharded
    driver."""
    slab = m2l_slab_fn(p, use_kernels)
    hpad = ((ex.M2L_HALO, ex.M2L_HALO), (0, 0), (0, 0))

    def fn(grid, level):
        return slab(jnp.pad(grid, hpad), level)
    return fn


def p2p_slab_reference(z_halo, q_halo, mask_halo, sigma):
    """Pure-jnp P2P over a slab with ±1 ghost rows/cols attached."""
    from .vortex import pairwise_w

    rows, cols = z_halo.shape[0] - 2, z_halo.shape[1] - 2
    z = z_halo[1:1 + rows, 1:1 + cols]
    w = jnp.zeros_like(z)
    for (dx, dy) in P2P_OFFSETS:
        zs = z_halo[1 + dy:1 + dy + rows, 1 + dx:1 + dx + cols]
        qs = q_halo[1 + dy:1 + dy + rows, 1 + dx:1 + dx + cols]
        ms = mask_halo[1 + dy:1 + dy + rows, 1 + dx:1 + dx + cols]
        w = w + pairwise_w(z, zs, qs, ms, sigma)
    return w


def p2p_slab_fn(use_kernels: bool = False):
    """Returns ``fn(z_halo, q_halo, mask_halo, sigma) -> w`` over a slab
    with ±1 ghost rows/cols already attached."""
    if use_kernels:
        from ..kernels import ops as kops

        return kops.p2p_apply_slab
    return p2p_slab_reference


# ---------------------------------------------------------------------------
# Interior/rim overlapped tile execution (DESIGN.md §9).
#
# A padded device tile is split into an INTERIOR — every box at least one
# halo width from each tile edge, whose stencil reads only local data — and
# four RIM strips along the edges, whose stencils read the exchanged ghost
# buffer.  The interior compute has no data dependency on the halo
# collectives, so the scheduler can hide the exchange behind it; the rim
# strips are computed from the buffer afterwards and stitched over the
# edges.  The serial driver is the degenerate zero-rim case of the same
# slab contract (no ghosts, interior == everything: ``m2l_grid_fn`` /
# ``near_field`` attach zero halos and run one monolithic slab), so there
# is still exactly one M2L / P2P formulation.
# ---------------------------------------------------------------------------


def m2l_tile_overlapped(m2l_slab, me_local: jnp.ndarray, me_buf: jnp.ndarray,
                        level: int, rows_valid, cols_valid) -> jnp.ndarray:
    """Interior/rim M2L over one padded tile.

    ``me_local`` is the (rmax, cmax, p) padded tile (padding rows/cols are
    zero); ``me_buf`` is the (rmax+2w, cmax+2w, p) two-axis halo buffer
    from ``_tile_halo`` (w = ``expansions.M2L_HALO``), with neighbors' data
    adjacent to the *valid* extents ``rows_valid``/``cols_valid`` (traced
    per-device scalars; tile origins and valid extents are parity-even at
    every sharded level, so ``row0=col0=0`` anchors every slice).  Returns
    the (rmax, cmax, p) LE tile; boxes outside the valid extents carry
    don't-care values exactly as in the monolithic path (masked out
    downstream).
    """
    w = ex.M2L_HALO
    rmax, cmax, p = me_local.shape
    le = jnp.zeros_like(me_local)
    if rmax > 2 * w and cmax > 2 * w:
        # interior: depends only on me_local -> overlappable with the
        # collectives filling me_buf
        interior = m2l_slab(me_local, level, halo=w, col_halo=w)
        le = jax.lax.dynamic_update_slice(le, interior, (w, w, 0))
    # rim strips: each strip's own w-halo is cut out of the exchanged
    # buffer (strip anchors stay parity-even, so row0=col0=0 holds)
    top = m2l_slab(jax.lax.slice_in_dim(me_buf, 0, 3 * w, axis=0),
                   level, halo=w, col_halo=w)                    # (w, cmax)
    bot = m2l_slab(jax.lax.dynamic_slice(
        me_buf, (rows_valid - w, 0, 0), (3 * w, cmax + 2 * w, p)),
        level, halo=w, col_halo=w)                               # (w, cmax)
    left = m2l_slab(jax.lax.slice_in_dim(me_buf, 0, 3 * w, axis=1),
                    level, halo=w, col_halo=w)                   # (rmax, w)
    right = m2l_slab(jax.lax.dynamic_slice(
        me_buf, (0, cols_valid - w, 0), (rmax + 2 * w, 3 * w, p)),
        level, halo=w, col_halo=w)                               # (rmax, w)
    le = jax.lax.dynamic_update_slice(le, left, (0, 0, 0))
    le = jax.lax.dynamic_update_slice(le, right, (0, cols_valid - w, 0))
    le = jax.lax.dynamic_update_slice(le, top, (0, 0, 0))
    le = jax.lax.dynamic_update_slice(le, bot, (rows_valid - w, 0, 0))
    return le


def p2p_tile_overlapped(p2p_slab, z, q, mask, z_buf, q_buf, m_buf,
                        rows_valid, cols_valid, sigma) -> jnp.ndarray:
    """Interior/rim P2P over one padded tile (halo width 1).

    ``z/q/mask`` are the (rmax, cmax, s) local tile; ``*_buf`` the
    (rmax+2, cmax+2, s) exchanged particle buffers (one packed collective —
    see ``parallel_fmm``).  The interior pass reads the local tile as its
    own ±1 halo (the overlap-independent bulk: P2P dominates FMM runtime),
    the four rim strips read the buffer, and the strips are stitched over
    the edges.  Returns the (rmax, cmax, s) W tile.
    """
    rmax, cmax, s = z.shape
    wout = jnp.zeros(z.shape, z.dtype)
    if rmax > 2 and cmax > 2:
        interior = p2p_slab(z, q, mask, sigma)      # (rmax-2, cmax-2, s)
        wout = jax.lax.dynamic_update_slice(wout, interior, (1, 1, 0))

    def row_strip(r0):
        sl = lambda a: jax.lax.dynamic_slice(a, (r0, 0, 0), (3, cmax + 2, s))
        return p2p_slab(sl(z_buf), sl(q_buf), sl(m_buf), sigma)  # (1, cmax)

    def col_strip(c0):
        sl = lambda a: jax.lax.dynamic_slice(a, (0, c0, 0), (rmax + 2, 3, s))
        return p2p_slab(sl(z_buf), sl(q_buf), sl(m_buf), sigma)  # (rmax, 1)

    wout = jax.lax.dynamic_update_slice(wout, col_strip(0), (0, 0, 0))
    wout = jax.lax.dynamic_update_slice(wout, col_strip(cols_valid - 1),
                                        (0, cols_valid - 1, 0))
    wout = jax.lax.dynamic_update_slice(wout, row_strip(0), (0, 0, 0))
    wout = jax.lax.dynamic_update_slice(wout, row_strip(rows_valid - 1),
                                        (rows_valid - 1, 0, 0))
    return wout


def upward_sweep(tree: Tree, p: int) -> list[jnp.ndarray]:
    """Build normalized MEs for every level; returns me[l] for l=0..L."""
    L = tree.level
    centers = jnp.asarray(box_centers(L), dtype=tree.z.dtype)
    me = [None] * (L + 1)
    me[L] = ex.p2m(tree.z, tree.q, tree.mask, centers, box_size(L), p)
    for l in range(L, 0, -1):
        me[l - 1] = ex.m2m(me[l], p)
    return me


def downward_sweep(me: list[jnp.ndarray], p: int,
                   m2l_fn=None) -> list[jnp.ndarray]:
    """Build LEs for levels 2..L (levels 0-1 have empty interaction lists)."""
    L = len(me) - 1
    m2l = m2l_fn or (lambda grid, level: ex.m2l_reference(grid, level, p))
    le = [None] * (L + 1)
    for l in range(2, L + 1):
        le[l] = m2l(me[l], l)
        if l > 2:
            le[l] = le[l] + ex.l2l(le[l - 1], p)
    return le


def near_field(tree: Tree, p2p_fn=None) -> jnp.ndarray:
    """P2P over the 3x3 stencil with the regularized kernel. -> (n,n,s) W."""
    slab = p2p_fn or p2p_slab_fn(use_kernels=False)
    pad = ((1, 1), (1, 1), (0, 0))
    return slab(jnp.pad(tree.z, pad), jnp.pad(tree.q, pad),
                jnp.pad(tree.mask, pad), tree.sigma)


@functools.partial(jax.jit, static_argnames=("p", "use_kernels"))
def fmm_velocity(tree: Tree, p: int, use_kernels: bool = False) -> jnp.ndarray:
    """Complete FMM evaluation: complex velocity W = u - iv per slot.

    ``use_kernels=True`` routes M2L and P2P through the Pallas kernels
    (interpret mode on CPU); otherwise the pure-jnp reference path runs.
    Both routes share the parity-folded slab implementations above.
    """
    L = tree.level
    p2p = p2p_slab_fn(use_kernels)
    if L < 2:
        # Tiny trees are all near field.
        return near_field(tree, p2p_fn=p2p)
    m2l_fn = m2l_grid_fn(p, use_kernels)

    me = upward_sweep(tree, p)
    le = downward_sweep(me, p, m2l_fn=m2l_fn)
    centers = jnp.asarray(box_centers(L), dtype=tree.z.dtype)
    far = ex.l2p(le[L], tree.z, centers, box_size(L), p)
    near = near_field(tree, p2p_fn=p2p)
    w = far + near
    return jnp.where(tree.mask, w, 0.0)


def fmm_velocity_singular(tree: Tree, p: int) -> jnp.ndarray:
    """FMM with the singular kernel also in the near field.

    Isolates pure series-truncation error: comparing against a singular
    direct sum measures the p-convergence of the expansions alone
    (no Type-I kernel-substitution error; cf. paper §7.1 and ref [8]).
    """
    sing = Tree(z=tree.z, q=tree.q, mask=tree.mask, level=tree.level, sigma=None)
    return fmm_velocity(sing, p)


def flops_estimate(tree_level: int, slots: int, p: int) -> dict:
    """Rough FLOP census per stage (used by benchmarks & cost-model checks).

    The M2L term counts 27 (p x p) apply-accumulates per box — and since
    the parity-folded implementation (expansions.m2l_folded) performs
    exactly the 27 valid interactions (structural zero blocks, no runtime
    masks), this is the work the hot path actually does, not just the
    useful fraction of a 40-offset masked sweep.  Consistency with
    cost_model.N_IL and the folded operator's block sparsity is asserted in
    tests/test_cost_model.py.
    """
    L, s = tree_level, slots
    nleaf = 4 ** L
    cmul = 6.0  # complex multiply-add ~ 6 real flops
    stages = {
        "p2m": nleaf * s * p * 2 * cmul,
        "m2m": sum(4 ** l for l in range(1, L + 1)) * p * p * cmul,
        "m2l": sum(4 ** l for l in range(2, L + 1)) * 27 * p * p * cmul,
        "l2l": sum(4 ** l for l in range(3, L + 1)) * p * p * cmul,
        "l2p": nleaf * s * p * 2 * cmul,
        "p2p": nleaf * 9 * s * s * 12.0,
    }
    stages["total"] = sum(stages.values())
    return stages
