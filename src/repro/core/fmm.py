"""Serial dense FMM driver (pure JAX, jit-able end to end).

Mirrors the paper's bird's-eye view (Fig 2): upward sweep (P2M, M2M),
downward sweep (M2L, L2L), evaluation (L2P + near-field P2P).  All stages
operate on dense level grids; see DESIGN.md §3 for the TPU-native layout.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import expansions as ex
from .quadtree import P2P_OFFSETS, Tree, box_centers, box_size


def upward_sweep(tree: Tree, p: int) -> list[jnp.ndarray]:
    """Build normalized MEs for every level; returns me[l] for l=0..L."""
    L = tree.level
    centers = jnp.asarray(box_centers(L), dtype=tree.z.dtype)
    me = [None] * (L + 1)
    me[L] = ex.p2m(tree.z, tree.q, tree.mask, centers, box_size(L), p)
    for l in range(L, 0, -1):
        me[l - 1] = ex.m2m(me[l], p)
    return me


def downward_sweep(me: list[jnp.ndarray], p: int,
                   m2l_fn=None) -> list[jnp.ndarray]:
    """Build LEs for levels 2..L (levels 0-1 have empty interaction lists)."""
    L = len(me) - 1
    m2l = m2l_fn or (lambda grid, level: ex.m2l_reference(grid, level, p))
    le = [None] * (L + 1)
    for l in range(2, L + 1):
        le[l] = m2l(me[l], l)
        if l > 2:
            le[l] = le[l] + ex.l2l(le[l - 1], p)
    return le


def near_field(tree: Tree, p2p_fn=None) -> jnp.ndarray:
    """P2P over the 3x3 stencil with the regularized kernel. -> (n,n,s) W."""
    if p2p_fn is not None:
        return p2p_fn(tree)
    from .vortex import pairwise_w

    n, s = tree.nside, tree.slots
    zp = jnp.pad(tree.z, ((1, 1), (1, 1), (0, 0)))
    qp = jnp.pad(tree.q, ((1, 1), (1, 1), (0, 0)))
    mp = jnp.pad(tree.mask, ((1, 1), (1, 1), (0, 0)))
    w = jnp.zeros_like(tree.z)
    for (dx, dy) in P2P_OFFSETS:
        zs = zp[1 + dy:1 + dy + n, 1 + dx:1 + dx + n]
        qs = qp[1 + dy:1 + dy + n, 1 + dx:1 + dx + n]
        ms = mp[1 + dy:1 + dy + n, 1 + dx:1 + dx + n]
        w = w + pairwise_w(tree.z, zs, qs, ms, tree.sigma)
    return w


@functools.partial(jax.jit, static_argnames=("p", "use_kernels"))
def fmm_velocity(tree: Tree, p: int, use_kernels: bool = False) -> jnp.ndarray:
    """Complete FMM evaluation: complex velocity W = u - iv per slot.

    ``use_kernels=True`` routes M2L and P2P through the Pallas kernels
    (interpret mode on CPU); otherwise the pure-jnp reference path runs.
    """
    L = tree.level
    if L < 2:
        # Tiny trees are all near field.
        return near_field(tree)
    m2l_fn = p2p_fn = None
    if use_kernels:
        from ..kernels import ops as kops

        m2l_fn = lambda grid, level: kops.m2l_apply(grid, level, p)  # noqa: E731
        p2p_fn = kops.p2p_apply

    me = upward_sweep(tree, p)
    le = downward_sweep(me, p, m2l_fn=m2l_fn)
    centers = jnp.asarray(box_centers(L), dtype=tree.z.dtype)
    far = ex.l2p(le[L], tree.z, centers, box_size(L), p)
    near = near_field(tree, p2p_fn=p2p_fn)
    w = far + near
    return jnp.where(tree.mask, w, 0.0)


def fmm_velocity_singular(tree: Tree, p: int) -> jnp.ndarray:
    """FMM with the singular kernel also in the near field.

    Isolates pure series-truncation error: comparing against a singular
    direct sum measures the p-convergence of the expansions alone
    (no Type-I kernel-substitution error; cf. paper §7.1 and ref [8]).
    """
    sing = Tree(z=tree.z, q=tree.q, mask=tree.mask, level=tree.level, sigma=None)
    return fmm_velocity(sing, p)


def flops_estimate(tree_level: int, slots: int, p: int) -> dict:
    """Rough FLOP census per stage (used by benchmarks & cost-model checks)."""
    L, s = tree_level, slots
    nleaf = 4 ** L
    cmul = 6.0  # complex multiply-add ~ 6 real flops
    stages = {
        "p2m": nleaf * s * p * 2 * cmul,
        "m2m": sum(4 ** l for l in range(1, L + 1)) * p * p * cmul,
        "m2l": sum(4 ** l for l in range(2, L + 1)) * 27 * p * p * cmul,
        "l2l": sum(4 ** l for l in range(3, L + 1)) * p * p * cmul,
        "l2p": nleaf * s * p * 2 * cmul,
        "p2p": nleaf * 9 * s * s * 12.0,
    }
    stages["total"] = sum(stages.values())
    return stages
