"""Serial dense FMM driver (pure JAX, jit-able end to end).

Mirrors the paper's bird's-eye view (Fig 2): upward sweep (P2M, M2M),
downward sweep (M2L, L2L), evaluation (L2P + near-field P2P).  All stages
operate on dense level grids; see DESIGN.md §3 for the TPU-native layout.

The M2L and P2P hot paths go through ONE slab-oriented implementation each
(``m2l_slab_fn`` / ``p2p_slab_fn``): the serial driver attaches zero ghost
rows, the ``shard_map`` driver (core/parallel_fmm.py) attaches exchanged
halos — same math, same kernels, same parity-folded operators either way
(DESIGN.md §4-§5).

Every kernel-specific piece — P2M charge map, translation operators, M2L
dimension scalar, L2P evaluation modes, the P2P pair interaction, output
arity — comes from an :class:`~repro.core.equations.EquationSpec`
(DESIGN.md §10).  The drivers consume only the spec: there are no
equation-name branches here (grep-guarded in tests/test_equations.py).
``fmm_velocity`` is the vortex-kernel wrapper over the generic
``fmm_evaluate``; passing ``targets`` evaluates the sources' field at a
separate batch of passive target points (the ``tracer`` mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import equations as eqs
from . import expansions as ex
from . import health as hw
from .quadtree import P2P_OFFSETS, Tree, box_centers, box_size


# ---------------------------------------------------------------------------
# Unified slab dispatchers — the one M2L / P2P path for both drivers.
# ---------------------------------------------------------------------------


def m2l_slab_fn(p: int, use_kernels: bool = False, eq=None):
    """Returns ``fn(me_halo, level, row0=0, halo=M2L_HALO, col0=0,
    col_halo=0) -> le_slab``.

    ``me_halo`` carries ``halo`` ghost rows top and bottom — and, when
    ``col_halo > 0``, ghost columns left and right (2-D tiles) — zeros at
    domain edges, exchanged halos under ``shard_map``; ``row0``/``col0``
    anchor the global parity.  Both the jnp path and the Pallas kernel path
    implement the same parity-folded contraction (exactly 27 interactions
    per box), with the block operator and dimension scalar supplied by the
    equation spec (vortex by default).
    """
    eq = eqs.get_equation(eq)
    if use_kernels:
        from ..kernels import ops as kops

        def fn(me_halo, level, row0=0, halo=ex.M2L_HALO, col0=0, col_halo=0):
            return kops.m2l_apply_slab(me_halo, level, p, row0=row0,
                                       halo=halo, col0=col0,
                                       col_halo=col_halo, eq=eq)
        return fn

    def fn(me_halo, level, row0=0, halo=ex.M2L_HALO, col0=0, col_halo=0):
        return ex.m2l_folded(me_halo, level, p, row0=row0, halo=halo,
                             col0=col0, col_halo=col_halo,
                             op=eq.m2l_folded(p, level),
                             scale=eq.m2l_scale(level))
    return fn


def m2l_grid_fn(p: int, use_kernels: bool = False, eq=None):
    """Grid form of ``m2l_slab_fn``: ``fn(grid, level)`` over a full
    (ny, nx, p) level grid, zero ghost rows attached here.  Used by the
    serial driver and for the replicated root-tree levels of the sharded
    driver."""
    slab = m2l_slab_fn(p, use_kernels, eq)
    hpad = ((ex.M2L_HALO, ex.M2L_HALO), (0, 0), (0, 0))

    def fn(grid, level):
        return slab(jnp.pad(grid, hpad), level)
    return fn


def p2p_slab_reference(z_halo, q_halo, mask_halo, sigma, z_tgt=None, eq=None):
    """Pure-jnp P2P over a slab with ±1 ghost rows/cols attached.

    ``z_tgt`` (rows, cols, st) evaluates the sources' field at separate
    target points instead of at the sources themselves (passive-target
    mode); None keeps source == target.  The pair interaction is the
    equation spec's ``p2p_terms`` — one formula shared with the Pallas
    kernel and the direct oracle.
    """
    eq = eqs.get_equation(eq)
    rows, cols = z_halo.shape[0] - 2, z_halo.shape[1] - 2
    zt = z_halo[1:1 + rows, 1:1 + cols] if z_tgt is None else z_tgt
    out = None
    for (dx, dy) in P2P_OFFSETS:
        zs = z_halo[1 + dy:1 + dy + rows, 1 + dx:1 + dx + cols]
        qs = q_halo[1 + dy:1 + dy + rows, 1 + dx:1 + dx + cols]
        ms = mask_halo[1 + dy:1 + dy + rows, 1 + dx:1 + dx + cols]
        w = eq.pairwise(zt, zs, qs, ms, sigma)
        out = w if out is None else out + w
    return out


def p2p_slab_fn(use_kernels: bool = False, eq=None):
    """Returns ``fn(z_halo, q_halo, mask_halo, sigma, z_tgt=None) -> w``
    over a slab with ±1 ghost rows/cols already attached; ``z_tgt`` selects
    passive-target evaluation (see ``p2p_slab_reference``)."""
    eq = eqs.get_equation(eq)
    if use_kernels:
        from ..kernels import ops as kops

        def fn(z_halo, q_halo, mask_halo, sigma, z_tgt=None):
            return kops.p2p_apply_slab(z_halo, q_halo, mask_halo, sigma,
                                       z_tgt=z_tgt, eq=eq)
        return fn

    def fn(z_halo, q_halo, mask_halo, sigma, z_tgt=None):
        return p2p_slab_reference(z_halo, q_halo, mask_halo, sigma,
                                  z_tgt=z_tgt, eq=eq)
    return fn


# ---------------------------------------------------------------------------
# Interior/rim overlapped tile execution (DESIGN.md §9).
#
# A padded device tile is split into an INTERIOR — every box at least one
# halo width from each tile edge, whose stencil reads only local data — and
# four RIM strips along the edges, whose stencils read the exchanged ghost
# buffer.  The interior compute has no data dependency on the halo
# collectives, so the scheduler can hide the exchange behind it; the rim
# strips are computed from the buffer afterwards and stitched over the
# edges.  The serial driver is the degenerate zero-rim case of the same
# slab contract (no ghosts, interior == everything: ``m2l_grid_fn`` /
# ``near_field`` attach zero halos and run one monolithic slab), so there
# is still exactly one M2L / P2P formulation.
# ---------------------------------------------------------------------------


def m2l_tile_overlapped(m2l_slab, me_local: jnp.ndarray, me_buf: jnp.ndarray,
                        level: int, rows_valid, cols_valid) -> jnp.ndarray:
    """Interior/rim M2L over one padded tile.

    ``me_local`` is the (rmax, cmax, p) padded tile (padding rows/cols are
    zero); ``me_buf`` is the (rmax+2w, cmax+2w, p) two-axis halo buffer
    from ``_tile_halo`` (w = ``expansions.M2L_HALO``), with neighbors' data
    adjacent to the *valid* extents ``rows_valid``/``cols_valid`` (traced
    per-device scalars; tile origins and valid extents are parity-even at
    every sharded level, so ``row0=col0=0`` anchors every slice).  Returns
    the (rmax, cmax, p) LE tile; boxes outside the valid extents carry
    don't-care values exactly as in the monolithic path (masked out
    downstream).
    """
    w = ex.M2L_HALO
    rmax, cmax, p = me_local.shape
    le = jnp.zeros_like(me_local)
    if rmax > 2 * w and cmax > 2 * w:
        # interior: depends only on me_local -> overlappable with the
        # collectives filling me_buf
        interior = m2l_slab(me_local, level, halo=w, col_halo=w)
        le = jax.lax.dynamic_update_slice(le, interior, (w, w, 0))
    # rim strips: each strip's own w-halo is cut out of the exchanged
    # buffer (strip anchors stay parity-even, so row0=col0=0 holds)
    top = m2l_slab(jax.lax.slice_in_dim(me_buf, 0, 3 * w, axis=0),
                   level, halo=w, col_halo=w)                    # (w, cmax)
    bot = m2l_slab(jax.lax.dynamic_slice(
        me_buf, (rows_valid - w, 0, 0), (3 * w, cmax + 2 * w, p)),
        level, halo=w, col_halo=w)                               # (w, cmax)
    left = m2l_slab(jax.lax.slice_in_dim(me_buf, 0, 3 * w, axis=1),
                    level, halo=w, col_halo=w)                   # (rmax, w)
    right = m2l_slab(jax.lax.dynamic_slice(
        me_buf, (0, cols_valid - w, 0), (rmax + 2 * w, 3 * w, p)),
        level, halo=w, col_halo=w)                               # (rmax, w)
    le = jax.lax.dynamic_update_slice(le, left, (0, 0, 0))
    le = jax.lax.dynamic_update_slice(le, right, (0, cols_valid - w, 0))
    le = jax.lax.dynamic_update_slice(le, top, (0, 0, 0))
    le = jax.lax.dynamic_update_slice(le, bot, (rows_valid - w, 0, 0))
    return le


def p2p_tile_overlapped(p2p_slab, z, q, mask, z_buf, q_buf, m_buf,
                        rows_valid, cols_valid, sigma,
                        z_tgt=None) -> jnp.ndarray:
    """Interior/rim P2P over one padded tile (halo width 1).

    ``z/q/mask`` are the (rmax, cmax, s) local tile; ``*_buf`` the
    (rmax+2, cmax+2, s) exchanged particle buffers (one packed collective —
    see ``parallel_fmm``).  The interior pass reads the local tile as its
    own ±1 halo (the overlap-independent bulk: P2P dominates FMM runtime),
    the four rim strips read the buffer, and the strips are stitched over
    the edges.  ``z_tgt`` (rmax, cmax, st) switches to passive-target
    evaluation: targets are tile-local (no halo of their own), so the
    interior/rim split partitions the TARGET boxes and the same stitching
    applies.  Returns the (rmax, cmax, s|st[, C]) output tile.
    """
    rmax, cmax, s = z.shape
    zt = z if z_tgt is None else z_tgt
    st = zt.shape[2]

    def tgt_block(r0, c0, nr, nc):
        if z_tgt is None:
            return None
        return jax.lax.dynamic_slice(z_tgt, (r0, c0, 0), (nr, nc, st))

    # probe one strip call to learn the static output channel shape
    def run(zh, qh, mh, tgt):
        return p2p_slab(zh, qh, mh, sigma, z_tgt=tgt)

    trail = (rmax, cmax, st)
    out_sample_shape = None
    wout = None
    if rmax > 2 and cmax > 2:
        interior = run(z, q, mask, tgt_block(1, 1, rmax - 2, cmax - 2))
        out_sample_shape = interior.shape[3:]
        wout = jnp.zeros(trail + out_sample_shape, interior.dtype)
        zi = (0,) * len(out_sample_shape)
        wout = jax.lax.dynamic_update_slice(wout, interior, (1, 1, 0) + zi)

    def row_strip(r0, tr0):
        sl = lambda a: jax.lax.dynamic_slice(a, (r0, 0, 0), (3, cmax + 2, s))
        return run(sl(z_buf), sl(q_buf), sl(m_buf),
                   tgt_block(tr0, 0, 1, cmax))                   # (1, cmax)

    def col_strip(c0, tc0):
        sl = lambda a: jax.lax.dynamic_slice(a, (0, c0, 0), (rmax + 2, 3, s))
        return run(sl(z_buf), sl(q_buf), sl(m_buf),
                   tgt_block(0, tc0, rmax, 1))                   # (rmax, 1)

    west = col_strip(0, 0)
    if wout is None:
        out_sample_shape = west.shape[3:]
        wout = jnp.zeros(trail + out_sample_shape, west.dtype)
    zi = (0,) * len(out_sample_shape)
    wout = jax.lax.dynamic_update_slice(wout, west, (0, 0, 0) + zi)
    wout = jax.lax.dynamic_update_slice(wout, col_strip(cols_valid - 1,
                                                        cols_valid - 1),
                                        (0, cols_valid - 1, 0) + zi)
    wout = jax.lax.dynamic_update_slice(wout, row_strip(0, 0), (0, 0, 0) + zi)
    wout = jax.lax.dynamic_update_slice(wout, row_strip(rows_valid - 1,
                                                        rows_valid - 1),
                                        (rows_valid - 1, 0, 0) + zi)
    return wout


def upward_sweep(tree: Tree, p: int, eq=None) -> list[jnp.ndarray]:
    """Build normalized MEs for every level; returns me[l] for l=0..L."""
    eq = eqs.get_equation(eq)
    L = tree.level
    centers = jnp.asarray(box_centers(L), dtype=tree.z.dtype)
    me = [None] * (L + 1)
    me[L] = ex.p2m(tree.z, tree.q, tree.mask, centers, box_size(L), p,
                   coeff=eq.p2m_coeff(p))
    mop = eq.m2m_operator(p)
    for l in range(L, 0, -1):
        me[l - 1] = ex.m2m(me[l], p, op=mop)
    return me


def downward_sweep(me: list[jnp.ndarray], p: int,
                   m2l_fn=None) -> list[jnp.ndarray]:
    """Build LEs for levels 2..L (levels 0-1 have empty interaction lists).

    L2L is the plain polynomial recentering of the local expansion, shared
    by every registered equation; the equation specifics live in ``m2l_fn``
    (built by ``m2l_grid_fn`` from the spec).
    """
    L = len(me) - 1
    m2l = m2l_fn or m2l_grid_fn(p)
    le = [None] * (L + 1)
    for l in range(2, L + 1):
        le[l] = m2l(me[l], l)
        if l > 2:
            le[l] = le[l] + ex.l2l(le[l - 1], p)
    return le


def near_field(tree: Tree, p2p_fn=None, z_tgt=None) -> jnp.ndarray:
    """P2P over the 3x3 stencil with the regularized kernel.

    ``z_tgt`` (n, n, st) evaluates at passive targets instead of the
    sources.  Returns (n, n, s|st[, C]).
    """
    slab = p2p_fn or p2p_slab_fn(use_kernels=False)
    pad = ((1, 1), (1, 1), (0, 0))
    return slab(jnp.pad(tree.z, pad), jnp.pad(tree.q, pad),
                jnp.pad(tree.mask, pad), tree.sigma, z_tgt)


def _mask_channels(mask, out):
    """Zero masked slots, broadcasting over trailing output channels."""
    m = mask if out.ndim == mask.ndim else mask[..., None]
    return jnp.where(m, out, 0.0)


@functools.partial(jax.jit, static_argnames=("p", "eq", "use_kernels",
                                             "with_health"))
def fmm_evaluate(tree: Tree, p: int, eq=None, use_kernels: bool = False,
                 targets: Tree | None = None, with_health: bool = False):
    """Complete FMM evaluation of any registered equation.

    Returns (n, n, s) complex for single-channel equations, or
    (n, n, s, eq.nout) with the spec's channel order (e.g. Laplace:
    potential value, field).  ``targets`` — a second :class:`Tree` at the
    same level holding passive target points (charges ignored) — switches
    to source != target evaluation: the output is per TARGET slot,
    (n, n, st[, C]).  ``use_kernels=True`` routes M2L and P2P through the
    Pallas kernels (interpret mode off-TPU); both routes share the
    parity-folded slab implementations above.

    ``with_health=True`` additionally returns a ``health.N_FIELDS`` int32
    health word computed inside the same program (non-finite sentinels on
    the leaf expansion coefficients and the masked output — the serial
    driver has no halo exchange, so that field stays 0); the result is then
    ``(out, health)`` with no extra host sync.
    """
    eq = eqs.get_equation(eq)
    if targets is None and eq.needs_targets:
        raise ValueError(f"equation {eq.name!r} requires a targets tree")
    if targets is not None and targets.level != tree.level:
        raise ValueError("targets tree level != source tree level")
    if eq.q_is_real:
        # real-charge equations read only Re q, in BOTH drivers: the
        # sharded halo exchange drops the Im q plane, so projecting here
        # keeps serial == sharded even on a tree whose charges were built
        # with a mismatched (complex) charge_scale
        tree = Tree(z=tree.z, q=(tree.q.real + 0j).astype(tree.q.dtype),
                    mask=tree.mask, level=tree.level, sigma=tree.sigma)
    L = tree.level
    p2p = p2p_slab_fn(use_kernels, eq)
    zt = None if targets is None else targets.z
    out_mask = tree.mask if targets is None else targets.mask
    if L < 2:
        # Tiny trees are all near field.
        out = _mask_channels(out_mask, near_field(tree, p2p_fn=p2p,
                                                  z_tgt=zt))
        if not with_health:
            return out
        health = hw.with_flag(hw.empty(), hw.F_VEL,
                              hw.nonfinite(out, out_mask))
        return out, health
    m2l_fn = m2l_grid_fn(p, use_kernels, eq)

    me = upward_sweep(tree, p, eq)
    le = downward_sweep(me, p, m2l_fn=m2l_fn)
    centers = jnp.asarray(box_centers(L), dtype=tree.z.dtype)
    z_eval = tree.z if targets is None else targets.z
    far = ex.l2p_eval(le[L], z_eval, centers, box_size(L), p, eq.l2p_modes)
    near = near_field(tree, p2p_fn=p2p, z_tgt=zt)
    out = _mask_channels(out_mask, far + near)
    if not with_health:
        return out
    health = hw.empty()
    health = hw.with_flag(health, hw.F_COEFF,
                          jnp.maximum(hw.nonfinite(me[L]),
                                      hw.nonfinite(le[L])))
    health = hw.with_flag(health, hw.F_VEL, hw.nonfinite(out, out_mask))
    return out, health


def fmm_velocity(tree: Tree, p: int, use_kernels: bool = False,
                 with_health: bool = False):
    """Complex velocity W = u - iv per slot — the vortex-kernel form of
    :func:`fmm_evaluate` (the registry's bit-compatible default)."""
    return fmm_evaluate(tree, p, eq=eqs.VORTEX, use_kernels=use_kernels,
                        with_health=with_health)


def fmm_velocity_singular(tree: Tree, p: int) -> jnp.ndarray:
    """FMM with the singular kernel also in the near field.

    Isolates pure series-truncation error: comparing against a singular
    direct sum measures the p-convergence of the expansions alone
    (no Type-I kernel-substitution error; cf. paper §7.1 and ref [8]).
    """
    sing = Tree(z=tree.z, q=tree.q, mask=tree.mask, level=tree.level, sigma=None)
    return fmm_velocity(sing, p)


def flops_estimate(tree_level: int, slots: int, p: int, eq=None,
                   grid: tuple[int, int] | None = None,
                   cut: int | None = None) -> dict:
    """Rough FLOP census per stage (used by benchmarks & cost-model checks).

    The M2L term counts 27 (p x p) apply-accumulates per box — and since
    the parity-folded implementation (expansions.m2l_folded) performs
    exactly the 27 valid interactions (structural zero blocks, no runtime
    masks), this is the work the hot path actually does, not just the
    useful fraction of a 40-offset masked sweep.  Consistency with
    cost_model.N_IL and the folded operator's block sparsity is asserted in
    tests/test_cost_model.py.

    The census reads the equation spec: P2P and L2P scale with the output
    arity ``eq.nout`` (the downward coefficient sweep is shared across
    channels, so M2M/M2L/L2L do not).  Alongside the flop stages (summed
    into ``total``) it reports the sharded driver's P2P exchange as the
    driver actually executes it since PR 4: ONE packed collective round of
    ``p2p_exchange_planes`` f32 planes (4 for real-charge equations, 5
    otherwise) costing ``p2p_exchange_collectives`` ppermutes on a
    ``grid=(Pr, Pc)`` device grid — not the three unfused (z, q, mask)
    rounds the pre-PR-4 census priced.  ``grid=None`` means serial (zero
    collectives).

    Since the substep pipeline (DESIGN.md §12) the census also reports
    the overlap windows the pipelined issue order opens: ``cut`` is the
    gather cut level (``plan.level - plan.sharded_depth()``; default 2),
    ``gather_overlap_flops`` is the sharded M2L work issued between the
    cut-level all_gather and its first consumption (the root-tree
    sweep), and ``p2p_prefetch_rounds`` counts packed exchange rounds
    issued a substep ahead of their consumer (1 per RK2 step when
    sharded, 0 serial).  These are windows, not extra work — they are
    NOT summed into ``total``.
    """
    eq = eqs.get_equation(eq)
    L, s, C = tree_level, slots, eq.nout
    nleaf = 4 ** L
    cmul = 6.0  # complex multiply-add ~ 6 real flops
    stages = {
        "p2m": nleaf * s * p * 2 * cmul,
        "m2m": sum(4 ** l for l in range(1, L + 1)) * p * p * cmul,
        "m2l": sum(4 ** l for l in range(2, L + 1)) * 27 * p * p * cmul,
        "l2l": sum(4 ** l for l in range(3, L + 1)) * p * p * cmul,
        "l2p": nleaf * s * p * 2 * cmul * C,
        "p2p": nleaf * 9 * s * s * 12.0 * C,
    }
    stages["total"] = sum(stages.values())
    planes = 4 if eq.q_is_real else 5
    if grid is None:
        collectives = 0
    else:
        collectives = 2 * int(grid[0] > 1) + 2 * int(grid[1] > 1)
    stages["p2p_exchange_planes"] = float(planes)
    stages["p2p_exchange_collectives"] = float(collectives)
    n = 1 << L
    stages["p2p_exchange_bytes"] = float(collectives * n * planes * s * 4)
    if cut is None:
        cut = min(2, L)
    stages["gather_overlap_flops"] = (
        0.0 if grid is None else
        sum(4 ** l for l in range(cut + 1, L + 1)) * 27 * p * p * cmul)
    stages["p2p_prefetch_rounds"] = 0.0 if grid is None else 1.0
    return stages
