"""On-device health sentinels for guarded FMM execution (DESIGN.md §11).

A *health word* is a tiny ``(N_FIELDS,) int32`` vector computed INSIDE the
jitted step / FMM programs and returned alongside the results — exactly
like the stepper's max-occupancy scalar from PR 4, so reading it costs no
extra host sync: it rides back with the step's own outputs.

Fields (index constants below):

  flags (0/1)           F_VEL       non-finite velocity/output at a live slot
                        F_COEFF     non-finite expansion coefficient (ME or LE)
                        F_HALO      non-finite value in an exchanged halo buffer
                        F_OVERFLOW  a leaf box overflowed its slots during rebin
  counts                F_OOD       live particles outside the unit domain
                                    (counted BEFORE the rebin clamps them)
                        F_DROPPED   live particles silently dropped by a rebin
                                    (capacity overflow surplus)
  gauges (max)          F_OCC       max leaf occupancy after the step

Merge semantics: flags and gauges combine by ``max``, counts by ``+`` —
``merge`` applies this for substep/driver composition and
``device_combine`` reduces a per-device stack the same way (flags from the
sharded driver are per-device; counts are computed once on the global
arrays, so double counting never arises).

``pack``/``unpack`` give the single packed word form for reports and logs:

  bits 0-3    F_VEL | F_COEFF<<1 | F_HALO<<2 | F_OVERFLOW<<3
  bits 4-15   F_OOD      (clamped to 4095)
  bits 16-23  F_DROPPED  (clamped to 255)
  bits 24-31  F_OCC      (clamped to 255)

``ok`` is the fault predicate the recovery ladder keys on: any flag set or
any count nonzero is a fault; occupancy is a gauge, not a fault (the
stepper's occupancy guard prices it against capacity separately).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

N_FIELDS = 8
F_VEL, F_COEFF, F_HALO, F_OVERFLOW, F_OOD, F_DROPPED, F_OCC, F_SPARE = \
    range(N_FIELDS)

FIELD_NAMES = ("vel_nonfinite", "coeff_nonfinite", "halo_nonfinite",
               "leaf_overflow", "out_of_domain", "dropped", "max_occupancy",
               "spare")

# count fields combine by +; everything else by max
_COUNT_FIELDS = (F_OOD, F_DROPPED)
_IS_COUNT = np.zeros(N_FIELDS, dtype=bool)
_IS_COUNT[list(_COUNT_FIELDS)] = True


def empty() -> jnp.ndarray:
    return jnp.zeros((N_FIELDS,), jnp.int32)


def nonfinite(x: jnp.ndarray, mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Traced 0/1: any non-finite entry (live slots only when ``mask``)."""
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        bad = ~(jnp.isfinite(x.real) & jnp.isfinite(x.imag))
    else:
        bad = ~jnp.isfinite(x)
    if mask is not None:
        m = mask if bad.ndim == mask.ndim else mask[..., None]
        bad = bad & m
    return jnp.any(bad).astype(jnp.int32)


def out_of_domain_count(z: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Live particles outside the unit square [0, 1)^2 — the positions the
    rebin would silently clamp into the edge boxes."""
    out = (z.real < 0.0) | (z.real >= 1.0) | (z.imag < 0.0) | (z.imag >= 1.0)
    return (out & mask).sum().astype(jnp.int32)


def with_flag(vec: jnp.ndarray, field: int, cond) -> jnp.ndarray:
    return vec.at[field].max(jnp.asarray(cond, jnp.int32))


def with_count(vec: jnp.ndarray, field: int, n) -> jnp.ndarray:
    return vec.at[field].add(jnp.asarray(n, jnp.int32))


def merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Compose two health words (substeps, driver + step level)."""
    is_count = jnp.asarray(_IS_COUNT)
    return jnp.where(is_count, a + b, jnp.maximum(a, b))


def device_combine(stacked: jnp.ndarray) -> jnp.ndarray:
    """Reduce a (P, N_FIELDS) per-device stack to one global word."""
    is_count = jnp.asarray(_IS_COUNT)
    return jnp.where(is_count, stacked.sum(axis=0),
                     stacked.max(axis=0)).astype(jnp.int32)


# -- host-side report helpers ------------------------------------------------


def ok(vec) -> bool:
    """True iff no fault is flagged (occupancy is a gauge, not a fault)."""
    v = np.asarray(vec, dtype=np.int64)
    return bool((v[:F_OCC] == 0).all())


def pack(vec) -> int:
    """Health vector -> one packed 32-bit word (clamped fields; see above)."""
    v = np.asarray(vec, dtype=np.int64)
    word = (min(max(int(v[F_VEL]), 0), 1)
            | (min(max(int(v[F_COEFF]), 0), 1) << 1)
            | (min(max(int(v[F_HALO]), 0), 1) << 2)
            | (min(max(int(v[F_OVERFLOW]), 0), 1) << 3)
            | (min(max(int(v[F_OOD]), 0), 4095) << 4)
            | (min(max(int(v[F_DROPPED]), 0), 255) << 16)
            | (min(max(int(v[F_OCC]), 0), 255) << 24))
    return int(word)


def unpack(word: int) -> np.ndarray:
    v = np.zeros(N_FIELDS, dtype=np.int64)
    v[F_VEL] = word & 1
    v[F_COEFF] = (word >> 1) & 1
    v[F_HALO] = (word >> 2) & 1
    v[F_OVERFLOW] = (word >> 3) & 1
    v[F_OOD] = (word >> 4) & 4095
    v[F_DROPPED] = (word >> 16) & 255
    v[F_OCC] = (word >> 24) & 255
    return v


def describe(vec) -> dict:
    """Human/structured view of a health vector (or packed word)."""
    v = unpack(vec) if np.isscalar(vec) or np.ndim(vec) == 0 \
        else np.asarray(vec, dtype=np.int64)
    return {name: int(v[i]) for i, name in enumerate(FIELD_NAMES)
            if name != "spare"}
