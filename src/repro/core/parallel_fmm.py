"""Distributed FMM under ``shard_map`` (paper §4, TPU-native form).

Execution layout ("mode A", DESIGN.md §3): the leaf grid is sharded into
row slabs of subtrees along y.  Levels ``l >= l_cut`` are sharded the same
way; levels below the cut form the paper's *root tree* and are replicated
via one ``all_gather`` (the SPMD equivalent of the paper's root-tree rank +
broadcast, with no serial bottleneck).

Communication structure (maps 1:1 onto the paper's Fig 3):
  * M2M / L2L  — subtree <-> root tree only: the single all_gather at the
    cut level (paper: "no communication between subtrees" for these ops);
  * M2L        — lateral/diagonal neighbor subtrees: ±3-row halo exchange
    per sharded level via ``lax.ppermute``;
  * P2P        — neighbor particles: ±1-row halo of (z, q, mask).

The cost model (core/cost_model.py) predicts exactly these volumes; the
partitioner chooses the slab decomposition and drives the modeled
reproduction of the paper's scaling experiments (benchmarks/fmm_scaling.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import expansions as ex
from .quadtree import M2L_OFFSETS, M2L_VALIDITY, P2P_OFFSETS, Tree, box_centers, box_size
from .vortex import pairwise_w


def _halo_exchange_rows(x: jnp.ndarray, width: int, axis_name: str) -> jnp.ndarray:
    """Concatenate ±``width`` ghost rows from slab neighbors along axis 0.

    Edge devices receive zeros (consistent with the serial zero padding of
    the domain boundary).  Two ``ppermute`` calls: one up, one down.
    """
    P_ = jax.lax.axis_size(axis_name)
    if P_ == 1:
        zeros = jnp.zeros((width,) + x.shape[1:], x.dtype)
        return jnp.concatenate([zeros, x, zeros], axis=0)
    top_rows = x[:width]      # my top rows -> neighbor above's bottom halo
    bot_rows = x[-width:]     # my bottom rows -> neighbor below's top halo
    # send bottom rows to d+1 (they become d+1's top halo)
    from_above = jax.lax.ppermute(bot_rows, axis_name,
                                  [(d, d + 1) for d in range(P_ - 1)])
    # send top rows to d-1 (they become d-1's bottom halo)
    from_below = jax.lax.ppermute(top_rows, axis_name,
                                  [(d + 1, d) for d in range(P_ - 1)])
    return jnp.concatenate([from_above, x, from_below], axis=0)


def _m2l_slab(me_halo: jnp.ndarray, level: int, p: int) -> jnp.ndarray:
    """M2L over a row slab with ±3 ghost rows already attached.

    me_halo: (rows+6, n, p).  Returns (rows, n, p).  Requires the slab's
    global start row to be even (guaranteed: rows-per-device is even), so
    the parity masks match the serial pattern.
    """
    rows = me_halo.shape[0] - 6
    n = me_halo.shape[1]
    r = box_size(level)
    ops = ex.m2l_operator(p)
    pad = jnp.pad(me_halo, ((0, 0), (3, 3), (0, 0)))
    le = jnp.zeros((rows, n, p), me_halo.dtype)
    for oi, (dx, dy) in enumerate(M2L_OFFSETS):
        src = pad[3 + dy:3 + dy + rows, 3 + dx:3 + dx + n, :]
        op = jnp.asarray(ops[oi], dtype=me_halo.dtype)
        contrib = jnp.einsum("yxk,lk->yxl", src, op)
        m = jnp.asarray(ex.parity_mask_rect(rows, n, M2L_VALIDITY[oi]),
                        dtype=me_halo.dtype)
        le = le + contrib * m[..., None]
    return le / r


def _p2p_slab(z, q, mask, sigma, axis_name: str) -> jnp.ndarray:
    """Near-field direct interactions over a row slab with ±1 ghost rows."""
    rows, n, s = z.shape
    zh = _halo_exchange_rows(z, 1, axis_name)
    qh = _halo_exchange_rows(q, 1, axis_name)
    mh = _halo_exchange_rows(mask, 1, axis_name)
    zp = jnp.pad(zh, ((0, 0), (1, 1), (0, 0)))
    qp = jnp.pad(qh, ((0, 0), (1, 1), (0, 0)))
    mp = jnp.pad(mh, ((0, 0), (1, 1), (0, 0)))
    w = jnp.zeros_like(z)
    for (dx, dy) in P2P_OFFSETS:
        zs = zp[1 + dy:1 + dy + rows, 1 + dx:1 + dx + n]
        qs = qp[1 + dy:1 + dy + rows, 1 + dx:1 + dx + n]
        ms = mp[1 + dy:1 + dy + rows, 1 + dx:1 + dx + n]
        w = w + pairwise_w(z, zs, qs, ms, sigma)
    return w


def _parallel_fmm_body(z, q, mask, *, level: int, p: int, sigma, axis_name: str):
    """Runs on each device over its (rows, n, s) slab of the leaf grid."""
    L = level
    n = 1 << L
    P_ = jax.lax.axis_size(axis_name)
    a = int(np.log2(P_)) if P_ > 1 else 0
    # sharded levels: rows/device >= 4 (single-hop ±3 halo); replicated below.
    l_cut = min(L, max(2, a + 2))
    dtype = z.dtype

    my_row0 = jax.lax.axis_index(axis_name) * (n // P_)
    centers = jnp.asarray(box_centers(L), dtype=dtype)
    my_centers = jax.lax.dynamic_slice_in_dim(centers, my_row0, n // P_, 0)

    # ---- upward sweep -----------------------------------------------------
    me = {L: ex.p2m(z, q, mask, my_centers, box_size(L), p)}
    l = L
    while l > l_cut:
        me[l - 1] = ex.m2m(me[l], p)
        l -= 1
    # gather the cut level -> replicated root tree (paper's M2M to root)
    me_cut_full = jax.lax.all_gather(me[l_cut], axis_name, axis=0, tiled=True)
    me_rep = {l_cut: me_cut_full}
    for lv in range(l_cut, 0, -1):
        me_rep[lv - 1] = ex.m2m(me_rep[lv], p)

    # ---- downward sweep ---------------------------------------------------
    # replicated root-tree levels 2 .. l_cut
    le_rep: dict[int, jnp.ndarray] = {}
    for lv in range(2, l_cut + 1):
        le_rep[lv] = ex.m2l_reference(me_rep[lv], lv, p)
        if lv > 2:
            le_rep[lv] = le_rep[lv] + ex.l2l(le_rep[lv - 1], p)
    # sharded levels l_cut+1 .. L
    le_prev = None  # my slab's LE at previous (coarser) level
    if l_cut >= 2 and L > l_cut:
        # slice my slab rows out of the replicated cut-level LE
        le_prev = jax.lax.dynamic_slice_in_dim(
            le_rep[l_cut], jax.lax.axis_index(axis_name) * ((1 << l_cut) // P_),
            (1 << l_cut) // P_, 0)
    for lv in range(l_cut + 1, L + 1):
        me_halo = _halo_exchange_rows(me[lv], 3, axis_name)
        le_lv = _m2l_slab(me_halo, lv, p)
        if le_prev is not None:
            le_lv = le_lv + ex.l2l(le_prev, p)
        le_prev = le_lv
    le_leaf = le_prev if L > l_cut else jax.lax.dynamic_slice_in_dim(
        le_rep[L], jax.lax.axis_index(axis_name) * (n // P_), n // P_, 0)

    # ---- evaluation -------------------------------------------------------
    far = ex.l2p(le_leaf, z, my_centers, box_size(L), p)
    near = _p2p_slab(z, q, mask, sigma, axis_name)
    return jnp.where(mask, far + near, 0.0)


@functools.partial(jax.jit, static_argnames=("p", "mesh", "mesh_axis"))
def parallel_fmm_velocity(tree: Tree, p: int, mesh: Optional[Mesh] = None,
                          mesh_axis: str = "data") -> jnp.ndarray:
    """Distributed FMM evaluation. Shards the leaf grid over ``mesh_axis``.

    Falls back to a 1-device mesh when ``mesh`` is None.  The number of
    devices along the axis must divide 2**level with an even quotient.
    """
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    P_ = mesh.shape[mesh_axis]
    n = tree.nside
    if tree.level < 2:
        raise ValueError("parallel FMM requires tree level >= 2")
    if n % P_ or (n // P_) % 2:
        raise ValueError(f"grid side {n} must split into even slabs over {P_} devices")

    body = functools.partial(_parallel_fmm_body, level=tree.level, p=p,
                             sigma=tree.sigma, axis_name=mesh_axis)
    spec = P(mesh_axis, None, None)
    fn = jax.shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                       out_specs=spec)
    return fn(tree.z, tree.q, tree.mask)
