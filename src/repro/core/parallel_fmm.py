"""Distributed FMM under ``shard_map`` (paper §4, TPU-native form).

Execution layout ("mode A", DESIGN.md §3/§7): the leaf grid is sharded into
row-slab *bands* along y, described by a static :class:`~repro.core.plan.SlabPlan`
— contiguous, parity-even bands of unequal height, padded to ``rows_max``
so shapes stay static.  The plan is produced by the cost-model partitioner
(core/plan.py over core/partition.py), which makes the paper's load
balancer actually schedule the sharded execution instead of assuming
``n // P`` rows per device.  Levels deep enough that band boundaries stay
aligned are sharded the same way; levels below the cut form the paper's
*root tree* and are replicated via one ``all_gather`` (the SPMD equivalent
of the paper's root-tree rank + broadcast, with no serial bottleneck).

Communication structure (maps 1:1 onto the paper's Fig 3):
  * M2M / L2L  — subtree <-> root tree only: the single all_gather at the
    cut level, reassembled across unequal bands by a static owner map
    (paper: "no communication between subtrees" for these ops);
  * M2L        — lateral/diagonal neighbor bands: ±2-row halo exchange per
    sharded level via ``lax.ppermute``, sliced at each band's *valid* edge
    (parity folding shrinks the paper's ±3 child-box halo to ±1 parent
    row — DESIGN.md §4);
  * P2P        — neighbor particles: ±1-row halo of (z, q, mask).

M2L and P2P themselves are the SAME slab implementations the serial driver
uses (core/fmm.py: ``m2l_slab_fn`` / ``p2p_slab_fn``); this module only
adds the halo exchanges, the band padding, and the root-tree replication
around them.  Padded rows carry ``mask=False`` and zero expansions and are
masked out of the result.

The cost model (core/cost_model.py) predicts exactly these volumes; the
partitioner chooses the band decomposition and ``core/stepper.py`` closes
the dynamic feedback loop.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import expansions as ex
from . import fmm
from .plan import SlabPlan, uniform_plan
from .quadtree import Tree, box_centers, box_size

# jax >= 0.6 exposes shard_map at the top level; older versions under
# jax.experimental.  Resolve once, version-compatibly — including the name
# of the replication-check kwarg (check_rep, renamed check_vma in jax 0.7).
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

_CHECK_KW = next((k for k in ("check_rep", "check_vma")
                  if k in _inspect.signature(_shard_map).parameters), None)


def _band_halo(x: jnp.ndarray, width: int, rows_valid, axis_name: str,
               axis_size: int) -> jnp.ndarray:
    """Attach ±``width`` ghost rows at the *valid* edges of a padded band.

    ``x`` is a (rows_max, ...) band whose rows ``[0, rows_valid)`` are
    valid (padding rows are zero).  Returns (rows_max + 2*width, ...): my
    band at offset ``width``, the upper neighbor's bottom ``width`` valid
    rows at ``[0, width)``, and the lower neighbor's top ``width`` rows
    placed *at* ``width + rows_valid`` — i.e. immediately after my valid
    rows, where the slab implementations expect adjacent data.  Edge
    devices receive zeros (consistent with the serial zero padding of the
    domain boundary).  Two ``ppermute`` calls: one up, one down.
    """
    P_ = axis_size
    shape = (width,) + x.shape[1:]
    if P_ == 1:
        recv_top = recv_bot = jnp.zeros(shape, x.dtype)
    else:
        bot_valid = jax.lax.dynamic_slice_in_dim(x, rows_valid - width, width, 0)
        top_valid = x[:width]
        # my bottom rows -> device below's top halo
        recv_top = jax.lax.ppermute(bot_valid, axis_name,
                                    [(d, d + 1) for d in range(P_ - 1)])
        # my top rows -> device above's bottom halo
        recv_bot = jax.lax.ppermute(top_valid, axis_name,
                                    [(d + 1, d) for d in range(P_ - 1)])
    buf = jnp.zeros((x.shape[0] + 2 * width,) + x.shape[1:], x.dtype)
    buf = jax.lax.dynamic_update_slice_in_dim(buf, x, width, 0)
    buf = jax.lax.dynamic_update_slice_in_dim(buf, recv_top, 0, 0)
    buf = jax.lax.dynamic_update_slice_in_dim(buf, recv_bot, width + rows_valid, 0)
    return buf


def _sharded_depth(plan: SlabPlan, min_rows: int = 4) -> int:
    """How many levels (from the leaves up) the plan's bands can shard.

    Level ``L - s`` is shardable when every band boundary stays even after
    ``s`` halvings (halo-2 slab contract needs even-aligned, even-length
    bands) and the smallest band keeps ``min_rows`` rows at the coarsest
    sharded level.  Parity-even plans always support depth 1 when L >= 3.
    """
    if plan.level < 3:
        return 0
    m = 1
    align = plan.alignment()
    while (m + 1 <= align and plan.level - (m + 1) >= 2
           and (min(plan.rows) >> m) >= min_rows):
        m += 1
    return m


def _parallel_fmm_body(z, q, mask, *, plan: SlabPlan, l_cut: int, p: int,
                       sigma, axis_name: str, axis_size: int,
                       use_kernels: bool):
    """Runs on each device over its padded (rows_max, n, s) band."""
    L = plan.level
    P_ = axis_size
    rows_max = plan.rows_max
    dtype = z.dtype

    m2l_slab = fmm.m2l_slab_fn(p, use_kernels)
    m2l_grid = fmm.m2l_grid_fn(p, use_kernels)
    p2p_slab = fmm.p2p_slab_fn(use_kernels)

    # static per-device band records, looked up by device index
    di = jax.lax.axis_index(axis_name)
    my_row0 = jnp.asarray(np.asarray(plan.row0, np.int32))[di]
    my_rows = jnp.asarray(np.asarray(plan.rows, np.int32))[di]

    # centers padded below so the dynamic slice never clamps short bands
    centers = jnp.asarray(box_centers(L), dtype=dtype)
    centers = jnp.pad(centers, ((0, rows_max), (0, 0)))
    my_centers = jax.lax.dynamic_slice_in_dim(centers, my_row0, rows_max, 0)

    # ---- upward sweep -----------------------------------------------------
    # Padding rows have mask=False everywhere, so their MEs are exactly zero
    # and M2M keeps them zero at every coarser band level.
    me = {L: ex.p2m(z, q, mask, my_centers, box_size(L), p)}
    for lv in range(L, l_cut, -1):
        me[lv - 1] = ex.m2m(me[lv], p)

    # gather the cut level -> replicated root tree (paper's M2M to root);
    # unequal bands are reassembled by the plan's static owner/local maps.
    cut_shift = L - l_cut
    gathered = jax.lax.all_gather(me[l_cut], axis_name, axis=0, tiled=False)
    owner, local = plan.band_row_maps(cut_shift)
    me_cut_full = gathered[jnp.asarray(owner), jnp.asarray(local)]
    me_rep = {l_cut: me_cut_full}
    for lv in range(l_cut, 0, -1):
        me_rep[lv - 1] = ex.m2m(me_rep[lv], p)

    # ---- downward sweep ---------------------------------------------------
    # replicated root-tree levels 2 .. l_cut (same folded path, zero ghosts)
    le_rep: dict[int, jnp.ndarray] = {}
    for lv in range(2, l_cut + 1):
        le_rep[lv] = m2l_grid(me_rep[lv], lv)
        if lv > 2:
            le_rep[lv] = le_rep[lv] + ex.l2l(le_rep[lv - 1], p)

    def slice_band(grid, shift):
        """My (rows_max >> shift)-row band out of a replicated level grid."""
        rmax = rows_max >> shift
        padded = jnp.pad(grid, ((0, rmax),) + ((0, 0),) * (grid.ndim - 1))
        return jax.lax.dynamic_slice_in_dim(padded, my_row0 >> shift, rmax, 0)

    # sharded levels l_cut+1 .. L: exchange ±M2L_HALO ghost rows at the
    # valid band edges, then the identical slab implementation.  Bands are
    # even-aligned at every sharded level (plan parity + _sharded_depth),
    # so row0=0 anchors the correct parity and the 2-row halo suffices.
    le_prev = None  # my band's LE at the previous (coarser) level
    if L > l_cut:
        le_prev = slice_band(le_rep[l_cut], cut_shift)
    for lv in range(l_cut + 1, L + 1):
        rv = my_rows >> (L - lv)
        me_buf = _band_halo(me[lv], ex.M2L_HALO, rv, axis_name, P_)
        le_lv = m2l_slab(me_buf, lv)
        le_lv = le_lv + ex.l2l(le_prev, p)
        le_prev = le_lv
    le_leaf = le_prev if L > l_cut else slice_band(le_rep[L], 0)

    # ---- evaluation -------------------------------------------------------
    far = ex.l2p(le_leaf, z, my_centers, box_size(L), p)
    cpad = ((0, 0), (1, 1), (0, 0))
    near = p2p_slab(jnp.pad(_band_halo(z, 1, my_rows, axis_name, P_), cpad),
                    jnp.pad(_band_halo(q, 1, my_rows, axis_name, P_), cpad),
                    jnp.pad(_band_halo(mask, 1, my_rows, axis_name, P_), cpad),
                    sigma)
    # padded rows (mask=False) are dropped here
    return jnp.where(mask, far + near, 0.0)


@functools.partial(jax.jit, static_argnames=("p", "mesh", "mesh_axis",
                                             "use_kernels", "plan"))
def parallel_fmm_velocity(tree: Tree, p: int, mesh: Optional[Mesh] = None,
                          mesh_axis: str = "data",
                          use_kernels: bool = False,
                          plan: Optional[SlabPlan] = None) -> jnp.ndarray:
    """Distributed FMM evaluation driven by a :class:`SlabPlan`.

    ``plan`` maps devices to contiguous parity-even leaf-row bands (the
    cost-model partitioner's output); ``plan=None`` falls back to the
    uniform equal-count strawman.  The tree is resharded into the plan's
    padded band layout, evaluated under ``shard_map``, and scattered back
    to standard layout, so the result is independent of the plan to f32
    roundoff.  Falls back to a 1-device mesh when ``mesh`` is None.
    ``use_kernels=True`` routes M2L/P2P through the same Pallas kernels the
    serial driver uses (interpret mode off-TPU).
    """
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    P_ = mesh.shape[mesh_axis]
    n = tree.nside
    if tree.level < 2:
        raise ValueError("parallel FMM requires tree level >= 2")
    if plan is None:
        if n % P_ or (n // P_) % 2:
            raise ValueError(
                f"grid side {n} must split into even slabs over {P_} devices")
        plan = uniform_plan(tree.level, P_)
    if plan.level != tree.level:
        raise ValueError(f"plan level {plan.level} != tree level {tree.level}")
    if plan.nparts != P_:
        raise ValueError(f"plan has {plan.nparts} bands for {P_} devices")

    rows_max = plan.rows_max
    identity = plan.is_uniform and P_ * rows_max == n
    if identity:
        z_sh, q_sh, m_sh = tree.z, tree.q, tree.mask
    else:
        idx, valid = plan.gather_index()
        idx = jnp.asarray(idx)
        vrow = jnp.asarray(valid)[:, None, None]
        z_sh = jnp.where(vrow, tree.z[idx], 0)
        q_sh = jnp.where(vrow, tree.q[idx], 0)
        m_sh = tree.mask[idx] & vrow

    l_cut = plan.level - _sharded_depth(plan)
    body = functools.partial(_parallel_fmm_body, plan=plan, l_cut=l_cut, p=p,
                             sigma=tree.sigma, axis_name=mesh_axis,
                             axis_size=P_, use_kernels=use_kernels)
    spec = P(mesh_axis, None, None)
    # pallas_call has no shard_map replication rule; disable the check on
    # the kernel route (numerics are unaffected — outputs stay sharded).
    kwargs = {_CHECK_KW: False} if (use_kernels and _CHECK_KW) else {}
    fn = _shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                    out_specs=spec, **kwargs)
    w = fn(z_sh, q_sh, m_sh)
    return w if identity else w[jnp.asarray(plan.scatter_index())]
