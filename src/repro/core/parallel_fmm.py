"""Distributed FMM under ``shard_map`` (paper §4, TPU-native form).

Execution layout ("mode A", DESIGN.md §3): the leaf grid is sharded into
row slabs of subtrees along y.  Levels ``l >= l_cut`` are sharded the same
way; levels below the cut form the paper's *root tree* and are replicated
via one ``all_gather`` (the SPMD equivalent of the paper's root-tree rank +
broadcast, with no serial bottleneck).

Communication structure (maps 1:1 onto the paper's Fig 3):
  * M2M / L2L  — subtree <-> root tree only: the single all_gather at the
    cut level (paper: "no communication between subtrees" for these ops);
  * M2L        — lateral/diagonal neighbor subtrees: ±2-row halo exchange
    per sharded level via ``lax.ppermute`` (parity folding shrinks the
    paper's ±3 child-box halo to ±1 parent row — DESIGN.md §4);
  * P2P        — neighbor particles: ±1-row halo of (z, q, mask).

M2L and P2P themselves are the SAME slab implementations the serial driver
uses (core/fmm.py: ``m2l_slab_fn`` / ``p2p_slab_fn``); this module only
adds the halo exchanges and the root-tree replication around them.

The cost model (core/cost_model.py) predicts exactly these volumes; the
partitioner chooses the slab decomposition and drives the modeled
reproduction of the paper's scaling experiments (benchmarks/fmm_scaling.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import expansions as ex
from . import fmm
from .quadtree import Tree, box_centers, box_size

# jax >= 0.6 exposes shard_map at the top level; older versions under
# jax.experimental.  Resolve once, version-compatibly — including the name
# of the replication-check kwarg (check_rep, renamed check_vma in jax 0.7).
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

_CHECK_KW = next((k for k in ("check_rep", "check_vma")
                  if k in _inspect.signature(_shard_map).parameters), None)


def _halo_exchange_rows(x: jnp.ndarray, width: int, axis_name: str,
                        axis_size: int) -> jnp.ndarray:
    """Concatenate ±``width`` ghost rows from slab neighbors along axis 0.

    Edge devices receive zeros (consistent with the serial zero padding of
    the domain boundary).  Two ``ppermute`` calls: one up, one down.
    (``axis_size`` is passed statically: jax 0.4 has no ``lax.axis_size``.)
    """
    P_ = axis_size
    if P_ == 1:
        zeros = jnp.zeros((width,) + x.shape[1:], x.dtype)
        return jnp.concatenate([zeros, x, zeros], axis=0)
    top_rows = x[:width]      # my top rows -> neighbor above's bottom halo
    bot_rows = x[-width:]     # my bottom rows -> neighbor below's top halo
    # send bottom rows to d+1 (they become d+1's top halo)
    from_above = jax.lax.ppermute(bot_rows, axis_name,
                                  [(d, d + 1) for d in range(P_ - 1)])
    # send top rows to d-1 (they become d-1's bottom halo)
    from_below = jax.lax.ppermute(top_rows, axis_name,
                                  [(d + 1, d) for d in range(P_ - 1)])
    return jnp.concatenate([from_above, x, from_below], axis=0)


def _parallel_fmm_body(z, q, mask, *, level: int, p: int, sigma,
                       axis_name: str, axis_size: int, use_kernels: bool):
    """Runs on each device over its (rows, n, s) slab of the leaf grid."""
    L = level
    n = 1 << L
    P_ = axis_size
    a = int(np.log2(P_)) if P_ > 1 else 0
    # sharded levels: rows/device >= 4 (single-hop halo); replicated below.
    l_cut = min(L, max(2, a + 2))
    dtype = z.dtype

    m2l_slab = fmm.m2l_slab_fn(p, use_kernels)
    m2l_grid = fmm.m2l_grid_fn(p, use_kernels)
    p2p_slab = fmm.p2p_slab_fn(use_kernels)

    my_row0 = jax.lax.axis_index(axis_name) * (n // P_)
    centers = jnp.asarray(box_centers(L), dtype=dtype)
    my_centers = jax.lax.dynamic_slice_in_dim(centers, my_row0, n // P_, 0)

    # ---- upward sweep -----------------------------------------------------
    me = {L: ex.p2m(z, q, mask, my_centers, box_size(L), p)}
    l = L
    while l > l_cut:
        me[l - 1] = ex.m2m(me[l], p)
        l -= 1
    # gather the cut level -> replicated root tree (paper's M2M to root)
    me_cut_full = jax.lax.all_gather(me[l_cut], axis_name, axis=0, tiled=True)
    me_rep = {l_cut: me_cut_full}
    for lv in range(l_cut, 0, -1):
        me_rep[lv - 1] = ex.m2m(me_rep[lv], p)

    # ---- downward sweep ---------------------------------------------------
    # replicated root-tree levels 2 .. l_cut (same folded path, zero ghosts)
    le_rep: dict[int, jnp.ndarray] = {}
    for lv in range(2, l_cut + 1):
        le_rep[lv] = m2l_grid(me_rep[lv], lv)
        if lv > 2:
            le_rep[lv] = le_rep[lv] + ex.l2l(le_rep[lv - 1], p)
    # sharded levels l_cut+1 .. L: exchange ±M2L_HALO ghost rows, then the
    # identical slab implementation with this slab's global parity anchor.
    # rows/device is even at every sharded level, so row0 stays even and the
    # 2-row halo suffices (expansions.m2l_slab_geometry enforces this).
    le_prev = None  # my slab's LE at previous (coarser) level
    if l_cut >= 2 and L > l_cut:
        # slice my slab rows out of the replicated cut-level LE
        le_prev = jax.lax.dynamic_slice_in_dim(
            le_rep[l_cut], jax.lax.axis_index(axis_name) * ((1 << l_cut) // P_),
            (1 << l_cut) // P_, 0)
    for lv in range(l_cut + 1, L + 1):
        me_halo = _halo_exchange_rows(me[lv], ex.M2L_HALO, axis_name, P_)
        le_lv = m2l_slab(me_halo, lv)
        if le_prev is not None:
            le_lv = le_lv + ex.l2l(le_prev, p)
        le_prev = le_lv
    le_leaf = le_prev if L > l_cut else jax.lax.dynamic_slice_in_dim(
        le_rep[L], jax.lax.axis_index(axis_name) * (n // P_), n // P_, 0)

    # ---- evaluation -------------------------------------------------------
    far = ex.l2p(le_leaf, z, my_centers, box_size(L), p)
    cpad = ((0, 0), (1, 1), (0, 0))
    near = p2p_slab(jnp.pad(_halo_exchange_rows(z, 1, axis_name, P_), cpad),
                    jnp.pad(_halo_exchange_rows(q, 1, axis_name, P_), cpad),
                    jnp.pad(_halo_exchange_rows(mask, 1, axis_name, P_), cpad),
                    sigma)
    return jnp.where(mask, far + near, 0.0)


@functools.partial(jax.jit, static_argnames=("p", "mesh", "mesh_axis",
                                             "use_kernels"))
def parallel_fmm_velocity(tree: Tree, p: int, mesh: Optional[Mesh] = None,
                          mesh_axis: str = "data",
                          use_kernels: bool = False) -> jnp.ndarray:
    """Distributed FMM evaluation. Shards the leaf grid over ``mesh_axis``.

    Falls back to a 1-device mesh when ``mesh`` is None.  The number of
    devices along the axis must divide 2**level with an even quotient.
    ``use_kernels=True`` routes M2L/P2P through the same Pallas kernels the
    serial driver uses (interpret mode off-TPU).
    """
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    P_ = mesh.shape[mesh_axis]
    n = tree.nside
    if tree.level < 2:
        raise ValueError("parallel FMM requires tree level >= 2")
    if n % P_ or (n // P_) % 2:
        raise ValueError(f"grid side {n} must split into even slabs over {P_} devices")

    body = functools.partial(_parallel_fmm_body, level=tree.level, p=p,
                             sigma=tree.sigma, axis_name=mesh_axis,
                             axis_size=P_, use_kernels=use_kernels)
    spec = P(mesh_axis, None, None)
    # pallas_call has no shard_map replication rule; disable the check on
    # the kernel route (numerics are unaffected — outputs stay sharded).
    kwargs = {_CHECK_KW: False} if (use_kernels and _CHECK_KW) else {}
    fn = _shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                    out_specs=spec, **kwargs)
    return fn(tree.z, tree.q, tree.mask)
