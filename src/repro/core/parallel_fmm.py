"""Distributed FMM under ``shard_map`` (paper §4, TPU-native form).

Execution layout ("mode A", DESIGN.md §3/§7/§8): the leaf grid is sharded
into device tiles described by a static execution plan — either a 1-D
:class:`~repro.core.plan.SlabPlan` (contiguous, parity-even row bands) or a
2-D :class:`~repro.core.plan.BlockPlan` (a ``Pr x Pc`` tensor grid of
parity-even row-x-column tiles).  Both kinds execute through ONE body: a
slab is simply the ``Pr x 1`` special case of a block (``SlabPlan.as_block``),
so there are no duplicated drivers.  The plan is produced by the cost-model
partitioner (core/plan.py over core/partition.py), which makes the paper's
load balancer actually schedule the sharded execution instead of assuming
``n // P`` rows per device.  Levels deep enough that tile boundaries stay
aligned are sharded the same way; levels below the cut form the paper's
*root tree* and are replicated via one ``all_gather`` (the SPMD equivalent
of the paper's root-tree rank + broadcast, with no serial bottleneck).

Communication structure (maps 1:1 onto the paper's Fig 3):
  * M2M / L2L  — subtree <-> root tree only: the single all_gather at the
    cut level, reassembled across unequal tiles by static 2-D owner maps
    (paper: "no communication between subtrees" for these ops);
  * M2L        — lateral/diagonal neighbor tiles: ±2-row/column halo
    exchange per sharded level via ``lax.ppermute``, sliced at each tile's
    *valid* edges (parity folding shrinks the paper's ±3 child-box halo to
    ±1 parent line — DESIGN.md §4);
  * P2P        — neighbor particles: ±1-row/column halo of (z, q, mask),
    packed into ONE buffer so the exchange is a single ``_tile_halo`` round
    (4 ppermutes) instead of three (12) — ``_pack_particles``.

The two-axis exchange runs columns first, then rows *of the column-extended
strips*: because the tile grid is a tensor product, east/west neighbors own
my exact row range, so the row strips carry the freshly attached column
halos and the diagonal (corner) ghosts arrive with them — M2L's and P2P's
corner interactions are complete with two ppermute hops per axis and no
separate corner transfer.

Interior/rim overlap (DESIGN.md §9): with ``overlap=True`` the driver
issues every halo collective *first* (the packed P2P exchange before the
upward sweep, the per-level M2L exchanges before the root-tree work) and
computes each tile's interior — every box at least one halo width from the
tile edges, the overwhelming bulk of the work — from local data alone while
the collectives are in flight; only the thin rim strips along the tile
edges consume the exchanged buffers (``fmm.m2l_tile_overlapped`` /
``fmm.p2p_tile_overlapped``), and they are stitched over the interior.
``overlap=False`` keeps the paper's serial exchange-then-compute ordering;
the two orderings share the same slab implementations and agree to f32
roundoff.  ``plan.halo_volume`` prices the rim recompute and
``plan.plan_comm_cost`` the overlap-aware serial comm residue.

Substep pipelining (DESIGN.md §12) extends the frontier further:
``pipeline=True`` defers the cut-level all_gather's first consumption past
all sharded-level M2L compute (the gather hides behind the downward sweep
instead of serializing in front of the root tree), and
``parallel_fmm_p2p_prefetch`` lets the RK2 stepper issue the NEXT
substep's packed P2P exchange while the current substep's trailing work
finishes — the cross-substep double buffer, consumed via the
``p2p_halo`` argument.

M2L and P2P themselves are the SAME slab implementations the serial driver
uses (core/fmm.py: ``m2l_slab_fn`` / ``p2p_slab_fn``, column halos handled
by the shared ``expansions.m2l_slab_stack`` geometry); this module only
adds the halo exchanges, the tile padding, and the root-tree replication
around them.  Padded rows/columns carry ``mask=False`` and zero expansions
and are masked out of the result.

The cost model (core/cost_model.py) predicts these volumes and
``plan.halo_volume`` prices them per plan; the partitioner chooses the tile
decomposition and ``core/stepper.py`` closes the dynamic feedback loop.
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import equations as _eqs
from . import expansions as ex
from . import faults as _faults
from . import fmm
from . import health as hw
from .plan import BlockPlan, SlabPlan, uniform_plan
from .quadtree import Tree, box_centers, box_size

# jax >= 0.6 exposes shard_map at the top level; older versions under
# jax.experimental.  Resolve once, version-compatibly — including the name
# of the replication-check kwarg (check_rep, renamed check_vma in jax 0.7).
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

_CHECK_KW = next((k for k in ("check_rep", "check_vma")
                  if k in _inspect.signature(_shard_map).parameters), None)


def _tile_halo(x: jnp.ndarray, width: int, rows_valid, cols_valid,
               axis_name: str, grid: tuple[int, int]) -> jnp.ndarray:
    """Attach ±``width`` ghost rows AND columns at the *valid* tile edges.

    ``x`` is a (rows_max, cols_max, ...) padded tile whose rows
    ``[0, rows_valid)`` and columns ``[0, cols_valid)`` are valid (padding
    is zero).  Returns (rows_max + 2w, cols_max + 2w, ...): my tile at
    offset ``(w, w)``, neighbors' edge data placed immediately adjacent to
    my valid extents (the upper/left neighbor's strips at offset 0, the
    lower/right neighbor's at ``w + rows_valid`` / ``w + cols_valid``).

    Columns are exchanged first; the row strips are then cut from the
    column-extended buffer, so they carry the column halos and the corner
    (diagonal-neighbor) ghosts ride along — no separate corner transfer.
    Domain-edge tiles receive zeros (consistent with the serial zero
    padding).  Devices are laid out ``d = i * Pc + j`` on the 1-D mesh
    axis; all four exchanges are single-hop ``ppermute``.

    A single-rank axis is degenerate: its ghost strips are structurally
    zero, so no collective is issued for it — and when the COLUMN axis is
    degenerate the row strips are shipped at raw width ``cmax`` instead of
    the column-extended ``cmax + 2w``, since the 2w extra columns would
    carry known zeros.  A ``Pr x 1`` slab therefore pays exactly one
    axis's ppermute round at minimal width (pinned by HLO-shape tests);
    the exchanged values are identical either way.
    """
    Pr, Pc = grid
    w = width
    rmax, cmax = x.shape[0], x.shape[1]
    trail = x.shape[2:]
    zi = (0,) * len(trail)
    # -- phase 1: columns (east/west neighbors own my exact row range) -----
    if Pc > 1:
        right_edge = jax.lax.dynamic_slice_in_dim(x, cols_valid - w, w, 1)
        left_edge = x[:, :w]
        # my right edge -> east neighbor's left halo, and vice versa
        recv_l = jax.lax.ppermute(right_edge, axis_name,
                                  [(i * Pc + j, i * Pc + j + 1)
                                   for i in range(Pr) for j in range(Pc - 1)])
        recv_r = jax.lax.ppermute(left_edge, axis_name,
                                  [(i * Pc + j, i * Pc + j - 1)
                                   for i in range(Pr) for j in range(1, Pc)])
        xc = jnp.zeros((rmax, cmax + 2 * w) + trail, x.dtype)
        xc = jax.lax.dynamic_update_slice_in_dim(xc, x, w, 1)
        xc = jax.lax.dynamic_update_slice_in_dim(xc, recv_l, 0, 1)
        xc = jax.lax.dynamic_update_slice_in_dim(xc, recv_r, w + cols_valid, 1)
        c0 = 0
    else:
        xc, c0 = x, w          # raw-width strips, placed at column offset w
    # -- phase 2: rows of the column-extended strips (corners ride along) --
    buf = jnp.zeros((rmax + 2 * w, cmax + 2 * w) + trail, x.dtype)
    buf = jax.lax.dynamic_update_slice(buf, xc, (w, c0) + zi)
    if Pr > 1:
        bot_edge = jax.lax.dynamic_slice_in_dim(xc, rows_valid - w, w, 0)
        top_edge = xc[:w]
        recv_t = jax.lax.ppermute(bot_edge, axis_name,
                                  [(d, d + Pc) for d in range((Pr - 1) * Pc)])
        recv_b = jax.lax.ppermute(top_edge, axis_name,
                                  [(d, d - Pc) for d in range(Pc, Pr * Pc)])
        buf = jax.lax.dynamic_update_slice(buf, recv_t, (0, c0) + zi)
        buf = jax.lax.dynamic_update_slice(buf, recv_b,
                                           (w + rows_valid, c0) + zi)
    return buf


def _pack_particles(z, q, mask, q_real: bool = False) -> jnp.ndarray:
    """Stack (z, q, mask) into ONE real (rows, cols, planes, s) buffer — so
    the P2P halo exchange is a single packed ``_tile_halo`` round (4
    ppermutes) instead of three (12).  The payload width is spec-dependent:
    planes are [Re z, Im z, Re q, Im q, mask] (5) for complex-charge
    equations, [Re z, Im z, Re q, mask] (4) when the equation spec declares
    ``q_is_real`` (e.g. Laplace charges).  f32 carries the complex64
    components and the bool mask exactly, so the round-trip is lossless."""
    planes = [z.real, z.imag, q.real]
    if not q_real:
        planes.append(q.imag)
    planes.append(mask.astype(jnp.float32))
    return jnp.stack(planes, axis=2)


def _unpack_particles(buf: jnp.ndarray, dtype, q_real: bool = False):
    """Inverse of :func:`_pack_particles` (on an exchanged, halo'd buffer)."""
    z = (buf[:, :, 0] + 1j * buf[:, :, 1]).astype(dtype)
    if q_real:
        q = (buf[:, :, 2] + 0j).astype(dtype)
        m = buf[:, :, 3] > 0.5
    else:
        q = (buf[:, :, 2] + 1j * buf[:, :, 3]).astype(dtype)
        m = buf[:, :, 4] > 0.5
    return z, q, m


def _parallel_fmm_body(z, q, mask, *extra, plan: BlockPlan, l_cut: int,
                       p: int, sigma, axis_name: str, use_kernels: bool,
                       overlap: bool, eq, pipeline: bool = False,
                       prefetched: bool = False, with_health: bool = False,
                       faults: tuple = ()):
    """Runs on each device over its padded (rows_max, cols_max, s) tile.

    ``overlap=True`` runs the interior/rim pipeline (DESIGN.md §9): every
    halo collective is issued before the compute that can hide it — the
    packed P2P exchange before the upward sweep, the per-level M2L
    exchanges before the root-tree work — and each exchanged buffer is
    consumed only by the thin rim strips, while the tile interiors (the
    bulk of the work) depend on local data alone.  ``overlap=False`` keeps
    the monolithic ordering: each exchange completes into a buffer the
    whole tile's compute then reads (the paper's serial comm-plus-compute
    model, Eqs 16-20).  Both orderings share the identical slab
    implementations and agree to f32 roundoff.

    ``pipeline=True`` additionally defers the first CONSUMPTION of the
    cut-level ``all_gather`` (DESIGN.md §12): every sharded level's M2L
    output is computed right after the gather is issued — it depends only
    on local MEs and the per-level exchanges — so the gather's flight time
    hides behind the bulk of the downward sweep instead of serializing in
    front of the replicated root tree; the root-tree sweep then runs at
    the gathered buffer's first use and the precomputed M2L outputs fold
    into the L2L chain unchanged (same adds, same order: the two orderings
    trace the same ops).  ``pipeline=False`` traces exactly the pre-§12
    program.

    ``prefetched=True`` means the LAST positional argument is the packed
    P2P halo buffer already exchanged by
    :func:`parallel_fmm_p2p_prefetch` (the cross-substep double buffer);
    the body then skips its own exchange round but still applies fault
    injection and the health sentinel to the buffer, so the guarded paths
    see identical data either way.

    Everything kernel-specific — charge map, translation operators, packed
    P2P payload width, L2P modes, output arity — comes from the equation
    spec ``eq``; ``targets``, when present, is the ``(z_t, mask_t)`` pair
    of a passive target tile evaluated against the sources' expansions and
    near field (same plan, same halos).
    """
    extra = list(extra)
    p2p_pre = extra.pop() if prefetched else None
    zt, mt = extra if extra else (None, None)
    L = plan.level
    Pr, Pc = plan.grid
    rows_max, cols_max = plan.rows_max, plan.cols_max
    dtype = z.dtype
    if eq.q_is_real:
        # the packed halo drops the Im q plane; project the LOCAL charges
        # too so interior and rim interactions read identical data even
        # when the tree was built with a mismatched complex charge_scale
        # (serial fmm_evaluate applies the same projection)
        q = (q.real + 0j).astype(dtype)

    m2l_slab = fmm.m2l_slab_fn(p, use_kernels, eq)
    m2l_grid = fmm.m2l_grid_fn(p, use_kernels, eq)
    p2p_slab = fmm.p2p_slab_fn(use_kernels, eq)

    # static per-device tile records, looked up by device index
    di = jax.lax.axis_index(axis_name)
    dev = np.arange(Pr * Pc)
    my_row0 = jnp.asarray(np.asarray(plan.row0, np.int32)[dev // Pc])[di]
    my_rows = jnp.asarray(np.asarray(plan.rows, np.int32)[dev // Pc])[di]
    my_col0 = jnp.asarray(np.asarray(plan.col0, np.int32)[dev % Pc])[di]
    my_cols = jnp.asarray(np.asarray(plan.cols, np.int32)[dev % Pc])[di]

    def halo(x, width, rows_valid, cols_valid):
        return _tile_halo(x, width, rows_valid, cols_valid, axis_name,
                          (Pr, Pc))

    # ---- P2P halo: ONE packed exchange round (z, q, mask ride together) ---
    # Issued first under ``overlap`` so the collective is in flight through
    # the entire upward sweep; only the rim strips of the near field read
    # it.  The payload width is spec-dependent (real-charge equations drop
    # the Im q plane); targets are tile-local and exchange nothing.  A
    # prefetched buffer (the cross-substep double buffer, DESIGN.md §12)
    # replaces the exchange but not the fault/health plumbing downstream.
    if p2p_pre is not None:
        p2p_buf = p2p_pre
    else:
        p2p_buf = halo(_pack_particles(z, q, mask, eq.q_is_real), 1,
                       my_rows, my_cols)
    p2p_buf = _faults.corrupt_halo(p2p_buf, faults, di, (Pr, Pc))
    halo_bad = hw.nonfinite(p2p_buf) if with_health else None
    z_buf, q_buf, m_buf = _unpack_particles(p2p_buf, dtype, eq.q_is_real)

    # centers padded below/right so the dynamic slice never clamps
    centers = jnp.asarray(box_centers(L), dtype=dtype)
    centers = jnp.pad(centers, ((0, rows_max), (0, cols_max)))
    my_centers = jax.lax.dynamic_slice(centers, (my_row0, my_col0),
                                       (rows_max, cols_max))

    # ---- upward sweep -----------------------------------------------------
    # Padding rows/cols have mask=False everywhere, so their MEs are exactly
    # zero and M2M keeps them zero at every coarser tile level.
    mop = eq.m2m_operator(p)
    me = {L: ex.p2m(z, q, mask, my_centers, box_size(L), p,
                    coeff=eq.p2m_coeff(p))}
    for lv in range(L, l_cut, -1):
        me[lv - 1] = ex.m2m(me[lv], p, op=mop)

    # overlap: issue every sharded level's M2L exchange now, before the
    # root-tree gather/compute and the tile interiors that can hide them
    me_bufs = {}
    if overlap:
        for lv in range(l_cut + 1, L + 1):
            shift = L - lv
            me_bufs[lv] = halo(me[lv], ex.M2L_HALO, my_rows >> shift,
                               my_cols >> shift)
            if with_health:
                halo_bad = jnp.maximum(halo_bad, hw.nonfinite(me_bufs[lv]))

    # gather the cut level -> replicated root tree (paper's M2M to root);
    # unequal tiles are reassembled by the plan's static 2-D owner maps.
    cut_shift = L - l_cut
    gathered = jax.lax.all_gather(me[l_cut], axis_name, axis=0, tiled=False)

    def sharded_m2l(lv, bad):
        """One sharded level's M2L: interior+rim under ``overlap``, else the
        monolithic exchange-then-slab (local MEs only — no gather input)."""
        shift = L - lv
        rv, cv = my_rows >> shift, my_cols >> shift
        if overlap:
            return fmm.m2l_tile_overlapped(m2l_slab, me[lv], me_bufs[lv],
                                           lv, rv, cv), bad
        me_buf = halo(me[lv], ex.M2L_HALO, rv, cv)
        if with_health:
            bad = jnp.maximum(bad, hw.nonfinite(me_buf))
        return m2l_slab(me_buf, lv, col_halo=ex.M2L_HALO), bad

    # pipeline (DESIGN.md §12): consume NOTHING from the gather yet — every
    # sharded level's M2L reads only local MEs and the per-level exchanges,
    # so this bulk compute hides the all_gather's flight time.  The outputs
    # fold into the L2L chain below with the same adds in the same order.
    le_m2l: dict[int, jnp.ndarray] = {}
    if pipeline:
        for lv in range(l_cut + 1, L + 1):
            le_m2l[lv], halo_bad = sharded_m2l(lv, halo_bad)

    # first consumption of the gathered buffer: the replicated root tree
    owner, loc_r, loc_c = plan.tile_maps(cut_shift)
    me_cut_full = gathered[jnp.asarray(owner), jnp.asarray(loc_r),
                           jnp.asarray(loc_c)]
    me_rep = {l_cut: me_cut_full}
    for lv in range(l_cut, 0, -1):
        me_rep[lv - 1] = ex.m2m(me_rep[lv], p, op=mop)

    # ---- downward sweep ---------------------------------------------------
    # replicated root-tree levels 2 .. l_cut (same folded path, zero ghosts)
    le_rep: dict[int, jnp.ndarray] = {}
    for lv in range(2, l_cut + 1):
        le_rep[lv] = m2l_grid(me_rep[lv], lv)
        if lv > 2:
            le_rep[lv] = le_rep[lv] + ex.l2l(le_rep[lv - 1], p)

    def slice_tile(grid_lv, shift):
        """My padded tile out of a replicated level grid."""
        rmax, cmax = rows_max >> shift, cols_max >> shift
        padded = jnp.pad(grid_lv, ((0, rmax), (0, cmax)) +
                         ((0, 0),) * (grid_lv.ndim - 2))
        return jax.lax.dynamic_slice(
            padded, (my_row0 >> shift, my_col0 >> shift) +
            (0,) * (grid_lv.ndim - 2),
            (rmax, cmax) + grid_lv.shape[2:])

    # sharded levels l_cut+1 .. L: exchange ±M2L_HALO ghost rows/columns at
    # the valid tile edges, then the identical slab implementation.  Tiles
    # are even-aligned on both axes at every sharded level (plan parity +
    # sharded_depth), so row0=col0=0 anchors the correct parity and the
    # 2-line halo suffices.
    le_prev = None  # my tile's LE at the previous (coarser) level
    if L > l_cut:
        le_prev = slice_tile(le_rep[l_cut], cut_shift)
    for lv in range(l_cut + 1, L + 1):
        if pipeline:
            le_lv = le_m2l[lv]
        else:
            le_lv, halo_bad = sharded_m2l(lv, halo_bad)
        le_lv = le_lv + ex.l2l(le_prev, p)
        le_prev = le_lv
    le_leaf = le_prev if L > l_cut else slice_tile(le_rep[L], 0)

    # ---- evaluation -------------------------------------------------------
    z_eval = z if zt is None else zt
    far = ex.l2p_eval(le_leaf, z_eval, my_centers, box_size(L), p,
                      eq.l2p_modes)
    if overlap:
        near = fmm.p2p_tile_overlapped(p2p_slab, z, q, mask,
                                       z_buf, q_buf, m_buf,
                                       my_rows, my_cols, sigma, z_tgt=zt)
    else:
        near = p2p_slab(z_buf, q_buf, m_buf, sigma, zt)
    # padded rows/cols (mask=False) are dropped here
    out = fmm._mask_channels(mask if mt is None else mt, far + near)
    out = _faults.corrupt_tile(out, faults, di)
    if not with_health:
        return out
    # per-device health word (flags only at driver level); the caller
    # reduces the stacked (P, N_FIELDS) output with the merge semantics
    health = hw.empty()
    health = hw.with_flag(health, hw.F_HALO, halo_bad)
    health = hw.with_flag(health, hw.F_COEFF,
                          jnp.maximum(hw.nonfinite(me[L]),
                                      hw.nonfinite(le_leaf)))
    health = hw.with_flag(health, hw.F_VEL,
                          hw.nonfinite(out, mask if mt is None else mt))
    return out, health


@functools.partial(jax.jit, static_argnames=("p", "mesh", "mesh_axis",
                                             "use_kernels", "plan",
                                             "overlap", "eq", "with_health",
                                             "faults", "pipeline"))
def parallel_fmm_evaluate(tree: Tree, p: int, mesh: Optional[Mesh] = None,
                          mesh_axis: str = "data",
                          use_kernels: bool = False,
                          plan: Optional[Union[SlabPlan, BlockPlan]] = None,
                          overlap: bool = True, eq=None,
                          targets: Optional[Tree] = None,
                          with_health: bool = False,
                          faults: tuple = (), pipeline: bool = True,
                          p2p_halo: Optional[jnp.ndarray] = None):
    """Distributed FMM evaluation of any registered equation, plan-driven.

    ``plan`` maps devices to contiguous parity-even leaf-row bands
    (:class:`SlabPlan`) or row-x-column tiles (:class:`BlockPlan`) — the
    cost-model partitioner's output; ``plan=None`` falls back to the
    uniform equal-count band strawman (``uniform_plan`` handles any device
    count, including non-dividing P, via base/extra parent rows).  The tree
    is resharded into the plan's padded tile layout, evaluated under
    ``shard_map``, and scattered back to standard layout, so the result is
    independent of the plan to f32 roundoff.  Falls back to a 1-device mesh
    when ``mesh`` is None.  ``use_kernels=True`` routes M2L/P2P through the
    same Pallas kernels the serial driver uses (interpret mode off-TPU) on
    both plan kinds.  ``overlap=True`` (default) executes the interior/rim
    pipeline that hides the halo collectives behind tile-interior compute;
    ``overlap=False`` keeps the monolithic exchange-then-compute ordering.
    Both agree to f32 roundoff on both plan kinds and kernel routes.

    ``eq`` selects the registered equation spec (vortex default); the
    drivers consume only the spec.  ``targets`` — a second :class:`Tree`
    at the same level holding passive target points — is resharded by the
    SAME plan and evaluated against the sources' local expansions and near
    field; the output is then per target slot, (n, n, st[, eq.nout]).

    ``with_health=True`` returns ``(out, health)`` with a global
    ``health.N_FIELDS`` int32 health word: non-finite sentinels on the
    exchanged halo buffers, the expansion coefficients, and the masked
    output, computed per device inside the shard_map body and reduced in
    the same program — the guard costs no extra host sync.  ``faults`` is
    the static tuple of active :class:`~repro.core.faults.FaultSpec`s
    (empty = the exact injection-free program).

    ``pipeline=True`` (default) extends the overlap frontier (DESIGN.md
    §12): the cut-level all_gather's first consumption is deferred past
    all sharded-level M2L compute.  ``pipeline=False`` traces exactly the
    pre-§12 ordering (the bit-identical escape hatch).  ``p2p_halo``, when
    given, is the already-exchanged packed particle buffer from
    :func:`parallel_fmm_p2p_prefetch` (the cross-substep double buffer, in
    device-tile layout): the body consumes it instead of issuing its own
    exchange round.
    """
    eq = _eqs.get_equation(eq)
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    P_ = mesh.shape[mesh_axis]
    n = tree.nside
    if tree.level < 2:
        raise ValueError("parallel FMM requires tree level >= 2")
    if targets is None and eq.needs_targets:
        raise ValueError(f"equation {eq.name!r} requires a targets tree")
    if targets is not None and targets.level != tree.level:
        raise ValueError("targets tree level != source tree level")
    if plan is None:
        plan = uniform_plan(tree.level, P_)
    if plan.level != tree.level:
        raise ValueError(f"plan level {plan.level} != tree level {tree.level}")
    if plan.nparts != P_:
        raise ValueError(f"plan has {plan.nparts} bands for {P_} devices")
    block = plan.as_block() if isinstance(plan, SlabPlan) else plan

    rows_max, cols_max = block.rows_max, block.cols_max
    identity = (block.grid[1] == 1 and block.is_uniform
                and P_ * rows_max == n)
    if identity:
        z_sh, q_sh, m_sh = tree.z, tree.q, tree.mask
        t_sh = () if targets is None else (targets.z, targets.mask)
    else:
        src_r, src_c, valid = block.gather_index()
        src_r, src_c = jnp.asarray(src_r), jnp.asarray(src_c)
        v = jnp.asarray(valid)[:, :, None]
        z_sh = jnp.where(v, tree.z[src_r, src_c], 0)
        q_sh = jnp.where(v, tree.q[src_r, src_c], 0)
        m_sh = tree.mask[src_r, src_c] & v
        t_sh = () if targets is None else (
            jnp.where(v, targets.z[src_r, src_c], 0),
            targets.mask[src_r, src_c] & v)

    l_cut = block.level - block.sharded_depth()
    pre = () if p2p_halo is None else (p2p_halo,)
    if pre:
        planes = 4 if eq.q_is_real else 5
        want = (P_ * (rows_max + 2), cols_max + 2, planes, tree.slots)
        if tuple(p2p_halo.shape) != want:
            raise ValueError(f"p2p_halo shape {tuple(p2p_halo.shape)} does "
                             f"not match plan/equation (expected {want})")
    body = functools.partial(_parallel_fmm_body, plan=block, l_cut=l_cut, p=p,
                             sigma=tree.sigma, axis_name=mesh_axis,
                             use_kernels=use_kernels, overlap=overlap, eq=eq,
                             pipeline=pipeline, prefetched=bool(pre),
                             with_health=with_health, faults=faults)
    spec = P(mesh_axis, None, None)
    out_spec = spec if eq.nout == 1 else P(mesh_axis, None, None, None)
    if with_health:
        out_spec = (out_spec, P(mesh_axis))
    # pallas_call has no shard_map replication rule; disable the check on
    # the kernel route (numerics are unaffected — outputs stay sharded).
    kwargs = {_CHECK_KW: False} if (use_kernels and _CHECK_KW) else {}
    pre_spec = (P(mesh_axis, None, None, None),) * len(pre)
    fn = _shard_map(body, mesh=mesh,
                    in_specs=(spec,) * (3 + len(t_sh)) + pre_spec,
                    out_specs=out_spec, **kwargs)
    if with_health:
        w, h = fn(z_sh, q_sh, m_sh, *t_sh, *pre)
        health = hw.device_combine(h.reshape(P_, hw.N_FIELDS))
    else:
        w = fn(z_sh, q_sh, m_sh, *t_sh, *pre)
    if not identity:
        sct_r, sct_c = block.scatter_index()
        w = w[jnp.asarray(sct_r), jnp.asarray(sct_c)]
    return (w, health) if with_health else w


@functools.partial(jax.jit, static_argnames=("mesh", "mesh_axis", "plan",
                                             "eq"))
def parallel_fmm_p2p_prefetch(tree: Tree, mesh: Optional[Mesh] = None,
                              mesh_axis: str = "data",
                              plan: Optional[Union[SlabPlan,
                                                   BlockPlan]] = None,
                              eq=None) -> jnp.ndarray:
    """Issue ONLY the packed (z, q, mask) P2P halo exchange for ``tree``.

    The cross-substep double buffer (DESIGN.md §12): the RK2 stepper calls
    this the moment substep k+1's rebinned particles exist — while substep
    k's trailing reductions are still pending — and hands the result to
    :func:`parallel_fmm_evaluate` via ``p2p_halo``, which then consumes the
    buffer instead of issuing its own round.  Under an async-collective
    backend the exchange's flight time hides behind everything traced
    between issue and first rim use (the guard reductions, the next
    evaluation's resharding and upward sweep).  The exchanged bytes are
    identical to the inline round — fault injection and the health
    sentinel are applied by the CONSUMER, exactly as on the inline path,
    so recovery semantics don't change.

    Returns the halo'd packed buffer in device-tile layout,
    ``(P * (rows_max + 2), cols_max + 2, planes, slots)``; the plan/mesh
    fallbacks mirror :func:`parallel_fmm_evaluate` so the pair always
    agrees on the layout.
    """
    eq = _eqs.get_equation(eq)
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    P_ = mesh.shape[mesh_axis]
    if plan is None:
        plan = uniform_plan(tree.level, P_)
    block = plan.as_block() if isinstance(plan, SlabPlan) else plan
    n = tree.nside
    rows_max, cols_max = block.rows_max, block.cols_max
    identity = (block.grid[1] == 1 and block.is_uniform
                and P_ * rows_max == n)
    if identity:
        z_sh, q_sh, m_sh = tree.z, tree.q, tree.mask
    else:
        src_r, src_c, valid = block.gather_index()
        src_r, src_c = jnp.asarray(src_r), jnp.asarray(src_c)
        v = jnp.asarray(valid)[:, :, None]
        z_sh = jnp.where(v, tree.z[src_r, src_c], 0)
        q_sh = jnp.where(v, tree.q[src_r, src_c], 0)
        m_sh = tree.mask[src_r, src_c] & v
    Pr, Pc = block.grid

    def body(z, q, m):
        if eq.q_is_real:
            q = (q.real + 0j).astype(z.dtype)
        di = jax.lax.axis_index(mesh_axis)
        dev = np.arange(Pr * Pc)
        my_rows = jnp.asarray(np.asarray(block.rows, np.int32)[dev // Pc])[di]
        my_cols = jnp.asarray(np.asarray(block.cols, np.int32)[dev % Pc])[di]
        return _tile_halo(_pack_particles(z, q, m, eq.q_is_real), 1,
                          my_rows, my_cols, mesh_axis, (Pr, Pc))

    spec = P(mesh_axis, None, None)
    fn = _shard_map(body, mesh=mesh, in_specs=(spec,) * 3,
                    out_specs=P(mesh_axis, None, None, None))
    return fn(z_sh, q_sh, m_sh)


# Named jitted entry points the static-analysis layer lowers and checks
# (repro/analysis: trace contracts, SPMD schedule verifier, retrace
# detector).  Keys are stable names — contracts reference entry points by
# name, so renaming a function here is an API change, not a refactor.
TRACE_ENTRY_POINTS = {
    "parallel_fmm_evaluate": parallel_fmm_evaluate,
    "parallel_fmm_p2p_prefetch": parallel_fmm_p2p_prefetch,
}


def parallel_fmm_velocity(tree: Tree, p: int, mesh: Optional[Mesh] = None,
                          mesh_axis: str = "data",
                          use_kernels: bool = False,
                          plan: Optional[Union[SlabPlan, BlockPlan]] = None,
                          overlap: bool = True, with_health: bool = False,
                          faults: tuple = (), pipeline: bool = True,
                          p2p_halo: Optional[jnp.ndarray] = None):
    """Complex velocity W per slot — the vortex-kernel form of
    :func:`parallel_fmm_evaluate` (the registry's bit-compatible default)."""
    return parallel_fmm_evaluate(tree, p, mesh, mesh_axis, use_kernels,
                                 plan, overlap, eq=_eqs.VORTEX,
                                 with_health=with_health, faults=faults,
                                 pipeline=pipeline, p2p_halo=p2p_halo)
