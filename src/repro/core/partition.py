"""Weighted-graph build and load-balancing partitioner (paper §4).

The paper cuts the FMM tree at level k, producing 4^k subtrees, builds a
weighted graph (vertex weight = modeled work, edge weight = modeled
communication) and partitions it with ParMETIS.  ParMETIS is not available
here, so we implement the same pipeline natively:

  * space-filling-curve (Morton) seeding — also the *baseline* uniform
    partition the paper compares against (DPMTA-style equal split),
  * greedy weight-balanced SFC split,
  * Fiduccia–Mattheyses/Kernighan–Lin boundary refinement (min cut subject
    to a balance constraint).

The module is generic: the same engine places FMM subtrees on devices and
MoE experts on expert-parallel ranks (DESIGN.md §4), and `rebalance` folds
measured execution times back into the weights (straggler mitigation /
heterogeneous pools — the paper's "dynamic" load balancing).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .cost_model import (
    ModelParams,
    comm_diagonal,
    comm_lateral,
    comm_particles_boundary,
    work_subtree,
)
from .quadtree import morton_encode


@dataclasses.dataclass
class Graph:
    """Undirected weighted graph in CSR-ish adjacency-list form."""

    vertex_weight: np.ndarray          # (V,) float
    adjacency: list[list[tuple[int, float]]]  # per-vertex [(nbr, edge_w)]

    @property
    def num_vertices(self) -> int:
        return len(self.vertex_weight)

    def edge_cut(self, assign: np.ndarray) -> float:
        cut = 0.0
        for u, nbrs in enumerate(self.adjacency):
            for v, w in nbrs:
                if v > u and assign[u] != assign[v]:
                    cut += w
        return cut

    def part_loads(self, assign: np.ndarray, nparts: int) -> np.ndarray:
        return np.bincount(assign, weights=self.vertex_weight, minlength=nparts)


def build_subtree_graph(counts: np.ndarray, params: ModelParams) -> Graph:
    """Paper §4/§5: subtree graph with modeled work and comm weights.

    counts: (2^L, 2^L) per-leaf-box particle counts.  Vertices are the 4^k
    subtrees in row-major cut-grid order.
    """
    k = params.cut
    nsub = 1 << k
    L = params.level
    sub_leaf = 1 << (L - k)

    vw = work_subtree(counts, params)  # (4^k,)

    lat = comm_lateral(params)
    diag = comm_diagonal(params)
    # particles on each subtree face (for the ghost-particle traffic term)
    csub = counts.reshape(nsub, sub_leaf, nsub, sub_leaf)
    face = {
        "N": csub[:, 0, :, :].sum(axis=-1),   # top row of each subtree
        "S": csub[:, -1, :, :].sum(axis=-1),
        "W": csub[:, :, :, 0].sum(axis=1),
        "E": csub[:, :, :, -1].sum(axis=1),
    }

    adjacency: list[list[tuple[int, float]]] = [[] for _ in range(nsub * nsub)]

    def vid(iy: int, ix: int) -> int:
        return iy * nsub + ix

    for iy in range(nsub):
        for ix in range(nsub):
            for dy, dx in ((0, 1), (1, 0), (1, 1), (1, -1)):
                jy, jx = iy + dy, ix + dx
                if not (0 <= jy < nsub and 0 <= jx < nsub):
                    continue
                if dy == 0:      # E-W lateral
                    ghost = face["E"][iy, ix] + face["W"][jy, jx]
                    w = lat + comm_particles_boundary(params, ghost)
                elif dx == 0:    # N-S lateral
                    ghost = face["S"][iy, ix] + face["N"][jy, jx]
                    w = lat + comm_particles_boundary(params, ghost)
                else:            # diagonal
                    w = diag
                u, v = vid(iy, ix), vid(jy, jx)
                adjacency[u].append((v, w))
                adjacency[v].append((u, w))

    return Graph(vertex_weight=vw.astype(np.float64), adjacency=adjacency)


def morton_order(nsub: int) -> np.ndarray:
    """Row-major vertex ids sorted by Morton code (the SFC traversal)."""
    iy, ix = np.divmod(np.arange(nsub * nsub), nsub)
    codes = morton_encode(ix.astype(np.uint32), iy.astype(np.uint32))
    return np.argsort(codes, kind="stable")


# ---------------------------------------------------------------------------
# Partitioners
# ---------------------------------------------------------------------------


def partition_uniform_sfc(num_vertices: int, nparts: int,
                          order: np.ndarray | None = None) -> np.ndarray:
    """Baseline: equal *count* contiguous SFC split (paper's strawman)."""
    order = np.arange(num_vertices) if order is None else order
    assign = np.empty(num_vertices, dtype=np.int64)
    bounds = np.linspace(0, num_vertices, nparts + 1).astype(int)
    for part in range(nparts):
        assign[order[bounds[part]:bounds[part + 1]]] = part
    return assign


def partition_weighted_sfc(vertex_weight: np.ndarray, nparts: int,
                           order: np.ndarray | None = None) -> np.ndarray:
    """Greedy weight-balanced contiguous split along the SFC."""
    V = len(vertex_weight)
    order = np.arange(V) if order is None else order
    w = vertex_weight[order]
    cum = np.cumsum(w)
    total = cum[-1]
    assign = np.empty(V, dtype=np.int64)
    start = 0
    for part in range(nparts):
        if part == nparts - 1:
            end = V
        else:
            target = total * (part + 1) / nparts
            idx = int(np.searchsorted(cum, target, side="left"))
            # boundary closest to the target load (unbiased for equal weights)
            if idx + 1 <= V and idx >= 1 and \
                    abs(cum[idx - 1] - target) <= abs(cum[min(idx, V - 1)] - target):
                end = idx
            else:
                end = idx + 1
            end = max(end, start + 1)
            end = min(end, V - (nparts - part - 1))
        assign[order[start:end]] = part
        start = end
    return assign


def refine_fm(graph: Graph, assign: np.ndarray, nparts: int,
              imbalance_tol: float = 0.05, max_passes: int = 8,
              comm_scale: float = 1.0) -> np.ndarray:
    """Fiduccia–Mattheyses-style boundary refinement.

    Moves boundary vertices to the adjacent part with the largest gain
    (cut-weight reduction, plus a load-balance gain term) while keeping
    every part's load under (1 + tol) * average.  This is the ParMETIS
    stand-in; passes terminate when no improving move exists.
    """
    assign = assign.copy()
    loads = graph.part_loads(assign, nparts)
    avg = loads.sum() / nparts
    cap = (1.0 + imbalance_tol) * avg
    floor = (1.0 - imbalance_tol) * avg
    vw = graph.vertex_weight

    for _ in range(max_passes):
        moved = 0
        for u in np.argsort(-vw):  # heavy vertices first
            pu = assign[u]
            # balance constraints: never overfill the target NOR drain the
            # source below the floor (else min/max LB collapses on uniform
            # distributions — the paper's own lattice case)
            if loads[pu] - vw[u] < floor:
                continue
            # connectivity of u to each part
            conn = {}
            for v, w in graph.adjacency[u]:
                conn[assign[v]] = conn.get(assign[v], 0.0) + w
            internal = conn.get(pu, 0.0)
            best_gain, best_part = 0.0, pu
            for pv, wv in conn.items():
                if pv == pu:
                    continue
                if loads[pv] + vw[u] > cap:
                    continue
                gain = comm_scale * (wv - internal)
                # balance gain: moving off an overloaded part is worth it
                gain += max(loads[pu] - avg, 0.0) - max(loads[pv] + vw[u] - avg, 0.0)
                if gain > best_gain:
                    best_gain, best_part = gain, pv
            if best_part != pu:
                loads[pu] -= vw[u]
                loads[best_part] += vw[u]
                assign[u] = best_part
                moved += 1
        if moved == 0:
            break
    return assign


def partition(graph: Graph, nparts: int, method: str = "model",
              order: np.ndarray | None = None,
              imbalance_tol: float = 0.05) -> np.ndarray:
    """Produce a subtree -> part assignment.

    method='uniform-sfc'  equal-count SFC split (baseline; no cost model)
    method='sfc'          weight-balanced SFC split (model, no refinement)
    method='model'        weight-balanced SFC seed + FM min-cut refinement
                          (the paper's full pipeline)
    """
    if nparts <= 1:
        return np.zeros(graph.num_vertices, dtype=np.int64)
    nsub = int(round(np.sqrt(graph.num_vertices)))
    if order is None and nsub * nsub == graph.num_vertices:
        order = morton_order(nsub)
    if method == "uniform-sfc":
        return partition_uniform_sfc(graph.num_vertices, nparts, order)
    seed = partition_weighted_sfc(graph.vertex_weight, nparts, order)
    if method == "sfc":
        return seed
    if method == "model":
        return refine_fm(graph, seed, nparts, imbalance_tol)
    raise ValueError(f"unknown partition method: {method}")


# ---------------------------------------------------------------------------
# Quality metrics and dynamic feedback
# ---------------------------------------------------------------------------


def load_balance_metric(graph: Graph, assign: np.ndarray, nparts: int) -> float:
    """Paper Eq (20) on modeled work: min part load / max part load."""
    loads = graph.part_loads(assign, nparts)
    return float(loads.min() / loads.max()) if loads.max() > 0 else 1.0


def partition_stats(graph: Graph, assign: np.ndarray, nparts: int) -> dict:
    loads = graph.part_loads(assign, nparts)
    return {
        "edge_cut": graph.edge_cut(assign),
        "load_balance": load_balance_metric(graph, assign, nparts),
        "max_load": float(loads.max()),
        "mean_load": float(loads.mean()),
        "imbalance": float(loads.max() / loads.mean()) if loads.mean() else 1.0,
    }


def measured_rates(loads: np.ndarray, measured_times: np.ndarray) -> np.ndarray:
    """Per-part slowdown rate (seconds per modeled work unit).

    If part p ran ``measured_times[p]`` seconds for modeled load W_p, its
    rate is t_p / W_p; empty or unmeasured parts inherit the mean positive
    rate.  This is the feedback signal both ``rebalance`` (2-D subtree
    weights) and ``plan.replan`` (1-D row-band weights) apply.
    """
    loads = np.asarray(loads, dtype=np.float64)
    t = np.asarray(measured_times, dtype=np.float64)
    rate = np.where(loads > 0, t / np.maximum(loads, 1e-30), 0.0)
    return np.where(rate > 0, rate,
                    rate[rate > 0].mean() if (rate > 0).any() else 1.0)


def rebalance(graph: Graph, assign: np.ndarray, nparts: int,
              measured_times: np.ndarray,
              imbalance_tol: float = 0.05) -> np.ndarray:
    """Dynamic feedback: fold measured per-part times into the weights.

    Every vertex in part p gets its weight scaled by p's ``measured_rates``
    slowdown before re-partitioning.  This reproduces the DPMTA-style
    measured rebalancing the paper discusses (§4) but keeps it
    model-driven, and doubles as straggler mitigation in the trainer.
    """
    rate = measured_rates(graph.part_loads(assign, nparts), measured_times)
    scaled = Graph(vertex_weight=graph.vertex_weight * rate[assign],
                   adjacency=graph.adjacency)
    return partition(scaled, nparts, method="model", imbalance_tol=imbalance_tol)
