"""Execution plans: the cost model's partition mapped onto slab bands.

The partitioner (core/partition.py) reproduces the paper's §4 pipeline —
weighted subtree graph, SFC seed, FM refinement, measured-time rebalance —
but the sharded driver executes *row slabs* of the dense leaf grid
(DESIGN.md §3, "mode A").  A :class:`SlabPlan` is the bridge: the modeled
per-row work (the 1-D projection of Eqs 13-15) is collapsed into contiguous,
parity-even leaf-row bands of *unequal* height, one per device, padded to a
common ``rows_max`` so shapes stay static under ``shard_map``.

The plan is a **static** (hashable) artifact: ``parallel_fmm_velocity`` jits
per plan, and the per-device ``row0`` / ``rows_valid`` records become
constant lookup tables indexed by ``axis_index`` inside the shard_map body.

Eq (20)'s min/max metric on modeled band loads (``plan_stats``) is the
quantity the model plan must win on versus the uniform strawman; the
benchmark harness and tests/test_partition.py pin this on the paper's own
Lamb-Oseen lattice.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import cost_model as cm
from .cost_model import ModelParams
from . import partition as pt


@dataclasses.dataclass(frozen=True)
class SlabPlan:
    """Contiguous, parity-even leaf-row bands, one per device.

    ``row0[d]`` is the global leaf row where device ``d``'s band starts and
    ``rows[d]`` its valid height; bands tile ``[0, 2**level)`` exactly.
    Every ``row0``/``rows`` is even so each band is aligned to parent rows
    (the folded M2L's 2-row halo contract, DESIGN.md §4) and M2M below the
    band never crosses a device boundary.  Execution pads every band to
    ``rows_max`` rows; the padding carries ``mask=False`` slots and zero
    expansions and is masked out of P2P/L2P.
    """

    level: int
    row0: tuple[int, ...]
    rows: tuple[int, ...]

    def __post_init__(self):
        n = 1 << self.level
        if len(self.row0) != len(self.rows) or not self.rows:
            raise ValueError("row0 and rows must be equal-length, non-empty")
        expect = 0
        for d, (r0, r) in enumerate(zip(self.row0, self.rows)):
            if r0 != expect:
                raise ValueError(f"band {d} starts at {r0}, expected {expect}"
                                 " (bands must be contiguous)")
            if r <= 0 or r % 2 or r0 % 2:
                raise ValueError(f"band {d} (row0={r0}, rows={r}) must be a"
                                 " positive parity-even band")
            expect = r0 + r
        if expect != n:
            raise ValueError(f"bands cover {expect} rows, grid has {n}")

    # -- static geometry ----------------------------------------------------

    @property
    def nparts(self) -> int:
        return len(self.rows)

    @property
    def nside(self) -> int:
        return 1 << self.level

    @property
    def rows_max(self) -> int:
        return max(self.rows)

    @property
    def is_uniform(self) -> bool:
        return len(set(self.rows)) == 1

    def alignment(self) -> int:
        """Largest ``m`` with every band boundary divisible by ``2**m``.

        The sharded driver may shard levels ``L-m+1 .. L`` (each needs the
        band to stay even-aligned after ``L-lv`` halvings)."""
        m = 1
        while all(r0 % (1 << (m + 1)) == 0 for r0 in self.row0) and \
                all(r % (1 << (m + 1)) == 0 for r in self.rows):
            m += 1
        return m

    # -- host-side index maps (all static numpy; plan is jit-static) --------

    def owner_of_row(self) -> np.ndarray:
        """(n,) device owning each global leaf row."""
        return np.repeat(np.arange(self.nparts), np.asarray(self.rows))

    def gather_index(self) -> tuple[np.ndarray, np.ndarray]:
        """Standard layout -> plan layout: ``(P*rows_max,)`` source row per
        padded slot plus a validity mask (False on padding rows)."""
        P, rmax = self.nparts, self.rows_max
        idx = np.zeros(P * rmax, dtype=np.int64)
        valid = np.zeros(P * rmax, dtype=bool)
        for d, (r0, r) in enumerate(zip(self.row0, self.rows)):
            idx[d * rmax:d * rmax + r] = r0 + np.arange(r)
            valid[d * rmax:d * rmax + r] = True
        return idx, valid

    def scatter_index(self) -> np.ndarray:
        """Plan layout -> standard layout: ``(n,)`` padded-slot per row."""
        owner = self.owner_of_row()
        r0 = np.asarray(self.row0)[owner]
        return owner * self.rows_max + (np.arange(self.nside) - r0)

    def band_row_maps(self, shift: int) -> tuple[np.ndarray, np.ndarray]:
        """Owner and band-local index of every grid row at level ``L-shift``.

        Requires all band boundaries divisible by ``2**shift`` (see
        ``alignment``); used to reassemble unequal bands after the
        cut-level ``all_gather``."""
        n_lv = self.nside >> shift
        owner = self.owner_of_row()[np.arange(n_lv) << shift]
        local = np.arange(n_lv) - (np.asarray(self.row0)[owner] >> shift)
        return owner, local

    def describe(self) -> str:
        return " ".join(f"[{r0}:{r0 + r})" for r0, r in zip(self.row0, self.rows))


# ---------------------------------------------------------------------------
# Plan construction from the cost model
# ---------------------------------------------------------------------------


def uniform_plan(level: int, nparts: int) -> SlabPlan:
    """The strawman: equal-count parity-even bands (DPMTA-style split)."""
    R = (1 << level) // 2                      # parent rows
    if nparts > R:
        raise ValueError(f"{nparts} parts need >= {2 * nparts} leaf rows"
                         f" (level {level} has {2 * R})")
    base, extra = divmod(R, nparts)
    rows = tuple(2 * (base + (1 if d < extra else 0)) for d in range(nparts))
    row0 = tuple(int(x) for x in np.concatenate([[0], np.cumsum(rows)[:-1]]))
    return SlabPlan(level=level, row0=row0, rows=rows)


def row_loads(counts: np.ndarray, params: ModelParams) -> np.ndarray:
    """Modeled work per *parent* leaf-row pair — Eqs (13)-(15) projected 1-D.

    Leaf work uses the exact per-box Eq (14) (with the true 3x3 neighbor
    P2P product); non-leaf work at levels ``cut..L-1`` is spread uniformly
    over the leaf rows each coarse row covers, matching ``work_subtree``'s
    census so band loads and subtree-graph loads share units.
    """
    n = counts.shape[0]
    L = params.level
    nb = cm.neighbor_count_sum(counts)
    per_row = cm.work_leaf(counts, params.p, neighbor_counts=nb).sum(axis=1)
    for l in range(params.cut, L):
        # 2^l boxes per level-l grid row, spread over 2^(L-l) leaf rows
        per_row = per_row + (2 ** l) * cm.work_nonleaf(params.p) / (2 ** (L - l))
    return per_row.reshape(n // 2, 2).sum(axis=1)


def _bounds_loads(w: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    pre = np.concatenate([[0.0], np.cumsum(w)])
    return pre[bounds[1:]] - pre[bounds[:-1]]


def _quantile_bounds(w: np.ndarray, nparts: int) -> np.ndarray:
    """Weight-quantile seed split (the 1-D analogue of the weighted-SFC
    seed in core/partition.py); every part gets at least one row."""
    assign = pt.partition_weighted_sfc(w, nparts)
    return np.concatenate([[0], np.cumsum(np.bincount(assign,
                                                      minlength=nparts))])


def _balance_key(loads: np.ndarray) -> tuple[float, float]:
    """Lexicographic objective: maximize Eq-20 min/max, then minimize the
    bottleneck.  Smaller is better."""
    mx = float(loads.max())
    ratio = float(loads.min()) / mx if mx > 0 else 1.0
    return (-ratio, mx)


def _refine_bounds(w: np.ndarray, bounds: np.ndarray, nparts: int) -> np.ndarray:
    """Move boundaries one row at a time while ``_balance_key`` improves
    (the 1-D analogue of partition.refine_fm's boundary passes)."""
    bounds = bounds.copy()
    loads = _bounds_loads(w, bounds)
    for _ in range(4 * len(w)):
        best_move, best_key = None, _balance_key(loads)
        for i in range(1, nparts):
            for step in (-1, 1):
                if not bounds[i - 1] < bounds[i] + step < bounds[i + 1]:
                    continue
                trial = loads.copy()
                dw = w[bounds[i] - 1] if step < 0 else w[bounds[i]]
                trial[i - 1] += step * dw
                trial[i] -= step * dw
                k = _balance_key(trial)
                if k < best_key:
                    best_move, best_key = (i, step, trial), k
        if best_move is None:
            break
        i, step, loads = best_move
        bounds[i] += step
    return bounds


def _split_min_max(w: np.ndarray, nparts: int) -> np.ndarray:
    """Balanced contiguous partition of ``w`` into ``nparts`` runs.

    Boundary refinement over the Eq-20 objective from two seeds — the
    weight-quantile split and the uniform equal-count split — keeping the
    better result.  Seeding from uniform guarantees the model plan is never
    worse than the strawman on the modeled metric.
    """
    R = len(w)
    base, extra = divmod(R, nparts)
    uni = np.concatenate([[0], np.cumsum([base + (1 if d < extra else 0)
                                          for d in range(nparts)])])
    cands = [_refine_bounds(w, _quantile_bounds(w, nparts), nparts),
             _refine_bounds(w, uni.astype(np.int64), nparts)]
    return min(cands, key=lambda b: _balance_key(_bounds_loads(w, b)))


def plan_from_counts(counts: np.ndarray, params: ModelParams, nparts: int,
                     method: str = "model",
                     row_weight_scale: np.ndarray | None = None) -> SlabPlan:
    """Collapse the cost model onto parity-even row bands.

    method='uniform'/'uniform-sfc'  equal-count bands (no cost model)
    method='sfc'                    greedy weight-balanced quantile split
    method='model'                  min-max optimal band boundaries

    ``row_weight_scale`` (length ``2**level // 2``, parent-row granularity)
    folds measured-feedback slowdowns into the weights — see ``replan``.
    """
    n = counts.shape[0]
    if n != 1 << params.level:
        raise ValueError(f"counts side {n} != 2**level ({1 << params.level})")
    if nparts <= 1:
        return SlabPlan(level=params.level, row0=(0,), rows=(n,))
    if method in ("uniform", "uniform-sfc"):
        return uniform_plan(params.level, nparts)
    w = row_loads(counts, params)
    if row_weight_scale is not None:
        w = w * np.asarray(row_weight_scale, dtype=np.float64)
    if nparts > len(w):
        raise ValueError(f"{nparts} parts need >= {2 * nparts} leaf rows")
    if method == "sfc":
        assign = pt.partition_weighted_sfc(w, nparts)
        bounds = np.concatenate([[0], np.cumsum(np.bincount(assign, minlength=nparts))])
    elif method == "model":
        bounds = _split_min_max(w, nparts)
    else:
        raise ValueError(f"unknown plan method: {method}")
    rows = tuple(int(2 * (b1 - b0)) for b0, b1 in zip(bounds[:-1], bounds[1:]))
    row0 = tuple(int(2 * b) for b in bounds[:-1])
    return SlabPlan(level=params.level, row0=row0, rows=rows)


# ---------------------------------------------------------------------------
# Quality metrics and dynamic feedback (paper Eq 20 / §4 "dynamic")
# ---------------------------------------------------------------------------


def plan_loads(plan: SlabPlan, counts: np.ndarray, params: ModelParams,
               row_weight_scale: np.ndarray | None = None) -> np.ndarray:
    """Modeled work per band under the current particle distribution."""
    w = row_loads(counts, params)
    if row_weight_scale is not None:
        w = w * np.asarray(row_weight_scale, dtype=np.float64)
    bounds = np.concatenate([[0], np.cumsum(np.asarray(plan.rows) // 2)])
    return _bounds_loads(w, bounds)


def plan_stats(plan: SlabPlan, counts: np.ndarray, params: ModelParams) -> dict:
    """Eq (20) min/max load balance + load summary, next to partition_stats."""
    loads = plan_loads(plan, counts, params)
    return {
        "load_balance": float(loads.min() / loads.max()) if loads.max() > 0 else 1.0,
        "max_load": float(loads.max()),
        "mean_load": float(loads.mean()),
        "min_load": float(loads.min()),
        "rows": list(plan.rows),
    }


def replan(counts: np.ndarray, params: ModelParams, nparts: int,
           prev_plan: SlabPlan | None = None,
           measured_times: np.ndarray | None = None,
           method: str = "model") -> SlabPlan:
    """Dynamic re-planning: current counts + measured per-device times.

    Without measurements this is a pure a-priori re-plan from the drifted
    particle distribution.  With ``measured_times`` the per-band slowdown
    rates (``partition.measured_rates`` — the same feedback ``rebalance``
    applies to subtree vertices) scale each band's rows before the min-max
    re-split, so a slow device sheds rows exactly as the paper's dynamic
    rebalancing sheds subtrees.
    """
    scale = None
    if measured_times is not None and prev_plan is not None:
        scale = measured_row_scale(prev_plan, counts, params, measured_times)
    return plan_from_counts(counts, params, nparts, method=method,
                            row_weight_scale=scale)


def measured_row_scale(plan: SlabPlan, counts: np.ndarray,
                       params: ModelParams,
                       measured_times: np.ndarray) -> np.ndarray:
    """Per-parent-row slowdown factors implied by measured band times —
    the weight scaling both ``replan`` and the stepper's adoption test
    must share (diverging formulas would re-split on different weights)."""
    loads = plan_loads(plan, counts, params)
    rates = pt.measured_rates(loads, np.asarray(measured_times, np.float64))
    return rates[plan.owner_of_row()[::2]]


def assignment_from_plan(plan: SlabPlan, cut: int) -> np.ndarray:
    """Majority-owner subtree assignment implied by the bands.

    Lets the stepper keep a 2-D subtree assignment in sync with the 1-D
    execution plan so ``partition.rebalance`` / ``partition_stats`` can run
    on the same graph the paper partitions.
    """
    nsub = 1 << cut
    sub_rows = plan.nside // nsub
    owner = plan.owner_of_row()
    # majority owner of the leaf rows under each cut-grid row
    row_owner = np.empty(nsub, dtype=np.int64)
    for t in range(nsub):
        block = owner[t * sub_rows:(t + 1) * sub_rows]
        row_owner[t] = np.bincount(block).argmax()
    return np.repeat(row_owner, nsub)
