"""Execution plans: the cost model's partition mapped onto device tiles.

The partitioner (core/partition.py) reproduces the paper's §4 pipeline —
weighted subtree graph, SFC seed, FM refinement, measured-time rebalance —
and two plan artifacts map it onto the dense leaf grid the sharded driver
executes (DESIGN.md §3, "mode A" / §8):

* :class:`SlabPlan` — 1-D: the per-row projection of Eqs 13-15 collapsed
  into contiguous, parity-even leaf-row bands of *unequal* height, one per
  device, padded to a common ``rows_max``;
* :class:`BlockPlan` — 2-D: a ``Pr x Pc`` device grid of contiguous,
  parity-even row-x-column tiles of unequal size (a tensor-product grid, so
  every tile's four lateral neighbors own matching extents and the halo
  exchange stays single-hop on both axes).  Boundaries come from recursive
  min/max splitting of the 2-D Eq 13-15 cost field (``cell_loads``) and are
  then refined under ``partition.refine_fm``'s objective — cut-weight
  reduction subject to a balance guard — applied *directly* to the 2-D
  boundary moves instead of via the 1-D majority collapse a SlabPlan needs.

Both plans are **static** (hashable) artifacts: ``parallel_fmm_velocity``
jits per plan, and the per-device ``row0/rows`` (± ``col0/cols``) records
become constant lookup tables indexed by ``axis_index`` inside the
shard_map body.

Eq (20)'s min/max metric on modeled tile loads (``plan_stats``) is the
quantity the model plan must win on versus the uniform strawman, and
``halo_volume`` prices the ppermute traffic each plan implies — the 2-D
block plan's whole reason to exist (ROADMAP "2-D execution plans"); the
benchmark harness and tests/test_partition.py pin both on the paper's own
Lamb-Oseen lattice.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import cost_model as cm
from .cost_model import ModelParams
from . import partition as pt


@dataclasses.dataclass(frozen=True)
class SlabPlan:
    """Contiguous, parity-even leaf-row bands, one per device.

    ``row0[d]`` is the global leaf row where device ``d``'s band starts and
    ``rows[d]`` its valid height; bands tile ``[0, 2**level)`` exactly.
    Every ``row0``/``rows`` is even so each band is aligned to parent rows
    (the folded M2L's 2-row halo contract, DESIGN.md §4) and M2M below the
    band never crosses a device boundary.  Execution pads every band to
    ``rows_max`` rows; the padding carries ``mask=False`` slots and zero
    expansions and is masked out of P2P/L2P.
    """

    level: int
    row0: tuple[int, ...]
    rows: tuple[int, ...]

    def __post_init__(self):
        n = 1 << self.level
        if len(self.row0) != len(self.rows) or not self.rows:
            raise ValueError("row0 and rows must be equal-length, non-empty")
        expect = 0
        for d, (r0, r) in enumerate(zip(self.row0, self.rows)):
            if r0 != expect:
                raise ValueError(f"band {d} starts at {r0}, expected {expect}"
                                 " (bands must be contiguous)")
            if r <= 0 or r % 2 or r0 % 2:
                raise ValueError(f"band {d} (row0={r0}, rows={r}) must be a"
                                 " positive parity-even band")
            expect = r0 + r
        if expect != n:
            raise ValueError(f"bands cover {expect} rows, grid has {n}")

    # -- static geometry ----------------------------------------------------

    @property
    def nparts(self) -> int:
        return len(self.rows)

    @property
    def nside(self) -> int:
        return 1 << self.level

    @property
    def rows_max(self) -> int:
        return max(self.rows)

    @property
    def is_uniform(self) -> bool:
        return len(set(self.rows)) == 1

    # -- host-side index maps (all static numpy; plan is jit-static) --------

    def owner_of_row(self) -> np.ndarray:
        """(n,) device owning each global leaf row."""
        return np.repeat(np.arange(self.nparts), np.asarray(self.rows))

    def gather_index(self) -> tuple[np.ndarray, np.ndarray]:
        """Standard layout -> plan layout: ``(P*rows_max,)`` source row per
        padded slot plus a validity mask (False on padding rows).

        Delegates to the 2-D maps of the ``Pr x 1`` block view — the index
        algebra the driver actually executes — so the 1-D contract can
        never diverge from it (a slab's columns span the full width, hence
        column 0 carries the whole per-row record)."""
        src_r, _, valid = self.as_block().gather_index()
        return src_r[:, 0].copy(), valid[:, 0].copy()

    def scatter_index(self) -> np.ndarray:
        """Plan layout -> standard layout: ``(n,)`` padded-slot per row."""
        return self.as_block().scatter_index()[0][:, 0].copy()

    def describe(self) -> str:
        return " ".join(f"[{r0}:{r0 + r})" for r0, r in zip(self.row0, self.rows))

    def as_block(self) -> "BlockPlan":
        """This plan as the ``Pr x 1`` special case of a :class:`BlockPlan`
        (the sharded driver executes both kinds through the one 2-D path)."""
        return BlockPlan(level=self.level, row0=self.row0, rows=self.rows,
                         col0=(0,), cols=(self.nside,))

    def sharded_depth(self, min_rows: int = 4) -> int:
        """How many levels (from the leaves up) the bands can shard."""
        return self.as_block().sharded_depth(min_rows)

    def interior_extents(self, w: int) -> tuple[tuple[int, int], ...]:
        """Per-device overlap-interior extents (see BlockPlan)."""
        return self.as_block().interior_extents(w)

    def rim_owners(self) -> tuple[tuple[int, int, int, int], ...]:
        """Per-device rim ghost owners (see BlockPlan)."""
        return self.as_block().rim_owners()


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """A ``Pr x Pc`` device grid of contiguous, parity-even leaf tiles.

    Device ``d = i * Pc + j`` owns the rectangle
    ``[row0[i], row0[i] + rows[i]) x [col0[j], col0[j] + cols[j])``.
    Row bands and column bands form a tensor-product grid: a tile's north/
    south neighbors own the same column range and its east/west neighbors
    the same row range, so the two-axis halo exchange is single-hop and the
    corner (diagonal) ghosts ride along on the second axis's strips (the
    strips carry the already-attached first-axis halos).  All ``row0/rows/
    col0/cols`` are even, so every tile is parent-aligned on both axes (the
    folded M2L's 2-row halo contract, DESIGN.md §4/§8).  Execution pads
    every tile to ``(rows_max, cols_max)``; padding carries ``mask=False``
    slots and zero expansions and is masked out of P2P/L2P.
    """

    level: int
    row0: tuple[int, ...]
    rows: tuple[int, ...]
    col0: tuple[int, ...]
    cols: tuple[int, ...]

    def __post_init__(self):
        n = 1 << self.level
        for axis, (b0, bl) in (("row", (self.row0, self.rows)),
                               ("col", (self.col0, self.cols))):
            if len(b0) != len(bl) or not bl:
                raise ValueError(f"{axis}0 and {axis}s must be equal-length,"
                                 " non-empty")
            expect = 0
            for d, (x0, x) in enumerate(zip(b0, bl)):
                if x0 != expect:
                    raise ValueError(f"{axis} band {d} starts at {x0}, expected"
                                     f" {expect} (bands must be contiguous)")
                if x <= 0 or x % 2 or x0 % 2:
                    raise ValueError(f"{axis} band {d} ({axis}0={x0}, extent="
                                     f"{x}) must be a positive parity-even band")
                expect = x0 + x
            if expect != n:
                raise ValueError(f"{axis} bands cover {expect}, grid has {n}")

    # -- static geometry ----------------------------------------------------

    @property
    def grid(self) -> tuple[int, int]:
        return len(self.rows), len(self.cols)

    @property
    def nparts(self) -> int:
        return len(self.rows) * len(self.cols)

    @property
    def nside(self) -> int:
        return 1 << self.level

    @property
    def rows_max(self) -> int:
        return max(self.rows)

    @property
    def cols_max(self) -> int:
        return max(self.cols)

    @property
    def is_uniform(self) -> bool:
        return len(set(self.rows)) == 1 and len(set(self.cols)) == 1

    def alignment(self) -> int:
        """Largest ``m`` with every tile boundary (both axes) divisible by
        ``2**m`` — levels ``L-m+1 .. L`` keep tiles even-aligned."""
        vals = self.row0 + self.rows + self.col0 + self.cols
        m = 1
        while all(v % (1 << (m + 1)) == 0 for v in vals):
            m += 1
        return m

    def sharded_depth(self, min_rows: int = 4) -> int:
        """How many levels (from the leaves up) the tiles can shard.

        Level ``L - s`` is shardable when every tile boundary stays even
        after ``s`` halvings on both axes and the smallest tile dimension
        keeps ``min_rows`` rows/cols at the coarsest sharded level.
        Parity-even plans always support depth 1 when L >= 3.
        """
        if self.level < 3:
            return 0
        m = 1
        align = self.alignment()
        dmin = min(min(self.rows), min(self.cols))
        while (m + 1 <= align and self.level - (m + 1) >= 2
               and (dmin >> m) >= min_rows):
            m += 1
        return m

    # -- host-side index maps (all static numpy; plan is jit-static) --------

    def owner_of_row(self) -> np.ndarray:
        """(n,) row-band index owning each global leaf row."""
        return np.repeat(np.arange(len(self.rows)), np.asarray(self.rows))

    def owner_of_col(self) -> np.ndarray:
        """(n,) column-band index owning each global leaf column."""
        return np.repeat(np.arange(len(self.cols)), np.asarray(self.cols))

    def gather_index(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Standard layout -> plan layout: per padded slot ``(P*rows_max,
        cols_max)`` source row, source column, and validity mask (False on
        padding rows/cols)."""
        Pr, Pc = self.grid
        rmax, cmax = self.rows_max, self.cols_max
        src_r = np.zeros((Pr * Pc * rmax, cmax), dtype=np.int64)
        src_c = np.zeros((Pr * Pc * rmax, cmax), dtype=np.int64)
        valid = np.zeros((Pr * Pc * rmax, cmax), dtype=bool)
        for i, (r0, r) in enumerate(zip(self.row0, self.rows)):
            for j, (c0, c) in enumerate(zip(self.col0, self.cols)):
                d0 = (i * Pc + j) * rmax
                src_r[d0:d0 + r, :c] = (r0 + np.arange(r))[:, None]
                src_c[d0:d0 + r, :c] = (c0 + np.arange(c))[None, :]
                valid[d0:d0 + r, :c] = True
        return src_r, src_c, valid

    def scatter_index(self) -> tuple[np.ndarray, np.ndarray]:
        """Plan layout -> standard layout: ``(n, n)`` padded row slot and
        column per grid cell (indexes the ``(P*rows_max, cols_max)`` shard
        output)."""
        Pr, Pc = self.grid
        oi = self.owner_of_row()
        oj = self.owner_of_col()
        d = oi[:, None] * Pc + oj[None, :]
        lr = np.arange(self.nside) - np.asarray(self.row0)[oi]
        lc = np.arange(self.nside) - np.asarray(self.col0)[oj]
        sr = d * self.rows_max + lr[:, None]
        sc = np.broadcast_to(lc[None, :], (self.nside, self.nside))
        return sr, np.ascontiguousarray(sc)

    def tile_maps(self, shift: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Device, tile-local row, and tile-local column of every grid cell
        at level ``L - shift`` — the 2-D owner maps that reassemble unequal
        tiles after the cut-level ``all_gather``.  Requires all boundaries
        divisible by ``2**shift`` (see ``alignment``)."""
        Pr, Pc = self.grid
        n_lv = self.nside >> shift
        oi = self.owner_of_row()[np.arange(n_lv) << shift]
        oj = self.owner_of_col()[np.arange(n_lv) << shift]
        owner = oi[:, None] * Pc + oj[None, :]
        lr = np.arange(n_lv) - (np.asarray(self.row0)[oi] >> shift)
        lc = np.arange(n_lv) - (np.asarray(self.col0)[oj] >> shift)
        return (owner,
                np.ascontiguousarray(np.broadcast_to(lr[:, None], owner.shape)),
                np.ascontiguousarray(np.broadcast_to(lc[None, :], owner.shape)))

    def describe(self) -> str:
        r = " ".join(f"[{x0}:{x0 + x})" for x0, x in zip(self.row0, self.rows))
        c = " ".join(f"[{x0}:{x0 + x})" for x0, x in zip(self.col0, self.cols))
        return f"rows {r} x cols {c}"

    # -- interior/rim geometry (overlapped execution, DESIGN.md §9) ---------

    def interior_extents(self, w: int) -> tuple[tuple[int, int], ...]:
        """Per-device (rows, cols) of the overlap *interior* — the boxes at
        least ``w`` rows/cols from every tile edge, whose stencils read
        only local data.  This is the work the overlapped driver computes
        while the halo collectives are in flight.  Device order
        ``d = i * Pc + j``."""
        return tuple((max(r - 2 * w, 0), max(c - 2 * w, 0))
                     for r in self.rows for c in self.cols)

    def rim_owners(self) -> tuple[tuple[int, int, int, int], ...]:
        """Per-device (north, south, west, east) neighbor device supplying
        each rim strip's ghost data, ``-1`` at domain edges (the strip then
        reads zeros, matching the serial zero padding).  Consumed by the
        halo/rim accounting (``_halo_device_stats``), which derives each
        device's exchanged-strip count from it; the driver's ppermute
        pairs are built independently in ``parallel_fmm._tile_halo`` from
        the same ``d = i * Pc + j`` raster layout — change the layout in
        both places.  Device order ``d = i * Pc + j``."""
        Pr, Pc = self.grid
        return tuple(((i - 1) * Pc + j if i > 0 else -1,
                      (i + 1) * Pc + j if i < Pr - 1 else -1,
                      i * Pc + j - 1 if j > 0 else -1,
                      i * Pc + j + 1 if j < Pc - 1 else -1)
                     for i in range(Pr) for j in range(Pc))


# ---------------------------------------------------------------------------
# Plan construction from the cost model
# ---------------------------------------------------------------------------


def uniform_plan(level: int, nparts: int) -> SlabPlan:
    """The strawman: equal-count parity-even bands (DPMTA-style split)."""
    R = (1 << level) // 2                      # parent rows
    if nparts > R:
        raise ValueError(f"{nparts} parts need >= {2 * nparts} leaf rows"
                         f" (level {level} has {2 * R})")
    base, extra = divmod(R, nparts)
    rows = tuple(2 * (base + (1 if d < extra else 0)) for d in range(nparts))
    row0 = tuple(int(x) for x in np.concatenate([[0], np.cumsum(rows)[:-1]]))
    return SlabPlan(level=level, row0=row0, rows=rows)


def cell_loads(counts: np.ndarray, params: ModelParams) -> np.ndarray:
    """Modeled work per *parent cell* (2x2 leaf block) — the 2-D Eq 13-15
    cost field, shape ``(2**level // 2, 2**level // 2)``.

    Leaf work uses the exact per-box Eq (14) (with the true 3x3 neighbor
    P2P product); non-leaf work at levels ``cut..L-1`` is spread uniformly
    over the leaf boxes each coarse box covers, matching ``work_subtree``'s
    census so tile loads and subtree-graph loads share units.
    """
    n = counts.shape[0]
    L = params.level
    nb = cm.neighbor_count_sum(counts)
    per_box = cm.work_leaf(counts, params.p, neighbor_counts=nb,
                           nout=params.nout)
    nonleaf = sum(4 ** l for l in range(params.cut, L)) \
        * cm.work_nonleaf(params.p) / (4 ** L)
    per_box = per_box + nonleaf
    return per_box.reshape(n // 2, 2, n // 2, 2).sum(axis=(1, 3))


def row_loads(counts: np.ndarray, params: ModelParams) -> np.ndarray:
    """Modeled work per *parent* leaf-row pair — ``cell_loads`` projected
    1-D (the quantity SlabPlan boundaries are optimized over)."""
    return cell_loads(counts, params).sum(axis=1)


def _bounds_loads(w: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    pre = np.concatenate([[0.0], np.cumsum(w)])
    return pre[bounds[1:]] - pre[bounds[:-1]]


def _quantile_bounds(w: np.ndarray, nparts: int) -> np.ndarray:
    """Weight-quantile seed split (the 1-D analogue of the weighted-SFC
    seed in core/partition.py); every part gets at least one row."""
    assign = pt.partition_weighted_sfc(w, nparts)
    return np.concatenate([[0], np.cumsum(np.bincount(assign,
                                                      minlength=nparts))])


def _uniform_bounds(length: int, nparts: int) -> np.ndarray:
    """Equal-count contiguous bounds (base/extra split) — the strawman seed
    both the 1-D and 2-D planners refine from."""
    base, extra = divmod(length, nparts)
    return np.concatenate([[0], np.cumsum([base + (1 if d < extra else 0)
                                           for d in range(nparts)])]
                          ).astype(np.int64)


def _balance_key(loads: np.ndarray) -> tuple[float, float]:
    """Lexicographic objective: maximize Eq-20 min/max, then minimize the
    bottleneck.  Smaller is better."""
    mx = float(loads.max())
    ratio = float(loads.min()) / mx if mx > 0 else 1.0
    return (-ratio, mx)


def _refine_bounds(w: np.ndarray, bounds: np.ndarray, nparts: int) -> np.ndarray:
    """Move boundaries one row at a time while ``_balance_key`` improves
    (the 1-D analogue of partition.refine_fm's boundary passes)."""
    bounds = bounds.copy()
    loads = _bounds_loads(w, bounds)
    for _ in range(4 * len(w)):
        best_move, best_key = None, _balance_key(loads)
        for i in range(1, nparts):
            for step in (-1, 1):
                if not bounds[i - 1] < bounds[i] + step < bounds[i + 1]:
                    continue
                trial = loads.copy()
                dw = w[bounds[i] - 1] if step < 0 else w[bounds[i]]
                trial[i - 1] += step * dw
                trial[i] -= step * dw
                k = _balance_key(trial)
                if k < best_key:
                    best_move, best_key = (i, step, trial), k
        if best_move is None:
            break
        i, step, loads = best_move
        bounds[i] += step
    return bounds


def _split_min_max(w: np.ndarray, nparts: int) -> np.ndarray:
    """Balanced contiguous partition of ``w`` into ``nparts`` runs.

    Boundary refinement over the Eq-20 objective from two seeds — the
    weight-quantile split and the uniform equal-count split — keeping the
    better result.  Seeding from uniform guarantees the model plan is never
    worse than the strawman on the modeled metric.
    """
    cands = [_refine_bounds(w, _quantile_bounds(w, nparts), nparts),
             _refine_bounds(w, _uniform_bounds(len(w), nparts), nparts)]
    return min(cands, key=lambda b: _balance_key(_bounds_loads(w, b)))


def plan_from_counts(counts: np.ndarray, params: ModelParams, nparts: int,
                     method: str = "model",
                     row_weight_scale: np.ndarray | None = None,
                     grid: tuple[int, int] | None = None):
    """Collapse the cost model onto parity-even row bands (or 2-D tiles).

    method='uniform'/'uniform-sfc'  equal-count bands (no cost model)
    method='sfc'                    greedy weight-balanced quantile split
    method='model'                  min-max optimal band boundaries

    ``row_weight_scale`` (parent-row granularity for bands, parent-cell
    ``(R, C)`` granularity for tiles) folds measured-feedback slowdowns into
    the weights — see ``replan``.  The uniform strawman carries no cost
    model, but measured feedback still applies: with a scale the equal-count
    split is re-split min/max on the measured slowdown field alone, so a
    dynamic stepper on the strawman sheds rows from a slow device instead of
    silently ignoring its own timer (tests/test_partition.py pins this).

    ``grid=(Pr, Pc)`` routes to :func:`block_plan_from_counts` and returns a
    :class:`BlockPlan` instead (``Pr * Pc`` must equal ``nparts``).
    """
    if grid is not None:
        if grid[0] * grid[1] != nparts:
            raise ValueError(f"grid {grid} has {grid[0] * grid[1]} tiles for"
                             f" {nparts} devices")
        return block_plan_from_counts(counts, params, grid, method=method,
                                      cell_weight_scale=row_weight_scale)
    n = counts.shape[0]
    if n != 1 << params.level:
        raise ValueError(f"counts side {n} != 2**level ({1 << params.level})")
    if nparts <= 1:
        return SlabPlan(level=params.level, row0=(0,), rows=(n,))
    if method in ("uniform", "uniform-sfc") and row_weight_scale is None:
        return uniform_plan(params.level, nparts)
    if method in ("uniform", "uniform-sfc"):
        w = np.ones(n // 2, dtype=np.float64)
    else:
        w = row_loads(counts, params)
    if row_weight_scale is not None:
        w = w * np.asarray(row_weight_scale, dtype=np.float64)
    if nparts > len(w):
        raise ValueError(f"{nparts} parts need >= {2 * nparts} leaf rows")
    if method == "sfc":
        assign = pt.partition_weighted_sfc(w, nparts)
        bounds = np.concatenate([[0], np.cumsum(np.bincount(assign, minlength=nparts))])
    elif method in ("model", "uniform", "uniform-sfc"):
        bounds = _split_min_max(w, nparts)
    else:
        raise ValueError(f"unknown plan method: {method}")
    rows = tuple(int(2 * (b1 - b0)) for b0, b1 in zip(bounds[:-1], bounds[1:]))
    row0 = tuple(int(2 * b) for b in bounds[:-1])
    return SlabPlan(level=params.level, row0=row0, rows=rows)


# ---------------------------------------------------------------------------
# 2-D block plans (tensor-product tile grids)
# ---------------------------------------------------------------------------


def uniform_block_plan(level: int, grid: tuple[int, int]) -> BlockPlan:
    """The 2-D strawman: equal-count parity-even tiles on a Pr x Pc grid."""
    rp = uniform_plan(level, grid[0])
    cp = uniform_plan(level, grid[1])
    return BlockPlan(level=level, row0=rp.row0, rows=rp.rows,
                     col0=cp.row0, cols=cp.rows)


def _prefix2d(W: np.ndarray) -> np.ndarray:
    """Inclusive 2-D prefix-sum table of ``W`` (one row/col of zeros
    prepended) — depends only on the weight field, so boundary-refinement
    loops hoist it once and score every candidate move against it."""
    S = np.zeros((W.shape[0] + 1, W.shape[1] + 1))
    S[1:, 1:] = W.cumsum(axis=0).cumsum(axis=1)
    return S


def _loads_from_prefix(S: np.ndarray, rb: np.ndarray,
                       cb: np.ndarray) -> np.ndarray:
    """(Pr, Pc) tile loads under tensor bounds, from a ``_prefix2d`` table."""
    P = S[np.ix_(rb, cb)]
    return P[1:, 1:] - P[:-1, 1:] - P[1:, :-1] + P[:-1, :-1]


def _grid_tile_loads(W: np.ndarray, rb: np.ndarray, cb: np.ndarray) -> np.ndarray:
    """(Pr, Pc) tile loads of the 2-D weight field under tensor bounds."""
    return _loads_from_prefix(_prefix2d(W), rb, cb)


def _grid_cut_weights(counts: np.ndarray, params: ModelParams
                      ) -> tuple[np.ndarray, np.ndarray]:
    """FM edge-cut field at parent-line granularity.

    ``hw[i, c]``: cost of cutting between parent rows ``i`` and ``i+1``
    within parent column ``c`` (shape ``(R-1, C)``); ``vw[r, j]`` likewise
    for column cuts (shape ``(R, C-1)``).  The expansion term is the Eq-11
    lateral ME/LE traffic (factor 4: both directions, both rings — the same
    constant ``partition.build_subtree_graph`` prices a subtree face with);
    the particle term is Eq's ghost traffic for the two leaf lines adjacent
    to the cut (``comm_particles_boundary``).
    """
    n = counts.shape[0]
    R = n // 2
    a = cm.alpha_comm(params.p, params.coeff_bytes) * 4.0
    colcells = counts.reshape(n, R, 2).sum(axis=-1)        # (n leaf rows, C)
    rowcells = counts.reshape(R, 2, n).sum(axis=1)         # (R, n leaf cols)
    hw = a + cm.PARTICLE_BYTES * (colcells[1:-2:2, :] + colcells[2:-1:2, :])
    vw = a + cm.PARTICLE_BYTES * (rowcells[:, 1:-2:2] + rowcells[:, 2:-1:2])
    return hw, vw


def _grid_edge_cut(hw: np.ndarray, vw: np.ndarray, rb: np.ndarray,
                   cb: np.ndarray) -> float:
    """Total cut weight of the tensor-grid boundaries (interior lines)."""
    cut = sum(float(hw[b - 1, :].sum()) for b in rb[1:-1])
    cut += sum(float(vw[:, b - 1].sum()) for b in cb[1:-1])
    return cut


def _grid_moves(rb: np.ndarray, cb: np.ndarray):
    """All legal ±1 boundary moves (axis, boundary index, step)."""
    for i in range(1, len(rb) - 1):
        for step in (-1, 1):
            if rb[i - 1] < rb[i] + step < rb[i + 1]:
                yield ("r", i, step)
    for j in range(1, len(cb) - 1):
        for step in (-1, 1):
            if cb[j - 1] < cb[j] + step < cb[j + 1]:
                yield ("c", j, step)


def _refine_grid(W: np.ndarray, hw: np.ndarray, vw: np.ndarray,
                 rb: np.ndarray, cb: np.ndarray,
                 imbalance_tol: float = 0.05) -> tuple[np.ndarray, np.ndarray]:
    """Two-phase boundary refinement of a tensor tile grid.

    Phase A moves row/column boundaries one parent line at a time while the
    Eq-20 lexicographic balance key improves (the 2-D analogue of
    ``_refine_bounds``).  Phase B then applies ``partition.refine_fm``'s
    objective directly to the 2-D boundaries: accept the move with the
    largest edge-cut reduction subject to the balance guard (bottleneck no
    worse than ``(1 + tol)`` x and min/max ratio no worse than ``(1 - tol)``
    x the phase-A optimum) — no 1-D majority collapse in the loop.
    """
    rb, cb = rb.copy(), cb.copy()
    S = _prefix2d(W)               # hoisted: W never changes during refinement

    def key(rbounds, cbounds):
        return _balance_key(_loads_from_prefix(S, rbounds, cbounds).ravel())

    def apply(move):
        r2, c2 = rb.copy(), cb.copy()
        axis, i, step = move
        (r2 if axis == "r" else c2)[i] += step
        return r2, c2

    for _ in range(4 * (W.shape[0] + W.shape[1])):
        best = min(((key(*apply(m)), m) for m in _grid_moves(rb, cb)),
                   default=None, key=lambda t: t[0])
        if best is None or best[0] >= key(rb, cb):
            break
        rb, cb = apply(best[1])

    ratio_a, max_a = key(rb, cb)
    for _ in range(4 * (W.shape[0] + W.shape[1])):
        cut0 = _grid_edge_cut(hw, vw, rb, cb)
        best = None
        for m in _grid_moves(rb, cb):
            r2, c2 = apply(m)
            ratio, mx = key(r2, c2)
            if mx > (1.0 + imbalance_tol) * max_a:
                continue
            if -ratio < (1.0 - imbalance_tol) * -ratio_a:
                continue
            cut = _grid_edge_cut(hw, vw, r2, c2)
            if cut < cut0 and (best is None or cut < best[0]):
                best = (cut, m)
        if best is None:
            break
        rb, cb = apply(best[1])
    return rb, cb


def block_plan_from_counts(counts: np.ndarray, params: ModelParams,
                           grid: tuple[int, int], method: str = "model",
                           cell_weight_scale: np.ndarray | None = None
                           ) -> BlockPlan:
    """Recursive min/max split of the 2-D cost field onto a Pr x Pc grid.

    Row bounds are seeded from the row projection of ``cell_loads`` and
    column bounds from the column projection (quantile and uniform seeds,
    as in the 1-D path — seeding from uniform guarantees the model plan
    never scores below the strawman on the modeled metric), then both axes
    are refined jointly under the Eq-20 balance key and the FM edge-cut
    objective (``_refine_grid``).

    ``cell_weight_scale`` (``(R, C)`` parent-cell granularity, or ``(R,)``
    per-parent-row — normalized to a column vector so row slowdowns scale
    rows, matching ``plan_loads``) folds measured-feedback slowdowns into
    the field; as in the 1-D path, the uniform strawman with a scale is
    re-split on the measured field alone.
    """
    if cell_weight_scale is not None:
        cell_weight_scale = np.asarray(cell_weight_scale, dtype=np.float64)
        if cell_weight_scale.ndim == 1:
            cell_weight_scale = cell_weight_scale[:, None]
    Pr, Pc = grid
    n = counts.shape[0]
    if n != 1 << params.level:
        raise ValueError(f"counts side {n} != 2**level ({1 << params.level})")
    if Pr < 1 or Pc < 1:
        raise ValueError(f"grid {grid} must be positive")
    if Pr * Pc == 1:
        return BlockPlan(level=params.level, row0=(0,), rows=(n,),
                         col0=(0,), cols=(n,))
    R = n // 2
    if Pr > R or Pc > R:
        raise ValueError(f"grid {grid} needs >= {2 * max(Pr, Pc)} leaf"
                         f" rows/cols (level {params.level} has {n})")
    if method in ("uniform", "uniform-sfc") and cell_weight_scale is None:
        return uniform_block_plan(params.level, grid)
    if method in ("uniform", "uniform-sfc"):
        W = np.ones((R, R), dtype=np.float64)
    elif method in ("model", "sfc"):
        W = cell_loads(counts, params)
    else:
        raise ValueError(f"unknown plan method: {method}")
    if cell_weight_scale is not None:
        W = W * np.asarray(cell_weight_scale, dtype=np.float64)

    def axis_bounds(w, nparts):
        return [_quantile_bounds(w, nparts), _uniform_bounds(len(w), nparts)]

    seeds = list(zip(axis_bounds(W.sum(axis=1), Pr),
                     axis_bounds(W.sum(axis=0), Pc)))
    if method == "sfc":
        rb, cb = seeds[0]
    else:
        hw, vw = _grid_cut_weights(counts, params)
        cands = [_refine_grid(W, hw, vw, rb, cb) for rb, cb in seeds]
        # keep the raw uniform seed as a candidate: phase B may trade up to
        # `imbalance_tol` of balance for cut, so without it the model plan
        # could score below the strawman on the Eq-20 metric
        cands.append(seeds[1])
        rb, cb = min(cands, key=lambda b: (
            _balance_key(_grid_tile_loads(W, *b).ravel()),
            _grid_edge_cut(hw, vw, *b)))
    return BlockPlan(
        level=params.level,
        row0=tuple(int(2 * b) for b in rb[:-1]),
        rows=tuple(int(2 * (b1 - b0)) for b0, b1 in zip(rb[:-1], rb[1:])),
        col0=tuple(int(2 * b) for b in cb[:-1]),
        cols=tuple(int(2 * (b1 - b0)) for b0, b1 in zip(cb[:-1], cb[1:])))


# ---------------------------------------------------------------------------
# Quality metrics and dynamic feedback (paper Eq 20 / §4 "dynamic")
# ---------------------------------------------------------------------------


def plan_loads(plan, counts: np.ndarray, params: ModelParams,
               weight_scale: np.ndarray | None = None) -> np.ndarray:
    """Modeled work per device under the current particle distribution.

    ``(nparts,)`` in device order for both plan kinds (BlockPlan devices in
    ``d = i * Pc + j`` raster order).  ``weight_scale`` may be per-parent-
    row ``(R,)`` or per-parent-cell ``(R, C)`` regardless of plan kind —
    the mismatched direction is broadcast (rows over cells) or projected
    (cells summed per row), so the grid autotuner and the stepper's
    adoption test can score slab and block candidates with one measured
    scale."""
    if isinstance(plan, BlockPlan):
        W = cell_loads(counts, params)
        if weight_scale is not None:
            ws = np.asarray(weight_scale, dtype=np.float64)
            W = W * (ws[:, None] if ws.ndim == 1 else ws)
        rb = np.concatenate([[0], np.cumsum(np.asarray(plan.rows) // 2)])
        cb = np.concatenate([[0], np.cumsum(np.asarray(plan.cols) // 2)])
        return _grid_tile_loads(W, rb, cb).ravel()
    if weight_scale is not None:
        ws = np.asarray(weight_scale, dtype=np.float64)
        if ws.ndim == 2:
            w = (cell_loads(counts, params) * ws).sum(axis=1)
        else:
            w = row_loads(counts, params) * ws
    else:
        w = row_loads(counts, params)
    bounds = np.concatenate([[0], np.cumsum(np.asarray(plan.rows) // 2)])
    return _bounds_loads(w, bounds)


def plan_stats(plan, counts: np.ndarray, params: ModelParams) -> dict:
    """Eq (20) min/max load balance + load summary, next to partition_stats."""
    loads = plan_loads(plan, counts, params)
    stats = {
        "load_balance": float(loads.min() / loads.max()) if loads.max() > 0 else 1.0,
        "max_load": float(loads.max()),
        "mean_load": float(loads.mean()),
        "min_load": float(loads.min()),
        "rows": list(plan.rows),
    }
    if isinstance(plan, BlockPlan):
        stats["cols"] = list(plan.cols)
        stats["grid"] = plan.grid
    return stats


def replan(counts: np.ndarray, params: ModelParams, nparts: int,
           prev_plan=None, measured_times: np.ndarray | None = None,
           method: str = "model", grid=None, overlap: bool = True,
           pipeline: bool = True):
    """Dynamic re-planning: current counts + measured per-device times.

    Without measurements this is a pure a-priori re-plan from the drifted
    particle distribution.  With ``measured_times`` the per-device slowdown
    rates (``partition.measured_rates`` — the same feedback ``rebalance``
    applies to subtree vertices) scale each device's rows/cells before the
    min-max re-split, so a slow device sheds rows (or tiles) exactly as the
    paper's dynamic rebalancing sheds subtrees.  A :class:`BlockPlan`
    ``prev_plan`` re-plans on its own grid unless ``grid`` overrides it.
    ``grid="auto"`` re-runs the per-axis grid autotuner
    (:func:`autotune_plan`) with the measured scale, so slab vs block and
    ``(Pr, Pc)`` are themselves re-chosen from the drifted distribution
    (``overlap`` and ``pipeline`` select the comm term the score uses —
    they must match the executing driver's flags or the model scores a
    different program than the one that runs).
    """
    if grid == "auto":
        scale = None
        if measured_times is not None and prev_plan is not None:
            scale = measured_row_scale(prev_plan, counts, params,
                                       measured_times)
        return autotune_plan(counts, params, nparts, method=method,
                             cell_weight_scale=scale, overlap=overlap,
                             pipeline=pipeline)
    if grid is None and isinstance(prev_plan, BlockPlan):
        grid = prev_plan.grid
    scale = None
    if measured_times is not None and prev_plan is not None:
        scale = measured_row_scale(prev_plan, counts, params, measured_times)
        if grid is not None and scale.ndim == 1:
            # migrating a 1-D slab plan onto a 2-D grid: the per-parent-row
            # slowdowns apply to every column of the cell field (an (R, 1)
            # column vector broadcasts per-row; a bare (R,) would multiply
            # along the wrong axis)
            scale = scale[:, None]
    return plan_from_counts(counts, params, nparts, method=method,
                            row_weight_scale=scale, grid=grid)


def measured_row_scale(plan, counts: np.ndarray, params: ModelParams,
                       measured_times: np.ndarray) -> np.ndarray:
    """Per-parent-row (bands) or per-parent-cell (tiles) slowdown factors
    implied by measured device times — the weight scaling both ``replan``
    and the stepper's adoption test must share (diverging formulas would
    re-split on different weights)."""
    loads = plan_loads(plan, counts, params)
    rates = pt.measured_rates(loads, np.asarray(measured_times, np.float64))
    if isinstance(plan, BlockPlan):
        Pc = len(plan.cols)
        oi = plan.owner_of_row()[::2]
        oj = plan.owner_of_col()[::2]
        return rates[oi[:, None] * Pc + oj[None, :]]
    return rates[plan.owner_of_row()[::2]]


def assignment_from_plan(plan, cut: int) -> np.ndarray:
    """Subtree assignment implied by the plan's leaf ownership.

    Lets the stepper keep the paper's 2-D subtree assignment in sync with
    the execution plan so ``partition.rebalance`` / ``partition_stats`` can
    run on the same graph the paper partitions.  For a SlabPlan this is the
    majority owner of the leaf rows under each cut-grid row; for a
    BlockPlan the maximum-overlap tile is exact and separable (majority row
    band x majority column band).
    """
    nsub = 1 << cut
    sub = plan.nside // nsub

    def majority(owner_1d):
        out = np.empty(nsub, dtype=np.int64)
        for t in range(nsub):
            out[t] = np.bincount(owner_1d[t * sub:(t + 1) * sub]).argmax()
        return out

    if isinstance(plan, BlockPlan):
        Pc = len(plan.cols)
        oi = majority(plan.owner_of_row())
        oj = majority(plan.owner_of_col())
        return (oi[:, None] * Pc + oj[None, :]).reshape(-1)
    row_owner = majority(plan.owner_of_row())
    return np.repeat(row_owner, nsub)


# ---------------------------------------------------------------------------
# Halo-volume accounting (implementation counterpart of Eqs 11-12, per plan)
# ---------------------------------------------------------------------------


def _halo_device_stats(block: BlockPlan, params: ModelParams,
                       executed: bool) -> tuple[np.ndarray, np.ndarray,
                                                np.ndarray, np.ndarray]:
    """Per-device halo traffic and rim recompute of one FMM evaluation.

    Returns ``(m2l_bytes, p2p_bytes, rim_m2l_boxes, rim_p2p_boxes)``, each
    ``(nparts,)`` in device order.  The byte terms price the two-axis
    ppermute strips (see :func:`halo_volume`); the rim terms count the
    boxes the *overlapped* driver evaluates from the exchanged buffer (the
    four edge strips per sharded M2L level / at the leaves — the work that
    cannot start until the collective lands, DESIGN.md §9)."""
    Pr, Pc = block.grid
    L = params.level
    depth = block.sharded_depth()
    l_cut = L - depth
    a = params.p * params.coeff_bytes
    m2l = np.zeros(Pr * Pc)
    p2p = np.zeros(Pr * Pc)
    rim_m2l = np.zeros(Pr * Pc)
    rim_p2p = np.zeros(Pr * Pc)
    owners = block.rim_owners()       # neighbor topology, -1 at domain edges
    for i in range(Pr):
        for j in range(Pc):
            d = i * Pc + j
            north, south, west, east = owners[d]
            row_nb = (north >= 0) + (south >= 0)     # strips sent up/down
            col_nb = (west >= 0) + (east >= 0)       # strips sent left/right
            for lv in range(l_cut + 1, L + 1):
                shift = L - lv
                w = cm.M2L_HALO_ROWS
                if executed:
                    rext, cext = block.rows_max >> shift, block.cols_max >> shift
                    cext += 2 * w                     # corner-carrying strips
                else:
                    rext = block.rows[i] >> shift
                    cext = (block.cols[j] >> shift) + col_nb * w
                m2l[d] += (col_nb * w * rext + row_nb * w * cext) * a
                rr = (block.rows_max if executed else block.rows[i]) >> shift
                cc = (block.cols_max if executed else block.cols[j]) >> shift
                rim_m2l[d] += 2 * w * (rr + cc)
            w = cm.P2P_HALO_ROWS
            if executed:
                rext, cext = block.rows_max, block.cols_max + 2 * w
            else:
                rext = block.rows[i]
                cext = block.cols[j] + col_nb * w
            p2p[d] += (col_nb * w * rext + row_nb * w * cext) \
                * params.slots * cm.PARTICLE_BYTES
            rr = block.rows_max if executed else block.rows[i]
            cc = block.cols_max if executed else block.cols[j]
            rim_p2p[d] += 2 * w * (rr + cc)
    return m2l, p2p, rim_m2l, rim_p2p


def halo_volume(plan, params: ModelParams, executed: bool = False) -> dict:
    """Bytes the driver's ppermute halo exchange moves per FMM evaluation.

    Sums, over every device and every sharded level, the M2L coefficient
    strips (width ``cost_model.M2L_HALO_ROWS``) and the leaf-level P2P
    particle strips (width ``P2P_HALO_ROWS``) the two-axis exchange sends.
    ``executed=False`` prices the *modeled* volume (valid tile extents —
    the quantity the 2-D plan must win on versus the 1-D slab);
    ``executed=True`` prices what the driver literally transfers, i.e. the
    padded ``(rows_max, cols_max)`` extents plus the corner-carrying column
    halos on every row strip.  The cut-level ``all_gather`` is not counted
    (identical structure for both plan kinds).

    ``rim_m2l_boxes`` / ``rim_p2p_boxes`` additionally report the rim cost
    of the overlapped driver: the boxes per evaluation whose compute is
    serialized behind the exchange (the four edge strips; multiply the P2P
    term by ``params.slots`` for slot counts) — the quantity the
    overlap-aware comm model (:func:`plan_comm_cost`) charges against the
    hiding budget.
    """
    block = plan.as_block() if isinstance(plan, SlabPlan) else plan
    m2l, p2p, rim_m2l, rim_p2p = _halo_device_stats(block, params, executed)
    return {"m2l": float(m2l.sum()), "p2p": float(p2p.sum()),
            "total": float((m2l + p2p).sum()),
            "rim_m2l_boxes": float(rim_m2l.sum()),
            "rim_p2p_boxes": float(rim_p2p.sum()),
            "sharded_levels": block.sharded_depth()}


def plan_comm_cost(plan, counts: np.ndarray, params: ModelParams,
                   overlap: bool = True, executed: bool = True,
                   weight_scale: np.ndarray | None = None,
                   pipeline: bool = True) -> np.ndarray:
    """(nparts,) modeled serial communication cost per device.

    ``overlap=False`` is the paper's Eq 16-20 price: ``t_byte`` times the
    device's halo bytes, paid serially before the dependent compute.
    ``overlap=True`` is the interior/rim driver's residue (DESIGN.md §9):
    each device's halo bytes are hidden behind its *interior* work — the
    plan load scaled by the interior fraction of the tile
    (``interior_extents``) — and only ``max(0, t_comm - t_hide)`` remains
    serial (``cost_model.comm_overlap_effective``, which owns both
    branches).  This is the term that stops the partitioner
    double-counting bytes the driver hides.  ``weight_scale`` (measured
    slowdown feedback, see ``plan_loads``) scales the hiding budget too:
    a slow device's interior takes longer in wall clock, so it hides the
    same exchange more easily — the comm term sees the same device speeds
    the balance term uses.

    ``pipeline=True`` (default, matching the drivers) enlarges the hiding
    budget with the substep pipeline's windows (DESIGN.md §12): the
    replicated root-tree sweep now runs between the halo collectives'
    issue and the rim consumption (``cost_model.work_root_tree``), and the
    prefetched cross-substep P2P exchange additionally flies through the
    next substep's upward sweep (``cost_model.work_upward``).  The enlarged
    budget can only shrink the residue, never grow it.
    """
    block = plan.as_block() if isinstance(plan, SlabPlan) else plan
    m2l_b, p2p_b, _, _ = _halo_device_stats(block, params, executed)
    bytes_d = m2l_b + p2p_b
    loads = plan_loads(plan, counts, params, weight_scale)
    Pr, Pc = block.grid
    area = np.array([block.rows[i] * block.cols[j]
                     for i in range(Pr) for j in range(Pc)], dtype=np.float64)
    ints = np.array([r * c for r, c in
                     block.interior_extents(cm.P2P_HALO_ROWS)],
                    dtype=np.float64)
    hide = loads * ints / np.maximum(area, 1.0)
    extra = 0.0
    if pipeline:
        extra = cm.work_root_tree(params) + cm.work_upward(params, area)
        if weight_scale is not None:
            # a slow device's pipeline windows stretch too: scale by its
            # mean slowdown, like the interior budget above
            mean_scale = loads / np.maximum(
                plan_loads(plan, counts, params), 1e-30)
            extra = extra * mean_scale
    return cm.comm_overlap_effective(bytes_d, hide, params, overlap=overlap,
                                     extra_hide=extra)


def plan_score(plan, counts: np.ndarray, params: ModelParams,
               overlap: bool = True,
               weight_scale: np.ndarray | None = None,
               pipeline: bool = True) -> float:
    """Modeled bottleneck step cost: Eq-20 max over devices of work plus
    the overlap-aware serial comm residue — the objective the grid
    autotuner minimizes.  Smaller is better.  ``weight_scale`` feeds both
    terms, so the balance and comm-hiding models see the same measured
    device speeds; ``pipeline`` selects the §12 enlarged hiding budget the
    executing driver actually has."""
    loads = plan_loads(plan, counts, params, weight_scale)
    comm = plan_comm_cost(plan, counts, params, overlap=overlap,
                          weight_scale=weight_scale, pipeline=pipeline)
    return float((params.t_flop * loads + comm).max())


def candidate_grids(nparts: int) -> list[tuple[int, int]]:
    """All ``(Pr, Pc)`` factorizations of ``nparts`` — ``(nparts, 1)`` is
    the 1-D slab candidate, everything else a 2-D block grid."""
    return [(pr, nparts // pr) for pr in range(1, nparts + 1)
            if nparts % pr == 0]


def autotune_plan(counts: np.ndarray, params: ModelParams, nparts: int,
                  method: str = "model",
                  cell_weight_scale: np.ndarray | None = None,
                  overlap: bool = True, pipeline: bool = True):
    """Per-axis plan autotuning (ROADMAP): choose slab vs block AND the
    ``(Pr, Pc)`` device grid at replan time.

    Builds one candidate plan per factorization of ``nparts`` (the
    ``(P, 1)`` slab plus every 2-D tensor grid that fits the leaf grid) and
    keeps the one minimizing :func:`plan_score` — the Eq-20 balance
    bottleneck plus the overlap-aware comm residue of ``halo_volume``, so
    the choice trades balance against the bytes the driver cannot hide.
    ``cell_weight_scale`` carries measured-feedback slowdowns at parent-row
    ``(R,)`` or parent-cell ``(R, C)`` granularity (either shape works for
    both candidate kinds; see ``plan_loads``).
    """
    R = (1 << params.level) // 2
    best: tuple[float, object] | None = None
    for Pr, Pc in candidate_grids(nparts):
        if Pr > R or Pc > R:
            continue
        if Pc == 1:
            row_scale = None
            if cell_weight_scale is not None:
                ws = np.asarray(cell_weight_scale, dtype=np.float64)
                if ws.ndim == 2:
                    # project cell slowdowns onto rows: the scale that makes
                    # scaled row loads equal the row sums of the scaled field
                    W = cell_loads(counts, params)
                    den = W.sum(axis=1)
                    num = (W * ws).sum(axis=1)
                    row_scale = np.where(den > 0, num / np.where(den > 0, den, 1.0), 1.0)
                else:
                    row_scale = ws
            plan = plan_from_counts(counts, params, nparts, method=method,
                                    row_weight_scale=row_scale)
        else:
            plan = block_plan_from_counts(counts, params, (Pr, Pc),
                                          method=method,
                                          cell_weight_scale=cell_weight_scale)
        score = plan_score(plan, counts, params, overlap=overlap,
                           weight_scale=cell_weight_scale, pipeline=pipeline)
        if best is None or score < best[0]:
            best = (score, plan)
    if best is None:
        raise ValueError(f"no (Pr, Pc) factorization of {nparts} fits a"
                         f" level-{params.level} grid")
    return best[1]
