"""Quadtree geometry, Morton indexing, and dense tree construction.

The paper (PetFMM, §2.1) uses a pointer quadtree.  On TPU we use *dense level
grids*: level ``l`` of the tree is a ``(2^l, 2^l, ...)`` array in row-major
grid order ``(iy, ix)``.  Morton (z-order) indices are used by the
partitioner (paper §4/§5.1) to enumerate subtrees and their neighbor sets.

Domain is the unit square ``[0, 1]^2``.  Box side at level ``l`` is
``2**-l``; particle positions are complex ``z = x + 1j*y``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Morton (z-order) indexing — used by the partitioner, not the dense kernels.
# ---------------------------------------------------------------------------


def _part1by1(x: np.ndarray) -> np.ndarray:
    """Interleave zeros: abcd -> 0a0b0c0d (supports up to 16-bit inputs)."""
    # NB: copy before the in-place ops — ``asarray`` aliases uint32 inputs
    # and the bit-twiddling must never mutate the caller's array.
    x = np.array(x, dtype=np.uint32, copy=True)
    x &= np.uint32(0x0000FFFF)
    x = (x | (x << 8)) & np.uint32(0x00FF00FF)
    x = (x | (x << 4)) & np.uint32(0x0F0F0F0F)
    x = (x | (x << 2)) & np.uint32(0x33333333)
    x = (x | (x << 1)) & np.uint32(0x55555555)
    return x


def _compact1by1(x: np.ndarray) -> np.ndarray:
    x = np.array(x, dtype=np.uint32, copy=True)   # never mutate the caller
    x &= np.uint32(0x55555555)
    x = (x | (x >> 1)) & np.uint32(0x33333333)
    x = (x | (x >> 2)) & np.uint32(0x0F0F0F0F)
    x = (x | (x >> 4)) & np.uint32(0x00FF00FF)
    x = (x | (x >> 8)) & np.uint32(0x0000FFFF)
    return x


def morton_encode(ix: np.ndarray, iy: np.ndarray) -> np.ndarray:
    """(ix, iy) grid coords -> z-order index (paper's quadtree numbering)."""
    return (_part1by1(iy) << 1) | _part1by1(ix)


def morton_decode(code: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    code = np.asarray(code, dtype=np.uint32)
    return _compact1by1(code), _compact1by1(code >> 1)


# ---------------------------------------------------------------------------
# Interaction-list algebra for the dense uniform tree.
#
# A source box at relative offset (dx, dy), |dx|,|dy| <= 3, is in the
# interaction list of a target box iff (a) it is not a near neighbor
# (max(|dx|,|dy|) >= 2) and (b) its parent is a neighbor of the target's
# parent.  Condition (b) depends only on the *parity* of the target's grid
# coordinate:   |floor((parity + d) / 2)| <= 1.
# There are 40 candidate offsets; each parity class admits exactly 27.
# ---------------------------------------------------------------------------

M2L_OFFSETS: list[tuple[int, int]] = [
    (dx, dy)
    for dy in range(-3, 4)
    for dx in range(-3, 4)
    if max(abs(dx), abs(dy)) >= 2
]
assert len(M2L_OFFSETS) == 40


def parity_valid(parity: int, d: int) -> bool:
    """True iff parent(target+d) is a neighbor of parent(target)."""
    import math

    return abs(math.floor((parity + d) / 2)) <= 1


# VALIDITY[o, py, px]: offset o is in the interaction list of boxes with
# grid-coordinate parities (iy % 2 == py, ix % 2 == px).
M2L_VALIDITY = np.zeros((len(M2L_OFFSETS), 2, 2), dtype=bool)
for _o, (_dx, _dy) in enumerate(M2L_OFFSETS):
    for _py in range(2):
        for _px in range(2):
            M2L_VALIDITY[_o, _py, _px] = parity_valid(_px, _dx) and parity_valid(_py, _dy)
# Each parity class has exactly 27 interaction-list members (paper §5.2).
assert (M2L_VALIDITY.sum(axis=0) == 27).all()

# Near-field stencil (self + 8 neighbors).
P2P_OFFSETS: list[tuple[int, int]] = [(dx, dy) for dy in (-1, 0, 1) for dx in (-1, 0, 1)]

# ---------------------------------------------------------------------------
# Parent-granularity (parity-folded) interaction algebra.
#
# Key identity (DESIGN.md §4): the parity validity above only ever excludes
# the extreme offsets d = ±3 (d = -3 needs odd parity, d = +3 even parity),
# so the 27 valid offsets of a box with parity (py, px) form the contiguous
# 6x6 window  dy in [-2-py, 3-py], dx in [-2-px, 3-px]  minus the 3x3 near
# field — i.e. exactly the children of the target's parent's 3x3 parent
# neighborhood, minus near neighbors.  Working on 2x2 child blocks therefore
# folds every parity mask into the *structure* of the operator: each
# (target-child, source-child, parent-offset) triple is either a valid
# interaction or a structural zero; nothing is masked at run time.
# ---------------------------------------------------------------------------

# The 8 contributing parent offsets (the (0,0) parent holds only near
# neighbors of every child, so its block is identically zero and dropped).
PARENT_NEIGH8: list[tuple[int, int]] = [
    (dx, dy) for dy in (-1, 0, 1) for dx in (-1, 0, 1) if (dx, dy) != (0, 0)
]

# M2L_PARITY_OFFSETS[py][px]: the 27 child-granularity offsets valid for
# parity class (py, px), in (parent-offset, source-child) raster order —
# the order the folded operator contracts them in.
M2L_PARITY_OFFSETS: list[list[list[tuple[int, int]]]] = [[[] for _ in range(2)]
                                                         for _ in range(2)]
for _py in range(2):
    for _px in range(2):
        for (_Dx, _Dy) in PARENT_NEIGH8:
            for _sy in range(2):
                for _sx in range(2):
                    _d = (2 * _Dx + _sx - _px, 2 * _Dy + _sy - _py)
                    if max(abs(_d[0]), abs(_d[1])) >= 2:
                        M2L_PARITY_OFFSETS[_py][_px].append(_d)

# Cross-check the folded enumeration against the mask table: same 27 sets.
for _py in range(2):
    for _px in range(2):
        _folded = set(M2L_PARITY_OFFSETS[_py][_px])
        _masked = {off for _o, off in enumerate(M2L_OFFSETS)
                   if M2L_VALIDITY[_o, _py, _px]}
        assert _folded == _masked and len(_folded) == 27


# ---------------------------------------------------------------------------
# Geometry helpers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Domain:
    """Physical root box mapped onto the solver's unit square.

    The FMM machinery everywhere assumes the unit domain ``[0, 1]^2``; a
    :class:`Domain` records the affine map from PHYSICAL coordinates to
    that unit square so the root box can GROW when particles escape (the
    stepper's domain-expansion recovery rung) without touching any of the
    tree/kernel geometry.  ``to_unit``/``from_unit`` act on ``(N, 2)``
    position arrays; the identity domain is bit-transparent.

    Scaling contract for the stepper (unit quantities fed to the solver):
    ``sigma_unit = sigma / size`` and — for the Biot-Savart/vortex kernel,
    where velocity ~ Gamma / r — ``gamma_unit = gamma / size**2``, so unit
    trajectories advanced with the physical ``dt`` map back to physical
    trajectories exactly.
    """

    origin: tuple[float, float] = (0.0, 0.0)
    size: float = 1.0

    def to_unit(self, positions: np.ndarray) -> np.ndarray:
        return (np.asarray(positions, np.float64)
                - np.asarray(self.origin)) / self.size

    def from_unit(self, positions: np.ndarray) -> np.ndarray:
        return np.asarray(positions, np.float64) * self.size \
            + np.asarray(self.origin)

    @property
    def is_identity(self) -> bool:
        return self.origin == (0.0, 0.0) and self.size == 1.0

    @staticmethod
    def covering(positions: np.ndarray, margin: float = 0.25,
                 at_least: Optional["Domain"] = None) -> "Domain":
        """Smallest square (plus relative ``margin`` per side) containing
        every position — and, when ``at_least`` is given, that whole domain
        too, so expansion never orphans the current root box."""
        pos = np.asarray(positions, np.float64)
        lo, hi = pos.min(axis=0), pos.max(axis=0)
        if at_least is not None:
            o = np.asarray(at_least.origin)
            lo = np.minimum(lo, o)
            hi = np.maximum(hi, o + at_least.size)
        side = max(float((hi - lo).max()), 1e-9)
        size = side * (1.0 + 2.0 * margin)
        center = (lo + hi) / 2.0
        origin = center - size / 2.0
        return Domain(origin=(float(origin[0]), float(origin[1])), size=size)


def box_size(level: int) -> float:
    return 2.0 ** (-level)


def box_centers(level: int) -> np.ndarray:
    """Complex centers of all boxes at ``level``, shape (2^l, 2^l) [iy, ix]."""
    n = 1 << level
    r = box_size(level)
    xs = (np.arange(n) + 0.5) * r
    cx, cy = np.meshgrid(xs, xs, indexing="xy")  # [iy, ix]
    return (cx + 1j * cy).astype(np.complex128)


# ---------------------------------------------------------------------------
# Dense tree container
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Tree:
    """Dense uniform quadtree of particles.

    ``z``/``q``/``mask`` have shape ``(n, n, s)`` with ``n = 2**level`` leaf
    boxes per side and ``s`` padded slots per box.  ``q`` already includes
    the ``gamma / (2*pi*i)`` pseudo-charge factor for the Biot-Savart kernel.
    """

    z: jax.Array       # complex64 (n, n, s) particle positions
    q: jax.Array       # complex64 (n, n, s) pseudo-charges
    mask: jax.Array    # bool      (n, n, s) slot occupancy
    level: int = dataclasses.field(metadata=dict(static=True))
    sigma: float = dataclasses.field(metadata=dict(static=True))

    @property
    def nside(self) -> int:
        return 1 << self.level

    @property
    def slots(self) -> int:
        return self.z.shape[-1]

    @property
    def num_particles(self) -> jax.Array:
        return self.mask.sum()


@dataclasses.dataclass(frozen=True)
class TreeIndex:
    """Host-side bookkeeping to map dense tree slots back to input order."""

    box_of_particle: np.ndarray   # (N,) flat row-major box id per input particle
    slot_of_particle: np.ndarray  # (N,) slot within the box
    counts: np.ndarray            # (n, n) particles per box


def choose_level(num_particles: int, target_per_box: float = 4.0, max_level: int = 12) -> int:
    """Pick the tree depth so the mean leaf occupancy ~ ``target_per_box``."""
    level = 0
    while level < max_level and num_particles / float(4 ** (level + 1)) >= target_per_box:
        level += 1
    return level


def build_tree(
    positions: np.ndarray,
    gamma: np.ndarray,
    level: int,
    sigma: float,
    slots: Optional[int] = None,
    dtype=np.complex64,
    charge_scale: Optional[complex] = None,
) -> tuple[Tree, TreeIndex]:
    """Bin particles into the dense leaf grid (host-side, NumPy).

    positions: (N, 2) float in [0, 1)^2;  gamma: (N,) real strengths.
    ``slots`` pads every box to a fixed capacity (defaults to the max
    occupancy).  ``charge_scale`` maps the input strength to the stored
    pseudo-charge ``q`` — the equation spec's ``charge_scale``
    (core/equations.py); None keeps the vortex default ``1/(2*pi*i)``
    (circulation -> Biot-Savart pseudo-charge).  This is the TPU-native
    replacement for the paper's ragged per-box particle lists (see
    DESIGN.md §3).
    """
    positions = np.asarray(positions, dtype=np.float64)
    gamma = np.asarray(gamma, dtype=np.float64)
    n = 1 << level
    ij = np.clip((positions * n).astype(np.int64), 0, n - 1)
    ix, iy = ij[:, 0], ij[:, 1]
    box = iy * n + ix  # flat row-major box id

    order = np.argsort(box, kind="stable")
    sorted_box = box[order]
    counts = np.bincount(sorted_box, minlength=n * n)
    max_occ = int(counts.max()) if counts.size else 0
    if slots is None:
        slots = max(max_occ, 1)
    if max_occ > slots:
        raise ValueError(f"box occupancy {max_occ} exceeds slot capacity {slots}")

    # slot index = rank of the particle within its (sorted) box run
    starts = np.zeros(n * n + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    slot_sorted = np.arange(len(box)) - starts[sorted_box]

    zflat = np.zeros((n * n, slots), dtype=np.complex128)
    qflat = np.zeros((n * n, slots), dtype=np.complex128)
    mflat = np.zeros((n * n, slots), dtype=bool)
    if charge_scale is None:
        charge_scale = 1.0 / (2j * np.pi)
    zsrc = positions[order, 0] + 1j * positions[order, 1]
    qsrc = gamma[order] * charge_scale
    zflat[sorted_box, slot_sorted] = zsrc
    qflat[sorted_box, slot_sorted] = qsrc
    mflat[sorted_box, slot_sorted] = True

    slot_of_particle = np.empty(len(box), dtype=np.int64)
    slot_of_particle[order] = slot_sorted

    tree = Tree(
        z=jnp.asarray(zflat.reshape(n, n, slots), dtype=dtype),
        q=jnp.asarray(qflat.reshape(n, n, slots), dtype=dtype),
        mask=jnp.asarray(mflat.reshape(n, n, slots)),
        level=level,
        sigma=float(sigma),
    )
    index = TreeIndex(box_of_particle=box, slot_of_particle=slot_of_particle,
                      counts=counts.reshape(n, n))
    return tree, index


def rebuild_tree(tree: Tree, new_z: jnp.ndarray, aux=None):
    """Device-side rebinning: scatter particles into a fresh dense tree.

    The jit-able counterpart of :func:`build_tree` — a whole advection step
    can run on device with no host round-trip (core/stepper.py).  ``new_z``
    holds updated complex positions in ``tree``'s slot layout; charges and
    occupancy come from ``tree``.  ``aux`` is an optional pytree of
    per-slot ``(n, n, s)`` arrays rebinned alongside the particles (e.g.
    the pre-step positions an RK2 midpoint stage needs).

    Returns ``(new_tree, new_aux, ok)``.  Slot capacity stays fixed at
    ``tree.slots``; when a box overflows, the surplus particles are dropped
    from the new tree and ``ok`` is False — callers must check it and
    rebuild at a deeper level / larger capacity on the host (the stepper's
    occupancy guard does this before overflow is ever reached).

    Positions outside the unit square are clamped into the edge boxes,
    matching ``build_tree``'s host binning.
    """
    n, s = tree.nside, tree.slots
    N = n * n * s
    z = new_z.reshape(N)
    q = tree.q.reshape(N)
    m = tree.mask.reshape(N)

    ix = jnp.clip((z.real * n).astype(jnp.int32), 0, n - 1)
    iy = jnp.clip((z.imag * n).astype(jnp.int32), 0, n - 1)
    box = jnp.where(m, iy * n + ix, n * n)        # empty slots sort last

    order = jnp.argsort(box)                      # stable in jax
    sb = box[order]
    idx = jnp.arange(N, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sb[1:] != sb[:-1]])
    # slot = rank within the sorted box run (distance to the run's start)
    slot = idx - jax.lax.cummax(jnp.where(is_start, idx, 0))
    ok = jnp.all((sb == n * n) | (slot < s))

    keep = (sb < n * n) & (slot < s)        # overflow slots are dropped
    dest = jnp.where(keep, sb * s + slot, N)

    def scatter(vals, fill=0):
        flat = jnp.full((N,), fill, dtype=vals.dtype)
        return flat.at[dest].set(vals.reshape(N)[order], mode="drop") \
                   .reshape(n, n, s)

    new_tree = Tree(z=scatter(z), q=scatter(q),
                    mask=scatter(m.astype(jnp.bool_)),
                    level=tree.level, sigma=tree.sigma)
    new_aux = jax.tree_util.tree_map(scatter, aux) if aux is not None else None
    return new_tree, new_aux, ok


def gather_particle_values(values: np.ndarray, index: TreeIndex) -> np.ndarray:
    """Read per-slot results back into the original particle order."""
    n2 = index.counts.size
    flat = np.asarray(values).reshape(n2, -1)
    return flat[index.box_of_particle, index.slot_of_particle]
