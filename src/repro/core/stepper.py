"""Dynamic load-balanced vortex time stepping (the paper's title, §4),
with guarded execution (DESIGN.md §11).

:class:`VortexStepper` owns the ``(tree, plan)`` pair and closes the
model -> execution -> measurement loop:

  * each RK2 (midpoint) step is ONE jitted device program — FMM velocity,
    half-kick, device-side rebinning (``quadtree.rebuild_tree``), second
    FMM, full kick, rebin — no host round-trip per substep;
  * every ``replan_every`` steps the current leaf occupancy is pulled,
    measured per-device times (when available) are folded into the weights
    via ``partition.measured_rates`` — the same feedback ``rebalance``
    applies to the subtree graph — and a new plan is emitted when the
    modeled Eq-20 bottleneck improves by more than ``replan_tol``;
  * an occupancy guard re-levels the tree on the host *before* any leaf
    box can overflow its slot capacity mid-run;
  * with ``guard=True`` (default) every step also returns an on-device
    health word (``core/health.py``) — NaN/Inf sentinels on velocities,
    coefficients, and exchanged halos; out-of-domain and dropped-particle
    counts; the overflow bit — and a fault walks the bounded
    :class:`RecoveryPolicy` ladder: plain retries -> halved dt -> host
    re-level -> root-box expansion (``quadtree.Domain``) -> plan fallback
    (block -> slab -> uniform) -> the serial jnp reference route ->
    rollback to the last checkpoint -> typed :class:`StepperFaultError`
    carrying a structured :class:`FaultReport`.

Periodic snapshots go through ``checkpoint.manager.CheckpointManager``
(atomic writes, keep-last-k); ``VortexStepper.from_checkpoint`` restores
bit-exact tree/payload state onto ANY device count by rebuilding the plan
from the restored leaf counts (elastic restore).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from .cost_model import ModelParams, array_digest
from . import faults as flt
from . import health as hw
from . import partition as pt
from ..checkpoint.manager import CheckpointManager
from .fmm import fmm_velocity
from .parallel_fmm import parallel_fmm_p2p_prefetch, parallel_fmm_velocity
from .plan import (BlockPlan, SlabPlan, assignment_from_plan, autotune_plan,
                   candidate_grids, measured_row_scale, plan_from_counts,
                   plan_loads, plan_stats, replan, uniform_plan)
from .quadtree import Domain, Tree, build_tree, choose_level, rebuild_tree


def _velocity(tree, p, mesh, mesh_axis, use_kernels, plan, overlap,
              with_health=False, faults=(), pipeline=True, p2p_halo=None):
    if mesh is None:
        return fmm_velocity(tree, p, use_kernels=use_kernels,
                            with_health=with_health)
    return parallel_fmm_velocity(tree, p, mesh, mesh_axis, use_kernels, plan,
                                 overlap, with_health=with_health,
                                 faults=faults, pipeline=pipeline,
                                 p2p_halo=p2p_halo)


def robust_wall(samples, clip: float = 4.0) -> float:
    """Median/clip outlier filter for wall-clock samples.

    One corrupted sample — a scheduler stall inflating a step, or a garbage
    near-zero timer reading — must not thrash the measured-feedback loop
    (``rebalance`` / replanning).  Samples outside ``[median/clip,
    median*clip]`` are discarded and the median of the survivors is
    returned, so a single outlier in either direction moves the estimate by
    at most one rank."""
    s = np.asarray(list(samples), dtype=np.float64)
    med = float(np.median(s))
    keep = s[(s >= med / clip) & (s <= med * clip)]
    return float(np.median(keep)) if keep.size else med


def clean_wall_samples(records) -> list[float]:
    """Steady-state wall-clock samples from a list of :class:`StepRecord`s.

    Drops every FLAGGED record (replanned, releveled, or recovered — those
    steps paid a host rebuild and/or recovery reruns inside their own
    timer) AND each flagged record's successor: a re-plan, an
    occupancy-guard re-level, and a domain expansion are all ADOPTED after
    their step ran, so the retrace for the new static plan / tree shapes
    lands on the FOLLOWING step's sample.  Without the successor drop one
    retrace-contaminated sample per adoption leaks into the window and
    only :func:`robust_wall`'s clip saves the estimate.
    """
    flagged = [bool(r.replanned or r.releveled or r.recovered)
               for r in records]
    return [r.seconds for i, r in enumerate(records)
            if not flagged[i] and not (i > 0 and flagged[i - 1])]


def host_wallclock_times(stepper: "VortexStepper"):
    """Default ``measured_times_fn``: per-device times from the host-side
    step wall clock.

    The host can only observe the whole step (the bottleneck device);
    attributing that wall time to devices in proportion to their modeled
    load share feeds the measured-feedback plumbing (``measured_row_scale``
    -> ``replan`` -> ``rebalance``) real wall-clock magnitudes every replan
    interval without inventing per-device resolution — the resulting rates
    are uniform, so the re-plan stays count-driven until real per-device
    timers (jax profiler device runtimes / TPU counters — the ROADMAP
    item) replace this hook.  Recompile-dominated samples are excluded via
    :func:`clean_wall_samples`: every adoption that changes the jitted
    step's static shapes — a re-plan, an occupancy-guard re-level, a
    recovery re-level, a domain expansion, a rollback — happens AFTER its
    step ran, so the retrace lands on the FOLLOWING step; both the flagged
    record and its successor are dropped.  The surviving samples go
    through :func:`robust_wall` (median/clip), so one corrupted sample
    can't thrash the replanner.  Returns None until a clean steady-state
    step exists.
    """
    recent = clean_wall_samples(stepper.history)[-6:]
    if not recent:
        return None
    wall = robust_wall(recent)
    # maybe_replan stashes the counts it just pulled; fall back to a fresh
    # pull only when called outside the replan path (no second device sync
    # in the steady-state replan check)
    counts = getattr(stepper, "_counts_cache", None)
    if counts is None:
        counts = stepper.counts()
    loads = plan_loads(stepper.plan, counts, stepper.params)
    peak = max(float(loads.max()), 1e-30)
    return wall * np.asarray(loads, dtype=np.float64) / peak


@functools.partial(jax.jit, static_argnames=("p", "mesh", "mesh_axis",
                                             "use_kernels", "plan",
                                             "overlap", "pipeline", "guard",
                                             "faults"))
def rk2_step(tree: Tree, dt, payload=None, *, p: int, mesh=None,
             mesh_axis: str = "data", use_kernels: bool = False,
             plan: Optional[SlabPlan] = None, overlap: bool = True,
             pipeline: bool = True, guard: bool = False, faults: tuple = ()):
    """One jitted RK2 midpoint step; ``dz/dt = conj(W)`` (W = u - iv).

    ``payload`` is an optional pytree of per-slot (n, n, s) arrays carried
    through both rebinnings (e.g. particle labels or initial radii).
    Returns ``(new_tree, new_payload, ok, occ, health)``: ``ok`` is False
    iff a leaf box overflowed its slots during either rebin and ``occ`` the
    maximum leaf occupancy after the step — both computed inside the one
    device program so the stepper's guards cost no extra host round trip.
    ``guard=True`` additionally assembles the full ``core/health.py`` word
    (driver sentinels merged with out-of-domain counts BEFORE the rebins
    clamp, dropped-particle counts from each rebin, the overflow bit, and
    occupancy); ``guard=False`` returns ``health=None`` and traces the
    exact unguarded program.  ``faults`` is the static tuple of active
    :class:`~repro.core.faults.FaultSpec`s (injected on the first substep;
    empty tuple = the injection-free program, bit for bit).

    ``pipeline=True`` (default) runs the substep pipeline (DESIGN.md §12)
    on sharded meshes: substep 2's packed P2P exchange is ISSUED the
    moment the rebinned midpoint tree exists — before substep 1's trailing
    guard reductions and substep 2's resharding/upward sweep, all of which
    then hide the collective's flight — and its evaluation consumes the
    prefetched buffer.  The gather-overlap stage inside each evaluation is
    gated by the same flag.  The exchanged bytes and every consuming op
    are identical, so the two orderings agree bit-for-bit in value;
    ``pipeline=False`` traces exactly the pre-§12 program (the escape
    hatch the equivalence tests pin).
    """
    v1 = _velocity(tree, p, mesh, mesh_axis, use_kernels, plan, overlap,
                   with_health=guard, faults=faults, pipeline=pipeline)
    w1, h1 = v1 if guard else (v1, None)
    z_mid = jnp.where(tree.mask, tree.z + 0.5 * dt * jnp.conj(w1), tree.z)
    z_mid = flt.corrupt_positions(z_mid, tree.mask, faults)
    live0 = tree.mask.sum()
    ood1 = None
    if guard and not pipeline:
        ood1 = hw.out_of_domain_count(z_mid, tree.mask)
    aux = (tree.z, payload) if payload is not None else (tree.z,)
    t_mid, aux, ok1 = rebuild_tree(tree, z_mid, aux=aux)
    z0 = aux[0]

    # cross-substep double buffer (DESIGN.md §12): issue substep 2's packed
    # exchange as soon as the rebinned particles exist, then deliberately
    # order substep 1's trailing guard reduction AFTER the issue — that
    # reduction plus the next evaluation's resharding/upward sweep is the
    # compute window the collective flies through.  Ownership rule: the
    # buffer is read-only from issue to consumption; fault injection and
    # the health sentinel run at the CONSUMER (inside the evaluation), so
    # the guarded paths observe identical data on both orderings.
    p2p_pre = None
    if pipeline and mesh is not None:
        p2p_pre = parallel_fmm_p2p_prefetch(t_mid, mesh=mesh,
                                            mesh_axis=mesh_axis, plan=plan)
    if guard and pipeline:
        ood1 = hw.out_of_domain_count(z_mid, tree.mask)

    v2 = _velocity(t_mid, p, mesh, mesh_axis, use_kernels, plan, overlap,
                   with_health=guard, faults=faults, pipeline=pipeline,
                   p2p_halo=p2p_pre)
    w2, h2 = v2 if guard else (v2, None)
    z_new = jnp.where(t_mid.mask, z0 + dt * jnp.conj(w2), t_mid.z)
    ood2 = hw.out_of_domain_count(z_new, t_mid.mask) if guard else None
    t_new, aux, ok2 = rebuild_tree(t_mid, z_new,
                                   aux=aux[1] if payload is not None else None)
    occ = t_new.mask.sum(axis=-1).max()
    health = None
    if guard:
        health = hw.merge(h1, h2)
        health = hw.with_count(health, hw.F_OOD, ood1 + ood2)
        # a rebin drop is live particles lost to capacity overflow — the
        # count callers would silently lose if they ignored ``ok``
        health = hw.with_count(health, hw.F_DROPPED,
                               live0 - t_new.mask.sum())
        health = hw.with_flag(health, hw.F_OVERFLOW, ~(ok1 & ok2))
        health = hw.with_flag(health, hw.F_OCC, occ)
    return t_new, aux, ok1 & ok2, occ, health


# Named jitted entry point for the static-analysis layer (repro/analysis):
# contracts lower "rk2_step" by name (sentinel-free when guard=False, no
# donated buffers — the recovery ladder retries from the intact pre-step
# tree), and the retrace detector monitors its compile cache.
TRACE_ENTRY_POINTS = {"rk2_step": rk2_step}


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """The recovery ladder's knobs, in escalation order (DESIGN.md §11)."""

    max_retries: int = 1          # rung 1: plain retries (transient faults)
    halve_dt: bool = True         # rung 2: two dt/2 substeps, same interval
    relevel: bool = True          # rung 3: host re-level at fresh capacity
    expand_domain: bool = True    # rung 4: grow the root box (OOD faults)
    domain_margin: float = 0.5    # relative margin of the expanded root box
    plan_fallback: bool = True    # rung 5: block -> slab -> uniform
    reference_route: bool = True  # rung 6: serial jnp route, no kernels
    rollback: bool = True         # rung 7: restore the last checkpoint


@dataclasses.dataclass
class FaultReport:
    """Structured account of an exhausted recovery ladder."""

    step: int                     # 1-based index of the step that faulted
    attempts: list                # [{"rung": str, "health": {field: int}}]
    plan: str                     # plan descriptor at the time of the fault
    level: int
    dt: float

    def __str__(self) -> str:
        rungs = " -> ".join(a["rung"] for a in self.attempts)
        last = self.attempts[-1]["health"] if self.attempts else {}
        bad = {k: v for k, v in last.items()
               if v and k != "max_occupancy"}
        return (f"step {self.step} unrecoverable after [{rungs}]; "
                f"last health {bad}; plan={self.plan} level={self.level} "
                f"dt={self.dt}")


class StepperFaultError(RuntimeError):
    """Raised when every enabled recovery rung failed; carries the report."""

    def __init__(self, report: FaultReport):
        super().__init__(str(report))
        self.report = report


@dataclasses.dataclass
class StepRecord:
    step: int
    seconds: float
    load_balance: float      # Eq (20) min/max on modeled band loads
    replanned: bool
    releveled: bool
    level: int
    recovered: str = ""      # recovery rung that rescued the step ("" = none)
    health: int = 0          # packed health word of the adopted attempt


class VortexStepper:
    """Owns ``(tree, plan)`` and advances the vortex system dynamically.

    ``plan_method``: 'uniform' (strawman), 'model' (a-priori cost-model
    plan), with ``dynamic=True`` adding re-planning from drifted counts and
    measured times.  ``plan_grid=(Pr, Pc)`` schedules a 2-D
    :class:`BlockPlan` tile grid (``Pr * Pc`` must equal the mesh size)
    instead of 1-D row bands; ``plan_grid="auto"`` lets the per-axis grid
    autotuner choose slab vs block at build and every replan.  ``overlap``
    selects the sharded driver's interior/rim overlapped execution.
    ``measured_times_fn(stepper) -> (nparts,) seconds`` is the injection
    point for real per-device timers; dynamic steppers default to
    :func:`host_wallclock_times`.

    Guarded execution: ``guard=True`` (default) runs every step with the
    on-device health word and walks the :class:`RecoveryPolicy` ladder on a
    fault; ``guard=False`` reproduces the pre-guard stepper exactly (only
    the legacy overflow retry remains).  ``faults`` accepts a
    :class:`~repro.core.faults.FaultInjector` for deterministic fault
    injection (tests / chaos drills); None costs nothing.

    Checkpointing: ``checkpoint_dir`` + ``checkpoint_every=k`` snapshots
    (tree, payload, meta) every k adopted steps through
    :class:`CheckpointManager`; the ladder's rollback rung restores the
    last snapshot bit-exact, and :meth:`from_checkpoint` rebuilds a stepper
    — including onto a different device count — from the saved state.

    ``domain`` maps physical coordinates onto the solver's unit square
    (identity by default); the domain-expansion rung grows it when
    particles escape the root box.
    """

    def __init__(self, positions: np.ndarray, gamma: np.ndarray, sigma: float,
                 *, p: int = 12, dt: float = 0.005, mesh=None,
                 mesh_axis: str = "data", use_kernels: bool = False,
                 plan_method: str = "model", dynamic: bool = False,
                 plan_grid=None, overlap: bool = True, pipeline: bool = True,
                 replan_every: int = 4, replan_tol: float = 0.05,
                 target_per_box: float = 8.0, slots_headroom: float = 2.0,
                 occupancy_guard: float = 0.9, cut: Optional[int] = None,
                 payload=None,
                 measured_times_fn: Optional[Callable[["VortexStepper"],
                                                      np.ndarray]] = None,
                 guard: bool = True,
                 policy: Optional[RecoveryPolicy] = None,
                 faults: Optional[flt.FaultInjector] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0, checkpoint_keep: int = 3,
                 domain: Optional[Domain] = None,
                 artifact_cache=None):
        self._init_config(
            p=p, dt=dt, mesh=mesh, mesh_axis=mesh_axis,
            use_kernels=use_kernels, plan_method=plan_method, dynamic=dynamic,
            plan_grid=plan_grid, overlap=overlap, pipeline=pipeline,
            replan_every=replan_every,
            replan_tol=replan_tol, target_per_box=target_per_box,
            slots_headroom=slots_headroom, occupancy_guard=occupancy_guard,
            cut=cut, sigma=sigma, measured_times_fn=measured_times_fn,
            guard=guard, policy=policy, faults=faults,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            checkpoint_keep=checkpoint_keep, domain=domain,
            artifact_cache=artifact_cache)
        self._build_host(np.asarray(positions, np.float64),
                         np.asarray(gamma, np.float64),
                         payload_values=None if payload is None else payload)

    def _init_config(self, *, p, dt, mesh, mesh_axis, use_kernels,
                     plan_method, dynamic, plan_grid, overlap, replan_every,
                     replan_tol, target_per_box, slots_headroom,
                     occupancy_guard, cut, sigma, measured_times_fn, guard,
                     policy, faults, checkpoint_dir, checkpoint_every,
                     checkpoint_keep, domain, pipeline=True,
                     artifact_cache=None):
        self.p, self.dt = p, float(dt)
        # externally-owned artifact cache (serve/fmm_service.ArtifactCache
        # duck type: get(key, builder)); None builds everything locally
        self.artifact_cache = artifact_cache
        self._artifact_keys: dict = {}
        self.mesh, self.mesh_axis = mesh, mesh_axis
        self.use_kernels = use_kernels
        self.plan_method = plan_method
        self.dynamic = dynamic
        self.overlap = overlap
        self.pipeline = bool(pipeline)
        self.plan_grid = plan_grid if plan_grid in (None, "auto") \
            else tuple(plan_grid)
        self.replan_every = max(int(replan_every), 1)
        self.replan_tol = float(replan_tol)
        self.target_per_box = float(target_per_box)
        self.slots_headroom = float(slots_headroom)
        self.occupancy_guard = float(occupancy_guard)
        self._cut = cut
        self.sigma = float(sigma)           # PHYSICAL core size
        self.domain = domain or Domain()
        self.guard = bool(guard)
        self.policy = policy or RecoveryPolicy()
        self.faults = faults
        self.checkpoint_every = int(checkpoint_every)
        self._ckpt = (CheckpointManager(checkpoint_dir, keep=checkpoint_keep)
                      if checkpoint_dir else None)
        self._rolled_back_steps: set[int] = set()
        # dynamic steppers default to the host wall-clock timer so
        # --plan dynamic exercises the full measured-feedback loop with
        # real magnitudes (injected per-device timers override it)
        if measured_times_fn is None and dynamic:
            measured_times_fn = host_wallclock_times
        self.measured_times_fn = measured_times_fn
        self.step_count = 0
        self.history: list[StepRecord] = []

    # -- host-side (re)construction -----------------------------------------

    @property
    def nparts(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.mesh_axis]

    def _min_level(self) -> int:
        # every device needs at least one parent row (2 leaf rows); a 2-D
        # grid only needs that per axis.  "auto" must fit its most
        # demanding *surviving* candidate, so size for the most square
        # factorization (the least demanding per axis) — larger-axis
        # candidates that don't fit are skipped by the autotuner.
        if self.plan_grid == "auto":
            need = max(min(2 * max(g) for g in candidate_grids(self.nparts)),
                       4)
        elif self.plan_grid is not None:
            need = max(2 * max(self.plan_grid), 4)
        else:
            need = max(2 * self.nparts, 4)
        return max(2, math.ceil(math.log2(need)))

    # -- externally-owned artifact cache (session re-entrancy) ---------------

    def _cached(self, key, builder):
        if self.artifact_cache is None:
            return builder()
        return self.artifact_cache.get(key, builder)

    def _plan_key(self, counts) -> tuple:
        return ("plan", array_digest(counts), self.params, self.nparts,
                self.plan_method, self.plan_grid, self.overlap, self.pipeline)

    def _build_plan(self, counts):
        """The deterministic a-priori plan build (cache-keyable — replans
        driven by MEASURED times never go through the cache)."""
        if self.plan_grid == "auto":
            return autotune_plan(counts, self.params, self.nparts,
                                 method=self.plan_method,
                                 overlap=self.overlap,
                                 pipeline=self.pipeline)
        return plan_from_counts(counts, self.params, self.nparts,
                                method=self.plan_method, grid=self.plan_grid)

    def artifact_keys(self) -> dict:
        """{cache_key: live_value} of the artifacts this stepper resolved
        through the external cache — the serving engine re-resolves them by
        key each step (steady state: pure hits) and repopulates an evicted
        entry from the live value."""
        out = {}
        if "tree" in self._artifact_keys:
            out[self._artifact_keys["tree"]] = (self.tree, self.index)
        if "plan" in self._artifact_keys:
            out[self._artifact_keys["plan"]] = self.plan
        return out

    def _build_host(self, positions, gamma, payload_values=None):
        """(Re)bin PHYSICAL particles through the domain map (unit coords,
        scaled sigma/gamma — see :class:`quadtree.Domain`)."""
        size = self.domain.size
        positions = self.domain.to_unit(positions)
        gamma = np.asarray(gamma, np.float64) / size ** 2
        sigma_unit = self.sigma / size
        level = max(choose_level(len(positions), self.target_per_box),
                    self._min_level())
        n = 1 << level
        ij = np.clip((positions * n).astype(np.int64), 0, n - 1)
        occ = np.bincount(ij[:, 1] * n + ij[:, 0], minlength=n * n).max()
        slots = max(int(math.ceil(occ * self.slots_headroom)), 2)
        tree_key = ("tree", array_digest(positions, gamma), level, slots,
                    float(sigma_unit), complex(1.0 / (2j * np.pi)))
        self.tree, self.index = self._cached(
            tree_key, lambda: build_tree(positions, gamma, level, sigma_unit,
                                         slots=slots))
        self._artifact_keys = {"tree": tree_key}
        if payload_values is not None:
            def scatter(v):
                flat = np.zeros((n * n, slots), dtype=np.asarray(v).dtype)
                flat[self.index.box_of_particle,
                     self.index.slot_of_particle] = v
                return jnp.asarray(flat.reshape(n, n, slots))
            self.payload = jax.tree_util.tree_map(scatter, payload_values)
        else:
            self.payload = None
        cut = self._cut if self._cut is not None else min(level - 1, 4)
        self.params = ModelParams(level=level, cut=max(cut, 1), p=self.p,
                                  slots=slots)
        if self.plan_grid not in (None, "auto") and \
                self.plan_grid[0] * self.plan_grid[1] != self.nparts:
            raise ValueError(f"plan_grid {self.plan_grid} has "
                             f"{self.plan_grid[0] * self.plan_grid[1]} tiles"
                             f" for {self.nparts} devices")
        counts = self.index.counts
        plan_key = self._plan_key(counts)
        self.plan = self._cached(plan_key, lambda: self._build_plan(counts))
        self._artifact_keys["plan"] = plan_key
        self.subtree_assign = assignment_from_plan(self.plan, self.params.cut)
        self._cached_lb = plan_stats(self.plan, counts,
                                     self.params)["load_balance"]

    def counts(self) -> np.ndarray:
        return np.asarray(self.tree.mask.sum(axis=-1))

    def particles(self) -> tuple[np.ndarray, np.ndarray]:
        """(positions, gamma) of the live particles, host-side, PHYSICAL
        coordinates (the inverse of the domain map ``_build_host`` applies;
        an identity domain is bit-transparent)."""
        m = np.asarray(self.tree.mask).reshape(-1)
        z = np.asarray(self.tree.z).reshape(-1)[m]
        q = np.asarray(self.tree.q).reshape(-1)[m]
        pos = self.domain.from_unit(np.stack([z.real, z.imag], axis=1))
        gamma = np.real(q * 2j * np.pi) * self.domain.size ** 2
        return pos, gamma

    def _gather_payload_values(self):
        if self.payload is None:
            return None
        m = np.asarray(self.tree.mask).reshape(-1)
        return jax.tree_util.tree_map(
            lambda a: np.asarray(a).reshape(-1)[m], self.payload)

    def _relevel(self):
        """Host rebuild at a freshly chosen level/capacity (overflow guard)."""
        pos, gamma = self.particles()
        self._build_host(pos, gamma,
                         payload_values=self._gather_payload_values())

    def _expand_domain(self, margin: Optional[float] = None):
        """Grow the root box and rebuild — the recovery rung for particles
        escaping the current domain.  The new domain covers the old one and
        is at least twice its size, so the escaping step gains real room."""
        margin = self.policy.domain_margin if margin is None else margin
        pos, gamma = self.particles()
        payload_values = self._gather_payload_values()
        new = Domain.covering(pos, margin=margin, at_least=self.domain)
        if new.size < 2.0 * self.domain.size:
            cx = new.origin[0] + new.size / 2.0
            cy = new.origin[1] + new.size / 2.0
            size = 2.0 * self.domain.size
            new = Domain(origin=(cx - size / 2.0, cy - size / 2.0), size=size)
        self.domain = new
        self._build_host(pos, gamma, payload_values=payload_values)

    # -- checkpointing -------------------------------------------------------

    def save_checkpoint(self):
        """Snapshot (tree, payload, meta) through the checkpoint manager."""
        if self._ckpt is None:
            raise RuntimeError("stepper built without checkpoint_dir")
        trees = {"tree": {"z": self.tree.z, "q": self.tree.q,
                          "mask": self.tree.mask}}
        payload_spec = None
        if self.payload is not None:
            trees["payload"] = self.payload
            if isinstance(self.payload, dict):
                payload_spec = {k: str(np.asarray(v).dtype)
                                for k, v in self.payload.items()}
        meta = {"level": self.params.level, "cut": self.params.cut,
                "slots": self.params.slots, "p": self.p, "dt": self.dt,
                "sigma": self.sigma, "sigma_unit": float(self.tree.sigma),
                "domain_origin": list(self.domain.origin),
                "domain_size": self.domain.size,
                "plan_method": self.plan_method,
                "payload_spec": payload_spec}
        self._ckpt.save(self.step_count, trees, meta)

    @staticmethod
    def _templates_from_meta(meta):
        n, s = 1 << meta["level"], meta["slots"]
        templates = {"tree": {"z": np.zeros((n, n, s), np.complex64),
                              "q": np.zeros((n, n, s), np.complex64),
                              "mask": np.zeros((n, n, s), bool)}}
        if meta.get("payload_spec"):
            templates["payload"] = {
                k: np.zeros((n, n, s), np.dtype(dt))
                for k, dt in meta["payload_spec"].items()}
        return templates

    def _adopt_restored(self, out, meta):
        """Install restored arrays + rebuild the plan from counts (the
        elastic part: any device count works as long as the saved level
        fits its minimum)."""
        t = out["tree"]
        self.tree = Tree(z=jnp.asarray(t["z"]), q=jnp.asarray(t["q"]),
                         mask=jnp.asarray(t["mask"]), level=meta["level"],
                         sigma=meta["sigma_unit"])
        self.payload = None
        if "payload" in out:
            self.payload = jax.tree_util.tree_map(jnp.asarray, out["payload"])
        self.domain = Domain(origin=tuple(meta["domain_origin"]),
                             size=meta["domain_size"])
        self.sigma = meta["sigma"]
        self.params = ModelParams(level=meta["level"], cut=meta["cut"],
                                  p=self.p, slots=meta["slots"])
        self.step_count = meta["step"]
        self._counts_cache = None
        if meta["level"] < self._min_level():
            # saved tree too shallow for this device count: re-level (the
            # only restore path that is not bit-exact — host rebuild)
            self._relevel()
            return
        counts = self.counts()
        plan_key = self._plan_key(counts)
        self.plan = self._cached(plan_key, lambda: self._build_plan(counts))
        # no host tree build on this path — only the plan key is live
        self._artifact_keys = {"plan": plan_key}
        self.subtree_assign = assignment_from_plan(self.plan, self.params.cut)
        self._cached_lb = plan_stats(self.plan, counts,
                                     self.params)["load_balance"]

    def rollback(self, step: Optional[int] = None) -> int:
        """Restore the last (or a given) checkpoint bit-exact; returns the
        restored step index."""
        if self._ckpt is None:
            raise RuntimeError("stepper built without checkpoint_dir")
        self._ckpt.wait()               # never race an in-flight save
        step = self._ckpt.latest_step() if step is None else step
        if step is None:
            raise RuntimeError("no checkpoint to roll back to")
        meta = self._ckpt.load_meta(step)
        out, meta = self._ckpt.restore(self._templates_from_meta(meta),
                                       step=step)
        self._adopt_restored(out, meta)
        return step

    @classmethod
    def from_checkpoint(cls, directory: str, *, mesh=None,
                        mesh_axis: str = "data", step: Optional[int] = None,
                        use_kernels: bool = False, plan_method: str = None,
                        dynamic: bool = False, plan_grid=None,
                        overlap: bool = True, pipeline: bool = True,
                        replan_every: int = 4,
                        replan_tol: float = 0.05,
                        target_per_box: float = 8.0,
                        slots_headroom: float = 2.0,
                        occupancy_guard: float = 0.9,
                        measured_times_fn=None, guard: bool = True,
                        policy: Optional[RecoveryPolicy] = None,
                        faults: Optional[flt.FaultInjector] = None,
                        checkpoint_every: int = 0,
                        checkpoint_keep: int = 3,
                        artifact_cache=None) -> "VortexStepper":
        """Elastic restore: rebuild a stepper from a checkpoint directory,
        onto ANY mesh/device count — tree and payload arrays are restored
        bit-exact (they are device-count independent) and the execution
        plan is rebuilt from the restored leaf counts."""
        mgr = CheckpointManager(directory, keep=checkpoint_keep)
        step = mgr.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
        meta = mgr.load_meta(step)
        out, meta = mgr.restore(cls._templates_from_meta(meta), step=step)
        st = cls.__new__(cls)
        st._init_config(
            p=meta["p"], dt=meta["dt"], mesh=mesh, mesh_axis=mesh_axis,
            use_kernels=use_kernels,
            plan_method=plan_method or meta.get("plan_method", "model"),
            dynamic=dynamic, plan_grid=plan_grid, overlap=overlap,
            pipeline=pipeline,
            replan_every=replan_every, replan_tol=replan_tol,
            target_per_box=target_per_box, slots_headroom=slots_headroom,
            occupancy_guard=occupancy_guard, cut=meta["cut"],
            sigma=meta["sigma"], measured_times_fn=measured_times_fn,
            guard=guard, policy=policy, faults=faults,
            checkpoint_dir=directory, checkpoint_every=checkpoint_every,
            checkpoint_keep=checkpoint_keep, domain=None,
            artifact_cache=artifact_cache)
        st._adopt_restored(out, meta)
        return st

    # -- the dynamic loop ----------------------------------------------------

    def maybe_replan(self, measured_times: Optional[np.ndarray] = None,
                     occ: Optional[int] = None) -> str:
        """Re-level if occupancy approaches capacity; re-plan if it pays.

        ``occ`` (max leaf occupancy) is normally read off the jitted step's
        own outputs (``rk2_step`` returns it), so the overflow guard
        triggers no extra device sync; the counts grid is then pulled once
        per replan interval to refresh the reported load balance and (when
        dynamic) drive the re-plan.
        Returns what was adopted: ``"relevel"`` when the occupancy guard
        rebuilt the tree, ``"replan"`` when a new plan was adopted, ``""``
        otherwise — truthiness-compatible with the old bool return, but
        lets :meth:`step` record the correct ``releveled``/``replanned``
        flags (both adoptions retrace on the NEXT step, which
        :func:`clean_wall_samples` relies on)."""
        if occ is None:
            occ = int(np.asarray(self.tree.mask.sum(axis=-1).max()))
        if occ >= self.occupancy_guard * self.params.slots:
            self._relevel()
            return "relevel"
        counts = self.counts()
        self._counts_cache = counts     # reused by host_wallclock_times
        self._cached_lb = plan_stats(self.plan, counts,
                                     self.params)["load_balance"]
        if not self.dynamic:
            return ""
        if measured_times is None and self.measured_times_fn is not None:
            measured_times = self.measured_times_fn(self)
        new_plan = replan(counts, self.params, self.nparts,
                          prev_plan=self.plan, measured_times=measured_times,
                          method=self.plan_method, grid=self.plan_grid,
                          overlap=self.overlap, pipeline=self.pipeline)
        if new_plan == self.plan:
            return ""
        # adopt when the modeled bottleneck (measured-rate-weighted when
        # times are available) improves by more than the tolerance
        scale = None
        if measured_times is not None:
            scale = measured_row_scale(self.plan, counts, self.params,
                                       measured_times)
        old_max = plan_loads(self.plan, counts, self.params, scale).max()
        new_max = plan_loads(new_plan, counts, self.params, scale).max()
        if new_max > (1.0 - self.replan_tol) * old_max:
            return ""
        self.plan = new_plan
        self._cached_lb = plan_stats(new_plan, counts,
                                     self.params)["load_balance"]
        # keep the paper's 2-D subtree assignment in sync (graph stats /
        # rebalance parity with §4)
        graph = pt.build_subtree_graph(counts, self.params)
        if measured_times is not None:
            self.subtree_assign = pt.rebalance(
                graph, assignment_from_plan(new_plan, self.params.cut),
                self.nparts, measured_times)
        else:
            self.subtree_assign = assignment_from_plan(new_plan,
                                                       self.params.cut)
        return "replan"

    # -- cross-process watchdog hooks (parallel/resilience, DESIGN.md §14) ---

    def modeled_step_work(self) -> float:
        """Eq 13-15 modeled bottleneck of the current plan: the max
        per-partition load.  Pure cost-model units — the resilience layer
        multiplies it by a measured seconds-per-work calibration to seed a
        watchdog deadline before any wall-clock history exists (e.g. the
        first step after a coordinated shrink restart)."""
        counts = getattr(self, "_counts_cache", None)
        if counts is None:
            counts = self.counts()
            self._counts_cache = counts
        return float(plan_loads(self.plan, counts, self.params).max())

    def predicted_step_seconds(self) -> Optional[float]:
        """Robust-filtered steady-state step wall time, or None until a
        clean sample exists.  Same filtering discipline as the replanner:
        flagged records and their retrace-contaminated successors are
        dropped (:func:`clean_wall_samples`), then :func:`robust_wall`
        median/clips the recent window — so one stalled step can't inflate
        (or a garbage timer deflate) the watchdog deadline derived from
        this."""
        recent = clean_wall_samples(self.history)[-8:]
        if not recent:
            return None
        return robust_wall(recent)

    # -- guarded execution ---------------------------------------------------

    def _active_faults(self, attempt: int) -> tuple:
        if self.faults is None:
            return ()
        active = self.faults.active(self.step_count + 1, attempt)
        # teleport magnitudes are PHYSICAL; rk2 runs in unit coordinates,
        # so rescale by the current domain size (root-box expansion can
        # then genuinely cure a sticky teleport that fits the new domain)
        return tuple(dataclasses.replace(f,
                                         magnitude=f.magnitude
                                         / self.domain.size)
                     if f.site == "teleport" else f
                     for f in active)

    def _run_rk2(self, dt, faults=(), plan=None, reference=False):
        """One rk2 attempt from the CURRENT (tree, payload); adopts nothing.

        ``reference=True`` runs the most conservative route: serial mesh,
        pure-jnp slabs, monolithic ordering — the ladder's last compute
        rung.  Returns host-side ``(tree, payload, ok, occ, health)``."""
        if reference:
            out = rk2_step(self.tree, dt, self.payload, p=self.p, mesh=None,
                           use_kernels=False, plan=None, overlap=False,
                           pipeline=False, guard=self.guard, faults=faults)
        else:
            out = rk2_step(
                self.tree, dt, self.payload, p=self.p, mesh=self.mesh,
                mesh_axis=self.mesh_axis, use_kernels=self.use_kernels,
                plan=None if self.mesh is None
                else (plan if plan is not None else self.plan),
                overlap=self.overlap, pipeline=self.pipeline,
                guard=self.guard, faults=faults)
        tree, payload, ok, occ, health = out
        jax.block_until_ready(tree.z)
        return (tree, payload, bool(ok), int(occ),
                None if health is None else np.asarray(health))

    def _recover(self, first_health: np.ndarray):
        """Walk the recovery ladder for the step that just faulted.

        Returns ``(tree, payload, occ, health, rung, releveled, replanned)``
        with the recovered step's state, or ``(None, ..., "rollback", ...)``
        after a checkpoint rollback (the step did NOT advance), or raises
        :class:`StepperFaultError` once every enabled rung is exhausted.
        """
        pol = self.policy
        attempts = [{"rung": "step", "health": hw.describe(first_health)}]
        saw_ood = int(first_health[hw.F_OOD]) > 0
        attempt = 1

        def run(dt, **kw):
            nonlocal attempt
            f = self._active_faults(attempt)
            attempt += 1
            return self._run_rk2(dt, faults=f, **kw)

        def note(rung, h):
            nonlocal saw_ood
            attempts.append({"rung": rung, "health": hw.describe(h)})
            saw_ood = saw_ood or int(h[hw.F_OOD]) > 0

        # rung 1: bounded plain retries (the transient-fault model: a
        # non-sticky injected fault, a one-off bad collective)
        for r in range(max(pol.max_retries, 0)):
            t = run(self.dt)
            note(f"retry_{r + 1}", t[4])
            if hw.ok(t[4]):
                return t[0], t[1], t[3], t[4], f"retry_{r + 1}", False, False
        # rung 2: halved dt — two half-steps covering the same interval, so
        # a recovered trajectory stays comparable to an unfaulted one
        if pol.halve_dt:
            t1 = run(self.dt / 2.0)
            note("half_dt_1", t1[4])
            if hw.ok(t1[4]):
                saved = (self.tree, self.payload)
                self.tree, self.payload = t1[0], t1[1]
                t2 = run(self.dt / 2.0)
                self.tree, self.payload = saved
                note("half_dt_2", t2[4])
                if hw.ok(t2[4]):
                    return t2[0], t2[1], t2[3], t2[4], "half_dt", False, False
        # rung 3: host re-level at freshly chosen depth/capacity (overflow,
        # capacity-drop faults)
        if pol.relevel:
            self._relevel()
            t = run(self.dt)
            note("relevel", t[4])
            if hw.ok(t[4]):
                return t[0], t[1], t[3], t[4], "relevel", True, False
        # rung 4: root-box expansion (particles escaped the domain)
        if pol.expand_domain and saw_ood:
            self._expand_domain()
            t = run(self.dt)
            note("expand_domain", t[4])
            if hw.ok(t[4]):
                return t[0], t[1], t[3], t[4], "expand_domain", True, False
        # rung 5: plan fallback block -> slab -> uniform (bad plan/exchange)
        if pol.plan_fallback and self.mesh is not None and self.nparts > 1:
            for name, fb in self._fallback_plans():
                t = run(self.dt, plan=fb)
                note(f"plan_{name}", t[4])
                if hw.ok(t[4]):
                    self.plan = fb
                    self.plan_grid = None
                    counts = self.counts()
                    self.subtree_assign = assignment_from_plan(
                        fb, self.params.cut)
                    self._cached_lb = plan_stats(fb, counts,
                                                 self.params)["load_balance"]
                    return t[0], t[1], t[3], t[4], f"plan_{name}", False, True
        # rung 6: the jnp reference route (serial, no kernels, monolithic)
        if pol.reference_route:
            t = run(self.dt, reference=True)
            note("reference", t[4])
            if hw.ok(t[4]):
                return t[0], t[1], t[3], t[4], "reference", False, False
        # rung 7: rollback to the last good checkpoint (once per step)
        fault_step = self.step_count + 1
        if (pol.rollback and self._ckpt is not None
                and fault_step not in self._rolled_back_steps
                and self._ckpt.latest_step() is not None):
            self._rolled_back_steps.add(fault_step)
            self.rollback()
            return None, None, 0, first_health, "rollback", False, False
        raise StepperFaultError(FaultReport(
            step=fault_step, attempts=attempts,
            plan=self.plan.describe(), level=self.params.level, dt=self.dt))

    def _fallback_plans(self):
        """Simpler-plan candidates in escalation order, current plan and
        infeasible geometries excluded (a slab needs 2 leaf rows/device)."""
        out = []
        n = 1 << self.params.level
        if n < 2 * self.nparts:
            return out
        counts = self.counts()
        is_block = isinstance(self.plan, BlockPlan) and self.plan.grid[1] > 1
        if is_block:
            out.append(("slab", plan_from_counts(counts, self.params,
                                                 self.nparts,
                                                 method="model")))
        uni = uniform_plan(self.params.level, self.nparts)
        if uni != self.plan:
            out.append(("uniform", uni))
        return out

    # -- stepping ------------------------------------------------------------

    def step(self) -> StepRecord:
        """Advance one RK2 step; time it; periodically re-plan.

        Guarded steppers check the on-device health word and walk the
        recovery ladder on any fault; a rollback record carries
        ``recovered="rollback"`` and does NOT advance ``step_count``."""
        t0 = time.perf_counter()
        recovered, releveled, fb_replanned = "", False, False
        tree, payload, ok, occ, health = self._run_rk2(
            self.dt, faults=self._active_faults(0))
        if self.guard:
            if not hw.ok(health):
                (tree, payload, occ, health, recovered, releveled,
                 fb_replanned) = self._recover(health)
                if tree is None:        # rolled back: step did not advance
                    seconds = time.perf_counter() - t0
                    rec = StepRecord(step=self.step_count, seconds=seconds,
                                     load_balance=self._cached_lb,
                                     replanned=False, releveled=False,
                                     level=self.params.level,
                                     recovered="rollback",
                                     health=hw.pack(health))
                    self.history.append(rec)
                    return rec
        elif not ok:
            # legacy (unguarded) overflow path: the old tree is still
            # intact — re-level on the host and redo the step safely.
            releveled = True
            self._relevel()
            tree, payload, ok, occ, health = self._run_rk2(self.dt)
            if not ok:
                raise RuntimeError(
                    "leaf box overflow persists after re-leveling; "
                    "increase slots_headroom or lower target_per_box")
        # the timer covers everything the step actually cost, including a
        # re-level/recovery + recompile when one happened
        seconds = time.perf_counter() - t0
        self.tree, self.payload = tree, payload
        self.step_count += 1
        if self.faults is not None:
            # host-side fault site: corrupt this step's wall-clock sample
            seconds *= self.faults.time_factor(self.step_count)
        replanned = fb_replanned
        self._counts_cache = None       # tree advanced: drop stale counts
        if self.step_count % self.replan_every == 0:
            # occ comes off the step's own outputs (already on host after
            # block_until_ready) — the check itself syncs nothing extra
            action = self.maybe_replan(occ=int(occ))
            replanned = replanned or action == "replan"
            releveled = releveled or action == "relevel"
        rec = StepRecord(step=self.step_count, seconds=seconds,
                         load_balance=self._cached_lb,
                         replanned=replanned,
                         releveled=releveled or bool(recovered == "relevel"),
                         level=self.params.level, recovered=recovered,
                         health=0 if health is None else hw.pack(health))
        self.history.append(rec)
        if (self._ckpt is not None and self.checkpoint_every
                and self.step_count % self.checkpoint_every == 0):
            self.save_checkpoint()
        return rec

    def stats(self) -> dict:
        return plan_stats(self.plan, self.counts(), self.params)
