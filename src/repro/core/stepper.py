"""Dynamic load-balanced vortex time stepping (the paper's title, §4).

:class:`VortexStepper` owns the ``(tree, plan)`` pair and closes the
model -> execution -> measurement loop:

  * each RK2 (midpoint) step is ONE jitted device program — FMM velocity,
    half-kick, device-side rebinning (``quadtree.rebuild_tree``), second
    FMM, full kick, rebin — no host round-trip per substep (the loop
    ``examples/vortex_sim.py`` used to run rebuilt the tree on the host
    twice per step);
  * every ``replan_every`` steps the current leaf occupancy is pulled,
    measured per-device times (when available) are folded into the weights
    via ``partition.measured_rates`` — the same feedback ``rebalance``
    applies to the subtree graph — and a new :class:`SlabPlan` is emitted
    when the modeled Eq-20 bottleneck improves by more than ``replan_tol``;
  * an occupancy guard re-levels the tree on the host *before* any leaf
    box can overflow its slot capacity mid-run.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from .cost_model import ModelParams
from . import partition as pt
from .fmm import fmm_velocity
from .parallel_fmm import parallel_fmm_velocity
from .plan import (SlabPlan, assignment_from_plan, autotune_plan,
                   candidate_grids, measured_row_scale, plan_from_counts,
                   plan_loads, plan_stats, replan)
from .quadtree import Tree, build_tree, choose_level, rebuild_tree


def _velocity(tree, p, mesh, mesh_axis, use_kernels, plan, overlap):
    if mesh is None:
        return fmm_velocity(tree, p, use_kernels=use_kernels)
    return parallel_fmm_velocity(tree, p, mesh, mesh_axis, use_kernels, plan,
                                 overlap)


def host_wallclock_times(stepper: "VortexStepper"):
    """Default ``measured_times_fn``: per-device times from the host-side
    step wall clock.

    The host can only observe the whole step (the bottleneck device);
    attributing that wall time to devices in proportion to their modeled
    load share feeds the measured-feedback plumbing (``measured_row_scale``
    -> ``replan`` -> ``rebalance``) real wall-clock magnitudes every replan
    interval without inventing per-device resolution — the resulting rates
    are uniform, so the re-plan stays count-driven until real per-device
    timers (jax profiler device runtimes / TPU counters — the ROADMAP
    item) replace this hook.  Recompile-dominated samples are excluded:
    a re-level pays its rebuild inside its own (flagged) step, but a
    re-plan is adopted AFTER its step ran, so the retrace for the new
    static plan lands on the FOLLOWING step — both the flagged record and
    its successor are dropped.  Returns None until a clean steady-state
    step exists.
    """
    recs = stepper.history
    clean = [r.seconds for prev, r in zip([None] + recs[:-1], recs)
             if not (r.replanned or r.releveled)
             and not (prev is not None
                      and (prev.replanned or prev.releveled))]
    recent = clean[-4:]
    if not recent:
        return None
    wall = min(recent)
    # maybe_replan stashes the counts it just pulled; fall back to a fresh
    # pull only when called outside the replan path (no second device sync
    # in the steady-state replan check)
    counts = getattr(stepper, "_counts_cache", None)
    if counts is None:
        counts = stepper.counts()
    loads = plan_loads(stepper.plan, counts, stepper.params)
    peak = max(float(loads.max()), 1e-30)
    return wall * np.asarray(loads, dtype=np.float64) / peak


@functools.partial(jax.jit, static_argnames=("p", "mesh", "mesh_axis",
                                             "use_kernels", "plan",
                                             "overlap"))
def rk2_step(tree: Tree, dt, payload=None, *, p: int, mesh=None,
             mesh_axis: str = "data", use_kernels: bool = False,
             plan: Optional[SlabPlan] = None, overlap: bool = True):
    """One jitted RK2 midpoint step; ``dz/dt = conj(W)`` (W = u - iv).

    ``payload`` is an optional pytree of per-slot (n, n, s) arrays carried
    through both rebinnings (e.g. particle labels or initial radii).
    Returns ``(new_tree, new_payload, ok, occ)`` with ``ok`` False iff a
    leaf box overflowed its slots during either rebin and ``occ`` the
    maximum leaf occupancy after the step — computed inside the one device
    program so the stepper's occupancy guard costs no extra host round
    trip (the steady-state replan check reads it off the step's own
    outputs).
    """
    w1 = _velocity(tree, p, mesh, mesh_axis, use_kernels, plan, overlap)
    z_mid = jnp.where(tree.mask, tree.z + 0.5 * dt * jnp.conj(w1), tree.z)
    aux = (tree.z, payload) if payload is not None else (tree.z,)
    t_mid, aux, ok1 = rebuild_tree(tree, z_mid, aux=aux)
    z0 = aux[0]

    w2 = _velocity(t_mid, p, mesh, mesh_axis, use_kernels, plan, overlap)
    z_new = jnp.where(t_mid.mask, z0 + dt * jnp.conj(w2), t_mid.z)
    t_new, aux, ok2 = rebuild_tree(t_mid, z_new,
                                   aux=aux[1] if payload is not None else None)
    occ = t_new.mask.sum(axis=-1).max()
    return t_new, aux, ok1 & ok2, occ


@dataclasses.dataclass
class StepRecord:
    step: int
    seconds: float
    load_balance: float      # Eq (20) min/max on modeled band loads
    replanned: bool
    releveled: bool
    level: int


class VortexStepper:
    """Owns ``(tree, plan)`` and advances the vortex system dynamically.

    ``plan_method``: 'uniform' (strawman), 'model' (a-priori cost-model
    plan), with ``dynamic=True`` adding re-planning from drifted counts and
    measured times.  ``plan_grid=(Pr, Pc)`` schedules a 2-D
    :class:`BlockPlan` tile grid (``Pr * Pc`` must equal the mesh size)
    instead of 1-D row bands; re-planning then works on per-tile weights
    through the same ``replan`` / ``measured_row_scale`` interface.
    ``plan_grid="auto"`` lets the per-axis grid autotuner
    (``plan.autotune_plan``) choose slab vs block and the ``(Pr, Pc)``
    factorization at build and every replan, scoring the Eq-20 balance
    bottleneck plus the overlap-aware comm residue across all candidate
    grids.  ``overlap`` selects the sharded driver's interior/rim
    overlapped execution (default) vs the monolithic ordering.
    ``measured_times_fn(stepper) -> (nparts,) seconds`` is the injection
    point for real per-device timers (tests use it to emulate heterogeneous
    pools); dynamic steppers default to :func:`host_wallclock_times`, which
    feeds the loop the measured step wall clock (per-device hardware timers
    stay a ROADMAP item).
    """

    def __init__(self, positions: np.ndarray, gamma: np.ndarray, sigma: float,
                 *, p: int = 12, dt: float = 0.005, mesh=None,
                 mesh_axis: str = "data", use_kernels: bool = False,
                 plan_method: str = "model", dynamic: bool = False,
                 plan_grid=None, overlap: bool = True,
                 replan_every: int = 4, replan_tol: float = 0.05,
                 target_per_box: float = 8.0, slots_headroom: float = 2.0,
                 occupancy_guard: float = 0.9, cut: Optional[int] = None,
                 payload=None,
                 measured_times_fn: Optional[Callable[["VortexStepper"],
                                                      np.ndarray]] = None):
        self.p, self.dt = p, float(dt)
        self.mesh, self.mesh_axis = mesh, mesh_axis
        self.use_kernels = use_kernels
        self.plan_method = plan_method
        self.dynamic = dynamic
        self.overlap = overlap
        self.plan_grid = plan_grid if plan_grid in (None, "auto") \
            else tuple(plan_grid)
        self.replan_every = max(int(replan_every), 1)
        self.replan_tol = float(replan_tol)
        self.target_per_box = float(target_per_box)
        self.slots_headroom = float(slots_headroom)
        self.occupancy_guard = float(occupancy_guard)
        self._cut = cut
        self.sigma = float(sigma)
        # dynamic steppers default to the host wall-clock timer so
        # --plan dynamic exercises the full measured-feedback loop with
        # real magnitudes (injected per-device timers override it)
        if measured_times_fn is None and dynamic:
            measured_times_fn = host_wallclock_times
        self.measured_times_fn = measured_times_fn
        self.step_count = 0
        self.history: list[StepRecord] = []

        self._build_host(np.asarray(positions, np.float64),
                         np.asarray(gamma, np.float64),
                         payload_values=None if payload is None else payload)

    # -- host-side (re)construction -----------------------------------------

    @property
    def nparts(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.mesh_axis]

    def _min_level(self) -> int:
        # every device needs at least one parent row (2 leaf rows); a 2-D
        # grid only needs that per axis.  "auto" must fit its most
        # demanding *surviving* candidate, so size for the most square
        # factorization (the least demanding per axis) — larger-axis
        # candidates that don't fit are skipped by the autotuner.
        if self.plan_grid == "auto":
            need = max(min(2 * max(g) for g in candidate_grids(self.nparts)),
                       4)
        elif self.plan_grid is not None:
            need = max(2 * max(self.plan_grid), 4)
        else:
            need = max(2 * self.nparts, 4)
        return max(2, math.ceil(math.log2(need)))

    def _build_host(self, positions, gamma, payload_values=None):
        level = max(choose_level(len(positions), self.target_per_box),
                    self._min_level())
        n = 1 << level
        ij = np.clip((positions * n).astype(np.int64), 0, n - 1)
        occ = np.bincount(ij[:, 1] * n + ij[:, 0], minlength=n * n).max()
        slots = max(int(math.ceil(occ * self.slots_headroom)), 2)
        self.tree, self.index = build_tree(positions, gamma, level,
                                           self.sigma, slots=slots)
        if payload_values is not None:
            def scatter(v):
                flat = np.zeros((n * n, slots), dtype=np.asarray(v).dtype)
                flat[self.index.box_of_particle,
                     self.index.slot_of_particle] = v
                return jnp.asarray(flat.reshape(n, n, slots))
            self.payload = jax.tree_util.tree_map(scatter, payload_values)
        else:
            self.payload = None
        cut = self._cut if self._cut is not None else min(level - 1, 4)
        self.params = ModelParams(level=level, cut=max(cut, 1), p=self.p,
                                  slots=slots)
        if self.plan_grid not in (None, "auto") and \
                self.plan_grid[0] * self.plan_grid[1] != self.nparts:
            raise ValueError(f"plan_grid {self.plan_grid} has "
                             f"{self.plan_grid[0] * self.plan_grid[1]} tiles"
                             f" for {self.nparts} devices")
        counts = self.index.counts
        if self.plan_grid == "auto":
            self.plan = autotune_plan(counts, self.params, self.nparts,
                                      method=self.plan_method,
                                      overlap=self.overlap)
        else:
            self.plan = plan_from_counts(counts, self.params, self.nparts,
                                         method=self.plan_method,
                                         grid=self.plan_grid)
        self.subtree_assign = assignment_from_plan(self.plan, self.params.cut)
        self._cached_lb = plan_stats(self.plan, counts,
                                     self.params)["load_balance"]

    def counts(self) -> np.ndarray:
        return np.asarray(self.tree.mask.sum(axis=-1))

    def particles(self) -> tuple[np.ndarray, np.ndarray]:
        """(positions, gamma) of the live particles, host-side."""
        m = np.asarray(self.tree.mask).reshape(-1)
        z = np.asarray(self.tree.z).reshape(-1)[m]
        q = np.asarray(self.tree.q).reshape(-1)[m]
        pos = np.stack([z.real, z.imag], axis=1)
        return pos, np.real(q * 2j * np.pi)

    def _relevel(self):
        """Host rebuild at a freshly chosen level/capacity (overflow guard)."""
        pos, gamma = self.particles()
        payload_values = None
        if self.payload is not None:
            m = np.asarray(self.tree.mask).reshape(-1)
            payload_values = jax.tree_util.tree_map(
                lambda a: np.asarray(a).reshape(-1)[m], self.payload)
        self._build_host(pos, gamma, payload_values=payload_values)

    # -- the dynamic loop ----------------------------------------------------

    def maybe_replan(self, measured_times: Optional[np.ndarray] = None,
                     occ: Optional[int] = None) -> bool:
        """Re-level if occupancy approaches capacity; re-plan if it pays.

        ``occ`` (max leaf occupancy) is normally read off the jitted step's
        own outputs (``rk2_step`` returns it), so the overflow guard
        triggers no extra device sync; the counts grid is then pulled once
        per replan interval to refresh the reported load balance and (when
        dynamic) drive the re-plan.
        Returns True when a new plan (or tree level) was adopted."""
        if occ is None:
            occ = int(np.asarray(self.tree.mask.sum(axis=-1).max()))
        if occ >= self.occupancy_guard * self.params.slots:
            self._relevel()
            return True
        counts = self.counts()
        self._counts_cache = counts     # reused by host_wallclock_times
        self._cached_lb = plan_stats(self.plan, counts,
                                     self.params)["load_balance"]
        if not self.dynamic:
            return False
        if measured_times is None and self.measured_times_fn is not None:
            measured_times = self.measured_times_fn(self)
        new_plan = replan(counts, self.params, self.nparts,
                          prev_plan=self.plan, measured_times=measured_times,
                          method=self.plan_method, grid=self.plan_grid,
                          overlap=self.overlap)
        if new_plan == self.plan:
            return False
        # adopt when the modeled bottleneck (measured-rate-weighted when
        # times are available) improves by more than the tolerance
        scale = None
        if measured_times is not None:
            scale = measured_row_scale(self.plan, counts, self.params,
                                       measured_times)
        old_max = plan_loads(self.plan, counts, self.params, scale).max()
        new_max = plan_loads(new_plan, counts, self.params, scale).max()
        if new_max > (1.0 - self.replan_tol) * old_max:
            return False
        self.plan = new_plan
        self._cached_lb = plan_stats(new_plan, counts,
                                     self.params)["load_balance"]
        # keep the paper's 2-D subtree assignment in sync (graph stats /
        # rebalance parity with §4)
        graph = pt.build_subtree_graph(counts, self.params)
        if measured_times is not None:
            self.subtree_assign = pt.rebalance(
                graph, assignment_from_plan(new_plan, self.params.cut),
                self.nparts, measured_times)
        else:
            self.subtree_assign = assignment_from_plan(new_plan,
                                                       self.params.cut)
        return True

    def step(self) -> StepRecord:
        """Advance one RK2 step; time it; periodically re-plan."""
        t0 = time.perf_counter()
        tree, payload, ok, occ = rk2_step(
            self.tree, self.dt, self.payload, p=self.p, mesh=self.mesh,
            mesh_axis=self.mesh_axis, use_kernels=self.use_kernels,
            plan=None if self.mesh is None else self.plan,
            overlap=self.overlap)
        jax.block_until_ready(tree.z)
        releveled = not bool(ok)
        if releveled:
            # a box overflowed during rebinning: the old tree is still
            # intact — re-level on the host and redo the step safely.
            self._relevel()
            tree, payload, ok, occ = rk2_step(
                self.tree, self.dt, self.payload, p=self.p, mesh=self.mesh,
                mesh_axis=self.mesh_axis, use_kernels=self.use_kernels,
                plan=None if self.mesh is None else self.plan,
                overlap=self.overlap)
            jax.block_until_ready(tree.z)
            if not bool(ok):
                raise RuntimeError(
                    "leaf box overflow persists after re-leveling; "
                    "increase slots_headroom or lower target_per_box")
        # the timer covers everything the step actually cost, including a
        # re-level + recompile when one happened
        seconds = time.perf_counter() - t0
        self.tree, self.payload = tree, payload
        self.step_count += 1
        replanned = False
        self._counts_cache = None       # tree advanced: drop stale counts
        if self.step_count % self.replan_every == 0:
            # occ comes off the step's own outputs (already on host after
            # block_until_ready) — the check itself syncs nothing extra
            replanned = self.maybe_replan(occ=int(occ))
        rec = StepRecord(step=self.step_count, seconds=seconds,
                         load_balance=self._cached_lb,
                         replanned=replanned, releveled=releveled,
                         level=self.params.level)
        self.history.append(rec)
        return rec

    def stats(self) -> dict:
        return plan_stats(self.plan, self.counts(), self.params)
