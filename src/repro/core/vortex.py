"""Vortex-particle client application (paper §3 and §7.1).

Complex-velocity convention: ``W = u - i v``.  A vortex of circulation
``gamma_j`` at ``z_j`` induces

    W(z) = gamma_j / (2*pi*i * (z - z_j))                       (singular)
    W_sigma(z) = W(z) * (1 - exp(-|z - z_j|^2 / (2 sigma^2)))   (Gaussian core)

which matches the paper's Eq (8).  With pseudo-charge ``q = gamma/(2*pi*i)``
both kernels are ``q/(z - z_j)`` times a mollifier.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def pairwise_w(z_tgt: jnp.ndarray, z_src: jnp.ndarray, q_src: jnp.ndarray,
               mask_src: jnp.ndarray, sigma: float | None,
               exclude_self: bool = True) -> jnp.ndarray:
    """Direct-sum complex velocity at ``z_tgt`` from masked sources.

    Shapes: z_tgt (..., T), z_src/q_src/mask_src (..., S) -> (..., T).
    ``sigma=None`` selects the singular kernel (used for far-field
    verification); finite sigma selects the regularized Biot-Savart kernel.
    Self/coincident pairs are excluded via an |dz|^2 == 0 guard.
    """
    dz = z_tgt[..., :, None] - z_src[..., None, :]            # (..., T, S)
    r2 = (dz * jnp.conj(dz)).real
    valid = mask_src[..., None, :] & (r2 > 0 if exclude_self else jnp.bool_(True))
    inv = jnp.where(valid, 1.0, 0.0) / jnp.where(r2 > 0, dz, 1.0)
    if sigma is not None:
        moll = 1.0 - jnp.exp(-r2 / (2.0 * sigma * sigma))
        inv = inv * moll.astype(inv.dtype)
    return jnp.einsum("...ts,...s->...t", inv, q_src)


def direct_sum(z: np.ndarray, gamma: np.ndarray, sigma: float | None,
               chunk: int = 2048) -> np.ndarray:
    """O(N^2) oracle: complex velocity W = u - iv at every particle (f64)."""
    z = np.asarray(z, dtype=np.complex128)
    q = np.asarray(gamma, dtype=np.float64) / (2j * np.pi)
    out = np.zeros_like(z)
    for start in range(0, len(z), chunk):
        zt = z[start:start + chunk]
        dz = zt[:, None] - z[None, :]
        r2 = np.abs(dz) ** 2
        with np.errstate(divide="ignore", invalid="ignore"):
            inv = np.where(r2 > 0, 1.0 / np.where(r2 > 0, dz, 1.0), 0.0)
        if sigma is not None:
            inv = inv * (1.0 - np.exp(-r2 / (2.0 * sigma * sigma)))
        out[start:start + chunk] = inv @ q
    return out


def velocity_from_w(w) -> tuple:
    """(u, v) from complex W = u - iv."""
    return (np.real(w), -np.imag(w)) if isinstance(w, np.ndarray) else (jnp.real(w), -jnp.imag(w))


# ---------------------------------------------------------------------------
# Lamb-Oseen vortex test case (paper §7.1)
# ---------------------------------------------------------------------------


def lamb_oseen_omega(r: np.ndarray, gamma0: float, nu: float, t: float) -> np.ndarray:
    """Vorticity field, paper Eq (16)."""
    return gamma0 / (4.0 * np.pi * nu * t) * np.exp(-r * r / (4.0 * nu * t))


def lamb_oseen_velocity(x: np.ndarray, y: np.ndarray, gamma0: float, nu: float,
                        t: float, x0: float = 0.5, y0: float = 0.5) -> tuple[np.ndarray, np.ndarray]:
    """Analytical azimuthal velocity of the Lamb-Oseen vortex (paper Eq 17).

    u_theta(r) = Gamma0 / (2 pi r) * (1 - exp(-r^2 / (4 nu t)))
    (the paper's printed Eq (17) has a typo; this is the standard form).
    """
    dx, dy = x - x0, y - y0
    r2 = dx * dx + dy * dy
    r = np.sqrt(r2)
    with np.errstate(divide="ignore", invalid="ignore"):
        ut = gamma0 / (2.0 * np.pi * np.where(r > 0, r, 1.0)) * (1.0 - np.exp(-r2 / (4.0 * nu * t)))
    ut = np.where(r > 0, ut, 0.0)
    return -ut * dy / np.where(r > 0, r, 1.0), ut * dx / np.where(r > 0, r, 1.0)


def lamb_oseen_particles(m_side: int, gamma0: float = 1.0, nu: float = 5e-4,
                         t: float = 4.0, spacing_ratio: float = 0.8,
                         sigma: float = 0.02, extent: float = 0.8,
                         x0: float = 0.5, y0: float = 0.5):
    """Lattice particle initialization as in the paper's strong-scaling setup.

    Particles on an ``m_side x m_side`` lattice covering ``extent`` of the
    unit domain; circulation = vorticity * cell area (h = spacing, with
    h / sigma = spacing_ratio as in [4] of the paper).
    """
    h = sigma * spacing_ratio
    span = (m_side - 1) * h
    scale = 1.0
    if span > extent:  # keep lattice inside the unit domain
        scale = extent / span
        h *= scale
        span = extent
    xs = x0 - span / 2 + h * np.arange(m_side)
    ys = y0 - span / 2 + h * np.arange(m_side)
    X, Y = np.meshgrid(xs, ys, indexing="xy")
    r = np.sqrt((X - x0) ** 2 + (Y - y0) ** 2)
    w = lamb_oseen_omega(r, gamma0, nu, t)
    gamma = (w * h * h).ravel()
    pos = np.stack([X.ravel(), Y.ravel()], axis=1)
    return pos, gamma, sigma * scale
