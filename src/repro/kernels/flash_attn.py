"""Pallas TPU kernel: blockwise online-softmax (flash) attention.

Used by the LM substrate for train/prefill attention so the (T, S) score
matrix never materializes in HBM — required for the ``prefill_32k`` shapes
(32768^2 scores/head would be ~4 GiB/head/layer).

Structure (the canonical TPU flash pattern):
  * grid = (batch*heads, q_blocks, kv_blocks); the kv axis is minor-most so
    the output block for a given (bh, iq) is revisited across kv iterations
    and stays resident in VMEM;
  * running max ``m``, normalizer ``l`` and the unnormalized accumulator are
    carried in output refs (revisited blocks), initialized at ik == 0 and
    finalized (division) at the last kv block;
  * GQA is handled with *index arithmetic* in the k/v BlockSpec index_map
    (no materialized head repeat): kv row = (bh // H) * Hkv + (bh % H) // g;
  * causal blocks strictly above the diagonal are skipped via ``pl.when``.

VMEM budget per program: q(bq,d) + k/v(bk,d) + scores(bq,bk) + acc(bq,d);
bq = bk = 128..512 with d = 64..256 stays well under 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
               scale: float, causal: bool, bq: int, bk: int, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _block():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos > qpos, NEG_INF, s)
        m_prev = m_ref[0]                              # (bq,)
        l_prev = l_ref[0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        palpha = jnp.exp(s - m_new[:, None])
        l_ref[0] = l_prev * alpha + palpha.sum(axis=-1)
        o_ref[0] = o_ref[0] * alpha[:, None] + \
            jnp.dot(palpha, v, preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    if causal:
        # skip kv blocks strictly above the causal diagonal
        pl.when(ik * bk <= iq * bq + bq - 1)(_block)
    else:
        _block()

    @pl.when(ik == nk - 1)
    def _fin():
        l = l_ref[0]
        o_ref[0] = o_ref[0] / jnp.where(l > 0, l, 1.0)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, T, d); k, v: (B, Hkv, S, d) with H % Hkv == 0 -> (B, H, T, d)."""
    B, H, T, d = q.shape
    _, Hkv, S, _ = k.shape
    assert H % Hkv == 0
    group = H // Hkv
    scale = 1.0 / (d ** 0.5)

    bq = min(block_q, T)
    bk = min(block_k, S)
    assert T % bq == 0 and S % bk == 0, (T, bq, S, bk)
    nq, nk = T // bq, S // bk

    qf = q.reshape(B * H, T, d)
    kf = k.reshape(B * Hkv, S, d)
    vf = v.reshape(B * Hkv, S, d)

    def kv_row(bh):
        return (bh // H) * Hkv + (bh % H) // group

    grid = (B * H, nq, nk)
    in_specs = [
        pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, bk, d), lambda b, i, j: (kv_row(b), j, 0)),
        pl.BlockSpec((1, bk, d), lambda b, i, j: (kv_row(b), j, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B * H, T, d), jnp.float32),
        jax.ShapeDtypeStruct((B * H, T), jnp.float32),
        jax.ShapeDtypeStruct((B * H, T), jnp.float32),
    ]
    kern = functools.partial(_fa_kernel, scale=scale, causal=causal,
                             bq=bq, bk=bk, nk=nk)
    o, _, _ = pl.pallas_call(kern, grid=grid, in_specs=in_specs,
                             out_specs=out_specs, out_shape=out_shape,
                             interpret=interpret)(qf, kf, vf)
    return o.reshape(B, H, T, d).astype(q.dtype)
