"""Pallas TPU kernel: fused multipole-to-local (M2L) transformation.

M2L is the second FMM hot spot (paper Eq 10, term ``c``): every box at every
level receives up to 27 (p x p) transform-accumulates.  The naive dense path
writes the LE accumulator to HBM 40 times (once per candidate offset); this
kernel keeps the accumulator in VMEM and performs the whole 40-offset
reduction as ONE GEMM:

  * the wrapper gathers, per target box, the 40 candidate source MEs
    (validity/parity masks folded in at gather time — invalid sources are
    zeroed, so the kernel is a pure contraction);
  * scale normalization (DESIGN.md §3) makes the (40, p, p) operator tensor
    level-independent, so it lives in VMEM once, reshaped to a
    (40*p, p) matrix;
  * per block of boxes:  LE(B, p) = ME_gathered(B, 40*p) @ Op(40*p, p),
    a single MXU matmul with complex arithmetic expanded to 4 real GEMMs.

On real hardware pad p (17) and 40*p (680) up to lane multiples; correctness
is independent of padding.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import expansions as ex
from ..core.quadtree import M2L_OFFSETS, M2L_VALIDITY


def _m2l_kernel(ar_ref, ai_ref, opr_ref, opi_ref, br_ref, bi_ref):
    ar = ar_ref[...]        # (BB, 40p)
    ai = ai_ref[...]
    opr = opr_ref[...]      # (40p, p)
    opi = opi_ref[...]
    # complex GEMM via 4 real GEMMs (MXU)
    br_ref[...] = jnp.dot(ar, opr, preferred_element_type=jnp.float32) - \
        jnp.dot(ai, opi, preferred_element_type=jnp.float32)
    bi_ref[...] = jnp.dot(ar, opi, preferred_element_type=jnp.float32) + \
        jnp.dot(ai, opr, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("level", "p", "block_boxes", "interpret"))
def m2l_pallas(me: jnp.ndarray, level: int, p: int, block_boxes: int = 128,
               interpret: bool = True) -> jnp.ndarray:
    """Fused M2L over a (ny, nx, p) complex ME grid -> (ny, nx, p) LE grid."""
    ny, nx = me.shape[:2]
    nb = ny * nx
    r = 2.0 ** (-level)

    # --- gather the 40 candidate sources per box, masks folded in ---------
    pad = jnp.pad(me, ((3, 3), (3, 3), (0, 0)))
    slabs = []
    for oi, (dx, dy) in enumerate(M2L_OFFSETS):
        src = pad[3 + dy:3 + dy + ny, 3 + dx:3 + dx + nx, :]
        m = jnp.asarray(ex.parity_mask_rect(ny, nx, M2L_VALIDITY[oi]),
                        dtype=me.dtype)
        slabs.append(src * m[..., None])
    gathered = jnp.stack(slabs, axis=2).reshape(nb, 40 * p)   # (nb, 40p)

    ops = np.transpose(ex.m2l_operator(p), (0, 2, 1)).reshape(40 * p, p)
    opr = jnp.asarray(ops.real, dtype=jnp.float32)
    opi = jnp.asarray(ops.imag, dtype=jnp.float32)

    nb_pad = -(-nb // block_boxes) * block_boxes
    ar = jnp.pad(gathered.real.astype(jnp.float32), ((0, nb_pad - nb), (0, 0)))
    ai = jnp.pad(gathered.imag.astype(jnp.float32), ((0, nb_pad - nb), (0, 0)))

    grid = (nb_pad // block_boxes,)
    in_specs = [
        pl.BlockSpec((block_boxes, 40 * p), lambda i: (i, 0)),
        pl.BlockSpec((block_boxes, 40 * p), lambda i: (i, 0)),
        pl.BlockSpec((40 * p, p), lambda i: (0, 0)),   # operator: VMEM-resident
        pl.BlockSpec((40 * p, p), lambda i: (0, 0)),
    ]
    out_specs = [pl.BlockSpec((block_boxes, p), lambda i: (i, 0))] * 2
    out_shape = [jax.ShapeDtypeStruct((nb_pad, p), jnp.float32)] * 2

    br, bi = pl.pallas_call(
        _m2l_kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret,
    )(ar, ai, opr, opi)

    le = (br[:nb] + 1j * bi[:nb]).reshape(ny, nx, p).astype(me.dtype)
    return le / r
