"""Pallas TPU kernel: halo-resident, parity-folded multipole-to-local (M2L).

M2L is the second FMM hot spot (paper Eq 10, term ``c``): every box at every
level receives exactly 27 (p x p) transform-accumulates.  The old kernel
wrapper materialized a ``(nb, 40p)`` gathered ME tensor in HBM (40x the grid)
and computed all 40 candidate offsets with parity masks folded in at gather
time — ~1.5x excess flops plus 40x staging traffic.  This kernel does
neither:

  * the grid is relayouted once into **parent planes** — the 2x2 child
    parities stacked along the coefficient axis, ``(PR+2, PC+2, 4p)`` with a
    ±1 parent halo (= 2 child rows; see DESIGN.md §4).  Same bytes as the
    grid itself, no 40x staging tensor;
  * the Pallas grid tiles the parent grid into ``(BY, BX)`` blocks whose
    BlockSpecs read **overlapping halo tiles** ``(BY+2, BX+2, 4p)`` directly
    from the padded parent-plane grid (``pl.Unblocked`` element-offset
    indexing), so the halo never exists as a separate HBM buffer;
  * the parity-folded ``(8, 4p, 4p)`` block operator (scale-normalized,
    hence level-independent — DESIGN.md §3) is VMEM-resident across the
    whole launch; its structural zero blocks *are* the parity masks, so
    every box receives exactly its 27 valid interactions;
  * the LE accumulator lives in VMEM registers across the full 8-neighbor
    reduction: per tile, 8 complex matmuls ``(BY*BX, 4p) @ (4p, 4p)``
    (expanded to 4 real GEMMs each for the MXU), one HBM write at the end.

On real hardware pad 4p (68 for p=17) up to lane multiples; correctness is
independent of padding.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import equations as _eqs
from ..core import expansions as ex
from ..core.quadtree import PARENT_NEIGH8


def _m2l_kernel(sr_ref, si_ref, wr_ref, wi_ref, or_ref, oi_ref,
                *, BY: int, BX: int, p4: int):
    tr = sr_ref[...]            # (BY+2, BX+2, 4p) halo tile, real
    ti = si_ref[...]
    wr = wr_ref[...]            # (8, 4p, 4p) folded operator, VMEM-resident
    wi = wi_ref[...]
    accr = jnp.zeros((BY * BX, p4), jnp.float32)
    acci = jnp.zeros((BY * BX, p4), jnp.float32)
    for d, (Dx, Dy) in enumerate(PARENT_NEIGH8):
        ar = tr[1 + Dy:1 + Dy + BY, 1 + Dx:1 + Dx + BX, :].reshape(BY * BX, p4)
        ai = ti[1 + Dy:1 + Dy + BY, 1 + Dx:1 + Dx + BX, :].reshape(BY * BX, p4)
        # complex GEMM via 4 real GEMMs (MXU); accumulator stays in VMEM
        accr = accr + jnp.dot(ar, wr[d], preferred_element_type=jnp.float32) \
            - jnp.dot(ai, wi[d], preferred_element_type=jnp.float32)
        acci = acci + jnp.dot(ar, wi[d], preferred_element_type=jnp.float32) \
            + jnp.dot(ai, wr[d], preferred_element_type=jnp.float32)
    or_ref[...] = accr.reshape(BY, BX, p4)
    oi_ref[...] = acci.reshape(BY, BX, p4)


@functools.partial(jax.jit, static_argnames=("level", "p", "row0", "halo",
                                             "col0", "col_halo", "block",
                                             "interpret", "lane_pad", "eq"))
def m2l_pallas_slab(me_halo: jnp.ndarray, level: int, p: int, row0: int = 0,
                    halo: int = ex.M2L_HALO, col0: int = 0, col_halo: int = 0,
                    block: tuple[int, int] = (8, 8),
                    interpret: bool = True,
                    lane_pad: bool = False, eq=None) -> jnp.ndarray:
    """Parity-folded M2L over a halo'd slab/tile — same contract as
    ``expansions.m2l_folded``: ``me_halo`` is (rows + 2*halo,
    cols + 2*col_halo, p) with ghost data attached, ``row0``/``col0``
    anchor the global parity (``col_halo=0`` means full-width columns,
    zero-padded internally).  Returns the (rows, cols, p) LE slab.

    ``lane_pad=True`` pads the stacked coefficient axis ``4p`` up to a lane
    multiple of 128 (real-TPU layout; DESIGN.md §5) — the folded operator is
    zero-padded to match, so the extra lanes contribute exact zeros and the
    numerics are unchanged; the accumulator is sliced back to ``4p``.

    ``eq`` selects the equation spec supplying the folded block operator
    and dimension scalar (core/equations.py; vortex default) — the kernel
    body is equation-independent: one contraction, any registered operator.
    """
    eq = _eqs.get_equation(eq)
    rows = me_halo.shape[0] - 2 * halo
    cols = me_halo.shape[1] - 2 * col_halo
    p4 = 4 * p
    p4l = -(-p4 // 128) * 128 if lane_pad else p4
    stack, (PR, shift), (PC, cshift) = ex.m2l_slab_stack(me_halo, p, row0,
                                                         halo, col0, col_halo)

    BY, BX = min(block[0], PR), min(block[1], PC)
    PRp = -(-PR // BY) * BY
    PCp = -(-PC // BX) * BX
    sr = jnp.pad(stack.real.astype(jnp.float32),
                 ((0, PRp - PR), (0, PCp - PC), (0, p4l - p4)))
    si = jnp.pad(stack.imag.astype(jnp.float32),
                 ((0, PRp - PR), (0, PCp - PC), (0, p4l - p4)))

    W = eq.m2l_folded(p, level)
    wpad = ((0, 0), (0, p4l - p4), (0, p4l - p4))
    wr = jnp.asarray(np.pad(W.real, wpad), dtype=jnp.float32)
    wi = jnp.asarray(np.pad(W.imag, wpad), dtype=jnp.float32)

    grid = (PRp // BY, PCp // BX)
    halo_spec = pl.BlockSpec((BY + 2, BX + 2, p4l),
                             lambda i, j: (i * BY, j * BX, 0),
                             indexing_mode=pl.Unblocked())
    op_spec = pl.BlockSpec((8, p4l, p4l), lambda i, j: (0, 0, 0))
    out_spec = pl.BlockSpec((BY, BX, p4l), lambda i, j: (i, j, 0))
    out_shape = [jax.ShapeDtypeStruct((PRp, PCp, p4l), jnp.float32)] * 2

    br, bi = pl.pallas_call(
        functools.partial(_m2l_kernel, BY=BY, BX=BX, p4=p4l),
        grid=grid,
        in_specs=[halo_spec, halo_spec, op_spec, op_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(sr, si, wr, wi)

    acc = (br[:PR, :PC, :p4] + 1j * bi[:PR, :PC, :p4]).astype(me_halo.dtype)
    le = ex.from_parent_planes(acc, p)                   # (2PR, 2PC, p)
    le = jax.lax.slice_in_dim(le, shift, shift + rows, axis=0)
    le = jax.lax.slice_in_dim(le, cshift, cshift + cols, axis=1)
    return le * eq.m2l_scale(level)


def m2l_pallas(me: jnp.ndarray, level: int, p: int,
               block: tuple[int, int] = (8, 8),
               interpret: bool = True, lane_pad: bool = False,
               eq=None) -> jnp.ndarray:
    """Fused M2L over a full (ny, nx, p) complex ME grid -> (ny, nx, p) LE."""
    me_halo = jnp.pad(me, ((ex.M2L_HALO, ex.M2L_HALO), (0, 0), (0, 0)))
    return m2l_pallas_slab(me_halo, level, p, row0=0, halo=ex.M2L_HALO,
                           block=block, interpret=interpret,
                           lane_pad=lane_pad, eq=eq)
