"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the Pallas interpreter executes the
kernel body on CPU for validation); on TPU backends the compiled kernels
run natively.
"""
from __future__ import annotations

import jax

from . import flash_attn as _fa
from . import m2l as _m2l
from . import p2p as _p2p


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def p2p_apply(tree, block_boxes: int = 64):
    """P2P near field for a core.quadtree.Tree -> complex W (n, n, s)."""
    return _p2p.p2p_pallas(tree.z, tree.q, tree.mask, sigma=tree.sigma,
                           block_boxes=block_boxes, interpret=_interpret())


def m2l_apply(me, level: int, p: int, block_boxes: int = 128):
    """Fused M2L for one level's (ny, nx, p) ME grid."""
    return _m2l.m2l_pallas(me, level, p, block_boxes=block_boxes,
                           interpret=_interpret())


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """Blockwise attention; q (B,H,T,d), k/v (B,Hkv,S,d)."""
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())
