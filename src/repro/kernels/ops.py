"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the Pallas interpreter executes the
kernel body on CPU for validation); on TPU backends the compiled kernels
run natively.

The M2L/P2P wrappers come in two forms with one kernel behind both: the
grid form (serial driver — zero ghosts attached here) and the slab form
(sharded driver — ghosts already exchanged by the caller).  See DESIGN.md
§4/§5.

Plan-aware block autotuning (DESIGN.md §5/§9; Holm et al., arXiv:1311.1006):
``block=None`` resolves the ``(BY, BX)`` launch tiling from a small static
table keyed by the launch-shape class the execution plan implies — the
monolithic/interior tile, or one of the thin rim strips of the overlapped
driver.  Block shape is a pure perf knob (bit-equivalent outputs, pinned by
tests), so the table can be retuned per backend without touching numerics.
Lane padding (``lane_pad=None`` -> pad on real TPU only) pads the kernels'
lane axes (``s`` for P2P, ``4p`` for M2L) to multiples of 128 inside the
wrappers; padded lanes are structural zeros, so this too is numerics-free.

Under the substep pipeline (DESIGN.md §12) the rim-strip launches of the
overlapped driver may execute while a second exchange buffer is in
flight (next substep's packed P2P halo, or the cut-level gather).  The
kernels are oblivious to this: launch shapes, block tables, and operand
buffers are unchanged — the in-flight buffer is a *different* array the
consumer reads later, never an alias of a kernel operand, so no kernel
ever races a collective.
"""
from __future__ import annotations

import jax

from . import flash_attn as _fa
from . import m2l as _m2l
from . import p2p as _p2p
from ..core import expansions as _ex


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Plan-aware block autotuning
# ---------------------------------------------------------------------------

# Static (BY, BX) per launch-shape class.  Classes map onto what an
# execution plan actually launches: full interior/monolithic tiles, wide
# row-slab tiles, and the thin rim strips of the overlapped driver (a few
# rows/cols spanning the whole tile edge).  Values are clipped to the
# launch extents, and the kernels pad non-dividing extents up to a block
# multiple, so any entry is legal for any shape.
BLOCK_TABLE: dict[str, tuple[int, int]] = {
    "rim_row": (2, 32),     # thin row strips: keep the whole strip in one
    "rim_col": (32, 2),     # sublane/lane-friendly pass along its long axis
    "small": (4, 4),        # tiles smaller than one default block
    "wide": (8, 16),        # row-slab tiles much wider than tall
    "tile": (8, 8),         # default square interior launch
}


def _shape_class(rows: int, cols: int) -> str:
    if rows <= 4 and cols > 4 * rows:
        return "rim_row"
    if cols <= 4 and rows > 4 * cols:
        return "rim_col"
    if rows <= 4 and cols <= 4:
        return "small"
    if cols >= 4 * rows:
        return "wide"
    return "tile"


def autotune_block(rows: int, cols: int) -> tuple[int, int]:
    """Pick ``(BY, BX)`` for a static (rows, cols) launch from BLOCK_TABLE.

    Clipped to the launch extents so a block never exceeds the grid it
    tiles.  Pure perf knob — every choice is bit-equivalent (DESIGN.md §5).
    """
    by, bx = BLOCK_TABLE[_shape_class(rows, cols)]
    return max(min(by, rows), 1), max(min(bx, cols), 1)


def _resolve(block, rows: int, cols: int, lane_pad):
    if block is None:
        block = autotune_block(rows, cols)
    if lane_pad is None:
        lane_pad = not _interpret()
    return block, lane_pad


def p2p_apply_slab(z_halo, q_halo, mask_halo, sigma,
                   block: tuple[int, int] | None = None,
                   lane_pad: bool | None = None, z_tgt=None, eq=None):
    """P2P over a slab with ±1 ghost rows/cols attached (sharded driver).

    ``block=None`` autotunes ``(BY, BX)`` from the interior launch shape;
    ``lane_pad=None`` pads the slot axes to lane multiples of 128 on real
    TPU.  ``z_tgt`` selects passive-target evaluation and ``eq`` the
    equation spec supplying the pair interaction (vortex default).
    """
    block, lane_pad = _resolve(block, z_halo.shape[0] - 2,
                               z_halo.shape[1] - 2, lane_pad)
    return _p2p.p2p_pallas_slab(z_halo, q_halo, mask_halo, sigma=sigma,
                                block=block, interpret=_interpret(),
                                lane_pad=lane_pad, z_tgt=z_tgt, eq=eq)


def m2l_apply(me, level: int, p: int, block: tuple[int, int] | None = None,
              lane_pad: bool | None = None, eq=None):
    """Parity-folded M2L for one level's full (ny, nx, p) ME grid."""
    block, lane_pad = _resolve(block, me.shape[0] // 2, me.shape[1] // 2,
                               lane_pad)
    return _m2l.m2l_pallas(me, level, p, block=block, interpret=_interpret(),
                           lane_pad=lane_pad, eq=eq)


def m2l_apply_slab(me_halo, level: int, p: int, row0: int = 0,
                   halo: int = _ex.M2L_HALO, col0: int = 0, col_halo: int = 0,
                   block: tuple[int, int] | None = None,
                   lane_pad: bool | None = None, eq=None):
    """Parity-folded M2L over a halo'd row slab or 2-D tile (sharded
    driver); ``col_halo>0`` means column ghosts are attached too.

    ``block=None`` autotunes ``(BY, BX)`` from the parent-plane launch
    shape (the tile/rim geometry the plan implies); ``lane_pad=None`` pads
    ``4p`` to a lane multiple of 128 on real TPU.  ``eq`` selects the
    equation spec supplying the folded operator (vortex default).
    """
    if block is None or lane_pad is None:
        rows = me_halo.shape[0] - 2 * halo
        _, PR, _ = _ex.m2l_slab_geometry(rows, row0, halo)
        if col_halo == 0:
            PC = me_halo.shape[1] // 2
        else:
            _, PC, _ = _ex.m2l_slab_geometry(me_halo.shape[1] - 2 * col_halo,
                                             col0, col_halo)
        block, lane_pad = _resolve(block, PR, PC, lane_pad)
    return _m2l.m2l_pallas_slab(me_halo, level, p, row0=row0, halo=halo,
                                col0=col0, col_halo=col_halo,
                                block=block, interpret=_interpret(),
                                lane_pad=lane_pad, eq=eq)


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """Blockwise attention; q (B,H,T,d), k/v (B,Hkv,S,d)."""
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())
