"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the Pallas interpreter executes the
kernel body on CPU for validation); on TPU backends the compiled kernels
run natively.

The M2L/P2P wrappers come in two forms with one kernel behind both: the
grid form (serial driver — zero ghosts attached here) and the slab form
(sharded driver — ghosts already exchanged by the caller).  See DESIGN.md
§4/§5.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import flash_attn as _fa
from . import m2l as _m2l
from . import p2p as _p2p
from ..core import expansions as _ex


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def p2p_apply_slab(z_halo, q_halo, mask_halo, sigma,
                   block: tuple[int, int] = (8, 8)):
    """P2P over a slab with ±1 ghost rows/cols attached (sharded driver)."""
    return _p2p.p2p_pallas_slab(z_halo, q_halo, mask_halo, sigma=sigma,
                                block=block, interpret=_interpret())


def m2l_apply(me, level: int, p: int, block: tuple[int, int] = (8, 8)):
    """Parity-folded M2L for one level's full (ny, nx, p) ME grid."""
    return _m2l.m2l_pallas(me, level, p, block=block, interpret=_interpret())


def m2l_apply_slab(me_halo, level: int, p: int, row0: int = 0,
                   halo: int = _ex.M2L_HALO, col0: int = 0, col_halo: int = 0,
                   block: tuple[int, int] = (8, 8)):
    """Parity-folded M2L over a halo'd row slab or 2-D tile (sharded
    driver); ``col_halo>0`` means column ghosts are attached too."""
    return _m2l.m2l_pallas_slab(me_halo, level, p, row0=row0, halo=halo,
                                col0=col0, col_halo=col_halo,
                                block=block, interpret=_interpret())


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """Blockwise attention; q (B,H,T,d), k/v (B,Hkv,S,d)."""
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())
