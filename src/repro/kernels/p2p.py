"""Pallas TPU kernel: halo-resident near-field direct interactions (P2P).

The P2P stage dominates FMM runtime (paper Eq 10, the ``d N B / P`` term),
so it gets a hand-written kernel.  The old wrapper gathered each leaf box's
3x3 neighborhood into a dense ``(boxes, 9s)`` source slab — 9x the particle
data staged through HBM before the kernel even started.  This version stages
nothing:

  * the leaf grid is padded by ±1 box (zeros at the domain edge; under
    ``shard_map`` the ghost rows have already been exchanged by the caller)
    and the Pallas grid tiles it into ``(BY, BX)`` blocks whose BlockSpecs
    read **overlapping halo tiles** ``(BY+2, BX+2, s)`` directly from the
    padded grid (``pl.Unblocked`` element-offset indexing);
  * the kernel slices the 9 neighbor offsets out of its VMEM tile and
    evaluates the regularized Biot-Savart pairwise sum on the VPU, keeping
    the W accumulator in VMEM across the whole 9-offset reduction — one HBM
    write per tile, ``(BB, s, s)`` pair temporaries instead of the old
    ``(BB, s, 9s)``;
  * complex arithmetic is explicit real/imag (the MXU/VPU have no complex
    type): with q = qr + i*qi, dz = dx + i*dy,
        w += q / dz * moll = (qr*dx + qi*dy + i(qi*dx - qr*dy)) / r2 * moll.

Block sizing: the (BY*BX, s, s) pair tensor should stay under ~2 MiB (f32),
and the lane dimension (s) should be a multiple of 128 on real hardware (pad
``s`` accordingly; correctness does not depend on it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.quadtree import P2P_OFFSETS

P2P_HALO = 1    # ghost rows/cols of particle data needed by a slab


def _p2p_kernel(zr_ref, zi_ref, qr_ref, qi_ref, m_ref, wr_ref, wi_ref,
                *, sigma: float | None, BY: int, BX: int, s: int):
    zr = zr_ref[...]            # (BY+2, BX+2, s) halo tiles
    zi = zi_ref[...]
    qr = qr_ref[...]
    qi = qi_ref[...]
    m = m_ref[...]
    tx = zr[1:1 + BY, 1:1 + BX, :].reshape(BY * BX, s)   # interior targets
    ty = zi[1:1 + BY, 1:1 + BX, :].reshape(BY * BX, s)
    accr = jnp.zeros((BY * BX, s), jnp.float32)
    acci = jnp.zeros((BY * BX, s), jnp.float32)
    for (dx, dy) in P2P_OFFSETS:
        sx = zr[1 + dy:1 + dy + BY, 1 + dx:1 + dx + BX, :].reshape(BY * BX, s)
        sy = zi[1 + dy:1 + dy + BY, 1 + dx:1 + dx + BX, :].reshape(BY * BX, s)
        sqr = qr[1 + dy:1 + dy + BY, 1 + dx:1 + dx + BX, :].reshape(BY * BX, s)
        sqi = qi[1 + dy:1 + dy + BY, 1 + dx:1 + dx + BX, :].reshape(BY * BX, s)
        sm = m[1 + dy:1 + dy + BY, 1 + dx:1 + dx + BX, :].reshape(BY * BX, s)
        ddx = tx[:, :, None] - sx[:, None, :]            # (BB, s, s)
        ddy = ty[:, :, None] - sy[:, None, :]
        r2 = ddx * ddx + ddy * ddy
        valid = (sm[:, None, :] > 0) & (r2 > 0.0)
        inv_r2 = jnp.where(valid, 1.0, 0.0) / jnp.where(r2 > 0.0, r2, 1.0)
        if sigma is not None:
            inv_r2 = inv_r2 * (1.0 - jnp.exp(-r2 / (2.0 * sigma * sigma)))
        qrb = sqr[:, None, :]
        qib = sqi[:, None, :]
        accr = accr + ((qrb * ddx + qib * ddy) * inv_r2).sum(axis=-1)
        acci = acci + ((qib * ddx - qrb * ddy) * inv_r2).sum(axis=-1)
    wr_ref[...] = accr.reshape(BY, BX, s)
    wi_ref[...] = acci.reshape(BY, BX, s)


@functools.partial(jax.jit, static_argnames=("sigma", "block", "interpret",
                                             "lane_pad"))
def p2p_pallas_slab(z_halo, q_halo, mask_halo, sigma=None,
                    block: tuple[int, int] = (8, 8), interpret: bool = True,
                    lane_pad: bool = False):
    """P2P over a slab with ±1 ghost rows/cols already attached.

    z_halo/q_halo: complex (rows+2, cols+2, s); mask_halo: bool.  Ghosts are
    zeros at domain edges or exchanged halos under ``shard_map``.  Returns
    the interior (rows, cols, s) complex W per slot.

    ``lane_pad=True`` pads the slot axis ``s`` up to a lane multiple of 128
    (real-TPU layout; DESIGN.md §5) — padded slots carry ``mask=0`` so they
    are structurally excluded and the numerics are unchanged; the output is
    sliced back to ``s``.
    """
    rows, cols, s = (z_halo.shape[0] - 2, z_halo.shape[1] - 2,
                     z_halo.shape[2])
    sl = -(-s // 128) * 128 if lane_pad else s
    BY, BX = min(block[0], rows), min(block[1], cols)
    rowsP = -(-rows // BY) * BY
    colsP = -(-cols // BX) * BX

    def prep(x):
        return jnp.pad(x.astype(jnp.float32),
                       ((0, rowsP - rows), (0, colsP - cols), (0, sl - s)))

    zr, zi = prep(z_halo.real), prep(z_halo.imag)
    qr, qi = prep(q_halo.real), prep(q_halo.imag)
    m = prep(mask_halo)

    grid = (rowsP // BY, colsP // BX)
    halo_spec = pl.BlockSpec((BY + 2, BX + 2, sl),
                             lambda i, j: (i * BY, j * BX, 0),
                             indexing_mode=pl.Unblocked())
    out_spec = pl.BlockSpec((BY, BX, sl), lambda i, j: (i, j, 0))
    out_shape = [jax.ShapeDtypeStruct((rowsP, colsP, sl), jnp.float32)] * 2

    wr, wi = pl.pallas_call(
        functools.partial(_p2p_kernel, sigma=sigma, BY=BY, BX=BX, s=sl),
        grid=grid,
        in_specs=[halo_spec] * 5,
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(zr, zi, qr, qi, m)

    return (wr[:rows, :cols, :s] + 1j * wi[:rows, :cols, :s]).astype(z_halo.dtype)


def p2p_pallas(z, q, mask, sigma=None, block: tuple[int, int] = (8, 8),
               interpret: bool = True, lane_pad: bool = False):
    """P2P over a (ny, nx, s) dense leaf grid.  Returns complex W per slot.

    z, q: complex64; mask: bool.  ``interpret=True`` runs the kernel body in
    the Pallas interpreter (CPU validation); on TPU pass False.
    """
    pad = ((P2P_HALO, P2P_HALO), (P2P_HALO, P2P_HALO), (0, 0))
    return p2p_pallas_slab(jnp.pad(z, pad), jnp.pad(q, pad),
                           jnp.pad(mask, pad), sigma=sigma, block=block,
                           interpret=interpret, lane_pad=lane_pad)
