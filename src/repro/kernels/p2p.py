"""Pallas TPU kernel: halo-resident near-field direct interactions (P2P).

The P2P stage dominates FMM runtime (paper Eq 10, the ``d N B / P`` term),
so it gets a hand-written kernel.  The old wrapper gathered each leaf box's
3x3 neighborhood into a dense ``(boxes, 9s)`` source slab — 9x the particle
data staged through HBM before the kernel even started.  This version stages
nothing:

  * the leaf grid is padded by ±1 box (zeros at the domain edge; under
    ``shard_map`` the ghost rows have already been exchanged by the caller)
    and the Pallas grid tiles it into ``(BY, BX)`` blocks whose BlockSpecs
    read **overlapping halo tiles** ``(BY+2, BX+2, s)`` directly from the
    padded grid (``pl.Unblocked`` element-offset indexing);
  * the kernel slices the 9 neighbor offsets out of its VMEM tile and
    evaluates the pair interaction on the VPU, keeping the accumulators in
    VMEM across the whole 9-offset reduction — one HBM write per output
    tile, ``(BB, st, s)`` pair temporaries instead of the old
    ``(BB, s, 9s)``;
  * the pair interaction itself comes from the equation spec
    (``core/equations.py: p2p_terms`` — explicit real/imag arithmetic, the
    MXU/VPU have no complex type).  The kernel body is equation-independent
    and emits ``eq.nout`` complex channels; passive source != target
    evaluation (probe grids, tracers) runs through the SAME kernel with the
    targets as a separate ``(BY, BX, st)`` block, while the default
    source == target mode slices its targets out of the already-loaded
    halo tile (no extra input streams — the pre-registry data path).

Block sizing: the (BY*BX, st, s) pair tensor should stay under ~2 MiB (f32),
and the lane dimension (s) should be a multiple of 128 on real hardware (pad
``s`` accordingly; correctness does not depend on it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import equations as _eqs
from ..core.quadtree import P2P_OFFSETS

P2P_HALO = 1    # ghost rows/cols of particle data needed by a slab


def _p2p_kernel(*refs, eq, sigma: float | None, BY: int, BX: int,
                st: int, s: int, tgt_from_src: bool):
    if tgt_from_src:
        zr_ref, zi_ref, qr_ref, qi_ref, m_ref, *out_refs = refs
    else:
        txr_ref, txi_ref, zr_ref, zi_ref, qr_ref, qi_ref, m_ref, \
            *out_refs = refs
    zr = zr_ref[...]            # (BY+2, BX+2, s) source halo tiles
    zi = zi_ref[...]
    qr = qr_ref[...]
    qi = qi_ref[...]
    m = m_ref[...]
    if tgt_from_src:
        # source == target mode: the targets ARE the halo tile's interior
        # — slice them out of the already-loaded zr/zi instead of paying
        # two extra HBM->VMEM input streams (st == s here)
        tx = zr[1:1 + BY, 1:1 + BX, :].reshape(BY * BX, st)
        ty = zi[1:1 + BY, 1:1 + BX, :].reshape(BY * BX, st)
    else:
        tx = txr_ref[...].reshape(BY * BX, st)   # (BY, BX, st) target block
        ty = txi_ref[...].reshape(BY * BX, st)
    nout = len(out_refs) // 2
    accs = [jnp.zeros((BY * BX, st), jnp.float32) for _ in range(2 * nout)]
    for (dx, dy) in P2P_OFFSETS:
        sx = zr[1 + dy:1 + dy + BY, 1 + dx:1 + dx + BX, :].reshape(BY * BX, s)
        sy = zi[1 + dy:1 + dy + BY, 1 + dx:1 + dx + BX, :].reshape(BY * BX, s)
        sqr = qr[1 + dy:1 + dy + BY, 1 + dx:1 + dx + BX, :].reshape(BY * BX, s)
        sqi = qi[1 + dy:1 + dy + BY, 1 + dx:1 + dx + BX, :].reshape(BY * BX, s)
        sm = m[1 + dy:1 + dy + BY, 1 + dx:1 + dx + BX, :].reshape(BY * BX, s)
        ddx = tx[:, :, None] - sx[:, None, :]            # (BB, st, s)
        ddy = ty[:, :, None] - sy[:, None, :]
        r2 = ddx * ddx + ddy * ddy
        valid = (sm[:, None, :] > 0) & (r2 > 0.0)
        moll = None
        if sigma is not None:
            moll = 1.0 - jnp.exp(-r2 / (2.0 * sigma * sigma))
        terms = eq.p2p_terms(ddx, ddy, r2, valid, sqr[:, None, :],
                             sqi[:, None, :], moll)
        for c, (tre, tim) in enumerate(terms):
            accs[2 * c] = accs[2 * c] + tre.sum(axis=-1)
            accs[2 * c + 1] = accs[2 * c + 1] + tim.sum(axis=-1)
    for i, ref in enumerate(out_refs):
        ref[...] = accs[i].reshape(BY, BX, st)


@functools.partial(jax.jit, static_argnames=("sigma", "block", "interpret",
                                             "lane_pad", "eq"))
def p2p_pallas_slab(z_halo, q_halo, mask_halo, sigma=None,
                    block: tuple[int, int] = (8, 8), interpret: bool = True,
                    lane_pad: bool = False, z_tgt=None, eq=None):
    """P2P over a slab with ±1 ghost rows/cols already attached.

    z_halo/q_halo: complex (rows+2, cols+2, s); mask_halo: bool.  Ghosts are
    zeros at domain edges or exchanged halos under ``shard_map``.  Returns
    the interior (rows, cols, st) complex output per slot — with a trailing
    ``eq.nout`` channel axis for multi-output equations.  ``z_tgt``
    (rows, cols, st) switches to passive-target evaluation (targets carry
    no halo; masked-off target slots yield don't-care values the caller
    masks); None evaluates at the sources themselves.

    ``lane_pad=True`` pads the slot axes up to lane multiples of 128
    (real-TPU layout; DESIGN.md §5) — padded source slots carry ``mask=0``
    so they are structurally excluded and the numerics are unchanged; the
    output is sliced back to ``st``.
    """
    eq = _eqs.get_equation(eq)
    rows, cols, s = (z_halo.shape[0] - 2, z_halo.shape[1] - 2,
                     z_halo.shape[2])
    tgt_from_src = z_tgt is None
    st = s if tgt_from_src else z_tgt.shape[2]
    sl = -(-s // 128) * 128 if lane_pad else s
    stl = sl if tgt_from_src else (-(-st // 128) * 128 if lane_pad else st)
    BY, BX = min(block[0], rows), min(block[1], cols)
    rowsP = -(-rows // BY) * BY
    colsP = -(-cols // BX) * BX

    def prep(x, lanes):
        # halo'd sources (rows+2 -> rowsP+2) and bare targets (rows ->
        # rowsP) take the same trailing pad
        return jnp.pad(x.astype(jnp.float32),
                       ((0, rowsP - rows), (0, colsP - cols),
                        (0, lanes - x.shape[2])))

    zr, zi = prep(z_halo.real, sl), prep(z_halo.imag, sl)
    qr, qi = prep(q_halo.real, sl), prep(q_halo.imag, sl)
    m = prep(mask_halo, sl)

    grid = (rowsP // BY, colsP // BX)
    halo_spec = pl.BlockSpec((BY + 2, BX + 2, sl),
                             lambda i, j: (i * BY, j * BX, 0),
                             indexing_mode=pl.Unblocked())
    tgt_spec = pl.BlockSpec((BY, BX, stl), lambda i, j: (i, j, 0))
    out_spec = pl.BlockSpec((BY, BX, stl), lambda i, j: (i, j, 0))
    out_shape = [jax.ShapeDtypeStruct((rowsP, colsP, stl), jnp.float32)
                 ] * (2 * eq.nout)

    if tgt_from_src:
        inputs = (zr, zi, qr, qi, m)
        in_specs = [halo_spec] * 5
    else:
        txr, txi = prep(z_tgt.real, stl), prep(z_tgt.imag, stl)
        inputs = (txr, txi, zr, zi, qr, qi, m)
        in_specs = [tgt_spec, tgt_spec] + [halo_spec] * 5

    outs = pl.pallas_call(
        functools.partial(_p2p_kernel, eq=eq, sigma=sigma, BY=BY, BX=BX,
                          st=stl, s=sl, tgt_from_src=tgt_from_src),
        grid=grid,
        in_specs=in_specs,
        out_specs=[out_spec] * (2 * eq.nout),
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)

    chans = [(outs[2 * c][:rows, :cols, :st] +
              1j * outs[2 * c + 1][:rows, :cols, :st]).astype(z_halo.dtype)
             for c in range(eq.nout)]
    return chans[0] if eq.nout == 1 else jnp.stack(chans, axis=-1)


def p2p_pallas(z, q, mask, sigma=None, block: tuple[int, int] = (8, 8),
               interpret: bool = True, lane_pad: bool = False, eq=None):
    """P2P over a (ny, nx, s) dense leaf grid.  Returns complex output per
    slot (trailing channel axis for multi-output equations).

    z, q: complex64; mask: bool.  ``interpret=True`` runs the kernel body in
    the Pallas interpreter (CPU validation); on TPU pass False.
    """
    pad = ((P2P_HALO, P2P_HALO), (P2P_HALO, P2P_HALO), (0, 0))
    return p2p_pallas_slab(jnp.pad(z, pad), jnp.pad(q, pad),
                           jnp.pad(mask, pad), sigma=sigma, block=block,
                           interpret=interpret, lane_pad=lane_pad, eq=eq)
