"""Pallas TPU kernel: near-field direct interactions (P2P).

The P2P stage dominates FMM runtime (paper Eq 10, the ``d N B / P`` term),
so it gets a hand-written kernel.  TPU adaptation of the paper's per-box
neighbor loops:

  * the wrapper gathers each leaf box's 3x3 neighborhood into a dense
    ``(boxes, 9*s)`` source slab (halo exchange happens *before* the kernel
    at the shard_map level, so the kernel itself is embarrassingly local);
  * the kernel tiles boxes into VMEM blocks and evaluates the regularized
    Biot-Savart pairwise sum on the VPU, targets x sources fully unrolled
    in registers;
  * complex arithmetic is explicit real/imag (the MXU/VPU have no complex
    type): with q = qr + i*qi, dz = dx + i*dy,
        w += q / dz * moll = (qr*dx + qi*dy + i(qi*dx - qr*dy)) / r2 * moll.

Block sizing: a (BB, s) target tile with its (BB, 9s) source tile and the
(BB, s, 9s) pair temporaries must fit VMEM; ``block_boxes`` is chosen so the
pair tensor stays under ~2 MiB (f32), and the lane dimension (9s) should be
a multiple of 128 on real hardware (pad ``s`` accordingly; correctness does
not depend on it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _p2p_kernel(tx_ref, ty_ref, sx_ref, sy_ref, sqr_ref, sqi_ref, sm_ref,
                wr_ref, wi_ref, *, sigma: float | None):
    tx = tx_ref[...]            # (BB, s)
    ty = ty_ref[...]
    sx = sx_ref[...]            # (BB, 9s)
    sy = sy_ref[...]
    sqr = sqr_ref[...]
    sqi = sqi_ref[...]
    sm = sm_ref[...]

    dx = tx[:, :, None] - sx[:, None, :]          # (BB, s, 9s)
    dy = ty[:, :, None] - sy[:, None, :]
    r2 = dx * dx + dy * dy
    valid = (sm[:, None, :] > 0) & (r2 > 0.0)
    inv_r2 = jnp.where(valid, 1.0, 0.0) / jnp.where(r2 > 0.0, r2, 1.0)
    if sigma is not None:
        inv_r2 = inv_r2 * (1.0 - jnp.exp(-r2 / (2.0 * sigma * sigma)))
    qr = sqr[:, None, :]
    qi = sqi[:, None, :]
    wr_ref[...] = ((qr * dx + qi * dy) * inv_r2).sum(axis=-1)
    wi_ref[...] = ((qi * dx - qr * dy) * inv_r2).sum(axis=-1)


@functools.partial(jax.jit, static_argnames=("sigma", "block_boxes", "interpret"))
def p2p_pallas(z, q, mask, sigma=None, block_boxes: int = 64,
               interpret: bool = True):
    """P2P over a (ny, nx, s) dense leaf grid.  Returns complex W per slot.

    z, q: complex64; mask: bool.  ``interpret=True`` runs the kernel body in
    the Pallas interpreter (CPU validation); on TPU pass False.
    """
    ny, nx, s = z.shape
    nb = ny * nx

    # Gather 3x3 neighborhoods -> (nb, 9s).  (Static slices; on TPU this is
    # a cheap pad+reshape, and under shard_map the halo rows have already
    # been exchanged by the caller.)
    zp = jnp.pad(z, ((1, 1), (1, 1), (0, 0)))
    qp = jnp.pad(q, ((1, 1), (1, 1), (0, 0)))
    mp = jnp.pad(mask, ((1, 1), (1, 1), (0, 0)))
    srcs = []
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            srcs.append((zp[1 + dy:1 + dy + ny, 1 + dx:1 + dx + nx],
                         qp[1 + dy:1 + dy + ny, 1 + dx:1 + dx + nx],
                         mp[1 + dy:1 + dy + ny, 1 + dx:1 + dx + nx]))
    sz = jnp.concatenate([a for a, _, _ in srcs], axis=-1).reshape(nb, 9 * s)
    sq = jnp.concatenate([b for _, b, _ in srcs], axis=-1).reshape(nb, 9 * s)
    sm = jnp.concatenate([c for _, _, c in srcs], axis=-1).reshape(nb, 9 * s)

    # pad box count to a multiple of the block
    nb_pad = -(-nb // block_boxes) * block_boxes
    pad = nb_pad - nb

    def padb(x):
        return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))

    tx = padb(z.reshape(nb, s).real.astype(jnp.float32))
    ty = padb(z.reshape(nb, s).imag.astype(jnp.float32))
    sxr = padb(sz.real.astype(jnp.float32))
    syr = padb(sz.imag.astype(jnp.float32))
    sqr = padb(sq.real.astype(jnp.float32))
    sqi = padb(sq.imag.astype(jnp.float32))
    smf = padb(sm.astype(jnp.float32))

    grid = (nb_pad // block_boxes,)
    tspec = pl.BlockSpec((block_boxes, s), lambda i: (i, 0))
    sspec = pl.BlockSpec((block_boxes, 9 * s), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((nb_pad, s), jnp.float32)] * 2

    wr, wi = pl.pallas_call(
        functools.partial(_p2p_kernel, sigma=sigma),
        grid=grid,
        in_specs=[tspec, tspec, sspec, sspec, sspec, sspec, sspec],
        out_specs=[tspec, tspec],
        out_shape=out_shape,
        interpret=interpret,
    )(tx, ty, sxr, syr, sqr, sqi, smf)

    w = (wr[:nb] + 1j * wi[:nb]).reshape(ny, nx, s).astype(z.dtype)
    return w
