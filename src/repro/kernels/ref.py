"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax.numpy as jnp

from ..core import expansions as ex
from ..core.quadtree import P2P_OFFSETS
from ..core.vortex import pairwise_w


def p2p_ref(z, q, mask, sigma=None):
    """Near-field direct sum over the 3x3 stencil; complex W per slot."""
    ny, nx, s = z.shape
    zp = jnp.pad(z, ((1, 1), (1, 1), (0, 0)))
    qp = jnp.pad(q, ((1, 1), (1, 1), (0, 0)))
    mp = jnp.pad(mask, ((1, 1), (1, 1), (0, 0)))
    w = jnp.zeros_like(z)
    for (dx, dy) in P2P_OFFSETS:
        w = w + pairwise_w(z,
                           zp[1 + dy:1 + dy + ny, 1 + dx:1 + dx + nx],
                           qp[1 + dy:1 + dy + ny, 1 + dx:1 + dx + nx],
                           mp[1 + dy:1 + dy + ny, 1 + dx:1 + dx + nx],
                           sigma)
    return w


def m2l_ref(me, level: int, p: int):
    """Dense 40-offset masked M2L — the independent (pre-folding) oracle."""
    return ex.m2l_masked40(me, level, p)


def attention_ref(q, k, v, causal: bool = True):
    """Exact softmax attention with GQA head grouping.  f32 math."""
    B, H, T, d = q.shape
    _, Hkv, S, _ = k.shape
    group = H // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((T, S), dtype=bool), k=S - T)
        s = jnp.where(mask, s, -1e30)
    a = jnp.exp(s - s.max(axis=-1, keepdims=True))
    a = a / a.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhts,bhsd->bhtd", a, v.astype(jnp.float32)).astype(q.dtype)
