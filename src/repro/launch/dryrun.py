import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init); everything else follows.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces:
  * proof the sharding config is coherent (compile succeeds),
  * compiled.memory_analysis()  -> fits-in-HBM evidence,
  * compiled.cost_analysis()    -> FLOPs / bytes for the roofline,
  * parsed collective volumes   -> the roofline's third term.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out experiments/dryrun
"""
import argparse
import functools
import json
import time
import traceback

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.registry import get_config, lm_archs
from ..models.config import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from ..models.transformer import init_cache, init_params
from ..optim.adamw import AdamWConfig
from ..parallel import sharding as shd
from ..serve.engine import decode_step, prefill_step
from ..train.loop import make_train_step
from .hlo_analysis import analyze_hlo
from .mesh import make_flat_mesh, make_production_mesh


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, t = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train" or shape.kind == "prefill":
        t_text = t - (cfg.num_patches or 0)
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, t_text), i32),
            "labels": jax.ShapeDtypeStruct((b, t_text), i32),
        }
        if cfg.num_patches:
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_patches, cfg.patch_dim), jnp.float32)
        if shape.kind == "prefill":
            specs.pop("labels")
        return specs
    # decode: one new token against a seq_len-deep cache
    return {
        "token": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def abstract_opt_state(params, state_dtype=jnp.float32):
    zeros = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, state_dtype), params)
    return {"mu": zeros, "nu": zeros, "step": jax.ShapeDtypeStruct((), jnp.int32)}


def train_memory_plan(cfg: ModelConfig) -> dict:
    """Per-arch HBM knobs for the train cells (documented in EXPERIMENTS.md).

    Microbatching bounds live activations (scan over microbatches); bf16
    optimizer states halve Adam HBM for the 100B+ archs.
    """
    n = cfg.param_count
    if n > 100e9:
        return {"num_microbatches": 16, "state_dtype": jnp.bfloat16}
    if n > 25e9:
        return {"num_microbatches": 8, "state_dtype": jnp.float32}
    if n > 8e9:
        return {"num_microbatches": 4, "state_dtype": jnp.float32}
    return {"num_microbatches": 1, "state_dtype": jnp.float32}


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------


def _dp(mesh):
    return shd.batch_axes(mesh)


def batch_shardings(mesh: Mesh, specs: dict):
    out = {}
    for k, v in specs.items():
        if k == "pos":
            out[k] = NamedSharding(mesh, P())
            continue
        b = v.shape[0]
        ax = _dp(mesh) if b % shd.axis_size(mesh, _dp(mesh)) == 0 else None
        out[k] = NamedSharding(mesh, P(ax, *([None] * (v.ndim - 1))))
    return out


def cache_shardings(mesh: Mesh, cfg: ModelConfig, caches):
    """Walk the cache pytree; shard by leaf role (KV / SSM / conv / ring pos)."""
    dp = _dp(mesh)
    tp = "model"
    tp_n = shd.axis_size(mesh, tp)

    def spec_for(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        shape = leaf.shape
        base = None
        if name.endswith("/k") or name.endswith("/v"):
            # (B, Hkv, S, d) possibly with leading stack dim
            nd = len(shape)
            b, hkv = shape[nd - 4], shape[nd - 3]
            b_ax = dp if b % shd.axis_size(mesh, dp) == 0 else None
            if hkv % tp_n == 0:
                base = P(b_ax, tp, None, None)
            else:
                base = P(b_ax, None, tp, None)   # SP decode: shard sequence
        elif name.endswith("/pos"):
            base = P(None)
        elif name.endswith("/ssm"):
            nd = len(shape)
            b, h = shape[nd - 4], shape[nd - 3]
            b_ax = dp if b % shd.axis_size(mesh, dp) == 0 else None
            h_ax = tp if h % tp_n == 0 else None
            base = P(b_ax, h_ax, None, None)
        elif name.endswith("/h"):
            b, w = shape[-2], shape[-1]
            b_ax = dp if b % shd.axis_size(mesh, dp) == 0 else None
            base = P(b_ax, tp if w % tp_n == 0 else None)
        elif name.endswith("/conv"):
            b, ch = shape[-3], shape[-1]
            b_ax = dp if b % shd.axis_size(mesh, dp) == 0 else None
            base = P(b_ax, None, tp if ch % tp_n == 0 else None)
        else:
            base = P(*([None] * len(shape)))
        pad = len(shape) - len(base)
        if pad > 0:
            base = P(*([None] * pad), *base)
        return NamedSharding(mesh, base)

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(treedef, [spec_for(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# Cell runners
# ---------------------------------------------------------------------------


def _analyze(lowered, compiled, nchips: int, wall: dict) -> dict:
    try:
        mem = compiled.memory_analysis()
        mem_out = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_out = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        cost_out = {"flops": cost.get("flops"),
                    "bytes_accessed": cost.get("bytes accessed")}
    except Exception as e:  # pragma: no cover
        cost_out = {"error": str(e)}
    t0 = time.time()
    hlo = analyze_hlo(compiled.as_text())
    wall["parse_s"] = round(time.time() - t0, 2)
    coll = {"per_kind": hlo["per_kind"], "total_bytes": hlo["collective_bytes"],
            "count": hlo["count"]}
    return {"memory_analysis": mem_out, "cost_analysis": cost_out,
            "hlo_analysis": {"flops": hlo["flops"], "bytes": hlo["bytes"],
                             "bytes_by_op": hlo.get("bytes_by_op", {})},
            "collectives": coll, "num_chips": nchips, "wall": wall}


def run_lm_cell(arch: str, shape_name: str, multi_pod: bool,
                donate: bool = True, overrides: dict | None = None) -> dict:
    import dataclasses
    cfg = get_config(arch)
    q_chunk = 512
    if overrides:
        q_chunk = overrides.pop("q_chunk", 512)
        mamba_chunk = overrides.pop("mamba_chunk", None)
        if mamba_chunk and cfg.mamba is not None:
            cfg = dataclasses.replace(
                cfg, mamba=dataclasses.replace(cfg.mamba, chunk=mamba_chunk))
        micro = overrides.pop("num_microbatches", None)
        cfg = dataclasses.replace(cfg, **overrides)
        if micro is not None:
            overrides["num_microbatches"] = micro
    else:
        micro = None
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    params = abstract_params(cfg)
    pshard = shd.param_shardings(mesh, params)
    specs = input_specs(cfg, shape)
    bshard = batch_shardings(mesh, specs)
    wall = {}
    t0 = time.time()

    if shape.kind == "train":
        plan = train_memory_plan(cfg)
        if micro is not None:
            plan["num_microbatches"] = micro
        # each microbatch must still split over the data-parallel axes
        dp_size = shd.axis_size(mesh, _dp(mesh))
        plan["num_microbatches"] = min(plan["num_microbatches"],
                                       max(shape.global_batch // dp_size, 1))
        opt = abstract_opt_state(params, plan["state_dtype"])
        oshard = {"mu": pshard, "nu": pshard, "step": NamedSharding(mesh, P())}
        step = make_train_step(
            cfg, mesh,
            AdamWConfig(total_steps=1000,
                        state_dtype=str(jnp.dtype(plan["state_dtype"]))),
            num_microbatches=plan["num_microbatches"], q_chunk=q_chunk)
        fn = jax.jit(step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
                     donate_argnums=(0, 1) if donate else ())
        lowered = fn.lower(params, opt, specs)
    elif shape.kind == "prefill":
        caches = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cshard = cache_shardings(mesh, cfg, caches)
        fn = jax.jit(functools.partial(prefill_step, cfg=cfg, mesh=mesh,
                                       q_chunk=q_chunk),
                     in_shardings=(pshard, bshard["tokens"], cshard),
                     out_shardings=(NamedSharding(mesh, P()), cshard),
                     donate_argnums=(2,) if donate else ())
        lowered = fn.lower(params, specs["tokens"], caches)
    else:  # decode
        caches = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cshard = cache_shardings(mesh, cfg, caches)
        fn = jax.jit(functools.partial(decode_step, cfg=cfg, mesh=mesh),
                     in_shardings=(pshard, bshard["token"], bshard["pos"], cshard),
                     out_shardings=(NamedSharding(mesh, P()), cshard),
                     donate_argnums=(3,) if donate else ())
        lowered = fn.lower(params, specs["token"], specs["pos"], caches)

    wall["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    wall["compile_s"] = round(time.time() - t0, 2)
    out = _analyze(lowered, compiled, mesh.size, wall)
    out.update({"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16"})
    return out


def run_fmm_cell(multi_pod: bool, level: int = 10, slots: int = 2,
                 p: int = 17) -> dict:
    """The paper's own app: distributed FMM velocity evaluation dry-run."""
    from ..core.parallel_fmm import parallel_fmm_velocity
    from ..core.quadtree import Tree

    mesh = make_flat_mesh(make_production_mesh(multi_pod=multi_pod), "data")
    n = 1 << level
    tree = Tree(z=jax.ShapeDtypeStruct((n, n, slots), jnp.complex64),
                q=jax.ShapeDtypeStruct((n, n, slots), jnp.complex64),
                mask=jax.ShapeDtypeStruct((n, n, slots), jnp.bool_),
                level=level, sigma=0.02)
    wall = {}
    t0 = time.time()
    fn = functools.partial(parallel_fmm_velocity, p=p, mesh=mesh)
    lowered = jax.jit(fn, static_argnames=()).lower(tree)
    wall["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    wall["compile_s"] = round(time.time() - t0, 2)
    out = _analyze(lowered, compiled, mesh.size, wall)
    out.update({"arch": "petfmm-vortex", "shape": f"level{level}_p{p}",
                "mesh": "512flat" if multi_pod else "256flat"})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fmm", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--tag", type=str, default=None,
                    help="suffix for output filenames (perf iterations)")
    # §Perf hillclimb knobs
    ap.add_argument("--score-dtype", type=str, default=None,
                    choices=[None, "float32", "bfloat16"])
    ap.add_argument("--remat-policy", type=str, default=None,
                    choices=[None, "full", "save_block_out"])
    ap.add_argument("--mamba-chunk", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--attn-impl", type=str, default=None,
                    choices=[None, "chunked", "skip_core"])
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--moe-gather-bits", type=int, default=None, choices=[None, 8, 16])
    args = ap.parse_args()

    overrides = {}
    if args.score_dtype:
        overrides["score_dtype"] = args.score_dtype
    if args.remat_policy:
        overrides["remat_policy"] = args.remat_policy
    if args.mamba_chunk:
        overrides["mamba_chunk"] = args.mamba_chunk
    if args.microbatches:
        overrides["num_microbatches"] = args.microbatches
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl
    if args.q_chunk:
        overrides["q_chunk"] = args.q_chunk
    if args.moe_gather_bits:
        overrides["moe_gather_bits"] = args.moe_gather_bits

    cells = []
    if args.fmm:
        cells.append(("petfmm-vortex", "fmm"))
    elif args.all:
        cells = [(a, s) for a in lm_archs() for s in SHAPES]
        cells.append(("petfmm-vortex", "fmm"))
    else:
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        label = f"{arch} x {shape} ({'2x16x16' if args.multi_pod else '16x16'})"
        try:
            if shape == "fmm":
                res = run_fmm_cell(args.multi_pod)
            else:
                res = run_lm_cell(arch, shape, args.multi_pod,
                                  overrides=dict(overrides) if overrides else None)
            status = "SKIP: " + res["skipped"] if "skipped" in res else "OK"
        except Exception as e:
            res = {"arch": arch, "shape": shape, "error": str(e),
                   "traceback": traceback.format_exc()}
            status = f"FAIL: {e}"
        results.append(res)
        print(f"[dryrun] {label}: {status}", flush=True)
        if "memory_analysis" in res:
            print(f"  memory: {res['memory_analysis']}", flush=True)
            print(f"  cost: {res['cost_analysis']}  hlo: {res['hlo_analysis']}",
                  flush=True)
            print(f"  collectives: total={res['collectives']['total_bytes']:.3e} B "
                  f"({res['collectives']['per_kind']})", flush=True)
            print(f"  wall: {res['wall']}", flush=True)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = "mp" if args.multi_pod else "sp"
            if args.tag:
                tag += "__" + args.tag
            fname = f"{res['arch']}__{res['shape']}__{tag}.json".replace("/", "_")
            with open(os.path.join(args.out, fname), "w") as f:
                json.dump(res, f, indent=1)
    nfail = sum("error" in r for r in results)
    print(f"[dryrun] done: {len(results)} cells, {nfail} failures", flush=True)
    return 0 if nfail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
