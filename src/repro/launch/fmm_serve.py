"""FMM serving launcher: price, admit, and serve a synthetic workload.

The CLI face of ``serve/fmm_service.py`` (DESIGN.md §15): builds an
:class:`~repro.serve.fmm_service.FmmServiceEngine` on N (forced host)
devices, submits a mixed one-shot + trajectory workload, and prints the
per-job prices, admission decisions, latency percentiles, cache
hit/miss counters, and the steady-state jit-entry count.

Run:  PYTHONPATH=src python -m repro.launch.fmm_serve [--devices 2]
          [--jobs 8] [--n 300] [--steps 2] [--max-job-flops 5e9]
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser(description="FMM-as-a-service smoke/driver")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--jobs", type=int, default=8,
                    help="one-shot jobs per equation wave")
    ap.add_argument("--n", type=int, default=300,
                    help="sources per one-shot job")
    ap.add_argument("--steps", type=int, default=2,
                    help="RK2 steps of the trajectory session (0 disables)")
    ap.add_argument("--p", type=int, default=8)
    ap.add_argument("--sigma", type=float, default=0.02)
    ap.add_argument("--max-job-flops", type=float, default=5e9)
    ap.add_argument("--max-queue-flops", type=float, default=2e10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.devices > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}")

    import numpy as np
    import jax
    from jax.sharding import Mesh

    from ..serve import fmm_service as svc

    ndev = min(args.devices, len(jax.devices()))
    mesh = Mesh(np.array(jax.devices()[:ndev]), ("data",)) if ndev > 1 \
        else None
    engine = svc.FmmServiceEngine(
        mesh=mesh,
        budget=svc.ServiceBudget(max_job_flops=args.max_job_flops,
                                 max_queue_flops=args.max_queue_flops))
    rng = np.random.default_rng(args.seed)
    print(f"== fmm_serve: {ndev} device(s), budget "
          f"max_job={args.max_job_flops:.2g} "
          f"max_queue={args.max_queue_flops:.2g} flops")

    jids = []
    for i in range(args.jobs):
        n = args.n + 4 * (i % 3)
        pos = rng.uniform(0.1, 0.9, size=(n, 2))
        q = rng.normal(size=n)
        job = svc.FmmJob(positions=pos, strength=q,
                         equation="vortex" if i % 2 == 0 else "laplace",
                         p=args.p, sigma=args.sigma, tenant=f"t{i % 3}")
        try:
            jids.append(engine.submit(job))
        except svc.JobRejected as e:
            print(f"   job {i}: REJECTED at "
                  f"{e.price.total_flops:.3g} flops")
    if args.steps:
        pos = rng.uniform(0.3, 0.7, size=(args.n, 2))
        sid = engine.submit(svc.FmmJob(
            positions=pos, strength=0.1 * rng.normal(size=args.n),
            steps=args.steps, p=args.p, dt=1e-3, sigma=args.sigma,
            tenant="session"))
        for i, _pos, rec in engine.session(sid).stream(args.steps):
            print(f"   session step {i}: {rec.seconds * 1e3:.1f} ms")
    engine.drain()

    for jid in jids:
        r = engine.result(jid)
        print(f"   job {jid}: lane={r.lane} cap={r.batch_capacity} "
              f"price={r.price.total_flops:.3g} flops "
              f"(level={r.price.level}, p={r.price.p}, "
              f"slots={r.price.slots}) latency={r.latency_s * 1e3:.1f} ms")
    stats = engine.stats()
    print(f"   admitted={stats['admitted']} deferred={stats['deferred']} "
          f"promoted={stats['promoted']} rejected={stats['rejected']} "
          f"batches={stats['batches']}")
    print(f"   cache={stats['cache']} "
          f"batch_utilization={stats['batch_utilization']:.2f} "
          f"jit_entries={stats['jit_entries']}")
    for lane, l in stats["latency"].items():
        print(f"   latency[{lane}]: p50={l['p50_ms']:.1f} ms "
              f"p99={l['p99_ms']:.1f} ms (n={l['n']})")
    print("== fmm_serve: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
