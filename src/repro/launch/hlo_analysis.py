"""Post-SPMD HLO analysis: per-device FLOPs, bytes, and collective volumes.

Why not ``compiled.cost_analysis()`` alone?  XLA's flat HLO cost analysis
counts while-loop bodies ONCE — a 94-layer ``lax.scan`` under-reports by
~94x.  We therefore walk the optimized module ourselves:

  * build a symbol table (value -> shape/bytes) per computation,
  * count dot/convolution FLOPs exactly (2 * out_elems * contraction size),
  * approximate HBM bytes *fusion-aware*: only materialization points count
    (dot/conv operands+results, fusion/reduce/copy/transpose results,
    slice/gather/scatter/concat results, collective results).  Pure
    elementwise ops, broadcasts, reshapes and converts are assumed fused
    into their producers, as the TPU backend would do — the CPU module we
    parse fuses less than TPU, so counting every result would overstate
    HBM traffic ~50x,
  * sum collective result sizes by kind,
  * multiply everything through ``while`` trip counts, read from
    backend_config known_trip_count (fallback: condition constants), and
    recurse through call/fusion boundaries.

Validated against cost_analysis() on unrolled graphs (tests/test_dryrun.py).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|"
    r"c64|c128)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?"n"\s*:\s*"?(\d+)')
_WHILE_RE = re.compile(r"\bwhile\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

# Ops whose results hit HBM even under aggressive TPU fusion.
_MATERIALIZING = frozenset({
    "fusion", "reduce", "reduce-window", "copy", "transpose",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "select-and-scatter", "sort", "concatenate", "pad", "slice", "reverse",
    "cumsum", "custom-call",
})


def _shapes(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class ModuleStats:
    __slots__ = ("flops", "bytes", "coll", "coll_count", "coll_counts",
                 "by_op", "op_count")

    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = defaultdict(float)
        self.coll_count = 0.0
        self.coll_counts = defaultdict(float)  # instance count per kind
        self.by_op = defaultdict(float)   # bytes per op kind (diagnostics)
        self.op_count = defaultdict(float)  # instance count per op kind

    def add(self, other, mult: float = 1.0):
        # EVERY additive stat is scaled by the while trip count, counts
        # included: a collective inside a known-trip-count loop body
        # executes ``mult`` times per module execution (regression-pinned
        # in tests/test_analysis.py — the pre-fix code under-counted
        # ``coll_count`` and the per-op diagnostics by the trip count).
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] += v * mult
        self.coll_count += other.coll_count * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] += v * mult
        for k, v in other.by_op.items():
            self.by_op[k] += v * mult
        for k, v in other.op_count.items():
            self.op_count[k] += v * mult


def analyze_hlo(hlo_text: str) -> dict:
    """Returns {'flops', 'bytes', 'collective_bytes', 'per_kind', ...} for
    one device's execution of the module (shapes are post-SPMD local)."""
    # ---- pass 1: split into computations, build symbol tables -------------
    comps: dict[str, list[str]] = {}
    entry = None
    current = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            current = m.group(2)
            comps[current] = []
            if m.group(1):
                entry = current
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line)

    symtabs: dict[str, dict[str, list]] = {}
    for cname, lines in comps.items():
        tab = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                rhs = dm.group(2)
                # result type is the prefix before the op name
                tab[dm.group(1)] = _shapes(rhs.split("(")[0])
        symtabs[cname] = tab

    # ---- pass 2: per-computation local stats + control-flow edges ---------
    local: dict[str, ModuleStats] = {}
    whiles: dict[str, list[tuple[str, str, int]]] = defaultdict(list)
    calls: dict[str, list[str]] = defaultdict(list)

    for cname, lines in comps.items():
        st = ModuleStats()
        tab = symtabs[cname]
        cond_consts: dict[str, int] = {}
        for line in lines:
            s = line.strip()
            dm = _DEF_RE.match(s)
            if not dm:
                continue
            rhs = dm.group(2)
            head, _, tail = rhs.partition("(")
            opname = head.split()[-1] if head.split() else ""
            res_shapes = _shapes(head)
            res_bytes = _bytes_of(res_shapes)

            wm = _WHILE_RE.search(s)
            if wm:
                tm = _TRIP_RE.search(s)
                trip = int(tm.group(1)) if tm else 0
                whiles[cname].append((wm.group(1), wm.group(2), trip))
                continue

            is_coll = False
            for kind in _COLLECTIVES:
                if opname == kind or opname == kind + "-start":
                    gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", s)
                    gsize = int(gm.group(2)) if gm else None
                    if gsize is None:
                        gb = re.search(r"replica_groups=\{\{([0-9, ]+)\}", s)
                        gsize = len(gb.group(1).split(",")) if gb else 1
                    nbytes = res_bytes * (max(gsize, 1) if kind == "reduce-scatter" else 1)
                    st.coll[kind] += nbytes
                    st.coll_count += 1
                    st.coll_counts[kind] += 1
                    st.bytes += res_bytes
                    st.by_op["collective"] += res_bytes
                    st.op_count["collective"] += 1
                    is_coll = True
                    break
            if is_coll:
                continue

            if opname in ("dot", "convolution"):
                args = tail.split(")")[0]
                operands = _OPERANDS_RE.findall(args)
                k = 1
                cm = _CDIMS_RE.search(s)
                if cm and operands:
                    lhs_shapes = tab.get(operands[0], [])
                    if lhs_shapes:
                        dims = lhs_shapes[0][1]
                        for ci in (int(x) for x in cm.group(1).split(",") if x):
                            if ci < len(dims):
                                k *= dims[ci]
                out_elems = res_bytes // max(
                    _DTYPE_BYTES[res_shapes[0][0]], 1) if res_shapes else 0
                st.flops += 2.0 * out_elems * k
                st.bytes += res_bytes
                st.by_op["dot_out"] += res_bytes
                for opnd in operands[:2]:
                    st.bytes += _bytes_of(tab.get(opnd, []))
                    st.by_op["dot_in"] += _bytes_of(tab.get(opnd, []))
                continue

            # materialization points only (see module docstring)
            if opname in _MATERIALIZING:
                st.bytes += res_bytes
                st.by_op[opname] += res_bytes
                st.op_count[opname] += 1
            for callee in _CALL_RE.findall(s):
                calls[cname].append(callee)
            # also capture cond constants for trip fallback
            for c in _CONST_RE.findall(s):
                cond_consts[cname] = max(cond_consts.get(cname, 0), int(c))
        local[cname] = st
        local[cname + "/__maxconst__"] = ModuleStats()
        local[cname + "/__maxconst__"].flops = cond_consts.get(cname, 0)

    # ---- pass 3: tree walk from entry with trip multiplication ------------
    def total(cname: str, depth=0) -> ModuleStats:
        out = ModuleStats()
        if depth > 12 or cname not in local:
            return out
        out.add(local[cname])
        for callee in calls.get(cname, ()):
            if callee != cname:
                out.add(total(callee, depth + 1))
        for cond, body, trip in whiles.get(cname, ()):
            if trip <= 0:
                trip = int(local.get(cond + "/__maxconst__", ModuleStats()).flops) or 1
            out.add(total(body, depth + 1), mult=trip)
            out.add(total(cond, depth + 1), mult=trip)
        return out

    st = total(entry) if entry else ModuleStats()
    return {
        "flops": st.flops,
        "bytes": st.bytes,
        "collective_bytes": float(sum(st.coll.values())),
        "per_kind": dict(st.coll),
        "count": int(round(st.coll_count)),
        "count_per_kind": {k: int(round(v)) for k, v in st.coll_counts.items()},
        "bytes_by_op": dict(st.by_op),
        "count_by_op": {k: int(round(v)) for k, v in st.op_count.items()},
    }


def shape_dim_pattern(dim: int) -> "re.Pattern[str]":
    """Regex matching any HLO tensor shape with a ``dim``-sized dimension,
    e.g. ``shape_dim_pattern(680)`` hits ``f32[256,680]``.  Shared by the
    M2L staging checks (tests/test_m2l_staging.py, benchmarks/run.py) that
    pin the absence of ``(nb, 40p)`` gather buffers."""
    return re.compile(r"\[(?:\d+,)*%d(?:,\d+)*\]" % dim)


def parse_collectives(hlo_text: str) -> dict:
    """Back-compat wrapper: collective volumes only."""
    r = analyze_hlo(hlo_text)
    return {"per_kind": r["per_kind"], "total_bytes": r["collective_bytes"],
            "count": r["count"]}


_SSA_DEF_RE = re.compile(r'^\s*(%[\w#]+(?::\d+)?)\s*=\s*"?stablehlo\.(\w+)"?')
_FUNC_RE = re.compile(r"^\s*func\.func\b")


def collective_issue_depths(
        stablehlo_text: str,
        collectives: tuple = ("all_gather", "collective_permute"),
        compute: tuple = ("dot_general", "convolution")) -> dict:
    """Per-collective *issue depth* in a lowered StableHLO module.

    StableHLO text preserves trace order, so the number of compute ops
    that sit between a collective's SSA definition and the first use of
    its result measures how much independent work the program issues the
    collective ahead of — the quantity the substep pipeline (DESIGN.md
    §12) restructures.  A depth of 0 means the result is consumed by the
    next compute op; larger depths give XLA's latency-hiding scheduler a
    window to overlap the transfer.

    Returns ``{kind: [depth, ...]}`` with one entry per ``collectives``
    kind, each listing the depth of every instance in issue order.
    Depths count only ``compute`` ops (default: dot_general /
    convolution — the FLOP carriers); elementwise glue is free to
    reorder and would only add noise.

    Hardened corner cases (unit-pinned in tests/test_analysis.py):

      * tuple-result collectives (``%5:2 = "stablehlo.all_gather" ...``)
        pin uses of both ``%5`` and the indexed ``%5#k`` forms;
      * SSA ids are FUNCTION-scoped: the use scan stops at the enclosing
        function's end, so an unrelated ``%5`` in a later function body
        can never terminate the window early (and a dead result's depth
        counts only to its own function's end);
      * a use on the same line as another tracked collective's def (the
        ``%7 = collective_permute(%5)`` chain) terminates the window
        BEFORE that def's own window opens, keeping windows independent;
      * compute ops on the first-use line itself do not count toward the
        depth (the consumer is the window's end, not part of it).
    """
    lines = stablehlo_text.splitlines()
    depths: dict = {k: [] for k in collectives}
    for i, line in enumerate(lines):
        m = _SSA_DEF_RE.match(line)
        if not m:
            continue
        rid, op = m.group(1), m.group(2)
        if op not in collectives:
            continue
        # strip tuple-arity (%5:2) / tuple-index (%5#0) suffixes so the
        # base id pins uses of every result component
        rid = rid.split(":")[0].split("#")[0]
        # %5 or %5#k, not %50 (\b guards the id; #\d+ covers tuple uses)
        use_re = re.compile(re.escape(rid) + r"(?:#\d+)?\b")
        depth = 0
        for later in lines[i + 1:]:
            if _FUNC_RE.match(later):
                break               # SSA scope ends with the function
            # search only the rhs so another def of a same-prefix id
            # (there are none in SSA, but be safe) can't false-match
            rhs = later.split("=", 1)[-1]
            if use_re.search(rhs):
                break
            if any("stablehlo." + c in rhs for c in compute):
                depth += 1
        depths[op].append(depth)
    return depths
