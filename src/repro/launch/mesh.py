"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = one pod of 256 chips; (2, 16, 16) = 2 pods / 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_flat_mesh(mesh: Mesh, axis: str = "data") -> Mesh:
    """1-D view of the same devices (used by the FMM slab decomposition)."""
    return Mesh(mesh.devices.reshape(-1), (axis,))


def make_local_mesh(axes=("pod", "data", "model")) -> Mesh:
    """Degenerate all-ones mesh for smoke tests on one device."""
    dev = np.array(jax.devices()[:1]).reshape((1,) * len(axes))
    return Mesh(dev, axes)


def make_world_mesh(world: int, axis: str = "data") -> Mesh:
    """1-D mesh over the first ``world`` LOCAL devices.

    The shrunken-world constructor of the resilience layer (DESIGN.md
    §14): after a coordinated shrink, every surviving rank rebuilds the
    mesh at the agreed world size and ``from_checkpoint``-restores onto it
    — the elastic restore path is device-count independent, so only the
    mesh changes shape.  Uses ``jax.local_devices()`` (the process's own
    devices) rather than the global list: each rank of the supervisor's
    process gang addresses only what it owns."""
    devs = jax.local_devices()
    if world > len(devs):
        raise ValueError(f"world {world} exceeds the {len(devs)} local "
                         f"devices (raise --xla_force_host_platform_"
                         f"device_count or shrink the world)")
    return Mesh(np.array(devs[:world]), (axis,))
