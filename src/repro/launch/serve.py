"""Production serving launcher: batched prefill/decode over the mesh.

Real cluster:  python -m repro.launch.serve --arch <id> --shape decode_32k
Local smoke:   python -m repro.launch.serve --arch yi-6b --local
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new", type=int, default=32)
    args = ap.parse_args()

    import numpy as np
    import jax
    from ..configs.registry import get_config, get_smoke_config
    from ..models.transformer import init_params
    from ..serve.engine import ServeEngine

    cfg = get_smoke_config(args.arch) if args.local else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, batch_slots=args.batch,
                         max_len=args.prompt_len + args.new + 8)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    out = engine.step_all(prompts, args.new)
    print(f"[serve] generated {out.shape} tokens; first: {out[0][:8].tolist()}")


if __name__ == "__main__":
    main()
