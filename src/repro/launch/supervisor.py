"""Kill-drill supervisor: multi-process fault tolerance (DESIGN.md §14).

``Supervisor`` runs the jitted ``VortexStepper`` across real OS processes
— one subprocess per rank — and survives a killed or hung rank:

  * every rank advances in LOCK-STEP through the epoch barrier of
    ``parallel/resilience.py`` (the per-step cross-process collective) and
    publishes a heartbeat whose deadline is derived from the Eq 13-15 cost
    model's predicted step time (robust_wall-filtered), so a hang is
    detected in bounded time instead of blocking forever;
  * on detection (a rank's process exits, or its heartbeat goes stale past
    its own published deadline) the survivors agree on the new world size
    via the epoch-numbered view protocol, the supervisor tears down the
    dead mesh (SIGKILL on stragglers — a SIGSTOPped rank included), and
    respawns the survivors at generation g+1, each restoring
    ``VortexStepper.from_checkpoint`` onto the shrunken mesh (the elastic
    restore path is device-count independent, so the post-shrink
    trajectory is bit-identical to a clean run at the smaller world);
  * the :class:`~repro.parallel.resilience.RestartPolicy` bounds the loop:
    max restarts, exponential backoff, quarantine-then-rejoin for flapping
    ranks, and a degraded-mode floor below which a typed
    :class:`~repro.parallel.resilience.MeshFaultError` carries the
    structured fault history out.

Process model (honest scope): each rank process forces
``--xla_force_host_platform_device_count=<world>`` and redundantly
executes the world-sized SPMD program on its own host devices — exactly
the program every controller of a real multi-controller deployment would
run — while the cross-process coupling (the part a process fault actually
breaks) is the per-step epoch barrier + heartbeat protocol.  Workers can
additionally bring up the REAL jax distributed runtime
(``distributed=True`` -> ``jax.distributed.initialize`` multi-controller
on host CPU; the init barrier and coordinator service are then genuinely
cross-process), but the device program stays rank-local; wiring the
collectives themselves over ICI/NCCL is the recorded ROADMAP remainder.
Drill faults are declared in the same ``FaultSpec`` vocabulary as PR 6's
data faults: ``proc_kill`` / ``proc_hang`` sites tell the supervisor to
SIGKILL / SIGSTOP rank k mid-step n.

CLI:
  python -m repro.launch.supervisor --world 4 --target-step 6 \
      --coord-dir /tmp/drill --kill 2:4      # SIGKILL rank 2 mid-step 4
(``--worker CFG.json`` is the internal rank entry point.)
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import subprocess
import sys
import time
from typing import Optional, Sequence

from ..parallel import resilience as rz

_SRC_DIR = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SupervisorConfig:
    world: int
    target_step: int
    coord_dir: str
    checkpoint_dir: Optional[str] = None    # default: <coord_dir>/ckpt
    # scenario (gen-0 build; later generations restore from checkpoint)
    n_side: int = 20
    p: int = 4
    dt: float = 0.004
    target_per_box: float = 8.0
    plan_method: str = "model"              # deterministic across ranks:
    use_kernels: bool = False               # measured-feedback replanning
    checkpoint_every: int = 2               # would diverge rank states
    checkpoint_keep: int = 8
    distributed: bool = False               # jax.distributed.initialize gang
    watchdog: rz.WatchdogPolicy = dataclasses.field(
        default_factory=rz.WatchdogPolicy)
    restart: rz.RestartPolicy = dataclasses.field(
        default_factory=rz.RestartPolicy)
    max_wall: float = 1800.0                # hard supervisor wall clock
    poll_interval: float = 0.1

    def __post_init__(self):
        if self.checkpoint_dir is None:
            self.checkpoint_dir = os.path.join(self.coord_dir, "ckpt")


@dataclasses.dataclass
class SupervisorResult:
    success: bool
    final_step: int
    generations: list                       # per-generation summary dicts
    faults: list                            # ProcFaultReport per shrink
    world_history: list                     # [(generation, ranks), ...]
    result_dir: str                         # gen dir with result_<rank>.npz
    ranks: tuple                            # final generation's ranks

    def describe(self) -> dict:
        d = dataclasses.asdict(self)
        d["faults"] = [f.describe() for f in self.faults]
        return d


# ---------------------------------------------------------------------------
# the supervisor
# ---------------------------------------------------------------------------


class Supervisor:
    """Spawns rank workers, watches heartbeats/exits, executes proc-fault
    drills, and coordinates shrink + generation-stamped restart."""

    def __init__(self, config: SupervisorConfig, faults=None):
        self.cfg = config
        self.faults = faults                # FaultInjector with proc sites
        self.fault_history: dict = {}       # rank -> [generation, ...]
        self.reports: list = []
        self.generations: list = []
        self.world_history: list = []

    # -- worker process management ------------------------------------------

    def _worker_env(self, world: int) -> dict:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={world}")
        env["JAX_PLATFORMS"] = "cpu"
        # shared compilation cache: every rank lowers the identical program,
        # so one rank compiles and the rest (and later generations /
        # comparison runs) hit the cache — essential at 1-core CI.  An
        # inherited cache dir wins, so a test session can share one cache
        # across drills.
        env.setdefault("JAX_COMPILATION_CACHE_DIR",
                       os.path.join(self.cfg.coord_dir, "jaxcache"))
        env["JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS"] = "0"
        pp = env.get("PYTHONPATH", "")
        if _SRC_DIR not in pp.split(os.pathsep):
            env["PYTHONPATH"] = _SRC_DIR + (os.pathsep + pp if pp else "")
        return env

    def _spawn_generation(self, generation: int, ranks: Sequence[int],
                          restore_step: Optional[int],
                          seconds_per_work: Optional[float]) -> dict:
        gdir = rz.gen_dir(self.cfg.coord_dir, generation)
        world = len(ranks)
        coordinator = None
        if self.cfg.distributed:
            import socket
            s = socket.socket()
            s.bind(("127.0.0.1", 0))
            coordinator = f"127.0.0.1:{s.getsockname()[1]}"
            s.close()
        procs = {}
        for rank in ranks:
            cfg = {
                "rank": int(rank), "ranks": [int(r) for r in ranks],
                "generation": int(generation),
                "coord_dir": self.cfg.coord_dir,
                "checkpoint_dir": self.cfg.checkpoint_dir,
                "restore_step": restore_step,
                "target_step": self.cfg.target_step,
                "n_side": self.cfg.n_side, "p": self.cfg.p,
                "dt": self.cfg.dt,
                "target_per_box": self.cfg.target_per_box,
                "plan_method": self.cfg.plan_method,
                "use_kernels": self.cfg.use_kernels,
                "checkpoint_every": self.cfg.checkpoint_every,
                "checkpoint_keep": self.cfg.checkpoint_keep,
                "seconds_per_work": seconds_per_work,
                "coordinator": coordinator,
                "num_processes": world,
                "process_index": list(ranks).index(rank),
                "watchdog": dataclasses.asdict(self.cfg.watchdog),
            }
            cfg_path = os.path.join(gdir, f"worker_{rank}.json")
            with open(cfg_path, "w") as f:
                json.dump(cfg, f)
            log = open(os.path.join(gdir, f"worker_{rank}.log"), "w")
            procs[rank] = (subprocess.Popen(
                [sys.executable, "-m", "repro.launch.supervisor",
                 "--worker", cfg_path],
                stdout=log, stderr=subprocess.STDOUT,
                env=self._worker_env(world)), log)
        return procs

    def _teardown(self, procs: dict) -> None:
        """SIGKILL every still-running rank (kills SIGSTOPped ones too)."""
        for rank, (p, log) in procs.items():
            if p.poll() is None:
                try:
                    os.kill(p.pid, signal.SIGKILL)
                except OSError:
                    pass
            try:
                p.wait(timeout=30)
            except subprocess.TimeoutExpired:
                pass
            log.close()

    # -- drill execution (proc_kill / proc_hang FaultSpec sites) ------------

    def _proc_specs(self) -> list:
        if self.faults is None:
            return []
        return list(self.faults.proc_faults())

    def _maybe_fire_drills(self, generation, ranks, procs, fired) -> list:
        """Execute due proc-fault specs; returns [(spec, t_injected)]."""
        events = []
        for spec in self._proc_specs():
            key = (spec.site, spec.rank, spec.step)
            if key in fired or spec.rank not in ranks:
                continue
            hb = rz.read_heartbeat(self.cfg.coord_dir, generation, spec.rank)
            if hb is None:
                continue
            due = (hb["step"] >= spec.step or
                   (hb["step"] >= spec.step - 1 and hb["phase"] == "step"))
            if not due:
                continue
            p, _ = procs[spec.rank]
            sig = (signal.SIGKILL if spec.site == "proc_kill"
                   else signal.SIGSTOP)
            try:
                os.kill(p.pid, sig)
                events.append((spec, time.time()))
            except OSError:
                pass
            fired.add(key)
        return events

    # -- the generation loop ------------------------------------------------

    def run(self) -> SupervisorResult:
        cfg = self.cfg
        os.makedirs(cfg.coord_dir, exist_ok=True)
        t_run0 = time.time()
        generation, restarts = 0, 0
        ranks = tuple(range(cfg.world))
        restore_step: Optional[int] = None
        seconds_per_work: Optional[float] = None
        fired: set = set()
        pending_report: Optional[rz.ProcFaultReport] = None

        while True:
            self.world_history.append((generation, list(ranks)))
            t_spawn = time.time()
            procs = self._spawn_generation(generation, ranks, restore_step,
                                           seconds_per_work)
            watchdog = rz.Watchdog(cfg.coord_dir, generation, ranks,
                                   cfg.watchdog)
            gen_rec = {"generation": generation, "ranks": list(ranks),
                       "restore_step": restore_step, "outcome": None}
            t_inject = t_detect = t_restored = t_first = None
            injected: list = []
            dead_exits: dict = {}
            shrink_exits: set = set()
            done_ranks: set = set()

            while True:
                time.sleep(cfg.poll_interval)
                now = time.time()
                if now - t_run0 > cfg.max_wall:
                    self._teardown(procs)
                    raise rz.MeshFaultError(
                        f"supervisor wall clock exceeded "
                        f"({cfg.max_wall:.0f}s)", self.reports)

                injected += self._maybe_fire_drills(generation, ranks, procs,
                                                    fired)
                if injected and t_inject is None:
                    t_inject = injected[0][1]

                hbs = {r: rz.read_heartbeat(cfg.coord_dir, generation, r)
                       for r in ranks}
                live = {r for r in ranks if r not in done_ranks}
                if t_restored is None and all(
                        hbs[r] and hbs[r]["phase"] != "boot" for r in ranks):
                    t_restored = now
                    # close the PREVIOUS fault's restore_seconds window
                    if pending_report is not None:
                        pending_report.restore_seconds = (
                            now - t_spawn + pending_report.restore_seconds)
                base_step = restore_step if restore_step is not None else 0
                if t_first is None and any(
                        hbs[r] and hbs[r]["step"] > base_step for r in ranks):
                    t_first = now
                    if pending_report is not None and t_restored is not None:
                        pending_report.first_step_seconds = now - t_restored
                        pending_report = None

                for r in list(live):
                    p, _ = procs[r]
                    rc = p.poll()
                    if rc is None:
                        continue
                    if rc == 0:
                        done_ranks.add(r)
                    elif rc == rz.EXIT_SHRINK:
                        shrink_exits.add(r)
                        done_ranks.add(r)       # exited deliberately
                    else:
                        dead_exits[r] = rc
                        done_ranks.add(r)

                if len(done_ranks) == len(ranks) and not dead_exits \
                        and not shrink_exits:
                    gen_rec["outcome"] = "completed"
                    self.generations.append(gen_rec)
                    self._teardown(procs)
                    return SupervisorResult(
                        success=True, final_step=cfg.target_step,
                        generations=self.generations, faults=self.reports,
                        world_history=self.world_history,
                        result_dir=rz.gen_dir(cfg.coord_dir, generation),
                        ranks=ranks)

                hung = {r: over for r, over in watchdog.overdue(now).items()
                        if r not in done_ranks and r not in dead_exits}
                announcement = rz.read_fault(cfg.coord_dir, generation)
                faulted = bool(dead_exits or hung or shrink_exits
                               or announcement)
                if not faulted:
                    continue
                if t_detect is None:
                    t_detect = now
                    # tell still-waiting ranks immediately (first writer
                    # wins; rank-side detections keep their own timestamp)
                    rz.announce_fault(cfg.coord_dir, generation,
                                      sorted(set(dead_exits) | set(hung)),
                                      epoch=None, by="supervisor")
                # give survivors a bounded grace to agree + exit on their
                # own; then tear the remnant mesh down
                remaining = [r for r in ranks if r not in done_ranks
                             and procs[r][0].poll() is None]
                if remaining and now - t_detect < cfg.watchdog.teardown_grace:
                    continue
                break

            # -- coordinated shrink -----------------------------------------
            self._teardown(procs)
            announcement = rz.read_fault(cfg.coord_dir, generation)
            decision = rz.read_decision(cfg.coord_dir, generation)
            dead = sorted(set(dead_exits) | set(hung) |
                          set((announcement or {}).get("dead", [])))
            if decision is not None:
                survivors = tuple(r for r in decision["survivors"]
                                  if r not in dead)
            else:
                survivors = tuple(r for r in ranks if r not in dead)
            for r in dead:
                self.fault_history.setdefault(r, []).append(generation)
            restarts += 1
            # carry the measured seconds-per-work calibration across the
            # restart so the next generation's watchdog deadline starts
            # from the cost model instead of the compile grace
            spus = [hbs[r]["spu"] for r in ranks
                    if hbs.get(r) and hbs[r].get("spu")]
            if spus:
                seconds_per_work = sorted(spus)[len(spus) // 2]

            try:
                from ..checkpoint.manager import CheckpointManager
                restore_step = CheckpointManager(
                    cfg.checkpoint_dir, keep=cfg.checkpoint_keep).latest_step()
            except OSError:
                restore_step = None

            report = rz.ProcFaultReport(
                generation=generation,
                epoch=(decision or announcement or {}).get("epoch"),
                dead=tuple(sorted(set(dead_exits) |
                                  set((announcement or {}).get("dead", []))
                                  - set(hung))),
                hung=tuple(sorted(hung)),
                world_before=len(ranks), world_after=len(survivors),
                restore_step=restore_step,
                detected_by=(announcement or {}).get("by", "supervisor"),
                detect_seconds=(t_detect - t_inject
                                if t_inject is not None and t_detect
                                else None),
                restore_seconds=0.0,    # grown by the next gen's milestones
                reason="shrink")
            self.reports.append(report)
            pending_report = report
            gen_rec["outcome"] = "fault"
            gen_rec["fault"] = str(report)
            self.generations.append(gen_rec)

            if restarts > cfg.restart.max_restarts:
                raise rz.MeshFaultError(
                    f"max restarts exceeded ({cfg.restart.max_restarts})",
                    self.reports)
            next_ranks = cfg.restart.next_ranks(survivors, generation,
                                                self.fault_history)
            if len(next_ranks) < cfg.restart.min_world:
                raise rz.MeshFaultError(
                    f"world shrank below the degraded-mode floor "
                    f"({len(next_ranks)} < {cfg.restart.min_world})",
                    self.reports)
            time.sleep(cfg.restart.backoff(restarts))
            # account teardown+backoff into the report's restore window
            report.restore_seconds = time.time() - t_detect
            generation += 1
            ranks = next_ranks


# ---------------------------------------------------------------------------
# the rank worker
# ---------------------------------------------------------------------------


def _init_distributed(cfg: dict) -> None:
    """Bring up the real jax multi-controller runtime (host CPU gang)."""
    import jax
    jax.distributed.initialize(coordinator_address=cfg["coordinator"],
                               num_processes=cfg["num_processes"],
                               process_id=cfg["process_index"])


def worker_main(cfg_path: str) -> int:
    with open(cfg_path) as f:
        cfg = json.load(f)
    rank, gen = cfg["rank"], cfg["generation"]
    ranks = tuple(cfg["ranks"])
    world = len(ranks)
    policy = rz.WatchdogPolicy(**cfg["watchdog"])
    coord = cfg["coord_dir"]
    hb = rz.Heartbeat(coord, gen, rank)
    hb.beat(step=cfg["restore_step"] or 0, phase="boot",
            deadline=policy.compile_grace)

    if cfg.get("distributed") or cfg.get("coordinator"):
        _init_distributed(cfg)
    import numpy as np
    import jax  # noqa: F401  (configured via env by the supervisor)
    from ..core.stepper import VortexStepper
    from ..core.vortex import lamb_oseen_particles
    from .mesh import make_world_mesh

    mesh = make_world_mesh(world)
    is_writer = rank == min(ranks)
    ck_dir, ck_every = cfg["checkpoint_dir"], cfg["checkpoint_every"]
    if cfg["restore_step"] is not None:
        st = VortexStepper.from_checkpoint(
            ck_dir, mesh=mesh, step=cfg["restore_step"],
            plan_method=cfg["plan_method"], use_kernels=cfg["use_kernels"],
            checkpoint_every=ck_every if is_writer else 0,
            checkpoint_keep=cfg["checkpoint_keep"])
    else:
        pos, gamma, sigma = lamb_oseen_particles(cfg["n_side"])
        st = VortexStepper(
            pos, gamma, sigma, p=cfg["p"], dt=cfg["dt"], mesh=mesh,
            plan_method=cfg["plan_method"], use_kernels=cfg["use_kernels"],
            target_per_box=cfg["target_per_box"],
            checkpoint_dir=ck_dir if is_writer else None,
            checkpoint_every=ck_every,
            checkpoint_keep=cfg["checkpoint_keep"])
        if is_writer:
            st.save_checkpoint()    # step 0: a shrink always has a restore
            st._ckpt.wait()         # point, even before the first cadence
    hb.beat(step=st.step_count, phase="restored",
            deadline=policy.compile_grace)

    barrier = rz.EpochBarrier(coord, gen, rank, ranks,
                              poll_interval=policy.poll_interval)
    watchdog = rz.Watchdog(coord, gen, ranks, policy)

    state = {"spu": cfg.get("seconds_per_work")}

    def detect_and_exit(dead, epoch):
        # Agreement can take a while (everyone converges on the survivor
        # view) — publish a deadline that covers it so the supervisor's
        # watchdog never mistakes an agreeing rank for a hung one.
        hb.beat(step=st.step_count, phase="agree",
                deadline=policy.agree_timeout + policy.slack,
                spu=state["spu"])
        ann = rz.announce_fault(coord, gen, dead, epoch, by=rank)
        dead = sorted(set(dead) | set(ann["dead"]))
        epoch = ann["epoch"] if ann.get("epoch") is not None else epoch
        if rank in dead:
            # The standing announcement names THIS rank (a watchdog race:
            # e.g. the supervisor flagged us while we blocked on a dead
            # peer).  Don't fight the vote — the survivors' decision
            # excludes us, so step aside and let the rebuild proceed.
            hb.beat(step=st.step_count, phase="evicted",
                    deadline=policy.compile_grace, spu=state["spu"])
            raise SystemExit(rz.EXIT_SHRINK)
        proposed = [r for r in ranks if r not in dead]
        agreed = rz.agree_view(coord, gen, rank, proposed, epoch,
                               timeout=policy.agree_timeout,
                               poll_interval=policy.poll_interval)
        assert rank in agreed
        if is_writer and st._ckpt is not None:
            st._ckpt.wait()         # never strand an in-flight snapshot
        hb.beat(step=st.step_count, phase="shrink",
                deadline=policy.compile_grace, spu=state["spu"])
        raise SystemExit(rz.EXIT_SHRINK)

    compiled = False
    modeled_work = st.modeled_step_work()
    while st.step_count < cfg["target_step"]:
        predicted = st.predicted_step_seconds()
        if predicted is None:
            predicted = rz.predicted_from_calibration(state["spu"],
                                                      modeled_work)
        deadline = rz.step_deadline(policy, predicted, compiled)
        hb.beat(step=st.step_count, phase="step", deadline=deadline,
                spu=state["spu"])
        epoch, rounds = st.step_count, 0
        # Beat on every barrier poll: a rank legitimately waiting out its
        # peer's deadline must keep proving liveness, or its own heartbeat
        # ages past the published deadline and the watchdog (supervisor's
        # or a peer's) flags the WAITER as hung alongside the real fault.
        refresh = lambda: hb.beat(step=st.step_count, phase="step",
                                  deadline=deadline, spu=state["spu"])
        while True:                     # the per-step collective
            try:
                barrier.wait(epoch, timeout=deadline, on_poll=refresh)
                break
            except rz.FaultAnnounced as e:
                detect_and_exit(e.dead, e.epoch if e.epoch is not None
                                else epoch)
            except rz.BarrierTimeout as e:
                stale = [r for r in watchdog.overdue()
                         if r != rank and r in e.missing]
                if stale:
                    detect_and_exit(stale, epoch)
                rounds += 1             # laggards still fresh: wait more,
                if rounds >= policy.max_barrier_rounds:     # but bounded
                    detect_and_exit(list(e.missing), epoch)
        rec = st.step()
        compiled = not (rec.replanned or rec.releveled)
        if not compiled:
            modeled_work = st.modeled_step_work()
        sample = st.predicted_step_seconds()
        if sample is not None and modeled_work > 0:
            state["spu"] = sample / modeled_work
    hb.beat(step=st.step_count, phase="done", deadline=policy.compile_grace,
            spu=state["spu"])
    out = os.path.join(rz.gen_dir(coord, gen), f"result_{rank}.npz")
    np.savez(out, z=np.asarray(st.tree.z), q=np.asarray(st.tree.q),
             mask=np.asarray(st.tree.mask), step=st.step_count)
    if is_writer and st._ckpt is not None:
        st._ckpt.wait()
    return 0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _parse_drills(kills, hangs):
    from ..core.faults import FaultInjector, FaultSpec
    specs = []
    for site, items in (("proc_kill", kills), ("proc_hang", hangs)):
        for item in items or ():
            r, s = item.split(":")
            specs.append(FaultSpec(site=site, step=int(s), device=int(r)))
    return FaultInjector(*specs) if specs else None


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.supervisor",
        description="multi-process kill-drill supervisor (DESIGN.md §14)")
    ap.add_argument("--worker", metavar="CFG", default=None,
                    help=argparse.SUPPRESS)   # internal rank entry point
    ap.add_argument("--world", type=int, default=4)
    ap.add_argument("--target-step", type=int, default=6)
    ap.add_argument("--coord-dir", default="/tmp/fmm-drill")
    ap.add_argument("--n-side", type=int, default=20)
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--dt", type=float, default=0.004)
    ap.add_argument("--checkpoint-every", type=int, default=2)
    ap.add_argument("--kill", action="append", metavar="RANK:STEP",
                    help="SIGKILL rank mid-step (repeatable)")
    ap.add_argument("--hang", action="append", metavar="RANK:STEP",
                    help="SIGSTOP rank mid-step (repeatable)")
    ap.add_argument("--min-world", type=int, default=1)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--max-wall", type=float, default=1800.0)
    ap.add_argument("--distributed", action="store_true",
                    help="bring up jax.distributed multi-controller")
    args = ap.parse_args(argv)

    if args.worker:
        return worker_main(args.worker)

    cfg = SupervisorConfig(
        world=args.world, target_step=args.target_step,
        coord_dir=args.coord_dir, n_side=args.n_side, p=args.p, dt=args.dt,
        checkpoint_every=args.checkpoint_every, distributed=args.distributed,
        restart=rz.RestartPolicy(max_restarts=args.max_restarts,
                                 min_world=args.min_world),
        max_wall=args.max_wall)
    sup = Supervisor(cfg, faults=_parse_drills(args.kill, args.hang))
    result = sup.run()
    print(json.dumps(result.describe(), indent=2, default=str))
    return 0 if result.success else 1


if __name__ == "__main__":
    sys.exit(main())
