"""Production training launcher.

On a real TPU cluster each host runs:
  python -m repro.launch.train --arch <id> --shape train_4k \
      [--multi-pod] [--steps N] [--ckpt-dir gs://...]

The launcher builds the production mesh, shards params/optimizer with the
repo's sharding rules, restores the latest checkpoint if present, and runs
the fault-tolerant loop (atomic async checkpoints, pipeline state included,
straggler-feedback expert rebalancing for MoE archs).

On this CPU container use --local to smoke the full path on a 1-device mesh
with the arch's reduced config.
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--local", action="store_true",
                    help="reduced config on the local 1-device mesh")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--rebalance-every", type=int, default=0)
    args = ap.parse_args()

    # mesh construction must precede heavy imports only in the dry-run case;
    # for real runs jax.distributed.initialize() is called by the host agent.
    import jax
    from ..configs.registry import get_config, get_smoke_config
    from ..models.config import SHAPES, ShapeConfig
    from ..optim.adamw import AdamWConfig
    from ..train.loop import Trainer, TrainerConfig
    from .mesh import make_local_mesh, make_production_mesh

    if args.local:
        cfg = get_smoke_config(args.arch)
        shape = ShapeConfig("local", "train", 128, 4)
        mesh = None
    else:
        cfg = get_config(args.arch)
        shape = SHAPES[args.shape]
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir,
                         rebalance_every=args.rebalance_every)
    tr = Trainer(cfg, shape, AdamWConfig(total_steps=args.steps), tcfg, mesh=mesh)
    if tr.try_restore():
        print(f"[train] resumed at step {int(tr.opt_state['step'])}")
    log = tr.run()
    print(f"[train] done: {len(log)} steps, final loss "
          f"{log[-1]['loss']:.4f}" if log else "[train] nothing to do")


if __name__ == "__main__":
    main()
