"""Model and shape configuration for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma-style hybrid: pattern of RG-LRU and local-attn blocks."""
    lru_width: int = 0            # defaults to d_model if 0
    window: int = 2048            # local attention window
    pattern: tuple[str, ...] = ("rglru", "rglru", "attn")  # Griffin 2:1


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256              # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    rglru: Optional[RGLRUConfig] = None
    mamba: Optional[MambaConfig] = None
    # vlm frontend stub: number of patch positions filled by precomputed
    # embeddings (input_specs provides them); 0 for non-vlm models.
    num_patches: int = 0
    patch_dim: int = 1024         # stub ViT output width
    dtype: str = "bfloat16"       # compute dtype
    # perf knobs (EXPERIMENTS.md §Perf): attention-score materialization
    # dtype ('float32' baseline, 'bfloat16' halves the dominant HBM term)
    # and scan-remat policy ('full' | 'save_block_out').
    score_dtype: str = "float32"
    remat_policy: str = "full"
    # 'chunked' = q-chunked exact attention (XLA path, scores hit HBM);
    # 'skip_core' = accounting probe that bypasses the score computation —
    # used ONLY to measure the flash-kernel (Pallas) HBM profile in the
    # dry-run, since Pallas-TPU cannot be lowered on this CPU container.
    attn_impl: str = "chunked"
    # FSDP expert-weight gather wire format: 16 = bf16 (exact), 8 = int8
    # absmax-quantized with a straight-through backward (halves the largest
    # collective of the MoE train cells; §Perf cell C).
    moe_gather_bits: int = 16

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def param_count(self) -> int:
        """Analytic parameter count (embeddings included)."""
        D, H, Hkv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim_
        per_layer = 0
        if self.family == "ssm":
            m = self.mamba
            d_in = m.expand * D
            nheads = d_in // m.head_dim
            per_layer = (D * (2 * d_in + 2 * m.d_state + nheads)  # in_proj (grouped)
                         + m.d_conv * (d_in + 2 * m.d_state)       # conv
                         + nheads + nheads                         # A_log, dt_bias
                         + d_in                                    # norm
                         + d_in * D)                               # out_proj
            per_layer += D  # pre-norm
        else:
            attn = D * H * hd + 2 * D * Hkv * hd + H * hd * D
            if self.qkv_bias:
                attn += (H + 2 * Hkv) * hd
            if self.moe is not None:
                ff = self.moe.num_experts * 3 * D * self.moe.expert_ff + D * self.moe.num_experts
            else:
                ff = 3 * D * self.d_ff
            per_layer = attn + ff + 2 * D  # + two RMSNorm scales
            if self.rglru is not None:
                # crude: recurrent blocks replace attention with LRU mixing
                pass
        total = self.num_layers * per_layer + self.vocab * D + D
        if not self.tie_embeddings:
            total += self.vocab * D
        if self.num_patches:
            total += self.patch_dim * D  # patch projection stub
        return int(total)

    @property
    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count
        D = self.d_model
        dense = self.param_count - self.num_layers * self.moe.num_experts * 3 * D * self.moe.expert_ff
        active_ff = self.num_layers * self.moe.top_k * 3 * D * self.moe.expert_ff
        return int(dense + active_ff)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                     # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a shape cell runs for this arch (DESIGN.md §5 skip rules)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("long-context decode requires sub-quadratic/bounded-state "
                       "attention; pure full-attention arch skips this cell")
    return True, ""
