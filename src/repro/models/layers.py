"""Shared transformer layers: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

Attention is *q-chunked memory-efficient* by default: a lax.scan over query
chunks with a rematerialized exact-softmax body, so peak memory is one
(chunk x S) score block instead of (T x S).  This is what makes the
``prefill_32k`` cells compile within HBM; the Pallas flash kernel
(kernels/flash_attn.py) is the TPU fast path for the same contraction.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig

NEG_INF = -1e30


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: (B, T, H, d) with even d; positions: (T,) or (B, T)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., T, half)
    if ang.ndim == 2:                                          # (T, half) -> broadcast B
        ang = ang[None]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core
# ---------------------------------------------------------------------------


def attention_core(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   causal: bool = True, window: Optional[int] = None,
                   q_chunk: int = 512, q_offset: int = 0,
                   score_dtype=jnp.float32, impl: str = "chunked") -> jnp.ndarray:
    """Exact attention, scanned over query chunks (memory-efficient).

    q: (B, H, T, d);  k, v: (B, Hkv, S, d).  GQA via head-group einsum (no
    kv repeat).  ``q_offset`` is the absolute position of q[0] (decode /
    chunked prefill).  ``window``: local attention span (RecurrentGemma).

    ``score_dtype=bfloat16`` keeps the (Tc, S) score/prob blocks — the
    dominant HBM traffic of every train/prefill cell — in bf16: the QK dot
    emits bf16, the max/sum reductions still run in f32 (converts fuse into
    the producing chains, so no extra materialization).
    """
    B, H, T, d = q.shape
    _, Hkv, S, _ = k.shape
    if impl == "skip_core":
        # HBM-accounting stand-in for the Pallas flash kernel: same q/k/v/o
        # streams, no score-sized materialization.  NOT a real model — used
        # by the dry-run to measure the kernel's roofline profile.
        return (q + k.mean(axis=2, keepdims=True).repeat(H // Hkv, 1)
                + v.mean(axis=2, keepdims=True).repeat(H // Hkv, 1)).astype(q.dtype)
    g = H // Hkv
    scale = 1.0 / (d ** 0.5)
    qc = min(q_chunk, T)
    if T % qc:
        qc = T  # fall back to single chunk for ragged tiny shapes
    nc = T // qc
    qr = q.reshape(B, Hkv, g, nc, qc, d)
    kpos = jnp.arange(S)
    sdt = jnp.dtype(score_dtype)
    neg = jnp.asarray(NEG_INF, sdt)   # -1e30 is representable in bf16

    def chunk_fn(idx):
        qc_ = jax.lax.dynamic_index_in_dim(qr, idx, axis=3, keepdims=False)
        s = jnp.einsum("bkgtd,bksd->bkgts", qc_.astype(sdt), k.astype(sdt),
                       preferred_element_type=sdt) * jnp.asarray(scale, sdt)
        qpos = q_offset + idx * qc + jnp.arange(qc)
        mask = jnp.ones((qc, S), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, neg)
        # stable softmax: reductions in f32, materialized blocks in sdt
        m = s.max(axis=-1, keepdims=True).astype(jnp.float32)
        p = jnp.exp(s.astype(jnp.float32) - m).astype(sdt)
        z = p.astype(jnp.float32).sum(axis=-1, keepdims=True)
        a = (p / z.astype(sdt))
        return jnp.einsum("bkgts,bksd->bkgtd", a, v.astype(sdt),
                          preferred_element_type=jnp.float32)

    if nc == 1:
        out = chunk_fn(jnp.int32(0))[:, :, :, None]
        out = jnp.moveaxis(out, 3, 0)
    else:
        out = jax.lax.map(jax.checkpoint(chunk_fn), jnp.arange(nc))  # (nc, B,Hkv,g,qc,d)
    out = jnp.moveaxis(out, 0, 3)                    # (B, Hkv, g, nc, qc, d)
    return out.reshape(B, H, T, d).astype(q.dtype)


def decode_attention(q1: jnp.ndarray, cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                     t: jnp.ndarray, window: Optional[int] = None) -> jnp.ndarray:
    """Single-token attention against a (B, Hkv, S, d) cache; t = current pos.

    The kv-length dim stays sharded (SP decode); softmax over a sharded axis
    lowers to small max/sum collectives under GSPMD (flash-decoding style).
    """
    B, H, _, d = q1.shape
    _, Hkv, S, _ = cache_k.shape
    g = H // Hkv
    qr = q1.reshape(B, Hkv, g, 1, d)
    s = jnp.einsum("bkgtd,bksd->bkgts", qr.astype(jnp.float32),
                   cache_k.astype(jnp.float32)) / (d ** 0.5)
    kpos = jnp.arange(S)
    mask = kpos <= t
    if window is not None:
        mask &= kpos > t - window
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bksd->bkgtd", a, cache_v.astype(jnp.float32))
    return out.reshape(B, H, 1, d).astype(q1.dtype)


# ---------------------------------------------------------------------------
# Attention layer (projections + rope + core/cache)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32):
    D, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sc = D ** -0.5
    p = {
        "w_q": jax.random.normal(k1, (D, H * hd), dtype) * sc,
        "w_k": jax.random.normal(k2, (D, Hkv * hd), dtype) * sc,
        "w_v": jax.random.normal(k3, (D, Hkv * hd), dtype) * sc,
        "w_o": jax.random.normal(k4, (H * hd, D), dtype) * ((H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((H * hd,), dtype)
        p["b_k"] = jnp.zeros((Hkv * hd,), dtype)
        p["b_v"] = jnp.zeros((Hkv * hd,), dtype)
    return p


def attention_layer(p, x, cfg: ModelConfig, *, positions, window=None,
                    cache=None, cache_index=None, q_chunk: int = 512):
    """x: (B, T, D).  Returns (out, new_cache).

    cache: optional (k, v) each (B, Hkv, S, d); when given with
    ``cache_index`` (scalar), runs decode: writes k/v at the index and
    attends to the cache.  Otherwise trains/prefills over the full T.
    """
    B, T, D = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    dt = x.dtype
    q = x @ p["w_q"].astype(dt)
    k = x @ p["w_k"].astype(dt)
    v = x @ p["w_v"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["b_q"].astype(dt)
        k = k + p["b_k"].astype(dt)
        v = v + p["b_v"].astype(dt)
    q = q.reshape(B, T, H, hd)
    k = k.reshape(B, T, Hkv, hd)
    v = v.reshape(B, T, Hkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = q.transpose(0, 2, 1, 3)                    # (B, H, T, d)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    new_cache = None
    if cache is not None:
        ck, cv = cache
        if cache_index is not None:    # decode: append one token
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, cache_index, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, cache_index, 0))
            out = decode_attention(q, ck, cv, cache_index, window=window)
            new_cache = (ck, cv)
        else:                          # prefill: write the whole prefix
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, 0, 0))
            out = attention_core(q, k, v, causal=True, window=window,
                                 q_chunk=q_chunk,
                                 score_dtype=jnp.dtype(cfg.score_dtype),
                                 impl=cfg.attn_impl)
            new_cache = (ck, cv)
    else:
        out = attention_core(q, k, v, causal=True, window=window, q_chunk=q_chunk,
                             score_dtype=jnp.dtype(cfg.score_dtype),
                             impl=cfg.attn_impl)

    out = out.transpose(0, 2, 1, 3).reshape(B, T, H * hd)
    return out @ p["w_o"].astype(dt), new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * d_model ** -0.5,
        "w_in": jax.random.normal(k2, (d_model, d_ff), dtype) * d_model ** -0.5,
        "w_out": jax.random.normal(k3, (d_ff, d_model), dtype) * d_ff ** -0.5,
    }


def mlp_layer(p, x):
    dt = x.dtype
    h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_in"].astype(dt))
    return h @ p["w_out"].astype(dt)
