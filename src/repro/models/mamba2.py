"""Mamba-2 SSD (state-space duality) block, arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: intra-chunk quadratic
(attention-like, MXU-friendly GEMMs) + inter-chunk linear recurrence over
chunk states — O(T) compute, O(chunk^2) working memory.  Decode is the pure
recurrence on a (heads, head_dim, d_state) state, so the ``long_500k`` cell
is bounded-state.  Single group (G=1) as in the 1.3b config.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rms_norm


def _dims(cfg: ModelConfig):
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    nheads = d_in // m.head_dim
    return m, d_in, nheads


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32):
    m, d_in, nheads = _dims(cfg)
    conv_ch = d_in + 2 * m.d_state
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * m.d_state + nheads
    return {
        "in_proj": jax.random.normal(ks[0], (cfg.d_model, proj_out), dtype) * cfg.d_model ** -0.5,
        "conv_w": jax.random.normal(ks[1], (m.d_conv, conv_ch), dtype) * 0.5,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": jax.random.normal(ks[3], (d_in, cfg.d_model), dtype) * d_in ** -0.5,
    }


def _segsum(a):
    """(..., l) -> (..., l, l) lower-tri cumulative segment sums."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    tril = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(tril, d, -jnp.inf)


def _ssd_chunked(x, dt, a, Bm, Cm, chunk: int, init_state=None,
                 big_dtype=None):
    """Chunked SSD.  x: (B, T, H, P); dt: (B, T, H); a: (H,) (negative);
    Bm, Cm: (B, T, N).  Returns (y, final_state (B, H, P, N)).

    ``big_dtype`` (e.g. bf16) is used for the large materialized
    intermediates (W, x*dt, chunk states); decay/cumsum math stays f32."""
    B_, T, H, P_ = x.shape
    N = Bm.shape[-1]
    l = min(chunk, T)
    if T % l:
        l = T
    nc = T // l
    xr = x.reshape(B_, nc, l, H, P_)
    dtr = dt.reshape(B_, nc, l, H)
    Br = Bm.reshape(B_, nc, l, N)
    Cr = Cm.reshape(B_, nc, l, N)

    dA = dtr * a                                          # (b, c, l, h)
    dA_cum = jnp.cumsum(dA, axis=2)

    # 1) intra-chunk (quadratic within chunk).  Contraction order matters
    # enormously here: a naive 4-operand einsum lets XLA materialize a
    # (b,c,h,l,s,p) 6-D intermediate (~100x the useful traffic, see
    # EXPERIMENTS.md §Perf).  We force the pairwise order: W = (C B^T) ∘ L
    # then one batched (l,s)@(s,hp) GEMM.
    bdt = big_dtype or x.dtype
    L = jnp.exp(_segsum(jnp.moveaxis(dA, 3, 2)))          # (b, c, h, l, l)
    S = jnp.einsum("bcln,bcsn->bcls", Cr, Br)             # (b, c, l, s)
    W = (S[:, :, None] * L).astype(bdt)                   # (b, c, h, l, s)
    xdt = (xr * dtr[..., None]).astype(bdt)               # (b, c, s, h, p)
    Y = jnp.einsum("bchls,bcshp->bclhp", W, xdt,
                   preferred_element_type=jnp.float32)

    # 2) per-chunk input states (pairwise order again: weight x first)
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (b, c, l, h)
    xw = (xr * (decay_states * dtr)[..., None]).astype(bdt)  # (b, c, l, h, p)
    states = jnp.einsum("bcln,bclhp->bchpn", Br.astype(bdt), xw,
                        preferred_element_type=jnp.float32)

    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])            # (b, c, h)
    s0 = jnp.zeros((B_, H, P_, N), x.dtype) if init_state is None else init_state

    def step(s, inp):
        dec, st = inp                                     # (b, h), (b, h, p, n)
        s_new = s * dec[..., None, None] + st
        return s_new, s
    cd = jnp.moveaxis(chunk_decay, 1, 0)                  # (c, b, h)
    st = jnp.moveaxis(states, 1, 0)                       # (c, b, h, p, n)
    final, prev = jax.lax.scan(step, s0, (cd, st))
    prev = jnp.moveaxis(prev, 0, 1)                       # (b, c, h, p, n)

    # 4) off-diagonal: contribution of previous chunks' state
    state_decay = jnp.exp(dA_cum)                         # (b, c, l, h)
    Y_off = jnp.einsum("bcln,bchpn->bclhp", Cr, prev)     # (l,n)@(n,hp) GEMM
    Y = Y + Y_off * state_decay[..., None]
    return Y.reshape(B_, T, H, P_), final


def mamba_layer(p, x, cfg: ModelConfig, state: Optional[dict] = None):
    """x: (B, T, D).  state (decode): {'ssm': (B,H,P,N), 'conv': (B,dc-1,ch)}.

    Returns (out, new_state)."""
    m, d_in, nheads = _dims(cfg)
    B_, T, D = x.shape
    dt_ = x.dtype
    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * m.d_state], axis=-1)

    # causal depthwise conv over (x, B, C)
    dc = m.d_conv
    tail = (jnp.zeros((B_, dc - 1, xbc.shape[-1]), dt_) if state is None
            else state["conv"])
    xp = jnp.concatenate([tail, xbc], axis=1)
    xbc = sum(xp[:, dc - 1 - j:dc - 1 - j + T] * p["conv_w"][j].astype(dt_)
              for j in range(dc)) + p["conv_b"].astype(dt_)
    new_conv = xp[:, -(dc - 1):]
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + m.d_state], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])    # (B, T, H)
    a = -jnp.exp(p["a_log"])                                           # (H,)
    xh = xs.reshape(B_, T, nheads, m.head_dim).astype(jnp.float32)
    Bm32, Cm32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    if state is not None and T == 1:
        s = state["ssm"]
        dec = jnp.exp(dt[:, 0] * a)                                    # (B, H)
        upd = jnp.einsum("bn,bh,bhp->bhpn", Bm32[:, 0], dt[:, 0], xh[:, 0])
        s_new = s * dec[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm32[:, 0], s_new)[:, None]     # (B,1,H,P)
        final = s_new
    else:
        init = state["ssm"] if state is not None else None
        y, final = _ssd_chunked(xh, dt, a, Bm32, Cm32, m.chunk, init,
                                big_dtype=jnp.dtype(cfg.score_dtype))

    y = y + p["d_skip"][:, None] * xh                                  # skip
    y = y.reshape(B_, T, d_in).astype(dt_)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm_scale"].astype(dt_), cfg.rms_eps)
    out = y @ p["out_proj"].astype(dt_)
    new_state = {"ssm": final, "conv": new_conv}
    return out, new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    m, d_in, nheads = _dims(cfg)
    return {"ssm": jnp.zeros((batch, nheads, m.head_dim, m.d_state), jnp.float32),
            "conv": jnp.zeros((batch, m.d_conv - 1, d_in + 2 * m.d_state), dtype)}
