"""Mixture-of-Experts layer with expert parallelism and cost-model placement.

Execution scheme ("replicated-dispatch EP", DESIGN.md §6): activations are
batch-sharded over the data axes and *replicated* over the model axis, while
experts are sharded over the model axis.  Inside a shard_map every model
rank routes its local tokens, gathers the subset destined for *its* experts
into a capacity-padded (E_local, C, D) block, applies the expert FFNs as
batched GEMMs, scatters weighted results back, and a single psum over the
model axis combines contributions — exactly one all-reduce per MoE layer
(the same collective cost as a Megatron TP FFN), zero all-to-alls.

The paper's technique enters through ``expert_placement``: expert->rank
assignment is a weighted-graph partition (core/partition.py) where vertex
weights are observed expert token loads and edges are co-activation counts,
so hot experts spread across ranks — the FMM subtree load-balancing model
transplanted to MoE (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .config import ModelConfig
from ..core.partition import Graph, partition


def make_fsdp_gather_q8(axes, compute_dtype):
    """int8-quantized FSDP all-gather with straight-through backward.

    Forward: per-expert absmax int8 quantization of the local dim-1 shard,
    all-gather of the int8 payload (+ tiny per-(expert, shard) scales),
    dequantize to the compute dtype — the wire carries 1 byte/element
    instead of 2.  Backward: the exact adjoint of a tiled all-gather
    (psum_scatter), i.e. the quantizer is treated as identity (STE).
    """

    def _quantized_gather(w):
        scale = jnp.max(jnp.abs(w), axis=(1, 2), keepdims=True) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
        qg = jax.lax.all_gather(q, axes, axis=1, tiled=True)
        sg = jax.lax.all_gather(scale, axes, axis=1, tiled=True)  # (E, nsh, 1)
        e, d_full, f = qg.shape
        nsh = sg.shape[1]
        blocks = qg.reshape(e, nsh, d_full // nsh, f).astype(compute_dtype)
        return (blocks * sg[..., None].astype(compute_dtype)).reshape(e, d_full, f)

    @jax.custom_vjp
    def gather(w):
        return _quantized_gather(w)

    def _fwd(w):
        return _quantized_gather(w), None

    def _bwd(_, g):
        gl = jax.lax.psum_scatter(g.astype(jnp.float32), axes,
                                  scatter_dimension=1, tiled=True)
        return (gl,)

    gather.defvjp(_fwd, _bwd)
    return gather


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    D, E, F = cfg.d_model, m.num_experts, m.expert_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(k1, (D, E), dtype) * D ** -0.5,
        "experts_gate": jax.random.normal(k2, (E, D, F), dtype) * D ** -0.5,
        "experts_in": jax.random.normal(k3, (E, D, F), dtype) * D ** -0.5,
        "experts_out": jax.random.normal(k4, (E, F, D), dtype) * F ** -0.5,
    }


def _moe_local(x, router, wg, wi, wo, *, top_k: int, num_experts: int,
               capacity: int, e_start, axis_name: Optional[str]):
    """Per-device MoE body.  x: (N, D) local tokens; wg/wi/wo: local experts.

    Routes all N tokens, keeps only those destined for this rank's experts
    [e_start, e_start + E_local), computes, and returns the partial output
    (psum over ``axis_name`` completes it).
    """
    N, D = x.shape
    E_local = wg.shape[0]
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))      # (N, E)
    gate_w, gate_e = jax.lax.top_k(logits, top_k)                      # (N, k)
    gate_w = jax.nn.softmax(gate_w, axis=-1)

    flat_e = gate_e.reshape(-1)                                        # (N*k,)
    flat_w = gate_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(N), top_k)

    local_e = flat_e - e_start
    mine = (local_e >= 0) & (local_e < E_local)
    local_e = jnp.where(mine, local_e, 0)

    # rank of each (token, choice) within its expert, among *my* assignments
    onehot = jnp.where(mine[:, None],
                       jax.nn.one_hot(local_e, E_local, dtype=jnp.int32), 0)
    rank = jnp.cumsum(onehot, axis=0) - onehot                         # exclusive
    rank = (rank * onehot).sum(-1)                                     # (N*k,)
    keep = mine & (rank < capacity)

    slot = local_e * capacity + rank                                   # (N*k,)
    slot = jnp.where(keep, slot, E_local * capacity)                   # overflow bin
    # gather tokens into (E_local*capacity+1, D) then drop the bin
    xe = jnp.zeros((E_local * capacity + 1, D), x.dtype).at[slot].set(
        jnp.where(keep[:, None], x[flat_tok], 0))
    xe = xe[:-1].reshape(E_local, capacity, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg.astype(x.dtype))) * \
        jnp.einsum("ecd,edf->ecf", xe, wi.astype(x.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, wo.astype(x.dtype))             # (E_local, C, D)

    yflat = jnp.concatenate([ye.reshape(-1, D), jnp.zeros((1, D), ye.dtype)])
    ytok = yflat[slot] * flat_w[:, None].astype(ye.dtype)              # (N*k, D)
    out = jnp.zeros_like(x).at[flat_tok].add(jnp.where(keep[:, None], ytok, 0))
    if axis_name is not None:
        out = jax.lax.psum(out, axis_name)
    return out


def moe_layer(p, x, cfg: ModelConfig, mesh: Optional[Mesh] = None,
              placement: Optional[np.ndarray] = None):
    """x: (B, T, D) -> (B, T, D).

    ``placement``: optional permutation of expert ids (cost-model expert
    placement); expert weights are pre-permuted at load/update time so rank
    r's shard holds the experts assigned to it.
    """
    B, T, D = x.shape
    m = cfg.moe
    if mesh is None or "model" not in mesh.axis_names or mesh.shape["model"] == 1:
        # single-rank path (smoke tests): all experts local
        cap = int(np.ceil(B * T * m.top_k / m.num_experts * m.capacity_factor))
        out = _moe_local(x.reshape(B * T, D), p["router"], p["experts_gate"],
                         p["experts_in"], p["experts_out"], top_k=m.top_k,
                         num_experts=m.num_experts, capacity=max(cap, 1),
                         e_start=0, axis_name=None)
        return out.reshape(B, T, D)

    tp = mesh.shape["model"]
    assert m.num_experts % tp == 0, (m.num_experts, tp)
    e_local = m.num_experts // tp
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    n_local = (B // dp if B % dp == 0 else B) * T
    cap = int(np.ceil(n_local * m.top_k / m.num_experts * m.capacity_factor))
    cap = max(cap, 1)
    # FSDP for expert weights: dim 1 sharded over the data axes when it
    # divides; the body gathers it back per layer (in compute dtype, so the
    # wire format is bf16 — half the f32 master-weight traffic).  The
    # fallback chain mirrors parallel.sharding.param_spec so storage and
    # shard_map specs agree (no hidden resharding).
    dim1 = p["experts_gate"].shape[-2]
    if dp > 1 and dim1 % dp == 0:
        fsdp_ax = dp_axes
    elif "data" in mesh.axis_names and mesh.shape["data"] > 1 \
            and dim1 % mesh.shape["data"] == 0:
        fsdp_ax = ("data",)
    else:
        fsdp_ax = None

    def body(xs, router, wg, wi, wo):
        rank = jax.lax.axis_index("model")
        Bl, Tl, _ = xs.shape
        if fsdp_ax is not None:
            if cfg.moe_gather_bits == 8:
                gather = make_fsdp_gather_q8(fsdp_ax, xs.dtype)
                wg, wi, wo = gather(wg), gather(wi), gather(wo)
            else:
                wg = jax.lax.all_gather(wg.astype(xs.dtype), fsdp_ax, axis=1,
                                        tiled=True)
                wi = jax.lax.all_gather(wi.astype(xs.dtype), fsdp_ax, axis=1,
                                        tiled=True)
                wo = jax.lax.all_gather(wo.astype(xs.dtype), fsdp_ax, axis=1,
                                        tiled=True)
        out = _moe_local(xs.reshape(Bl * Tl, D), router, wg, wi, wo,
                         top_k=m.top_k, num_experts=m.num_experts,
                         capacity=cap, e_start=rank * e_local,
                         axis_name="model")
        return out.reshape(Bl, Tl, D)

    x_spec = P(dp_axes if dp_axes else None, None, None)
    e_spec = P("model", fsdp_ax, None)
    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(x_spec, P(None, None), e_spec, e_spec, e_spec),
                       out_specs=x_spec)
    return fn(x, p["router"], p["experts_gate"], p["experts_in"], p["experts_out"])


def moe_param_specs(mesh: Mesh) -> dict:
    """PartitionSpecs for MoE params (experts over the model axis = EP)."""
    return {
        "router": P(None, None),
        "experts_gate": P("model", None, None),
        "experts_in": P("model", None, None),
        "experts_out": P("model", None, None),
    }


# ---------------------------------------------------------------------------
# Cost-model expert placement (the paper's technique, transplanted)
# ---------------------------------------------------------------------------


def expert_placement(token_counts: np.ndarray, coactivation: np.ndarray,
                     num_ranks: int) -> np.ndarray:
    """Assign experts to EP ranks balancing load and minimizing co-traffic.

    token_counts: (E,) observed tokens routed per expert (vertex weights =
    the paper's per-subtree work estimate); coactivation: (E, E) counts of
    experts co-selected for the same token (edge weights = the paper's
    inter-subtree communication estimate).  Returns (E,) rank per expert.
    """
    E = len(token_counts)
    adjacency = [[] for _ in range(E)]
    for i in range(E):
        for j in range(i + 1, E):
            if coactivation[i, j] > 0:
                adjacency[i].append((j, float(coactivation[i, j])))
                adjacency[j].append((i, float(coactivation[i, j])))
    g = Graph(vertex_weight=np.asarray(token_counts, np.float64), adjacency=adjacency)
    assign = partition(g, num_ranks, method="model",
                       order=np.argsort(-np.asarray(token_counts)))
    return assign


def placement_permutation(assign: np.ndarray, num_ranks: int) -> np.ndarray:
    """Expert-id permutation so rank r's contiguous shard = its experts.

    Pads ranks to equal expert counts by stealing from the least-loaded
    ranks is NOT done here — callers should ensure |experts per rank| is
    uniform (capacity-style placement); we round-robin any remainder.
    """
    E = len(assign)
    per = E // num_ranks
    buckets = [list(np.where(assign == r)[0]) for r in range(num_ranks)]
    # rebalance counts to exactly `per` per rank (EP shards must be equal)
    overflow = []
    for b in buckets:
        while len(b) > per:
            overflow.append(b.pop())
    for b in buckets:
        while len(b) < per:
            b.append(overflow.pop())
    return np.concatenate([np.asarray(b, np.int64) for b in buckets])
