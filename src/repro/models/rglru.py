"""RecurrentGemma/Griffin hybrid block: RG-LRU recurrence + local attention.

Block pattern follows arXiv:2402.19427 (2 recurrent : 1 local-attn).  The
recurrence

    a_t = exp(-c * softplus(Lambda) * r_t),   r_t = sigmoid(W_a x_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

is linear in h, so training uses ``lax.associative_scan`` (parallel prefix,
O(T log T) span) and decode carries (h, conv tail) state — bounded memory at
any context length, which is why this arch runs the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig

_C = 8.0  # RG-LRU temperature constant


def init_rglru(key, cfg: ModelConfig, dtype=jnp.float32):
    D = cfg.d_model
    W = cfg.rglru.lru_width or D
    ks = jax.random.split(key, 6)
    return {
        "w_gate": jax.random.normal(ks[0], (D, W), dtype) * D ** -0.5,
        "w_in": jax.random.normal(ks[1], (D, W), dtype) * D ** -0.5,
        "w_out": jax.random.normal(ks[2], (W, D), dtype) * W ** -0.5,
        "conv_w": jax.random.normal(ks[3], (4, W), dtype) * 0.5,
        "lru_wa": jax.random.normal(ks[4], (W, W), dtype) * W ** -0.5,
        "lru_wi": jax.random.normal(ks[5], (W, W), dtype) * W ** -0.5,
        "lru_lambda": jnp.linspace(0.5, 4.0, W).astype(dtype),  # softplus^-1 spread
        "lru_ba": jnp.zeros((W,), dtype),
        "lru_bi": jnp.zeros((W,), dtype),
    }


def _causal_conv4(x, w, state=None):
    """Depthwise causal conv, width 4.  x: (B, T, W); w: (4, W).

    state: (B, 3, W) trailing inputs from the previous segment (decode).
    Returns (y, new_state).
    """
    B, T, W = x.shape
    tail = jnp.zeros((B, 3, W), x.dtype) if state is None else state
    xp = jnp.concatenate([tail, x], axis=1)            # (B, T+3, W)
    y = sum(xp[:, 3 - j:3 - j + T] * w[j] for j in range(4))
    return y, xp[:, -3:]


def _lru_scan(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t via associative scan.  a, b: (B, T, W)."""
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_layer(p, x, cfg: ModelConfig, state: Optional[dict] = None):
    """x: (B, T, D).  state (decode): {'h': (B, W), 'conv': (B, 3, W)}.

    Returns (out, new_state).
    """
    dt = x.dtype
    u = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    c = x @ p["w_in"].astype(dt)
    conv_state = state["conv"] if state is not None else None
    c, new_conv = _causal_conv4(c, p["conv_w"].astype(dt), conv_state)

    cf = c.astype(jnp.float32)
    r = jax.nn.sigmoid(cf @ p["lru_wa"].astype(jnp.float32) + p["lru_ba"])
    i = jax.nn.sigmoid(cf @ p["lru_wi"].astype(jnp.float32) + p["lru_bi"])
    log_a = -_C * jax.nn.softplus(p["lru_lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * cf)

    if state is not None and x.shape[1] == 1:          # decode single step
        h = a[:, 0] * state["h"] + b[:, 0]
        hseq = h[:, None]
        new_state = {"h": h, "conv": new_conv}
    else:
        h0 = state["h"] if state is not None else None
        hseq = _lru_scan(a, b, h0)
        new_state = {"h": hseq[:, -1], "conv": new_conv}

    out = (u * hseq.astype(dt)) @ p["w_out"].astype(dt)
    return out, new_state


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    W = cfg.rglru.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, W), jnp.float32),
            "conv": jnp.zeros((batch, 3, W), dtype)}
