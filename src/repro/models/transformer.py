"""Model assembly: block dispatch per family, scan-over-layers, caches, loss.

Families:
  dense/audio/vlm : [attn + SwiGLU MLP] x L          (audio = small-vocab LM;
                    vlm prepends projected patch embeddings from the stub)
  moe             : [attn + MoE FFN] x L
  hybrid          : Griffin pattern (rglru, rglru, local-attn) cycled
  ssm             : [mamba2 SSD] x L

Layers are stacked and traversed with ``lax.scan`` (rematerialized bodies),
which keeps HLO size O(1) in depth — essential for the 94-layer dry-runs.
Decode maintains a cache pytree per family (KV cache / ring-buffer window
cache / SSM + conv states).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from jax.ad_checkpoint import checkpoint_name
from .config import ModelConfig
from . import layers as ll
from .layers import attention_layer, init_attention, init_mlp, mlp_layer, rms_norm
from .mamba2 import init_mamba, init_mamba_state, mamba_layer
from .moe import init_moe, moe_layer
from .rglru import init_rglru, init_rglru_state, rglru_layer


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------


def layer_kinds(cfg: ModelConfig) -> list[str]:
    if cfg.family == "ssm":
        return ["mamba"] * cfg.num_layers
    if cfg.family == "moe":
        return ["moe"] * cfg.num_layers
    if cfg.family == "hybrid":
        pat = cfg.rglru.pattern
        return [pat[i % len(pat)] for i in range(cfg.num_layers)]
    return ["attn"] * cfg.num_layers


def _scan_groups(kinds: list[str]) -> list[tuple[list[str], int]]:
    """Group layers into (pattern, repeats) so each group scans uniformly.

    Uniform stacks -> one group; hybrid -> (pattern, L // len) + remainder
    groups of single layers.
    """
    if len(set(kinds)) == 1:
        return [([kinds[0]], len(kinds))]
    # periodic pattern
    for plen in range(1, len(kinds) + 1):
        pat = kinds[:plen]
        reps = len(kinds) // plen
        if pat * reps == kinds[:plen * reps]:
            groups = [(pat, reps)] if reps > 0 else []
            rest = kinds[plen * reps:]
            groups += [([k], 1) for k in rest]
            if plen * reps + len(rest) == len(kinds) and reps > 1:
                return groups
    return [([k], 1) for k in kinds]


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _init_one(key, cfg: ModelConfig, kind: str, dtype):
    D = cfg.d_model
    if kind == "mamba":
        return {"ln": jnp.ones((D,), dtype), "mamba": init_mamba(key, cfg, dtype)}
    if kind == "rglru":
        k1, k2 = jax.random.split(key)
        return {"ln1": jnp.ones((D,), dtype), "rec": init_rglru(k1, cfg, dtype),
                "ln2": jnp.ones((D,), dtype),
                "mlp": init_mlp(k2, D, cfg.d_ff, dtype)}
    if kind == "moe":
        k1, k2 = jax.random.split(key)
        return {"ln1": jnp.ones((D,), dtype), "attn": init_attention(k1, cfg, dtype),
                "ln2": jnp.ones((D,), dtype), "moe": init_moe(k2, cfg, dtype)}
    # attn (dense / local-attn hybrid block)
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((D,), dtype), "attn": init_attention(k1, cfg, dtype),
            "ln2": jnp.ones((D,), dtype), "mlp": init_mlp(k2, D, cfg.d_ff, dtype)}


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    kinds = layer_kinds(cfg)
    keys = jax.random.split(key, len(kinds) + 3)
    params: dict[str, Any] = {
        "embed": jax.random.normal(keys[-1], (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            keys[-2], (cfg.vocab, cfg.d_model), dtype) * 0.02
    if cfg.num_patches:
        params["patch_proj"] = jax.random.normal(
            keys[-3], (cfg.patch_dim, cfg.d_model), dtype) * cfg.patch_dim ** -0.5

    groups = _scan_groups(kinds)
    gparams = []
    li = 0
    for pat, reps in groups:
        if reps == 1:
            gparams.append([_init_one(keys[li + j], cfg, k, dtype)
                            for j, k in enumerate(pat)])
            li += len(pat)
        else:
            stacked = []
            for j, k in enumerate(pat):
                ks = jnp.stack([jax.random.fold_in(keys[li + j], r) for r in range(reps)])
                stacked.append(jax.vmap(lambda kk: _init_one(kk, cfg, k, dtype))(ks))
            gparams.append(stacked)
            li += len(pat) * reps
    params["groups"] = gparams
    return params


# ---------------------------------------------------------------------------
# Caches / recurrent state
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decode-state pytree mirroring the group structure of the params."""
    kinds = layer_kinds(cfg)
    groups = _scan_groups(kinds)
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim_

    def one(kind):
        if kind == "mamba":
            return init_mamba_state(cfg, batch, dtype)
        if kind == "rglru":
            return init_rglru_state(cfg, batch, dtype)
        wlen = max_len
        if kind == "attn" and cfg.rglru is not None:
            wlen = min(max_len, cfg.rglru.window)   # ring-buffer window cache
        return {"k": jnp.zeros((batch, Hkv, wlen, hd), dtype),
                "v": jnp.zeros((batch, Hkv, wlen, hd), dtype),
                "pos": jnp.full((wlen,), -1, jnp.int32)}

    gcaches = []
    for pat, reps in groups:
        if reps == 1:
            gcaches.append([one(k) for k in pat])
        else:
            gcaches.append([jax.tree.map(lambda x: jnp.broadcast_to(
                x, (reps,) + x.shape), one(k)) for k in pat])
    return gcaches


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _attn_block(p, h, cfg, mesh, *, positions, window, cache, pos_scalar, q_chunk):
    """Attention with optional ring-buffer cache.  Returns (h, new_cache)."""
    x = rms_norm(h, p["ln1"].astype(h.dtype), cfg.rms_eps)
    if cache is None:
        out, _ = attention_layer(p["attn"], x, cfg, positions=positions,
                                 window=window, q_chunk=q_chunk)
        out = checkpoint_name(out, "attn_out")
        return h + out, None

    B, T, D = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    dt = x.dtype
    q = (x @ p["attn"]["w_q"].astype(dt)).reshape(B, T, H, hd)
    k = (x @ p["attn"]["w_k"].astype(dt)).reshape(B, T, Hkv, hd)
    v = (x @ p["attn"]["w_v"].astype(dt)).reshape(B, T, Hkv, hd)
    if cfg.qkv_bias:
        q += p["attn"]["b_q"].astype(dt).reshape(H, hd)
        k += p["attn"]["b_k"].astype(dt).reshape(Hkv, hd)
        v += p["attn"]["b_v"].astype(dt).reshape(Hkv, hd)
    q = ll.rope(q, positions, cfg.rope_theta).transpose(0, 2, 1, 3)
    k = ll.rope(k, positions, cfg.rope_theta).transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    wlen = cache["k"].shape[2]
    if T == 1:  # decode: ring-buffer write at pos % wlen
        slot = pos_scalar % wlen
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, slot, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, slot, 0))
        cpos = jax.lax.dynamic_update_slice(cache["pos"],
                                            pos_scalar[None].astype(jnp.int32), (slot,))
        out = _masked_decode_attn(q, ck, cv, cpos, pos_scalar, window)
    else:       # prefill: write last wlen tokens at their slots
        ntail = min(T, wlen)
        ktail = k[:, :, T - ntail:]
        vtail = v[:, :, T - ntail:]
        ptail = positions[T - ntail:]
        slots = (ptail % wlen).astype(jnp.int32)
        ck = cache["k"].at[:, :, slots].set(ktail.astype(cache["k"].dtype))
        cv = cache["v"].at[:, :, slots].set(vtail.astype(cache["v"].dtype))
        cpos = cache["pos"].at[slots].set(ptail.astype(jnp.int32))
        out = ll.attention_core(q, k, v, causal=True, window=window,
                                q_chunk=q_chunk,
                                score_dtype=jnp.dtype(cfg.score_dtype),
                                impl=cfg.attn_impl)

    out = out.transpose(0, 2, 1, 3).reshape(B, T, H * hd)
    out = out @ p["attn"]["w_o"].astype(dt)
    out = checkpoint_name(out, "attn_out")
    return h + out, {"k": ck, "v": cv, "pos": cpos}


def _masked_decode_attn(q1, ck, cv, kpos, t, window):
    B, H, _, d = q1.shape
    Hkv = ck.shape[1]
    g = H // Hkv
    s = jnp.einsum("bkgtd,bksd->bkgts", q1.reshape(B, Hkv, g, 1, d).astype(jnp.float32),
                   ck.astype(jnp.float32)) / (d ** 0.5)
    mask = (kpos >= 0) & (kpos <= t)
    if window is not None:
        mask &= kpos > t - window
    s = jnp.where(mask[None, None, None, None, :], s, ll.NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bksd->bkgtd", a, cv.astype(jnp.float32))
    return out.reshape(B, H, 1, d).astype(q1.dtype)


def _ffn_block(p, h, cfg, mesh, kind):
    x = rms_norm(h, p["ln2"].astype(h.dtype), cfg.rms_eps)
    if kind == "moe":
        out = moe_layer(p["moe"], x, cfg, mesh)
        # named so remat_policy='save_block_out' keeps the psum+FSDP-gather
        # result: backward then skips the expert re-gather (§Perf iter)
        out = checkpoint_name(out, "moe_out")
        return h + out
    return h + mlp_layer(p["mlp"], x)


def apply_layer(p, h, cfg, mesh, kind, *, positions, cache, pos_scalar, q_chunk):
    """One block.  Returns (h, new_cache)."""
    if kind == "mamba":
        x = rms_norm(h, p["ln"].astype(h.dtype), cfg.rms_eps)
        out, st = mamba_layer(p["mamba"], x, cfg, cache)
        return h + out, st
    if kind == "rglru":
        x = rms_norm(h, p["ln1"].astype(h.dtype), cfg.rms_eps)
        out, st = rglru_layer(p["rec"], x, cfg, cache)
        h = h + out
        return _ffn_block(p, h, cfg, mesh, "mlp"), st
    window = cfg.rglru.window if (cfg.rglru is not None and kind == "attn") else None
    h, st = _attn_block(p, h, cfg, mesh, positions=positions, window=window,
                        cache=cache, pos_scalar=pos_scalar, q_chunk=q_chunk)
    return _ffn_block(p, h, cfg, mesh, kind), st


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def forward(params, tokens, cfg: ModelConfig, mesh: Optional[Mesh] = None, *,
            patch_embeds=None, caches=None, pos_scalar=None,
            q_chunk: int = 512, remat: bool = True):
    """Returns (hidden (B, T, D), new_caches).

    tokens: (B, T_text) int32.  For vlm, ``patch_embeds`` (B, P, patch_dim)
    is prepended after projection (T = P + T_text).  ``caches``/``pos_scalar``
    select decode (T == 1) or prefill behaviour.
    """
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(dt)[tokens]
    if cfg.num_patches and patch_embeds is not None:
        pe = patch_embeds.astype(dt) @ params["patch_proj"].astype(dt)
        h = jnp.concatenate([pe, h], axis=1)
    B, T, D = h.shape
    if pos_scalar is not None and T == 1:
        positions = jnp.full((B, 1), pos_scalar, jnp.int32)
    else:
        positions = jnp.arange(T, dtype=jnp.int32)

    kinds = layer_kinds(cfg)
    groups = _scan_groups(kinds)
    gparams = params["groups"]
    new_caches = []

    for gi, (pat, reps) in enumerate(groups):
        gp = gparams[gi]
        gc = caches[gi] if caches is not None else [None] * len(pat)

        if reps == 1:
            ncs = []
            for j, kind in enumerate(pat):
                h, nc = apply_layer(gp[j], h, cfg, mesh, kind, positions=positions,
                                    cache=gc[j], pos_scalar=pos_scalar,
                                    q_chunk=q_chunk)
                ncs.append(nc)
            new_caches.append(ncs)
            continue

        def body(hc, xs):
            pslices, cslices = xs
            ncs = []
            for j, kind in enumerate(pat):
                hc, nc = apply_layer(pslices[j], hc, cfg, mesh, kind,
                                     positions=positions, cache=cslices[j],
                                     pos_scalar=pos_scalar, q_chunk=q_chunk)
                ncs.append(nc if nc is not None else 0)
            return hc, ncs

        if remat:
            if cfg.remat_policy == "save_block_out":
                pol = jax.checkpoint_policies.save_only_these_names(
                    "moe_out", "attn_out")
                body = jax.checkpoint(body, policy=pol)
            else:
                body = jax.checkpoint(body)
        h, stacked_nc = jax.lax.scan(body, h, (gp, gc))
        new_caches.append(stacked_nc if caches is not None else [None] * len(pat))

    h = rms_norm(h, params["final_norm"].astype(dt), cfg.rms_eps)
    return h, (new_caches if caches is not None else None)


def unembed(params, h, cfg: ModelConfig):
    W = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return h.astype(jnp.float32) @ W.astype(jnp.float32).T


# ---------------------------------------------------------------------------
# Loss: chunked cross-entropy (never materializes (B, T, V))
# ---------------------------------------------------------------------------


def lm_loss(params, hidden, labels, cfg: ModelConfig, chunk: int = 256):
    """Mean NLL over labels >= 0.  hidden (B, T, D); labels (B, T).

    Scans T in chunks with a rematerialized body: the (B, c, V) logits block
    exists only transiently (forward) and is recomputed in backward.
    """
    B, T, D = hidden.shape
    W = (params["embed"] if cfg.tie_embeddings else params["lm_head"])
    c = min(chunk, T)
    if T % c:
        c = T
    nc = T // c

    def body(carry, idx):
        nll_sum, cnt = carry
        hc = jax.lax.dynamic_slice_in_dim(hidden, idx * c, c, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, idx * c, c, axis=1)
        logits = hc.astype(jnp.float32) @ W.astype(jnp.float32).T   # (B, c, V)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        lsafe = jnp.maximum(lc, 0)
        tgt = jnp.take_along_axis(logits, lsafe[..., None], axis=-1)[..., 0]
        m = (lc >= 0).astype(jnp.float32)
        return (nll_sum + ((lse - tgt) * m).sum(), cnt + m.sum()), None

    (nll, cnt), _ = jax.lax.scan(jax.checkpoint(body), (jnp.float32(0), jnp.float32(0)),
                                 jnp.arange(nc))
    return nll / jnp.maximum(cnt, 1.0)
