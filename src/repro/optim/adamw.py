"""Sharded AdamW with cosine schedule, global-norm clipping, and optional
int8-compressed gradient reduction with error feedback.

States inherit the parameter shardings (pjit propagates from in_shardings),
so optimizer memory scales 1/P like the params themselves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"   # "bfloat16" halves optimizer HBM (Adafactor-
                                   # style tradeoff) for the biggest models


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_state(params, cfg: "AdamWConfig | None" = None) -> dict:
    dt = jnp.dtype(cfg.state_dtype) if cfg is not None else jnp.float32
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, dt), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        mhat = mu32 / c1
        nhat = nu32 / c2
        new_p = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {"mu": jax.tree.unflatten(tdef, [o[1] for o in out]),
                 "nu": jax.tree.unflatten(tdef, [o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# Gradient compression (int8 quantized reduce with error feedback)
# ---------------------------------------------------------------------------


def compress_decompress(g: jnp.ndarray, err: jnp.ndarray):
    """Quantize g+err to int8 (per-tensor absmax scale) and back.

    Returns (g_hat, new_err).  Used before the DP mean so the wire format is
    1 byte/element; error feedback keeps the scheme convergent (EF-SGD).
    """
    g32 = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    g_hat = q.astype(jnp.float32) * scale
    return g_hat, g32 - g_hat


def compressed_psum_mean(grads, errors, axis_name: str):
    """int8-quantized psum-mean with error feedback (inside shard_map)."""
    n = jax.lax.psum(1, axis_name)
    new_g, new_e = {}, {}
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    outs = []
    for g, e in zip(flat_g, flat_e):
        gh, ne = compress_decompress(g, e)
        outs.append((jax.lax.psum(gh, axis_name) / n, ne))
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))
