"""Cross-process resilience protocol (DESIGN.md §14).

Pure-stdlib primitives shared by the rank workers and the supervisor
(``launch/supervisor.py``): a heartbeat file protocol, a collective-timeout
watchdog whose deadline is derived from the Eq 13-15 cost model's predicted
step time (robust_wall-filtered seconds-per-work-unit times the current
plan's modeled bottleneck), an epoch-numbered barrier that doubles as the
per-step cross-process collective, a membership-agreement protocol for
coordinated mesh shrink, and the :class:`RestartPolicy` /
:class:`MeshFaultError` pair bounding the supervisor's restart loop.

This module deliberately imports NO jax: the supervisor process and the
heartbeat-only test fixtures must be able to use it without initializing a
device runtime, and the rank workers import it before jax is configured.

File layout (everything generation-scoped under ``coord_dir/gen_<g>/``):

  hb_<rank>.json       heartbeat: {rank, gen, step, phase, t, pid, deadline,
                       spu} — atomically replaced on every beat.  ``phase``
                       walks boot -> restored -> step -> done (or shrink);
                       ``deadline`` is the rank's own published per-step
                       watchdog deadline, so readers never need to model a
                       peer's workload to judge its staleness.
  bar_<rank>           barrier cursor: the highest epoch this rank reached
                       (monotonic; one file per rank, atomically replaced).
  fault.json           first-writer-wins fault announcement: {dead, epoch,
                       by, t}.  Ranks poll it inside the barrier wait so a
                       supervisor-side (or peer-side) detection aborts the
                       wait immediately instead of after a full timeout.
  view_<epoch>_<rank>.json / decision_<epoch>.json
                       the epoch-numbered membership agreement (below).

Detection -> agreement -> shrink (the worker side):

  A rank killed or stopped mid-step stops beating; survivors block at the
  NEXT epoch barrier.  The wait is bounded by the watchdog deadline; on
  timeout each survivor checks every laggard's heartbeat age against the
  laggard's own published deadline, announces the stale set in
  ``fault.json``, writes its proposed survivor view for the detection
  epoch, and waits for identical views from every proposed member.  Two
  ranks detecting the same death concurrently converge trivially
  (identical proposals); diverging proposals are intersected and re-voted
  at epoch+1 (bounded rounds).  The first rank to observe full agreement
  publishes ``decision_<epoch>.json`` via O_EXCL; everyone returns the
  agreed view and exits with ``EXIT_SHRINK`` so the supervisor tears down
  the dead mesh and respawns the survivors at generation g+1.
"""
from __future__ import annotations

import dataclasses
import errno
import json
import os
import time
from typing import Optional, Sequence

# Worker exit code meaning "I detected a process fault, agreed on the
# survivor view, and am exiting for a coordinated shrink" (vs 0 = reached
# the target step, anything else = this rank itself failed).
EXIT_SHRINK = 75


# ---------------------------------------------------------------------------
# small atomic-file helpers
# ---------------------------------------------------------------------------


def _write_atomic(path: str, payload: str) -> None:
    tmp = path + f".tmp{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(payload)
    os.replace(tmp, path)


def _read_json(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _write_excl_json(path: str, obj: dict) -> bool:
    """First-writer-wins publication; False when someone else already won."""
    try:
        fd = os.open(path + ".lock", os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except OSError as e:
        if e.errno == errno.EEXIST:
            return False
        raise
    try:
        _write_atomic(path, json.dumps(obj))
    finally:
        os.close(fd)
    return True


def gen_dir(coord_dir: str, generation: int) -> str:
    d = os.path.join(coord_dir, f"gen_{generation}")
    os.makedirs(d, exist_ok=True)
    return d


# ---------------------------------------------------------------------------
# watchdog policy + deadline derivation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WatchdogPolicy:
    """Knobs for the collective-timeout watchdog.

    The per-step deadline is ``margin * predicted + slack`` floored at
    ``min_deadline``, where ``predicted`` is the robust_wall-filtered
    measured step time when the process has its own clean samples, else
    the Eq 13-15 modeled bottleneck times the calibrated seconds-per-work
    handed down from the previous generation.  Steps that are known to
    retrace (the first step in a process, the step after a plan/level
    adoption) are covered by ``compile_grace`` instead — a deadline tuned
    for steady-state steps would flag every legitimate recompile."""

    margin: float = 3.0
    slack: float = 2.0
    min_deadline: float = 1.0
    compile_grace: float = 300.0
    poll_interval: float = 0.05
    agree_timeout: float = 30.0
    max_barrier_rounds: int = 10
    teardown_grace: float = 15.0


def step_deadline(policy: WatchdogPolicy, predicted: Optional[float],
                  compiled: bool = True) -> float:
    """Bounded-time deadline for one stepper call.

    ``predicted`` is the cost-model/measurement step-seconds estimate
    (None = no estimate yet); ``compiled=False`` marks steps that will
    retrace (first call in the process, post-adoption), which get the
    compile grace window instead of the steady-state deadline."""
    if predicted is None:
        return policy.compile_grace
    d = max(policy.min_deadline, policy.margin * predicted + policy.slack)
    if not compiled:
        d = max(d, policy.compile_grace)
    return d


def predicted_from_calibration(seconds_per_work: Optional[float],
                               modeled_work: Optional[float]) -> Optional[float]:
    """Eq 13-15 prediction: calibrated seconds-per-work-unit (robust_wall
    over the previous generation's clean samples divided by its modeled
    bottleneck) times the current plan's modeled bottleneck load."""
    if seconds_per_work is None or modeled_work is None:
        return None
    if seconds_per_work <= 0.0 or modeled_work <= 0.0:
        return None
    return seconds_per_work * modeled_work


# ---------------------------------------------------------------------------
# heartbeats
# ---------------------------------------------------------------------------


class Heartbeat:
    """Per-rank heartbeat writer (atomic replace; one file per rank)."""

    def __init__(self, coord_dir: str, generation: int, rank: int):
        self.dir = gen_dir(coord_dir, generation)
        self.rank = int(rank)
        self.generation = int(generation)
        self.path = os.path.join(self.dir, f"hb_{rank}.json")

    def beat(self, *, step: int, phase: str, deadline: float,
             spu: Optional[float] = None) -> None:
        _write_atomic(self.path, json.dumps({
            "rank": self.rank, "gen": self.generation, "step": int(step),
            "phase": phase, "deadline": float(deadline), "t": time.time(),
            "pid": os.getpid(), "spu": spu}))


def read_heartbeat(coord_dir: str, generation: int,
                   rank: int) -> Optional[dict]:
    return _read_json(os.path.join(coord_dir, f"gen_{generation}",
                                   f"hb_{rank}.json"))


class Watchdog:
    """Heartbeat staleness detector over a set of ranks.

    A rank is OVERDUE when its last beat is older than the deadline it
    itself published with that beat (a SIGKILLed or SIGSTOPped rank's
    heartbeat freezes, so its age grows past its own deadline in bounded
    time); a rank that never beat is overdue once the generation is older
    than ``policy.compile_grace``."""

    def __init__(self, coord_dir: str, generation: int,
                 ranks: Sequence[int], policy: WatchdogPolicy):
        self.coord_dir = coord_dir
        self.generation = int(generation)
        self.ranks = tuple(int(r) for r in ranks)
        self.policy = policy
        self.start = time.time()

    def ages(self, now: Optional[float] = None) -> dict:
        """rank -> (age_seconds, published_deadline) for ranks with beats."""
        now = time.time() if now is None else now
        out = {}
        for r in self.ranks:
            hb = read_heartbeat(self.coord_dir, self.generation, r)
            if hb is not None:
                out[r] = (now - hb["t"], hb["deadline"])
        return out

    def overdue(self, now: Optional[float] = None) -> dict:
        """rank -> seconds past its own deadline, for every stale rank."""
        now = time.time() if now is None else now
        out = {}
        seen = self.ages(now)
        for r in self.ranks:
            if r in seen:
                age, deadline = seen[r]
                if age > deadline:
                    out[r] = age - deadline
            elif now - self.start > self.policy.compile_grace:
                out[r] = now - self.start - self.policy.compile_grace
        return out

    def fresh(self, now: Optional[float] = None) -> tuple:
        bad = self.overdue(now)
        return tuple(r for r in self.ranks if r not in bad)


# ---------------------------------------------------------------------------
# epoch barrier (the per-step cross-process collective)
# ---------------------------------------------------------------------------


class BarrierTimeout(RuntimeError):
    def __init__(self, epoch: int, missing: Sequence[int]):
        super().__init__(f"barrier epoch {epoch} timed out waiting for "
                         f"ranks {sorted(missing)}")
        self.epoch = epoch
        self.missing = tuple(sorted(missing))


class FaultAnnounced(RuntimeError):
    """Raised out of a barrier wait when a fault announcement lands."""

    def __init__(self, dead: Sequence[int], epoch: Optional[int], by):
        super().__init__(f"fault announced by {by}: dead={sorted(dead)}")
        self.dead = tuple(sorted(dead))
        self.epoch = epoch
        self.by = by


def announce_fault(coord_dir: str, generation: int, dead: Sequence[int],
                   epoch: Optional[int], by) -> dict:
    """Publish (first-writer-wins) and return the generation's fault
    announcement.  Later announcers get the original announcement back —
    detection is idempotent across the supervisor and any number of
    concurrently-detecting ranks."""
    path = os.path.join(gen_dir(coord_dir, generation), "fault.json")
    obj = {"dead": sorted(int(r) for r in dead), "epoch": epoch,
           "by": by, "t": time.time()}
    _write_excl_json(path, obj)
    got = _read_json(path)
    return got if got is not None else obj


def read_fault(coord_dir: str, generation: int) -> Optional[dict]:
    return _read_json(os.path.join(coord_dir, f"gen_{generation}",
                                   "fault.json"))


class EpochBarrier:
    """File barrier over monotonically increasing epochs.

    Each rank owns one cursor file holding the highest epoch it reached;
    ``wait(e)`` publishes the local cursor and polls until every peer's
    cursor is >= e.  The wait aborts with :class:`FaultAnnounced` the
    moment a fault announcement exists (so the slowest survivor does not
    serialize detection behind its own full timeout) and with
    :class:`BarrierTimeout` after ``timeout`` seconds."""

    def __init__(self, coord_dir: str, generation: int, rank: int,
                 ranks: Sequence[int],
                 poll_interval: float = 0.05):
        self.coord_dir = coord_dir
        self.dir = gen_dir(coord_dir, generation)
        self.generation = int(generation)
        self.rank = int(rank)
        self.ranks = tuple(int(r) for r in ranks)
        self.poll_interval = poll_interval

    def _cursor_path(self, rank: int) -> str:
        return os.path.join(self.dir, f"bar_{rank}")

    def cursor(self, rank: int) -> int:
        try:
            with open(self._cursor_path(rank)) as f:
                return int(f.read().strip())
        except (OSError, ValueError):
            return -1

    def arrive(self, epoch: int) -> None:
        _write_atomic(self._cursor_path(self.rank), str(int(epoch)))

    def wait(self, epoch: int, timeout: float, on_poll=None) -> None:
        """``on_poll`` (no-arg callable) runs every poll iteration — the
        worker refreshes its heartbeat there, so a rank BLOCKED at the
        barrier stays provably alive (only its in-step compute window is
        covered by the published deadline; without the refresh a long wait
        for a genuinely-dead peer would make every waiting survivor look
        stale too)."""
        self.arrive(epoch)
        deadline = time.time() + timeout
        while True:
            if on_poll is not None:
                on_poll()
            fault = read_fault(self.coord_dir, self.generation)
            if fault is not None:
                raise FaultAnnounced(fault["dead"], fault.get("epoch"),
                                     fault.get("by"))
            missing = [r for r in self.ranks
                       if r != self.rank and self.cursor(r) < epoch]
            if not missing:
                return
            if time.time() > deadline:
                raise BarrierTimeout(epoch, missing)
            time.sleep(self.poll_interval)


# ---------------------------------------------------------------------------
# epoch-numbered membership agreement
# ---------------------------------------------------------------------------


class AgreementError(RuntimeError):
    pass


def agree_view(coord_dir: str, generation: int, rank: int,
               proposed: Sequence[int], epoch: int, *,
               timeout: float = 30.0, poll_interval: float = 0.02,
               max_rounds: int = 4) -> tuple:
    """Agree on the survivor view for a shrink.

    Each participating rank writes ``view_<epoch>_<rank>.json`` with its
    proposed alive set and waits for a view from every member of that set.
    All identical -> the first observer publishes ``decision_<epoch>.json``
    (O_EXCL) and everyone returns the agreed tuple.  Mismatched views are
    intersected and re-voted at epoch+1; members that never produce a view
    within ``timeout`` (a cascading death mid-agreement) are dropped from
    the next round's proposal.  Bounded by ``max_rounds``."""
    d = gen_dir(coord_dir, generation)
    proposed = sorted(int(r) for r in proposed)
    rank = int(rank)
    if rank not in proposed:
        raise AgreementError(f"rank {rank} proposing a view without itself")
    for _ in range(max_rounds):
        dec_path = os.path.join(d, f"decision_{epoch}.json")
        _write_atomic(os.path.join(d, f"view_{epoch}_{rank}.json"),
                      json.dumps({"rank": rank, "alive": proposed}))
        deadline = time.time() + timeout
        while True:
            dec = _read_json(dec_path)
            if dec is not None:
                return tuple(dec["survivors"])
            views = {}
            for r in proposed:
                v = _read_json(os.path.join(d, f"view_{epoch}_{r}.json"))
                if v is not None:
                    views[r] = tuple(sorted(v["alive"]))
            if len(views) == len(proposed):
                if len(set(views.values())) == 1:
                    agreed = views[rank]
                    _write_excl_json(dec_path, {
                        "survivors": list(agreed), "epoch": epoch,
                        "by": rank, "t": time.time()})
                    dec = _read_json(dec_path)
                    return tuple(dec["survivors"]) if dec else agreed
                # diverging proposals: intersect, re-vote at epoch + 1
                common = set(proposed)
                for v in views.values():
                    common &= set(v)
                proposed = sorted(common)
                break
            if time.time() > deadline:
                # non-responders are themselves dead: drop them and re-vote
                proposed = sorted(set(views) & set(proposed) | {rank})
                break
            time.sleep(poll_interval)
        epoch += 1
        if rank not in proposed or len(proposed) == 0:
            raise AgreementError("agreement collapsed to an empty view")
    raise AgreementError(f"no agreement after {max_rounds} rounds")


def read_decision(coord_dir: str, generation: int) -> Optional[dict]:
    """Latest published shrink decision of a generation, if any."""
    d = os.path.join(coord_dir, f"gen_{generation}")
    best = None
    try:
        names = os.listdir(d)
    except OSError:
        return None
    for name in names:
        if name.startswith("decision_") and name.endswith(".json"):
            obj = _read_json(os.path.join(d, name))
            if obj is not None and (best is None or
                                    obj["epoch"] > best["epoch"]):
                best = obj
    return best


# ---------------------------------------------------------------------------
# restart policy + typed fault error
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """Bounds on the supervisor's coordinated-restart loop.

    ``max_restarts`` caps shrink/restart events across the whole run;
    restarts back off exponentially (``backoff_base * 2**(n-1)`` capped at
    ``backoff_max``); a faulted rank is quarantined and may rejoin after
    ``rejoin_after`` generations (None = never) unless it has faulted
    ``flap_limit`` times (a flapping rank is quarantined permanently);
    shrinking below ``min_world`` ranks raises :class:`MeshFaultError`
    (the degraded-mode floor)."""

    max_restarts: int = 3
    backoff_base: float = 0.5
    backoff_max: float = 30.0
    min_world: int = 1
    rejoin_after: Optional[int] = None
    flap_limit: int = 2

    def backoff(self, restarts: int) -> float:
        if restarts <= 0:
            return 0.0
        return min(self.backoff_base * (2.0 ** (restarts - 1)),
                   self.backoff_max)

    def next_ranks(self, survivors: Sequence[int], generation: int,
                   fault_history: dict) -> tuple:
        """Ranks of generation ``generation + 1``: the survivors plus any
        quarantined rank whose quarantine expired (``rejoin_after``
        generations since its last fault) and that is not flapping.
        ``fault_history``: rank -> list of generations it faulted in."""
        ranks = set(int(r) for r in survivors)
        if self.rejoin_after is not None:
            for r, gens in fault_history.items():
                if int(r) in ranks or len(gens) >= self.flap_limit:
                    continue
                if generation + 1 - max(gens) >= self.rejoin_after:
                    ranks.add(int(r))
        return tuple(sorted(ranks))


@dataclasses.dataclass
class ProcFaultReport:
    """Structured account of one detected process fault (the §14 analogue
    of the in-process ladder's FaultReport)."""

    generation: int
    epoch: Optional[int]            # barrier epoch the fault was caught at
    dead: tuple                     # ranks that exited / were SIGKILLed
    hung: tuple                     # ranks alive but heartbeat-stale
    world_before: int
    world_after: int
    restore_step: Optional[int]     # checkpoint step the survivors restored
    detected_by: object             # "supervisor" or a rank id
    detect_seconds: Optional[float] = None   # injection -> detection
    restore_seconds: Optional[float] = None  # detection -> survivors ready
    first_step_seconds: Optional[float] = None  # ready -> first step done
    reason: str = ""

    def describe(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        t = [f"gen {self.generation}: dead={list(self.dead)} "
             f"hung={list(self.hung)} world {self.world_before}->"
             f"{self.world_after} restore_step={self.restore_step} "
             f"detected_by={self.detected_by}"]
        if self.detect_seconds is not None:
            t.append(f"detect={self.detect_seconds:.2f}s")
        if self.restore_seconds is not None:
            t.append(f"restore={self.restore_seconds:.2f}s")
        if self.reason:
            t.append(self.reason)
        return " ".join(t)


class MeshFaultError(RuntimeError):
    """Raised when the restart policy is exhausted (max restarts, degraded
    floor, or supervisor wall clock); carries the structured fault
    history."""

    def __init__(self, reason: str, faults: Sequence[ProcFaultReport] = ()):
        lines = [reason] + [f"  {f}" for f in faults]
        super().__init__("\n".join(lines))
        self.reason = reason
        self.faults = tuple(faults)
