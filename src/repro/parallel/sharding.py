"""Sharding rules: map parameter/activation names onto the (pod, data, model) mesh.

Scheme (DESIGN.md §6):
  * DP/FSDP: batch over ('pod', 'data'); parameters sharded over 'data'
    (and 'pod' too — full FSDP — whenever the dim divides);
  * TP: attention heads / FFN hidden / vocab over 'model';
  * EP: MoE experts over 'model';
  * SP: decode KV caches sequence-sharded over 'model' when kv-heads don't
    divide the model axis.

Everything degrades gracefully: if a dim does not divide the axis size the
spec falls back to replication on that dim (never an error at lowering).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


BATCH_AXES = ("pod", "data")     # logical data-parallel axes
FSDP_AXIS = "data"
TP_AXIS = "model"


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _maybe(mesh: Mesh, dim: int, axes) -> Optional[object]:
    """Return ``axes`` if ``dim`` divides their product, else None."""
    return axes if dim % axis_size(mesh, axes) == 0 else None


def param_spec(mesh: Mesh, name: str, shape: tuple[int, ...]) -> P:
    """PartitionSpec for a parameter by convention on its name/rank.

    Conventions (leaf path name contains):
      'embed'   (V, D): vocab over TP, D over FSDP
      'w_q','w_in','w_gate'  (D, X): D over FSDP, X over TP
      'w_o','w_out'          (X, D): X over TP, D over FSDP
      'experts'              (E, D, F) / (E, F, D): E over TP(=EP), D over FSDP
      bias/scale 1-D: replicated

    Parameters living under a scanned layer stack ('groups/...') carry a
    leading (L,) dim: the rule applies to shape[1:], L stays unsharded.
    """
    if "groups" in name and len(shape) >= 2:
        inner = param_spec(mesh, name.replace("groups", "_g_"), shape[1:])
        return P(None, *inner)
    dp = batch_axes(mesh)
    if len(shape) <= 1:
        return P()
    if "router" in name:
        return P(*([None] * len(shape)))
    if "experts" in name:
        # EP over model on the expert dim + FSDP on dim 1 over every data
        # axis that divides (the MoE body all-gathers dim 1 per layer).
        e_ax = _maybe(mesh, shape[0], TP_AXIS)
        d_ax = _maybe(mesh, shape[1], dp) or _maybe(mesh, shape[1], FSDP_AXIS)
        return P(e_ax, d_ax, *([None] * (len(shape) - 2)))
    if "embed" in name or "lm_head" in name:
        v_ax = _maybe(mesh, shape[0], TP_AXIS)
        d_ax = _maybe(mesh, shape[1], FSDP_AXIS)
        return P(v_ax, d_ax)
    if any(k in name for k in ("w_o", "w_out", "out_proj")):
        x_ax = _maybe(mesh, shape[0], TP_AXIS)
        d_ax = _maybe(mesh, shape[1], FSDP_AXIS)
        return P(x_ax, d_ax)
    if len(shape) == 2:
        # default input-proj convention (D, X)
        d_ax = _maybe(mesh, shape[0], FSDP_AXIS)
        x_ax = _maybe(mesh, shape[1], TP_AXIS)
        return P(d_ax, x_ax)
    return P(*([None] * len(shape)))


def param_shardings(mesh: Mesh, params) -> object:
    """Pytree of NamedShardings matching ``params`` (by flattened key path)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        specs.append(NamedSharding(mesh, param_spec(mesh, name, leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_spec(mesh: Mesh, rank: int = 2) -> P:
    """Tokens/labels (B, T, ...) -> batch over dp axes."""
    return P(batch_axes(mesh), *([None] * (rank - 1)))


def activation_spec(mesh: Mesh) -> P:
    """Hidden states (B, T, D)."""
    return P(batch_axes(mesh), None, None)


def kv_cache_spec(mesh: Mesh, num_kv_heads: int, batch: int) -> P:
    """KV cache (B, Hkv, S, d): shard B over dp; Hkv over TP if it divides,
    else shard the sequence dim over TP (SP decode, flash-decoding style)."""
    dp = batch_axes(mesh)
    b_ax = dp if batch % axis_size(mesh, dp) == 0 else None
    if num_kv_heads % axis_size(mesh, TP_AXIS) == 0:
        return P(b_ax, TP_AXIS, None, None)
    return P(b_ax, None, TP_AXIS, None)


def constrain(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that is a no-op on 1-device meshes."""
    if mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
