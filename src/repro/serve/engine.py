"""Serving: prefill + decode steps and a continuous-batching engine.

``prefill_step`` and ``decode_step`` are the functions the dry-run lowers
for the *_32k / long_500k cells.  The KV cache is sharded per
parallel/sharding.kv_cache_spec (SP decode when kv-heads don't divide the
model axis); SSM/RG-LRU states are bounded, enabling the 500k cell.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..models.config import ModelConfig
from ..models.transformer import forward, init_cache, unembed


def prefill_step(params, tokens, caches, cfg: ModelConfig,
                 mesh: Optional[Mesh] = None, patch_embeds=None,
                 q_chunk: int = 512):
    """Process the prompt, fill caches.  Returns (last_logits, caches)."""
    h, caches = forward(params, tokens, cfg, mesh, patch_embeds=patch_embeds,
                        caches=caches, pos_scalar=None, q_chunk=q_chunk,
                        remat=True)
    logits = unembed(params, h[:, -1:], cfg)[:, 0]
    return logits, caches


def decode_step(params, token, pos, caches, cfg: ModelConfig,
                mesh: Optional[Mesh] = None):
    """One token for every sequence.  token: (B, 1) int32; pos: scalar int32.

    (Uniform position across the batch — slot-aligned continuous batching;
    per-sequence offsets live in the engine's bookkeeping.)
    """
    h, caches = forward(params, token, cfg, mesh, caches=caches,
                        pos_scalar=pos, remat=False)
    logits = unembed(params, h, cfg)[:, 0]
    return logits, caches


def make_serve_fns(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                   q_chunk: int = 512):
    pre = jax.jit(functools.partial(prefill_step, cfg=cfg, mesh=mesh,
                                    q_chunk=q_chunk))
    dec = jax.jit(functools.partial(decode_step, cfg=cfg, mesh=mesh))
    return pre, dec


# ---------------------------------------------------------------------------
# Minimal batch-decode engine (example/server use)
# ---------------------------------------------------------------------------


class ServeEngine:
    """Batched greedy decoding: :meth:`step_all` is the ONLY serving API.

    An earlier scaffold carried a slot/``submit``/``_admit`` continuous-
    batching surface that ``step_all`` never consulted (it builds a fresh
    cache per call); those dead members are gone.  Admission control,
    request queues, and batching policy live in the FMM serving engine
    (``serve/fmm_service.FmmServiceEngine``) — a continuous-batching LM
    decode loop would be a separate subsystem, not a half-wired attribute
    set here.
    """

    def __init__(self, params, cfg: ModelConfig, batch_slots: int,
                 max_len: int, mesh: Optional[Mesh] = None):
        self.params = params
        self.cfg = cfg
        self.mesh = mesh
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.prefill_fn, self.decode_fn = make_serve_fns(cfg, mesh)

    def step_all(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        """Convenience batch API: greedy-decode ``max_new`` tokens for a
        full batch of equal-length prompts.  Returns (B, max_new)."""
        B, T = prompts.shape
        caches = init_cache(self.cfg, B, self.max_len)
        logits, caches = self.prefill_fn(self.params, jnp.asarray(prompts), caches)
        outs = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for t in range(max_new):
            outs.append(np.asarray(tok))
            logits, caches = self.decode_fn(self.params, tok[:, None],
                                            jnp.int32(T + t), caches)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return np.stack(outs, axis=1)
