"""FMM-as-a-service: a batched multi-tenant evaluation engine.

Clients submit :class:`FmmJob`s — (charges, optional probe grid, equation
name, depth/expansion order or ``"auto"``, RK2 step count for trajectory
sessions) — and the engine turns the single-tenant library underneath
(PRs 1-9) into a serving path (DESIGN.md §15):

* **price** — every job is priced a priori with the paper's Eq 13-15 work
  model (:func:`~repro.core.fmm.flops_estimate`) plus the plan-level
  communication model (:func:`~repro.core.plan.plan_comm_cost`) BEFORE any
  device work is scheduled.  A job whose total modeled work exceeds
  ``ServiceBudget.max_job_flops`` is rejected with a typed
  :class:`JobRejected` carrying its :class:`JobPrice`; a job that would
  overflow the in-flight queue budget is deferred and promoted as budget
  frees up.
* **batch** — independent one-shot jobs are bin-packed into shape buckets
  (:class:`BucketKey`: tree level, pow2-rounded slot capacity, expansion
  order, equation, core size, probe capacity) and executed as ONE device
  program via ``vmap`` over a padded batch axis
  (:func:`batched_fmm_eval` / :func:`batched_fmm_eval_targets`).  The
  bucket key IS the jit cache key, so steady-state serving compiles once
  per bucket and the retrace detector (PR 8) stays quiet; the padding
  waste the dense batch pays is accounted with
  :func:`~repro.core.cost_model.batch_padding_stats`.
* **amortize** — host-built artifacts (``build_tree`` results, ``SlabPlan``
  / ``BlockPlan`` objects) live in a keyed :class:`ArtifactCache` with
  hit/miss counters, shared between the one-shot lanes and the trajectory
  sessions (``VortexStepper(artifact_cache=...)``): repeated evaluations
  over the same charge set, session restarts, and ``from_checkpoint``
  restores skip the rebuild.
* **stream** — RK2 trajectory sessions yield their steps through
  :meth:`TrajectorySession.stream`, a bounded prefetch generator that
  computes step k+1 while the client consumes step k, reusing PR 7's
  substep pipelining inside each step.

Everything crossing the service boundary is device-put before it can
reach a jit entry (``jnp.stack`` / ``jnp.asarray``): raw numpy leaves key
a SEPARATE jit cache entry from device arrays of identical aval (the
PR 8 restore foot-gun), which on a serving path would mean one silent
recompile per client request.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import queue as queue_mod
import threading
import time
from collections import defaultdict
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..core import equations as eqs
from ..core import parallel_fmm as pf
from ..core.cost_model import ModelParams, array_digest, batch_padding_stats
from ..core.fmm import fmm_evaluate, flops_estimate
from ..core.plan import plan_comm_cost, plan_from_counts
from ..core.quadtree import (Tree, build_tree, choose_level,
                             gather_particle_values)
from ..core.stepper import VortexStepper

__all__ = ["FmmJob", "JobPrice", "JobRejected", "JobResult", "ServiceBudget",
           "ArtifactCache", "BucketKey", "FmmServiceEngine",
           "TrajectorySession", "batched_fmm_eval", "batched_fmm_eval_targets",
           "ensure_device", "stack_trees", "TRACE_ENTRY_POINTS"]


# ---------------------------------------------------------------------------
# Jobs, prices, budgets
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FmmJob:
    """One client request.

    ``positions``/``strength`` are the charge set (unit-square coords, raw
    strengths — circulation for vortex/tracer, charge for laplace).
    ``targets`` is an optional (T, 2) probe set evaluated passively against
    the sources.  ``level``/``p`` accept ``"auto"`` (cost-model defaults)
    or explicit ints.  ``steps > 0`` requests an RK2 trajectory session
    (vortex only) instead of a one-shot evaluation.
    """

    positions: np.ndarray
    strength: np.ndarray
    equation: str = "vortex"
    targets: Optional[np.ndarray] = None
    level: int | str = "auto"
    p: int | str = "auto"
    steps: int = 0
    dt: float = 0.005
    sigma: float = 0.05
    tenant: str = "default"


@dataclasses.dataclass(frozen=True)
class JobPrice:
    """Eq 13-15 price computed at admission — BEFORE any device work."""

    flops_per_eval: float     # modeled work of one FMM evaluation
    total_flops: float        # x 2 evaluations/step x steps for sessions
    comm_cost: float          # plan_comm_cost bottleneck (0 off-mesh)
    level: int
    p: int
    slots: int
    steps: int
    lane: str                 # "batched" | "sharded" | "session"


class JobRejected(RuntimeError):
    """Typed admission failure; ``.price`` carries the cost-model price."""

    def __init__(self, message: str, price: JobPrice):
        super().__init__(message)
        self.price = price


@dataclasses.dataclass(frozen=True)
class ServiceBudget:
    """Admission-control knobs, all in Eq 13-15 flop units.

    ``max_job_flops`` rejects a single oversized job outright;
    ``max_queue_flops`` bounds the admitted-but-unexecuted backlog (excess
    jobs are deferred, then promoted as the queue drains — a deferred job
    is always promoted once the queue is empty, so admission never
    deadlocks); ``shard_threshold_flops`` routes jobs at least this
    expensive to the sharded latency lane when a mesh is attached.
    """

    max_job_flops: float = 5e9
    max_queue_flops: float = 2e10
    shard_threshold_flops: float = 1e8


@dataclasses.dataclass
class JobResult:
    job_id: int
    out: np.ndarray           # (N,) / (N, nout) at sources, or at targets
    price: JobPrice
    lane: str
    latency_s: float
    batch_capacity: int = 1


# ---------------------------------------------------------------------------
# Artifact cache (trees, plans) — keyed, counted, shared across tenants
# ---------------------------------------------------------------------------


class ArtifactCache:
    """Keyed store for host-built artifacts with hit/miss counters.

    Keys are value tuples (array digests + static config); values are
    whatever the builder returns (``(Tree, TreeIndex)`` pairs, plan
    objects).  The stepper consumes this duck-typed (``get(key, builder)``)
    so ``core`` never imports ``serve``.
    """

    def __init__(self):
        self._store: dict = {}
        self.hits = 0
        self.misses = 0

    def get(self, key, builder):
        try:
            value = self._store[key]
        except KeyError:
            self.misses += 1
            value = self._store[key] = builder()
            return value
        self.hits += 1
        return value

    def __contains__(self, key) -> bool:
        return key in self._store

    def __len__(self) -> int:
        return len(self._store)

    def clear(self):
        self._store.clear()

    def stats(self) -> dict:
        return {"entries": len(self._store), "hits": self.hits,
                "misses": self.misses}


# ---------------------------------------------------------------------------
# Shape buckets and the batched jit entry points
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """Static identity of one batched jit entry (the bin-packing target).

    Slot capacities are rounded up to powers of two at admission, so jobs
    of nearby sizes share one compiled program instead of keying a fresh
    entry per exact occupancy.  ``sigma`` participates because the tree's
    core size is static metadata; ``tgt_slots == 0`` means no probe grid.
    """

    level: int
    slots: int
    p: int
    equation: str
    sigma: float
    tgt_slots: int = 0


def ensure_device(tree: Tree) -> Tree:
    """Device-put every array leaf of a tree at the service boundary.

    Numpy leaves key a separate jit cache entry from device arrays of the
    same aval, so client-supplied or checkpoint-restored host buffers would
    silently recompile every entry point on first touch."""
    return Tree(z=jnp.asarray(tree.z), q=jnp.asarray(tree.q),
                mask=jnp.asarray(tree.mask), level=tree.level,
                sigma=tree.sigma)


def stack_trees(trees: list, capacity: int):
    """Stack per-job leaf grids into (B, n, n, s) batch arrays, padding to
    ``capacity`` with empty (all-masked-out) trees.  ``jnp.stack`` returns
    device arrays whatever the inputs were — the batch axis is also the
    numpy-leaf guard."""
    t0 = trees[0]
    pad = capacity - len(trees)
    z = jnp.stack([t.z for t in trees] + [jnp.zeros_like(t0.z)] * pad)
    q = jnp.stack([t.q for t in trees] + [jnp.zeros_like(t0.q)] * pad)
    m = jnp.stack([t.mask for t in trees] + [jnp.zeros_like(t0.mask)] * pad)
    return z, q, m


@functools.partial(jax.jit, static_argnames=("level", "sigma", "p", "eq"))
def batched_fmm_eval(z, q, mask, *, level: int, sigma: float, p: int, eq):
    """One device program evaluating a whole bucket: vmap of the serial
    FMM over the padded batch axis.  Inputs are (B, n, n, s); output is
    (B, n, n, s[, nout]).  Padded batch rows carry all-False masks, so
    every kernel's occupancy/r2 guards zero them for free."""
    def one(z1, q1, m1):
        tree = Tree(z=z1, q=q1, mask=m1, level=level, sigma=sigma)
        return fmm_evaluate(tree, p, eq=eq)
    return jax.vmap(one)(z, q, mask)


@functools.partial(jax.jit, static_argnames=("level", "sigma", "p", "eq"))
def batched_fmm_eval_targets(z, q, mask, tz, tmask, *, level: int,
                             sigma: float, p: int, eq):
    """Probe-grid variant: passive targets (B, n, n, st) evaluated against
    the sources; output is per TARGET slot, (B, n, n, st[, nout])."""
    def one(z1, q1, m1, tz1, tm1):
        src = Tree(z=z1, q=q1, mask=m1, level=level, sigma=sigma)
        tgt = Tree(z=tz1, q=jnp.zeros_like(tz1), mask=tm1, level=level,
                   sigma=sigma)
        return fmm_evaluate(src, p, eq=eq, targets=tgt)
    return jax.vmap(one)(z, q, mask, tz, tmask)


# Named jitted entry points for the static-analysis layer (PR 8): the
# contract/retrace sections lower and monitor these directly.
TRACE_ENTRY_POINTS = {
    "batched_fmm_eval": batched_fmm_eval,
    "batched_fmm_eval_targets": batched_fmm_eval_targets,
}


def batched_cache_entries() -> int:
    """Total live jit cache entries across the batched entry points — the
    steady-state count the trace-contract row pins."""
    return int(batched_fmm_eval._cache_size()
               + batched_fmm_eval_targets._cache_size())


# ---------------------------------------------------------------------------
# Engine internals
# ---------------------------------------------------------------------------


def _pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def _leaf_counts(positions, level: int) -> np.ndarray:
    n = 1 << level
    ij = np.clip((np.asarray(positions, np.float64) * n).astype(np.int64),
                 0, n - 1)
    return np.bincount(ij[:, 1] * n + ij[:, 0],
                       minlength=n * n).reshape(n, n)


@dataclasses.dataclass
class _Admitted:
    """Internal record of an admitted (or deferred) one-shot job."""

    job_id: int
    job: FmmJob
    spec: eqs.EquationSpec
    price: JobPrice
    bucket: BucketKey
    tree_key: tuple
    tgt_key: Optional[tuple]
    submitted: float


class TrajectorySession:
    """One tenant's live RK2 trajectory: a stepper plus its cache keys.

    The engine owns the heavy artifacts through the shared
    :class:`ArtifactCache`; the session holds keys and re-resolves them
    every step (:meth:`FmmServiceEngine.step_session`), so steady-state
    stepping is a pure cache hit and an evicted/restored session
    repopulates from live state instead of rebuilding."""

    def __init__(self, session_id: int, stepper: VortexStepper,
                 engine: "FmmServiceEngine", price: JobPrice):
        self.id = session_id
        self.stepper = stepper
        self.engine = engine
        self.price = price

    def step(self):
        return self.engine.step_session(self.id)

    def particles(self):
        return self.stepper.particles()

    def stream(self, steps: int, prefetch: bool = True):
        """Yield ``(step_index, positions, StepRecord)`` per RK2 step.

        With ``prefetch`` (default) a worker thread runs the device steps
        ahead through a bounded queue: step k+1 computes while the client
        consumes step k — the serving-side face of PR 7's pipelining.
        Worker exceptions re-raise in the consumer."""
        if not prefetch:
            for i in range(steps):
                rec = self.step()
                pos, _ = self.particles()
                yield i, pos, rec
            return
        out: queue_mod.Queue = queue_mod.Queue(maxsize=2)

        def worker():
            try:
                for i in range(steps):
                    rec = self.step()
                    pos, _ = self.particles()
                    out.put((i, pos, rec))
                out.put(None)
            except BaseException as exc:       # noqa: BLE001 — re-raised
                out.put(exc)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                item = out.get()
                if item is None:
                    break
                if isinstance(item, BaseException):
                    raise item
                yield item
        finally:
            t.join(timeout=60.0)


class FmmServiceEngine:
    """Multi-tenant FMM evaluation engine (job lifecycle in DESIGN.md §15).

    One-shot jobs flow submit -> price -> admit/defer/reject -> bucket ->
    batch -> execute -> result; ``steps > 0`` jobs open a
    :class:`TrajectorySession` instead.  ``mesh=None`` serves everything
    through the vmap-batched serial lane; with a mesh attached, jobs
    priced at or above ``budget.shard_threshold_flops`` (and all sessions)
    run through the sharded driver/stepper on their own execution plan.
    """

    def __init__(self, *, budget: Optional[ServiceBudget] = None, mesh=None,
                 mesh_axis: str = "data",
                 batch_capacities: tuple = (1, 2, 4, 8),
                 target_per_box: float = 4.0, use_kernels: bool = False,
                 cache: Optional[ArtifactCache] = None,
                 session_kwargs: Optional[dict] = None):
        self.budget = budget or ServiceBudget()
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.batch_capacities = tuple(sorted(set(batch_capacities)))
        self.target_per_box = float(target_per_box)
        self.use_kernels = bool(use_kernels)
        self.cache = cache if cache is not None else ArtifactCache()
        self.session_kwargs = dict(session_kwargs or {})
        self.queue: list[_Admitted] = []
        self.deferred: list[_Admitted] = []
        self.results: dict[int, JobResult] = {}
        self.sessions: dict[int, TrajectorySession] = {}
        self._next_id = 0
        self._latencies: dict[str, list] = defaultdict(list)
        self.counters = {"submitted": 0, "admitted": 0, "rejected": 0,
                         "deferred": 0, "promoted": 0, "batches": 0,
                         "batched_jobs": 0, "sharded_jobs": 0,
                         "sessions": 0, "session_steps": 0,
                         "padding_paid_flops": 0.0,
                         "padding_useful_flops": 0.0}

    # -- admission: price first, schedule second ----------------------------

    @property
    def nparts(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.mesh_axis]

    def _shard_min_level(self) -> int:
        return max(2, math.ceil(math.log2(max(2 * self.nparts, 4))))

    def _resolve_oneshot(self, job: FmmJob, spec: eqs.EquationSpec):
        """Resolve (level, p, slots, tgt_slots, counts) and the lane."""
        n = len(job.positions)
        p = spec.default_p if job.p == "auto" else int(job.p)
        level = (max(choose_level(n, self.target_per_box), 2)
                 if job.level == "auto" else int(job.level))
        lane = "batched"
        if self.mesh is not None:
            probe = flops_estimate(level, max(int(_leaf_counts(
                job.positions, level).max()), 1), p, eq=spec)["total"]
            if probe >= self.budget.shard_threshold_flops:
                lane = "sharded"
                level = max(level, self._shard_min_level())
        counts = _leaf_counts(job.positions, level)
        slots = _pow2(max(int(counts.max()), 2))
        tgt_slots = 0
        if job.targets is not None:
            tgt_slots = _pow2(max(int(_leaf_counts(job.targets,
                                                   level).max()), 2))
        return level, p, slots, tgt_slots, counts, lane

    def _price_oneshot(self, job, spec, level, p, slots, tgt_slots, counts,
                       lane) -> JobPrice:
        census = flops_estimate(level, slots, p, eq=spec)
        per_eval = census["total"]
        if tgt_slots:
            # passive probes add their own L2P + P2P at target capacity
            tc = flops_estimate(level, tgt_slots, p, eq=spec)
            per_eval += tc["l2p"] + tc["p2p"]
        comm = 0.0
        if lane == "sharded":
            params = ModelParams(level=level,
                                 cut=max(min(level - 1, 4), 1), p=p,
                                 slots=slots, nout=spec.nout)
            plan = self.cache.get(
                self._plan_key(counts, params),
                lambda: plan_from_counts(counts, params, self.nparts,
                                         method="model"))
            comm = float(plan_comm_cost(plan, counts, params).max())
        return JobPrice(flops_per_eval=float(per_eval),
                        total_flops=float(per_eval), comm_cost=comm,
                        level=level, p=p, slots=slots, steps=0, lane=lane)

    def _plan_key(self, counts, params) -> tuple:
        return ("plan", array_digest(counts), params, self.nparts,
                "model", None, True, True)

    def _tree_key(self, positions, strength, level, slots, sigma,
                  charge_scale) -> tuple:
        return ("tree", array_digest(positions, strength), level, slots,
                float(sigma), complex(charge_scale))

    def _price_session(self, job: FmmJob, spec: eqs.EquationSpec) -> JobPrice:
        """Price a trajectory session with the STEPPER's own sizing rules
        (target_per_box=8, 2x slot headroom, mesh minimum level), so the
        plan priced here is the very plan the stepper pulls from the
        shared cache at open."""
        n = len(job.positions)
        p = spec.default_p if job.p == "auto" else int(job.p)
        level = max(choose_level(n, 8.0), 2,
                    math.ceil(math.log2(max(2 * self.nparts, 4))))
        counts = _leaf_counts(job.positions, level)
        slots = max(int(math.ceil(int(counts.max()) * 2.0)), 2)
        params = ModelParams(level=level, cut=max(min(level - 1, 4), 1),
                             p=p, slots=slots)
        per_eval = float(flops_estimate(level, slots, p, eq=spec)["total"])
        comm = 0.0
        if self.mesh is not None:
            plan = self.cache.get(
                self._plan_key(counts, params),
                lambda: plan_from_counts(counts, params, self.nparts,
                                         method="model"))
            comm = float(plan_comm_cost(plan, counts, params).max())
        return JobPrice(flops_per_eval=per_eval,
                        total_flops=per_eval * 2.0 * job.steps,
                        comm_cost=comm, level=level, p=p, slots=slots,
                        steps=job.steps, lane="session")

    def _queued_flops(self) -> float:
        return sum(r.price.total_flops for r in self.queue)

    def submit(self, job: FmmJob) -> int:
        """Price, then admit/defer/reject.  Returns a job id (one-shots:
        claim the result after :meth:`drain`; sessions: pass to
        :meth:`session` / :meth:`step_session`).  Raises
        :class:`JobRejected` when the Eq 13-15 price blows the budget."""
        self.counters["submitted"] += 1
        spec = eqs.resolve_job_spec(job.equation,
                                    have_targets=job.targets is not None,
                                    steps=job.steps)
        if job.steps:
            price = self._price_session(job, spec)
        else:
            res = self._resolve_oneshot(job, spec)
            price = self._price_oneshot(job, spec, *res)
        if price.total_flops > self.budget.max_job_flops:
            self.counters["rejected"] += 1
            raise JobRejected(
                f"job priced at {price.total_flops:.3g} modeled flops "
                f"(level={price.level}, p={price.p}, slots={price.slots}, "
                f"steps={price.steps}) exceeds max_job_flops "
                f"{self.budget.max_job_flops:.3g}", price)
        self._next_id += 1
        jid = self._next_id
        if job.steps:
            self._open_session(jid, job, spec, price)
            return jid
        level, p, slots, tgt_slots, counts, lane = res
        rec = _Admitted(
            job_id=jid, job=job, spec=spec, price=price,
            bucket=BucketKey(level=level, slots=slots, p=p, equation=spec.name,
                             sigma=float(job.sigma), tgt_slots=tgt_slots),
            tree_key=self._tree_key(job.positions, job.strength, level, slots,
                                    job.sigma, spec.charge_scale),
            tgt_key=None if job.targets is None else self._tree_key(
                job.targets, np.zeros(len(job.targets)), level, tgt_slots,
                job.sigma, 0.0),
            submitted=time.perf_counter())
        if self.queue and \
                self._queued_flops() + price.total_flops \
                > self.budget.max_queue_flops:
            self.deferred.append(rec)
            self.counters["deferred"] += 1
        else:
            self.queue.append(rec)
            self.counters["admitted"] += 1
        return jid

    # -- execution: bucket -> batch -> one device program --------------------

    def _build_job_tree(self, rec: _Admitted):
        return build_tree(rec.job.positions, rec.job.strength,
                          rec.bucket.level, rec.job.sigma,
                          slots=rec.bucket.slots,
                          charge_scale=rec.spec.charge_scale)

    def _build_target_tree(self, rec: _Admitted):
        return build_tree(rec.job.targets, np.zeros(len(rec.job.targets)),
                          rec.bucket.level, rec.job.sigma,
                          slots=rec.bucket.tgt_slots)

    @staticmethod
    def _gather(out_slot: np.ndarray, index, nout: int) -> np.ndarray:
        if nout == 1:
            return gather_particle_values(out_slot, index)
        return np.stack([gather_particle_values(out_slot[..., c], index)
                         for c in range(nout)], axis=-1)

    def _finish(self, rec: _Admitted, out: np.ndarray, capacity: int):
        latency = time.perf_counter() - rec.submitted
        self._latencies[rec.price.lane].append(latency)
        self.results[rec.job_id] = JobResult(
            job_id=rec.job_id, out=out, price=rec.price,
            lane=rec.price.lane, latency_s=latency, batch_capacity=capacity)

    def _run_bucket(self, bucket: BucketKey, recs: list):
        spec = eqs.get_equation(bucket.equation)
        capacity = next(c for c in self.batch_capacities if c >= len(recs))
        pairs = [self.cache.get(r.tree_key,
                                functools.partial(self._build_job_tree, r))
                 for r in recs]
        z, q, m = stack_trees([t for t, _ in pairs], capacity)
        if bucket.tgt_slots:
            tpairs = [self.cache.get(r.tgt_key, functools.partial(
                self._build_target_tree, r)) for r in recs]
            tz, _, tm = stack_trees([t for t, _ in tpairs], capacity)
            out = batched_fmm_eval_targets(
                z, q, m, tz, tm, level=bucket.level, sigma=bucket.sigma,
                p=bucket.p, eq=spec)
            indices = [i for _, i in tpairs]
        else:
            out = batched_fmm_eval(z, q, m, level=bucket.level,
                                   sigma=bucket.sigma, p=bucket.p, eq=spec)
            indices = [i for _, i in pairs]
        out = np.asarray(out)                 # one host pull per batch
        for b, rec in enumerate(recs):
            self._finish(rec, self._gather(out[b], indices[b], spec.nout),
                         capacity)
        self.counters["batches"] += 1
        self.counters["batched_jobs"] += len(recs)
        pad = batch_padding_stats(recs[0].price.flops_per_eval, len(recs),
                                  capacity)
        self.counters["padding_paid_flops"] += pad["paid"]
        self.counters["padding_useful_flops"] += pad["useful"]

    def _run_sharded(self, rec: _Admitted):
        spec = rec.spec
        tree, index = self.cache.get(
            rec.tree_key, functools.partial(self._build_job_tree, rec))
        counts = index.counts
        params = ModelParams(level=rec.bucket.level,
                             cut=max(min(rec.bucket.level - 1, 4), 1),
                             p=rec.bucket.p, slots=rec.bucket.slots,
                             nout=spec.nout)
        plan = self.cache.get(
            self._plan_key(counts, params),
            lambda: plan_from_counts(counts, params, self.nparts,
                                     method="model"))
        targets = None
        out_index = index
        if rec.tgt_key is not None:
            targets, out_index = self.cache.get(
                rec.tgt_key, functools.partial(self._build_target_tree, rec))
            targets = ensure_device(targets)
        out = pf.parallel_fmm_evaluate(
            ensure_device(tree), rec.bucket.p, mesh=self.mesh,
            mesh_axis=self.mesh_axis, use_kernels=self.use_kernels,
            plan=plan, eq=spec, targets=targets)
        self._finish(rec, self._gather(np.asarray(out), out_index,
                                       spec.nout), 1)
        self.counters["sharded_jobs"] += 1

    def run_once(self) -> list:
        """Execute the admitted queue (one pass), then promote deferred
        jobs into the freed budget.  Returns completed job ids."""
        batch, self.queue = self.queue, []
        done = []
        groups: dict[BucketKey, list] = defaultdict(list)
        for rec in batch:
            if rec.price.lane == "sharded":
                self._run_sharded(rec)
                done.append(rec.job_id)
            else:
                groups[rec.bucket].append(rec)
        cap_max = self.batch_capacities[-1]
        for bucket, recs in groups.items():
            for i in range(0, len(recs), cap_max):
                chunk = recs[i:i + cap_max]
                self._run_bucket(bucket, chunk)
                done.extend(r.job_id for r in chunk)
        still = []
        for rec in self.deferred:
            if not self.queue or self._queued_flops() + rec.price.total_flops \
                    <= self.budget.max_queue_flops:
                self.queue.append(rec)
                self.counters["promoted"] += 1
                self.counters["admitted"] += 1
            else:
                still.append(rec)
        self.deferred = still
        return done

    def drain(self) -> dict:
        """Run until the queue and deferred list are empty; returns the
        results dict (job id -> :class:`JobResult`)."""
        while self.queue or self.deferred:
            self.run_once()
        return self.results

    def result(self, job_id: int) -> JobResult:
        return self.results[job_id]

    # -- trajectory sessions -------------------------------------------------

    def _open_session(self, sid: int, job: FmmJob, spec, price: JobPrice):
        kwargs = dict(p=price.p, dt=job.dt, mesh=self.mesh,
                      mesh_axis=self.mesh_axis, use_kernels=self.use_kernels,
                      artifact_cache=self.cache)
        kwargs.update(self.session_kwargs)
        stepper = VortexStepper(job.positions, job.strength, job.sigma,
                                **kwargs)
        self.sessions[sid] = TrajectorySession(sid, stepper, self, price)
        self.counters["sessions"] += 1

    def session(self, session_id: int) -> TrajectorySession:
        return self.sessions[session_id]

    def restore_session(self, directory: str, **from_checkpoint_kwargs) -> int:
        """Reopen a session from its checkpoint directory through the
        SHARED artifact cache: the restored plan is pulled by value key (a
        hit when this engine built it), restored arrays are device-put by
        the stepper (``_adopt_restored``), so restore triggers zero
        retraces of the step entry point."""
        stepper = VortexStepper.from_checkpoint(
            directory, mesh=self.mesh, mesh_axis=self.mesh_axis,
            artifact_cache=self.cache, **from_checkpoint_kwargs)
        price = JobPrice(
            flops_per_eval=float(flops_estimate(
                stepper.params.level, stepper.params.slots,
                stepper.p)["total"]),
            total_flops=0.0, comm_cost=0.0, level=stepper.params.level,
            p=stepper.p, slots=stepper.params.slots, steps=0, lane="session")
        self._next_id += 1
        sid = self._next_id
        self.sessions[sid] = TrajectorySession(sid, stepper, self, price)
        return sid

    def step_session(self, session_id: int):
        """Advance one RK2 step, re-resolving the session's heavy
        artifacts from the shared cache first (the cache is the owner;
        the session only holds keys).  Steady state: pure hits; after an
        eviction the live artifacts re-register under the same keys."""
        ses = self.sessions[session_id]
        stepper = ses.stepper
        for key, live in stepper.artifact_keys().items():
            self.cache.get(key, lambda value=live: value)
        record = stepper.step()
        self.counters["session_steps"] += 1
        self._latencies["session"].append(record.seconds)
        return record

    # -- observability -------------------------------------------------------

    def stats(self) -> dict:
        lat = {}
        for lane, xs in self._latencies.items():
            a = np.asarray(xs, dtype=np.float64)
            lat[lane] = {"n": int(a.size),
                         "p50_ms": float(np.percentile(a, 50) * 1e3),
                         "p99_ms": float(np.percentile(a, 99) * 1e3)}
        paid = self.counters["padding_paid_flops"]
        useful = self.counters["padding_useful_flops"]
        return {**self.counters, "cache": self.cache.stats(),
                "latency": lat,
                "batch_utilization": (useful / paid) if paid else 1.0,
                "jit_entries": batched_cache_entries()}
