"""Training loop: jitted step, fault tolerance, straggler rebalancing.

Fault-tolerance posture (DESIGN.md §6):
  * checkpoint every ``ckpt_every`` steps (atomic, async, keep-last-k),
    data-pipeline state included -> deterministic resume;
  * restore-on-start; elastic restore re-shards onto whatever mesh the
    relaunch provides (checkpoint/manager.py);
  * per-step wall times feed the cost-model rebalancer
    (core/partition.rebalance) — the paper's dynamic load balancing doubles
    as straggler mitigation for MoE expert placement.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..checkpoint.manager import CheckpointManager
from ..data.pipeline import PipelineState, advance, make_inputs
from ..models.config import ModelConfig, ShapeConfig
from ..models.transformer import forward, init_params, lm_loss
from ..models import moe as moe_mod
from ..optim.adamw import AdamWConfig, apply_updates, init_state
from ..parallel import sharding as shd


def make_loss_fn(cfg: ModelConfig, mesh: Optional[Mesh], *, q_chunk: int = 512,
                 loss_chunk: int = 256, remat: bool = True):
    def loss_fn(params, batch):
        h, _ = forward(params, batch["tokens"], cfg, mesh,
                       patch_embeds=batch.get("patch_embeds"),
                       q_chunk=q_chunk, remat=remat)
        if cfg.num_patches:
            h = h[:, cfg.num_patches:]      # loss over text positions only
        return lm_loss(params, h, batch["labels"], cfg, chunk=loss_chunk)
    return loss_fn


def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh], opt_cfg: AdamWConfig,
                    num_microbatches: int = 1, **loss_kw):
    """num_microbatches > 1 = gradient accumulation: the global batch is
    split on the batch dim and scanned, so live activations scale 1/n —
    how the ≥35B train cells fit HBM (see EXPERIMENTS.md §Dry-run)."""
    loss_fn = make_loss_fn(cfg, mesh, **loss_kw)

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            n = num_microbatches
            mb = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

            def body(carry, mbatch):
                loss_acc, gacc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                gacc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gacc, g)
                return (loss_acc + l, gacc), None

            zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), zeros), mb)
            loss = loss / n
            grads = jax.tree.map(lambda g: g / n, grads)
        new_params, new_opt, metrics = apply_updates(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def probe_expert_load(params, batch, cfg: ModelConfig) -> np.ndarray:
    """Router token counts for layer-0 experts (drives expert placement)."""
    assert cfg.moe is not None
    emb = params["embed"][batch["tokens"]]
    p0 = jax.tree.map(lambda x: x[0], params["groups"][0][0])  # layer 0 slice
    from ..models.layers import rms_norm
    x = rms_norm(emb, p0["ln1"], cfg.rms_eps)
    logits = x.reshape(-1, cfg.d_model) @ p0["moe"]["router"]
    _, idx = jax.lax.top_k(logits, cfg.moe.top_k)
    counts = jnp.bincount(idx.reshape(-1), length=cfg.moe.num_experts)
    return np.asarray(counts)


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    rebalance_every: int = 0     # 0 = off; >0 = expert-placement refresh cadence


class Trainer:
    """End-to-end driver used by examples/train_lm.py and the tests."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 opt_cfg: Optional[AdamWConfig] = None,
                 tcfg: Optional[TrainerConfig] = None,
                 mesh: Optional[Mesh] = None, remat: bool = True):
        self.cfg = cfg
        self.shape = shape
        self.opt_cfg = opt_cfg or AdamWConfig(total_steps=(tcfg or TrainerConfig()).steps)
        self.tcfg = tcfg or TrainerConfig()
        self.mesh = mesh
        self.ckpt = CheckpointManager(self.tcfg.ckpt_dir, keep=self.tcfg.keep)
        self.pipeline = PipelineState(seed=self.tcfg.seed, step=0)
        self.step_times: list[float] = []
        self.expert_assignment: Optional[np.ndarray] = None

        key = jax.random.PRNGKey(self.tcfg.seed)
        self.params = init_params(key, cfg)
        self.opt_state = init_state(self.params, self.opt_cfg)
        if mesh is not None and mesh.size > 1:
            pshard = shd.param_shardings(mesh, self.params)
            self.params = jax.tree.map(jax.device_put, self.params, pshard)
            oshard = {"mu": pshard, "nu": pshard,
                      "step": NamedSharding(mesh, P())}
            self.opt_state = {
                "mu": jax.tree.map(jax.device_put, self.opt_state["mu"], pshard),
                "nu": jax.tree.map(jax.device_put, self.opt_state["nu"], pshard),
                "step": self.opt_state["step"],
            }
        self._step_fn = jax.jit(make_train_step(cfg, mesh, self.opt_cfg,
                                                remat=remat))
        self.metrics_log: list[dict] = []

    # -- fault tolerance ----------------------------------------------------

    def try_restore(self) -> bool:
        out, meta = self.ckpt.restore({"params": self.params, "opt": self.opt_state})
        if out is None:
            return False
        self.params, self.opt_state = out["params"], out["opt"]
        self.pipeline = PipelineState(seed=meta["pipeline_seed"],
                                      step=meta["pipeline_step"])
        return True

    def save(self, step: int):
        self.ckpt.save(step, {"params": self.params, "opt": self.opt_state},
                       meta={"pipeline_seed": self.pipeline.seed,
                             "pipeline_step": self.pipeline.step})

    # -- main loop ----------------------------------------------------------

    def run(self, steps: Optional[int] = None) -> list[dict]:
        steps = steps or self.tcfg.steps
        start = int(self.opt_state["step"])
        for i in range(start, steps):
            batch = make_inputs(self.pipeline, self.cfg, self.shape)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            self.pipeline = advance(self.pipeline)
            metrics["step"] = i
            metrics["time_s"] = dt
            self.metrics_log.append(metrics)
            if self.tcfg.ckpt_every and (i + 1) % self.tcfg.ckpt_every == 0:
                self.save(i + 1)
            if (self.tcfg.rebalance_every and self.cfg.moe is not None
                    and (i + 1) % self.tcfg.rebalance_every == 0):
                self.refresh_expert_placement(batch)
        self.ckpt.wait()
        return self.metrics_log

    # -- paper's technique: dynamic load balancing for MoE -------------------

    def refresh_expert_placement(self, batch):
        counts = probe_expert_load(self.params, batch, self.cfg)
        coact = np.zeros((self.cfg.moe.num_experts,) * 2)
        ranks = (self.mesh.shape["model"]
                 if self.mesh is not None and "model" in self.mesh.axis_names else 1)
        if ranks > 1:
            assign = moe_mod.expert_placement(counts, coact, ranks)
            self.expert_assignment = moe_mod.placement_permutation(assign, ranks)
        return counts
