"""Unit pins for the static-analysis subsystem (src/repro/analysis).

Four groups:

* ``launch/hlo_analysis`` hardening — the while-body trip-count
  regression (``ModuleStats.add`` must multiply collective COUNTS, not
  just bytes) and the ``collective_issue_depths`` corner cases
  (tuple-result collectives, function-scoped SSA ids, same-line
  def+use chains, compute on the use line);
* the trace-contract catalog — every contract class gets at least one
  planted-violation negative test via ``Lowered.from_text``, plus a
  real positive control (a genuinely donated jit buffer must trip
  ``not_donated``);
* the AST lint rules — planted good/bad snippets through
  ``lint_source``, and the real tree must lint clean;
* the retrace monitor — miss/hit accounting against a live jit cache,
  argument blame on an unexpected miss, and the host-resident-leaf tag
  that names the restore-without-device-put foot-gun.
"""
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import contracts as C
from repro.analysis import lint as L
from repro.analysis.retrace import (RetraceMonitor, RetraceViolation,
                                    diff_signatures, signature_of)
from repro.launch.hlo_analysis import analyze_hlo, collective_issue_depths

SRC_REPRO = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


# ---------------------------------------------------------------------------
# hlo_analysis: while-body trip multiplication of collective counts
# ---------------------------------------------------------------------------

# Optimized-HLO skeleton: one collective-permute inside a while body whose
# backend_config pins a known trip count of 7.  The pre-fix ModuleStats.add
# scaled bytes by the trip count but added counts unscaled, so this module
# reported count == 1.
_WHILE_HLO = """\
HloModule planted_while

%wcond (c.1: (s32[])) -> pred[] {
  %c.1 = (s32[]) parameter(0)
  ROOT %lt = pred[] constant(1)
}

%wbody (b.1: (s32[])) -> (s32[]) {
  %b.1 = (s32[]) parameter(0)
  %cp = f32[8]{0} collective-permute(%b.1), source_target_pairs={{0,1},{1,0}}
  ROOT %t = (s32[]) tuple(%b.1)
}

ENTRY %main (a.1: s32[]) -> (s32[]) {
  %a.1 = s32[] parameter(0)
  ROOT %w = (s32[]) while((s32[]) %a.1), condition=%wcond, body=%wbody, backend_config={"known_trip_count":{"n":"7"}}
}
"""


def test_while_body_collective_count_multiplied_by_trip():
    r = analyze_hlo(_WHILE_HLO)
    assert r["count"] == 7, r
    assert r["count_per_kind"] == {"collective-permute": 7}, r
    assert r["count_by_op"]["collective"] == 7, r


def test_while_body_collective_count_real_scan():
    """A real jitted scan with a known trip count: the compiled module's
    per-kind count must equal the trip count x per-iteration instances."""
    trips = 5

    def step(x, _):
        return x * 2.0 + 1.0, None

    fn = jax.jit(lambda x: jax.lax.scan(step, x, None, length=trips)[0])
    txt = fn.lower(jnp.ones((8,), jnp.float32)).compile().as_text()
    r = analyze_hlo(txt)
    # no collectives here — but the while body's materializing ops must be
    # scaled: op_count is trip-multiplied the same way coll_counts is
    assert r["count"] == 0
    assert all(v == int(v) for v in r["count_by_op"].values())


# ---------------------------------------------------------------------------
# hlo_analysis: collective_issue_depths corner cases
# ---------------------------------------------------------------------------

_DEPTH_TUPLE = """\
module {
  func.func public @main(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %0:2 = "stablehlo.all_gather"(%arg0, %arg0) : (tensor<4xf32>, tensor<4xf32>) -> (tensor<4xf32>, tensor<4xf32>)
    %1 = stablehlo.dot_general %arg0, %arg0 : tensor<4xf32>
    %2 = stablehlo.dot_general %1, %1 : tensor<4xf32>
    %3 = stablehlo.add %0#1, %2 : tensor<4xf32>
    return %3 : tensor<4xf32>
  }
}
"""


def test_issue_depth_tuple_result_indexed_use():
    d = collective_issue_depths(_DEPTH_TUPLE)
    assert d["all_gather"] == [2], d


_DEPTH_SCOPED = """\
module {
  func.func private @a(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %5 = "stablehlo.all_gather"(%arg0) : (tensor<4xf32>) -> tensor<4xf32>
    %6 = stablehlo.dot_general %arg0, %arg0 : tensor<4xf32>
    return %6 : tensor<4xf32>
  }
  func.func private @b(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %4 = stablehlo.dot_general %arg0, %arg0 : tensor<4xf32>
    %5 = stablehlo.dot_general %4, %4 : tensor<4xf32>
    %6 = stablehlo.add %5, %5 : tensor<4xf32>
    return %6 : tensor<4xf32>
  }
}
"""


def test_issue_depth_ssa_ids_are_function_scoped():
    """@a's dead %5 window ends at @a's closing brace; @b's unrelated %5
    must neither terminate it early nor extend it with @b's dots."""
    d = collective_issue_depths(_DEPTH_SCOPED)
    assert d["all_gather"] == [1], d


_DEPTH_CHAIN = """\
module {
  func.func public @main(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %5 = "stablehlo.all_gather"(%arg0) : (tensor<4xf32>) -> tensor<4xf32>
    %6 = stablehlo.dot_general %arg0, %arg0 : tensor<4xf32>
    %7 = "stablehlo.collective_permute"(%5) : (tensor<4xf32>) -> tensor<4xf32>
    %8 = stablehlo.dot_general %6, %6 : tensor<4xf32>
    %9 = stablehlo.add %7, %8 : tensor<4xf32>
    return %9 : tensor<4xf32>
  }
}
"""


def test_issue_depth_same_line_def_and_use_chain():
    """%5 is consumed on the line that DEFINES %7: %5's window must close
    there (depth 1) and %7's window opens after it (depth 1)."""
    d = collective_issue_depths(_DEPTH_CHAIN)
    assert d["all_gather"] == [1], d
    assert d["collective_permute"] == [1], d


_DEPTH_USE_LINE = """\
module {
  func.func public @main(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %0 = "stablehlo.all_gather"(%arg0) : (tensor<4xf32>) -> tensor<4xf32>
    %1 = stablehlo.dot_general %0, %arg0 : tensor<4xf32>
    return %1 : tensor<4xf32>
  }
}
"""


def test_issue_depth_compute_on_use_line_not_counted():
    d = collective_issue_depths(_DEPTH_USE_LINE)
    assert d["all_gather"] == [0], d


# ---------------------------------------------------------------------------
# trace contracts: one planted violation per contract class
# ---------------------------------------------------------------------------


def _planted(text, ir="stablehlo"):
    return C.Lowered.from_text(text, ir=ir, label="planted")


def test_no_staging_dim_planted_violation_and_pass():
    bad = _planted("  %x = f32[256,680]{1,0} copy(%p)\n", ir="hlo")
    good = _planted("  %x = f32[256,40]{1,0} copy(%p)\n", ir="hlo")
    (r_bad,) = C.evaluate(bad, [C.no_staging_dim(680)])
    (r_good,) = C.evaluate(good, [C.no_staging_dim(680)])
    assert not r_bad.ok and "680" in r_bad.detail
    assert r_good.ok


_TWO_PERMUTES_HLO = """\
HloModule planted_counts

ENTRY %main (a.1: f32[8]) -> f32[8] {
  %a.1 = f32[8]{0} parameter(0)
  %c1 = f32[8]{0} collective-permute(%a.1), source_target_pairs={{0,1},{1,0}}
  ROOT %c2 = f32[8]{0} collective-permute(%c1), source_target_pairs={{0,1},{1,0}}
}
"""


def test_collective_count_planted_violation_and_pass():
    low = _planted(_TWO_PERMUTES_HLO, ir="hlo")
    (r_bad,) = C.evaluate(low, [C.collective_count("collective-permute", 4)])
    (r_good,) = C.evaluate(low, [C.collective_count("collective-permute", 2)])
    (r_band,) = C.evaluate(low, [C.collective_count("collective-permute",
                                                    max_count=3)])
    assert not r_bad.ok and "x2" in r_bad.detail
    assert r_good.ok and r_band.ok


def test_min_issue_depth_planted_violation():
    (r,) = C.evaluate(_planted(_DEPTH_USE_LINE),
                      [C.min_issue_depth("all_gather", 8)])
    assert not r.ok and "depth 0" in r.detail
    (r2,) = C.evaluate(_planted(_DEPTH_TUPLE),
                       [C.min_issue_depth("all_gather", 2)])
    assert r2.ok


@pytest.mark.parametrize("factory,needle", [
    (C.no_f64_upcast, "f64[4]"),
    (C.sentinel_free, "is_finite"),
    (C.no_host_callback, "stablehlo.custom_call @xla_python_cpu_callback"),
    (C.not_donated, "tf.aliasing_output = 0"),
])
def test_absence_contracts_planted_violations(factory, needle):
    bad = _planted(f"  %0 = {needle} something : tensor<4xf32>\n")
    good = _planted("  %0 = stablehlo.add %a, %b : tensor<4xf32>\n")
    (r_bad,) = C.evaluate(bad, [factory()])
    (r_good,) = C.evaluate(good, [factory()])
    assert not r_bad.ok, (factory, r_bad)
    # failure messages show the offending line, not an offset
    assert needle.split()[0].lstrip("%") in r_bad.detail or \
        needle in r_bad.detail
    assert r_good.ok


def test_not_donated_real_positive_control():
    """A genuinely donated input must trip the contract: jit with
    donate_argnums marks the buffer with tf.aliasing_output."""
    donating = jax.jit(lambda x: x * 2.0, donate_argnums=0)
    low = C.Lowered(donating, jnp.ones((8,), jnp.float32), label="donating")
    (r,) = C.evaluate(low, [C.not_donated("x")])
    assert not r.ok, r
    assert "aliasing_output" in r.detail


def test_fewer_bytes_pair_planted():
    small = _planted("ENTRY %main (p: f32[4]) -> f32[4] {\n"
                     "  %p = f32[4]{0} parameter(0)\n"
                     "  ROOT %c = f32[4]{0} copy(%p)\n}\n", ir="hlo")
    big = _planted("ENTRY %main (p: f32[4]) -> f32[1000] {\n"
                   "  %p = f32[1000]{0} parameter(0)\n"
                   "  ROOT %c = f32[1000]{0} copy(%p)\n}\n", ir="hlo")
    (r_ok,) = C.evaluate(small, [C.fewer_bytes("small", "big")],
                         pair_with=big)
    (r_bad,) = C.evaluate(big, [C.fewer_bytes("big", "small")],
                          pair_with=small)
    assert r_ok.ok and not r_bad.ok
    assert "ratio" in r_ok.detail


def test_issue_depth_grows_pair_planted():
    deep, shallow = _planted(_DEPTH_CHAIN), _planted(_DEPTH_USE_LINE)
    # _DEPTH_CHAIN: ag depth 1, 1 permute; _DEPTH_USE_LINE: ag depth 0,
    # 0 permutes -> depth grows but the permute-count guard differs
    (r_guard,) = C.evaluate(deep, [C.issue_depth_grows("all_gather")],
                            pair_with=shallow)
    assert not r_guard.ok, r_guard
    # same module on both sides: depth does not strictly grow -> violation
    (r_flat,) = C.evaluate(deep, [C.issue_depth_grows("all_gather")],
                           pair_with=deep)
    assert not r_flat.ok
    # planted pass: deep vs a permute-matched shallow module
    shallow_matched = _planted(_DEPTH_CHAIN.replace(
        "%6 = stablehlo.dot_general %arg0, %arg0 : tensor<4xf32>",
        "%6 = stablehlo.add %arg0, %arg0 : tensor<4xf32>"))
    (r_ok,) = C.evaluate(deep, [C.issue_depth_grows("all_gather")],
                         pair_with=shallow_matched)
    assert r_ok.ok, r_ok


def test_pair_contract_requires_pair_with():
    with pytest.raises(ValueError):
        C.evaluate(_planted(_DEPTH_CHAIN), [C.issue_depth_grows()])


def test_lowered_lazy_real_entry_and_labels():
    low = C.Lowered(jax.jit(lambda x: x + 1.0),
                    jnp.ones((4,), jnp.float32), label="inc")
    results = C.evaluate(low, [C.no_f64_upcast(), C.sentinel_free()])
    assert all(r.ok for r in results)
    assert all(r.target == "inc" for r in results)
    assert C.violations(results) == []
    assert "OK" in C.format_results(results)


# ---------------------------------------------------------------------------
# lint rules: planted snippets
# ---------------------------------------------------------------------------


def test_lint_equation_branch_rule():
    bad = ("def drive(eq, x, kind):\n"
           "    if eq.name == kind:\n"
           "        return x\n"
           "    if kind == 'vortex':\n"
           "        return 2 * x\n"
           "    if isinstance(eq, LaplaceEquation):\n"
           "        return -x\n")
    findings = L.lint_source(bad, path="core/fmm.py")
    assert len(findings) == 3, findings
    assert any("eq.name" in f.message for f in findings)
    assert any("'vortex'" in f.message for f in findings)
    assert any("isinstance" in f.message for f in findings)
    # the rule is scoped to the slab-path files
    assert L.lint_source(bad, path="core/stepper.py") == []
    good = "def drive(eq, x):\n    return eq.p2p(x)\n"
    assert L.lint_source(good, path="core/fmm.py") == []


def test_lint_host_sync_rule_reaches_through_helpers():
    bad = ("import jax\n"
           "def helper(x):\n"
           "    return x.sum().item()\n"
           "@jax.jit\n"
           "def f(x):\n"
           "    return helper(x)\n")
    findings = L.lint_source(bad, path="core/x.py")
    assert len(findings) == 1 and ".item()" in findings[0].message
    # the same sync in a host-side function NOT reachable from a jit root
    # is legitimate (drivers read device scalars)
    ok = ("import jax\n"
          "def host_driver(x):\n"
          "    return float(jax.device_put(x).sum())\n")
    host = L.lint_source(ok, path="core/x.py")
    assert host == [], host


def test_lint_host_sync_rule_cast_of_traced_expr():
    bad = ("import jax\nimport jax.numpy as jnp\n"
           "@jax.jit\n"
           "def f(x):\n"
           "    return float(jnp.sum(x))\n")
    findings = L.lint_source(bad, path="core/x.py")
    assert len(findings) == 1 and "float()" in findings[0].message
    # np.asarray on static host data and jnp.asarray on device are fine
    ok = ("import jax\nimport numpy as np\nimport jax.numpy as jnp\n"
          "@jax.jit\n"
          "def f(x, plan):\n"
          "    rows = np.asarray(plan.rows)\n"
          "    return jnp.asarray(jnp.sum(x))\n")
    assert L.lint_source(ok, path="core/x.py") == []


def test_lint_static_args_rule():
    bad = ("import functools, jax\n"
           "@functools.partial(jax.jit, static_argnames=('p', 'mesh'))\n"
           "def f(x, p):\n"
           "    return x * p\n")
    findings = L.lint_source(bad, path="core/x.py")
    assert len(findings) == 1 and "'mesh'" in findings[0].message
    mutable = ("import functools, jax\n"
               "@functools.partial(jax.jit, static_argnames=('faults',))\n"
               "def f(x, faults=[]):\n"
               "    return x\n")
    findings = L.lint_source(mutable, path="core/x.py")
    assert len(findings) == 1 and "unhashable" in findings[0].message
    good = ("import functools, jax\n"
            "@functools.partial(jax.jit, static_argnames=('p',))\n"
            "def f(x, p=4):\n"
            "    return x * p\n")
    assert L.lint_source(good, path="core/x.py") == []


def test_lint_nondeterminism_rule():
    bad = ("import jax, time\nimport numpy as np\n"
           "@jax.jit\n"
           "def f(x):\n"
           "    return x * time.time() + np.random.normal()\n")
    findings = L.lint_source(bad, path="core/x.py")
    assert len(findings) == 2, findings
    assert any("time()" in f.message for f in findings)
    assert any("np.random.normal()" in f.message for f in findings)
    # wall-clock reads in host-side benchmark code are fine
    ok = "import time\ndef bench():\n    return time.perf_counter()\n"
    assert L.lint_source(ok, path="benchmarks/x.py") == []


def test_lint_rebuild_tree_rule():
    bad_arity = "t = rebuild_tree(x)\n"
    bad_discard = "t, aux, _ = rebuild_tree(x)\n"
    good = "t, aux, ok = rebuild_tree(x)\n"
    multiline = "t, aux, ok = rebuild_tree(\n    x,\n    level=3)\n"
    assert len(L.lint_source(bad_arity, path="a.py")) == 1
    assert len(L.lint_source(bad_discard, path="a.py")) == 1
    assert L.lint_source(good, path="a.py") == []
    # the AST form catches multi-line calls the old regex could not see
    assert L.lint_source(multiline, path="a.py") == []


def test_repo_lints_clean():
    findings = L.run_lint(SRC_REPRO)
    assert findings == [], L.format_findings(findings)


# ---------------------------------------------------------------------------
# retrace monitor
# ---------------------------------------------------------------------------


def test_retrace_monitor_hit_miss_and_blame():
    fn = jax.jit(lambda x, k: x * k, static_argnames=("k",))
    mon = RetraceMonitor(fn, "toy")
    x = jnp.ones((4,), jnp.float32)
    mon.expect_miss(x, k=2, step="cold")
    mon.expect_hit(x, k=2, step="steady")
    # changed static arg: a legitimate miss, blame names it
    mon.call(x, k=3, expect="miss", step="retune")
    assert mon.ok
    mon.call(x, k=4, expect="hit", step="surprise", strict=False)
    assert not mon.ok
    bad = [e for e in mon.events if not e.ok]
    assert len(bad) == 1 and bad[0].step == "surprise"
    assert any("'k'" in b or "k]" in b for b in bad[0].blame), bad[0].blame


def test_retrace_monitor_strict_raises_with_blame():
    fn = jax.jit(lambda x: x + 1.0)
    mon = RetraceMonitor(fn, "toy2")
    mon.expect_miss(jnp.ones((4,), jnp.float32), step="cold")
    with pytest.raises(RetraceViolation) as exc:
        mon.expect_hit(jnp.ones((8,), jnp.float32), step="reshape")
    assert "reshape" in str(exc.value)
    assert "(4,)" in str(exc.value) and "(8,)" in str(exc.value)


def test_retrace_monitor_host_leaf_tag():
    """Numpy leaves key a SEPARATE jit cache entry from device arrays of
    identical aval — the blame must name the host-resident argument (the
    restore-without-device-put foot-gun run_session pins)."""
    fn = jax.jit(lambda x: x * 2.0)
    mon = RetraceMonitor(fn, "toy3")
    dev = jnp.ones((4,), jnp.float32)
    mon.expect_miss(dev, step="cold")
    mon.call(np.ones((4,), np.float32), expect="hit",
             step="host-restore", strict=False)
    ev = mon.events[-1]
    assert ev.got == "miss"
    assert any(":host" in b for b in ev.blame), ev.blame


def test_retrace_monitor_rejects_unjitted():
    with pytest.raises(TypeError):
        RetraceMonitor(lambda x: x)


def test_signature_diff_names_paths():
    a = signature_of((jnp.ones((4,)),), {"p": 4})
    b = signature_of((jnp.ones((8,)),), {"p": 5})
    diffs = diff_signatures(a, b)
    assert len(diffs) == 2
    assert any("'p'" in d and "4 -> 5" in d for d in diffs), diffs
    assert diff_signatures(None, a) == ["<first call>"]
    same = diff_signatures(a, a)
    assert "identical" in same[0]
