"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + finiteness; serve path (prefill + decode) per family."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_smoke_config, lm_archs
from repro.data.pipeline import PipelineState, make_inputs
from repro.models.config import SHAPES, ShapeConfig, shape_applicable
from repro.models.transformer import forward, init_cache, init_params, lm_loss, unembed
from repro.optim.adamw import AdamWConfig, apply_updates, init_state
from repro.serve.engine import make_serve_fns
from repro.train.loop import make_train_step

SMOKE_SHAPE = ShapeConfig("smoke", "train", seq_len=64, global_batch=2)


def _smoke_inputs(cfg):
    state = PipelineState(seed=0, step=0)
    return make_inputs(state, cfg, SMOKE_SHAPE)


@pytest.mark.parametrize("arch", lm_archs())
def test_forward_smoke(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _smoke_inputs(cfg)
    h, _ = forward(params, batch["tokens"], cfg, None,
                   patch_embeds=batch.get("patch_embeds"), q_chunk=32)
    assert h.shape == (2, SMOKE_SHAPE.seq_len, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()
    logits = unembed(params, h[:, -1:], cfg)
    assert logits.shape == (2, 1, cfg.vocab)


@pytest.mark.parametrize("arch", lm_archs())
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_state(params)
    step = jax.jit(make_train_step(cfg, None, AdamWConfig(total_steps=10),
                                   q_chunk=32, loss_chunk=32))
    batch = _smoke_inputs(cfg)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["yi_6b", "recurrentgemma_2b", "mamba2_13b",
                                  "granite_moe_1b_a400m"])
def test_serve_prefill_decode(arch):
    """Prefill a prompt then greedy-decode; decode must be consistent with
    teacher-forced forward over the same tokens."""
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, T_prompt, n_new = 2, 32, 4
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, T_prompt)), jnp.int32)
    caches = init_cache(cfg, B, max_len=64)
    pre, dec = make_serve_fns(cfg, None, q_chunk=16)
    logits, caches = pre(params, prompt, caches)
    assert logits.shape == (B, cfg.vocab)
    toks = [jnp.argmax(logits, -1).astype(jnp.int32)]
    for t in range(n_new):
        logits, caches = dec(params, toks[-1][:, None], jnp.int32(T_prompt + t), caches)
        assert np.isfinite(np.asarray(logits)).all()
        toks.append(jnp.argmax(logits, -1).astype(jnp.int32))

    # consistency: teacher-forced forward over [prompt + generated[:-1]]
    full = jnp.concatenate([prompt] + [t[:, None] for t in toks[:-1]], axis=1)
    h, _ = forward(params, full, cfg, None, q_chunk=16)
    ref_logits = unembed(params, h[:, -1:], cfg)[:, 0]
    ref_next = jnp.argmax(ref_logits, -1)
    np.testing.assert_array_equal(np.asarray(ref_next), np.asarray(toks[-1]))


def test_param_counts_match_published_sizes():
    """Analytic counts from the assigned spec sheets.

    Nominal marketing names differ where the assigned sheet deviates from
    the shipped model (e.g. command-r assigned GQA kv=8 vs published MHA;
    codeqwen/qwen1.5 assigned MHA).  Tolerances reflect that.
    """
    approx = {
        "qwen3_moe_235b_a22b": (235e9, 0.05),
        "command_r_35b": (35e9, 0.20),     # spec'd kv=8 trims vs published MHA
        "codeqwen15_7b": (7e9, 0.25),      # spec-sheet MHA computes to 8.2B
        "yi_6b": (6e9, 0.10),
        "qwen15_32b": (32e9, 0.15),
        "mamba2_13b": (1.3e9, 0.15),
        "granite_moe_1b_a400m": (1.3e9, 0.35),
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).param_count
        assert abs(n - target) / target < tol, (arch, n, target)
    # hand-checkable exact case: yi-6b
    yi = get_config("yi_6b")
    per_layer = (4096 * 4096 + 2 * 4096 * 512 + 4096 * 4096  # q, kv, o
                 + 3 * 4096 * 11008 + 2 * 4096)
    expect = 32 * per_layer + 2 * 64000 * 4096 + 4096
    assert yi.param_count == expect
    active = get_config("qwen3_moe_235b_a22b").active_param_count
    assert abs(active - 22e9) / 22e9 < 0.2, active


def test_shape_applicability_rules():
    assert shape_applicable(get_config("mamba2_13b"), SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("recurrentgemma_2b"), SHAPES["long_500k"])[0]
    for arch in ("yi_6b", "command_r_35b", "musicgen_large", "internvl2_26b"):
        ok, why = shape_applicable(get_config(arch), SHAPES["long_500k"])
        assert not ok and "full-attention" in why
    for arch in lm_archs():
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(arch), SHAPES[s])[0]


def test_petfmm_vortex_config_matches_paper():
    """The paper's own app config: N=765,625, level 10, cut 4, p=17 (§7.2)."""
    from repro.configs.registry import get_config, get_smoke_config
    c = get_config("petfmm_vortex")
    assert (c.num_particles, c.level, c.cut_level, c.p) == (765_625, 10, 4, 17)
    assert c.num_particles == 875 ** 2  # lattice side
    s = get_smoke_config("petfmm_vortex")
    assert s.level <= 5 and s.p <= 10
