"""CheckpointManager with FMM pytrees + the stepper's elastic restore.

Pins the crash-safety contract (a crash mid-save never corrupts the
previous checkpoint: LATEST is written last, after the atomic directory
rename), keep-last-k GC, complex/bool FMM array roundtrips, and
``VortexStepper.from_checkpoint`` restoring tree/payload BIT-EXACT onto a
different device count (the plan is rebuilt from the restored counts; the
arrays are device-count independent).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.stepper import VortexStepper
from repro.core.vortex import lamb_oseen_particles


def _fmm_trees(seed=0, n=8, s=4):
    rng = np.random.default_rng(seed)
    z = (rng.random((n, n, s)) + 1j * rng.random((n, n, s))).astype(
        np.complex64)
    q = (rng.standard_normal((n, n, s))
         + 1j * rng.standard_normal((n, n, s))).astype(np.complex64)
    mask = rng.random((n, n, s)) < 0.5
    return {"tree": {"z": z, "q": q, "mask": mask},
            "payload": {"r0": z * 2.0}}


def _templates(trees):
    import jax
    return jax.tree_util.tree_map(np.zeros_like, trees)


def test_fmm_pytree_roundtrip(tmp_path):
    trees = _fmm_trees()
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, trees, {"level": 3})
    out, meta = mgr.restore(_templates(trees), step=5)
    assert meta["step"] == 5 and meta["level"] == 3
    np.testing.assert_array_equal(out["tree"]["z"], trees["tree"]["z"])
    np.testing.assert_array_equal(out["tree"]["mask"], trees["tree"]["mask"])
    np.testing.assert_array_equal(out["payload"]["r0"],
                                  trees["payload"]["r0"])
    assert out["tree"]["z"].dtype == np.complex64
    assert mgr.load_meta(5)["level"] == 3
    assert mgr.load_meta()["step"] == 5


def test_crash_mid_save_leaves_latest_intact(tmp_path):
    trees = _fmm_trees()
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, trees, {"tag": "good"})

    # simulate a crash mid-save of step 2: npz files written, but the
    # process dies before the tmp-dir rename / LATEST update
    import repro.checkpoint.manager as M
    orig_rename = os.rename

    def crash(src, dst):
        raise RuntimeError("simulated crash before atomic rename")

    os.rename = crash
    try:
        with pytest.raises(RuntimeError, match="simulated crash"):
            mgr.save(2, _fmm_trees(seed=9), {"tag": "bad"})
    finally:
        os.rename = orig_rename

    assert mgr.latest_step() == 1
    assert mgr.all_steps() == [1]
    out, meta = mgr.restore(_templates(trees))
    assert meta["tag"] == "good"
    np.testing.assert_array_equal(out["tree"]["z"], trees["tree"]["z"])
    # a later successful save cleans up and moves LATEST forward
    mgr.save(3, trees, {"tag": "next"})
    assert mgr.latest_step() == 3


def test_async_save_error_surfaces(tmp_path, monkeypatch):
    """An exception in the async ``_write`` thread must NOT die silently:
    it re-raises on the next save()/wait() (satellite of DESIGN.md §14 —
    the shrink path restores from latest_step() and must be able to trust
    that saves that claimed to start actually landed)."""
    trees = _fmm_trees()
    mgr = CheckpointManager(str(tmp_path), async_save=True)

    def boom(*a, **k):
        raise OSError("disk full (simulated)")

    monkeypatch.setattr(np, "savez", boom)
    mgr.save(1, trees, None)           # returns; the failure is in-thread
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        mgr.wait()
    monkeypatch.undo()

    # the error also surfaces on the NEXT save (not just wait)
    monkeypatch.setattr(np, "savez", boom)
    mgr.save(2, trees, None)
    mgr._thread.join()      # let the failing write land while patched
    monkeypatch.undo()
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        mgr.save(3, trees, None)
    # once surfaced it is cleared: the pipeline keeps going
    mgr.save(4, trees, None)
    mgr.wait()
    assert mgr.latest_step() == 4


def test_latest_step_falls_back_when_latest_dangles(tmp_path):
    """LATEST pointing at a GC'd/missing directory (crash between GC and
    pointer update) must not strand restore: fall back to the newest
    complete step directory."""
    trees = _fmm_trees()
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, trees, {"tag": "one"})
    mgr.save(2, trees, {"tag": "two"})
    # simulate the referent vanishing out from under LATEST
    import shutil
    shutil.rmtree(tmp_path / "step_2")
    assert mgr.latest_step() == 1
    out, meta = mgr.restore(_templates(trees))
    assert meta["tag"] == "one"
    # corrupt LATEST content -> same fallback
    (tmp_path / "LATEST").write_text("not-a-step")
    assert mgr.latest_step() == 1
    # nothing restorable at all -> None, not an exception
    shutil.rmtree(tmp_path / "step_1")
    assert mgr.latest_step() is None


def test_commit_point_fsyncs(tmp_path, monkeypatch):
    """Durability pin: every payload file, meta.json, and LATEST are
    fsync'd at the commit point (power loss after the rename cannot lose
    LATEST's referent)."""
    synced = []
    real_fsync = os.fsync
    monkeypatch.setattr(os, "fsync", lambda fd: synced.append(fd)
                        or real_fsync(fd))
    trees = _fmm_trees()
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, trees, None)
    # 2 payload npz + meta.json + LATEST.tmp + >= 2 directory fsyncs
    assert len(synced) >= 6


def test_keep_last_k_gc(tmp_path):
    trees = _fmm_trees()
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    for s in range(1, 7):
        mgr.save(s, trees, None)
    assert mgr.all_steps() == [4, 5, 6]
    assert mgr.latest_step() == 6
    out, meta = mgr.restore(_templates(trees), step=4)
    assert meta["step"] == 4


def test_stepper_checkpoint_cycle(tmp_path):
    """Serial stepper: periodic snapshots land, rollback is bit-exact on
    tree AND payload, and from_checkpoint resumes the identical state."""
    pos, gamma, sigma = lamb_oseen_particles(24)
    r0 = np.hypot(pos[:, 0] - 0.5, pos[:, 1] - 0.5)
    st = VortexStepper(pos, gamma, sigma, p=6, dt=0.002,
                       payload={"r0": r0 + 0j},
                       checkpoint_dir=str(tmp_path), checkpoint_every=2)
    for _ in range(4):
        st.step()
    st._ckpt.wait()
    assert st._ckpt.all_steps() == [2, 4]
    z4 = np.asarray(st.tree.z).copy()
    p4 = np.asarray(st.payload["r0"]).copy()
    st.step()
    st.rollback()
    assert st.step_count == 4
    assert np.array_equal(np.asarray(st.tree.z), z4)
    assert np.array_equal(np.asarray(st.payload["r0"]), p4)

    st2 = VortexStepper.from_checkpoint(str(tmp_path))
    assert st2.step_count == 4
    assert st2.sigma == st.sigma and st2.dt == st.dt and st2.p == st.p
    assert np.array_equal(np.asarray(st2.tree.z), z4)
    assert np.array_equal(np.asarray(st2.payload["r0"]), p4)
    st2.step()     # the restored stepper keeps stepping


def test_elastic_restore_onto_different_device_count(tmp_path):
    """A checkpoint written by a 1-device stepper restores bit-exact onto a
    4-device mesh (and steps there); runs in a subprocess to force host
    devices without polluting this process."""
    body = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        import jax
        from jax.sharding import Mesh
        from repro.core.stepper import VortexStepper
        from repro.core.vortex import lamb_oseen_particles

        d = {str(tmp_path)!r}
        pos, gamma, sigma = lamb_oseen_particles(56)
        st = VortexStepper(pos, gamma, sigma, p=6, dt=0.002,
                           target_per_box=3.0,
                           checkpoint_dir=d, checkpoint_every=2)
        st.step(); st.step()
        st._ckpt.wait()
        z2 = np.asarray(st.tree.z)

        mesh = Mesh(np.array(jax.devices()), ("data",))
        st4 = VortexStepper.from_checkpoint(d, mesh=mesh)
        assert st4.nparts == 4
        assert st4.step_count == 2
        assert np.array_equal(np.asarray(st4.tree.z), z2), "not bit-exact"
        assert st4.plan.nparts == 4
        st4.step()
        print("elastic ok")
    """)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", body], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "elastic ok" in r.stdout
