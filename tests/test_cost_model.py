"""Paper §5 cost model + §4 partitioner behaviour."""
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core.partition import (
    build_subtree_graph, partition, partition_stats, rebalance,
    load_balance_metric, morton_order,
)


def _params(level=6, cut=3, p=17, slots=4):
    return cm.ModelParams(level=level, cut=cut, p=p, slots=slots)


def _uniform_counts(level, per_box=2):
    n = 1 << level
    return np.full((n, n), per_box, dtype=np.int64)


def _gaussian_counts(level, total=120_000, seed=0, sigma=0.15):
    """Asymmetric two-scale distribution: off-center cluster + background.

    (A centered Gaussian is accidentally balanced by Morton quadrants, which
    would flatter the uniform baseline; the paper's motivation is the
    *non-uniform, asymmetric* case.)
    """
    rng = np.random.default_rng(seed)
    n = 1 << level
    n_cluster = int(total * 0.7)
    cluster = rng.normal((0.3, 0.62), sigma, size=(n_cluster, 2))
    background = rng.uniform(0, 1, size=(total - n_cluster, 2))
    pos = np.concatenate([cluster, background]).clip(0.001, 0.999)
    ij = (pos * n).astype(int)
    counts = np.zeros((n, n), dtype=np.int64)
    np.add.at(counts, (ij[:, 1], ij[:, 0]), 1)
    return counts


def test_work_estimates_eq13_eq14():
    p = 17
    assert cm.work_nonleaf(p) == p * p * (2 * 4 + 27)
    w = cm.work_leaf(np.array([3.0]), p)
    assert w[0] == 2 * 3 * p + p * p * 27 + 9 * 9


def test_flops_estimate_consistent_with_folded_m2l():
    """fmm.flops_estimate's 27 M2L ops/box is what the parity-folded
    implementation actually performs: the folded (8, 4p, 4p) operator has
    exactly N_IL = 27 nonzero (p, p) blocks per target child, and the
    per-parity offset tables enumerate the same 27 interactions the mask
    table admits."""
    from repro.core import expansions as ex
    from repro.core.fmm import flops_estimate
    from repro.core.quadtree import M2L_PARITY_OFFSETS, M2L_VALIDITY

    p = 5
    W = ex.m2l_folded_operator(p)
    for c in range(4):                      # target child = parity class
        blocks = W[:, :, c * p:(c + 1) * p].reshape(8, 4, p, p)
        nonzero = int(sum(bool(np.any(blocks[d, s] != 0))
                          for d in range(8) for s in range(4)))
        assert nonzero == cm.N_IL == 27
    assert (M2L_VALIDITY.sum(axis=0) == cm.N_IL).all()
    for py in range(2):
        for px in range(2):
            assert len(M2L_PARITY_OFFSETS[py][px]) == cm.N_IL

    # the stage census uses the same count
    L, s, p = 5, 4, 17
    est = flops_estimate(L, s, p)
    expect = sum(4 ** l for l in range(2, L + 1)) * cm.N_IL * p * p * 6.0
    assert est["m2l"] == expect


def test_halo_constants_match_implementation():
    """Cost-model halo widths == what the slab implementations exchange."""
    from repro.core import expansions as ex
    from repro.kernels.p2p import P2P_HALO

    assert cm.M2L_HALO_ROWS == ex.M2L_HALO == 2
    assert cm.P2P_HALO_ROWS == P2P_HALO == 1
    # even-aligned even-length slabs must be coverable with exactly 2 rows
    ex.m2l_slab_geometry(rows=4, row0=0, halo=cm.M2L_HALO_ROWS)
    with pytest.raises(ValueError):
        ex.m2l_slab_geometry(rows=4, row0=1, halo=cm.M2L_HALO_ROWS)


def test_comm_halo_dense_volumes():
    params = _params(level=6, cut=3, p=17, slots=4)
    comm = cm.comm_halo_dense(params)
    expect_m2l = sum(2 * 2 * (2 ** n) * 17 * 16 for n in range(4, 7))
    assert comm["m2l"] == expect_m2l
    assert comm["p2p"] == 2 * 1 * (2 ** 6) * 4 * cm.PARTICLE_BYTES
    assert comm["total"] == comm["m2l"] + comm["p2p"]
    # parity folding: strictly less volume than the box-granularity ±3-row
    # exchange the unfolded interaction list implies
    unfolded_m2l = sum(2 * 3 * (2 ** n) * 17 * 16 for n in range(4, 7))
    assert comm["m2l"] < unfolded_m2l


def test_work_subtree_uniform_equal():
    params = _params()
    counts = _uniform_counts(params.level)
    w = cm.work_subtree(counts, params)
    assert w.shape == (4 ** params.cut,)
    # uniform distribution -> near-equal work (domain-edge boxes have a
    # smaller near-domain, a sub-0.1% effect the model captures correctly)
    assert w.max() / w.min() < 1.001


def test_comm_estimates_eq11_eq12():
    params = _params(level=10, cut=4, p=17)
    a = cm.alpha_comm(17)
    expect = sum(a * 2 ** (n - 4) * 4 for n in range(5, 11))
    assert cm.comm_lateral(params) == expect
    assert cm.comm_diagonal(params) == a * (10 - 4 - 1) * 4
    # lateral >> diagonal: faces exchange whole boundary rows, corners one box
    assert cm.comm_lateral(params) > 10 * cm.comm_diagonal(params)


def test_memory_tables():
    params = _params(level=10, cut=4, p=17, slots=1)
    mem = cm.memory_serial(params, n_particles=765_625)
    lam = cm.total_boxes(10)
    assert lam == (4 ** 11 - 1) // 3
    assert mem["multipole_coefficients"] == 16 * 17 * lam
    # paper's headline: 64M particles on 64 procs used < 1.01 GB/proc.
    per_proc = (sum(mem.values()) / 64 +
                sum(cm.memory_parallel(params, 64, 4 ** 4, 2 ** 5).values()))
    assert per_proc < 1.2e9

    par = cm.memory_parallel(params, n_procs=64, n_local_trees=256, n_boundary_boxes=32)
    assert par["interaction_send_overlap"] == 27 * 32 * 108


def test_partition_uniform_distribution_balanced():
    params = _params(level=6, cut=3)
    counts = _uniform_counts(params.level)
    g = build_subtree_graph(counts, params)
    for nparts in (4, 16):
        a = partition(g, nparts, method="model")
        assert load_balance_metric(g, a, nparts) > 0.95


@pytest.mark.parametrize("nparts", [4, 8, 16])
def test_partition_nonuniform_beats_uniform_baseline(nparts):
    """The paper's point: cost-model partition >> equal-count SFC split.

    The cut must be deep enough that no single subtree exceeds the per-part
    work target (paper §4: 'obtain more subtrees than processors').
    """
    params = _params(level=7, cut=4)
    counts = _gaussian_counts(params.level)
    g = build_subtree_graph(counts, params)
    base = partition(g, nparts, method="uniform-sfc")
    model = partition(g, nparts, method="model")
    lb_base = load_balance_metric(g, base, nparts)
    lb_model = load_balance_metric(g, model, nparts)
    assert lb_model > lb_base
    assert lb_model > 0.8  # paper: LB within 5-7% for 32-64 procs


def test_refinement_reduces_cut():
    params = _params(level=6, cut=3)
    counts = _gaussian_counts(params.level, seed=3)
    g = build_subtree_graph(counts, params)
    sfc = partition(g, 8, method="sfc")
    ref = partition(g, 8, method="model")
    s_sfc = partition_stats(g, sfc, 8)
    s_ref = partition_stats(g, ref, 8)
    assert s_ref["load_balance"] >= s_sfc["load_balance"] - 0.05
    # refinement must not blow up the cut while balancing
    assert s_ref["edge_cut"] <= s_sfc["edge_cut"] * 1.5


def test_rebalance_counters_slow_processor():
    """Heterogeneous pool: one proc 3x slower -> rebalance shrinks its load."""
    params = _params(level=6, cut=3)
    counts = _gaussian_counts(params.level, seed=5)
    g = build_subtree_graph(counts, params)
    nparts = 4
    a0 = partition(g, nparts, method="model")
    loads0 = g.part_loads(a0, nparts)
    slow = 0
    times = loads0.copy()
    times[slow] *= 3.0  # proc 0 is 3x slower
    a1 = rebalance(g, a0, nparts, times)
    loads1 = g.part_loads(a1, nparts)
    # the slow processor should receive less modeled work than before
    assert loads1[slow] < loads0[slow] * 0.75


def test_morton_order_is_permutation():
    o = morton_order(8)
    assert sorted(o.tolist()) == list(range(64))
    # first four entries are the first z-curve quad
    assert set(o[:4]) == {0, 1, 8, 9}
