"""Dry-run machinery tests (1-device variants; the 512-device campaign runs
via `python -m repro.launch.dryrun --all`).

The HLO analyzer is validated against XLA's own cost_analysis on unrolled
graphs, and against analytic counts on scanned graphs (where XLA's flat
analysis is known to undercount loop bodies).
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo


def test_analyzer_matches_cost_analysis_unrolled():
    D = 256

    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    args = [jax.ShapeDtypeStruct(s, jnp.float32)
            for s in ((32, D), (D, D), (D, D))]
    comp = jax.jit(f).lower(*args).compile()
    cost = comp.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    mine = analyze_hlo(comp.as_text())
    expect = 2 * 32 * D * D * 2          # two matmuls
    assert abs(mine["flops"] - expect) / expect < 0.05
    # XLA counts elementwise tanh flops too; ours counts dots — within 2%
    assert abs(mine["flops"] - cost["flops"]) / cost["flops"] < 0.05


def test_analyzer_scales_with_scan_trip_count():
    D = 128

    def model(h, ws):
        h, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), h, ws)
        return h.sum()

    flops = {}
    for L in (2, 8):
        args = (jax.ShapeDtypeStruct((16, D), jnp.float32),
                jax.ShapeDtypeStruct((L, D, D), jnp.float32))
        comp = jax.jit(model).lower(*args).compile()
        flops[L] = analyze_hlo(comp.as_text())["flops"]
    per_layer = 2 * 16 * D * D
    assert abs(flops[2] - 2 * per_layer) / (2 * per_layer) < 0.1
    assert abs(flops[8] - 8 * per_layer) / (8 * per_layer) < 0.1
    # XLA's flat analysis would report flops[2] == flops[8]; ours must not.
    assert flops[8] > 3 * flops[2]


def test_input_specs_cover_all_cells():
    from repro.configs.registry import get_config, lm_archs
    from repro.launch.dryrun import input_specs
    from repro.models.config import SHAPES, shape_applicable

    n_cells = 0
    for arch in lm_archs():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            n_cells += 1
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            assert all(hasattr(v, "shape") for v in specs.values())
            if shape.kind == "train":
                assert specs["tokens"].shape[0] == shape.global_batch
                total = specs["tokens"].shape[1] + (cfg.num_patches or 0)
                assert total == shape.seq_len
            elif shape.kind == "decode":
                assert specs["token"].shape == (shape.global_batch, 1)
    assert n_cells == 40  # 10 archs x 4 shapes


def test_cache_shardings_cover_cache_tree():
    from repro.configs.registry import get_config
    from repro.launch.dryrun import abstract_cache, cache_shardings
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    for arch in ("yi_6b", "mamba2_13b", "recurrentgemma_2b"):
        cfg = get_config(arch)
        caches = abstract_cache(cfg, 4, 128)
        shards = cache_shardings(mesh, cfg, caches)
        n_leaves = len(jax.tree.leaves(caches))
        n_specs = len(jax.tree.leaves(shards))
        assert n_leaves == n_specs
