"""The pluggable equation subsystem (DESIGN.md §10).

Pins the acceptance criteria of the kernel registry: each registered
equation — ``vortex`` (the bit-compatible default), ``laplace`` (2-D
potential + field from one downward sweep), ``tracer`` (passive
source != target evaluation) — matches an independent f64 direct sum,
singular at interaction-list distance and regularized in the near field,
at p = 17; serial == sharded on 4 devices across both kernel routes, both
plan kinds, and both overlap orderings; and the drivers consume ONLY the
spec (lint-guarded via repro/analysis/lint: no equation-name branches at
the slab call sites).

Multidevice cases run in a subprocess because jax locks the device count
at first init and the rest of the suite must see exactly 1 CPU device.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import equations as eqs
from repro.core import vortex
from repro.core.fmm import fmm_evaluate, fmm_velocity, flops_estimate
from repro.core.quadtree import Tree, build_tree, gather_particle_values


def _rel(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30)


def _case(n=1500, seed=0, level=3, eq=eqs.VORTEX, sigma=0.02):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.02, 0.98, size=(n, 2))
    strength = rng.normal(size=n)
    tree, index = build_tree(pos, strength, level, sigma=sigma,
                             charge_scale=eq.charge_scale)
    return pos, strength, tree, index


def _singular(tree):
    return Tree(z=tree.z, q=tree.q, mask=tree.mask, level=tree.level,
                sigma=None)


# ---------------------------------------------------------------------------
# Registry basics
# ---------------------------------------------------------------------------


def test_registry_contents_and_hashing():
    assert set(eqs.EQUATIONS) >= {"vortex", "laplace", "tracer"}
    assert eqs.get_equation(None) is eqs.VORTEX
    assert eqs.get_equation("laplace") is eqs.LAPLACE
    assert eqs.get_equation(eqs.TRACER) is eqs.TRACER
    with pytest.raises(ValueError, match="unknown equation"):
        eqs.get_equation("navier-stokes")
    # specs are jit-static: hashable, equal by name
    assert hash(eqs.LAPLACE) == hash(eqs.LaplaceEquation())
    assert eqs.LAPLACE == eqs.LaplaceEquation()
    assert eqs.LAPLACE != eqs.VORTEX
    assert eqs.VORTEX.nout == 1 and eqs.LAPLACE.nout == 2
    assert eqs.TRACER.needs_targets and not eqs.VORTEX.needs_targets


def test_register_refuses_silent_replacement():
    """Drivers jit-cache on the spec: swapping different physics behind an
    existing name must fail loudly, and specs of different classes must
    not collide in hash-based caches even when they share a name."""

    class Variant(eqs.LaplaceEquation):
        pass

    v = Variant()
    assert v.name == "laplace"
    assert v != eqs.LAPLACE and hash(v) != hash(eqs.LAPLACE)
    with pytest.raises(ValueError, match="already registered"):
        eqs.register(v)
    # idempotent re-registration of the same spec is fine
    assert eqs.register(eqs.LAPLACE) is eqs.LAPLACE

    class Custom(eqs.EquationSpec):
        name = "custom-test-eq"

    try:
        assert eqs.register(Custom()) == Custom()
        assert eqs.get_equation("custom-test-eq") == Custom()
    finally:
        eqs.EQUATIONS.pop("custom-test-eq", None)


def test_vortex_default_is_bit_compatible():
    """fmm_velocity == fmm_evaluate(eq=vortex) — the registry default is
    the same program, and matches the pre-registry direct oracle."""
    pos, strength, tree, index = _case()
    w_named = np.asarray(fmm_evaluate(tree, 12, eq=eqs.VORTEX))
    w_default = np.asarray(fmm_evaluate(tree, 12))
    w_legacy = np.asarray(fmm_velocity(tree, 12))
    assert np.array_equal(w_named, w_default)
    assert np.array_equal(w_named, w_legacy)
    z = pos[:, 0] + 1j * pos[:, 1]
    exact = vortex.direct_sum(z, strength, sigma=0.02)
    assert _rel(gather_particle_values(w_named, index), exact) < 5e-4


# ---------------------------------------------------------------------------
# Laplace: potential + field from one downward sweep, vs f64 direct sums
# ---------------------------------------------------------------------------


def test_laplace_matches_direct_singular_p17():
    """Both channels vs the singular f64 oracle at p = 17 (the truncation
    error is spectral; the residual is the f32 arithmetic floor)."""
    pos, strength, tree, index = _case(eq=eqs.LAPLACE)
    z = pos[:, 0] + 1j * pos[:, 1]
    out = np.asarray(fmm_evaluate(_singular(tree), 17, eq=eqs.LAPLACE))
    assert out.shape == tree.z.shape + (2,)
    exact = eqs.direct_sum(eqs.LAPLACE, z, z, strength, sigma=None)
    pot = gather_particle_values(out[..., 0], index)
    fld = gather_particle_values(out[..., 1], index)
    assert _rel(pot.real, exact[:, 0].real) < 1e-5
    assert _rel(fld, exact[:, 1]) < 5e-5          # f32 floor (cf. vortex)


def test_laplace_matches_direct_regularized_p17():
    """Near field regularized + far field singular vs the regularized f64
    oracle (Type-I kernel substitution, paper §3) — to 1e-5 at p = 17."""
    pos, strength, tree, index = _case(eq=eqs.LAPLACE)
    z = pos[:, 0] + 1j * pos[:, 1]
    out = np.asarray(fmm_evaluate(tree, 17, eq=eqs.LAPLACE))
    exact = eqs.direct_sum(eqs.LAPLACE, z, z, strength, sigma=0.02)
    assert _rel(gather_particle_values(out[..., 0], index).real,
                exact[:, 0].real) < 1e-5
    assert _rel(gather_particle_values(out[..., 1], index),
                exact[:, 1]) < 1e-5


def test_laplace_field_is_negated_vortex():
    """Cross-check of the log-expansion operator algebra: for real charges
    the Laplace field ``-q/(z - z_j)`` must equal the negated vortex
    velocity computed by the INDEPENDENT velocity-kernel operators."""
    pos, strength, tree, index = _case(eq=eqs.LAPLACE)
    sing = _singular(tree)
    fld = np.asarray(fmm_evaluate(sing, 17, eq=eqs.LAPLACE))[..., 1]
    w = np.asarray(fmm_evaluate(sing, 17, eq=eqs.VORTEX))
    assert _rel(fld, -w) < 1e-6


def test_laplace_p_convergence():
    """Truncation error decays with p for both channels."""
    pos, strength, tree, index = _case(n=1200, seed=7, eq=eqs.LAPLACE)
    z = pos[:, 0] + 1j * pos[:, 1]
    exact = eqs.direct_sum(eqs.LAPLACE, z, z, strength, sigma=None)
    errs = []
    for p in (4, 8, 16):
        out = np.asarray(fmm_evaluate(_singular(tree), p, eq=eqs.LAPLACE))
        errs.append(_rel(gather_particle_values(out[..., 0], index).real,
                         exact[:, 0].real))
    assert errs[1] < errs[0] * 0.5
    assert errs[2] < errs[1]


def test_laplace_kernel_route_matches_jnp():
    """use_kernels=True (Pallas M2L + multi-channel P2P) == jnp route."""
    pos, strength, tree, index = _case(eq=eqs.LAPLACE)
    ref = np.asarray(fmm_evaluate(tree, 12, eq=eqs.LAPLACE))
    kern = np.asarray(fmm_evaluate(tree, 12, eq=eqs.LAPLACE,
                                   use_kernels=True))
    assert _rel(kern, ref) < 1e-5


# ---------------------------------------------------------------------------
# Tracer: passive source != target evaluation
# ---------------------------------------------------------------------------


def _probe_case(level=3, n_src=1500, n_tgt=800, seed=3):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.02, 0.98, size=(n_src, 2))
    strength = rng.normal(size=n_src)
    tpos = rng.uniform(0.05, 0.95, size=(n_tgt, 2))
    tree, _ = build_tree(pos, strength, level, sigma=0.02)
    targets, tindex = build_tree(tpos, np.zeros(n_tgt), level, sigma=0.02)
    return pos, strength, tpos, tree, targets, tindex


def test_tracer_matches_direct_both_routes():
    pos, strength, tpos, tree, targets, tindex = _probe_case()
    z = pos[:, 0] + 1j * pos[:, 1]
    tz = tpos[:, 0] + 1j * tpos[:, 1]
    exact = eqs.direct_sum(eqs.TRACER, tz, z, strength, sigma=0.02)
    for use_kernels in (False, True):
        out = np.asarray(fmm_evaluate(tree, 17, eq=eqs.TRACER,
                                      targets=targets,
                                      use_kernels=use_kernels))
        assert out.shape == targets.z.shape
        got = gather_particle_values(out, tindex)
        assert _rel(got, exact) < 5e-5, use_kernels


def test_tracer_requires_targets():
    pos, strength, tree, index = _case()
    with pytest.raises(ValueError, match="requires a targets tree"):
        fmm_evaluate(tree, 8, eq=eqs.TRACER)


def test_laplace_at_probe_targets():
    """eq and targets compose: potential + field at passive probes."""
    rng = np.random.default_rng(11)
    pos = rng.uniform(0.02, 0.98, size=(1200, 2))
    strength = rng.normal(size=1200)
    tpos = rng.uniform(0.1, 0.9, size=(500, 2))
    tree, _ = build_tree(pos, strength, 3, sigma=0.02,
                         charge_scale=eqs.LAPLACE.charge_scale)
    targets, tindex = build_tree(tpos, np.zeros(500), 3, sigma=0.02)
    out = np.asarray(fmm_evaluate(tree, 17, eq=eqs.LAPLACE, targets=targets))
    assert out.shape == targets.z.shape + (2,)
    z = pos[:, 0] + 1j * pos[:, 1]
    tz = tpos[:, 0] + 1j * tpos[:, 1]
    exact = eqs.direct_sum(eqs.LAPLACE, tz, z, strength, sigma=0.02)
    assert _rel(gather_particle_values(out[..., 0], tindex).real,
                exact[:, 0].real) < 1e-5
    assert _rel(gather_particle_values(out[..., 1], tindex),
                exact[:, 1]) < 1e-5


# ---------------------------------------------------------------------------
# Serial == sharded on 4 devices, both kernel routes, both plan kinds
# ---------------------------------------------------------------------------


_MULTIDEVICE_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import equations as eqs
    from repro.core.cost_model import ModelParams
    from repro.core.fmm import fmm_evaluate
    from repro.core.parallel_fmm import parallel_fmm_evaluate
    from repro.core.plan import block_plan_from_counts, plan_from_counts
    from repro.core.quadtree import build_tree

    rng = np.random.default_rng(0)
    level, p, ndev = 5, 12, 4
    pos = rng.uniform(0.02, 0.98, size=(2500, 2))
    strength = rng.normal(size=2500)
    tpos = rng.uniform(0.05, 0.95, size=(1200, 2))
    mesh = Mesh(np.array(jax.devices()[:ndev]), ("data",))

    def rel(a, b):
        return np.linalg.norm(a - b) / np.linalg.norm(b)

    ltree, lindex = build_tree(pos, strength, level, sigma=0.02,
                               charge_scale=eqs.LAPLACE.charge_scale)
    params = ModelParams(level=level, cut=4, p=p, slots=ltree.slots,
                         nout=eqs.LAPLACE.nout)
    slab = plan_from_counts(lindex.counts, params, ndev, method="model")
    block = block_plan_from_counts(lindex.counts, params, (2, 2),
                                   method="model")

    vtree, _ = build_tree(pos, strength, level, sigma=0.02)
    targets, _ = build_tree(tpos, np.zeros(len(tpos)), level, sigma=0.02)
    cases = {
        "laplace": (ltree, eqs.LAPLACE, None),
        "tracer": (vtree, eqs.TRACER, targets),
    }
    for name, (tree, eq, tgt) in cases.items():
        serial = np.asarray(fmm_evaluate(tree, p, eq=eq, targets=tgt))
        for plan in (slab, block):
            for use_kernels in (False, True):
                for overlap in (False, True):
                    par = np.asarray(parallel_fmm_evaluate(
                        tree, p, mesh, plan=plan, use_kernels=use_kernels,
                        overlap=overlap, eq=eq, targets=tgt))
                    err = rel(par, serial)
                    print(f"{name} {type(plan).__name__} "
                          f"kernels={use_kernels} overlap={overlap} "
                          f"rel={err:.2e}")
                    assert err < 1e-5, (name, plan, use_kernels, overlap,
                                        err)
    print("OK")
""")


def test_equations_multidevice():
    """laplace and tracer: serial == sharded on 4 devices — SlabPlan and
    BlockPlan, kernels on/off, overlapped and monolithic orderings
    (acceptance-pinned)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MULTIDEVICE_BODY],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


# ---------------------------------------------------------------------------
# The drivers consume only the spec (grep guard) + spec-dependent payload
# ---------------------------------------------------------------------------


def test_drivers_have_no_equation_branches():
    """The slab paths are spec-parametric: neither driver may branch on an
    equation name or instance.  Formerly a regex grep; now the
    ``no-equation-branches`` AST lint rule (repro/analysis/lint), which
    also catches multi-line comparisons the regex missed."""
    from repro.analysis.lint import EquationBranchRule, run_lint

    root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    rule = EquationBranchRule()
    findings = run_lint(root, rules=[rule])
    assert findings == [], "\n".join(str(f) for f in findings)
    # the rule actually covers every slab-path file the old grep did
    for rel_path in ("core/fmm.py", "core/parallel_fmm.py",
                     "kernels/ops.py", "kernels/m2l.py", "kernels/p2p.py"):
        assert rule.applies(rel_path), rel_path


def test_packed_exchange_payload_width_is_spec_dependent():
    """Real-charge equations drop the Im q plane: 4 planes instead of 5,
    losslessly."""
    import jax.numpy as jnp
    from repro.core.parallel_fmm import _pack_particles, _unpack_particles

    rng = np.random.default_rng(7)
    shape = (6, 4, 3)
    z = jnp.asarray(rng.normal(size=shape) + 1j * rng.normal(size=shape),
                    jnp.complex64)
    q = jnp.asarray(rng.normal(size=shape) + 0j, jnp.complex64)
    m = jnp.asarray(rng.uniform(size=shape) > 0.5)
    packed = _pack_particles(z, q, m, q_real=True)
    assert packed.shape == (6, 4, 4, 3) and packed.dtype == jnp.float32
    z2, q2, m2 = _unpack_particles(packed, z.dtype, q_real=True)
    assert np.array_equal(np.asarray(z2), np.asarray(z))
    assert np.array_equal(np.asarray(q2), np.asarray(q))
    assert np.array_equal(np.asarray(m2), np.asarray(m))
    # complex-charge default keeps the 5-plane layout
    assert _pack_particles(z, q, m).shape == (6, 4, 5, 3)


def test_real_charge_equation_reads_only_re_q():
    """A real-charge equation on a tree built with a mismatched COMPLEX
    charge_scale must behave as if q were projected to its real part —
    the sharded halo drops the Im q plane, so the drivers project local
    charges too and serial == sharded holds even on inconsistent input."""
    rng = np.random.default_rng(5)
    pos = rng.uniform(0.02, 0.98, size=(800, 2))
    strength = rng.normal(size=800)
    # wrong: vortex charge_scale 1/(2*pi*i) makes q purely imaginary
    bad, _ = build_tree(pos, strength, 3, sigma=0.02)
    proj = Tree(z=bad.z, q=(np.asarray(bad.q).real + 0j).astype(np.complex64),
                mask=bad.mask, level=bad.level, sigma=bad.sigma)
    out_bad = np.asarray(fmm_evaluate(bad, 10, eq=eqs.LAPLACE))
    out_proj = np.asarray(fmm_evaluate(proj, 10, eq=eqs.LAPLACE))
    assert np.array_equal(out_bad, out_proj)


# ---------------------------------------------------------------------------
# Cost model reads the spec (flops_estimate bugfix + Eq 13-15 loads)
# ---------------------------------------------------------------------------


def test_flops_estimate_reads_equation_spec():
    base = flops_estimate(5, 4, 17)
    lap = flops_estimate(5, 4, 17, eq=eqs.LAPLACE)
    # P2P and L2P scale with the output arity; the shared coefficient
    # sweeps do not
    assert lap["p2p"] == 2 * base["p2p"]
    assert lap["l2p"] == 2 * base["l2p"]
    for stage in ("p2m", "m2m", "m2l", "l2l"):
        assert lap[stage] == base[stage]
    assert lap["total"] == base["total"] + base["p2p"] + base["l2p"]


def test_flops_estimate_prices_fused_exchange():
    """The census reports the PR-4 fused packed exchange, not the three
    unfused rounds: one _tile_halo round is 4 ppermutes on a 2x2 grid
    (12 was the unfused count — the 3x reduction the benchmark pins), 2 on
    a 1-D band grid, 0 serial; real-charge payloads are 4 planes, not 5."""
    est = flops_estimate(5, 4, 12, grid=(2, 2))
    assert est["p2p_exchange_collectives"] == 4 == 12 / 3
    assert flops_estimate(5, 4, 12, grid=(4, 1))["p2p_exchange_collectives"] == 2
    assert flops_estimate(5, 4, 12)["p2p_exchange_collectives"] == 0
    assert est["p2p_exchange_planes"] == 5
    lap = flops_estimate(5, 4, 12, eq=eqs.LAPLACE, grid=(2, 2))
    assert lap["p2p_exchange_planes"] == 4
    # the count entries ride outside the flop total
    assert est["total"] == flops_estimate(5, 4, 12)["total"]


def test_cell_loads_scale_with_equation_arity():
    from repro.core.cost_model import ModelParams
    from repro.core.plan import cell_loads
    from repro.core.vortex import lamb_oseen_particles

    pos, gamma, sigma = lamb_oseen_particles(80)
    tree, index = build_tree(pos, gamma, 5, sigma)
    p1 = ModelParams(level=5, cut=4, p=12, slots=tree.slots, nout=1)
    p2 = ModelParams(level=5, cut=4, p=12, slots=tree.slots,
                     nout=eqs.LAPLACE.nout)
    w1, w2 = cell_loads(index.counts, p1), cell_loads(index.counts, p2)
    assert (w2 > w1).any() and (w2 >= w1).all()


# ---------------------------------------------------------------------------
# Stepper: host wall-clock measured-times default
# ---------------------------------------------------------------------------


def test_stepper_defaults_to_wallclock_times():
    from repro.core.stepper import VortexStepper, host_wallclock_times
    from repro.core.vortex import lamb_oseen_particles

    pos, gamma, sigma = lamb_oseen_particles(40)
    st = VortexStepper(pos, gamma, sigma, p=8, dt=0.004, dynamic=True,
                       replan_every=2)
    assert st.measured_times_fn is host_wallclock_times
    assert host_wallclock_times(st) is None     # no clean step yet
    for _ in range(2):
        st.step()
    times = host_wallclock_times(st)
    assert times is not None and times.shape == (st.nparts,)
    assert (times > 0).all()
    # a static stepper keeps the injection point empty
    st2 = VortexStepper(pos, gamma, sigma, p=8, dt=0.004)
    assert st2.measured_times_fn is None
