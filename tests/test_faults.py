"""Fault-injection drills: every injection site on 4 forced host devices.

The acceptance criterion per site: the guarded stepper either RECOVERS —
and its final state matches the uninjected run within f32 tolerance
(bit-exact for plain-retry recoveries, which re-run the identical program
from the intact pre-step tree) — or raises the typed
:class:`StepperFaultError` carrying a structured :class:`FaultReport`.

Each scenario runs in a subprocess (jax pins the host device count at
first init; the rest of the suite needs exactly 1 device).
"""
import os
import subprocess
import sys
import textwrap

_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core.stepper import (RecoveryPolicy, StepperFaultError,
                                    VortexStepper)
    from repro.core.faults import FaultInjector, FaultSpec
    from repro.core import health as hw

    assert len(jax.devices()) == 4
    mesh = Mesh(np.array(jax.devices()), ("data",))
    rng = np.random.default_rng(1)
    pos = 0.02 + 0.96 * rng.random((300, 2))     # every device band occupied
    gamma = rng.standard_normal(300) * 0.1
    KW = dict(sigma=0.02, p=6, dt=0.002, mesh=mesh)

    def run(faults=None, steps=3, **extra):
        st = VortexStepper(pos, gamma, faults=faults, **KW, **extra)
        recs = [st.step() for _ in range(steps)]
        return st, recs
""")


def _run(body, timeout=900):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _PRELUDE + textwrap.dedent(body)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_transient_faults_recover_bit_exact():
    """Non-sticky faults fire only on attempt 0: the ladder's plain retry
    re-runs the identical program from the intact pre-step tree, so the
    recovered trajectory is BIT-EXACT vs the uninjected run."""
    _run("""
        st0, _ = run()
        z0 = np.asarray(st0.tree.z)
        for site, kw in [("halo_nan", {}), ("tile_corrupt", {}),
                         ("teleport", dict(magnitude=0.6)),
                         ("overflow", {})]:
            st, recs = run(FaultInjector(FaultSpec(site, step=2, **kw)))
            assert recs[1].recovered == "retry_1", (site, recs[1])
            assert recs[1].health != 0, site     # adopted attempt's word...
            assert hw.ok(hw.unpack(recs[1].health)), site  # ...is healthy
            assert np.array_equal(np.asarray(st.tree.z), z0), site
            assert recs[0].recovered == "" and recs[2].recovered == "", site
        print("transient ok")
    """)


def test_sticky_teleport_recovers_via_domain_expansion():
    """A sticky teleport whose (physical) magnitude fits a doubled root box
    escalates past retry/half-dt/re-level to the domain-expansion rung."""
    _run("""
        st0, _ = run()
        p0, g0 = st0.particles()
        st, recs = run(FaultInjector(
            FaultSpec("teleport", step=2, sticky=True, magnitude=0.6)))
        assert recs[1].recovered == "expand_domain", recs[1]
        assert st.domain.size >= 2.0, st.domain
        # the injected shift is real physics from here on: positions differ
        # from the uninjected run, but must be finite and inside the domain
        p1, g1 = st.particles()
        assert np.isfinite(p1).all()
        u = st.domain.to_unit(p1)
        assert (u >= 0).all() and (u <= 1).all()
        np.testing.assert_allclose(np.sort(g1), np.sort(g0), rtol=1e-5)
        print("expand ok")
    """)


def test_sticky_halo_nan_recovers_via_reference_route():
    """A sticky halo fault poisons every sharded exchange; only the serial
    jnp reference route (no exchange) escapes it.  The recovered state must
    match the uninjected run within f32 tolerance."""
    _run("""
        st0, _ = run()
        z0 = np.asarray(st0.tree.z)
        pol = RecoveryPolicy(expand_domain=False)   # pin the rung
        st, recs = run(FaultInjector(
            FaultSpec("halo_nan", step=2, sticky=True)), policy=pol)
        assert recs[1].recovered == "reference", recs[1]
        za, zb = np.sort_complex(np.asarray(st.tree.z).ravel()), \
            np.sort_complex(z0.ravel())
        np.testing.assert_allclose(za, zb, atol=5e-5)
        print("reference ok")
    """)


def test_grid_bound_halo_fault_recovers_via_plan_fallback():
    """``only_grid`` pins the halo fault to the 2-D block exchange: the
    plan-fallback rung adopts the 1-D slab plan and escapes it."""
    _run("""
        st, recs = run(FaultInjector(
            FaultSpec("halo_nan", step=2, sticky=True, only_grid=(2, 2))),
            plan_grid=(2, 2), target_per_box=3.0,
            policy=RecoveryPolicy(expand_domain=False))
        assert recs[1].recovered == "plan_slab", recs[1]
        assert recs[1].replanned
        from repro.core.plan import SlabPlan
        assert isinstance(st.plan, SlabPlan) or st.plan.grid[1] == 1
        # the adopted fallback sticks: later steps run it cleanly
        assert recs[2].recovered == ""
        print("fallback ok")
    """)


def test_unrecoverable_fault_raises_typed_error_with_report():
    """A sticky overflow (every particle clumped into one leaf box) defeats
    every compute rung; with no checkpoint to roll back to, the stepper
    must raise the typed error with the structured ladder report."""
    _run("""
        st = VortexStepper(pos, gamma, faults=FaultInjector(
            FaultSpec("overflow", step=2, sticky=True)), **KW)
        st.step()
        try:
            st.step()
        except StepperFaultError as e:
            rep = e.report
            assert rep.step == 2
            rungs = [a["rung"] for a in rep.attempts]
            assert rungs[0] == "step" and len(rungs) >= 3, rungs
            assert all("health" in a for a in rep.attempts)
            assert rep.attempts[0]["health"]["leaf_overflow"] == 1
            assert "unrecoverable" in str(e)
        else:
            raise AssertionError("expected StepperFaultError")
        # the pre-step state was never clobbered by the failed attempts
        assert st.step_count == 1
        print("typed error ok")
    """)


def test_rollback_restores_last_checkpoint_bit_exact():
    """With every compute rung disabled, a sticky fault falls through to
    the rollback rung: the stepper restores the last snapshot bit-exact
    and does NOT advance; a second encounter of the same faulty step
    raises instead of looping."""
    _run("""
        import tempfile
        d = tempfile.mkdtemp()
        pol = RecoveryPolicy(max_retries=0, halve_dt=False, relevel=False,
                             expand_domain=False, plan_fallback=False,
                             reference_route=False)
        st = VortexStepper(pos, gamma, faults=FaultInjector(
            FaultSpec("teleport", step=3, sticky=True, magnitude=2.0)),
            policy=pol, checkpoint_dir=d, checkpoint_every=1, **KW)
        st.step(); st.step()
        st._ckpt.wait()
        z2 = np.asarray(st.tree.z).copy()
        rec = st.step()                      # faulty step -> rollback
        assert rec.recovered == "rollback", rec
        assert st.step_count == 2
        assert np.array_equal(np.asarray(st.tree.z), z2)
        try:
            st.step()                        # same step, same sticky fault
        except StepperFaultError as e:
            assert e.report.step == 3
        else:
            raise AssertionError("expected StepperFaultError after rollback")
        print("rollback ok")
    """)


def test_time_inflation_does_not_thrash_replanning():
    """The host-side fault: one corrupted wall-clock sample.  The
    median/clip filter keeps the measured-feedback loop stable — the
    dynamic stepper replans identically with and without the inflated
    sample."""
    _run("""
        from repro.core.stepper import host_wallclock_times, robust_wall
        def plans(faults):
            st, recs = run(faults, steps=8, dynamic=True, replan_every=2)
            t = host_wallclock_times(st)
            assert t is None or np.isfinite(t).all()
            return [r.replanned for r in recs], st.plan
        base_flags, base_plan = plans(None)
        inf_flags, inf_plan = plans(FaultInjector(
            FaultSpec("time_inflate", step=3, magnitude=50.0)))
        assert inf_plan == base_plan, (base_plan, inf_plan)
        # the filter itself: one 50x outlier moves the estimate < 2x
        clean = [0.01, 0.011, 0.009, 0.0105]
        assert robust_wall(clean + [0.5]) < 2 * robust_wall(clean)
        print("time inflate ok")
    """)
