"""Correctness of the serial FMM vs the O(N^2) direct oracle (paper §6.2)."""
import numpy as np
import pytest

from repro.core import expansions as ex
from repro.core import vortex
from repro.core.fmm import fmm_velocity, fmm_velocity_singular
from repro.core.quadtree import build_tree, gather_particle_values, choose_level


def _random_case(n=2000, seed=0, level=4):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.02, 0.98, size=(n, 2))
    gamma = rng.normal(size=n)
    sigma = 0.02
    tree, index = build_tree(pos, gamma, level=level, sigma=sigma)
    return pos, gamma, sigma, tree, index


def _rel_err(approx, exact):
    return np.linalg.norm(approx - exact) / np.linalg.norm(exact)


# ---------------------------------------------------------------------------
# Expansion-level unit tests: each operator against brute-force evaluation.
# ---------------------------------------------------------------------------


def test_me_matches_direct_far_eval():
    rng = np.random.default_rng(1)
    p = 20
    center, r = 0.5 + 0.5j, 0.25
    zsrc = center + (rng.uniform(-.5, .5, 8) + 1j * rng.uniform(-.5, .5, 8)) * r
    q = rng.normal(size=8) + 0j
    ahat = np.array([np.sum(q * ((zsrc - center) / r) ** k) for k in range(p)])
    ztgt = center + 3.0 * r * np.exp(1j * rng.uniform(0, 2 * np.pi, 16))
    exact = np.array([np.sum(q / (zt - zsrc)) for zt in ztgt])
    approx = ex.eval_me(ahat, center, r, ztgt)
    assert _rel_err(approx, exact) < 1e-10


def test_m2m_preserves_far_field():
    rng = np.random.default_rng(2)
    p = 20
    import jax.numpy as jnp
    # children at level 1 (2x2 grid), parent = root
    zsrc = rng.uniform(0.05, 0.95, 32) + 1j * rng.uniform(0.05, 0.95, 32)
    q = rng.normal(size=32) + 0j
    from repro.core.quadtree import box_centers, box_size
    c1 = box_centers(1)
    me1 = np.zeros((2, 2, p), dtype=np.complex128)
    for iy in range(2):
        for ix in range(2):
            sel = (np.floor(zsrc.real * 2).astype(int) == ix) & \
                  (np.floor(zsrc.imag * 2).astype(int) == iy)
            zz, qq = zsrc[sel], q[sel]
            for k in range(p):
                me1[iy, ix, k] = np.sum(qq * ((zz - c1[iy, ix]) / box_size(1)) ** k)
    me0 = np.asarray(ex.m2m(jnp.asarray(me1), p))[0, 0]
    ztgt = 0.5 + 0.5j + 5.0 * np.exp(1j * rng.uniform(0, 2 * np.pi, 16))
    exact = np.array([np.sum(q / (zt - zsrc)) for zt in ztgt])
    approx = ex.eval_me(me0, 0.5 + 0.5j, 1.0, ztgt)
    assert _rel_err(approx, exact) < 1e-8


def test_m2l_l2l_roundtrip():
    """ME at an interaction-list offset -> LE -> evaluation matches direct."""
    import jax.numpy as jnp
    rng = np.random.default_rng(3)
    p, level = 22, 3
    from repro.core.quadtree import box_centers, box_size
    n, r = 1 << level, box_size(level)
    centers = box_centers(level)
    # sources in box (iy=2, ix=6); targets in box (iy=2, ix=2): offset dx=4 — not
    # in IL (|dx|>3). Use (2,5)->(2,2): dx=3 valid for even parity? px=0,dx=3 valid.
    src_box, tgt_box = (2, 5), (2, 2)
    zsrc = centers[src_box] + (rng.uniform(-.5, .5, 10) + 1j * rng.uniform(-.5, .5, 10)) * r
    q = rng.normal(size=10) + 0j
    me = np.zeros((n, n, p), dtype=np.complex128)
    for k in range(p):
        me[src_box + (k,)] = np.sum(q * ((zsrc - centers[src_box]) / r) ** k)
    le = np.asarray(ex.m2l_reference(jnp.asarray(me), level, p))
    ztgt = centers[tgt_box] + (rng.uniform(-.5, .5, 16) + 1j * rng.uniform(-.5, .5, 16)) * r
    exact = np.array([np.sum(q / (zt - zsrc)) for zt in ztgt])
    approx = ex.eval_le(le[tgt_box], centers[tgt_box], r, ztgt)
    assert _rel_err(approx, exact) < 1e-6

    # L2L: push the level-3 LE down to level 4 and re-evaluate.
    le4 = np.asarray(ex.l2l(jnp.asarray(le), p))
    c4 = box_centers(level + 1)
    for cy in range(2):
        for cx in range(2):
            box4 = (2 * tgt_box[0] + cy, 2 * tgt_box[1] + cx)
            zin = c4[box4] + (rng.uniform(-.5, .5, 8) + 1j * rng.uniform(-.5, .5, 8)) * r / 2
            exact = np.array([np.sum(q / (zt - zsrc)) for zt in zin])
            approx = ex.eval_le(le4[box4], c4[box4], r / 2, zin)
            assert _rel_err(approx, exact) < 1e-6


# ---------------------------------------------------------------------------
# End-to-end FMM vs direct sum.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("level", [2, 3, 4])
def test_fmm_matches_direct_singular(level):
    pos, gamma, sigma, tree, index = _random_case(n=1500, seed=level, level=level)
    w = np.asarray(fmm_velocity_singular(tree, p=17))
    w_at = gather_particle_values(w, index)
    exact = vortex.direct_sum(pos[:, 0] + 1j * pos[:, 1], gamma, sigma=None)
    assert _rel_err(w_at, exact) < 2e-4  # f32 arithmetic floor


def test_fmm_p_convergence():
    """Truncation error decays with p (spectral convergence)."""
    pos, gamma, sigma, tree, index = _random_case(n=1200, seed=7, level=3)
    exact = vortex.direct_sum(pos[:, 0] + 1j * pos[:, 1], gamma, sigma=None)
    errs = []
    for p in (4, 8, 16):
        w = gather_particle_values(np.asarray(fmm_velocity_singular(tree, p=p)), index)
        errs.append(_rel_err(w, exact))
    assert errs[1] < errs[0] * 0.5
    assert errs[2] < errs[1]


def test_fmm_regularized_kernel_substitution():
    """Near field regularized + far field singular vs regularized direct sum.

    Type-I (kernel substitution) error is small when sigma << box size
    (paper §3 and ref [8]).
    """
    pos, gamma, sigma, tree, index = _random_case(n=2000, seed=9, level=3)
    w = gather_particle_values(np.asarray(fmm_velocity(tree, p=17)), index)
    exact = vortex.direct_sum(pos[:, 0] + 1j * pos[:, 1], gamma, sigma=sigma)
    assert _rel_err(w, exact) < 5e-4


def test_tree_roundtrip_and_level_chooser():
    pos, gamma, sigma, tree, index = _random_case(n=500, seed=11, level=3)
    assert int(tree.num_particles) == 500
    back = gather_particle_values(np.asarray(tree.z), index)
    np.testing.assert_allclose(back.real, pos[:, 0], atol=1e-6)
    assert choose_level(765_625, target_per_box=1.0) >= 9
