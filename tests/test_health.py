"""On-device health word (core/health.py) + its driver integration.

Pins the packed-word layout, the merge semantics (flags max, counts sum),
the NaN/Inf and out-of-domain sentinels, the serial/sharded drivers'
``with_health`` outputs, and the two zero-cost guarantees: disabled fault
injection returns the SAME array object (no trace change) and the
unguarded serial driver lowers with no finiteness sentinels at all.

Also the overflow-bugfix lint guard: ``quadtree.rebuild_tree`` silently
drops surplus particles when a leaf overflows, so EVERY call site in src/
must consume its ``ok`` flag (and the guarded stepper folds the dropped
count into the health word).
"""
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import health as hw
from repro.core.faults import (FaultInjector, FaultSpec, corrupt_halo,
                               corrupt_positions, corrupt_tile)
from repro.core.fmm import fmm_velocity
from repro.core.quadtree import Domain, build_tree
from repro.core.stepper import robust_wall
from repro.core.vortex import lamb_oseen_particles

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


# -- word layout / algebra ---------------------------------------------------


def test_pack_unpack_roundtrip():
    vec = np.zeros(hw.N_FIELDS, np.int32)
    vec[hw.F_VEL] = 1
    vec[hw.F_HALO] = 1
    vec[hw.F_OOD] = 37
    vec[hw.F_DROPPED] = 5
    vec[hw.F_OCC] = 19
    word = hw.pack(vec)
    assert isinstance(word, int)
    back = hw.unpack(word)
    np.testing.assert_array_equal(back, vec)
    assert not hw.ok(vec)
    assert hw.ok(hw.unpack(hw.pack(np.zeros(hw.N_FIELDS, np.int32))))


def test_pack_saturates_counts():
    vec = np.zeros(hw.N_FIELDS, np.int32)
    vec[hw.F_OOD] = 1 << 20        # far beyond the 12-bit OOD field
    vec[hw.F_DROPPED] = 10_000     # beyond the 8-bit dropped field
    back = hw.unpack(hw.pack(vec))
    assert back[hw.F_OOD] == (1 << 12) - 1
    assert back[hw.F_DROPPED] == (1 << 8) - 1
    assert not hw.ok(back)


def test_describe_names_every_field():
    vec = np.arange(hw.N_FIELDS, dtype=np.int32)
    d = hw.describe(vec)
    assert len(d) >= hw.N_FIELDS - 1          # spare field may be hidden
    assert d["out_of_domain"] == hw.F_OOD
    assert d["max_occupancy"] == hw.F_OCC


def test_merge_flags_max_counts_sum():
    a = np.zeros(hw.N_FIELDS, np.int32)
    b = np.zeros(hw.N_FIELDS, np.int32)
    a[hw.F_VEL], b[hw.F_VEL] = 1, 1
    a[hw.F_OOD], b[hw.F_OOD] = 3, 4
    a[hw.F_OCC], b[hw.F_OCC] = 10, 7
    m = np.asarray(hw.merge(jnp.asarray(a), jnp.asarray(b)))
    assert m[hw.F_VEL] == 1          # flag: max, not sum
    assert m[hw.F_OOD] == 7          # count: sum across substeps/devices
    assert m[hw.F_OCC] == 10         # gauge: max

    stacked = jnp.stack([jnp.asarray(a), jnp.asarray(b)])
    g = np.asarray(hw.device_combine(stacked))
    np.testing.assert_array_equal(g, m)


def test_nonfinite_and_ood_sentinels():
    z = jnp.asarray([[0.2 + 0.3j, jnp.nan + 0j], [0.9 + 0.9j, 5.0 + 0.5j]])
    mask = jnp.asarray([[True, False], [True, True]])
    assert int(hw.nonfinite(z)) == 1
    assert int(hw.nonfinite(z, mask)) == 0       # the NaN slot is dead
    assert int(hw.nonfinite(jnp.asarray([1.0, 2.0]))) == 0
    # out-of-domain counts LIVE particles outside [0, 1)^2 only
    assert int(hw.out_of_domain_count(z, mask)) == 1
    assert int(hw.out_of_domain_count(z, jnp.zeros_like(mask))) == 0


def test_robust_wall_rejects_outliers():
    assert robust_wall([1.0, 1.1, 0.9, 100.0]) == pytest.approx(1.0, rel=0.2)
    assert robust_wall([1.0, 1.1, 0.9, 1e-9]) == pytest.approx(1.0, rel=0.2)
    assert robust_wall([2.0]) == 2.0


# -- driver integration ------------------------------------------------------


def test_serial_fmm_with_health():
    pos, gamma, sigma = lamb_oseen_particles(40)
    tree, _ = build_tree(pos, gamma, level=4, sigma=sigma)
    w_plain = fmm_velocity(tree, p=8)
    w, h = fmm_velocity(tree, p=8, with_health=True)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(w_plain))
    assert hw.ok(np.asarray(h))
    # poison one live particle position -> velocity + coefficients flagged
    bad_z = tree.z.reshape(-1).at[np.flatnonzero(
        np.asarray(tree.mask).reshape(-1))[0]].set(jnp.nan + 0j)
    bad = tree.__class__(z=bad_z.reshape(tree.z.shape), q=tree.q,
                         mask=tree.mask, level=tree.level, sigma=tree.sigma)
    _, h_bad = fmm_velocity(bad, p=8, with_health=True)
    h_bad = np.asarray(h_bad)
    assert h_bad[hw.F_VEL] == 1
    assert not hw.ok(h_bad)


def test_disabled_injection_is_identity():
    x = jnp.ones((4, 4), jnp.complex64)
    m = jnp.ones((4, 4), bool)
    assert corrupt_tile(x, (), 0) is x
    assert corrupt_halo(x, (), 0, (4, 1)) is x
    assert corrupt_positions(x, m, ()) is x
    # an injector with faults at OTHER steps contributes nothing either
    inj = FaultInjector(FaultSpec("halo_nan", step=7))
    assert inj.active(3) == ()
    assert inj.time_factor(3) == 1.0


def test_unguarded_serial_driver_lowers_without_sentinels():
    """The PR-6 zero-cost guarantee, as the ``sentinel_free`` trace
    contract: guard=False traces the exact unguarded program."""
    from repro.analysis import contracts as C

    pos, gamma, sigma = lamb_oseen_particles(24)
    tree, _ = build_tree(pos, gamma, level=3, sigma=sigma)
    low = C.Lowered(jax.jit(lambda t: fmm_velocity(t, p=6)), tree,
                    label="fmm_velocity")
    (r,) = C.evaluate(low, [C.sentinel_free()])
    assert r.ok, r


# -- the rebuild_tree overflow-drop lint guard -------------------------------


def test_every_rebuild_tree_call_site_checks_ok():
    """``rebuild_tree`` returns ``(tree, aux, ok)`` and silently drops
    overflow particles; a call site that ignores ``ok`` loses particles
    without any signal.  Formerly a regex over src/; now the
    ``rebuild-tree-ok-consumed`` AST lint rule (repro/analysis/lint),
    which also catches multi-line call sites.  The suite still asserts at
    least one real call site exists so the rule is never vacuous."""
    import ast

    from repro.analysis.lint import RebuildTreeOkRule, run_lint

    findings = run_lint(SRC, rules=[RebuildTreeOkRule()])
    assert findings == [], "\n".join(str(f) for f in findings)
    sites = 0
    for path in SRC.rglob("*.py"):
        for node in ast.walk(ast.parse(path.read_text())):
            if isinstance(node, ast.Call) and \
                    getattr(node.func, "id", getattr(node.func, "attr",
                                                     "")) == "rebuild_tree":
                sites += 1
    assert sites > 0, "expected at least one rebuild_tree call site"


def test_domain_roundtrip_and_covering():
    d = Domain(origin=(-1.5, 2.0), size=4.0)
    pos = np.array([[0.0, 3.0], [2.0, 5.5]])
    np.testing.assert_allclose(d.from_unit(d.to_unit(pos)), pos, atol=1e-12)
    assert Domain().is_identity
    got = Domain.covering(pos, margin=0.25)
    u = got.to_unit(pos)
    assert (u > 0).all() and (u < 1).all()
    # covering(at_least=...) never orphans the old root box
    grown = Domain.covering(pos, margin=0.25, at_least=d)
    for corner in ([-1.5, 2.0], [2.5, 6.0]):
        uc = grown.to_unit(np.asarray([corner]))
        assert (uc >= 0).all() and (uc <= 1).all()
