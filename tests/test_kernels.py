"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode.

The M2L oracle is the pre-folding 40-offset masked formulation
(``expansions.m2l_masked40``), so these tests also pin the parity-folded
math — jnp and Pallas — against an independent implementation.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import expansions as ex
from repro.kernels import ref
from repro.kernels.flash_attn import flash_attention
from repro.kernels.m2l import m2l_pallas, m2l_pallas_slab
from repro.kernels.p2p import p2p_pallas, p2p_pallas_slab
from repro.core.fmm import fmm_velocity
from repro.core.quadtree import build_tree


def _rel(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30)


# ---------------------------------------------------------------------------
# P2P kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ny,nx,s", [(4, 4, 3), (8, 8, 5), (8, 16, 1), (6, 6, 8)])
@pytest.mark.parametrize("sigma", [None, 0.05])
def test_p2p_kernel_sweep(ny, nx, s, sigma):
    rng = np.random.default_rng(ny * 100 + nx + s)
    z = (rng.uniform(size=(ny, nx, s)) + 1j * rng.uniform(size=(ny, nx, s)))
    q = (rng.normal(size=(ny, nx, s)) + 1j * rng.normal(size=(ny, nx, s)))
    mask = rng.uniform(size=(ny, nx, s)) > 0.3
    z, q = jnp.asarray(z, jnp.complex64), jnp.asarray(q, jnp.complex64)
    mask = jnp.asarray(mask)
    out = p2p_pallas(z, q, mask, sigma=sigma, block=(4, 4))
    expect = ref.p2p_ref(z, q, mask, sigma=sigma)
    expect = jnp.where(mask, expect, 0)  # kernel computes everywhere; compare masked
    out = jnp.where(mask, out, 0)
    assert _rel(out, expect) < 1e-5


def test_p2p_kernel_block_size_invariance():
    """(BY, BX) is a pure perf knob — outputs must agree across shapes."""
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.uniform(size=(8, 8, 4)) + 1j * rng.uniform(size=(8, 8, 4)),
                    jnp.complex64)
    q = jnp.asarray(rng.normal(size=(8, 8, 4)) + 0j, jnp.complex64)
    mask = jnp.ones((8, 8, 4), bool)
    outs = [np.asarray(p2p_pallas(z, q, mask, sigma=0.1, block=b))
            for b in ((2, 2), (4, 8), (8, 8), (16, 16))]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-6, atol=1e-6)


def test_p2p_slab_matches_grid():
    """The slab entry point (ghosts attached by caller) == grid wrapper."""
    rng = np.random.default_rng(5)
    z = jnp.asarray(rng.uniform(size=(8, 8, 3)) + 1j * rng.uniform(size=(8, 8, 3)),
                    jnp.complex64)
    q = jnp.asarray(rng.normal(size=(8, 8, 3)) + 0j, jnp.complex64)
    mask = jnp.asarray(rng.uniform(size=(8, 8, 3)) > 0.2)
    full = np.asarray(p2p_pallas(z, q, mask, sigma=0.05, block=(4, 4)))
    # slab = grid rows 2..5; ghost rows 1 and 6 are true neighbor rows
    cpad = ((0, 0), (1, 1), (0, 0))
    out = np.asarray(p2p_pallas_slab(jnp.pad(z[1:7], cpad),
                                     jnp.pad(q[1:7], cpad),
                                     jnp.pad(mask[1:7], cpad),
                                     sigma=0.05, block=(4, 4)))
    m = np.asarray(mask[2:6])
    np.testing.assert_allclose(np.where(m, out, 0), np.where(m, full[2:6], 0),
                               rtol=2e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# M2L kernel (parity-folded, halo-resident)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("level,p", [(2, 4), (3, 8), (4, 17), (5, 12)])
def test_m2l_kernel_sweep(level, p):
    rng = np.random.default_rng(level * 10 + p)
    n = 1 << level
    me = jnp.asarray(rng.normal(size=(n, n, p)) + 1j * rng.normal(size=(n, n, p)),
                     jnp.complex64)
    out = m2l_pallas(me, level, p, block=(4, 4))
    expect = ref.m2l_ref(me, level, p)
    assert _rel(out, expect) < 1e-5


def test_m2l_kernel_block_size_sweep_equivalence():
    """(BY, BX) sweep: every block shape produces the same LE grid."""
    rng = np.random.default_rng(2)
    level, p = 4, 17
    n = 1 << level
    me = jnp.asarray(rng.normal(size=(n, n, p)) + 1j * rng.normal(size=(n, n, p)),
                     jnp.complex64)
    outs = [np.asarray(m2l_pallas(me, level, p, block=b))
            for b in ((1, 1), (2, 4), (4, 2), (8, 8), (16, 16))]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("row0,rows,halo", [(0, 4, 2), (4, 8, 2), (1, 5, 3),
                                            (3, 7, 3), (5, 2, 3)])
def test_m2l_slab_rectangular_row0_parity(row0, rows, halo):
    """Rectangular slabs, including odd ``row0`` parity origins, match the
    corresponding rows of the full-grid masked oracle — jnp and Pallas."""
    rng = np.random.default_rng(row0 * 7 + rows)
    level, p = 4, 7
    n = 1 << level
    me = jnp.asarray(rng.normal(size=(n, n, p)) + 1j * rng.normal(size=(n, n, p)),
                     jnp.complex64)
    full = np.asarray(ex.m2l_masked40(me, level, p))
    pad = jnp.pad(me, ((3, 3), (0, 0), (0, 0)))
    me_halo = pad[3 + row0 - halo:3 + row0 + rows + halo]
    want = full[row0:row0 + rows]
    got_jnp = np.asarray(ex.m2l_folded(me_halo, level, p, row0=row0, halo=halo))
    got_pls = np.asarray(m2l_pallas_slab(me_halo, level, p, row0=row0,
                                         halo=halo, block=(4, 4)))
    assert _rel(got_jnp, want) < 1e-5
    assert _rel(got_pls, want) < 1e-5


def test_m2l_folded_reference_matches_masked40_p17():
    """The folded jnp hot path == 40-offset masked oracle at p=17, 1e-5."""
    rng = np.random.default_rng(17)
    level, p = 5, 17
    n = 1 << level
    me = jnp.asarray(rng.normal(size=(n, n, p)) + 1j * rng.normal(size=(n, n, p)),
                     jnp.complex64)
    assert _rel(ex.m2l_reference(me, level, p), ex.m2l_masked40(me, level, p)) < 1e-5


def test_fmm_end_to_end_with_kernels():
    """Full FMM with Pallas M2L + P2P == pure-jnp FMM."""
    rng = np.random.default_rng(3)
    pos = rng.uniform(0.02, 0.98, size=(1200, 2))
    gamma = rng.normal(size=1200)
    tree, _ = build_tree(pos, gamma, level=3, sigma=0.02)
    w_ref = np.asarray(fmm_velocity(tree, p=12, use_kernels=False))
    w_k = np.asarray(fmm_velocity(tree, p=12, use_kernels=True))
    assert _rel(w_k, w_ref) < 1e-5


def test_fmm_end_to_end_with_kernels_p17():
    """use_kernels=True vs reference at p=17 to 1e-5 relative error."""
    rng = np.random.default_rng(4)
    pos = rng.uniform(0.02, 0.98, size=(1500, 2))
    gamma = rng.normal(size=1500)
    tree, _ = build_tree(pos, gamma, level=4, sigma=0.02)
    w_ref = np.asarray(fmm_velocity(tree, p=17, use_kernels=False))
    w_k = np.asarray(fmm_velocity(tree, p=17, use_kernels=True))
    assert _rel(w_k, w_ref) < 1e-5


# ---------------------------------------------------------------------------
# Plan-aware block autotuning + lane padding (numerics-free, DESIGN.md §5/§9)
# ---------------------------------------------------------------------------


def test_autotune_block_table_and_clipping():
    from repro.kernels.ops import BLOCK_TABLE, autotune_block

    assert autotune_block(1, 32) == (1, 32)          # rim row strip, clipped
    assert autotune_block(2, 64) == BLOCK_TABLE["rim_row"]
    assert autotune_block(64, 2) == BLOCK_TABLE["rim_col"]
    assert autotune_block(3, 3) == (3, 3)            # small tile, clipped
    assert autotune_block(16, 16) == BLOCK_TABLE["tile"]
    assert autotune_block(8, 64) == BLOCK_TABLE["wide"]
    by, bx = autotune_block(1, 1)
    assert by >= 1 and bx >= 1


def test_m2l_lane_pad_and_autotune_block_equivalence():
    """lane_pad (4p -> 128 lanes), block=None autotuning, and non-dividing
    explicit blocks all reproduce the default launch bit-for-bit in f32."""
    rng = np.random.default_rng(21)
    level, p = 4, 7                                   # 4p = 28, pads to 128
    n = 1 << level
    me = jnp.asarray(rng.normal(size=(n, n, p)) + 1j * rng.normal(size=(n, n, p)),
                     jnp.complex64)
    me_halo = jnp.pad(me, ((2, 2), (0, 0), (0, 0)))
    base = np.asarray(m2l_pallas_slab(me_halo, level, p, block=(4, 4)))
    padded = np.asarray(m2l_pallas_slab(me_halo, level, p, block=(4, 4),
                                        lane_pad=True))
    np.testing.assert_allclose(padded, base, rtol=1e-6, atol=1e-6)
    from repro.kernels import ops as kops
    auto = np.asarray(kops.m2l_apply_slab(me_halo, level, p, lane_pad=False))
    np.testing.assert_allclose(auto, base, rtol=1e-6, atol=1e-6)
    auto_pad = np.asarray(kops.m2l_apply_slab(me_halo, level, p,
                                              lane_pad=True))
    np.testing.assert_allclose(auto_pad, base, rtol=1e-6, atol=1e-6)
    for blk in ((3, 5), (7, 2)):                      # non-dividing blocks
        odd = np.asarray(m2l_pallas_slab(me_halo, level, p, block=blk))
        np.testing.assert_allclose(odd, base, rtol=1e-6, atol=1e-6)


def test_p2p_lane_pad_and_autotune_block_equivalence():
    rng = np.random.default_rng(22)
    ny, nx, s = 6, 12, 5                              # s = 5 pads to 128
    z = jnp.asarray(rng.uniform(size=(ny, nx, s)) + 1j * rng.uniform(size=(ny, nx, s)),
                    jnp.complex64)
    q = jnp.asarray(rng.normal(size=(ny, nx, s)) + 0j, jnp.complex64)
    mask = jnp.asarray(rng.uniform(size=(ny, nx, s)) > 0.3)
    base = np.asarray(p2p_pallas(z, q, mask, sigma=0.05, block=(4, 4)))
    padded = np.asarray(p2p_pallas(z, q, mask, sigma=0.05, block=(4, 4),
                                   lane_pad=True))
    m = np.asarray(mask)
    np.testing.assert_allclose(np.where(m, padded, 0), np.where(m, base, 0),
                               rtol=1e-6, atol=1e-6)
    from repro.kernels import ops as kops
    pad3 = ((1, 1), (1, 1), (0, 0))
    zh, qh, mh = (jnp.pad(z, pad3), jnp.pad(q, pad3), jnp.pad(mask, pad3))
    auto = np.asarray(kops.p2p_apply_slab(zh, qh, mh, 0.05, lane_pad=False))
    np.testing.assert_allclose(np.where(m, auto, 0), np.where(m, base, 0),
                               rtol=1e-6, atol=1e-6)
    auto_pad = np.asarray(kops.p2p_apply_slab(zh, qh, mh, 0.05,
                                              lane_pad=True))
    np.testing.assert_allclose(np.where(m, auto_pad, 0), np.where(m, base, 0),
                               rtol=1e-6, atol=1e-6)
    for blk in ((5, 3), (7, 7)):                      # non-dividing blocks
        odd = np.asarray(p2p_pallas(z, q, mask, sigma=0.05, block=blk))
        np.testing.assert_allclose(np.where(m, odd, 0), np.where(m, base, 0),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Flash attention kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,Hkv,T,d", [
    (2, 4, 4, 128, 32),     # MHA
    (1, 8, 2, 256, 64),     # GQA 4:1
    (2, 4, 1, 128, 64),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, Hkv, T, d, causal):
    rng = np.random.default_rng(H * T + d)
    q = jnp.asarray(rng.normal(size=(B, H, T, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, T, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, T, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    expect = ref.attention_ref(q, k, v, causal=causal)
    assert _rel(out, expect) < 2e-5


def test_flash_attention_bf16_and_blocks():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.bfloat16)
    expect = ref.attention_ref(q, k, v, causal=True)
    for bq, bk in ((128, 64), (64, 128), (256, 256)):
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        assert _rel(out.astype(np.float32), expect.astype(np.float32)) < 2e-2


def test_flash_attention_cross_attention_shapes():
    """S != T (prefill chunking / encoder-decoder style)."""
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 192, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 192, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    expect = ref.attention_ref(q, k, v, causal=False)
    assert _rel(out, expect) < 2e-5
