"""HLO-level guarantees of the parity-folded M2L path.

The pre-folding kernel wrapper materialized a ``(nb, 40p)`` gathered ME
tensor in HBM before the kernel ran.  These tests walk the optimized HLO
(launch/hlo_analysis) to pin that the folded paths (a) contain no buffer
with a 40p-wide dimension at all and (b) move strictly fewer HBM bytes
than the masked-40 formulation.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import expansions as ex
from repro.core.quadtree import M2L_OFFSETS, M2L_VALIDITY
from repro.kernels import ops as kops
from repro.launch.hlo_analysis import analyze_hlo, shape_dim_pattern

LEVEL, P = 4, 17
N = 1 << LEVEL


def _me():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(N, N, P)) + 1j * rng.normal(size=(N, N, P)),
                       jnp.complex64)


def _hlo(fn, me):
    return jax.jit(fn).lower(me).compile().as_text()


def _staging_pattern():
    # any tensor shape with a 40p-sized dimension, e.g. f32[256,680]
    return shape_dim_pattern(40 * P)


def _old_gather_wrapper(me):
    """The seed wrapper's staging stage (positive control for the regex):
    gather 40 masked source slabs and flatten to (nb, 40p)."""
    pad = jnp.pad(me, ((3, 3), (3, 3), (0, 0)))
    slabs = []
    for oi, (dx, dy) in enumerate(M2L_OFFSETS):
        src = pad[3 + dy:3 + dy + N, 3 + dx:3 + dx + N, :]
        m = jnp.asarray(ex.parity_mask_rect(N, N, M2L_VALIDITY[oi]),
                        dtype=me.dtype)
        slabs.append(src * m[..., None])
    return jnp.stack(slabs, axis=2).reshape(N * N, 40 * P)


def test_regex_detects_old_staging_tensor():
    """Positive control: the detector fires on the seed-style gather."""
    txt = _hlo(_old_gather_wrapper, _me())
    assert _staging_pattern().search(txt) is not None


def test_kernel_wrapper_has_no_40p_staging_tensor():
    txt = _hlo(lambda g: kops.m2l_apply(g, LEVEL, P), _me())
    assert _staging_pattern().search(txt) is None


def test_folded_reference_has_no_40p_staging_tensor():
    txt = _hlo(lambda g: ex.m2l_reference(g, LEVEL, P), _me())
    assert _staging_pattern().search(txt) is None


def test_folded_reference_moves_fewer_hbm_bytes():
    me = _me()
    b_old = analyze_hlo(_hlo(lambda g: ex.m2l_masked40(g, LEVEL, P), me))["bytes"]
    b_new = analyze_hlo(_hlo(lambda g: ex.m2l_reference(g, LEVEL, P), me))["bytes"]
    assert b_new < b_old, (b_new, b_old)
