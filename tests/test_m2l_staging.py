"""HLO-level guarantees of the parity-folded M2L path, as trace contracts.

The pre-folding kernel wrapper materialized a ``(nb, 40p)`` gathered ME
tensor in HBM before the kernel ran.  These pins now live in the contract
registry (repro/analysis/contracts): ``no_staging_dim(40p)`` states no
buffer with a 40p-wide dimension exists at all, ``fewer_bytes`` states the
folded formulation moves strictly fewer fusion-aware HBM bytes than the
masked-40 one.  The seed-style gather wrapper is kept as the positive
control: the contract must FAIL on it, or the detector is vacuous.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.analysis import contracts as C
from repro.core import expansions as ex
from repro.core.quadtree import M2L_OFFSETS, M2L_VALIDITY
from repro.kernels import ops as kops

LEVEL, P = 4, 17
N = 1 << LEVEL


def _me():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(N, N, P)) + 1j * rng.normal(size=(N, N, P)),
                       jnp.complex64)


def _lowered(fn, label):
    return C.Lowered(jax.jit(fn), _me(), label=label)


def _old_gather_wrapper(me):
    """The seed wrapper's staging stage (positive control for the
    contract): gather 40 masked source slabs and flatten to (nb, 40p)."""
    pad = jnp.pad(me, ((3, 3), (3, 3), (0, 0)))
    slabs = []
    for oi, (dx, dy) in enumerate(M2L_OFFSETS):
        src = pad[3 + dy:3 + dy + N, 3 + dx:3 + dx + N, :]
        m = jnp.asarray(ex.parity_mask_rect(N, N, M2L_VALIDITY[oi]),
                        dtype=me.dtype)
        slabs.append(src * m[..., None])
    return jnp.stack(slabs, axis=2).reshape(N * N, 40 * P)


def test_contract_detects_old_staging_tensor():
    """Positive control: no_staging_dim must FAIL on the seed-style
    gather, and its failure message must show the offending buffer."""
    (r,) = C.evaluate(_lowered(_old_gather_wrapper, "seed_gather"),
                      [C.no_staging_dim(40 * P)])
    assert not r.ok, r
    assert str(40 * P) in r.detail


def test_kernel_wrapper_has_no_40p_staging_tensor():
    (r,) = C.evaluate(_lowered(lambda g: kops.m2l_apply(g, LEVEL, P),
                               "m2l_apply"), [C.no_staging_dim(40 * P)])
    assert r.ok, r


def test_folded_reference_has_no_40p_staging_tensor():
    (r,) = C.evaluate(_lowered(lambda g: ex.m2l_reference(g, LEVEL, P),
                               "m2l_reference"), [C.no_staging_dim(40 * P)])
    assert r.ok, r


def test_folded_reference_moves_fewer_hbm_bytes():
    fold = _lowered(lambda g: ex.m2l_reference(g, LEVEL, P), "folded")
    m40 = _lowered(lambda g: ex.m2l_masked40(g, LEVEL, P), "masked40")
    (r,) = C.evaluate(fold, [C.fewer_bytes("folded", "masked40")],
                      pair_with=m40)
    assert r.ok, r
