"""Multi-device MoE: EP shard_map path == single-device path; q8 gather close.

Subprocess with 8 forced host devices (same pattern as test_parallel_fmm).
"""
import os
import subprocess
import sys
import textwrap

_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.configs.registry import get_smoke_config
    from repro.models.moe import init_moe, moe_layer

    cfg = get_smoke_config("granite_moe_1b_a400m")
    # 8 experts, top-2, generous capacity: token-drop priority differs
    # between the global (1-device) and per-shard (EP) dispatch, so the
    # exact-equivalence check must run drop-free.
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_experts=8, capacity_factor=4.0))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p = init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)), jnp.float32)

    ref = np.asarray(moe_layer(p, x, cfg, None))               # 1-device path
    par = np.asarray(jax.jit(lambda p, x: moe_layer(p, x, cfg, mesh))(p, x))
    err = np.linalg.norm(par - ref) / np.linalg.norm(ref)
    print(f"ep_vs_local rel_err={err:.3e}")
    assert err < 5e-3, err   # capacity differs slightly between paths

    cfg8 = dataclasses.replace(cfg, moe_gather_bits=8)
    q8 = np.asarray(jax.jit(lambda p, x: moe_layer(p, x, cfg8, mesh))(p, x))
    err8 = np.linalg.norm(q8 - par) / np.linalg.norm(par)
    print(f"q8_vs_bf16 rel_err={err8:.3e}")
    assert err8 < 5e-2, err8  # int8 weight quantization noise

    # gradients flow through the quantized gather (STE)
    g = jax.grad(lambda p: jnp.sum(moe_layer(p, x, cfg8, mesh) ** 2))(p)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("OK")
""")


def test_moe_ep_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _BODY],
                          capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout
