"""Interior/rim overlapped execution == monolithic execution (DESIGN.md §9).

Pins the acceptance criteria of the overlap work: the overlapped sharded
driver (halo collectives issued first, tile interiors computed from local
data while they fly, rim strips stitched from the exchanged buffers)
matches the monolithic exchange-then-compute driver — and the serial
driver — to f32 roundoff on SlabPlan and BlockPlan, with ``use_kernels``
on and off, at P in {4, 6}, including thin 2-row/2-col boundary tiles
where the whole tile is rim.  Also pins the packed single-round P2P
exchange (3 -> 1 collectives) bit-exactly against the three separate
exchanges it replaced, and the overlap-aware cost-model terms.

Multidevice cases run in subprocesses because jax locks the device count
at first init and the rest of the suite must see exactly 1 CPU device.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core.cost_model import ModelParams, comm_overlap_effective
from repro.core.plan import (BlockPlan, SlabPlan, autotune_plan,
                             block_plan_from_counts, candidate_grids,
                             halo_volume, plan_comm_cost, plan_from_counts,
                             plan_score, uniform_plan)
from repro.core.quadtree import build_tree
from repro.core.vortex import lamb_oseen_particles


def _run(body: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", body],
                          capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


_SLAB_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core.cost_model import ModelParams
    from repro.core.fmm import fmm_velocity
    from repro.core.parallel_fmm import parallel_fmm_velocity
    from repro.core.plan import SlabPlan, plan_from_counts
    from repro.core.quadtree import build_tree
    from repro.core.stepper import VortexStepper
    from repro.core.vortex import lamb_oseen_particles

    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    pos, gamma, sigma = lamb_oseen_particles(160)
    tree, index = build_tree(pos, gamma, level=5, sigma=sigma)
    serial = np.asarray(fmm_velocity(tree, p=12))
    params = ModelParams(level=5, cut=4, p=12, slots=tree.slots)
    model = plan_from_counts(index.counts, params, 4, method="model")
    # thin plan: 2-row boundary bands are ALL rim (interior is empty and
    # statically skipped); the strips must cover the whole band
    thin = SlabPlan(level=5, row0=(0, 2, 16, 30), rows=(2, 14, 14, 2))
    for plan in (model, thin):
        for use_kernels in (False, True):
            got = {}
            for overlap in (False, True):
                w = np.asarray(parallel_fmm_velocity(
                    tree, 12, mesh, use_kernels=use_kernels, plan=plan,
                    overlap=overlap))
                err = np.linalg.norm(w - serial) / np.linalg.norm(serial)
                print(f"rows={plan.rows} kernels={use_kernels} "
                      f"overlap={overlap} rel_err={err:.3e}")
                assert err < 1e-5, (plan.rows, use_kernels, overlap, err)
                got[overlap] = w
            d = np.linalg.norm(got[True] - got[False]) / \
                max(np.linalg.norm(got[False]), 1e-30)
            assert d < 1e-6, (plan.rows, use_kernels, d)

    # the grid autotuner drives the stepper end to end under the mesh
    st = VortexStepper(pos, gamma, sigma, p=8, dt=0.004, mesh=mesh,
                       plan_method="model", dynamic=True, plan_grid="auto",
                       replan_every=2)
    for _ in range(2):
        rec = st.step()
    assert rec.step == 2 and rec.seconds > 0
    print("auto plan:", type(st.plan).__name__, st.plan.describe())
    print("OK")
""")


_BLOCK_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core.cost_model import ModelParams
    from repro.core.fmm import fmm_velocity
    from repro.core.parallel_fmm import parallel_fmm_velocity
    from repro.core.plan import BlockPlan, block_plan_from_counts
    from repro.core.quadtree import build_tree
    from repro.core.vortex import lamb_oseen_particles

    mesh6 = Mesh(np.array(jax.devices()[:6]), ("data",))
    pos, gamma, sigma = lamb_oseen_particles(160)
    tree, index = build_tree(pos, gamma, level=5, sigma=sigma)
    serial = np.asarray(fmm_velocity(tree, p=12))
    params = ModelParams(level=5, cut=4, p=12, slots=tree.slots)
    b23 = block_plan_from_counts(index.counts, params, (2, 3), method="model")
    # minimum-size 2-row/2-col boundary tiles: whole tiles are rim on both
    # axes and the corner-carrying strips span the entire neighbor tile
    skew = BlockPlan(level=5, row0=(0, 2, 22), rows=(2, 20, 10),
                     col0=(0, 30), cols=(30, 2))
    for plan in (b23, skew):
        for use_kernels in (False, True):
            got = {}
            for overlap in (False, True):
                w = np.asarray(parallel_fmm_velocity(
                    tree, 12, mesh6, use_kernels=use_kernels, plan=plan,
                    overlap=overlap))
                err = np.linalg.norm(w - serial) / np.linalg.norm(serial)
                print(f"rows={plan.rows} cols={plan.cols} "
                      f"kernels={use_kernels} overlap={overlap} "
                      f"rel_err={err:.3e}")
                assert err < 1e-5, (plan.rows, use_kernels, overlap, err)
                got[overlap] = w
            d = np.linalg.norm(got[True] - got[False]) / \
                max(np.linalg.norm(got[False]), 1e-30)
            assert d < 1e-6, (plan.rows, use_kernels, d)
    print("OK")
""")


_PACKED_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core import parallel_fmm as pf

    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    grid = (2, 2)
    rmax = cmax = 8
    s = 5
    rv, cv = 6, 8          # unequal valid extents exercise the dynamic edges

    def fused(z, q, m):
        buf = pf._tile_halo(pf._pack_particles(z, q, m), 1, rv, cv,
                            "data", grid)
        return pf._unpack_particles(buf, z.dtype)

    def unfused(z, q, m):
        return (pf._tile_halo(z, 1, rv, cv, "data", grid),
                pf._tile_halo(q, 1, rv, cv, "data", grid),
                pf._tile_halo(m, 1, rv, cv, "data", grid))

    spec = P("data", None, None)
    kw = {pf._CHECK_KW: False} if pf._CHECK_KW else {}
    rng = np.random.default_rng(0)
    shape = (4 * rmax, cmax, s)
    z = jnp.asarray(rng.normal(size=shape) + 1j * rng.normal(size=shape),
                    jnp.complex64)
    q = jnp.asarray(rng.normal(size=shape) - 1j * rng.normal(size=shape),
                    jnp.complex64)
    m = jnp.asarray(rng.uniform(size=shape) > 0.4)
    outs = {}
    for name, fn in (("fused", fused), ("unfused", unfused)):
        sm = pf._shard_map(fn, mesh=mesh, in_specs=(spec,) * 3,
                           out_specs=(spec,) * 3, **kw)
        outs[name] = [np.asarray(a) for a in jax.jit(sm)(z, q, m)]
    for a, b in zip(outs["fused"], outs["unfused"]):
        assert a.dtype == b.dtype and np.array_equal(a, b)
    print("OK")
""")


def test_overlap_matches_monolithic_slab_4dev():
    """Overlapped == monolithic == serial on 4 devices, SlabPlan, both
    kernel routes, thin 2-row boundary bands included; the grid autotuner
    (plan_grid='auto') steps end to end (acceptance-pinned)."""
    _run(_SLAB_BODY)


def test_overlap_matches_monolithic_block_6dev():
    """Overlapped == monolithic == serial on 6 devices, BlockPlan (2x3 and
    thin 2-row/2-col boundary tiles), both kernel routes."""
    _run(_BLOCK_BODY)


def test_packed_p2p_exchange_roundtrip_multidevice():
    """The ONE packed (z, q, mask) exchange reproduces the three separate
    ``_tile_halo`` rounds bit-exactly, including dtype, on a 2x2 grid with
    valid extents smaller than the padded tile."""
    _run(_PACKED_BODY)


def test_pack_unpack_roundtrip_host():
    """_pack_particles / _unpack_particles are a lossless pair (complex64
    components and the bool mask survive the f32 packing exactly)."""
    import jax.numpy as jnp

    from repro.core.parallel_fmm import _pack_particles, _unpack_particles

    rng = np.random.default_rng(7)
    shape = (6, 4, 3)
    z = jnp.asarray(rng.normal(size=shape) + 1j * rng.normal(size=shape),
                    jnp.complex64)
    q = jnp.asarray(rng.normal(size=shape) - 1j * rng.normal(size=shape),
                    jnp.complex64)
    m = jnp.asarray(rng.uniform(size=shape) > 0.5)
    packed = _pack_particles(z, q, m)
    assert packed.shape == (6, 4, 5, 3) and packed.dtype == jnp.float32
    z2, q2, m2 = _unpack_particles(packed, z.dtype)
    assert np.array_equal(np.asarray(z2), np.asarray(z))
    assert np.array_equal(np.asarray(q2), np.asarray(q))
    assert np.array_equal(np.asarray(m2), np.asarray(m))


# ---------------------------------------------------------------------------
# Rim/interior geometry and the overlap-aware cost model (host-side)
# ---------------------------------------------------------------------------


def _lamb_setup(level=5, P=4):
    pos, gamma, sigma = lamb_oseen_particles(120)
    tree, index = build_tree(pos, gamma, level=level, sigma=sigma)
    params = ModelParams(level=level, cut=4, p=10, slots=tree.slots)
    return index.counts, params


def test_interior_extents_and_rim_owners():
    plan = BlockPlan(level=5, row0=(0, 4), rows=(4, 28),
                     col0=(0, 20), cols=(20, 12))
    # w=1 (P2P): interior loses one ring; w=2 (M2L) two; 4-row tiles with
    # w=2 have an EMPTY interior (clamped to 0, the all-rim case)
    assert plan.interior_extents(1) == ((2, 18), (2, 10), (26, 18), (26, 10))
    assert plan.interior_extents(2) == ((0, 16), (0, 8), (24, 16), (24, 8))
    # rim ghost owners (N, S, W, E), -1 at domain edges
    assert plan.rim_owners() == ((-1, 2, -1, 1), (-1, 3, 0, -1),
                                 (0, -1, -1, 3), (1, -1, 2, -1))
    slab = uniform_plan(5, 4)
    assert slab.interior_extents(2) == tuple((4, 28) for _ in range(4))
    assert slab.rim_owners() == ((-1, 1, -1, -1), (0, 2, -1, -1),
                                 (1, 3, -1, -1), (2, -1, -1, -1))


def test_halo_volume_reports_rim_cost():
    counts, params = _lamb_setup()
    plan = plan_from_counts(counts, params, 4, method="model")
    hv = halo_volume(plan, params, executed=True)
    assert hv["rim_m2l_boxes"] > 0 and hv["rim_p2p_boxes"] > 0
    # per sharded level each band recomputes 2w rows of cols_max plus 2w
    # cols of rows_max; the leaf P2P strips are width 1
    block = plan.as_block()
    expect_p2p = sum(2 * (block.rows_max + block.cols_max)
                     for _ in range(4))
    assert hv["rim_p2p_boxes"] == expect_p2p


def test_comm_overlap_effective_residue():
    params = ModelParams(level=5, cut=4, p=10, slots=8)
    assert comm_overlap_effective(100.0, 40.0, params) == 60.0
    assert comm_overlap_effective(100.0, 1000.0, params) == 0.0
    assert comm_overlap_effective(100.0, 1000.0, params, overlap=False) == 100.0
    out = comm_overlap_effective(np.array([10.0, 50.0]),
                                 np.array([20.0, 20.0]), params)
    np.testing.assert_allclose(out, [0.0, 30.0])


def test_plan_comm_cost_overlap_never_exceeds_serial():
    counts, params = _lamb_setup()
    for plan in (plan_from_counts(counts, params, 4, method="model"),
                 block_plan_from_counts(counts, params, (2, 2),
                                        method="model")):
        hidden = plan_comm_cost(plan, counts, params, overlap=True)
        serial = plan_comm_cost(plan, counts, params, overlap=False)
        assert hidden.shape == serial.shape == (4,)
        assert (hidden <= serial + 1e-12).all()
        assert serial.sum() > 0


def test_autotune_plan_picks_min_score_grid():
    counts, params = _lamb_setup()
    best = autotune_plan(counts, params, 4, method="model")
    best_score = plan_score(best, counts, params)
    for Pr, Pc in candidate_grids(4):
        if Pc == 1:
            cand = plan_from_counts(counts, params, 4, method="model")
        else:
            cand = block_plan_from_counts(counts, params, (Pr, Pc),
                                          method="model")
        assert best_score <= plan_score(cand, counts, params) + 1e-9
    # candidate enumeration covers slab and block factorizations
    assert (4, 1) in candidate_grids(4) and (2, 2) in candidate_grids(4)
    assert candidate_grids(6) == [(1, 6), (2, 3), (3, 2), (6, 1)]


def test_block_plan_1d_scale_applies_to_rows():
    """Regression: a 1-D (R,) measured-feedback scale handed to the 2-D
    planner must scale ROWS (column-vector broadcast), not columns —
    matching ``plan_loads`` — so the autotuner's block candidates re-plan
    on the same slowdown field the slab candidates see."""
    counts, params = _lamb_setup()
    R = (1 << params.level) // 2
    scale = np.ones(R)
    scale[: R // 4] = 4.0                    # top rows slowed 4x
    b1 = block_plan_from_counts(counts, params, (2, 2), method="model",
                                cell_weight_scale=scale)
    b2 = block_plan_from_counts(counts, params, (2, 2), method="model",
                                cell_weight_scale=scale[:, None])
    assert b1 == b2
    # the slowed TOP rows shed work: the first row band shrinks vs unscaled
    b0 = block_plan_from_counts(counts, params, (2, 2), method="model")
    assert b1.rows[0] < b0.rows[0], (b1.rows, b0.rows)


def test_replan_auto_with_measured_times_switches_kind():
    """grid='auto' re-plans across plan kinds; measured feedback flows
    through whichever scale shape the previous plan produced."""
    from repro.core.plan import replan

    counts, params = _lamb_setup()
    prev_slab = plan_from_counts(counts, params, 4, method="model")
    out = replan(counts, params, 4, prev_plan=prev_slab,
                 measured_times=np.array([2.0, 1.0, 1.0, 1.0]), grid="auto")
    assert isinstance(out, (SlabPlan, BlockPlan))
    prev_block = block_plan_from_counts(counts, params, (2, 2),
                                        method="model")
    out = replan(counts, params, 4, prev_plan=prev_block,
                 measured_times=np.array([1.0, 1.0, 1.0, 2.0]), grid="auto")
    assert isinstance(out, (SlabPlan, BlockPlan))
