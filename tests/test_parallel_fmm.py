"""Parallel FMM == serial FMM, on 8 forced host devices.

Runs in a subprocess because jax locks the device count at first init and
the rest of the suite must see exactly 1 CPU device.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core.fmm import fmm_velocity
from repro.core.parallel_fmm import parallel_fmm_velocity
from repro.core.quadtree import build_tree

_SUBPROCESS_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core.fmm import fmm_velocity
    from repro.core.parallel_fmm import parallel_fmm_velocity
    from repro.core.quadtree import build_tree

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(0)
    pos = rng.uniform(0.02, 0.98, size=(3000, 2))
    gamma = rng.normal(size=3000)
    tree, _ = build_tree(pos, gamma, level=5, sigma=0.02)

    serial = np.asarray(fmm_velocity(tree, p=12))
    for ndev in (2, 4, 8):
        mesh = Mesh(np.array(jax.devices()[:ndev]), ("data",))
        par = np.asarray(parallel_fmm_velocity(tree, 12, mesh))
        err = np.linalg.norm(par - serial) / np.linalg.norm(serial)
        print(f"ndev={ndev} rel_err={err:.3e}")
        assert err < 1e-5, (ndev, err)
    # kernel route: same Pallas slab kernels as the serial driver, with
    # exchanged (not zero) halos feeding the halo-resident BlockSpecs
    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    par = np.asarray(parallel_fmm_velocity(tree, 12, mesh, use_kernels=True))
    err = np.linalg.norm(par - serial) / np.linalg.norm(serial)
    print(f"ndev=2 kernels rel_err={err:.3e}")
    assert err < 1e-5, err
    print("OK")
""")


def test_parallel_matches_serial_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_BODY],
                          capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


def test_parallel_single_device_matches_serial():
    """Same code path with a 1-device mesh (runs in-process)."""
    rng = np.random.default_rng(1)
    pos = rng.uniform(0.02, 0.98, size=(1500, 2))
    gamma = rng.normal(size=1500)
    tree, _ = build_tree(pos, gamma, level=4, sigma=0.02)
    serial = np.asarray(fmm_velocity(tree, p=10))
    par = np.asarray(parallel_fmm_velocity(tree, 10, None))
    err = np.linalg.norm(par - serial) / np.linalg.norm(serial)
    assert err < 1e-5


def test_parallel_kernel_route_matches_serial():
    """The sharded driver's use_kernels route (same Pallas slab kernels as
    the serial driver) agrees with the pure-jnp serial result."""
    rng = np.random.default_rng(2)
    pos = rng.uniform(0.02, 0.98, size=(1200, 2))
    gamma = rng.normal(size=1200)
    tree, _ = build_tree(pos, gamma, level=4, sigma=0.02)
    serial = np.asarray(fmm_velocity(tree, p=10))
    par = np.asarray(parallel_fmm_velocity(tree, 10, None, use_kernels=True))
    err = np.linalg.norm(par - serial) / np.linalg.norm(serial)
    assert err < 1e-5
