"""Partitioner + execution-plan coverage (paper §4-§5, Eq 20).

Pins the PR-2 acceptance criteria: the cost-model pipeline beats the
uniform strawman on the paper's own Lamb-Oseen lattice, measured-time
rebalancing sheds load from a slowed part, and SlabPlan bands obey the
contracts the sharded driver depends on (contiguous, parity-even, exact
row cover).
"""
import numpy as np
import pytest

from repro.core import partition as pt
from repro.core.cost_model import ModelParams
from repro.core.plan import (SlabPlan, assignment_from_plan, plan_from_counts,
                             plan_loads, plan_stats, replan, row_loads,
                             uniform_plan)
from repro.core.vortex import lamb_oseen_particles


def lamb_oseen_counts(level: int, m_side: int = 120) -> np.ndarray:
    pos, _, _ = lamb_oseen_particles(m_side)
    n = 1 << level
    ij = np.clip((pos * n).astype(int), 0, n - 1)
    counts = np.zeros((n, n), dtype=np.int64)
    np.add.at(counts, (ij[:, 1], ij[:, 0]), 1)
    return counts


# ---------------------------------------------------------------------------
# FM refinement vs the uniform-SFC strawman on the paper's test case
# ---------------------------------------------------------------------------


def test_fm_beats_uniform_sfc_on_lamb_oseen():
    """Paper Figs 7-9 on the Lamb-Oseen lattice: the full model pipeline
    (weighted SFC seed + FM refinement) beats the equal-count SFC split on
    BOTH the edge cut and the Eq-20 min/max load metric."""
    params = ModelParams(level=6, cut=4, p=12, slots=4)
    counts = lamb_oseen_counts(params.level)
    g = pt.build_subtree_graph(counts, params)
    nparts = 6
    base = pt.partition(g, nparts, method="uniform-sfc")
    model = pt.partition(g, nparts, method="model")
    s_base = pt.partition_stats(g, base, nparts)
    s_model = pt.partition_stats(g, model, nparts)
    assert s_model["load_balance"] > s_base["load_balance"]
    assert s_model["edge_cut"] < s_base["edge_cut"]


def test_rebalance_sheds_load_from_slowed_part_lamb_oseen():
    params = ModelParams(level=6, cut=3, p=12, slots=4)
    counts = lamb_oseen_counts(params.level)
    g = pt.build_subtree_graph(counts, params)
    nparts = 4
    a0 = pt.partition(g, nparts, method="model")
    loads0 = g.part_loads(a0, nparts)
    slow = 2
    times = loads0.copy()
    times[slow] *= 3.0
    a1 = pt.rebalance(g, a0, nparts, times)
    loads1 = g.part_loads(a1, nparts)
    assert loads1[slow] < loads0[slow] * 0.75


def test_measured_rates_fills_empty_parts():
    rates = pt.measured_rates(np.array([10.0, 0.0, 20.0]),
                              np.array([10.0, 5.0, 40.0]))
    assert rates[0] == pytest.approx(1.0)
    assert rates[2] == pytest.approx(2.0)
    assert rates[1] == pytest.approx(1.5)   # mean positive rate


# ---------------------------------------------------------------------------
# SlabPlan invariants — the contract the sharded driver depends on
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["uniform", "sfc", "model"])
@pytest.mark.parametrize("nparts", [2, 3, 4, 7])
def test_slab_plan_bands_cover_grid(method, nparts):
    params = ModelParams(level=5, cut=3, p=12, slots=4)
    counts = lamb_oseen_counts(params.level, m_side=100)
    plan = plan_from_counts(counts, params, nparts, method=method)
    assert plan.nparts == nparts
    covered = []
    for r0, r in zip(plan.row0, plan.rows):
        assert r0 % 2 == 0 and r % 2 == 0 and r > 0     # parity-even
        covered.extend(range(r0, r0 + r))
    assert covered == list(range(1 << params.level))     # exact cover, in order
    # index maps round-trip
    idx, valid = plan.gather_index()
    assert sorted(idx[valid].tolist()) == covered
    scatter = plan.scatter_index()
    owner = plan.owner_of_row()
    assert (idx[scatter] == np.arange(1 << params.level)).all()
    assert (np.bincount(owner) == np.asarray(plan.rows)).all()


def test_slab_plan_rejects_bad_bands():
    with pytest.raises(ValueError):
        SlabPlan(level=4, row0=(0, 8), rows=(8, 6))       # short cover
    with pytest.raises(ValueError):
        SlabPlan(level=4, row0=(0, 6), rows=(8, 8))       # overlap/gap
    with pytest.raises(ValueError):
        SlabPlan(level=4, row0=(0, 5), rows=(5, 11))      # odd band
    with pytest.raises(ValueError):
        uniform_plan(level=2, nparts=3)                   # too many parts


def test_plan_is_static_and_hashable():
    a = uniform_plan(5, 4)
    b = uniform_plan(5, 4)
    assert a == b and hash(a) == hash(b)
    assert a != SlabPlan(level=5, row0=(0, 4, 10, 20), rows=(4, 6, 10, 12))


# ---------------------------------------------------------------------------
# Model plan beats the uniform strawman on Lamb-Oseen (acceptance-pinned)
# ---------------------------------------------------------------------------


def test_model_plan_beats_uniform_on_lamb_oseen():
    """Eq (20) min/max modeled load: model bands strictly beat equal-count
    bands on the Lamb-Oseen lattice (the acceptance criterion's pinned
    configuration — 4 parts, level 5, p=12, m_side=160)."""
    params = ModelParams(level=5, cut=4, p=12, slots=8)
    counts = lamb_oseen_counts(params.level, m_side=160)
    model = plan_from_counts(counts, params, 4, method="model")
    uniform = plan_from_counts(counts, params, 4, method="uniform")
    lb_model = plan_stats(model, counts, params)["load_balance"]
    lb_uniform = plan_stats(uniform, counts, params)["load_balance"]
    assert lb_model > lb_uniform
    assert not model.is_uniform


@pytest.mark.parametrize("nparts", [2, 4, 8])
def test_model_plan_never_loses_to_uniform(nparts):
    """Refinement seeds from the uniform split, so the model plan dominates
    the strawman on the modeled metric for every part count."""
    params = ModelParams(level=6, cut=4, p=8, slots=8)
    counts = lamb_oseen_counts(params.level, m_side=160)
    model = plan_from_counts(counts, params, nparts, method="model")
    uniform = uniform_plan(params.level, nparts)
    assert plan_stats(model, counts, params)["load_balance"] >= \
        plan_stats(uniform, counts, params)["load_balance"]


def test_row_loads_match_band_loads():
    params = ModelParams(level=5, cut=3, p=10, slots=4)
    counts = lamb_oseen_counts(params.level, m_side=100)
    w = row_loads(counts, params)
    assert w.shape == ((1 << params.level) // 2,)
    plan = plan_from_counts(counts, params, 4, method="model")
    loads = plan_loads(plan, counts, params)
    assert loads.sum() == pytest.approx(w.sum())
    assert plan_stats(plan, counts, params)["max_load"] == pytest.approx(loads.max())


# ---------------------------------------------------------------------------
# Dynamic feedback at plan level
# ---------------------------------------------------------------------------


def test_replan_shifts_rows_off_slowed_device():
    """A 3x-slower device must end up with fewer rows after measured-time
    feedback (the paper's dynamic rebalancing, at band granularity)."""
    params = ModelParams(level=6, cut=4, p=12, slots=8)
    counts = lamb_oseen_counts(params.level, m_side=160)
    nparts = 4
    plan0 = plan_from_counts(counts, params, nparts, method="model")
    loads0 = plan_loads(plan0, counts, params)
    slow = 1
    times = loads0.copy()
    times[slow] *= 3.0
    plan1 = replan(counts, params, nparts, prev_plan=plan0,
                   measured_times=times, method="model")
    assert plan1.rows[slow] < plan0.rows[slow]
    # modeled load on the slow device drops too
    assert plan_loads(plan1, counts, params)[slow] < loads0[slow]
    # without measurements, replan reproduces the a-priori plan
    assert replan(counts, params, nparts, prev_plan=plan0) == plan0


def test_assignment_from_plan_majority():
    plan = SlabPlan(level=4, row0=(0, 8), rows=(8, 8))
    assign = assignment_from_plan(plan, cut=2)   # 4x4 subtree grid
    assert assign.shape == (16,)
    assert (assign[:8] == 0).all() and (assign[8:] == 1).all()
