"""Partitioner + execution-plan coverage (paper §4-§5, Eq 20).

Pins the PR-2 acceptance criteria: the cost-model pipeline beats the
uniform strawman on the paper's own Lamb-Oseen lattice, measured-time
rebalancing sheds load from a slowed part, and SlabPlan bands obey the
contracts the sharded driver depends on (contiguous, parity-even, exact
row cover).
"""
import numpy as np
import pytest

from repro.core import partition as pt
from repro.core.cost_model import ModelParams
from repro.core.plan import (BlockPlan, SlabPlan, assignment_from_plan,
                             block_plan_from_counts, cell_loads, halo_volume,
                             plan_from_counts, plan_loads, plan_stats, replan,
                             row_loads, uniform_block_plan, uniform_plan)
from repro.core.vortex import lamb_oseen_particles


def lamb_oseen_counts(level: int, m_side: int = 120) -> np.ndarray:
    pos, _, _ = lamb_oseen_particles(m_side)
    n = 1 << level
    ij = np.clip((pos * n).astype(int), 0, n - 1)
    counts = np.zeros((n, n), dtype=np.int64)
    np.add.at(counts, (ij[:, 1], ij[:, 0]), 1)
    return counts


# ---------------------------------------------------------------------------
# FM refinement vs the uniform-SFC strawman on the paper's test case
# ---------------------------------------------------------------------------


def test_fm_beats_uniform_sfc_on_lamb_oseen():
    """Paper Figs 7-9 on the Lamb-Oseen lattice: the full model pipeline
    (weighted SFC seed + FM refinement) beats the equal-count SFC split on
    BOTH the edge cut and the Eq-20 min/max load metric."""
    params = ModelParams(level=6, cut=4, p=12, slots=4)
    counts = lamb_oseen_counts(params.level)
    g = pt.build_subtree_graph(counts, params)
    nparts = 6
    base = pt.partition(g, nparts, method="uniform-sfc")
    model = pt.partition(g, nparts, method="model")
    s_base = pt.partition_stats(g, base, nparts)
    s_model = pt.partition_stats(g, model, nparts)
    assert s_model["load_balance"] > s_base["load_balance"]
    assert s_model["edge_cut"] < s_base["edge_cut"]


def test_rebalance_sheds_load_from_slowed_part_lamb_oseen():
    params = ModelParams(level=6, cut=3, p=12, slots=4)
    counts = lamb_oseen_counts(params.level)
    g = pt.build_subtree_graph(counts, params)
    nparts = 4
    a0 = pt.partition(g, nparts, method="model")
    loads0 = g.part_loads(a0, nparts)
    slow = 2
    times = loads0.copy()
    times[slow] *= 3.0
    a1 = pt.rebalance(g, a0, nparts, times)
    loads1 = g.part_loads(a1, nparts)
    assert loads1[slow] < loads0[slow] * 0.75


def test_measured_rates_fills_empty_parts():
    rates = pt.measured_rates(np.array([10.0, 0.0, 20.0]),
                              np.array([10.0, 5.0, 40.0]))
    assert rates[0] == pytest.approx(1.0)
    assert rates[2] == pytest.approx(2.0)
    assert rates[1] == pytest.approx(1.5)   # mean positive rate


# ---------------------------------------------------------------------------
# SlabPlan invariants — the contract the sharded driver depends on
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["uniform", "sfc", "model"])
@pytest.mark.parametrize("nparts", [2, 3, 4, 7])
def test_slab_plan_bands_cover_grid(method, nparts):
    params = ModelParams(level=5, cut=3, p=12, slots=4)
    counts = lamb_oseen_counts(params.level, m_side=100)
    plan = plan_from_counts(counts, params, nparts, method=method)
    assert plan.nparts == nparts
    covered = []
    for r0, r in zip(plan.row0, plan.rows):
        assert r0 % 2 == 0 and r % 2 == 0 and r > 0     # parity-even
        covered.extend(range(r0, r0 + r))
    assert covered == list(range(1 << params.level))     # exact cover, in order
    # index maps round-trip
    idx, valid = plan.gather_index()
    assert sorted(idx[valid].tolist()) == covered
    scatter = plan.scatter_index()
    owner = plan.owner_of_row()
    assert (idx[scatter] == np.arange(1 << params.level)).all()
    assert (np.bincount(owner) == np.asarray(plan.rows)).all()


def test_slab_plan_rejects_bad_bands():
    with pytest.raises(ValueError):
        SlabPlan(level=4, row0=(0, 8), rows=(8, 6))       # short cover
    with pytest.raises(ValueError):
        SlabPlan(level=4, row0=(0, 6), rows=(8, 8))       # overlap/gap
    with pytest.raises(ValueError):
        SlabPlan(level=4, row0=(0, 5), rows=(5, 11))      # odd band
    with pytest.raises(ValueError):
        uniform_plan(level=2, nparts=3)                   # too many parts


def test_plan_is_static_and_hashable():
    a = uniform_plan(5, 4)
    b = uniform_plan(5, 4)
    assert a == b and hash(a) == hash(b)
    assert a != SlabPlan(level=5, row0=(0, 4, 10, 20), rows=(4, 6, 10, 12))


# ---------------------------------------------------------------------------
# Model plan beats the uniform strawman on Lamb-Oseen (acceptance-pinned)
# ---------------------------------------------------------------------------


def test_model_plan_beats_uniform_on_lamb_oseen():
    """Eq (20) min/max modeled load: model bands strictly beat equal-count
    bands on the Lamb-Oseen lattice (the acceptance criterion's pinned
    configuration — 4 parts, level 5, p=12, m_side=160)."""
    params = ModelParams(level=5, cut=4, p=12, slots=8)
    counts = lamb_oseen_counts(params.level, m_side=160)
    model = plan_from_counts(counts, params, 4, method="model")
    uniform = plan_from_counts(counts, params, 4, method="uniform")
    lb_model = plan_stats(model, counts, params)["load_balance"]
    lb_uniform = plan_stats(uniform, counts, params)["load_balance"]
    assert lb_model > lb_uniform
    assert not model.is_uniform


@pytest.mark.parametrize("nparts", [2, 4, 8])
def test_model_plan_never_loses_to_uniform(nparts):
    """Refinement seeds from the uniform split, so the model plan dominates
    the strawman on the modeled metric for every part count."""
    params = ModelParams(level=6, cut=4, p=8, slots=8)
    counts = lamb_oseen_counts(params.level, m_side=160)
    model = plan_from_counts(counts, params, nparts, method="model")
    uniform = uniform_plan(params.level, nparts)
    assert plan_stats(model, counts, params)["load_balance"] >= \
        plan_stats(uniform, counts, params)["load_balance"]


def test_row_loads_match_band_loads():
    params = ModelParams(level=5, cut=3, p=10, slots=4)
    counts = lamb_oseen_counts(params.level, m_side=100)
    w = row_loads(counts, params)
    assert w.shape == ((1 << params.level) // 2,)
    plan = plan_from_counts(counts, params, 4, method="model")
    loads = plan_loads(plan, counts, params)
    assert loads.sum() == pytest.approx(w.sum())
    assert plan_stats(plan, counts, params)["max_load"] == pytest.approx(loads.max())


# ---------------------------------------------------------------------------
# Dynamic feedback at plan level
# ---------------------------------------------------------------------------


def test_replan_shifts_rows_off_slowed_device():
    """A 3x-slower device must end up with fewer rows after measured-time
    feedback (the paper's dynamic rebalancing, at band granularity)."""
    params = ModelParams(level=6, cut=4, p=12, slots=8)
    counts = lamb_oseen_counts(params.level, m_side=160)
    nparts = 4
    plan0 = plan_from_counts(counts, params, nparts, method="model")
    loads0 = plan_loads(plan0, counts, params)
    slow = 1
    times = loads0.copy()
    times[slow] *= 3.0
    plan1 = replan(counts, params, nparts, prev_plan=plan0,
                   measured_times=times, method="model")
    assert plan1.rows[slow] < plan0.rows[slow]
    # modeled load on the slow device drops too
    assert plan_loads(plan1, counts, params)[slow] < loads0[slow]
    # without measurements, replan reproduces the a-priori plan
    assert replan(counts, params, nparts, prev_plan=plan0) == plan0


def test_assignment_from_plan_majority():
    plan = SlabPlan(level=4, row0=(0, 8), rows=(8, 8))
    assign = assignment_from_plan(plan, cut=2)   # 4x4 subtree grid
    assert assign.shape == (16,)
    assert (assign[:8] == 0).all() and (assign[8:] == 1).all()


def test_uniform_plan_applies_measured_scale():
    """The uniform strawman must react to measured-time feedback rather
    than silently ignoring ``row_weight_scale`` (a dynamic stepper on
    plan_method='uniform' re-splits on the measured slowdown field)."""
    params = ModelParams(level=5, cut=3, p=8, slots=4)
    counts = lamb_oseen_counts(params.level, m_side=100)
    base = plan_from_counts(counts, params, 4, method="uniform")
    assert base == uniform_plan(5, 4)
    scale = np.ones(16)
    scale[:4] = 4.0            # device 0's rows measured 4x slower
    scaled = plan_from_counts(counts, params, 4, method="uniform",
                              row_weight_scale=scale)
    assert scaled.rows[0] < base.rows[0]
    # the same feedback flows through replan for a uniform-method stepper
    times = np.ones(4)
    times[0] = 4.0
    replanned = replan(counts, params, 4, prev_plan=base,
                       measured_times=times, method="uniform")
    assert replanned.rows[0] < base.rows[0]


# ---------------------------------------------------------------------------
# BlockPlan invariants — the 2-D contract the sharded driver depends on
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("grid", [(2, 2), (2, 3), (4, 2), (3, 3)])
@pytest.mark.parametrize("method", ["uniform", "model"])
def test_block_plan_tiles_cover_grid(grid, method):
    params = ModelParams(level=5, cut=3, p=12, slots=4)
    counts = lamb_oseen_counts(params.level, m_side=100)
    plan = plan_from_counts(counts, params, grid[0] * grid[1],
                            method=method, grid=grid)
    assert isinstance(plan, BlockPlan) and plan.grid == grid
    n = 1 << params.level
    for b0, bl in ((plan.row0, plan.rows), (plan.col0, plan.cols)):
        covered = []
        for x0, x in zip(b0, bl):
            assert x0 % 2 == 0 and x % 2 == 0 and x > 0   # parity-even
            covered.extend(range(x0, x0 + x))
        assert covered == list(range(n))                  # exact cover
    # gather -> scatter round-trips the standard layout
    src_r, src_c, valid = plan.gather_index()
    x = np.arange(n * n).reshape(n, n)
    sharded = np.where(valid, x[src_r, src_c], -1)
    sct_r, sct_c = plan.scatter_index()
    assert (sharded[sct_r, sct_c] == x).all()
    # every grid cell has exactly one owner slot
    assert valid.sum() == n * n
    # tile maps agree with the leaf owner maps at shift 0
    owner, lr, lc = plan.tile_maps(0)
    oi, oj = plan.owner_of_row(), plan.owner_of_col()
    assert (owner == oi[:, None] * grid[1] + oj[None, :]).all()


def test_block_plan_rejects_bad_tiles():
    with pytest.raises(ValueError):
        BlockPlan(level=4, row0=(0, 8), rows=(8, 6), col0=(0,), cols=(16,))
    with pytest.raises(ValueError):
        BlockPlan(level=4, row0=(0,), rows=(16,), col0=(0, 5), cols=(5, 11))
    with pytest.raises(ValueError):
        BlockPlan(level=4, row0=(0, 6), rows=(8, 8), col0=(0,), cols=(16,))
    with pytest.raises(ValueError):
        plan_from_counts(np.zeros((16, 16)), ModelParams(4, 2, 8, 4), 4,
                         grid=(2, 3))                     # grid != nparts
    a = uniform_block_plan(5, (2, 3))
    assert a == uniform_block_plan(5, (2, 3)) and hash(a) is not None


def test_block_model_beats_uniform_and_cell_loads_are_consistent():
    """2-D Eq-20: the model block plan never loses to the uniform block
    strawman, and the 2-D cost field projects exactly onto row_loads."""
    params = ModelParams(level=6, cut=4, p=12, slots=8)
    counts = lamb_oseen_counts(params.level, m_side=160)
    W = cell_loads(counts, params)
    np.testing.assert_allclose(W.sum(axis=1), row_loads(counts, params))
    for grid in ((2, 2), (2, 3), (4, 2), (4, 4)):
        model = block_plan_from_counts(counts, params, grid, method="model")
        uni = uniform_block_plan(params.level, grid)
        lb_m = plan_stats(model, counts, params)["load_balance"]
        lb_u = plan_stats(uni, counts, params)["load_balance"]
        assert lb_m >= lb_u, (grid, lb_m, lb_u)
        loads = plan_loads(model, counts, params)
        assert loads.shape == (grid[0] * grid[1],)
        assert loads.sum() == pytest.approx(W.sum())
    # and strictly beats it on a grid where equal-count splits misalign
    # with the vortex-centered distribution
    model = block_plan_from_counts(counts, params, (2, 3), method="model")
    assert plan_stats(model, counts, params)["load_balance"] > \
        plan_stats(uniform_block_plan(params.level, (2, 3)), counts,
                   params)["load_balance"]


def test_block_halo_volume_beats_slab():
    """The BlockPlan's reason to exist (acceptance-pinned): modeled halo
    volume strictly below the 1-D SlabPlan's at P >= 8 on the Lamb-Oseen
    lattice (and, as it happens, at P = 4 too)."""
    params = ModelParams(level=6, cut=4, p=12, slots=8)
    counts = lamb_oseen_counts(params.level, m_side=160)
    for nparts, grid in ((8, (4, 2)), (16, (4, 4))):
        slab = plan_from_counts(counts, params, nparts, method="model")
        block = block_plan_from_counts(counts, params, grid, method="model")
        hs = halo_volume(slab, params)["total"]
        hb = halo_volume(block, params)["total"]
        assert hb < hs, (nparts, hs, hb)
        # the driver-exact (padded-extent) volume wins too
        es = halo_volume(slab, params, executed=True)["total"]
        eb = halo_volume(block, params, executed=True)["total"]
        assert eb < es, (nparts, es, eb)


def test_block_replan_sheds_tiles_off_slowed_device():
    """Measured-time feedback at tile granularity: a 3x-slower device's
    modeled load drops after a 2-D re-plan (no 1-D collapse in the loop)."""
    params = ModelParams(level=6, cut=4, p=12, slots=8)
    counts = lamb_oseen_counts(params.level, m_side=160)
    plan0 = block_plan_from_counts(counts, params, (2, 3), method="model")
    loads0 = plan_loads(plan0, counts, params)
    slow = 0
    times = loads0.copy()
    times[slow] *= 3.0
    plan1 = replan(counts, params, 6, prev_plan=plan0, measured_times=times)
    assert isinstance(plan1, BlockPlan) and plan1.grid == (2, 3)
    assert plan_loads(plan1, counts, params)[slow] < loads0[slow]


def test_replan_migrates_slab_to_grid_with_row_scale():
    """replan(prev_plan=<SlabPlan>, grid=(Pr, Pc)) applies the 1-D row
    slowdowns per ROW of the 2-D cell field (not broadcast along columns):
    a slow top band must shrink the new plan's top row band."""
    params = ModelParams(level=6, cut=4, p=12, slots=8)
    counts = lamb_oseen_counts(params.level, m_side=160)
    slab = plan_from_counts(counts, params, 6, method="model")
    times = plan_loads(slab, counts, params)
    times[0] *= 4.0                      # device 0 owns the top rows
    block = replan(counts, params, 6, prev_plan=slab, measured_times=times,
                   grid=(2, 3))
    assert isinstance(block, BlockPlan) and block.grid == (2, 3)
    uni_rows = uniform_block_plan(params.level, (2, 3)).rows
    assert block.rows[0] < uni_rows[0]


def test_block_assignment_from_plan_exact_overlap():
    plan = BlockPlan(level=4, row0=(0, 8), rows=(8, 8),
                     col0=(0, 10), cols=(10, 6))
    assign = assignment_from_plan(plan, cut=2).reshape(4, 4)
    # rows split 2/2; cols split at leaf 10 -> subtree cols 0-1 (and the
    # majority of col 2) belong to column band 0
    assert (assign[:2, :3] == 0).all() and (assign[:2, 3] == 1).all()
    assert (assign[2:, :3] == 2).all() and (assign[2:, 3] == 3).all()
