"""Numerics of the §Perf optimization paths vs their baselines."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import PipelineState, make_inputs
from repro.models.config import ShapeConfig
from repro.models.layers import attention_core
from repro.models.transformer import forward, init_params
from repro.train.loop import make_loss_fn

TINY = ShapeConfig("tiny", "train", 64, 2)


def test_bf16_scores_close_to_f32():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 8, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 2, 128, 64)), jnp.bfloat16)
    a32 = attention_core(q, k, v, causal=True, q_chunk=64,
                         score_dtype=jnp.float32).astype(jnp.float32)
    a16 = attention_core(q, k, v, causal=True, q_chunk=64,
                         score_dtype=jnp.bfloat16).astype(jnp.float32)
    rel = np.linalg.norm(np.asarray(a16 - a32)) / np.linalg.norm(np.asarray(a32))
    assert rel < 3e-2, rel     # bf16 probs: ~1% relative, fine for training


def test_bf16_scores_loss_close():
    cfg = get_smoke_config("yi_6b")
    cfg16 = dataclasses.replace(cfg, score_dtype="bfloat16")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_inputs(PipelineState(seed=0, step=0), cfg, TINY)
    l32 = float(make_loss_fn(cfg, None, q_chunk=32, loss_chunk=32)(params, batch))
    l16 = float(make_loss_fn(cfg16, None, q_chunk=32, loss_chunk=32)(params, batch))
    assert abs(l32 - l16) < 0.02 * abs(l32), (l32, l16)


def test_save_block_out_remat_same_gradients():
    cfg = get_smoke_config("granite_moe_1b_a400m")
    cfgS = dataclasses.replace(cfg, remat_policy="save_block_out")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_inputs(PipelineState(seed=0, step=0), cfg, TINY)
    g1 = jax.grad(make_loss_fn(cfg, None, q_chunk=32, loss_chunk=32))(params, batch)
    g2 = jax.grad(make_loss_fn(cfgS, None, q_chunk=32, loss_chunk=32))(params, batch)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_mamba_chunk_size_invariance():
    """SSD output must not depend on the chunk length (pure perf knob)."""
    cfg = get_smoke_config("mamba2_13b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_inputs(PipelineState(seed=0, step=0), cfg, TINY)
    outs = []
    for chunk in (8, 16, 64):
        c = dataclasses.replace(cfg, mamba=dataclasses.replace(cfg.mamba,
                                                               chunk=chunk))
        h, _ = forward(params, batch["tokens"], c, None, q_chunk=32)
        outs.append(np.asarray(h, np.float32))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-2, atol=2e-3)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-2, atol=2e-3)
