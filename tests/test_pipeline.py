"""Substep-pipelined asynchrony == the serial issue order (DESIGN.md §12).

Pins the acceptance criteria of the pipeline work: the pipelined sharded
driver (cut-level gather issued before the remaining sharded M2L levels,
root-tree sweep deferred to the gather's first consumption, next
substep's packed P2P exchange issued as soon as the rebinned particles
exist) matches the unpipelined driver — and the serial driver — to f32
roundoff on SlabPlan and BlockPlan, with ``use_kernels`` on and off, at
P in {4, 6}; the prefetched-halo route is BIT-exact against the inline
exchange.  Structural pins: the gather's issue depth (compute ops
between issue and first use in the lowered StableHLO, which preserves
trace order) must grow under pipelining while collective counts stay
EQUAL (the prefetch replaces the exchange, never duplicates it), and
degenerate plan axes ship raw-width strips with zero ppermutes on the
single-rank axis.  Fault-injection interplay: a transient halo fault
with an exchange in flight across the substep boundary still recovers
bit-exactly via the plain-retry rung.

Multidevice cases run in subprocesses because jax locks the device count
at first init and the rest of the suite must see exactly 1 CPU device.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core.cost_model import (ModelParams, comm_overlap_effective,
                                   work_root_tree, work_upward)
from repro.core.fmm import flops_estimate
from repro.core.plan import (block_plan_from_counts, plan_comm_cost,
                             plan_from_counts)
from repro.core.quadtree import build_tree
from repro.core.vortex import lamb_oseen_particles
from repro.launch.hlo_analysis import collective_issue_depths


def _run(body: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", body],
                          capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


_SLAB_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import parallel_fmm as pf
    from repro.core.cost_model import ModelParams
    from repro.core.fmm import fmm_velocity
    from repro.core.plan import SlabPlan, plan_from_counts
    from repro.core.quadtree import build_tree
    from repro.core.stepper import rk2_step
    from repro.core.vortex import lamb_oseen_particles
    from repro.launch.hlo_analysis import collective_issue_depths

    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    pos, gamma, sigma = lamb_oseen_particles(160)
    tree, index = build_tree(pos, gamma, level=5, sigma=sigma)
    serial = np.asarray(fmm_velocity(tree, p=12))
    params = ModelParams(level=5, cut=4, p=12, slots=tree.slots)
    model = plan_from_counts(index.counts, params, 4, method="model")
    # thin plan: 2-row boundary bands are ALL rim; the pipeline's deferred
    # root-tree consumption must still see the same gathered cut level
    thin = SlabPlan(level=5, row0=(0, 2, 16, 30), rows=(2, 14, 14, 2))
    for plan in (model, thin):
        for use_kernels in (False, True):
            got = {}
            for pipe in (False, True):
                w = np.asarray(pf.parallel_fmm_velocity(
                    tree, 12, mesh, use_kernels=use_kernels, plan=plan,
                    pipeline=pipe))
                err = np.linalg.norm(w - serial) / np.linalg.norm(serial)
                print(f"rows={plan.rows} kernels={use_kernels} "
                      f"pipeline={pipe} rel_err={err:.3e}")
                assert err < 1e-5, (plan.rows, use_kernels, pipe, err)
                got[pipe] = w
            d = np.linalg.norm(got[True] - got[False]) / \\
                max(np.linalg.norm(got[False]), 1e-30)
            assert d < 1e-6, (plan.rows, use_kernels, d)

    # prefetched-halo route is BIT-exact vs the inline exchange
    pre = pf.parallel_fmm_p2p_prefetch(tree, mesh=mesh, plan=model)
    w_pre = np.asarray(pf.parallel_fmm_velocity(
        tree, 12, mesh, plan=model, pipeline=True, p2p_halo=pre))
    w_inl = np.asarray(pf.parallel_fmm_velocity(
        tree, 12, mesh, plan=model, pipeline=True))
    assert np.array_equal(w_pre, w_inl)

    # full RK2 step: pipelined issue order == pre-pipeline ordering
    outs = {}
    for pipe in (False, True):
        t2 = rk2_step(tree, 1e-4, p=12, mesh=mesh, plan=model,
                      pipeline=pipe)[0]
        outs[pipe] = np.asarray(t2.z)
    assert np.array_equal(outs[True], outs[False])

    # issue-order pin: the cut-level all_gather must be issued with a
    # deeper consumption window under pipelining, at EQUAL collective
    # counts (the prefetch replaces the exchange, never duplicates it)
    depths = {}
    for pipe in (False, True):
        text = jax.jit(lambda tr: pf.parallel_fmm_evaluate(
            tr, 12, mesh=mesh, plan=model, pipeline=pipe)).lower(
                tree).as_text()
        depths[pipe] = collective_issue_depths(text)
    ag_on = max(depths[True]["all_gather"], default=0)
    ag_off = max(depths[False]["all_gather"], default=0)
    assert ag_on > ag_off, (ag_on, ag_off)
    assert len(depths[True]["all_gather"]) == \\
        len(depths[False]["all_gather"])
    assert len(depths[True]["collective_permute"]) == \\
        len(depths[False]["collective_permute"])
    print("gather issue depth:", ag_on, "was", ag_off)
    print("OK")
""")


_BLOCK_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core.cost_model import ModelParams
    from repro.core.fmm import fmm_velocity
    from repro.core.parallel_fmm import parallel_fmm_velocity
    from repro.core.plan import BlockPlan, block_plan_from_counts
    from repro.core.quadtree import build_tree
    from repro.core.vortex import lamb_oseen_particles

    mesh6 = Mesh(np.array(jax.devices()[:6]), ("data",))
    pos, gamma, sigma = lamb_oseen_particles(160)
    tree, index = build_tree(pos, gamma, level=5, sigma=sigma)
    serial = np.asarray(fmm_velocity(tree, p=12))
    params = ModelParams(level=5, cut=4, p=12, slots=tree.slots)
    b23 = block_plan_from_counts(index.counts, params, (2, 3), method="model")
    # minimum-size boundary tiles: whole tiles are rim on both axes, so
    # every deferred sharded-M2L level reads ghosts exchanged before the
    # gather was issued
    skew = BlockPlan(level=5, row0=(0, 2, 22), rows=(2, 20, 10),
                     col0=(0, 30), cols=(30, 2))
    for plan in (b23, skew):
        for use_kernels in (False, True):
            got = {}
            for pipe in (False, True):
                w = np.asarray(parallel_fmm_velocity(
                    tree, 12, mesh6, use_kernels=use_kernels, plan=plan,
                    pipeline=pipe))
                err = np.linalg.norm(w - serial) / np.linalg.norm(serial)
                print(f"rows={plan.rows} cols={plan.cols} "
                      f"kernels={use_kernels} pipeline={pipe} "
                      f"rel_err={err:.3e}")
                assert err < 1e-5, (plan.rows, use_kernels, pipe, err)
                got[pipe] = w
            d = np.linalg.norm(got[True] - got[False]) / \\
                max(np.linalg.norm(got[False]), 1e-30)
            assert d < 1e-6, (plan.rows, use_kernels, d)
    print("OK")
""")


_DEGENERATE_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import re
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core import parallel_fmm as pf

    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    rmax = cmax = 8
    s = 3
    spec = P("data", None, None, None)
    kw = {pf._CHECK_KW: False} if pf._CHECK_KW else {}
    rng = np.random.default_rng(0)
    packed = jnp.asarray(rng.normal(size=(4 * rmax, cmax, 5, s)), jnp.float32)

    def shapes_of(grid):
        fn = lambda x: pf._tile_halo(x, 1, rmax, cmax, "data", grid)
        sm = pf._shard_map(fn, mesh=mesh, in_specs=(spec,),
                           out_specs=spec, **kw)
        text = jax.jit(sm).lower(packed).as_text()
        perm = [l for l in text.splitlines() if "collective_permute" in l]
        widths = set()
        for l in perm:
            for t in re.findall(r"tensor<([0-9]+)x([0-9]+)x[0-9x]*f32", l):
                widths.add(int(t[1]))
        return len(perm), widths

    # 2x2: both axes exchange -> 4 ppermutes; row strips carry the
    # column-extended width (cmax + 2)
    n22, w22 = shapes_of((2, 2))
    assert n22 == 4, n22
    assert cmax + 2 in w22, w22
    # 4x1 slab: the column axis is single-rank -> only the 2 row
    # ppermutes remain and the strips are RAW width (no +2 padding)
    n41, w41 = shapes_of((4, 1))
    assert n41 == 2, n41
    assert w41 == {cmax}, w41
    # 1x4: the row axis is single-rank -> only the 2 column ppermutes
    n14, w14 = shapes_of((1, 4))
    assert n14 == 2, n14

    # value pin: the buffer keeps the padded (rmax+2, cmax+2) shape the
    # consumers index into; only the STRIPS shrank.  Interior of each
    # tile is the tile's own data, untouched, and the degenerate column
    # halo stays zero
    out = np.asarray(jax.jit(pf._shard_map(
        lambda x: pf._tile_halo(x, 1, rmax, cmax, "data", (4, 1)),
        mesh=mesh, in_specs=(spec,), out_specs=spec, **kw))(packed))
    assert out.shape == (4 * (rmax + 2), cmax + 2, 5, s)
    for d in range(4):
        r0 = d * (rmax + 2)
        np.testing.assert_array_equal(
            out[r0 + 1: r0 + 1 + rmax, 1: 1 + cmax],
            np.asarray(packed[d * rmax:(d + 1) * rmax]))
        assert (out[r0: r0 + rmax + 2, 0] == 0).all()
        assert (out[r0: r0 + rmax + 2, cmax + 1] == 0).all()
        # row halos carry the neighbor tiles' edge rows
        if d > 0:
            np.testing.assert_array_equal(
                out[r0, 1: 1 + cmax],
                np.asarray(packed[d * rmax - 1]))
        if d < 3:
            np.testing.assert_array_equal(
                out[r0 + 1 + rmax, 1: 1 + cmax],
                np.asarray(packed[(d + 1) * rmax]))
    print("OK")
""")


_FAULT_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core.stepper import VortexStepper
    from repro.core.faults import FaultInjector, FaultSpec
    from repro.core import health as hw

    mesh = Mesh(np.array(jax.devices()), ("data",))
    rng = np.random.default_rng(1)
    pos = 0.02 + 0.96 * rng.random((300, 2))
    gamma = rng.standard_normal(300) * 0.1
    KW = dict(sigma=0.02, p=6, dt=0.002, mesh=mesh, pipeline=True)

    def run(faults=None, steps=3):
        st = VortexStepper(pos, gamma, faults=faults, **KW)
        recs = [st.step() for _ in range(steps)]
        return st, recs

    st0, _ = run()
    z0 = np.asarray(st0.tree.z)
    # transient halo corruption lands while substep 2's prefetched
    # exchange is already in flight across the substep boundary; the
    # health word must still merge it in, and the plain-retry rung
    # re-runs the identical pipelined program from the intact pre-step
    # tree -> BIT-exact vs the uninjected pipelined run
    for site in ("halo_nan", "tile_corrupt"):
        st, recs = run(FaultInjector(FaultSpec(site, step=2)))
        assert recs[1].recovered == "retry_1", (site, recs[1])
        assert recs[1].health != 0, site
        assert hw.ok(hw.unpack(recs[1].health)), site
        assert np.array_equal(np.asarray(st.tree.z), z0), site
        assert recs[0].recovered == "" and recs[2].recovered == "", site
    print("OK")
""")


def test_pipeline_matches_unpipelined_slab_4dev():
    """Pipelined == unpipelined == serial on 4 devices, SlabPlan, both
    kernel routes, thin all-rim bands included; prefetched halo bit-exact;
    RK2 step value-identical across issue orders; gather issue-depth and
    equal-collective pins (acceptance-pinned)."""
    _run(_SLAB_BODY)


def test_pipeline_matches_unpipelined_block_6dev():
    """Pipelined == unpipelined == serial on 6 devices, BlockPlan (2x3 and
    thin 2-row/2-col boundary tiles), both kernel routes."""
    _run(_BLOCK_BODY)


def test_degenerate_axis_exchange_is_minimal():
    """Single-rank plan axes ship NO ppermutes and raw-width strips
    (satellite bugfix: slab plans used to pay the column-extended width
    on their row strips); 2x2 keeps the full 4-ppermute exchange."""
    _run(_DEGENERATE_BODY)


def test_pipeline_fault_interplay_recovers_bit_exact():
    """A transient fault injected while the cross-substep exchange is in
    flight still recovers via plain retry, bit-exact — recovery semantics
    are applied at the consumer, not the prefetch site."""
    _run(_FAULT_BODY)


# ---------------------------------------------------------------------------
# Host-side: issue-depth parser and the pipeline-aware cost model
# ---------------------------------------------------------------------------


_TOY_HLO = textwrap.dedent("""
    module @toy {
      func.func public @main(%arg0: tensor<4x8xf32>) -> tensor<4x8xf32> {
        %0 = "stablehlo.all_gather"(%arg0) : (tensor<4x8xf32>) -> tensor<16x8xf32>
        %1 = stablehlo.dot_general %arg0, %arg0, contracting_dims = [1] x [1]
        %2 = stablehlo.add %1, %1 : tensor<4x4xf32>
        %3 = stablehlo.dot_general %2, %2, contracting_dims = [1] x [1]
        %4 = "stablehlo.collective_permute"(%3) : (tensor<4x4xf32>) -> tensor<4x4xf32>
        %5 = stablehlo.dot_general %4, %4, contracting_dims = [1] x [1]
        %6 = stablehlo.slice %0 [0:4, 0:8] : (tensor<16x8xf32>) -> tensor<4x8xf32>
        return %6 : tensor<4x8xf32>
      }
    }
""")


def test_collective_issue_depths_parser():
    d = collective_issue_depths(_TOY_HLO)
    # %0 (all_gather) is first consumed by %6: three dot_generals between
    assert d["all_gather"] == [3]
    # %4 (permute) is consumed by the very next dot_general: depth 0
    assert d["collective_permute"] == [0]
    # elementwise glue (%2 add) never counts toward depth
    d2 = collective_issue_depths(_TOY_HLO, compute=("add",))
    assert d2["all_gather"] == [1]


def _lamb_setup(level=5):
    pos, gamma, sigma = lamb_oseen_particles(120)
    tree, index = build_tree(pos, gamma, level=level, sigma=sigma)
    params = ModelParams(level=level, cut=4, p=10, slots=tree.slots)
    return index.counts, params


def test_pipeline_enlarges_hiding_budget():
    """pipeline=True adds root-tree + upward flops to the hiding budget:
    the comm residue can only shrink, and stays between the overlapped
    and serial prices."""
    counts, params = _lamb_setup()
    for plan in (plan_from_counts(counts, params, 4, method="model"),
                 block_plan_from_counts(counts, params, (2, 2),
                                        method="model")):
        piped = plan_comm_cost(plan, counts, params, overlap=True,
                               pipeline=True)
        plain = plan_comm_cost(plan, counts, params, overlap=True,
                               pipeline=False)
        serial = plan_comm_cost(plan, counts, params, overlap=False)
        assert (piped <= plain + 1e-12).all()
        assert (plain <= serial + 1e-12).all()
        assert serial.sum() > 0


def test_comm_overlap_effective_extra_hide():
    params = ModelParams(level=5, cut=4, p=10, slots=8)
    assert comm_overlap_effective(100.0, 40.0, params) == 60.0
    assert comm_overlap_effective(100.0, 40.0, params, extra_hide=30.0) == 30.0
    assert comm_overlap_effective(100.0, 40.0, params, extra_hide=1e9) == 0.0
    # the extra budget is an overlap feature: serial pricing ignores it
    assert comm_overlap_effective(100.0, 40.0, params, overlap=False,
                                  extra_hide=1e9) == 100.0


def test_work_root_tree_and_upward_terms():
    params = ModelParams(level=6, cut=3, p=10, slots=8)
    rt = work_root_tree(params)
    up = work_upward(params, leaf_boxes=64.0)
    assert rt > 0 and up > 0
    # deeper cut -> more replicated root-tree levels -> more hidden work
    deeper = ModelParams(level=6, cut=4, p=10, slots=8)
    assert work_root_tree(deeper) > rt


def test_flops_estimate_pipeline_census():
    base = flops_estimate(5, 8, 10)
    assert base["gather_overlap_flops"] == 0.0
    assert base["p2p_prefetch_rounds"] == 0.0
    sh = flops_estimate(5, 8, 10, grid=(2, 2), cut=2)
    assert sh["p2p_prefetch_rounds"] == 1.0
    expect = sum(4 ** l for l in range(3, 6)) * 27 * 10 * 10 * 6.0
    assert sh["gather_overlap_flops"] == expect
    # windows, not work: the stage total is unchanged
    assert sh["total"] == base["total"]
