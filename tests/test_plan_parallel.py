"""Partition-driven sharded FMM == serial FMM, on 4 forced host devices.

The acceptance-pinned criterion: ``parallel_fmm_velocity`` with a
*non-uniform* SlabPlan (4 virtual devices, Lamb-Oseen particles) matches
the serial ``fmm_velocity`` to f32 roundoff with both ``use_kernels``
settings, and the model plan's Eq-20 min/max modeled-load metric strictly
beats the uniform plan's on that distribution.

Runs in a subprocess because jax locks the device count at first init and
the rest of the suite must see exactly 1 CPU device.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core.fmm import fmm_velocity
from repro.core.parallel_fmm import parallel_fmm_velocity
from repro.core.plan import SlabPlan
from repro.core.quadtree import build_tree

_SUBPROCESS_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core.cost_model import ModelParams
    from repro.core.fmm import fmm_velocity
    from repro.core.parallel_fmm import parallel_fmm_velocity
    from repro.core.plan import (SlabPlan, plan_from_counts, plan_stats,
                                 uniform_plan)
    from repro.core.quadtree import build_tree
    from repro.core.stepper import VortexStepper
    from repro.core.vortex import lamb_oseen_particles

    assert len(jax.devices()) == 4
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))

    pos, gamma, sigma = lamb_oseen_particles(160)
    tree, index = build_tree(pos, gamma, level=5, sigma=sigma)
    serial = np.asarray(fmm_velocity(tree, p=12))

    params = ModelParams(level=5, cut=4, p=12, slots=tree.slots)
    model = plan_from_counts(index.counts, params, 4, method="model")
    uniform = uniform_plan(5, 4)
    assert not model.is_uniform, model.rows
    lb_model = plan_stats(model, index.counts, params)["load_balance"]
    lb_uniform = plan_stats(uniform, index.counts, params)["load_balance"]
    print(f"LB model={lb_model:.3f} uniform={lb_uniform:.3f}")
    assert lb_model > lb_uniform, (lb_model, lb_uniform)

    # a deliberately skewed handcrafted plan exercises the unequal-band
    # padding + halo-at-valid-edge machinery hardest; the thin plan pins
    # minimum-height (2-row) bands at both domain boundaries, where the
    # M2L halo spans the entire neighbor band
    skewed = SlabPlan(level=5, row0=(0, 4, 10, 20), rows=(4, 6, 10, 12))
    thin = SlabPlan(level=5, row0=(0, 2, 16, 30), rows=(2, 14, 14, 2))
    for plan in (uniform, model, skewed, thin):
        for use_kernels in (False, True):
            par = np.asarray(parallel_fmm_velocity(
                tree, 12, mesh, use_kernels=use_kernels, plan=plan))
            err = np.linalg.norm(par - serial) / np.linalg.norm(serial)
            print(f"rows={plan.rows} kernels={use_kernels} rel_err={err:.3e}")
            assert err < 1e-5, (plan.rows, use_kernels, err)

    # nparts that does NOT divide the grid side: plans make it legal
    mesh3 = Mesh(np.array(jax.devices()[:3]), ("data",))
    plan3 = plan_from_counts(index.counts, params, 3, method="model")
    par = np.asarray(parallel_fmm_velocity(tree, 12, mesh3, plan=plan3))
    err = np.linalg.norm(par - serial) / np.linalg.norm(serial)
    print(f"P=3 rows={plan3.rows} rel_err={err:.3e}")
    assert err < 1e-5, err

    # regression: plan=None with n % P != 0 must fall back to uniform_plan
    # (which handles non-dividing device counts via base/extra bands) — the
    # old driver raised "grid side must split into even slabs" here
    tree3, _ = build_tree(pos[::64], gamma[::64], level=3, sigma=sigma)
    serial3 = np.asarray(fmm_velocity(tree3, p=8))
    par = np.asarray(parallel_fmm_velocity(tree3, 8, mesh3, plan=None))
    err = np.linalg.norm(par - serial3) / np.linalg.norm(serial3)
    print(f"P=3 level=3 no-plan rel_err={err:.3e}")
    assert err < 1e-5, err

    # dynamic stepper runs end to end under the mesh
    st = VortexStepper(pos, gamma, sigma, p=8, dt=0.004, mesh=mesh,
                       plan_method="model", dynamic=True, replan_every=2)
    for _ in range(2):
        rec = st.step()
    assert rec.step == 2 and rec.seconds > 0
    print("OK")
""")


_BLOCK_SUBPROCESS_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core.cost_model import ModelParams
    from repro.core.fmm import fmm_velocity
    from repro.core.parallel_fmm import parallel_fmm_velocity
    from repro.core.plan import (BlockPlan, block_plan_from_counts,
                                 plan_stats, uniform_block_plan)
    from repro.core.quadtree import build_tree
    from repro.core.stepper import VortexStepper
    from repro.core.vortex import lamb_oseen_particles

    assert len(jax.devices()) == 6
    mesh4 = Mesh(np.array(jax.devices()[:4]), ("data",))
    mesh6 = Mesh(np.array(jax.devices()[:6]), ("data",))

    pos, gamma, sigma = lamb_oseen_particles(160)
    tree, index = build_tree(pos, gamma, level=5, sigma=sigma)
    serial = np.asarray(fmm_velocity(tree, p=12))
    params = ModelParams(level=5, cut=4, p=12, slots=tree.slots)

    # 2x2 (square) and 2x3 (non-square) model grids — both kernel routes;
    # the skewed handcrafted plan pins minimum-size (2-row/2-col) tiles on
    # the domain boundary, where the two-axis halo + corner strips span the
    # entire neighbor tile
    b22 = block_plan_from_counts(index.counts, params, (2, 2), method="model")
    b23 = block_plan_from_counts(index.counts, params, (2, 3), method="model")
    skew = BlockPlan(level=5, row0=(0, 2, 22), rows=(2, 20, 10),
                     col0=(0, 30), cols=(30, 2))
    lb23 = plan_stats(b23, index.counts, params)["load_balance"]
    lbu = plan_stats(uniform_block_plan(5, (2, 3)),
                     index.counts, params)["load_balance"]
    print(f"LB block-2x3 model={lb23:.3f} uniform={lbu:.3f}")
    assert lb23 >= lbu, (lb23, lbu)
    for mesh, plan in ((mesh4, b22), (mesh6, b23), (mesh6, skew)):
        for use_kernels in (False, True):
            par = np.asarray(parallel_fmm_velocity(
                tree, 12, mesh, use_kernels=use_kernels, plan=plan))
            err = np.linalg.norm(par - serial) / np.linalg.norm(serial)
            print(f"grid={plan.grid} rows={plan.rows} cols={plan.cols} "
                  f"kernels={use_kernels} rel_err={err:.3e}")
            assert err < 1e-5, (plan.grid, use_kernels, err)

    # dynamic 2-D stepper runs end to end under the 2x3 mesh
    st = VortexStepper(pos, gamma, sigma, p=8, dt=0.004, mesh=mesh6,
                       plan_method="model", dynamic=True, plan_grid=(2, 3),
                       replan_every=2)
    for _ in range(2):
        rec = st.step()
    assert rec.step == 2 and rec.seconds > 0
    assert st.plan.grid == (2, 3)
    print("OK")
""")


def test_plan_driven_parallel_matches_serial_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_BODY],
                          capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


def test_block_plan_parallel_matches_serial_multidevice():
    """BlockPlan on 2x2 and 2x3 device grids == serial to f32, both kernel
    routes, plus the dynamic 2-D stepper (acceptance-pinned)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _BLOCK_SUBPROCESS_BODY],
                          capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


def test_nonuniform_plan_single_device_matches_serial():
    """The plan machinery (reshard, padding, masking) with P=1 bands."""
    rng = np.random.default_rng(3)
    pos = rng.uniform(0.02, 0.98, size=(1200, 2))
    gamma = rng.normal(size=1200)
    tree, _ = build_tree(pos, gamma, level=4, sigma=0.02)
    serial = np.asarray(fmm_velocity(tree, p=10))
    plan = SlabPlan(level=4, row0=(0,), rows=(16,))
    par = np.asarray(parallel_fmm_velocity(tree, 10, None, plan=plan))
    err = np.linalg.norm(par - serial) / np.linalg.norm(serial)
    assert err < 1e-5


def test_plan_validation_errors():
    import pytest

    rng = np.random.default_rng(4)
    pos = rng.uniform(0.02, 0.98, size=(200, 2))
    tree, _ = build_tree(pos, rng.normal(size=200), level=4, sigma=0.02)
    with pytest.raises(ValueError, match="plan level"):
        parallel_fmm_velocity(tree, 8, None,
                              plan=SlabPlan(level=3, row0=(0,), rows=(8,)))
    with pytest.raises(ValueError, match="bands for"):
        parallel_fmm_velocity(tree, 8, None,
                              plan=SlabPlan(level=4, row0=(0, 8), rows=(8, 8)))
