"""Property-based tests (hypothesis) for the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dependency (pip install repro[hypothesis])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import cost_model as cm
from repro.core.partition import (Graph, build_subtree_graph,
                                  load_balance_metric, morton_order, partition)
from repro.core.quadtree import (build_tree, gather_particle_values,
                                 morton_decode, morton_encode)


# ---------------------------------------------------------------------------
# Morton indexing
# ---------------------------------------------------------------------------


@given(st.lists(st.tuples(st.integers(0, 2**15 - 1), st.integers(0, 2**15 - 1)),
                min_size=1, max_size=64))
def test_morton_roundtrip(coords):
    ix = np.array([c[0] for c in coords], dtype=np.uint32)
    iy = np.array([c[1] for c in coords], dtype=np.uint32)
    dx, dy = morton_decode(morton_encode(ix, iy))
    np.testing.assert_array_equal(dx, ix)
    np.testing.assert_array_equal(dy, iy)


@given(st.integers(1, 5))
def test_morton_order_locality(k):
    """Consecutive z-order ids at any level stay within the same parent quad
    for 3 of every 4 steps (z-curve locality)."""
    n = 1 << k
    order = morton_order(n)
    iy, ix = np.divmod(order, n)
    same_parent = ((ix[1:] // 2 == ix[:-1] // 2) &
                   (iy[1:] // 2 == iy[:-1] // 2))
    assert same_parent.sum() >= len(order) * 3 // 4 - 1


# ---------------------------------------------------------------------------
# Tree build / gather
# ---------------------------------------------------------------------------


@given(st.integers(1, 400), st.integers(2, 5), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_tree_roundtrip_property(n, level, seed):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.001, 0.999, size=(n, 2))
    gamma = rng.normal(size=n)
    tree, index = build_tree(pos, gamma, level, sigma=0.02)
    assert int(np.asarray(tree.mask).sum()) == n           # no particle lost
    back = gather_particle_values(np.asarray(tree.z), index)
    np.testing.assert_allclose(back.real, pos[:, 0], atol=1e-6)
    np.testing.assert_allclose(back.imag, pos[:, 1], atol=1e-6)
    # charges preserved: sum of q equals sum(gamma)/(2 pi i)
    total_q = np.asarray(tree.q)[np.asarray(tree.mask)].sum()
    np.testing.assert_allclose(total_q, gamma.sum() / (2j * np.pi), rtol=1e-4)


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


@given(st.integers(0, 1000), st.integers(2, 40))
def test_leaf_work_monotone_in_particles(n_i, p):
    assert cm.work_leaf(np.array([n_i + 1.0]), p)[0] > \
        cm.work_leaf(np.array([float(n_i)]), p)[0]


@given(st.integers(3, 6), st.integers(2, 3), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_subtree_work_conserves_total(level, cut, seed):
    """Sum of per-subtree work == work of the whole tree (no leakage)."""
    rng = np.random.default_rng(seed)
    n = 1 << level
    counts = rng.integers(0, 6, size=(n, n))
    params = cm.ModelParams(level=level, cut=cut, p=8,
                            slots=max(int(counts.max()), 1))
    per_subtree = cm.work_subtree(counts, params)
    nonleaf_boxes = sum(4 ** (l - cut) for l in range(cut, level)) * 4 ** cut
    direct = (cm.work_leaf(counts.astype(float), 8,
                           neighbor_counts=cm.neighbor_count_sum(counts)).sum()
              + nonleaf_boxes * cm.work_nonleaf(8))
    np.testing.assert_allclose(per_subtree.sum(), direct, rtol=1e-12)


# ---------------------------------------------------------------------------
# Partitioner
# ---------------------------------------------------------------------------


@given(st.integers(2, 4), st.integers(2, 16), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_partition_invariants(cut, nparts, seed):
    rng = np.random.default_rng(seed)
    n = 1 << (cut + 2)
    counts = rng.integers(0, 8, size=(n, n))
    params = cm.ModelParams(level=cut + 2, cut=cut, p=8,
                            slots=max(int(counts.max()), 1))
    g = build_subtree_graph(counts, params)
    if nparts > g.num_vertices:
        return
    for method in ("uniform-sfc", "sfc", "model"):
        a = partition(g, nparts, method=method)
        assert a.shape == (g.num_vertices,)
        assert a.min() >= 0 and a.max() < nparts
        # every part non-empty (required for SPMD shard assignment)
        assert len(np.unique(a)) == nparts
        assert 0.0 < load_balance_metric(g, a, nparts) <= 1.0


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_refinement_never_hurts_balance_much(seed):
    """model refinement stays within the imbalance tolerance of its seed."""
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 20, size=(32, 32))
    params = cm.ModelParams(level=5, cut=3, p=8,
                            slots=max(int(counts.max()), 1))
    g = build_subtree_graph(counts, params)
    seed_a = partition(g, 4, method="sfc")
    model_a = partition(g, 4, method="model")
    loads = g.part_loads(model_a, 4)
    # refined max load stays under (1 + tol) * avg (the FM cap)
    assert loads.max() <= 1.06 * loads.mean() or \
        g.part_loads(seed_a, 4).max() <= loads.max() + 1e-9
