"""Cross-process fault tolerance (DESIGN.md §14): watchdog deadlines,
heartbeat staleness, epoch-barrier agreement, restart policy, and the
kill/hang drills.

The drills spawn REAL OS processes via ``launch/supervisor.py`` — rank
workers running the jitted ``VortexStepper`` in lock-step — SIGKILL (or
SIGSTOP) one mid-step, and assert the run completes on the survivors with
the post-restore trajectory bit-identical to a clean shrunken-world run
resumed from the same checkpoint.  One jax compilation cache is shared
across every subprocess of the module so each distinct world size
compiles once per session.
"""
import json
import os
import signal
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

from repro.parallel import resilience as rz
from repro.core.faults import FaultInjector, FaultSpec, PROC_SITES, SITES
from repro.launch.supervisor import Supervisor, SupervisorConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# one drill scenario for the whole module: the 3-rank generation of the
# kill drill, the hang drill's gen 0, and the clean comparison run all
# lower the identical program, so the shared cache pays each world size's
# compile once
N_SIDE, P, DT = 20, 4, 0.004


@pytest.fixture(scope="module")
def jax_cache(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("jaxcache"))
    old = os.environ.get("JAX_COMPILATION_CACHE_DIR")
    os.environ["JAX_COMPILATION_CACHE_DIR"] = d
    os.environ["JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS"] = "0"
    yield d
    if old is None:
        os.environ.pop("JAX_COMPILATION_CACHE_DIR", None)
    else:
        os.environ["JAX_COMPILATION_CACHE_DIR"] = old


# ---------------------------------------------------------------------------
# deadline computation (satellite: tight but not flappy)
# ---------------------------------------------------------------------------


def test_step_deadline_units():
    pol = rz.WatchdogPolicy(margin=3.0, slack=2.0, min_deadline=1.0,
                            compile_grace=300.0)
    # no estimate yet -> compile grace
    assert rz.step_deadline(pol, None) == 300.0
    # steady state: margin * predicted + slack
    assert rz.step_deadline(pol, 0.5) == pytest.approx(3.5)
    # floored (slack=0 so the floor binds)
    assert rz.step_deadline(
        rz.WatchdogPolicy(margin=3.0, slack=0.0, min_deadline=1.0),
        1e-6) == 1.0
    # a step known to retrace gets the grace window even with an estimate
    assert rz.step_deadline(pol, 0.5, compiled=False) == 300.0
    # Eq 13-15 calibration path
    assert rz.predicted_from_calibration(2e-6, 1e5) == pytest.approx(0.2)
    assert rz.predicted_from_calibration(None, 1e5) is None
    assert rz.predicted_from_calibration(2e-6, None) is None
    assert rz.predicted_from_calibration(0.0, 1e5) is None


def test_watchdog_deadline_no_false_positives_20_steps(tmp_path, jax_cache):
    """Cost-model-derived deadlines across 20 clean steps at 4 ranks
    (4 forced host devices): every step finishes inside the deadline
    computed BEFORE it ran (no false positive would ever trip the
    barrier), and post-warmup deadlines are tight (far below the compile
    grace window)."""
    body = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json
        from repro.core.stepper import VortexStepper
        from repro.core.vortex import lamb_oseen_particles
        from repro.launch.mesh import make_world_mesh
        from repro.parallel import resilience as rz

        pol = rz.WatchdogPolicy(margin=3.0, slack=0.5, min_deadline=0.05,
                                compile_grace=900.0)
        pos, gamma, sigma = lamb_oseen_particles({N_SIDE})
        st = VortexStepper(pos, gamma, sigma, p={P}, dt={DT},
                           mesh=make_world_mesh(4), plan_method="model")
        rows, compiled = [], False
        for _ in range(20):
            deadline = rz.step_deadline(pol, st.predicted_step_seconds(),
                                        compiled)
            rec = st.step()
            compiled = not (rec.replanned or rec.releveled)
            rows.append((deadline, rec.seconds))
        print("ROWS " + json.dumps(rows))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", body], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    rows = json.loads(r.stdout.split("ROWS ", 1)[1].splitlines()[0])
    assert len(rows) == 20
    for i, (deadline, seconds) in enumerate(rows):
        assert seconds < deadline, \
            f"step {i + 1}: false positive ({seconds:.3f}s > {deadline:.3f}s)"
    # tight after warmup: the last deadlines come from measured steady
    # state, nowhere near the compile grace fallback
    tail = [d for d, _ in rows[5:]]
    assert max(tail) < 900.0 / 4, f"deadlines never tightened: {tail}"


# ---------------------------------------------------------------------------
# heartbeat staleness (satellite: SIGSTOPped peer)
# ---------------------------------------------------------------------------


def test_heartbeat_staleness_sigstop_peer(tmp_path):
    """A SIGSTOPped beater (pure stdlib subprocess — no jax) goes overdue
    against its OWN published deadline within bounded time; a beating peer
    never does."""
    beater = textwrap.dedent(f"""
        import sys, time
        sys.path.insert(0, {SRC!r})
        from repro.parallel import resilience as rz
        hb = rz.Heartbeat({str(tmp_path)!r}, 0, 1)
        while True:
            hb.beat(step=3, phase="step", deadline=0.5)
            time.sleep(0.05)
    """)
    p = subprocess.Popen([sys.executable, "-c", beater])
    pol = rz.WatchdogPolicy(compile_grace=30.0)
    wd = rz.Watchdog(str(tmp_path), 0, ranks=(1,), policy=pol)
    try:
        deadline = time.time() + 10
        while rz.read_heartbeat(str(tmp_path), 0, 1) is None:
            assert time.time() < deadline, "beater never started"
            time.sleep(0.02)
        time.sleep(0.3)
        assert wd.overdue() == {}          # beating -> fresh
        assert wd.fresh() == (1,)
        os.kill(p.pid, signal.SIGSTOP)     # hung, not dead
        t0 = time.time()
        while not wd.overdue():
            assert time.time() - t0 < 5.0, \
                "stopped beater never went overdue"
            time.sleep(0.05)
        over = wd.overdue()
        assert 1 in over and over[1] > 0.0
        assert wd.fresh() == ()
        # hb file still shows the stopped rank's final published deadline
        assert rz.read_heartbeat(str(tmp_path), 0, 1)["deadline"] == 0.5
    finally:
        os.kill(p.pid, signal.SIGCONT)
        p.kill()
        p.wait()


def test_watchdog_never_beat_rank(tmp_path):
    pol = rz.WatchdogPolicy(compile_grace=0.2)
    wd = rz.Watchdog(str(tmp_path), 0, ranks=(0,), policy=pol)
    assert wd.overdue() == {}              # inside the boot grace
    time.sleep(0.3)
    assert 0 in wd.overdue()               # grace expired, no beat ever


# ---------------------------------------------------------------------------
# epoch barrier + membership agreement (satellite: concurrent detection)
# ---------------------------------------------------------------------------


def test_epoch_barrier_passes_and_times_out(tmp_path):
    d = str(tmp_path)
    b0 = rz.EpochBarrier(d, 0, 0, (0, 1), poll_interval=0.01)
    b1 = rz.EpochBarrier(d, 0, 1, (0, 1), poll_interval=0.01)
    t = threading.Thread(target=lambda: b1.wait(0, timeout=5.0))
    t.start()
    b0.wait(0, timeout=5.0)
    t.join(timeout=5.0)
    assert not t.is_alive()
    # rank 1 never reaches epoch 1 -> bounded timeout naming the laggard;
    # on_poll fires every poll so a blocked waiter can keep its heartbeat
    # fresh (a stale WAITER would be indistinguishable from a hung rank)
    beats = []
    with pytest.raises(rz.BarrierTimeout) as ei:
        b0.wait(1, timeout=0.3, on_poll=lambda: beats.append(time.time()))
    assert ei.value.missing == (1,)
    assert ei.value.epoch == 1
    assert len(beats) >= 5


def test_barrier_aborts_on_fault_announcement(tmp_path):
    """A waiting rank aborts IMMEDIATELY when a fault announcement lands —
    detection is not serialized behind the full timeout."""
    d = str(tmp_path)
    b0 = rz.EpochBarrier(d, 0, 0, (0, 1), poll_interval=0.01)
    caught = {}

    def waiter():
        try:
            b0.wait(0, timeout=60.0)
        except rz.FaultAnnounced as e:
            caught["dead"] = e.dead
            caught["t"] = time.time()

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.2)
    t0 = time.time()
    rz.announce_fault(d, 0, [1], epoch=0, by="supervisor")
    t.join(timeout=5.0)
    assert not t.is_alive()
    assert caught["dead"] == (1,)
    assert caught["t"] - t0 < 2.0          # nowhere near the 60s timeout


def test_concurrent_detection_single_decision(tmp_path):
    """Two ranks detect the same death concurrently: identical proposals,
    both announce (first writer wins), both agree on the same survivor
    view, and exactly ONE decision is published."""
    d = str(tmp_path)
    results, anns = {}, {}

    def detect(rank):
        anns[rank] = rz.announce_fault(d, 0, [2], epoch=7, by=rank)
        results[rank] = rz.agree_view(d, 0, rank, [0, 1], 7, timeout=5.0)

    ts = [threading.Thread(target=detect, args=(r,)) for r in (0, 1)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=30.0)
        assert not t.is_alive()
    assert results[0] == results[1] == (0, 1)
    # the announcement is idempotent: both detectors saw one winner
    assert anns[0] == anns[1]
    assert anns[0]["dead"] == [2]
    decisions = [n for n in os.listdir(os.path.join(d, "gen_0"))
                 if n.startswith("decision_") and n.endswith(".json")]
    assert decisions == ["decision_7.json"]
    assert rz.read_decision(d, 0)["survivors"] == [0, 1]


def test_divergent_views_converge_by_intersection(tmp_path):
    """One detector still believes a doubly-dead rank is alive; the views
    are intersected and re-voted at epoch+1 until identical."""
    d = str(tmp_path)
    results = {}

    def vote(rank, proposed):
        results[rank] = rz.agree_view(d, 0, rank, proposed, 3,
                                      timeout=1.0, max_rounds=4)

    ts = [threading.Thread(target=vote, args=(0, [0, 1])),
          threading.Thread(target=vote, args=(1, [0, 1, 3]))]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=30.0)
        assert not t.is_alive()
    # rank 3 never votes: dropped on timeout / intersection; both converge
    assert results[0] == results[1] == (0, 1)


def test_agreement_rejects_selfless_proposal(tmp_path):
    with pytest.raises(rz.AgreementError):
        rz.agree_view(str(tmp_path), 0, 2, [0, 1], 0, timeout=0.2)


# ---------------------------------------------------------------------------
# restart policy
# ---------------------------------------------------------------------------


def test_restart_policy_backoff_and_floor():
    pol = rz.RestartPolicy(max_restarts=3, backoff_base=0.5,
                           backoff_max=4.0, min_world=2)
    assert pol.backoff(0) == 0.0
    assert [pol.backoff(n) for n in (1, 2, 3, 4, 5)] == \
        [0.5, 1.0, 2.0, 4.0, 4.0]


def test_restart_policy_quarantine_and_rejoin():
    pol = rz.RestartPolicy(rejoin_after=2, flap_limit=2)
    hist = {2: [0]}
    # quarantine not yet expired (faulted in gen 0, now entering gen 1)
    assert pol.next_ranks([0, 1, 3], 0, hist) == (0, 1, 3)
    # expired after rejoin_after generations -> rank 2 rejoins
    assert pol.next_ranks([0, 1, 3], 2, hist) == (0, 1, 2, 3)
    # a flapping rank (faulted flap_limit times) never rejoins
    assert pol.next_ranks([0, 1, 3], 9, {2: [0, 5]}) == (0, 1, 3)
    # rejoin disabled by default
    assert rz.RestartPolicy().next_ranks([0, 1], 9, hist) == (0, 1)


def test_mesh_fault_error_carries_reports():
    rep = rz.ProcFaultReport(generation=1, epoch=4, dead=(2,), hung=(),
                             world_before=4, world_after=3, restore_step=2,
                             detected_by="supervisor", detect_seconds=0.4)
    err = rz.MeshFaultError("max restarts exceeded", [rep])
    assert err.faults == (rep,)
    assert "max restarts exceeded" in str(err)
    assert "dead=[2]" in str(err)
    assert rep.describe()["world_after"] == 3


# ---------------------------------------------------------------------------
# FaultSpec promotion to process granularity
# ---------------------------------------------------------------------------


def test_proc_fault_sites():
    assert set(PROC_SITES) <= set(SITES)
    kill = FaultSpec(site="proc_kill", step=4, device=2)
    hang = FaultSpec(site="proc_hang", step=3, device=1, sticky=True)
    assert kill.rank == 2 and hang.rank == 1
    inj = FaultInjector(kill, hang,
                        FaultSpec(site="teleport", step=4),
                        FaultSpec(site="time_inflate", step=4))
    assert inj.proc_faults() == (kill, hang)
    # proc (and host) sites NEVER enter the jitted step's static tuple
    active = inj.active(4)
    assert all(f.site not in PROC_SITES + ("time_inflate",) for f in active)
    assert [f.site for f in active] == ["teleport"]
    with pytest.raises(ValueError):
        FaultSpec(site="proc_reboot", step=1)


# ---------------------------------------------------------------------------
# the drills (tentpole acceptance)
# ---------------------------------------------------------------------------


def _drill_config(tmp_path, world, target, min_world):
    return SupervisorConfig(
        world=world, target_step=target, coord_dir=str(tmp_path),
        n_side=N_SIDE, p=P, dt=DT, checkpoint_every=2, checkpoint_keep=8,
        watchdog=rz.WatchdogPolicy(compile_grace=900.0, teardown_grace=30.0,
                                   agree_timeout=120.0),
        restart=rz.RestartPolicy(min_world=min_world, backoff_base=0.1),
        max_wall=3000.0)


def _load_result(path):
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def _clean_shrunken_run(ckpt_dir, world, restore_step, target, out_path,
                        env):
    """Reference trajectory: ONE fresh process, ``world`` forced devices,
    from_checkpoint at the drill's restore step, stepped to the target."""
    body = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={world}"
        import numpy as np
        from repro.core.stepper import VortexStepper
        from repro.launch.mesh import make_world_mesh

        st = VortexStepper.from_checkpoint(
            {ckpt_dir!r}, mesh=make_world_mesh({world}),
            step={restore_step}, plan_method="model", checkpoint_every=0)
        while st.step_count < {target}:
            st.step()
        np.savez({out_path!r}, z=np.asarray(st.tree.z),
                 q=np.asarray(st.tree.q), mask=np.asarray(st.tree.mask))
        print("clean ok")
    """)
    r = subprocess.run([sys.executable, "-c", body], capture_output=True,
                       text=True, timeout=1200, env=env)
    assert r.returncode == 0, f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"


def _drill_env():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    return env


def _worker_logs(coord_dir):
    out = []
    for root, _, names in os.walk(coord_dir):
        for n in sorted(names):
            if n.endswith(".log"):
                with open(os.path.join(root, n), errors="replace") as f:
                    out.append(f"--- {os.path.join(root, n)}\n" + f.read())
    return "\n".join(out)


def test_kill_drill_4_ranks_sigkill_completes_on_3(tmp_path, jax_cache,
                                                   monkeypatch):
    """THE acceptance drill: 4 ranks, rank 2 SIGKILLed mid-step 4; the run
    completes on 3 survivors and the post-restore trajectory is
    bit-identical to a clean 3-rank run resumed from the same
    checkpoint."""
    for k, v in _drill_env().items():
        monkeypatch.setenv(k, v)
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    cfg = _drill_config(tmp_path, world=4, target=6, min_world=2)
    sup = Supervisor(cfg, faults=FaultInjector(
        FaultSpec(site="proc_kill", step=4, device=2)))
    try:
        result = sup.run()
    except rz.MeshFaultError as e:
        pytest.fail(f"drill did not survive: {e}\n"
                    f"{_worker_logs(str(tmp_path))}")
    assert result.success and result.final_step == 6

    # exactly one shrink: 4 -> 3 with rank 2 gone
    assert len(result.faults) == 1
    rep = result.faults[0]
    assert 2 in rep.dead and rep.hung == ()
    assert (rep.world_before, rep.world_after) == (4, 3)
    assert rep.restore_step is not None
    assert result.ranks == (0, 1, 3)
    # MTTR pieces are finite (the bench row publishes these)
    assert rep.detect_seconds is not None and rep.detect_seconds < 120.0
    assert rep.restore_seconds is not None and rep.restore_seconds > 0.0

    # every survivor finished with the SAME state...
    outs = [_load_result(os.path.join(result.result_dir, f"result_{r}.npz"))
            for r in result.ranks]
    for o in outs[1:]:
        for k in ("z", "q", "mask"):
            np.testing.assert_array_equal(o[k], outs[0][k])

    # ...bit-identical to a clean 3-rank run from the same checkpoint
    clean_path = str(tmp_path / "clean3.npz")
    _clean_shrunken_run(cfg.checkpoint_dir, 3, rep.restore_step, 6,
                        clean_path, _drill_env())
    clean = _load_result(clean_path)
    for k in ("z", "q", "mask"):
        np.testing.assert_array_equal(outs[0][k], clean[k],
                                      err_msg=f"{k} diverged from the "
                                      f"clean shrunken-world run")


def test_hang_drill_sigstop_detected_within_deadline(tmp_path, jax_cache,
                                                     monkeypatch):
    """Hung-not-dead: rank 1 of 3 SIGSTOPped mid-step.  The watchdog (not
    CI's killer) must detect the stale heartbeat in bounded time, the
    survivors shrink to 2, and the run completes."""
    for k, v in _drill_env().items():
        monkeypatch.setenv(k, v)
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    cfg = _drill_config(tmp_path, world=3, target=5, min_world=1)
    sup = Supervisor(cfg, faults=FaultInjector(
        FaultSpec(site="proc_hang", step=3, device=1)))
    try:
        result = sup.run()
    except rz.MeshFaultError as e:
        pytest.fail(f"hang drill did not survive: {e}\n"
                    f"{_worker_logs(str(tmp_path))}")
    assert result.success and result.final_step == 5
    assert len(result.faults) == 1
    rep = result.faults[0]
    assert 1 in (rep.hung + rep.dead)      # stale heartbeat, not an exit
    assert rep.world_after == 2
    assert result.ranks == (0, 2)
    # bounded detection: stale-heartbeat deadlines, not the compile grace
    # window and certainly not the CI job timeout
    assert rep.detect_seconds is not None and rep.detect_seconds < 120.0
